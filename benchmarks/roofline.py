"""§Roofline reporting: read the dry-run artifacts and emit the three-term
roofline table (one row per arch x shape x mesh).

    python -m benchmarks.roofline                  # CSV rows (bench format)
    python -m benchmarks.roofline --markdown       # EXPERIMENTS.md table
"""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="single", tag=""):
    rows = []
    for fn in sorted(glob.glob(os.path.join(ART, f"dryrun_{mesh}_*.json"))):
        with open(fn) as f:
            d = json.load(f)
        if d.get("tag", "") != tag:
            continue
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"])
                             if d["shape"] in SHAPE_ORDER else 9))
    return rows


def run():
    out = []
    for d in load("single"):
        if d.get("status") != "ok":
            name = f"roofline_{d['arch']}_{d['shape']}"
            out.append((name, 0.0, f"ERROR {d.get('error', '')[:60]}"))
            continue
        r = d["roofline"]
        out.append(
            (
                f"roofline_{d['arch']}_{d['shape']}",
                r["compute_s"] * 1e6,
                f"mem={r['memory_s']*1e6:.0f}us coll={r['collective_s']*1e6:.0f}us "
                f"dominant={r['dominant']} mfr={d.get('model_flops_ratio', 0):.2f}",
            )
        )
    return out


def markdown(tag=""):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO flops | step bound (s) | HW util* |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in load("single", tag):
        if d.get("status") != "ok":
            lines.append(
                f"| {d['arch']} | {d['shape']} | — | — | — | ERROR | — | — | — |"
            )
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        util = r["compute_s"] / bound if bound else 0.0
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{d.get('model_flops_ratio', 0):.2f} | {bound:.4f} | {util:.1%} |"
        )
    lines.append("")
    lines.append(
        "*HW util = compute term / dominant term = the MFU this step would "
        "achieve if the dominant roofline bound is met."
    )
    return "\n".join(lines)


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        tag = ""
        if "--tag" in sys.argv:
            tag = sys.argv[sys.argv.index("--tag") + 1]
        print(markdown(tag))
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
