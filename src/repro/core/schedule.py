"""Folding-set schedule model for the 2-parallel NTT -> iNTT cascade
(paper §III, Eq 1/2, Tables I/II, Fig 17; timing Eq 11-13).

The container has no FPGA, so contribution 1 is validated at the level the
paper itself argues it: the *schedule*.  We model the 2-parallel folded
pipeline exactly:

* Forward NTT last PE (PE_{m-1}) emits butterfly-pair k at clock
  (k - 1) mod n/2  (Table I row PE_{m-1}: folding order l -> node l+1).
* The iNTT's first stage needs, for its drawn-DFG node j (which pairs
  frequencies j and j + n/2), the *physical* pair produced by forward
  node rev(j): the forward output wire 2k carries frequency brv(k) and
  wire 2k+1 carries brv(k) + n/2.
* Therefore consuming with the **bit-reversed folding set** (Table II:
  folding order l -> node <l+1>) makes every pair's consumption clock
  equal its production clock — zero buffer, zero added latency.  With the
  *same* folding set as the NTT (the conventional choice) the pairs must
  wait, requiring an n/4-deep delay-switch-delay buffer and n/4 extra
  clocks (Fig 17).

``simulate_cascade`` computes production/consumption clocks and the
buffer occupancy for both schedules; tests assert the paper's claims
(0 vs n/4) for a sweep of n.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ntt import bit_reverse_indices


# --------------------------------------------------------------------------
# Timing model (Eq 11-13)
# --------------------------------------------------------------------------


def bpp_cycles(n: int) -> int:
    """Block processing period of the 2-parallel multiplier (Eq 11)."""
    return n // 2


def latency_cycles(n: int, t_pipe: int = 0, with_shuffle: bool = False) -> int:
    """Latency of one modular polynomial multiplication (Eq 12); the
    conventional shuffled cascade pays an extra n/4 (Fig 17)."""
    extra = n // 4 if with_shuffle else 0
    return (n - 2) + extra + t_pipe


def total_cycles(n: int, L: int, t_pipe: int = 0, with_shuffle: bool = False) -> int:
    """Clock cycles for L back-to-back multiplications (Eq 13)."""
    return latency_cycles(n, t_pipe, with_shuffle) + bpp_cycles(n) * L


# --------------------------------------------------------------------------
# Folding sets (Tables I and II)
# --------------------------------------------------------------------------


def ntt_folding_order(n: int, s: int) -> np.ndarray:
    """Table I: node index processed by PE_s at each folding clock l.
    PE_s at clock l processes node (2^{m-s-1} + l) mod n/2."""
    m = n.bit_length() - 1
    half = n // 2
    l = np.arange(half)
    return (2 ** (m - s - 1) + l) % half if s < m - 1 else (l + 1) % half


def intt_folding_order(n: int, s: int) -> np.ndarray:
    """Table II: node processed by iNTT PE_s at folding clock l; <x> is the
    bit-reverse over (m-1) bits."""
    m = n.bit_length() - 1
    half = n // 2
    brv = bit_reverse_indices(half)
    l = np.arange(half)
    if s == 0:
        return brv[(l + 1) % half]
    return brv[(2 - 2**s + l) % half]


# --------------------------------------------------------------------------
# Cascade buffer simulation
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CascadeSim:
    n: int
    max_buffer_pairs: int  # peak # of product pairs parked between NTT & iNTT
    added_latency: int  # extra clocks before iNTT can start consuming


def simulate_cascade(n: int, bit_reversed_intt: bool = True) -> CascadeSim:
    """Clock-accurate production/consumption simulation at the NTT->iNTT
    boundary of the 2-parallel cascade."""
    half = n // 2
    brv_half = bit_reverse_indices(half)
    # Production: forward PE_{m-1} emits physical pair k at clock (k-1) mod half.
    prod_clock = np.empty(half, dtype=np.int64)
    order = ntt_folding_order(n, n.bit_length() - 2)  # PE_{m-1} row
    for clock, node in enumerate(order):
        prod_clock[node] = clock
    # Consumption: iNTT drawn-node j needs physical pair rev(j).
    cons_clock = np.empty(half, dtype=np.int64)
    if bit_reversed_intt:
        intt_order = intt_folding_order(n, 0)  # Table II PE_0
    else:
        intt_order = (np.arange(half) + 1) % half  # same folding as NTT
    for clock, node in enumerate(intt_order):
        cons_clock[brv_half[node]] = clock
    # A pair produced at clock p and consumed at clock c >= p occupies the
    # buffer during [p, c).  If any c < p the schedule is infeasible in the
    # same period; it slips by `slip` full periods handled as added latency.
    slip = int(np.max(prod_clock - cons_clock).clip(min=0))
    cons_eff = cons_clock + slip
    occupancy = np.zeros(2 * half + 1, dtype=np.int64)
    for p, c in zip(prod_clock, cons_eff):
        occupancy[p] += 1
        occupancy[c] -= 1
    peak = int(np.max(np.cumsum(occupancy))) - 1  # pass-through pair not buffered
    return CascadeSim(n=n, max_buffer_pairs=max(peak, 0), added_latency=slip)
