"""Paper Tables VI/VII: the end-to-end PaReNTT modular polynomial
multiplier at the paper's operating point (n=4096, 180-bit q, t=6/v=30).

Reported: BPP / latency cycle model at 240 MHz (the paper's clock), the
measured CPU wall-clock of the full jit pipeline through the PUBLIC
backend-dispatch layer for BOTH the ``jnp`` and ``pallas_fused``
datapaths, a bit-exactness check of the fused path against the Python
bigint oracle, and the 49.2x latency comparison against Roy [7]
re-derived from the cycle model.

Note on absolute numbers: off-TPU the Pallas kernels run in *interpret*
mode, so their wall-clock here measures the emulation, not the silicon;
the comparison that matters off-TPU is the HBM-traffic model at the
bottom (the fused cascade's win) plus bit-exactness of both paths.
"""
import random
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.core import schedule as sched

FREQ = 240e6  # paper's post-pipelining clock


def _time_backend(p, backend: str, za, zb, iters: int = 3) -> float:
    """us per polynomial through ParenttMultiplier on one backend."""
    m = pm.ParenttMultiplier(p, backend=backend)
    batch = za.shape[0]
    jax.block_until_ready(m(za, zb))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(m(za, zb))
    return (time.perf_counter() - t0) / iters / batch * 1e6


def run():
    out = []
    n = 4096
    bpp = sched.bpp_cycles(n)
    lat = sched.latency_cycles(n, t_pipe=152)  # paper reports 4246-4254
    out.append(
        (
            "tableVII_cycle_model",
            lat / FREQ * 1e6,
            f"bpp={bpp}cyc({bpp/FREQ*1e6:.1f}us) latency={lat}cyc "
            f"({lat/FREQ*1e6:.1f}us) paper=17.4-17.7us",
        )
    )
    roy_cycles = 196_003  # paper's normalized Roy [7] latency (§V-D)
    out.append(
        (
            "tableVII_vs_roy_hpca19",
            roy_cycles / 225e6 * 1e6,
            f"roy=871.1us ours={lat/FREQ*1e6:.1f}us "
            f"reduction={roy_cycles/225e6/(lat/FREQ):.1f}x (paper: 49.2x)",
        )
    )
    # bit-exactness gate: the fused Pallas path vs the Python bigint
    # oracle (and the schoolbook), at a size where the O(n^2) oracle is
    # fast.  Runs through the same public dispatch layer as the timing.
    pchk = params_mod.make_params(n=256, t=6, v=30)
    rchk = random.Random(0)
    ca = [rchk.randrange(pchk.q) for _ in range(pchk.n)]
    cb = [rchk.randrange(pchk.q) for _ in range(pchk.n)]
    fused_ints = pm.ParenttMultiplier(pchk, backend="pallas_fused").multiply_ints(ca, cb)
    oracle_ints = pm.oracle_multiply(ca, cb, pchk)
    if fused_ints != oracle_ints or fused_ints != pm.schoolbook_negacyclic(ca, cb, pchk.q):
        raise AssertionError("pallas_fused != bigint oracle at n=256/t=6/v=30")
    out.append(
        (
            "fused_vs_bigint_oracle_n256",
            0.0,
            "pallas_fused bit-exact vs oracle_multiply + schoolbook (n=256, t=6, v=30)",
        )
    )
    # measured: full pipeline (t=6, v=30, n=4096), both datapaths through
    # the public backend-dispatch layer
    p = params_mod.make_params(n=4096, t=6, v=30)
    rng = np.random.default_rng(0)
    batch = 4
    za = jnp.asarray(
        rng.integers(0, 1 << 30, size=(batch, n, p.plan.seg_count))
    )
    zb = jnp.asarray(rng.integers(0, 1 << 30, size=(batch, n, p.plan.seg_count)))
    us = _time_backend(p, "jnp", za, zb)
    out.append(
        (
            "tableVI_measured_polymul_t6_v30_jnp",
            us,
            f"per 4096-coeff 180-bit modular polymul (backend=jnp, CPU, batch={batch})",
        )
    )
    us_fused = _time_backend(p, "pallas_fused", za, zb)
    mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
    out.append(
        (
            "tableVI_measured_polymul_t6_v30_pallas_fused",
            us_fused,
            f"per 4096-coeff 180-bit modular polymul (backend=pallas_fused, "
            f"{mode} mode, batch={batch})",
        )
    )
    # throughput in NTT-channel butterflies/s for context
    butterflies = 6 * 3 * (n // 2) * 12  # t * (2 NTT + iNTT) * n/2 * log n
    out.append(
        (
            "tableVI_butterfly_rate",
            0.0,
            f"{butterflies / (us/1e6) / 1e6:.1f}M butterflies/s on 1 CPU core",
        )
    )
    # Table VI's t=4 vs t=6 comparison, both measured in-JAX (t=4/v=45
    # rides the digit-split wide datapath of core/wide.py)
    from repro.core import wide as wide_mod

    p4 = params_mod.make_params(n=4096, t=4, v=45)
    m4 = wide_mod.WideParenttMultiplier(p4)
    za4 = jnp.asarray(
        rng.integers(0, 1 << 45, size=(batch, n, p4.plan.seg_count))
    )
    zb4 = jnp.asarray(rng.integers(0, 1 << 45, size=(batch, n, p4.plan.seg_count)))
    iters = 3
    f4 = jax.jit(m4.__call__)
    jax.block_until_ready(f4(za4, zb4))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f4(za4, zb4))
    us4 = (time.perf_counter() - t0) / iters / batch * 1e6
    out.append(
        (
            "tableVI_measured_polymul_t4_v45",
            us4,
            f"wide digit-split datapath; t6/t4 time ratio={us/us4:.2f} "
            f"(paper: t=6 wins on ABP/power)",
        )
    )
    # beyond-paper (§Perf P4): fused cascade HBM-traffic model.  Unfused:
    # NTT(a) out, NTT(b) out, product in x2/out, iNTT in = 6 HBM crossings
    # of (rows, n) int64 per channel beyond inputs/outputs; fused kernel
    # keeps everything VMEM-resident: only a/b in + p out cross HBM.
    row_bytes = 8 * n
    unfused = 8 * row_bytes  # 2 in + 2 ntt-out + prod(w+r via 2 reads) + intt in/out
    fused = 3 * row_bytes  # a in, b in, p out
    out.append(
        (
            "perfP4_fused_cascade_traffic",
            0.0,
            f"unfused={unfused/1024:.0f}KiB/row-channel fused={fused/1024:.0f}KiB "
            f"reduction={unfused/fused:.1f}x (plus the paper's zero-permutation property)",
        )
    )
    return out
