"""Crypto serving launcher: synthetic mixed-preset polymul traffic
through the batching :class:`repro.serve.crypto_engine.PolymulEngine`.

    PYTHONPATH=src python -m repro.launch.serve_crypto --requests 32 --slots 8

The traffic generator interleaves heterogeneous presets (default: the
paper's two operating points scaled to CPU-friendly n) and draws
Poisson arrivals at ``--rate`` requests/s (0 = closed loop: everything
arrives at t=0).  Requests are bucketed by plan config and served in
padded micro-batches; the report shows throughput, latency percentiles
and the bucket/trace accounting.

Mesh mode: ``--mesh 2x2`` shards dispatches over a (data, model) host
mesh — run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(or on real multi-device hardware).  int64-width presets only.

Robustness knobs (PR 8): ``--deadline-ms`` sheds late requests with a
typed error, ``--max-pending`` bounds the queue (backpressure), and
``--async-dispatch`` serves from the engine's background dispatcher
thread; the summary reports goodput and the shed/retry/breaker
counters next to throughput.

Observability (PR 10): ``--span-log FILE`` traces every request into a
JSONL span log (inspect with ``repro.launch.obs_report``), ``--json``
emits the summary as a machine-readable record with the ``--seed``
stamped in, so a run is reproducible from its own output.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro import api, obs
from repro.serve.crypto_engine import PolymulEngine


def parse_preset(spec: str) -> dict:
    """'n:t:v' (e.g. '64:3:30') -> plan kwargs."""
    try:
        n, t, v = (int(x) for x in spec.split(":"))
    except ValueError as e:
        raise SystemExit(f"bad --presets entry {spec!r}: want n:t:v") from e
    return {"n": n, "t": t, "v": v}


def build_mesh(spec: str):
    """'DxM' -> Mesh over the first D*M host devices as (data, model)."""
    from jax.sharding import Mesh

    d, m = (int(x) for x in spec.lower().split("x"))
    devs = jax.devices()
    if len(devs) < d * m:
        raise SystemExit(
            f"--mesh {spec} needs {d * m} devices but only {len(devs)} "
            f"are visible; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={d * m} for a host mesh"
        )
    return Mesh(np.array(devs[: d * m]).reshape(d, m), ("data", "model"))


def make_traffic(plans, requests: int, rate: float, rng) -> list:
    """[(arrival_s, plan, za, zb)] — presets interleaved round-robin,
    exponential inter-arrival gaps at ``rate`` req/s (0 = all at t=0)."""
    out, now = [], 0.0
    for i in range(requests):
        pl = plans[i % len(plans)]
        if rate > 0:
            now += float(rng.exponential(1.0 / rate))
        shape = (pl.n, pl.config.seg_count)
        out.append(
            (
                now,
                pl,
                rng.integers(0, 1 << pl.v, size=shape),
                rng.integers(0, 1 << pl.v, size=shape),
            )
        )
    return out


def drive(eng: PolymulEngine, traffic, *, deadline_s=None) -> list:
    """Open-loop event pump: submit each request at its arrival time,
    stepping the engine whenever work is pending (with the background
    dispatcher running, submission is all this loop does).  Returns
    futures."""
    futs = []
    i = 0
    t0 = time.perf_counter()
    while i < len(traffic) or eng.pending():
        now = time.perf_counter() - t0
        while i < len(traffic) and traffic[i][0] <= now:
            _, pl, za, zb = traffic[i]
            futs.append(eng.submit(pl, za, zb, deadline=deadline_s))
            i += 1
        if eng.running:
            time.sleep(1e-3)
        elif eng.pending():
            eng.step()
        elif i < len(traffic):
            time.sleep(min(traffic[i][0] - now, 1e-3))
    if eng.running:
        eng.run_until_idle()
    return futs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8,
                    help="fixed batch slots per dispatch")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests/s (0 = closed loop)")
    ap.add_argument("--presets", default="64:3:30,64:4:45",
                    help="comma-separated n:t:v presets, interleaved")
    ap.add_argument("--mesh", default="",
                    help="'DxM' (data x model) host mesh, e.g. 2x2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--donate", action="store_true",
                    help="donate operand buffers to XLA per dispatch")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; late requests are shed "
                         "with DeadlineExceededError (0 = none)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="bound the submission queue (0 = unbounded); "
                         "submit then blocks for space")
    ap.add_argument("--async-dispatch", action="store_true",
                    help="serve from the background dispatcher thread "
                         "instead of stepping inline")
    ap.add_argument("--span-log", default=None, metavar="FILE",
                    help="trace every request into this JSONL span log "
                         "(inspect with repro.launch.obs_report)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as a JSON record (seed "
                         "stamped in) instead of text")
    args = ap.parse_args(argv)

    mesh = build_mesh(args.mesh) if args.mesh else None
    span_log = obs.SpanLog(args.span_log) if args.span_log else None
    eng = PolymulEngine(batch_slots=args.slots, mesh=mesh,
                        donate=args.donate,
                        max_pending=args.max_pending or None,
                        span_log=span_log)
    plans = [eng.plan(**parse_preset(s)) for s in args.presets.split(",")]
    rng = np.random.default_rng(args.seed)

    # warm: one padded dispatch per distinct config so the timed run
    # measures serving, not compilation
    for pl in plans:
        shape = (pl.n, pl.config.seg_count)
        eng.submit(pl, np.zeros(shape, np.int64), np.zeros(shape, np.int64))
    eng.run_until_idle()
    eng.reset_stats()

    if args.async_dispatch:
        eng.start()
    traffic = make_traffic(plans, args.requests, args.rate, rng)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    t0 = time.perf_counter()
    futs = drive(eng, traffic, deadline_s=deadline_s)
    wall = time.perf_counter() - t0
    if args.async_dispatch:
        eng.stop()

    snap = eng.snapshot()
    ok = [f for f in futs if f.exception() is None]
    served = snap["served"]
    if span_log is not None:
        span_log.flush()
    if args.json:
        lat = np.array([f.latency_s for f in ok]) * 1e3
        record = {
            "seed": args.seed,
            "requests": len(futs),
            "rate_rps": args.rate,
            "presets": args.presets,
            "wall_s": wall,
            "served_rps": served / wall,
            "goodput_rps": len(ok) / wall,
            "latency_p50_ms": (
                float(np.percentile(lat, 50)) if lat.size else None
            ),
            "latency_p99_ms": (
                float(np.percentile(lat, 99)) if lat.size else None
            ),
            "jit_traces": eng.trace_count,
            "span_log": args.span_log,
            "snapshot": snap,
        }
        print(json.dumps(record, indent=1))
        return 0
    print(f"served {served}/{len(futs)} requests in {wall:.3f}s "
          f"({served / wall:.1f} req/s, goodput {len(ok) / wall:.1f} "
          f"req/s) [seed={args.seed}]")
    if ok:
        lat = np.array([f.latency_s for f in ok]) * 1e3
        print(f"latency p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
    print(f"dispatches={snap['dispatches']} "
          f"padded_slots={snap['padded_slots']} "
          f"jit_traces={eng.trace_count} "
          f"buckets={len({api.plan_key(p) for p in plans})}")
    print(f"shed={snap['shed']} retried={snap['retried']} "
          f"failed={snap['failed']} rejected={snap['rejected']} "
          f"dispatch_failures={snap['dispatch_failures']} "
          f"breaker_opened={snap['breaker_opened']} "
          f"breaker_recovered={snap['breaker_recovered']}")
    if mesh is not None:
        print(f"mesh axes={dict(mesh.shape)}")
    if args.span_log:
        print(f"span log: {args.span_log}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
