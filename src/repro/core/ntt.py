"""Low-complexity negative-wrapped-convolution NTT / iNTT (paper §II-D, Fig 1,
supplementary Eq 14-25) with the *no-shuffle cascade* (contribution 1).

Design notes
------------
* Forward transform: decimation-in-time (CT) butterflies with the weights
  psi_{2n}^{(2k+1)} merged into the twiddles (Eq 16-19).  Natural-order
  input -> **bit-reversed** output.
* Inverse transform retraces the forward flow graph in reverse stage order
  (first inverse stage undoes the forward's last), with the inverse
  twiddles psi^{-brv(h+i)} and the factor n^{-1} folded in: every stage
  halves both butterfly outputs with the shift-and-conditional-add trick
  of Eq 24/25 (the paper's Fig 9 PE).  **Bit-reversed** input ->
  natural-order output.
* Because the pointwise product is order-agnostic, the cascade
  ``intt(ntt(a) * ntt(b))`` needs **zero permutations** — this is the
  data-flow-level content of the paper's different-folding-sets trick
  (the hardware folding/latency model itself lives in
  :mod:`repro.core.schedule`).
* Butterfly reduction: the scalar helpers live in
  :mod:`repro.core.modmath` (shared with the Pallas kernels so the two
  datapaths cannot drift).  When a configuration's moduli fit the
  63-bit-safe envelope (q < 2^31, uniform width — the paper's v=30
  preferred point), the butterfly multiply reduces with a precomputed
  per-channel Barrett constant instead of a generic ``%``.

All arithmetic is int64; residues must satisfy q < 2**31 so products fit
(the v<=30 fast path; the paper's preferred config).  The v=45 config is
served by the numpy-object oracle in :mod:`repro.core.polymul`.

Shapes: transforms operate on the last axis; any leading batch dims.  The
`*_channels` variants vmap over a leading RNS-channel axis with per-channel
moduli/tables; twiddles and moduli are device-resident (uploaded once per
table object, not per call).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import modmath
from repro.core import primes as primes_mod

# Re-exported so existing call sites (benchmarks, notebooks) keep working;
# the implementations live in modmath.
add_mod = modmath.add_mod
sub_mod = modmath.sub_mod
mul_mod = modmath.mul_mod
div2_mod = modmath.div2_mod


def bit_reverse_indices(n: int) -> np.ndarray:
    """Permutation p with p[i] = bit-reverse of i over log2(n) bits."""
    m = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    out = np.zeros_like(idx)
    for b in range(m):
        out |= ((idx >> b) & 1) << (m - 1 - b)
    return out


# --------------------------------------------------------------------------
# Four-step (Bailey) schedule: the SAME flow graph as the radix-2 loops,
# re-grouped for the TPU lane geometry.  View the length-n polynomial as
# an (n1, n2) tile (n2 = the lane-width factor): the first log2(n1)
# radix-2 stages pair at strides that are multiples of n2 — they are
# independent length-n1 column transforms whose twiddles are exactly the
# fwd[:n1] prefix (brv of i < n1 over log2(n) bits = brv_{n1}(i) * n2, so
# fwd[i] = (psi^{n2})^{brv_{n1}(i)}: the length-n1 NWC table for the root
# psi^{n2}).  The remaining log2(n2) stages pair INSIDE each row; after a
# tile transpose they pair along the sublane axis instead, with the
# twist correction merged into per-row twiddle tables (gather below) the
# same way the NWC weights psi are merged into the radix-2 twiddles —
# zero extra multiplies and bit-identical output order.  Result: no
# butterfly stage ever pairs along the lane axis at stride < n2.
# --------------------------------------------------------------------------


def four_step_split(n: int) -> tuple[int, int]:
    """(n1, n2) tile for the lane-aligned schedule: n2 = 128 (the TPU
    lane width) when n >= 256, else n // 2 so at least one column stage
    exists.  Requires n a power of two >= 4."""
    if n < 4 or n & (n - 1):
        raise ValueError(
            f"four_step schedule needs a power-of-two n >= 4, got n={n}"
        )
    n2 = 128 if n >= 256 else n // 2
    return n // n2, n2


def four_step_row_indices(n1: int, n2: int) -> np.ndarray:
    """(n2, n1) gather into a length-n stage table: the row-stage twiddle
    for transposed-tile entry (m', j) — m' = 2^k + l the DIT block index
    of a length-n2 transform, j the original row — is
    base[(n1 + j) * 2^k + l].  Applying this gather to ``fwd``/``inv``
    yields the twist-merged row tables; entry m' = 0 is never read (the
    stage loops slice [m : 2m] with m >= 1)."""
    idx = np.zeros((n2, n1), dtype=np.int64)
    for mp in range(1, n2):
        k = mp.bit_length() - 1
        low = mp - (1 << k)
        for j in range(n1):
            idx[mp, j] = ((n1 + j) << k) + low
    return idx


def stage_lane_strides(n: int, schedule: str) -> tuple[int, ...]:
    """Butterfly pair distance along the LANE (last tile) axis per stage
    of one transform — the structural definition the cost model's
    ``sublane_stages`` count is computed from.  radix2 pairs in the flat
    coefficient axis at strides n/2 .. 1; four_step pairs only along the
    sublane axis of its (n1, n2) / transposed (n2, n1) tiles, so its
    lane-axis distance is 0 at every stage."""
    stages = n.bit_length() - 1
    if schedule == "four_step":
        four_step_split(n)  # validate n
        return (0,) * stages
    if schedule != "radix2":
        raise ValueError(f"unknown concrete schedule {schedule!r}")
    return tuple(n >> (s + 1) for s in range(stages))


class NttTables(NamedTuple):
    """Per-modulus twiddle tables for the merged-weight NWC transforms."""

    q: int
    n: int
    psi: int  # primitive 2n-th root of unity mod q
    fwd: np.ndarray  # (n,)  fwd[i] = psi^{brv(i)}    (CT/DIT stage tables)
    inv: np.ndarray  # (n,)  inv[i] = psi^{-brv(i)}   (mirror-order inverse)
    half: int  # (q + 1) / 2, for the div-by-2 PE (Eq 24)
    mul_eps: int | None = None  # Barrett eps for residue products (q<2^31)
    mul_shifts: tuple[int, int] | None = None


@functools.lru_cache(maxsize=None)
def make_tables(q: int, n: int) -> NttTables:
    """Precompute twiddles (host-side Python bigints, cached)."""
    psi = primes_mod.root_of_unity(q, 2 * n)
    brv = bit_reverse_indices(n)
    fwd = np.array([pow(psi, int(b), q) for b in brv], dtype=np.int64)
    psi_inv = pow(psi, q - 2, q)
    inv = np.array([pow(psi_inv, int(b), q) for b in brv], dtype=np.int64)
    eps, shifts = modmath.mul_barrett_constants([q])
    return NttTables(
        q=q,
        n=n,
        psi=psi,
        fwd=fwd,
        inv=inv,
        half=(q + 1) // 2,
        mul_eps=int(eps[0]) if eps is not None else None,
        mul_shifts=shifts,
    )


# --------------------------------------------------------------------------
# Transforms (single modulus; q/half/eps scalars or 0-d arrays, shifts
# static python ints)
# --------------------------------------------------------------------------


def ntt_raw(a: jax.Array, fwd: jax.Array, q, eps=None, shifts=None) -> jax.Array:
    """Forward NWC NTT, natural-in, bit-reversed-out. Last-axis transform."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    m, t = 1, n
    while m < n:
        t //= 2
        w = fwd[m : 2 * m]  # static slice
        x = a.reshape(lead + (m, 2, t))
        u = x[..., 0, :]
        v = mul_mod(x[..., 1, :], w[:, None], q, eps, shifts)
        a = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-2)
        a = a.reshape(lead + (n,))
        m *= 2
    return a


def intt_raw(a: jax.Array, inv: jax.Array, q, half, eps=None, shifts=None) -> jax.Array:
    """Inverse NWC NTT, bit-reversed-in, natural-out; n^{-1} folded into the
    per-stage halving (paper Fig 9 / Eq 20-25)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    h, t = n // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        x = a.reshape(lead + (h, 2, t))
        u, v = x[..., 0, :], x[..., 1, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[:, None], q, eps, shifts)
        a = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-2)
        a = a.reshape(lead + (n,))
        h //= 2
        t *= 2
    return a


def ntt_raw_four_step(a, fwd, row_fwd, q, eps=None, shifts=None) -> jax.Array:
    """Forward NWC NTT via the lane-aligned four-step schedule —
    bit-identical to :func:`ntt_raw` (same flow graph, re-grouped).

    fwd: (n,) radix-2 table (columns use the [:n1] prefix); row_fwd:
    (n2, n1) twist-merged row tables (``fwd[four_step_row_indices(...)]``).
    Column stages pair along the n1 (sublane) axis; rows pair along the
    former n2 axis after the tile transpose — never along lanes."""
    n = a.shape[-1]
    n2, n1 = row_fwd.shape
    lead = a.shape[:-1]
    x = a.reshape(lead + (n1, n2))
    m, tc = 1, n1
    while m < n1:
        tc //= 2
        w = fwd[m : 2 * m]
        y = x.reshape(lead + (m, 2, tc, n2))
        u = y[..., 0, :, :]
        v = mul_mod(y[..., 1, :, :], w[:, None, None], q, eps, shifts)
        x = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-3)
        x = x.reshape(lead + (n1, n2))
        m *= 2
    xt = jnp.swapaxes(x, -1, -2)  # (n2, n1): row stages pair on sublanes
    m, tr = 1, n2
    while m < n2:
        tr //= 2
        wr = row_fwd[m : 2 * m]  # (m, n1): per-row twist-merged twiddles
        y = xt.reshape(lead + (m, 2, tr, n1))
        u = y[..., 0, :, :]
        v = mul_mod(y[..., 1, :, :], wr[:, None, :], q, eps, shifts)
        xt = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-3)
        xt = xt.reshape(lead + (n2, n1))
        m *= 2
    return jnp.swapaxes(xt, -1, -2).reshape(lead + (n,))


def intt_raw_four_step(a, inv, row_inv, q, half, eps=None, shifts=None) -> jax.Array:
    """Inverse mirror of :func:`ntt_raw_four_step` — bit-identical to
    :func:`intt_raw`.  Row stages (transposed tile) first, then column
    stages, retracing the forward flow in reverse stage order."""
    n = a.shape[-1]
    n2, n1 = row_inv.shape
    lead = a.shape[:-1]
    xt = jnp.swapaxes(a.reshape(lead + (n1, n2)), -1, -2)  # (n2, n1)
    h, tr = n2 // 2, 1
    while h >= 1:
        wr = row_inv[h : 2 * h]  # (h, n1)
        y = xt.reshape(lead + (h, 2, tr, n1))
        u, v = y[..., 0, :, :], y[..., 1, :, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), wr[:, None, :], q, eps, shifts)
        xt = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-3)
        xt = xt.reshape(lead + (n2, n1))
        h //= 2
        tr *= 2
    x = jnp.swapaxes(xt, -1, -2)  # back to (n1, n2)
    h, tc = n1 // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        y = x.reshape(lead + (h, 2, tc, n2))
        u, v = y[..., 0, :, :], y[..., 1, :, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[:, None, None], q, eps, shifts)
        x = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-3)
        x = x.reshape(lead + (n1, n2))
        h //= 2
        tc *= 2
    return x.reshape(lead + (n,))


def ntt(a: jax.Array, tables: NttTables) -> jax.Array:
    return ntt_raw(
        a, jnp.asarray(tables.fwd), tables.q, tables.mul_eps, tables.mul_shifts
    )


def intt(a: jax.Array, tables: NttTables) -> jax.Array:
    return intt_raw(
        a,
        jnp.asarray(tables.inv),
        tables.q,
        tables.half,
        tables.mul_eps,
        tables.mul_shifts,
    )


def negacyclic_mul(a: jax.Array, b: jax.Array, tables: NttTables) -> jax.Array:
    """The no-shuffle cascade: NTT(a) ⊙ NTT(b) -> iNTT, zero permutations."""
    fa = ntt(a, tables)
    fb = ntt(b, tables)
    prod = mul_mod(fa, fb, tables.q, tables.mul_eps, tables.mul_shifts)
    return intt(prod, tables)


# --------------------------------------------------------------------------
# Multi-channel (RNS) variants: leading axis = RNS channel, one modulus each.
# This is the paper's "t parallel residue datapaths"; under pjit the channel
# axis shards over the `model` mesh axis.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static-safe
class ChannelTables:
    """Stacked per-channel twiddle tables + Barrett mul constants, plus
    the four-step row-table layout and the Harvey lazy-reduction
    (Shoup) constants with their window bookkeeping.

    Host arrays are the canonical values; the ``*_d`` cached properties
    hold the device-resident copies, uploaded exactly once per table
    object (call sites must NOT re-wrap the host arrays in
    ``jnp.asarray`` — that is the per-call H2D re-upload this class
    exists to eliminate).
    """

    qs: np.ndarray  # (t,)
    fwd: np.ndarray  # (t, n)
    inv: np.ndarray  # (t, n)
    half: np.ndarray  # (t,)
    mul_eps: np.ndarray | None = None  # (t,) Barrett eps, None outside envelope
    mul_shifts: tuple[int, int] | None = None  # static shift pair
    # four-step layout: (t, n2, n1) twist-merged row tables (columns use
    # the fwd/inv [:, :n1] prefixes — no extra storage); None when n < 4
    fs_row_fwd: np.ndarray | None = None
    fs_row_inv: np.ndarray | None = None
    # Harvey lazy reduction: per-twiddle Shoup constants, same layouts as
    # their twiddle tables; None outside the 63-bit-safe lazy envelope
    fwd_shoup: np.ndarray | None = None
    inv_shoup: np.ndarray | None = None
    fs_row_fwd_shoup: np.ndarray | None = None
    fs_row_inv_shoup: np.ndarray | None = None
    lazy_window: int | None = None  # butterfly values stay in [0, window*q)
    shoup_beta: int | None = None  # static Shoup shift

    @property
    def n(self) -> int:
        return self.fwd.shape[-1]

    @property
    def t(self) -> int:
        return self.fwd.shape[0]

    @property
    def fs_split(self) -> tuple[int, int]:
        return four_step_split(self.n)

    def stage_bounds(self, inverse: bool = False):
        """Per-stage (value_bound, peak) in units of q under the lazy
        window — the bound bookkeeping validated at construction; None
        when lazy reduction is unavailable (strict butterflies keep
        everything canonical, bound 1)."""
        if self.lazy_window is None:
            return None
        return modmath.lazy_stage_bounds(
            self.lazy_window, self.n.bit_length() - 1, inverse=inverse
        )

    # -- device-resident copies, uploaded once at construction time.
    # Eager (not lazy/cached) on purpose: a lazy first touch could happen
    # inside a jit trace, where jnp.asarray yields a tracer that must not
    # be cached.  Constructed host-side, these are concrete device arrays
    # that close over traces as constants.
    def __post_init__(self):
        if self.lazy_window is not None:
            for q in np.atleast_1d(self.qs):
                modmath.validate_lazy_envelope(
                    int(q), self.lazy_window, self.shoup_beta
                )
        for name in (
            "qs",
            "fwd",
            "inv",
            "half",
            "mul_eps",
            "fs_row_fwd",
            "fs_row_inv",
            "fwd_shoup",
            "inv_shoup",
            "fs_row_fwd_shoup",
            "fs_row_inv_shoup",
        ):
            host = getattr(self, name)
            object.__setattr__(
                self, name + "_d", None if host is None else jnp.asarray(host)
            )


def make_channel_tables(qs, n: int) -> ChannelTables:
    tabs = [make_tables(int(q), n) for q in qs]
    eps, shifts = modmath.mul_barrett_constants([t.q for t in tabs])
    fwd = np.stack([t.fwd for t in tabs])
    inv = np.stack([t.inv for t in tabs])
    fs_row_fwd = fs_row_inv = None
    if n >= 4:
        idx = four_step_row_indices(*four_step_split(n))
        fs_row_fwd = fwd[:, idx]  # (t, n2, n1)
        fs_row_inv = inv[:, idx]
    window, beta = modmath.lazy_params([t.q for t in tabs])
    shoups = {}
    if window is not None:
        for name, tab in (
            ("fwd_shoup", fwd), ("inv_shoup", inv),
            ("fs_row_fwd_shoup", fs_row_fwd), ("fs_row_inv_shoup", fs_row_inv),
        ):
            if tab is not None:
                shoups[name] = np.stack(
                    [
                        modmath.shoup_constants(tab[i], int(t.q), beta)
                        for i, t in enumerate(tabs)
                    ]
                )
    return ChannelTables(
        qs=np.array([t.q for t in tabs], dtype=np.int64),
        fwd=fwd,
        inv=inv,
        half=np.array([t.half for t in tabs], dtype=np.int64),
        mul_eps=eps,
        mul_shifts=shifts,
        fs_row_fwd=fs_row_fwd,
        fs_row_inv=fs_row_inv,
        lazy_window=window,
        shoup_beta=beta,
        **shoups,
    )


def _eps_axes(ct: ChannelTables):
    """(eps array | dummy, vmap axis) — vmap needs a concrete operand."""
    if ct.mul_eps is None:
        return None, None
    return ct.mul_eps_d, 0


def ntt_channels(
    a: jax.Array, ct: ChannelTables, schedule: str = "radix2"
) -> jax.Array:
    """a: (t, ..., n) -> (t, ..., n), channel c transformed mod qs[c]."""
    eps, ax = _eps_axes(ct)
    if schedule == "four_step":
        fn = functools.partial(ntt_raw_four_step, shifts=ct.mul_shifts)
        return jax.vmap(fn, in_axes=(0, 0, 0, 0, ax))(
            a, ct.fwd_d, ct.fs_row_fwd_d, ct.qs_d, eps
        )
    fn = functools.partial(ntt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, ax))(a, ct.fwd_d, ct.qs_d, eps)


def intt_channels(
    a: jax.Array, ct: ChannelTables, schedule: str = "radix2"
) -> jax.Array:
    eps, ax = _eps_axes(ct)
    if schedule == "four_step":
        fn = functools.partial(intt_raw_four_step, shifts=ct.mul_shifts)
        return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, ax))(
            a, ct.inv_d, ct.fs_row_inv_d, ct.qs_d, ct.half_d, eps
        )
    fn = functools.partial(intt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, ax))(
        a, ct.inv_d, ct.qs_d, ct.half_d, eps
    )


def negacyclic_mul_channels(
    a, b, ct: ChannelTables, schedule: str = "radix2"
) -> jax.Array:
    """(t, ..., n) x (t, ..., n) — the full RNS-parallel no-shuffle cascade."""
    bshape = (ct.t,) + (1,) * (a.ndim - 1)
    q_b = ct.qs_d.reshape(bshape)
    eps_b = None if ct.mul_eps is None else ct.mul_eps_d.reshape(bshape)
    fa = ntt_channels(a, ct, schedule)
    fb = ntt_channels(b, ct, schedule)
    prod = mul_mod(fa, fb, q_b, eps_b, ct.mul_shifts)
    return intt_channels(prod, ct, schedule)
