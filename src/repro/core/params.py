"""Parameter presets tying together prime search, NTT tables and RNS plans.

The paper's hardware configs:
  * t=6, v=30, n=4096  (preferred: best ABP/power)   -> 180-bit q
  * t=4, v=45, n=4096  (wide-word alternative)       -> 180-bit q; served
    by the numpy-object oracle (products exceed int64), see polymul.py.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core import ntt as ntt_mod
from repro.core import primes as primes_mod
from repro.core import rns as rns_mod
from repro.errors import UnknownKnobError


# Datapath selection for the whole stack (see repro.kernels.ops, which
# dispatches on this): pure-jnp reference, per-stage Pallas kernels, the
# fused single-kernel NTT -> ⊙ -> iNTT cascade (paper contribution 1), or
# the fully fused decompose -> cascade -> compose end-to-end kernel (the
# paper's complete feed-forward datapath, Fig 10 — residues never touch
# HBM).
BACKENDS = ("jnp", "pallas", "pallas_fused", "pallas_fused_e2e")

# NTT stage schedule (see repro.core.ntt / DESIGN.md §6 & §10): "radix2"
# is the flat loop (late forward stages pair at lane stride < 128),
# "four_step" the lane-aligned (n1, n2) tile schedule (no butterfly stage
# pairs along the lane axis; recurses into the hierarchical chain at
# n >= 8192), "four_step:h" asserts the hierarchical (depth >= 2) form,
# "auto" picks four_step when n >= 256 (where the tile reaches the full
# 128-lane width) and radix2 below.  plan() resolves any of these into a
# concrete repro.core.schedule.ScheduleSpec.
SCHEDULES = ("auto", "radix2", "four_step", "four_step:h")


def validate_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise UnknownKnobError(
            f"unknown backend {backend!r}: expected one of {BACKENDS}",
            knob="backend",
            value=backend,
            alternatives=BACKENDS,
        )
    return backend


def validate_schedule(schedule: str) -> str:
    if schedule not in SCHEDULES:
        raise UnknownKnobError(
            f"unknown schedule {schedule!r}: expected one of {SCHEDULES}",
            knob="schedule",
            value=schedule,
            alternatives=SCHEDULES,
        )
    return schedule


@dataclasses.dataclass(frozen=True)
class ParenttParams:
    n: int
    v: int
    t: int
    primes: tuple[primes_mod.SpecialPrime, ...]
    plan: rns_mod.RnsPlan
    tables: ntt_mod.ChannelTables | None  # None for v > 31 (oracle-only)
    backend: str = "jnp"  # default datapath; per-call backend= overrides
    schedule: str = "auto"  # NTT stage schedule; per-call schedule= overrides
    row_blk: int | None = None  # kernel tile rows; None = per-kernel default

    @property
    def q(self) -> int:
        return self.plan.q

    @property
    def qs(self):
        return self.plan.qs

    def with_backend(self, backend: str) -> "ParenttParams":
        return dataclasses.replace(self, backend=validate_backend(backend))

    def with_schedule(self, schedule: str) -> "ParenttParams":
        return dataclasses.replace(self, schedule=validate_schedule(schedule))

    def with_row_blk(self, row_blk: int | None) -> "ParenttParams":
        if row_blk is not None and row_blk < 1:
            raise ValueError(f"row_blk must be >= 1, got {row_blk}")
        return dataclasses.replace(self, row_blk=row_blk)


@functools.lru_cache(maxsize=None)
def _make_params_base(n: int, t: int, v: int) -> ParenttParams:
    specials = primes_mod.default_prime_set(n, t, v)
    qs = [s.q for s in specials]
    plan = rns_mod.make_plan(
        qs, n=n, v=v, beta_terms=[s.beta_terms for s in specials]
    )
    tables = ntt_mod.make_channel_tables(qs, n) if v <= 31 else None
    return ParenttParams(n=n, v=v, t=t, primes=specials, plan=plan, tables=tables)


def make_params(
    n: int = 4096, t: int = 6, v: int = 30, backend: str = "jnp",
    schedule: str = "auto", row_blk: int | None = None,
) -> ParenttParams:
    """Build (cached) params.  Backend/schedule/row_blk variants of the
    same (n, t, v) share one plan / table set, so twiddles upload to
    device once."""
    p = _make_params_base(n, t, v)
    if backend != "jnp":
        p = p.with_backend(backend)
    if schedule != "auto":
        p = p.with_schedule(schedule)
    if row_blk is not None:
        p = p.with_row_blk(row_blk)
    return p


# Small presets used across tests (fast to build).
def test_params(n: int = 64, t: int = 3, v: int = 30) -> ParenttParams:
    return make_params(n=n, t=t, v=v)
