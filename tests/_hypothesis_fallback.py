"""Degrade-to-skip stand-ins for ``hypothesis`` (see pyproject `test` extra).

The property-test modules guard their import (the tier-1 suite previously
died at collection with ``ModuleNotFoundError: hypothesis``).  When the
real package is absent, these stubs keep every non-property test running
and turn each ``@given`` test into an individual skip instead of a
module-level collection error.
"""
from __future__ import annotations

import pytest


class _AnyStrategy:
    """Accepts any ``st.<name>(...)`` call chain; never generates values."""

    def __getattr__(self, name):
        def make(*args, **kwargs):
            return self

        return make

    def __call__(self, *args, **kwargs):
        return self

    def map(self, fn):
        return self

    def filter(self, fn):
        return self


st = _AnyStrategy()


def given(*_args, **_kwargs):
    def deco(fn):
        def skipped(*args, **kwargs):
            pytest.skip("hypothesis not installed")

        skipped.__name__ = getattr(fn, "__name__", "skipped_property_test")
        skipped.__doc__ = getattr(fn, "__doc__", None)
        return skipped

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco
