"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These re-use the core library implementations so the kernels are pinned to
the same math that the schoolbook-validated pipeline uses.
"""
from __future__ import annotations

from repro.core import ntt as ntt_mod
from repro.core import rns as rns_mod


def ntt_ref(a, fwd, q):
    """a: (..., n) residues; fwd: (n,) twiddles; q scalar."""
    return ntt_mod.ntt_raw(a, fwd, q)


def intt_ref(a, inv, q, half):
    return ntt_mod.intt_raw(a, inv, q, half)


def fused_polymul_ref(a, b, fwd, inv, q, half):
    """NTT(a) ⊙ NTT(b) -> iNTT, one modulus."""
    fa = ntt_mod.ntt_raw(a, fwd, q)
    fb = ntt_mod.ntt_raw(b, fwd, q)
    return ntt_mod.intt_raw(ntt_mod.mul_mod(fa, fb, q), inv, q, half)


def decompose_channel_ref(z, beta_pows_i, qi):
    """z: (..., S) segments -> residues (...,) for ONE channel."""
    terms = (z * beta_pows_i) % qi
    return terms.sum(axis=-1) % qi


def compose_ref(residues, plan: rns_mod.RnsPlan):
    """residues (t, ...) -> limbs (..., L); full optimized Eq 10 path."""
    return rns_mod.compose(residues, plan)


def barrett_ref(x, q):
    return x % q


def pointwise_mul_ref(a, b, q):
    return (a * b) % q
