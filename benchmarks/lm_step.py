"""Framework benchmark: per-arch reduced-config train/decode step wall-clock
on CPU (smoke-scale — the production numbers are the §Roofline terms)."""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.train import train_step as ts_mod


def run():
    out = []
    for arch in sorted(registry.ARCHS):
        cfg = registry.get(arch).reduced()
        run_cfg = RunConfig(model=cfg, remat=False)
        params, opt = ts_mod.init_state(run_cfg, jax.random.PRNGKey(0))
        step = jax.jit(ts_mod.make_train_step(run_cfg))
        rng = np.random.default_rng(0)
        B, S = 2, 32
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        if cfg.family == "encdec":
            batch["enc_embeddings"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        elif cfg.frontend:
            batch["embeddings"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        else:
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        p2, o2, metrics = step(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        t0 = time.perf_counter()
        for _ in range(3):
            p2, o2, metrics = step(p2, o2, batch)
        jax.block_until_ready(metrics["loss"])
        us = (time.perf_counter() - t0) / 3 * 1e6
        out.append((f"lm_train_step_{arch}", us,
                    f"reduced cfg, B={B} S={S}, loss={float(metrics['loss']):.3f}"))
    return out
