"""Batched HE-polymul serving engine: shape-bucketed continuous batching
and mesh-sharded execution over the plan/execute API.

The paper's pitch is *low latency and high sample rate* — the
feed-forward PaReNTT datapath "can be pipelined at arbitrary levels" —
and the GPU-HE literature (Shivdikar et al., accelerating polynomial
multiplication on GPUs) locates the real throughput in batching many
residue-polynomial products into one device dispatch.  This module is
that serving layer for the reproduction:

* **Shape buckets.**  Requests arrive with heterogeneous plans; the
  frozen, hashable :class:`repro.api.PlanConfig` (``api.plan_key``) is
  the bucket key.  Every distinct config gets exactly ONE jit trace —
  the engine's executor takes the :class:`~repro.api.Plan` pytree as an
  ordinary argument, so same-config dispatches hit one compiled entry
  (asserted by the trace-count probe in ``tests/test_serve_crypto.py``).
* **Fixed batch slots.**  Each dispatch pads its bucket's pending
  requests to ``batch_slots`` rows with zero polynomials, so the
  compiled executable sees ONE static shape per config (continuous-
  batching admission, same slot discipline as the LM
  :class:`repro.serve.engine.Engine`).  Zero rows are dead weight, not
  a correctness hazard: results are sliced back per request.
* **Mesh mode.**  With ``mesh=``, dispatches run
  :func:`polymul_sharded`: decompose/compose ride GSPMD on the
  data-parallel batch edges while the heavy residue cascade runs under
  an explicit ``shard_map`` — the RNS channel axis of
  ``repro.negacyclic_mul`` over ``model`` (the paper's t parallel
  datapaths mapped to t parallel shards) and the batch axis over
  ``data``.  The plan's table leaves are sliced per shard by the same
  ``shard_map`` (``partition.plan_leaf_specs``), which is exactly what
  the leaf-threaded ops layer (DESIGN §7) exists for: each shard's
  kernels bind the NTT/Shoup/CRT tables of its own channels, not jit
  constants.

Usage::

    eng = PolymulEngine(batch_slots=8)
    pl = eng.plan(n=4096, t=6, v=30)
    fut = eng.submit(pl, za, zb)      # za, zb: (n, S) segment arrays
    eng.run_until_idle()
    limbs = fut.result()              # (n, L)

Driver entry points: ``launch/serve_crypto.py`` (synthetic mixed-preset
traffic, Poisson arrivals) and ``benchmarks/serve_throughput.py`` (the
``serve-smoke`` CI gate: batched throughput >= the unbatched loop).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.compat import shard_map
from repro.sharding import ctx as ctx_mod
from repro.sharding import partition

__all__ = [
    "PolymulEngine",
    "PolymulFuture",
    "negacyclic_mul_sharded",
    "polymul_sharded",
]


# --------------------------------------------------------------------------
# mesh-sharded execution (the model x data layout of DESIGN §8)
# --------------------------------------------------------------------------


def _mesh_sizes(mesh) -> tuple[int, int]:
    """(model_size, data_batch_size) of a serving mesh."""
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    bsize = 1
    for a in partition.batch_axes(mesh):
        bsize *= mesh.shape[a]
    return msize, bsize


def negacyclic_mul_sharded(pl: api.Plan, a, b, *, mesh):
    """``shard_map`` the residue cascade: ``a``, ``b`` are ``(t, B, n)``
    residue tensors; the RNS channel axis shards over ``model``, the
    batch axis over the data axes, and the plan's table leaves are
    sliced per shard alongside them (``partition.plan_leaf_specs``) so
    every shard's NTT runs on locally-resident tables.

    Bit-exact vs. the single-device :func:`repro.api.negacyclic_mul`:
    the per-channel cascades are independent (the RNS parallelism the
    paper's t datapaths exploit), so sharding channels is a pure
    layout decision.  int64-width plans only — the wide datapath keys
    per-channel host constants by global channel index and cannot be
    sliced by leaves alone.
    """
    cfg = api.plan_key(pl)
    if cfg.width != "int64":
        raise ValueError(
            f"negacyclic_mul_sharded serves int64-width plans only "
            f"(got width={cfg.width!r}); the wide/oracle datapaths bake "
            f"per-channel host constants that shard_map cannot slice"
        )
    msize, bsize = _mesh_sizes(mesh)
    if cfg.t % msize:
        raise ValueError(
            f"t={cfg.t} RNS channels do not divide the model axis "
            f"({msize}-way): shrink the axis or pick t a multiple of it"
        )
    if a.ndim != 3 or a.shape[0] != cfg.t or a.shape[-1] != cfg.n:
        raise ValueError(
            f"negacyclic_mul_sharded: expected residues (t={cfg.t}, B, "
            f"n={cfg.n}), got shape {tuple(a.shape)}"
        )
    if a.shape != b.shape:
        raise ValueError(
            f"negacyclic_mul_sharded: operand shapes differ: "
            f"{tuple(a.shape)} vs {tuple(b.shape)}"
        )
    if a.shape[1] % bsize:
        raise ValueError(
            f"batch {a.shape[1]} does not divide the data axes "
            f"({bsize}-way); pad the batch (the engine's slot padding "
            f"guarantees this)"
        )
    leaf_specs = partition.plan_leaf_specs(mesh, pl)
    res_spec = partition.polymul_specs(mesh, pl)["residues"]

    def _local(consts, a_s, b_s):
        # Rebuild a shard-local Plan around the sliced leaves: the ops
        # layer rebinds its kernels to these tables and re-derives the
        # local channel count from their shapes (api._bound_params).
        local = api.Plan(config=pl.config, params=pl.params, consts=consts)
        return api.negacyclic_mul(local, a_s, b_s)

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(leaf_specs, res_spec, res_spec),
        out_specs=res_spec,
    )
    return fn(pl.consts, a, b)


def polymul_sharded(pl: api.Plan, za, zb, *, mesh):
    """Mesh-mode end-to-end polymul: ``(B, n, S)`` segments ->
    ``(B, n, L)`` limbs.  Decompose/compose are batch-parallel edges
    (constrained to the ``polymul_specs`` layout so GSPMD cannot
    all-gather the residue tensors); the cascade between them is the
    explicit ``model`` x ``data`` ``shard_map`` of
    :func:`negacyclic_mul_sharded`.  Compose's channel reduction is the
    one cross-``model`` collective, and GSPMD inserts exactly that."""
    cfg = api.plan_key(pl)
    if cfg.width != "int64":
        raise ValueError(
            f"polymul_sharded serves int64-width plans only "
            f"(got width={cfg.width!r})"
        )
    pol = ctx_mod.make_crypto_policy(mesh, pl)
    za = pol(za, "segments")
    zb = pol(zb, "segments")
    ra = pol(api.decompose(pl, za), "residues")
    rb = pol(api.decompose(pl, zb), "residues")
    rp = negacyclic_mul_sharded(pl, ra, rb, mesh=mesh)
    return pol(api.compose(pl, rp), "limbs")


# --------------------------------------------------------------------------
# request plumbing
# --------------------------------------------------------------------------


class PolymulFuture:
    """Handle for one submitted product.  Resolved when the engine
    dispatches the request's micro-batch; ``latency_s`` then holds the
    submit-to-result wall time (what the throughput benchmark's
    p50/p99 columns aggregate)."""

    __slots__ = ("_value", "_done", "latency_s")

    def __init__(self):
        self._value = None
        self._done = False
        self.latency_s = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            raise RuntimeError(
                "request not served yet — drive the engine "
                "(step() / run_until_idle())"
            )
        return self._value

    def _set(self, value, latency_s: float):
        self._value = value
        self.latency_s = latency_s
        self._done = True


@dataclasses.dataclass
class _Request:
    za: np.ndarray  # (n, S)
    zb: np.ndarray  # (n, S)
    future: PolymulFuture
    seq: int
    t_submit: float


@dataclasses.dataclass
class _Bucket:
    plan: api.Plan
    queue: deque = dataclasses.field(default_factory=deque)


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class PolymulEngine:
    """Shape-bucketed continuous-batching engine over the Plan API.

    Parameters
    ----------
    batch_slots:
        Fixed rows per dispatch.  Every micro-batch is padded to this
        many polynomials, so each distinct ``PlanConfig`` compiles ONE
        executable (shape stability is what makes the trace count ==
        the config count).
    mesh:
        Optional ``jax.sharding.Mesh`` with ``model``/data axes; when
        set, dispatches run :func:`polymul_sharded`.  ``batch_slots``
        must divide the data axes so the padded batch always shards.
    donate:
        Donate the padded operand buffers to XLA (they are rebuilt per
        dispatch, so nothing reads them back); the serving hot-loop
        counterpart of ``api.execute(donate=True)``.
    """

    def __init__(self, *, batch_slots: int = 8, mesh=None,
                 donate: bool = False):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if mesh is not None:
            _, bsize = _mesh_sizes(mesh)
            if batch_slots % bsize:
                raise ValueError(
                    f"batch_slots={batch_slots} must divide the mesh's "
                    f"data axes ({bsize}-way) so padded batches shard"
                )
        self.batch_slots = batch_slots
        self.mesh = mesh
        self._plans: dict[api.PlanConfig, api.Plan] = {}
        self._buckets: dict[api.PlanConfig, _Bucket] = {}
        self._seq = itertools.count()
        self._trace_log: list[api.PlanConfig] = []
        self.stats = {
            "submitted": 0,
            "served": 0,
            "dispatches": 0,
            "padded_slots": 0,
        }

        def _run(pl, za, zb):
            # Appended at TRACE time only: the probe that asserts one
            # compilation per distinct PlanConfig.
            self._trace_log.append(api.plan_key(pl))
            if mesh is not None:
                return polymul_sharded(pl, za, zb, mesh=mesh)
            return api.polymul(pl, za, zb)

        self._exec = jax.jit(
            _run, donate_argnums=(1, 2) if donate else ()
        )

    # -- plan cache ----------------------------------------------------
    def plan(self, n: int = 4096, t: int = 6, v: int = 30, **kw) -> api.Plan:
        """Build-or-fetch a plan, cached by its resolved
        :func:`api.plan_key` — repeated preset lookups share one Plan
        object (and, transitively, one set of device tables)."""
        pl = api.plan(n=n, t=t, v=v, **kw)
        return self._plans.setdefault(api.plan_key(pl), pl)

    # -- request intake ------------------------------------------------
    def submit(self, pl: api.Plan, za, zb) -> PolymulFuture:
        """Enqueue one product ``a * b`` under plan ``pl``.  ``za``,
        ``zb``: ``(n, S)`` base-2^v segment arrays.  Returns a
        :class:`PolymulFuture`; drive the engine to resolve it."""
        cfg = api.plan_key(pl)
        za = np.asarray(za)
        zb = np.asarray(zb)
        want = (cfg.n, cfg.seg_count)
        for name, z in (("za", za), ("zb", zb)):
            if z.shape != want:
                raise ValueError(
                    f"submit: expected {name} segments (n={cfg.n}, "
                    f"S={cfg.seg_count}), got shape {z.shape}"
                )
        if self.mesh is not None:
            # Mirror the sharded-dispatch preconditions HERE: step()
            # pops requests before dispatching, so a config that can
            # only fail at trace time would lose its popped requests.
            if cfg.width != "int64":
                raise ValueError(
                    f"mesh mode serves int64-width plans only "
                    f"(got width={cfg.width!r})"
                )
            msize, _ = _mesh_sizes(self.mesh)
            if cfg.t % msize:
                raise ValueError(
                    f"mesh mode: t={cfg.t} RNS channels do not divide "
                    f"the model axis ({msize}-way); pick t a multiple "
                    f"of it or shrink the axis"
                )
        bucket = self._buckets.get(cfg)
        if bucket is None:
            bucket = self._buckets[cfg] = _Bucket(
                plan=self._plans.setdefault(cfg, pl)
            )
        fut = PolymulFuture()
        bucket.queue.append(
            _Request(za, zb, fut, next(self._seq), time.perf_counter())
        )
        self.stats["submitted"] += 1
        return fut

    def pending(self) -> int:
        return sum(len(b.queue) for b in self._buckets.values())

    # -- dispatch ------------------------------------------------------
    def step(self) -> int:
        """Dispatch ONE micro-batch from the bucket whose head request
        has waited longest (FIFO across buckets — latency fairness over
        pure bucket packing).  Returns the number of requests served,
        0 when idle."""
        live = [b for b in self._buckets.values() if b.queue]
        if not live:
            return 0
        bucket = min(live, key=lambda b: b.queue[0].seq)
        k = min(len(bucket.queue), self.batch_slots)
        reqs = [bucket.queue.popleft() for _ in range(k)]
        cfg = api.plan_key(bucket.plan)
        if cfg.width == "oracle":
            # Host-only width: no tracing, no padding — zero rows would
            # be pure wasted bigint work on the CPU.
            za = np.stack([r.za for r in reqs])
            zb = np.stack([r.zb for r in reqs])
            out = np.asarray(api.polymul(bucket.plan, za, zb))
            pad = 0
        else:
            B = self.batch_slots
            za = np.zeros((B, cfg.n, cfg.seg_count), np.int64)
            zb = np.zeros_like(za)
            for i, r in enumerate(reqs):
                za[i] = r.za
                zb[i] = r.zb
            out = np.asarray(
                self._exec(bucket.plan, jnp.asarray(za), jnp.asarray(zb))
            )
            pad = B - k
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.future._set(out[i], now - r.t_submit)
        self.stats["dispatches"] += 1
        self.stats["served"] += k
        self.stats["padded_slots"] += pad
        return k

    def run_until_idle(self) -> int:
        """Drain every bucket; returns total requests served."""
        total = 0
        while True:
            n = self.step()
            if n == 0:
                return total
            total += n

    def serve(self, requests) -> list[np.ndarray]:
        """Convenience closed loop: submit ``(plan, za, zb)`` triples,
        drain, return results in submission order."""
        futs = [self.submit(pl, za, zb) for pl, za, zb in requests]
        self.run_until_idle()
        return [f.result() for f in futs]

    # -- probes --------------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Compilations of the engine executor so far; equals the
        number of distinct PlanConfigs served (the bucket contract)."""
        return len(self._trace_log)

    @property
    def traced_configs(self) -> tuple:
        return tuple(self._trace_log)
