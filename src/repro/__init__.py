"""repro: PaReNTT — parallel RNS + NTT long polynomial modular multiplication
(Tan, Chiu, Wang, Lao, Parhi, 2023) as a production JAX framework.

The public surface is the plan/execute pair::

    import repro

    pl = repro.plan(n=4096, t=6, v=30)     # resolve + upload everything once
    limbs = repro.polymul(pl, za, zb)      # (..., n, S) -> (..., n, L)

``repro.plan`` dispatches on modulus width internally (int64 Pallas for
v <= 31, digit-split wide for v <= 46, host bigint oracle beyond); the
returned ``Plan`` is a JAX pytree, so ``jax.jit(repro.polymul)`` /
``jax.vmap`` / ``shard_map`` treat it as an ordinary argument.  See
:mod:`repro.api` for the full contract.

The crypto core requires 64-bit integer arithmetic; enable x64 once at
package import (before anything touches jax.numpy).  All floating-point
model code states dtypes explicitly, so the x64 default does not leak
into LM layers.
"""
from jax import config as _config

_config.update("jax_enable_x64", True)

__version__ = "0.2.0"

from repro.errors import (  # noqa: E402
    BackendFailedError,
    DeadlineExceededError,
    EngineError,
    PlanError,
    QueueFullError,
    UnknownKnobError,
    UnservableConfigError,
)
from repro.api import (  # noqa: E402  (x64 must flip before jax.numpy use)
    BACKENDS,
    SCHEDULES,
    WIDTHS,
    Plan,
    PlanConfig,
    ScheduleSpec,
    compose,
    decompose,
    execute,
    from_limbs,
    intt,
    negacyclic_mul,
    ntt,
    plan,
    plan_key,
    polymul,
    polymul_ints,
    to_segments,
)


def verify_plan(pl, **kwargs):
    """Statically verify a Plan's kernel datapaths (overflow / envelope /
    lane / staticness).  Thin lazy wrapper over
    :func:`repro.analysis.verify.verify_plan` so importing ``repro`` does
    not pull the analysis stack."""
    from repro.analysis.verify import verify_plan as _vp

    return _vp(pl, **kwargs)


__all__ = [
    "BACKENDS",
    "BackendFailedError",
    "DeadlineExceededError",
    "EngineError",
    "QueueFullError",
    "SCHEDULES",
    "WIDTHS",
    "Plan",
    "PlanConfig",
    "PlanError",
    "ScheduleSpec",
    "UnknownKnobError",
    "UnservableConfigError",
    "__version__",
    "compose",
    "decompose",
    "execute",
    "from_limbs",
    "intt",
    "negacyclic_mul",
    "ntt",
    "plan",
    "plan_key",
    "polymul",
    "polymul_ints",
    "to_segments",
    "verify_plan",
]
