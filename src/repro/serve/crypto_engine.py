"""Batched HE-polymul serving engine: shape-bucketed continuous batching,
mesh-sharded execution, and fault-tolerant async serving over the
plan/execute API.

The paper's pitch is *low latency and high sample rate* — the
feed-forward PaReNTT datapath "can be pipelined at arbitrary levels" —
and the GPU-HE literature (Shivdikar et al., accelerating polynomial
multiplication on GPUs) locates the real throughput in batching many
residue-polynomial products into one device dispatch.  The FIFO-pipelined
and hazard-free dataflow NTT architectures (arXiv 2501.11867,
2410.04805) get their *sustained* rates from bounded in-flight occupancy
and stall-free hazard handling; this module is the software analogue of
both halves:

* **Shape buckets.**  Requests arrive with heterogeneous plans; the
  frozen, hashable :class:`repro.api.PlanConfig` (``api.plan_key``) is
  the bucket key.  Every distinct config gets exactly ONE jit trace —
  the engine's executor takes the :class:`~repro.api.Plan` pytree as an
  ordinary argument, so same-config dispatches hit one compiled entry
  (asserted by the trace-count probe in ``tests/test_serve_crypto.py``).
* **Fixed batch slots.**  Each dispatch pads its bucket's pending
  requests to ``batch_slots`` rows with zero polynomials, so the
  compiled executable sees ONE static shape per config.  Zero rows are
  dead weight, not a correctness hazard: results are sliced back per
  request.
* **Deadlines, priorities, EDF.**  ``submit(..., deadline=, priority=)``
  attaches scheduling metadata; dispatch picks the bucket whose head
  request is earliest-deadline-first (deadline-less requests order FIFO
  behind any deadline, priority breaks ties).  Admission control sheds
  requests whose deadline has passed or cannot be met — each shed
  future resolves with :class:`repro.errors.DeadlineExceededError`,
  never silently dropped.
* **Backpressure.**  ``max_pending=`` bounds the submission queue;
  ``submit(timeout=)`` blocks for space (raising
  :class:`repro.errors.QueueFullError` on expiry) and ``try_submit``
  returns ``None`` instead of waiting.
* **Failure semantics.**  A dispatch that raises fails or requeues
  exactly the popped requests: bounded per-request retries with
  exponential per-bucket backoff, then
  :class:`repro.errors.BackendFailedError` (underlying exception
  chained as ``__cause__``).  Every admitted request resolves exactly
  once — a value or a typed :class:`repro.errors.EngineError`.
* **Circuit breaker / degradation.**  ``breaker_threshold`` consecutive
  dispatch failures re-plan the bucket one step down the backend
  fallback chain (``pallas_fused_e2e -> pallas -> jnp``) via
  :func:`repro.api.plan` with the same ``n/t/v``, so degraded results
  stay bit-exact; after ``breaker_cooldown_s`` the next dispatch probes
  the original backend and restores it on success.
* **Async front end.**  ``start()`` launches a background dispatcher
  thread driving :meth:`PolymulEngine.step`; submission then overlaps
  host batching with device execution and futures support
  ``result(timeout=)`` blocking waits.  The synchronous
  ``step()``/``run_until_idle()`` closed loop keeps working unchanged.
* **Mesh mode.**  With ``mesh=``, dispatches run
  :func:`polymul_sharded`: the RNS channel axis of the residue cascade
  shard_maps over ``model`` and the batch axis over ``data``, with the
  plan's table leaves sliced per shard (``partition.plan_leaf_specs``)
  — the leaf-threaded ops layer (DESIGN §7) at work.

Usage::

    eng = PolymulEngine(batch_slots=8, max_pending=64)
    with eng:                               # background dispatcher
        pl = eng.plan(n=4096, t=6, v=30)
        fut = eng.submit(pl, za, zb, deadline=0.5)   # za, zb: (n, S)
        limbs = fut.result(timeout=5.0)     # (n, L), or raises EngineError

Fault injection for soak testing wraps ``engine.executor``
(:mod:`repro.serve.faults`); the soak driver is
``launch/serve_soak.py`` and the throughput benchmark
``benchmarks/serve_throughput.py`` (the ``serve-smoke`` /
``serve-soak`` CI gates).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.compat import shard_map
from repro.errors import (
    BackendFailedError,
    DeadlineExceededError,
    QueueFullError,
)
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.sharding import ctx as ctx_mod
from repro.sharding import partition

__all__ = [
    "FALLBACK_NEXT",
    "SNAPSHOT_KEYS",
    "SNAPSHOT_SCHEMA_VERSION",
    "PolymulEngine",
    "PolymulFuture",
    "negacyclic_mul_sharded",
    "polymul_sharded",
]


# --------------------------------------------------------------------------
# mesh-sharded execution (the model x data layout of DESIGN §8)
# --------------------------------------------------------------------------


def _mesh_sizes(mesh) -> tuple[int, int]:
    """(model_size, data_batch_size) of a serving mesh."""
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    bsize = 1
    for a in partition.batch_axes(mesh):
        bsize *= mesh.shape[a]
    return msize, bsize


def negacyclic_mul_sharded(pl: api.Plan, a, b, *, mesh):
    """``shard_map`` the residue cascade: ``a``, ``b`` are ``(t, B, n)``
    residue tensors; the RNS channel axis shards over ``model``, the
    batch axis over the data axes, and the plan's table leaves are
    sliced per shard alongside them (``partition.plan_leaf_specs``) so
    every shard's NTT runs on locally-resident tables.

    Bit-exact vs. the single-device :func:`repro.api.negacyclic_mul`:
    the per-channel cascades are independent (the RNS parallelism the
    paper's t datapaths exploit), so sharding channels is a pure
    layout decision.  Device widths only: the int64 width rebinds its
    kernel tables from the sliced leaves (``api._bound_params``), and
    the wide width rebuilds shard-local channel specs from its
    ``wide_qs``/``wide_betas`` leaves (``api._wide_exec_specs`` — the
    channel-offset view); the oracle width is host-only and cannot be
    traced, let alone sharded.
    """
    cfg = api.plan_key(pl)
    if cfg.width not in ("int64", "wide"):
        raise ValueError(
            f"negacyclic_mul_sharded serves int64/wide-width plans only "
            f"(got width={cfg.width!r}); the oracle datapath is host-only "
            f"and cannot be traced"
        )
    msize, bsize = _mesh_sizes(mesh)
    if cfg.t % msize:
        raise ValueError(
            f"t={cfg.t} RNS channels do not divide the model axis "
            f"({msize}-way): shrink the axis or pick t a multiple of it"
        )
    if a.ndim != 3 or a.shape[0] != cfg.t or a.shape[-1] != cfg.n:
        raise ValueError(
            f"negacyclic_mul_sharded: expected residues (t={cfg.t}, B, "
            f"n={cfg.n}), got shape {tuple(a.shape)}"
        )
    if a.shape != b.shape:
        raise ValueError(
            f"negacyclic_mul_sharded: operand shapes differ: "
            f"{tuple(a.shape)} vs {tuple(b.shape)}"
        )
    if a.shape[1] % bsize:
        raise ValueError(
            f"batch {a.shape[1]} does not divide the data axes "
            f"({bsize}-way); pad the batch (the engine's slot padding "
            f"guarantees this)"
        )
    leaf_specs = partition.plan_leaf_specs(mesh, pl)
    res_spec = partition.polymul_specs(mesh, pl)["residues"]

    def _local(consts, a_s, b_s):
        # Rebuild a shard-local Plan around the sliced leaves: the ops
        # layer rebinds its kernels to these tables and re-derives the
        # local channel count from their shapes (api._bound_params).
        local = api.Plan(config=pl.config, params=pl.params, consts=consts)
        return api.negacyclic_mul(local, a_s, b_s)

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(leaf_specs, res_spec, res_spec),
        out_specs=res_spec,
    )
    return fn(pl.consts, a, b)


def polymul_sharded(pl: api.Plan, za, zb, *, mesh):
    """Mesh-mode end-to-end polymul: ``(B, n, S)`` segments ->
    ``(B, n, L)`` limbs.  Decompose/compose are batch-parallel edges
    (constrained to the ``polymul_specs`` layout so GSPMD cannot
    all-gather the residue tensors); the cascade between them is the
    explicit ``model`` x ``data`` ``shard_map`` of
    :func:`negacyclic_mul_sharded`.  Compose's channel reduction is the
    one cross-``model`` collective, and GSPMD inserts exactly that."""
    cfg = api.plan_key(pl)
    if cfg.width not in ("int64", "wide"):
        raise ValueError(
            f"polymul_sharded serves int64/wide-width plans only "
            f"(got width={cfg.width!r})"
        )
    pol = ctx_mod.make_crypto_policy(mesh, pl)
    za = pol(za, "segments")
    zb = pol(zb, "segments")
    ra = pol(api.decompose(pl, za), "residues")
    rb = pol(api.decompose(pl, zb), "residues")
    rp = negacyclic_mul_sharded(pl, ra, rb, mesh=mesh)
    return pol(api.compose(pl, rp), "limbs")


# --------------------------------------------------------------------------
# request plumbing
# --------------------------------------------------------------------------


class PolymulFuture:
    """Handle for one submitted product, with a three-state lifecycle:

    ``PENDING`` (queued or in flight) -> ``DONE`` (``result()`` returns
    the ``(n, L)`` limb array; ``latency_s`` holds submit-to-resolve
    wall time) or ``FAILED`` (``result()`` re-raises the stored
    :class:`repro.errors.EngineError`; ``exception()`` returns it).

    ``result(timeout=)``/``exception(timeout=)`` block up to ``timeout``
    seconds for resolution (raising ``TimeoutError`` on expiry).  With
    no timeout, a future submitted while the engine's background
    dispatcher is running blocks until resolved; otherwise an unserved
    future raises immediately — drive the engine (``step()`` /
    ``run_until_idle()``).  A future resolves exactly once; a second
    resolution attempt is an engine bug and raises.
    """

    PENDING = "PENDING"
    DONE = "DONE"
    FAILED = "FAILED"

    __slots__ = (
        "_value", "_exc", "_state", "_event", "_async",
        "latency_s", "dispatch_index", "trace_id",
    )

    def __init__(self):
        self._value = None
        self._exc = None
        self._state = PolymulFuture.PENDING
        self._event = threading.Event()
        self._async = False
        self.latency_s = None
        self.dispatch_index = None  # executor call index that resolved it
        self.trace_id = None  # obs span id (engines with a span_log)

    @property
    def state(self) -> str:
        return self._state

    def done(self) -> bool:
        return self._state != PolymulFuture.PENDING

    def exception(self, timeout: float | None = None):
        """The stored EngineError of a FAILED future, None when DONE."""
        self._wait(timeout)
        return self._exc

    def result(self, timeout: float | None = None):
        self._wait(timeout)
        if self._state == PolymulFuture.DONE:
            return self._value
        if self._state == PolymulFuture.FAILED:
            raise self._exc
        raise RuntimeError(
            "request not served yet — drive the engine "
            "(step() / run_until_idle()), or pass result(timeout=)"
        )

    def _wait(self, timeout: float | None) -> None:
        if self._state != PolymulFuture.PENDING:
            return
        if timeout is not None:
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"request still PENDING after {timeout}s"
                )
        elif self._async:
            self._event.wait()

    # -- engine side ---------------------------------------------------
    def _check_unresolved(self) -> None:
        if self._state != PolymulFuture.PENDING:
            raise RuntimeError(
                f"future resolved twice (state={self._state}) — "
                f"engine invariant violation"
            )

    def _resolve(self, value, latency_s: float, dispatch_index=None):
        self._check_unresolved()
        self._value = value
        self.latency_s = latency_s
        self.dispatch_index = dispatch_index
        self._state = PolymulFuture.DONE
        self._event.set()

    def _fail(self, exc: Exception, latency_s=None, dispatch_index=None):
        self._check_unresolved()
        self._exc = exc
        self.latency_s = latency_s
        self.dispatch_index = dispatch_index
        self._state = PolymulFuture.FAILED
        self._event.set()


@dataclasses.dataclass
class _Request:
    za: np.ndarray  # (n, S)
    zb: np.ndarray  # (n, S)
    future: PolymulFuture
    seq: int
    t_submit: float
    deadline: float | None = None  # absolute engine-clock deadline
    priority: int = 0  # higher dispatches sooner among equal deadlines
    attempts: int = 0  # failed dispatch attempts ridden so far
    span: obs_tracing.Span | None = None  # request trace (span_log engines)


def _order_key(req: _Request) -> tuple:
    """Heap key: earliest deadline first (deadline-less requests sort
    behind every deadline), then priority (higher first), then FIFO."""
    dl = req.deadline if req.deadline is not None else math.inf
    return (dl, -req.priority, req.seq)


@dataclasses.dataclass
class _Bucket:
    """One PlanConfig's queue + breaker state.  ``chain[0]`` is the
    original plan; ``chain[level]`` is the currently-active (possibly
    degraded) plan.  ``failures`` counts consecutive dispatch failures
    at the current level; ``not_before`` is the backoff gate."""

    key: api.PlanConfig
    chain: list  # [Plan, ...] original + lazily-built fallbacks
    heap: list = dataclasses.field(default_factory=list)
    level: int = 0
    failures: int = 0
    not_before: float = 0.0
    opened_at: float = 0.0  # when the breaker last opened / probe failed
    ewma_service_s: float = 0.0

    def push(self, req: _Request) -> None:
        heapq.heappush(self.heap, (*_order_key(req), req))

    def pop(self) -> _Request:
        return heapq.heappop(self.heap)[3]

    @property
    def plan(self) -> api.Plan:  # the original, pre-degradation plan
        return self.chain[0]

    @property
    def active_plan(self) -> api.Plan:
        return self.chain[self.level]


# Backend degradation chain (circuit breaker): each entry's fallback is
# strictly simpler/more portable; all entries are bit-exact vs each
# other (tests/test_backends.py), so degrading never changes results.
FALLBACK_NEXT = {
    "pallas_fused_e2e": "pallas",
    "pallas_fused": "pallas",
    "pallas": "jnp",
}


# --------------------------------------------------------------------------
# observability vocabulary
# --------------------------------------------------------------------------

# The engine's counters, registered as `repro_engine_<key>_total` counter
# families labeled {engine=<name>} in the process metrics registry
# (repro.obs.metrics).  `PolymulEngine.stats` is a live dict view over
# this engine's children; exporters read the same numbers.
_STAT_KEYS = (
    "submitted",  # admitted + DOA-shed requests (rejected NOT included)
    "served",  # futures resolved with a result
    "dispatches",  # successful executor calls
    "padded_slots",  # zero rows padded across successful dispatches
    "rejected",  # backpressure: never admitted (no future created)
    "shed",  # futures resolved with DeadlineExceededError
    "retried",  # request requeues after failed dispatches
    "failed",  # futures resolved with BackendFailedError
    "dispatch_failures",  # executor calls that raised
    "breaker_opened",  # bucket degradations down FALLBACK_NEXT
    "breaker_recovered",  # successful probes restoring the original
    "probes",  # original-backend probe dispatches while degraded
)

# snapshot() wire contract, pinned by tests/test_obs.py: the exact key
# set a snapshot dict carries at SNAPSHOT_SCHEMA_VERSION.  Changing the
# schema means bumping the version AND this tuple in the same commit —
# downstream dashboards key on it.
SNAPSHOT_SCHEMA_VERSION = 1
SNAPSHOT_KEYS = _STAT_KEYS + (
    "schema_version",  # int, == SNAPSHOT_SCHEMA_VERSION
    "engine",  # engine instance name (metrics label value)
    "queue_depth",  # queued, not yet dispatched
    "inflight",  # popped, dispatch outcome pending
    "latency_p50_ms",  # submit->result p50 over latency_window (or None)
    "latency_p99_ms",  # submit->result p99 over latency_window (or None)
    "degraded_buckets",  # buckets currently serving a fallback backend
    "bucket_backends",  # {bucket key str: active backend str}
)

_engine_names = itertools.count()


def _bucket_key_str(cfg: api.PlanConfig) -> str:
    """Human-stable bucket label used in snapshot()['bucket_backends']
    and span attrs: enough of the PlanConfig to tell buckets apart."""
    return f"n{cfg.n}_t{cfg.t}_v{cfg.v}_{cfg.backend}"


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class PolymulEngine:
    """Shape-bucketed continuous-batching engine over the Plan API, with
    deadline/priority scheduling, bounded-queue backpressure, bounded
    retry, per-bucket circuit breaking onto fallback backends, and an
    optional background dispatcher thread (see module docstring).

    Parameters
    ----------
    batch_slots:
        Fixed rows per dispatch.  Every micro-batch is padded to this
        many polynomials, so each distinct ``PlanConfig`` compiles ONE
        executable.
    mesh:
        Optional ``jax.sharding.Mesh`` with ``model``/data axes; when
        set, dispatches run :func:`polymul_sharded`.  ``batch_slots``
        must divide the data axes so the padded batch always shards.
    donate:
        Donate the padded operand buffers to XLA (they are rebuilt per
        dispatch, so nothing reads them back).
    max_pending:
        Bound on queued (not yet dispatched) requests; ``None`` (the
        default) leaves the queue unbounded.  With a bound set,
        ``submit`` blocks for space and ``try_submit`` returns ``None``
        when full.
    max_retries:
        How many times one request may be re-queued after a failed
        dispatch before its future fails with ``BackendFailedError``
        (probe dispatches of the original backend don't count).
    breaker_threshold:
        Consecutive dispatch failures at the bucket's current backend
        before the circuit breaker degrades it one step down
        ``FALLBACK_NEXT``.
    breaker_cooldown_s:
        How long a degraded bucket serves its fallback before the next
        dispatch probes the original backend again.
    backoff_base_s:
        Base of the per-bucket exponential dispatch backoff
        (``base * 2^(failures-1)``, capped at 1 s).
    name:
        Metrics label for this engine instance (``engine=<name>`` on
        every ``repro_engine_*`` series); auto-minted when omitted.
    registry:
        The :class:`repro.obs.metrics.MetricsRegistry` to count into
        (default: the process-wide registry).
    span_log:
        Optional :class:`repro.obs.tracing.SpanLog`.  When set, every
        ``submit()`` mints a request span (trace id on the returned
        future as ``fut.trace_id``) and the full lifecycle — admit,
        dispatch, retry, breaker transitions, terminal resolve/shed/
        fail — lands in the log.  ``None`` (default) keeps the hot
        paths tracing-free.
    """

    def __init__(self, *, batch_slots: int = 8, mesh=None,
                 donate: bool = False, max_pending: int | None = None,
                 max_retries: int = 3, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 backoff_base_s: float = 0.01,
                 latency_window: int = 4096,
                 name: str | None = None,
                 registry: obs_metrics.MetricsRegistry | None = None,
                 span_log: obs_tracing.SpanLog | None = None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if mesh is not None:
            _, bsize = _mesh_sizes(mesh)
            if batch_slots % bsize:
                raise ValueError(
                    f"batch_slots={batch_slots} must divide the mesh's "
                    f"data axes ({bsize}-way) so padded batches shard"
                )
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}"
            )
        self.batch_slots = batch_slots
        self.mesh = mesh
        self.max_pending = max_pending
        self.max_retries = max_retries
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self.backoff_base_s = backoff_base_s
        self._plans: dict[api.PlanConfig, api.Plan] = {}
        self._buckets: dict[api.PlanConfig, _Bucket] = {}
        self._seq = itertools.count()
        self._trace_log: list[api.PlanConfig] = []
        self._cond = threading.Condition()
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._loop_error: BaseException | None = None
        self._inflight = 0
        self._dispatch_seq = 0  # executor call counter (success + failure)
        self._latencies: deque = deque(maxlen=latency_window)
        self.name = name if name is not None else f"engine-{next(_engine_names)}"
        self.span_log = span_log
        self._registry = (
            registry if registry is not None else obs_metrics.registry()
        )
        # One counter child per stat, labeled by engine instance; the
        # `stats` property is a read view over exactly these children.
        self._m = {
            k: self._registry.counter(
                f"repro_engine_{k}_total", labelnames=("engine",)
            ).labels(engine=self.name)
            for k in _STAT_KEYS
        }
        self._h_latency = self._registry.histogram(
            "repro_engine_latency_seconds",
            "submit-to-result latency of served requests",
            ("engine",),
        ).labels(engine=self.name)
        self._h_queue_wait = self._registry.histogram(
            "repro_engine_queue_wait_seconds",
            "submit-to-first-dispatch wait of dispatched requests",
            ("engine",),
        ).labels(engine=self.name)
        self._g_queue_depth = self._registry.gauge(
            "repro_engine_queue_depth", "queued, not yet dispatched",
            ("engine",),
        ).labels(engine=self.name)
        self._g_inflight = self._registry.gauge(
            "repro_engine_inflight", "popped, dispatch outcome pending",
            ("engine",),
        ).labels(engine=self.name)

        def _run(pl, za, zb):
            # Appended at TRACE time only: the probe that asserts one
            # compilation per distinct PlanConfig.
            self._trace_log.append(api.plan_key(pl))
            if mesh is not None:
                return polymul_sharded(pl, za, zb, mesh=mesh)
            return api.polymul(pl, za, zb)

        self._exec = jax.jit(
            _run, donate_argnums=(1, 2) if donate else ()
        )
        # The raw batch executor; fault injectors and tests wrap THIS
        # attribute (repro.serve.faults.FaultInjector.install).  Every
        # dispatch goes through it, so a wrapper sees one call per
        # engine dispatch attempt in dispatch-index order.
        self.executor = self._execute_batch

    # -- plan cache ----------------------------------------------------
    def plan(self, n: int = 4096, t: int = 6, v: int = 30, **kw) -> api.Plan:
        """Build-or-fetch a plan, cached by its resolved
        :func:`api.plan_key` — repeated preset lookups share one Plan
        object (and, transitively, one set of device tables)."""
        pl = api.plan(n=n, t=t, v=v, **kw)
        return self._plans.setdefault(api.plan_key(pl), pl)

    # -- request intake ------------------------------------------------
    def _validate_submit(self, pl, za, zb):
        cfg = api.plan_key(pl)
        za = np.asarray(za)
        zb = np.asarray(zb)
        want = (cfg.n, cfg.seg_count)
        for name, z in (("za", za), ("zb", zb)):
            if z.shape != want:
                raise ValueError(
                    f"submit: expected {name} segments (n={cfg.n}, "
                    f"S={cfg.seg_count}), got shape {z.shape}"
                )
        if self.mesh is not None:
            # Mirror the sharded-dispatch preconditions HERE: step()
            # pops requests before dispatching, so a config that can
            # only fail at trace time would burn retries for nothing.
            if cfg.width not in ("int64", "wide"):
                raise ValueError(
                    f"mesh mode serves int64/wide-width plans only "
                    f"(got width={cfg.width!r})"
                )
            msize, _ = _mesh_sizes(self.mesh)
            if cfg.t % msize:
                raise ValueError(
                    f"mesh mode: t={cfg.t} RNS channels do not divide "
                    f"the model axis ({msize}-way); pick t a multiple "
                    f"of it or shrink the axis"
                )
        return cfg, za, zb

    def _enqueue_locked(self, cfg, pl, za, zb, deadline, priority,
                        now: float) -> PolymulFuture:
        bucket = self._buckets.get(cfg)
        if bucket is None:
            bucket = self._buckets[cfg] = _Bucket(
                key=cfg, chain=[self._plans.setdefault(cfg, pl)]
            )
        fut = PolymulFuture()
        fut._async = self._thread is not None
        req = _Request(
            za=za, zb=zb, future=fut, seq=next(self._seq), t_submit=now,
            deadline=(now + deadline) if deadline is not None else None,
            priority=priority,
        )
        if self.span_log is not None:
            req.span = self.span_log.start_span(
                "request", engine=self.name, seq=req.seq,
                bucket=_bucket_key_str(cfg), deadline=req.deadline,
                priority=priority,
            )
            fut.trace_id = req.span.trace_id
        self._m["submitted"].inc()
        if req.deadline is not None and req.deadline <= now:
            # dead on arrival: admission control resolves it, queue
            # untouched (typed error, never a silent drop)
            self._m["shed"].inc()
            if req.span is not None:
                req.span.finish("shed", reason="doa", latency_s=0.0)
            fut._fail(
                DeadlineExceededError(
                    f"deadline expired {now - req.deadline:.6f}s before "
                    f"admission (seq {req.seq})",
                    request_seq=req.seq, deadline_s=req.deadline,
                    late_s=now - req.deadline,
                ),
                latency_s=0.0,
            )
            return fut
        if req.span is not None:
            req.span.event("admit", queue_depth=self._pending_locked())
        bucket.push(req)
        self._cond.notify_all()
        return fut

    def submit(self, pl: api.Plan, za, zb, *, deadline: float | None = None,
               priority: int = 0,
               timeout: float | None = None) -> PolymulFuture:
        """Enqueue one product ``a * b`` under plan ``pl``.  ``za``,
        ``zb``: ``(n, S)`` base-2^v segment arrays.  ``deadline`` is
        seconds from now; a request that cannot dispatch in time is shed
        (future fails with ``DeadlineExceededError``).  ``priority``
        orders equal-deadline requests (higher first).  When the queue
        is bounded and full, blocks up to ``timeout`` seconds for space
        (forever if ``timeout`` is None — pass a timeout or use
        :meth:`try_submit` when nothing else drives the engine), then
        raises :class:`repro.errors.QueueFullError`.  Returns a
        :class:`PolymulFuture`; drive the engine (or run the background
        dispatcher) to resolve it."""
        cfg, za, zb = self._validate_submit(pl, za, zb)
        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while (self.max_pending is not None
                   and self._pending_locked() >= self.max_pending):
                remaining = (
                    None if t_end is None else t_end - time.perf_counter()
                )
                if remaining is not None and remaining <= 0:
                    self._m["rejected"].inc()
                    if self.span_log is not None:
                        s = self.span_log.start_span(
                            "request", engine=self.name,
                            bucket=_bucket_key_str(cfg),
                        )
                        s.finish("rejected", reason="queue_full")
                    raise QueueFullError(
                        f"submission queue full "
                        f"({self._pending_locked()} >= "
                        f"max_pending={self.max_pending}) after "
                        f"{timeout}s",
                        queue_depth=self._pending_locked(),
                        max_pending=self.max_pending,
                    )
                # bounded wait so a missed notify cannot wedge a waiter
                self._cond.wait(0.05 if remaining is None
                                else min(remaining, 0.05))
            return self._enqueue_locked(
                cfg, pl, za, zb, deadline, priority, time.perf_counter()
            )

    def try_submit(self, pl: api.Plan, za, zb, *,
                   deadline: float | None = None,
                   priority: int = 0) -> PolymulFuture | None:
        """Non-blocking :meth:`submit`: returns ``None`` (and counts a
        rejection) instead of waiting when the bounded queue is full."""
        cfg, za, zb = self._validate_submit(pl, za, zb)
        with self._cond:
            if (self.max_pending is not None
                    and self._pending_locked() >= self.max_pending):
                self._m["rejected"].inc()
                if self.span_log is not None:
                    s = self.span_log.start_span(
                        "request", engine=self.name,
                        bucket=_bucket_key_str(cfg),
                    )
                    s.finish("rejected", reason="queue_full")
                return None
            return self._enqueue_locked(
                cfg, pl, za, zb, deadline, priority, time.perf_counter()
            )

    def _pending_locked(self) -> int:
        return sum(len(b.heap) for b in self._buckets.values())

    def pending(self) -> int:
        with self._cond:
            return self._pending_locked()

    def _has_work_locked(self) -> bool:
        return self._inflight > 0 or any(
            b.heap for b in self._buckets.values()
        )

    # -- execution -----------------------------------------------------
    def _execute_batch(self, pl: api.Plan, za, zb):
        """The raw dispatch executor: one padded micro-batch through the
        plan's datapath.  ``self.executor`` points here unless a fault
        injector (or test double) wrapped it."""
        if api.plan_key(pl).width == "oracle":
            # Host-only width: no tracing — runs the exact bigint path.
            return np.asarray(api.polymul(pl, za, zb))
        return np.asarray(self._exec(pl, jnp.asarray(za), jnp.asarray(zb)))

    def _fallback_plan(self, pl: api.Plan) -> api.Plan | None:
        """The next plan down the degradation chain for ``pl`` — same
        n/t/v (bit-exact results), one backend simpler — or ``None``
        when the chain is exhausted / the width has no chain."""
        cfg = api.plan_key(pl)
        if cfg.width != "int64":
            return None
        nb = FALLBACK_NEXT.get(cfg.backend)
        if nb is None:
            return None
        # Prefer carrying the frozen spec (identical tiling); if that
        # combination is unservable on the fallback backend, let plan()
        # re-resolve from the schedule kind.
        for sched in (cfg.schedule, cfg.schedule.kind):
            try:
                return api.plan(
                    n=cfg.n, t=cfg.t, v=cfg.v, backend=nb, schedule=sched,
                    row_blk=cfg.row_blk, use_sau=cfg.use_sau,
                )
            except ValueError:
                continue
        return None

    # -- scheduling ----------------------------------------------------
    def _collect_expired(self) -> list[tuple[_Request, float]]:
        """Pop every queued request whose deadline has already passed.
        The heap orders by deadline first, so expired entries are always
        at the head."""
        out = []
        now = time.perf_counter()
        with self._cond:
            for b in self._buckets.values():
                while b.heap and b.heap[0][0] < now:
                    out.append((b.pop(), now))
            if out:
                self._m["shed"].inc(len(out))
                self._cond.notify_all()  # queue space freed
        return out

    def _resolve_shed(self, items: list[tuple[_Request, float]]) -> int:
        for req, now in items:
            if req.span is not None:
                reason = (
                    "expired" if req.deadline is not None
                    and req.deadline <= now else "unmeetable"
                )
                req.span.finish(
                    "shed", reason=reason, latency_s=now - req.t_submit
                )
            req.future._fail(
                DeadlineExceededError(
                    f"deadline missed before dispatch (seq {req.seq}, "
                    f"{max(now - req.deadline, 0.0):.6f}s late)",
                    request_seq=req.seq, deadline_s=req.deadline,
                    late_s=max(now - req.deadline, 0.0),
                ),
                latency_s=now - req.t_submit,
            )
        return len(items)

    def _select_locked(self, now: float):
        """EDF bucket choice: among buckets whose backoff gate is open,
        pick the one whose head request sorts first by
        (deadline, -priority, seq).  Returns ``None`` (idle),
        ``("defer", wake_at)`` (all live buckets backing off) or
        ``("go", bucket, plan_to_use, probing)``."""
        live = [b for b in self._buckets.values() if b.heap]
        if not live:
            return None
        ready = [b for b in live if b.not_before <= now]
        if not ready:
            return ("defer", min(b.not_before for b in live))
        bucket = min(ready, key=lambda b: b.heap[0][:3])
        probing = (
            bucket.level > 0
            and now - bucket.opened_at >= self.breaker_cooldown_s
        )
        if probing:
            self._m["probes"].inc()
            if self.span_log is not None:
                self.span_log.event(
                    "probe", engine=self.name,
                    bucket=_bucket_key_str(bucket.key),
                    backend=api.plan_key(bucket.chain[0]).backend,
                )
            use_plan = bucket.chain[0]
        else:
            use_plan = bucket.active_plan
        return ("go", bucket, use_plan, probing)

    def _admit_locked(self, bucket: _Bucket):
        """Pop up to ``batch_slots`` requests, shedding any whose
        deadline cannot be met given the bucket's EWMA service time."""
        now = time.perf_counter()
        est = bucket.ewma_service_s
        reqs, shed = [], []
        while bucket.heap and len(reqs) < self.batch_slots:
            req = bucket.pop()
            if req.deadline is not None and now + est > req.deadline:
                shed.append((req, now))
            else:
                reqs.append(req)
        if shed:
            self._m["shed"].inc(len(shed))
        if reqs or shed:
            self._cond.notify_all()  # queue space freed
        return reqs, shed

    # -- dispatch ------------------------------------------------------
    def step(self) -> int:
        """Dispatch ONE micro-batch from the EDF-chosen bucket.  Returns
        the number of requests *resolved* during the call — served,
        shed, or failed; 0 when idle.  A dispatch that raises never
        loses requests: the popped requests are requeued (bounded
        retries, per-bucket backoff + circuit breaking) or their futures
        fail with a typed error."""
        resolved = self._resolve_shed(self._collect_expired())
        while True:
            with self._cond:
                pick = self._select_locked(time.perf_counter())
            if pick is None:
                return resolved
            if pick[0] == "defer":
                if self._stop_evt.is_set():
                    return resolved
                time.sleep(
                    min(max(pick[1] - time.perf_counter(), 0.0), 0.05)
                )
                resolved += self._resolve_shed(self._collect_expired())
                continue
            _, bucket, use_plan, probing = pick
            with self._cond:
                reqs, shed = self._admit_locked(bucket)
                if reqs:
                    self._inflight += len(reqs)
            resolved += self._resolve_shed(shed)
            if not reqs:
                continue  # everything admitted this round was shed
            with self._cond:
                dispatch_idx = self._dispatch_seq
                self._dispatch_seq += 1
            cfg = api.plan_key(use_plan)
            traces_before = len(self._trace_log)
            t0 = time.perf_counter()
            for r in reqs:
                if r.attempts == 0:  # first attempt: the queue wait
                    self._h_queue_wait.observe(t0 - r.t_submit)
                if r.span is not None:
                    r.span.event(
                        "dispatch", dispatch_index=dispatch_idx,
                        backend=cfg.backend, batch=len(reqs),
                        attempt=r.attempts, probing=probing,
                    )
            try:
                if cfg.width == "oracle":
                    za = np.stack([r.za for r in reqs])
                    zb = np.stack([r.zb for r in reqs])
                    out = np.asarray(self.executor(use_plan, za, zb))
                    pad = 0
                else:
                    B = self.batch_slots
                    za = np.zeros((B, cfg.n, cfg.seg_count), np.int64)
                    zb = np.zeros_like(za)
                    for i, r in enumerate(reqs):
                        za[i] = r.za
                        zb[i] = r.zb
                    out = np.asarray(self.executor(use_plan, za, zb))
                    pad = B - len(reqs)
            except Exception as e:  # noqa: BLE001 — any dispatch failure
                resolved += self._on_dispatch_failure(
                    bucket, use_plan, probing, reqs, e
                )
                return resolved
            # A dispatch that triggered a jit trace spent its wall time
            # compiling; folding that into the EWMA would make the
            # deadline admission shed everything behind it.
            exec_s = (
                time.perf_counter() - t0
                if len(self._trace_log) == traces_before else None
            )
            resolved += self._on_dispatch_success(
                bucket, probing, reqs, out, pad, dispatch_idx, exec_s
            )
            return resolved

    def _on_dispatch_success(self, bucket, probing, reqs, out, pad,
                             dispatch_idx, exec_s) -> int:
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            if r.span is not None:
                r.span.finish(
                    "resolved", latency_s=now - r.t_submit,
                    dispatch_index=dispatch_idx,
                )
            r.future._resolve(out[i], now - r.t_submit,
                              dispatch_index=dispatch_idx)
        with self._cond:
            self._inflight -= len(reqs)
            bucket.failures = 0
            bucket.not_before = 0.0
            if probing and bucket.level > 0:
                bucket.level = 0  # probe succeeded: breaker closes
                self._m["breaker_recovered"].inc()
                if self.span_log is not None:
                    self.span_log.event(
                        "breaker_recovered", engine=self.name,
                        bucket=_bucket_key_str(bucket.key),
                    )
            if exec_s is not None:  # None: compile dispatch, not service
                bucket.ewma_service_s = (
                    exec_s if bucket.ewma_service_s == 0.0
                    else 0.75 * bucket.ewma_service_s + 0.25 * exec_s
                )
            self._m["dispatches"].inc()
            self._m["served"].inc(len(reqs))
            self._m["padded_slots"].inc(pad)
            for r in reqs:
                self._latencies.append(now - r.t_submit)
                self._h_latency.observe(now - r.t_submit)
            self._cond.notify_all()
        return len(reqs)

    def _on_dispatch_failure(self, bucket, use_plan, probing, reqs,
                             exc) -> int:
        """Fail or requeue exactly the popped requests — never lose
        them.  Non-probe failures advance the bucket's backoff and (at
        the threshold) its circuit breaker; probe failures just restart
        the cool-down without burning request retry budget."""
        now = time.perf_counter()
        failed: list[_Request] = []
        with self._cond:
            self._inflight -= len(reqs)
            self._m["dispatch_failures"].inc()
            for r in reqs:
                if not probing:
                    r.attempts += 1
                if r.attempts > self.max_retries:
                    failed.append(r)
                else:
                    bucket.push(r)
                    self._m["retried"].inc()
                    if r.span is not None:
                        r.span.event(
                            "retry", attempt=r.attempts,
                            error=type(exc).__name__, probing=probing,
                        )
            self._m["failed"].inc(len(failed))
            if probing:
                bucket.opened_at = now  # stay degraded, restart cooldown
            else:
                bucket.failures += 1
                if (bucket.failures >= self.breaker_threshold
                        and self._degrade_locked(bucket, now)):
                    pass  # breaker opened: retry immediately on fallback
                else:
                    bucket.not_before = now + min(
                        self.backoff_base_s * 2 ** (bucket.failures - 1),
                        1.0,
                    )
            self._cond.notify_all()
        backend = api.plan_key(use_plan).backend
        for r in failed:
            if r.span is not None:
                r.span.finish(
                    "failed", backend=backend, attempts=r.attempts,
                    error=type(exc).__name__, latency_s=now - r.t_submit,
                )
            err = BackendFailedError(
                f"request seq {r.seq} failed after {r.attempts} dispatch "
                f"attempts (last backend {backend!r}): {exc}",
                request_seq=r.seq, backend=backend, attempts=r.attempts,
            )
            err.__cause__ = exc
            r.future._fail(err, latency_s=now - r.t_submit)
        return len(failed)

    def _degrade_locked(self, bucket: _Bucket, now: float) -> bool:
        """Open the bucket's breaker: activate (building if needed) the
        next plan down the fallback chain.  False when exhausted."""
        if bucket.level + 1 >= len(bucket.chain):
            nxt = self._fallback_plan(bucket.chain[bucket.level])
            if nxt is None:
                return False
            bucket.chain.append(nxt)
        bucket.level += 1
        bucket.failures = 0
        bucket.opened_at = now
        bucket.not_before = 0.0
        self._m["breaker_opened"].inc()
        if self.span_log is not None:
            self.span_log.event(
                "breaker_open", engine=self.name,
                bucket=_bucket_key_str(bucket.key),
                level=bucket.level,
                backend=api.plan_key(bucket.active_plan).backend,
            )
        return True

    def run_until_idle(self) -> int:
        """Drain every bucket.  Synchronous mode: drives :meth:`step`
        and returns the number of requests resolved.  With the
        background dispatcher running: blocks until the queue and
        in-flight work drain (returns 0; see ``stats``)."""
        if self._thread is not None:
            with self._cond:
                while self._has_work_locked():
                    if self._loop_error is not None:
                        raise RuntimeError(
                            "engine dispatcher thread died"
                        ) from self._loop_error
                    self._cond.wait(0.01)
            return 0
        total = 0
        while True:
            total += self.step()
            with self._cond:
                if not self._has_work_locked():
                    return total

    def serve(self, requests) -> list[np.ndarray]:
        """Convenience closed loop: submit ``(plan, za, zb)`` triples,
        drain, return results in submission order."""
        futs = [self.submit(pl, za, zb) for pl, za, zb in requests]
        self.run_until_idle()
        return [f.result() for f in futs]

    # -- async front end -----------------------------------------------
    def start(self) -> "PolymulEngine":
        """Launch the background dispatcher thread (idempotent).  While
        running, submissions are served without the caller driving
        ``step()``, and new futures block in ``result()``."""
        if self._thread is not None:
            return self
        self._stop_evt.clear()
        self._loop_error = None
        self._thread = threading.Thread(
            target=self._dispatch_loop, name="polymul-engine-dispatch",
            daemon=True,
        )
        self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None

    def _dispatch_loop(self) -> None:
        try:
            while not self._stop_evt.is_set():
                n = self.step()
                if n == 0:
                    with self._cond:
                        if (not self._stop_evt.is_set()
                                and not self._has_work_locked()):
                            self._cond.wait(0.02)
        except BaseException as e:  # surfaced by run_until_idle/stop
            self._loop_error = e
            with self._cond:
                self._cond.notify_all()
            raise

    def stop(self, *, drain: bool = True,
             timeout: float | None = None) -> None:
        """Stop the dispatcher thread; with ``drain`` (default) first
        wait until every queued request has resolved."""
        if self._thread is None:
            return
        if drain:
            self.run_until_idle()
        self._stop_evt.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join(timeout)
        self._thread = None
        if self._loop_error is not None:
            err, self._loop_error = self._loop_error, None
            raise RuntimeError("engine dispatcher thread died") from err

    def __enter__(self) -> "PolymulEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop(drain=exc_type is None)
        return False

    # -- probes --------------------------------------------------------
    @property
    def stats(self) -> dict:
        """Live counter view: ``{stat: int}`` read from this engine's
        children in the metrics registry (see ``_STAT_KEYS`` for the
        vocabulary).  A fresh plain dict per access — exporters and the
        registry itself hold the canonical series."""
        return {k: int(c.value) for k, c in self._m.items()}

    def snapshot(self) -> dict:
        """Point-in-time stats: the counters plus queue depth, in-flight
        count, p50/p99 submit-to-result latency (ms, over the last
        ``latency_window`` served requests) and per-bucket active
        backends — what the soak driver and CLIs gate on/report.

        The snapshot is a FROZEN wire contract: its key set is exactly
        ``SNAPSHOT_KEYS`` at ``schema_version`` =
        ``SNAPSHOT_SCHEMA_VERSION`` (each key documented there), pinned
        by a regression test so dashboards can't silently break.  It is
        an exporter view over the metrics registry — the same numbers
        are scrapeable via :func:`repro.obs.to_prometheus`."""
        with self._cond:
            snap = dict(self.stats)
            snap["schema_version"] = SNAPSHOT_SCHEMA_VERSION
            snap["engine"] = self.name
            snap["queue_depth"] = self._pending_locked()
            snap["inflight"] = self._inflight
            self._g_queue_depth.set(snap["queue_depth"])
            self._g_inflight.set(snap["inflight"])
            if self._latencies:
                lat = np.asarray(self._latencies) * 1e3
                snap["latency_p50_ms"] = float(np.percentile(lat, 50))
                snap["latency_p99_ms"] = float(np.percentile(lat, 99))
            else:
                snap["latency_p50_ms"] = None
                snap["latency_p99_ms"] = None
            snap["degraded_buckets"] = sum(
                1 for b in self._buckets.values() if b.level > 0
            )
            snap["bucket_backends"] = {
                _bucket_key_str(c): api.plan_key(b.active_plan).backend
                for c, b in self._buckets.items()
            }
        return snap

    def reset_stats(self) -> None:
        """Zero every counter/histogram series of THIS engine and drop
        the latency window (benchmark warm-up hygiene)."""
        with self._cond:
            for c in self._m.values():
                c.reset()
            self._h_latency.reset()
            self._h_queue_wait.reset()
            self._latencies.clear()

    @property
    def trace_count(self) -> int:
        """Compilations of the engine executor so far; equals the
        number of distinct PlanConfigs dispatched (the bucket contract —
        breaker degradation adds one per newly-activated fallback)."""
        return len(self._trace_log)

    @property
    def traced_configs(self) -> tuple:
        return tuple(self._trace_log)
