"""Backend-dispatch layer: the one switch that selects the datapath for
the whole stack (jnp / pallas / pallas_fused), its shape contracts, and
the regression gate that keeps the fused Pallas cascade bit-exact
against the Python bigint oracles."""
import os
import random
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.kernels import ops

PRESETS = [(3, 30, 64), (6, 30, 256)]


def _rand_ints(p, seed):
    rng = random.Random(seed)
    a = [rng.randrange(p.q) for _ in range(p.n)]
    b = [rng.randrange(p.q) for _ in range(p.n)]
    return a, b


class TestFusedBitExact:
    """The paper's headline datapath must match the exact oracles."""

    @pytest.mark.parametrize("t,v,n", PRESETS)
    def test_pallas_fused_vs_oracles(self, t, v, n):
        p = params_mod.make_params(n=n, t=t, v=v)
        a, b = _rand_ints(p, seed=n)
        pl = repro.plan(n=n, t=t, v=v, backend="pallas_fused")
        got = repro.polymul_ints(pl, a, b)
        assert got == pm.oracle_multiply(a, b, p)
        assert got == pm.schoolbook_negacyclic(a, b, p.q)

    @pytest.mark.parametrize("t,v,n", PRESETS)
    def test_pallas_fused_e2e_vs_oracles(self, t, v, n):
        """The single-kernel decompose -> cascade -> compose pipeline
        (residues never touch HBM) must be bit-exact too."""
        p = params_mod.make_params(n=n, t=t, v=v)
        a, b = _rand_ints(p, seed=13 * n)
        pl = repro.plan(n=n, t=t, v=v, backend="pallas_fused_e2e")
        got = repro.polymul_ints(pl, a, b)
        assert got == pm.oracle_multiply(a, b, p)
        assert got == pm.schoolbook_negacyclic(a, b, p.q)

    @pytest.mark.parametrize("t,v,n", PRESETS)
    def test_backends_agree(self, t, v, n):
        p = params_mod.make_params(n=n, t=t, v=v)
        a, b = _rand_ints(p, seed=7 * n)
        outs = {
            bk: repro.polymul_ints(repro.plan(n=n, t=t, v=v, backend=bk), a, b)
            for bk in ops.BACKENDS
        }
        for bk, got in outs.items():
            assert got == outs["jnp"], f"backend {bk} disagrees with jnp"


class TestDispatch:
    def test_params_carry_backend(self):
        p = params_mod.make_params(n=64, t=3, v=30, backend="pallas_fused")
        assert p.backend == "pallas_fused"
        from repro import api

        assert api.plan_from_params(p).config.backend == "pallas_fused"
        # backend variants share one table/plan object (single H2D upload)
        pj = params_mod.make_params(n=64, t=3, v=30)
        assert p.tables is pj.tables and p.plan is pj.plan

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown backend"):
            repro.plan(n=64, t=3, v=30, backend="cuda")
        with pytest.raises(ValueError, match="unknown backend"):
            params_mod.make_params(n=64, t=3, v=30, backend="nope")
        err = pytest.raises(
            repro.UnknownKnobError, repro.plan, n=64, t=3, v=30, backend="cuda"
        ).value
        assert err.knob == "backend" and err.value == "cuda"
        assert "jnp" in err.alternatives

    def test_v45_pallas_backend_unservable(self):
        # The wide width has no Pallas datapath: the plan-time error
        # carries the knob and the servable alternatives.
        err = pytest.raises(
            repro.UnservableConfigError,
            repro.plan, n=64, t=4, v=45, backend="pallas_fused",
        ).value
        assert err.knob == "backend" and err.value == "pallas_fused"
        assert err.alternatives == ("auto", "jnp")

    def test_residue_shape_mismatch_fails_loudly(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        good = jnp.zeros((3, 2, 64), dtype=jnp.int64)
        bad_t = jnp.zeros((4, 2, 64), dtype=jnp.int64)
        bad_n = jnp.zeros((3, 2, 32), dtype=jnp.int64)
        with pytest.raises(ValueError, match="expected residues"):
            ops.negacyclic_mul(bad_t, bad_t, p)
        with pytest.raises(ValueError, match="expected residues"):
            ops.ntt_forward(bad_n, p)
        with pytest.raises(ValueError, match="shapes differ"):
            ops.negacyclic_mul(good, jnp.zeros((3, 3, 64), dtype=jnp.int64), p)

    def test_segment_shape_mismatch_fails_loudly(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        with pytest.raises(ValueError, match="segments"):
            ops.rns_decompose(jnp.zeros((5, p.plan.seg_count + 1), dtype=jnp.int64), p)
        with pytest.raises(ValueError, match="rns_compose"):
            ops.rns_compose(jnp.zeros((p.t + 1, 5), dtype=jnp.int64), p)

    def test_e2e_stage_calls_degrade(self):
        """Under pallas_fused_e2e the stage entry points have no
        single-kernel equivalent: they must run (degrading to the
        per-stage kernels) and stay exact, so BFV residue-domain call
        sites keep working with the backend threaded through params."""
        p = params_mod.make_params(n=64, t=3, v=30, backend="pallas_fused_e2e")
        pj = params_mod.make_params(n=64, t=3, v=30)
        rng = np.random.default_rng(5)
        z = jnp.asarray(rng.integers(0, 1 << 30, size=(64, p.plan.seg_count)))
        res = ops.rns_decompose(z, p)
        assert np.array_equal(
            np.asarray(res), np.asarray(ops.rns_decompose(z, pj))
        )
        spec = ops.ntt_forward(res.reshape(p.t, 1, p.n), p)
        back = ops.ntt_inverse(spec, p)
        assert np.array_equal(
            np.asarray(back), np.asarray(res.reshape(p.t, 1, p.n))
        )
        limbs = ops.rns_compose(res, p)
        assert np.array_equal(
            np.asarray(limbs), np.asarray(ops.rns_compose(res, pj))
        )

    def test_hbm_traffic_model_ordering(self):
        """The invariant the bench-smoke CI job enforces: each fusion
        level strictly reduces modeled HBM bytes and kernel launches."""
        p = params_mod.make_params(n=64, t=3, v=30)
        models = {
            bk: ops.hbm_traffic_model(p, rows=4, backend=bk)
            for bk in ops.BACKENDS
        }
        assert (
            models["pallas_fused_e2e"]["hbm_bytes"]
            < models["pallas_fused"]["hbm_bytes"]
            < models["pallas"]["hbm_bytes"]
        )
        assert models["pallas_fused_e2e"]["kernel_launches"] == 1
        assert models["pallas_fused_e2e"]["intermediate_bytes"] == 0
        assert models["jnp"]["kernel_launches"] == 0
        # segments in / limbs out is the irreducible floor
        m = models["pallas_fused_e2e"]
        assert m["hbm_bytes"] == m["segment_bytes_in"] + m["limb_bytes_out"]

    def test_traffic_model_matches_traced_launch_counts(self):
        """The model's kernel_launches must equal the number of
        pallas_call equations in the actual traced computation — the
        structural tie that keeps the bench-smoke gate honest if a
        backend is ever de-fused."""
        p = params_mod.make_params(n=64, t=3, v=30)
        for bk in ops.BACKENDS:
            counted = ops.count_pallas_launches(p, backend=bk, rows=2)
            claimed = ops.hbm_traffic_model(p, rows=2, backend=bk)[
                "kernel_launches"
            ]
            assert counted == claimed, (
                f"backend {bk}: traced {counted} pallas_calls, "
                f"model claims {claimed}"
            )
        assert ops.count_pallas_launches(p, backend="pallas_fused_e2e") == 1

    @pytest.mark.parametrize("backend", ["pallas", "pallas_fused", "pallas_fused_e2e"])
    def test_arbitrary_leading_batch_dims(self, backend):
        """(t, B1, B2, n) residues work on the kernel backends (which fold
        to (t, rows, n) tiles internally) and match jnp exactly."""
        p = params_mod.make_params(n=64, t=3, v=30)
        rng = np.random.default_rng(3)
        shape = (2, 3, 64)
        a = jnp.asarray(
            np.stack([rng.integers(0, int(q), size=shape) for q in p.plan.qs])
        )
        b = jnp.asarray(
            np.stack([rng.integers(0, int(q), size=shape) for q in p.plan.qs])
        )
        got = ops.negacyclic_mul(a, b, p, backend=backend)
        want = ops.negacyclic_mul(a, b, p, backend="jnp")
        assert got.shape == a.shape
        assert np.array_equal(np.asarray(got), np.asarray(want))


class TestCollection:
    @pytest.mark.slow  # subprocess full-suite collection (~30 s); the CI
    # fast lane runs the same check as a dedicated workflow step
    def test_collect_only_is_clean(self):
        """Collection errors can never silently return: `pytest
        --collect-only` over the whole suite must exit 0."""
        repo = Path(__file__).resolve().parent.parent
        env = dict(os.environ)
        src = str(repo / "src")
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "--collect-only", "-q", "tests"],
            cwd=repo,
            env=env,
            capture_output=True,
            text=True,
            timeout=300,
        )
        # pytest exits 2 on collection errors, 0 when everything collects
        assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-2000:]
