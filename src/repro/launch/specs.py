"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation.

``train`` cells lower train_step(params, opt_state, batch);
``prefill`` cells lower a last-token-logits forward;
``decode`` cells lower serve_step(params, cache, one-token batch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.train import optimizer as opt_mod

ENC_LEN_FRACTION = 1  # encoder length == shape seq_len for encdec prefill/train


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input ShapeDtypeStructs for one cell."""
    B = shape.global_batch
    S = shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    emb = lambda b, s: jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        out = {"labels": tok(B, S)}
        if cfg.family == "encdec":
            out["enc_embeddings"] = emb(B, S)
            out["tokens"] = tok(B, S)
        elif cfg.frontend:
            out["embeddings"] = emb(B, S)
        else:
            out["tokens"] = tok(B, S)
        return out
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"enc_embeddings": emb(B, S), "tokens": tok(B, S)}
        if cfg.frontend:
            return {"embeddings": emb(B, S)}
        return {"tokens": tok(B, S)}
    # decode: one new token against a seq_len-deep cache
    if cfg.frontend and cfg.family != "encdec":
        return {"embeddings": emb(B, 1)}
    return {"tokens": tok(B, 1)}


def state_specs(cfg: ModelConfig):
    """params + optimizer-state ShapeDtypeStructs via eval_shape."""
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: opt_mod.init(params))
    return params, opt


def cache_specs_for(cfg: ModelConfig, shape: ShapeConfig):
    B, T = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: M.init_cache(cfg, B, T, enc_len=min(T, 4096)))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Everything the lowered step consumes, as ShapeDtypeStructs."""
    out = {"batch": batch_specs(cfg, shape)}
    params, opt = state_specs(cfg)
    out["params"] = params
    if shape.kind == "train":
        out["opt_state"] = opt
    if shape.kind == "decode":
        out["cache"] = cache_specs_for(cfg, shape)
    return out
