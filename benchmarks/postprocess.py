"""Paper Table V: inverse-mapping (post-processing) — conventional Fig 16(a)
(multiply by full e_i, wide reduction over q) vs optimized Fig 16(b)
(Eq 10: short mod-q_i, v x (t-1)v constant multiply, conditional-subtract
tail).  Op-count proxy + measured wall-clock of both jit'd paths.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import params as params_mod
from repro.core import rns


def op_counts(plan):
    t, L = plan.t, plan.L
    conventional = {
        "wide_mult_bits": t * plan.v * plan.q.bit_length(),  # v x vt each
        "wide_reductions": 1,  # mod q over ~ (vt + v)-bit value
        "adds": t - 1,
    }
    proposed = {
        "short_mults_bits": t * plan.v * plan.v  # [p_i q~_i]_{q_i}
        + t * plan.v * (plan.q.bit_length() - plan.v),  # x q_i^*
        "mod_qi_reductions": t,
        "cond_subs": t - 1,
    }
    return conventional, proposed


def run():
    out = []
    p = params_mod.make_params(n=4096, t=6, v=30)
    conv, prop = op_counts(p.plan)
    out.append(
        (
            "tableV_opcounts_t6_v30",
            0.0,
            f"conv_wide_mult_bits={conv['wide_mult_bits']} "
            f"prop_short_mult_bits={prop['short_mults_bits']} "
            f"conv_wide_modq=1 prop_mod_qi={prop['mod_qi_reductions']} "
            f"prop_cond_subs={prop['cond_subs']}",
        )
    )
    rng = np.random.default_rng(1)
    res = jnp.asarray(
        np.stack([rng.integers(0, int(q), size=4096) for q in p.plan.qs])
    )
    f_opt = jax.jit(lambda r: rns.compose(r, p.plan))
    f_conv = jax.jit(lambda r: rns.compose_conventional(r, p.plan))
    a, b = np.asarray(f_opt(res)), np.asarray(f_conv(res))
    assert np.array_equal(a, b[:, : a.shape[1]])
    for name, fn in [("optimized_eq10", f_opt), ("conventional", f_conv)]:
        jax.block_until_ready(fn(res))
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(res))
        us = (time.perf_counter() - t0) / 10 * 1e6
        out.append(
            (f"tableV_postprocess_{name}", us, "n=4096 coeffs, t=6, v=30 (CPU)")
        )
    return out
