"""Structured plan-time error taxonomy (PR 7).

Every rejection in :func:`repro.plan` raises one of these instead of a
bare ``ValueError`` so callers (and serving front ends) can react to the
*shape* of the failure, not a message string:

* :class:`PlanError` — base class; subclasses ``ValueError`` so existing
  ``except ValueError`` call sites keep working.
* :class:`UnknownKnobError` — the value of a single knob is not in its
  vocabulary (unknown backend/schedule string, malformed ``n``/``v``).
* :class:`UnservableConfigError` — every knob is individually valid but
  the combination cannot be served (four_step depth beyond the canonical
  chain, a tile that cannot fit the VMEM budget at ``row_blk=1``, a
  Pallas backend on the wide width, the wide inverse-CRT overflow).

All three carry the offending ``knob`` name, the rejected ``value`` and
a tuple of nearest valid ``alternatives`` (may be empty when nothing is
close).
"""
from __future__ import annotations

from typing import Any, Iterable


class PlanError(ValueError):
    """A configuration was rejected at plan time.

    Attributes
    ----------
    knob:
        Name of the offending keyword (``"backend"``, ``"schedule"``,
        ``"tiling"``, ``"row_blk"``, ``"n"``, ``"v"``, ...), or ``None``
        when the failure is not attributable to a single knob.
    value:
        The rejected value, verbatim.
    alternatives:
        Nearest valid values for that knob (possibly empty).
    """

    def __init__(
        self,
        message: str,
        *,
        knob: str | None = None,
        value: Any = None,
        alternatives: Iterable[Any] = (),
    ) -> None:
        super().__init__(message)
        self.knob = knob
        self.value = value
        self.alternatives = tuple(alternatives)


class UnknownKnobError(PlanError):
    """A single knob's value is outside its vocabulary."""


class UnservableConfigError(PlanError):
    """Individually-valid knobs combine into a config no datapath serves."""
