"""seamless-m4t-medium — enc-dec, 12L(+12L) d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206; speech frontend stubbed to frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,  # encoder layers; decoder mirrors with cross-attention
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    frontend="audio",
)
