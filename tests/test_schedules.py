"""Lane-aligned four-step NTT schedule + Harvey lazy-reduction
butterflies (DESIGN.md §6): bit-exactness of the four_step schedule vs
the radix-2 oracle and the bigint oracle across every backend and entry
point, the lane-alignment / reduction-op cost model vs the traced
kernels, and the lazy-reduction bound bookkeeping."""
import random

import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

import repro
from repro.core import modmath
from repro.core import ntt as ntt_mod
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.kernels import ops

PRESETS = [(3, 30, 64), (6, 30, 256)]
KERNEL_BACKENDS = ["pallas", "pallas_fused", "pallas_fused_e2e"]


def _rand_res(p, rows, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack([rng.integers(0, int(q), size=(rows, p.n)) for q in p.plan.qs])
    )


class TestFourStepOracle:
    """The four-step flow graph must be bit-identical to the radix-2
    reference it was re-grouped from — pure-jnp, no kernels."""

    @pytest.mark.parametrize("n", [8, 64, 128, 256, 512])
    def test_fwd_inv_match_radix2(self, n):
        q = 12289 if n <= 256 else 998244353
        tab = ntt_mod.make_tables(q, n)
        idx = ntt_mod.four_step_row_indices(*ntt_mod.four_step_split(n))
        row_fwd = jnp.asarray(np.asarray(tab.fwd)[idx])
        row_inv = jnp.asarray(np.asarray(tab.inv)[idx])
        rng = np.random.default_rng(n)
        a = jnp.asarray(rng.integers(0, q, size=(3, n)))
        f_ref = ntt_mod.ntt_raw(
            a, jnp.asarray(tab.fwd), q, tab.mul_eps, tab.mul_shifts
        )
        f_fs = ntt_mod.ntt_raw_four_step(
            a, jnp.asarray(tab.fwd), row_fwd, q, tab.mul_eps, tab.mul_shifts
        )
        assert np.array_equal(np.asarray(f_fs), np.asarray(f_ref))
        i_ref = ntt_mod.intt_raw(
            f_ref, jnp.asarray(tab.inv), q, tab.half, tab.mul_eps, tab.mul_shifts
        )
        i_fs = ntt_mod.intt_raw_four_step(
            f_fs, jnp.asarray(tab.inv), row_inv, q, tab.half,
            tab.mul_eps, tab.mul_shifts,
        )
        assert np.array_equal(np.asarray(i_fs), np.asarray(i_ref))
        assert np.array_equal(np.asarray(i_fs), np.asarray(a))

    def test_split_and_bad_n(self):
        assert ntt_mod.four_step_split(256) == (2, 128)
        assert ntt_mod.four_step_split(4096) == (32, 128)
        assert ntt_mod.four_step_split(64) == (2, 32)
        with pytest.raises(ValueError, match="power-of-two"):
            ntt_mod.four_step_split(2)
        with pytest.raises(ValueError, match="power-of-two"):
            ntt_mod.four_step_split(96)


class TestScheduleBitExact:
    """four_step == radix2 == bigint oracle for every dispatch entry
    point, on both presets, across every backend (acceptance gate)."""

    @pytest.mark.parametrize("t,v,n", PRESETS)
    @pytest.mark.parametrize("backend", ["jnp"] + KERNEL_BACKENDS)
    def test_stage_entry_points(self, t, v, n, backend):
        p = params_mod.make_params(n=n, t=t, v=v)
        a = _rand_res(p, 2, seed=n)
        b = _rand_res(p, 2, seed=n + 1)
        for fn, args in (
            (ops.ntt_forward, (a,)),
            (ops.ntt_inverse, (a,)),
            (ops.negacyclic_mul, (a, b)),
        ):
            want = fn(*args, p, backend=backend, schedule="radix2")
            got = fn(*args, p, backend=backend, schedule="four_step")
            assert np.array_equal(np.asarray(got), np.asarray(want)), (
                fn.__name__, backend)

    @pytest.mark.parametrize("t,v,n", PRESETS)
    @pytest.mark.parametrize("schedule", ["radix2", "four_step", "auto"])
    def test_e2e_vs_bigint_oracle(self, t, v, n, schedule):
        pl = repro.plan(
            n=n, t=t, v=v, backend="pallas_fused_e2e", schedule=schedule
        )
        rng = random.Random(17 * n)
        a = [rng.randrange(pl.q) for _ in range(n)]
        b = [rng.randrange(pl.q) for _ in range(n)]
        got = repro.polymul_ints(pl, a, b)
        assert got == pm.oracle_multiply(a, b, pl.params)

    def test_auto_resolution(self):
        spec = ops.resolve_schedule(params_mod.make_params(n=64, t=3, v=30))
        assert spec.kind == "radix2" and spec.splits == ()
        spec = ops.resolve_schedule(params_mod.make_params(n=256, t=6, v=30))
        assert spec.kind == "four_step" and spec.splits == ((2, 128),)
        p = params_mod.make_params(n=64, t=3, v=30, schedule="four_step")
        assert ops.resolve_schedule(p).kind == "four_step"
        assert ops.resolve_schedule(p, "radix2").kind == "radix2"
        with pytest.raises(ValueError, match="unknown schedule"):
            params_mod.make_params(n=64, t=3, v=30, schedule="fft")

    @settings(max_examples=20, deadline=None)
    @given(data=st.data())
    def test_property_schedule_backend_rows(self, data):
        """Randomized (schedule, backend, rows): the cascade is
        bit-identical across every datapath combination."""
        schedule = data.draw(st.sampled_from(["radix2", "four_step", "auto"]))
        backend = data.draw(st.sampled_from(["jnp"] + KERNEL_BACKENDS))
        rows = data.draw(st.integers(min_value=1, max_value=9))
        seed = data.draw(st.integers(min_value=0, max_value=2**31))
        p = params_mod.make_params(n=64, t=3, v=30)
        a = _rand_res(p, rows, seed)
        b = _rand_res(p, rows, seed + 1)
        got = ops.negacyclic_mul(a, b, p, backend=backend, schedule=schedule)
        want = ops.negacyclic_mul(a, b, p, backend="jnp", schedule="radix2")
        assert np.array_equal(np.asarray(got), np.asarray(want))


class TestRowPadding:
    """rows not divisible by row_blk (e.g. rows=3, row_blk=8): the
    padding path must stay bit-exact on every kernel backend — easy to
    break when the grid changes."""

    @pytest.mark.parametrize("backend", KERNEL_BACKENDS)
    @pytest.mark.parametrize("rows,row_blk", [(3, 8), (5, 4), (1, 8)])
    def test_residue_entry_points(self, backend, rows, row_blk):
        p = params_mod.make_params(n=64, t=3, v=30, row_blk=row_blk)
        pj = params_mod.make_params(n=64, t=3, v=30)
        a = _rand_res(p, rows, seed=rows)
        b = _rand_res(p, rows, seed=rows + 100)
        got = ops.negacyclic_mul(a, b, p, backend=backend)
        want = ops.negacyclic_mul(a, b, pj, backend="jnp")
        assert got.shape == a.shape
        assert np.array_equal(np.asarray(got), np.asarray(want))
        gf = ops.ntt_forward(a, p, backend=backend)
        assert np.array_equal(
            np.asarray(gf), np.asarray(ops.ntt_forward(a, pj, backend="jnp"))
        )

    @pytest.mark.parametrize("rows,row_blk", [(3, 8), (7, 4)])
    @pytest.mark.parametrize("schedule", ["radix2", "four_step"])
    def test_e2e_padding(self, rows, row_blk, schedule):
        p = params_mod.make_params(
            n=64, t=3, v=30, backend="pallas_fused_e2e",
            schedule=schedule, row_blk=row_blk,
        )
        pj = params_mod.make_params(n=64, t=3, v=30)
        rng = np.random.default_rng(rows)
        za = jnp.asarray(
            rng.integers(0, 1 << 30, size=(rows, p.n, p.plan.seg_count))
        )
        zb = jnp.asarray(
            rng.integers(0, 1 << 30, size=(rows, p.n, p.plan.seg_count))
        )
        got = ops.fused_polymul_e2e(za, zb, p)
        want = ops.fused_polymul_e2e(za, zb, pj, backend="jnp")
        assert np.array_equal(np.asarray(got), np.asarray(want))


class TestE2eKernelVariants:
    """Both e2e kernel variants — the channel-tiled grid (default for
    t >= 2) and the unrolled-channel fallback (channel_grid=False, not
    reachable through ops dispatch) — must stay bit-exact on both
    schedules."""

    @pytest.mark.parametrize("schedule", ["radix2", "four_step"])
    @pytest.mark.parametrize("channel_grid", [False, True])
    def test_variants_match_jnp(self, schedule, channel_grid):
        from repro.kernels import ntt as ntt_kernels

        p = params_mod.make_params(n=64, t=3, v=30)
        ct = p.tables
        rng = np.random.default_rng(11)
        za = jnp.asarray(
            rng.integers(0, 1 << 30, size=(3, p.n, p.plan.seg_count))
        )
        zb = jnp.asarray(
            rng.integers(0, 1 << 30, size=(3, p.n, p.plan.seg_count))
        )
        lazy = (ct.lazy_window, ct.shoup_beta)
        fwd, fsh, frow, frsh = ops._sched_tables(ct, schedule, lazy, "fwd")
        inv, ish, irow, irsh = ops._sched_tables(ct, schedule, lazy, "inv")
        got = ntt_kernels.fused_e2e_polymul_pallas(
            za, zb, fwd, inv, p.plan.qi_star_limbs_d, p.plan.q_limbs_d,
            fsh, ish, frow, irow, frsh, irsh,
            plan=p.plan, schedule=schedule, lazy=lazy,
            channel_grid=channel_grid, interpret=True,
        )
        want = ops.fused_polymul_e2e(za, zb, p, backend="jnp")
        assert np.array_equal(np.asarray(got), np.asarray(want))


class TestCostModel:
    """The lane-alignment / reduction-op claims, cross-checked against
    the traced kernels (the bench-smoke discipline, in-tree)."""

    @pytest.mark.parametrize("t,v,n", PRESETS)
    @pytest.mark.parametrize("schedule", ["radix2", "four_step"])
    @pytest.mark.parametrize("direction", ["fwd", "inv"])
    def test_model_matches_traced_selects(self, t, v, n, schedule, direction):
        p = params_mod.make_params(n=n, t=t, v=v)
        m = ops.transform_cost_model(p, schedule=schedule, direction=direction)
        c = ops.count_reduction_selects(p, schedule=schedule, direction=direction)
        assert m["reduction_ops"] == c

    def test_four_step_has_no_sublane_stages(self):
        for n in (64, 256, 4096):
            strides = ntt_mod.stage_lane_strides(n, "four_step")
            assert all(s == 0 for s in strides)
        p = params_mod.make_params(n=256, t=6, v=30)
        m = ops.transform_cost_model(p, schedule="four_step")
        assert m["sublane_stages"] == 0
        assert ops.transform_cost_model(p, schedule="radix2")["sublane_stages"] == 7

    def test_lazy_saves_2x_reduction_ops(self):
        p = params_mod.make_params(n=256, t=6, v=30)
        for schedule in ("radix2", "four_step"):
            for direction in ("fwd", "inv"):
                m = ops.transform_cost_model(
                    p, schedule=schedule, direction=direction
                )
                assert m["lazy_window"] is not None
                assert 2 * m["reduction_ops"] <= m["strict_reduction_ops"]


class TestLazyBounds:
    """Harvey lazy-reduction bookkeeping: window selection, the Shoup
    product bounds, and the per-stage invariant the tables record."""

    def test_window_selection(self):
        assert modmath.lazy_params([12289]) == (4, 16)  # 14-bit: wide window
        q30 = int(params_mod.make_params(n=64, t=3, v=30).plan.qs[0])
        assert modmath.lazy_params([q30]) == (2, 32)
        assert modmath.lazy_params([(1 << 31) + 11]) == (None, None)
        assert modmath.lazy_params([12289, 40961 * 4 + 1]) == (None, None)  # mixed

    def test_envelope_validation(self):
        with pytest.raises(ValueError, match="window"):
            modmath.validate_lazy_envelope(12289, 3, 16)
        with pytest.raises(ValueError, match="Shoup operand"):
            modmath.validate_lazy_envelope(12289, 4, 10)

    @pytest.mark.parametrize("q", [12289, None])
    def test_shoup_mul_window(self, q):
        if q is None:
            q = int(params_mod.make_params(n=64, t=3, v=30).plan.qs[0])
        window, beta = modmath.lazy_params([q])
        rng = np.random.default_rng(q & 0xFFFF)
        w = rng.integers(0, q, size=64)
        ws = modmath.shoup_constants(w, q, beta)
        v = jnp.asarray(rng.integers(0, window * q, size=64))
        out = np.asarray(
            modmath.shoup_mul(v, jnp.asarray(w), jnp.asarray(ws), q, beta)
        )
        assert (out >= 0).all() and (out < 2 * q).all()
        assert (out % q == np.asarray(v) * w % q).all()
        canon = np.asarray(modmath.canonicalize(jnp.asarray(out), q, window))
        assert (canon == np.asarray(v) * w % q).all()

    def test_tables_record_bounds(self):
        ct = params_mod.make_params(n=64, t=3, v=30).tables
        assert ct.lazy_window == 2 and ct.shoup_beta == 32
        assert ct.fwd_shoup.shape == ct.fwd.shape
        assert ct.fs_row_fwd.shape == (ct.t, 32, 2)
        bounds = ct.stage_bounds()
        assert len(bounds) == 6  # log2(64) stages
        assert all(b == (2, 4) for b in bounds)
        assert ct.stage_bounds(inverse=True)[0] == (2, 4)
