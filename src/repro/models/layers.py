"""Shared neural layers: RMSNorm, RoPE / M-RoPE, GQA attention (sliding
window, logit softcap, KV cache), SwiGLU MLP, dropping-MoE.

Parameters are plain dict pytrees; layer functions are pure.  Compute in
bf16, normalization/softmax statistics in f32, params in f32 (cast on
entry).  Every array creation states its dtype explicitly (the package
enables x64 for the crypto core).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding import ctx

CDTYPE = jnp.bfloat16


def _cast(x):
    return x.astype(CDTYPE)


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x, positions, theta: float, sections: tuple[int, ...] = ()):
    """x: (B, S, H, Dh); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl §3): the head_dim/2 frequency slots are split into
    `sections` (temporal / height / width), each rotated by its own
    position stream.  With a text-only stream all three position ids are
    equal and the math degenerates to standard RoPE (the vision frontend
    stub supplies equal ids; the *datapath* is the sectioned one).
    """
    B, S, H, Dh = x.shape
    half = Dh // 2
    freqs = jnp.asarray(rope_freqs(Dh, theta), dtype=jnp.float32)  # (half,)
    if sections:
        assert sum(sections) == half, (sections, half)
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        pos_parts = []
        start = 0
        for si, sec in enumerate(sections):
            p = positions[si].astype(jnp.float32)  # (B, S)
            pos_parts.append(p[:, :, None] * freqs[None, None, start : start + sec])
            start += sec
        ang = jnp.concatenate(pos_parts, axis=-1)  # (B, S, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA; optional sliding window + softcap; prefill & decode)
# --------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig):
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, hk * dh)),
        "wv": dense_init(ks[2], (d, hk * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits


def _attn_scores(q, k, cfg: ModelConfig, q_pos, k_pos, window, causal: bool):
    """q: (B,Sq,Hk,G,Dh), k: (B,Sk,Hk,Dh) -> masked logits (B,Hk,G,Sq,Sk)."""
    # python float (weak type): np.float64 here promotes the whole S^2
    # softmax chain to f64 under x64 — 2x HBM on the dominant tensors
    # (caught by the §Perf hillclimb, iteration B2)
    scale = float(1.0 / np.sqrt(cfg.head_dim_))
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    dq = q_pos[:, :, None]  # (B, Sq, 1)
    dk = k_pos[:, None, :]  # (B, 1, Sk)
    mask = jnp.ones(dq.shape[:2] + dk.shape[-1:], dtype=bool)
    if causal:
        mask = mask & (dk <= dq)
    if window is not None:
        # window == 0 means global: keep everything
        mask = mask & ((dk > dq - window) | (window == 0))
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    return logits


def attention_apply(
    params,
    x,
    cfg: ModelConfig,
    positions,
    *,
    layer_window=None,  # traced scalar or None: sliding window size (0 = global)
    kv_cache: Optional[dict] = None,  # {"k","v": (B,T,Hk,Dh), "pos": scalar}
    cross_kv=None,  # (k, v) for cross-attention (enc-dec)
    causal: bool = True,
):
    """Returns (out, new_kv_cache)."""
    B, S, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // hk
    xq = _cast(x) @ _cast(params["wq"])
    q = xq.reshape(B, S, h, dh)
    if cross_kv is None:
        k = (_cast(x) @ _cast(params["wk"])).reshape(B, S, hk, dh)
        v = (_cast(x) @ _cast(params["wv"])).reshape(B, S, hk, dh)
        rope_pos = positions
        q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.mrope_sections)
    else:
        k, v = cross_kv

    new_cache = None
    if kv_cache is not None and cross_kv is None:
        pos = kv_cache["pos"]  # scalar int: #valid entries
        z = jnp.zeros((), jnp.int32)
        idx = (z, pos.astype(jnp.int32), z, z)
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, idx)
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, idx)
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
        k, v = ck, cv
        T = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        # entries beyond pos+S are invalid -> mask via causal (q_pos < them)
    else:
        Sk = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))

    q_pos = positions if positions.ndim == 2 else positions[0]
    qg = q.reshape(B, S, hk, g, dh)
    window = None
    if layer_window is not None:
        window = layer_window
    logits = _attn_scores(qg, k, cfg, q_pos, k_pos, window, causal and cross_kv is None)
    probs = jax.nn.softmax(logits, axis=-1).astype(CDTYPE)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    ctx = ctx.reshape(B, S, h * dh)
    out = ctx @ _cast(params["wo"])
    return out, new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------


def mlp_init(key, d: int, f: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f)),
        "w_up": dense_init(ks[1], (d, f)),
        "w_down": dense_init(ks[2], (f, d)),
    }


def mlp_apply(params, x):
    xc = _cast(x)
    h = jax.nn.silu(xc @ _cast(params["w_gate"])) * (xc @ _cast(params["w_up"]))
    return h @ _cast(params["w_down"])


# --------------------------------------------------------------------------
# Dropping MoE (mesh-TF style dispatch/combine einsums; capacity-bounded)
# --------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=0.02),
        "we_gate": dense_init(ks[1], (e, d, f)),
        "we_up": dense_init(ks[2], (e, d, f)),
        "we_down": dense_init(ks[3], (e, f, d)),
    }
    if cfg.moe_shared_expert:
        p["shared"] = mlp_init(ks[4], d, f)
    return p


def moe_apply(params, x, cfg: ModelConfig):
    """x: (B, S, D).  Top-k routing, capacity-bounded with token dropping.

    Dispatch is PER BATCH ROW: every row owns an (E, C_row) slot buffer
    (C_row = ceil(S*K/E * cf)) filled with a vmapped LOCAL scatter.  This
    makes the dispatch shardable by construction — the batch dim shards
    over `data`, so the scatter never crosses devices, and the only
    cross-device movement is the (B, E, C_row, D) expert exchange over
    `model` (the canonical MoE all-to-all).  A single global-capacity
    scatter is unshardable for GSPMD: it replicates the buffer and then
    either 16x's the expert FLOPs or all-reduces (C, F) partial sums
    (measured; EXPERIMENTS §Perf cell A iterations 1-3).

    Compute is O(tokens * K * cf * D * F) — proportional to *active*
    parameters.  Deterministic shapes -> dryrun friendly."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(np.ceil(S * K / E * cfg.capacity_factor)))  # per row
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B,S,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9, None)
    flat_e = gate_idx.reshape(B, S * K)  # expert id per assignment, per row
    gates = gate_vals.reshape(B, S * K).astype(CDTYPE)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, SK, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    my_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]  # (B, SK)
    keep = my_pos < C
    slot = jnp.where(keep, flat_e * C + my_pos, E * C)  # sentinel slot drops
    tok = jnp.arange(S * K, dtype=jnp.int32) // K
    xk = _cast(x)[:, tok, :] * keep[..., None].astype(CDTYPE)  # (B, SK, D)

    # shard_map'd per-row scatter: batch-local, zero collectives (see
    # ctx.moe_scatter for why a plain batched scatter cannot be sharded)
    buf = ctx.moe_scatter(slot, xk, E * C + 1)  # (B, E*C+1, D)
    xe = buf[:, : E * C].reshape(B, E, C, D)
    xe = ctx.constrain(xe, "moe_tokens")  # a2a: batch->data, experts->model
    # FSDP-stored expert weights are GATHERED for use (weight all-gather,
    # ~0.5 GB/layer); without this, GSPMD contracts the FSDP-sharded dim
    # and all-reduces (C, F)-sized grad partial sums (28 GB x 2 x L).
    w_gate = ctx.constrain(_cast(params["we_gate"]), "moe_w")
    w_up = ctx.constrain(_cast(params["we_up"]), "moe_w")
    w_down = ctx.constrain(_cast(params["we_down"]), "moe_w")
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w_gate))
    h = h * jnp.einsum("becd,edf->becf", xe, w_up)
    eout = jnp.einsum("becf,efd->becd", h, w_down)
    eout = ctx.constrain(eout, "moe_tokens")
    eout = jnp.concatenate(
        [eout.reshape(B, E * C, D), jnp.zeros((B, 1, D), dtype=CDTYPE)], axis=1
    )
    y = ctx.moe_gather(eout, slot) * gates[..., None]
    out = y.reshape(B, S, K, D).sum(axis=2)
    if cfg.moe_shared_expert:
        out = out + mlp_apply(params["shared"], x)
    return out
