"""Observability report CLI: render latency / throughput / lifecycle
breakdowns from a span log, audit span conservation, validate exporter
output, and (optionally) run the per-stage profiling harness.

    PYTHONPATH=src python -m repro.launch.obs_report spans.jsonl
    PYTHONPATH=src python -m repro.launch.obs_report spans.jsonl --check
    PYTHONPATH=src python -m repro.launch.obs_report --prom metrics.prom
    PYTHONPATH=src python -m repro.launch.obs_report --stages 64:3:30

``--check`` is the span-conservation gate of the ``obs-smoke`` CI job:
every admitted request span must carry exactly one terminal status
(``resolved`` / ``shed`` / ``failed``); any violation exits non-zero
with the offending trace IDs.  ``--prom`` parses a Prometheus text-format
file through the strict validator (:func:`repro.obs.parse_prometheus`)
and exits non-zero on malformed expositions.  ``--stages n:t:v`` runs
the compiled stage-timing harness and prints measured stage shares
beside the ``hbm_traffic_model`` predictions with per-stage drift.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any

import numpy as np

from repro import obs

__all__ = ["lifecycle_report", "main"]


def _percentiles(xs: list[float]) -> dict[str, float | None]:
    if not xs:
        return {"p50_ms": None, "p99_ms": None, "mean_ms": None}
    a = np.asarray(xs) * 1e3
    return {
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "mean_ms": float(a.mean()),
    }


def lifecycle_report(records: list[dict[str, Any]]) -> dict[str, Any]:
    """Aggregate a span log into the report record: conservation audit,
    per-status counts, latency/queue-wait percentiles, throughput, and
    per-bucket + engine-event breakdowns."""
    cons = obs.conservation(records)
    spans = [
        r for r in records
        if r["kind"] == "span" and r["name"] == "request"
    ]
    resolved = [s for s in spans if s["status"] == "resolved"]

    latencies, queue_waits, dispatch_waits = [], [], []
    retries = 0
    by_bucket: dict[str, dict[str, int]] = {}
    for s in spans:
        bucket = s["attrs"].get("bucket", "?")
        bb = by_bucket.setdefault(bucket, {})
        bb[s["status"]] = bb.get(s["status"], 0) + 1
        first_dispatch = next(
            (e for e in s["events"] if e["name"] == "dispatch"), None
        )
        if first_dispatch is not None:
            queue_waits.append(first_dispatch["t"] - s["t_start"])
        retries += sum(1 for e in s["events"] if e["name"] == "retry")
        if s["status"] == "resolved" and s["t_end"] is not None:
            latencies.append(s["t_end"] - s["t_start"])
            if first_dispatch is not None:
                dispatch_waits.append(s["t_end"] - first_dispatch["t"])

    t_lo = min((s["t_start"] for s in spans), default=None)
    t_hi = max(
        (s["t_end"] for s in spans if s["t_end"] is not None), default=None
    )
    wall = (t_hi - t_lo) if (t_lo is not None and t_hi is not None) else None
    events: dict[str, int] = {}
    for r in records:
        if r["kind"] == "event":
            events[r["name"]] = events.get(r["name"], 0) + 1
    return {
        "records": len(records),
        "spans": cons["spans"],
        "admitted": cons["admitted"],
        "by_status": cons["by_status"],
        "violations": cons["violations"],
        "retry_events": retries,
        "engine_events": events,
        "wall_s": wall,
        "throughput_rps": (
            len(resolved) / wall if wall else None
        ),
        "latency": _percentiles(latencies),
        "queue_wait": _percentiles(queue_waits),
        "dispatch_to_resolve": _percentiles(dispatch_waits),
        "by_bucket": by_bucket,
    }


def _fmt_ms(v: float | None) -> str:
    return "-" if v is None else f"{v:.3f}ms"


def _print_report(rep: dict[str, Any]) -> None:
    print(f"spans: {rep['spans']} ({rep['admitted']} admitted) "
          f"over {rep['records']} records")
    for status, count in sorted(rep["by_status"].items()):
        print(f"  {status:<10} {count}")
    if rep["engine_events"]:
        ev = ", ".join(
            f"{k}={v}" for k, v in sorted(rep["engine_events"].items())
        )
        print(f"engine events: {ev}")
    if rep["retry_events"]:
        print(f"retry events: {rep['retry_events']}")
    if rep["wall_s"]:
        print(f"throughput: {rep['throughput_rps']:.1f} resolved/s "
              f"over {rep['wall_s']:.3f}s")
    for label, key in (("latency", "latency"),
                       ("queue wait", "queue_wait"),
                       ("dispatch->resolve", "dispatch_to_resolve")):
        p = rep[key]
        print(f"{label:<18} p50={_fmt_ms(p['p50_ms'])} "
              f"p99={_fmt_ms(p['p99_ms'])} mean={_fmt_ms(p['mean_ms'])}")
    for bucket, counts in sorted(rep["by_bucket"].items()):
        cs = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        print(f"bucket {bucket}: {cs}")
    for v in rep["violations"]:
        print(f"[VIOLATION] {v}", file=sys.stderr)


def _run_stages(spec: str, *, batch: int, iters: int,
                as_json: bool) -> int:
    import repro

    n, t, v = (int(x) for x in spec.split(":"))
    pl = repro.plan(n=n, t=t, v=v)
    rec = obs.stage_timings(pl, batch=batch, iters=iters)
    if as_json:
        print(json.dumps(rec, indent=1))
        return 0
    print(f"stage timings n={n} t={t} v={v} backend={rec['backend']} "
          f"batch={rec['batch']}")
    print(f"{'stage':<12}{'measured':>12}{'share':>8}{'model':>8}"
          f"{'drift':>8}")
    for stage in obs.STAGES:
        s = rec["stages"][stage]
        print(f"{stage:<12}{s['seconds'] * 1e6:>10.1f}us"
              f"{s['share_measured']:>8.1%}{s['share_predicted']:>8.1%}"
              f"{s['drift']:>8.1%}")
    print(f"sum-of-stages {rec['stage_sum_s'] * 1e6:.1f}us, "
          f"e2e {rec['e2e_s'] * 1e6:.1f}us "
          f"(fusion speedup {rec['fusion_speedup']:.2f}x)")
    tc = rec["transform_cost_model"]
    print(f"transform_cost_model: {tc}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("span_log", nargs="?", default=None,
                    help="JSONL span log (repro.obs.SpanLog output)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on span-conservation violations "
                         "(the obs-smoke CI gate)")
    ap.add_argument("--prom", default=None, metavar="FILE",
                    help="validate a Prometheus text-format exposition")
    ap.add_argument("--stages", default=None, metavar="N:T:V",
                    help="run the compiled per-stage profiling harness "
                         "for one plan preset")
    ap.add_argument("--batch", type=int, default=4,
                    help="profiling batch rows (--stages)")
    ap.add_argument("--iters", type=int, default=10,
                    help="profiling timing iterations (--stages)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)

    if args.span_log is None and args.prom is None and args.stages is None:
        ap.error("nothing to do: pass a span log, --prom, or --stages")

    rc = 0
    if args.prom is not None:
        with open(args.prom) as f:
            text = f.read()
        try:
            families = obs.parse_prometheus(text)
        except ValueError as e:
            print(f"[FAIL] {args.prom}: {e}", file=sys.stderr)
            return 1
        print(f"{args.prom}: valid Prometheus text format "
              f"({len(families)} families, "
              f"{sum(len(f['samples']) for f in families.values())} "
              f"samples)")

    if args.span_log is not None:
        records = obs.read_jsonl(args.span_log)
        rep = lifecycle_report(records)
        if args.json:
            print(json.dumps(rep, indent=1))
        else:
            _print_report(rep)
        if args.check and rep["violations"]:
            rc = 1

    if args.stages is not None:
        rc = max(rc, _run_stages(args.stages, batch=args.batch,
                                 iters=args.iters, as_json=args.json))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
