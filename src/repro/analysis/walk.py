"""Generic recursive jaxpr traversal, shared by the verifier passes and
the structural counters in :mod:`repro.kernels.ops`.

Dependency-free within the repo (jax-version tolerant, attribute-
probing) so both the kernels layer and the analysis passes can import
it without cycles.
"""
from __future__ import annotations

from typing import Any, Iterator, Optional, Tuple


def subjaxprs(eqn: Any) -> Iterator[Tuple[str, Any]]:
    """Yield ``(label, jaxpr-like)`` for every sub-jaxpr of one eqn:
    pjit/closed-call bodies, cond branches, pallas kernel bodies.  The
    yielded object may be a ClosedJaxpr or a raw Jaxpr."""
    params = getattr(eqn, "params", None) or {}
    for key in ("jaxpr", "call_jaxpr"):
        inner = params.get(key)
        if inner is not None and hasattr(getattr(inner, "jaxpr", inner), "eqns"):
            yield key, inner
            break
    for i, br in enumerate(params.get("branches", ()) or ()):
        if hasattr(getattr(br, "jaxpr", br), "eqns"):
            yield f"branch{i}", br


def raw(jaxpr_like: Any) -> Any:
    """Unwrap a ClosedJaxpr to its raw Jaxpr (identity for raw Jaxprs)."""
    return getattr(jaxpr_like, "jaxpr", jaxpr_like)


def iter_eqns(
    jaxpr_like: Any, path: Tuple[str, ...] = ()
) -> Iterator[Tuple[Tuple[str, ...], Any]]:
    """Depth-first ``(path, eqn)`` over a jaxpr and every nested body."""
    for eqn in raw(jaxpr_like).eqns:
        yield path, eqn
        for label, inner in subjaxprs(eqn):
            name = getattr(eqn.primitive, "name", "?")
            yield from iter_eqns(inner, path + (f"{name}:{label}",))


def iter_consts(
    jaxpr_like: Any, path: Tuple[str, ...] = ()
) -> Iterator[Tuple[Tuple[str, ...], Any]]:
    """Depth-first ``(path, const)`` over the closure constants of a
    ClosedJaxpr and of every nested ClosedJaxpr (pjit bodies, cond
    branches); pallas bodies are raw Jaxprs and carry no consts."""
    for const in getattr(jaxpr_like, "consts", ()) or ():
        yield path, const
    for eqn in raw(jaxpr_like).eqns:
        for label, inner in subjaxprs(eqn):
            name = getattr(eqn.primitive, "name", "?")
            yield from iter_consts(inner, path + (f"{name}:{label}",))


def iter_pallas_calls(
    jaxpr_like: Any, path: Tuple[str, ...] = ()
) -> Iterator[Tuple[Tuple[str, ...], Any]]:
    """Depth-first ``(path, eqn)`` over every ``pallas_call`` eqn."""
    for p, eqn in iter_eqns(jaxpr_like, path):
        if getattr(eqn.primitive, "name", "") == "pallas_call":
            yield p, eqn


def count_prim(jaxpr_like: Any, name: str, *, inside_pallas_only: bool = False) -> int:
    """Count primitive occurrences, optionally only under pallas bodies."""
    total = 0
    for path, eqn in iter_eqns(jaxpr_like):
        if getattr(eqn.primitive, "name", "") != name:
            continue
        if inside_pallas_only and not any(p.startswith("pallas_call:") for p in path):
            continue
        total += 1
    return total
