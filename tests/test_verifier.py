"""Kernel-verifier tests (DESIGN.md §9).

Four layers:

* abstract-domain unit tests — interval/q-linear transfer functions
  against brute-force concrete enumeration;
* preset sweep — every registered preset must verify clean (the same
  sweep the blocking ``verify-kernels`` CI job runs);
* adversarial — the mutation self-check plus planted overflow and
  staticness violations, proving the analyzer is not vacuous;
* soundness property (hypothesis) — every integer intermediate of a
  concretely evaluated trace lands inside the interval the abstract
  interpreter predicted for it.
"""
from fractions import Fraction

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

import repro
from repro.analysis import domain as D
from repro.analysis import passes, verify, walk
from repro.analysis.domain import AbsVal, QCtx
from repro.analysis.interp import analyze_closed_jaxpr


QCTX = QCtx(q_min=535756801, q_max=1071643649)  # spans v29..v30 moduli


def _plan(name):
    preset = next(p for p in verify.PRESETS if p.name == name)
    return preset.build_plan()


# --------------------------------------------------------------------------
# abstract domain
# --------------------------------------------------------------------------


class TestDomain:
    def test_interval_transfer_vs_concrete(self):
        """add/sub/mul bounds contain every concrete combination."""
        samples = [(-3, 2), (0, 5), (4, 4), (-7, -1)]
        for alo, ahi in samples:
            for blo, bhi in samples:
                a, b = D.from_ints(alo, ahi), D.from_ints(blo, bhi)
                xs = range(alo, ahi + 1)
                ys = range(blo, bhi + 1)
                for op, ref in (
                    (D.add, lambda x, y: x + y),
                    (D.sub, lambda x, y: x - y),
                    (D.mul, lambda x, y: x * y),
                ):
                    out = op(a, b, QCTX)
                    vals = [ref(x, y) for x in xs for y in ys]
                    assert out.lo <= min(vals) and max(vals) <= out.hi

    def test_units_of_q_canonical_and_window(self):
        one_q = AbsVal(0, QCTX.q_max - 1).with_qlin(
            Fraction(1), Fraction(-1), QCTX
        )
        assert D.units_of_q(one_q, QCTX) == 1
        two_q = D.add(one_q, one_q, QCTX)
        assert D.units_of_q(two_q, QCTX) == 2

    def test_join_keeps_dominating_qlin(self):
        """pad/select joins must not lose 'x < q' when the other branch's
        constant bound already sits below q at the worst channel."""
        canon = AbsVal(0, QCTX.q_max - 1).with_qlin(
            Fraction(1), Fraction(-1), QCTX
        )
        zero = D.const(0)
        out = D.join(canon, zero, QCTX)
        assert out.qa == Fraction(1) and out.qb == Fraction(-1)
        # ... but a constant ABOVE qa*q_min+qb kills the q-linear form
        big = D.const(QCTX.q_min + 7)
        out2 = D.join(canon, big, QCTX)
        assert out2.qa is None

    def test_mul_scales_qlin_only_by_small_singletons(self):
        canon = AbsVal(0, QCTX.q_max - 1).with_qlin(
            Fraction(1), Fraction(-1), QCTX
        )
        doubled = D.mul(canon, D.const(2), QCTX)
        assert doubled.qa == Fraction(2)
        # a data-sized factor must NOT manufacture a q-linear form
        wide = D.mul(canon, D.from_ints(0, 1 << 20), QCTX)
        assert wide.qa is None

    def test_shift_left_scales_both_qlinear_forms(self):
        av = AbsVal(1, QCTX.q_max - 1).with_qlin(
            Fraction(1), Fraction(-1), QCTX
        ).with_qlo(Fraction(0), Fraction(1), QCTX)
        out = D.shift_left(av, D.const(2), QCTX)
        assert out.qa == Fraction(4) and out.la == Fraction(0)
        assert out.lb == Fraction(4)


# --------------------------------------------------------------------------
# preset sweep (what the verify-kernels CI job runs)
# --------------------------------------------------------------------------


class TestPresetSweep:
    @pytest.mark.parametrize(
        "preset", verify.PRESETS, ids=[p.name for p in verify.PRESETS]
    )
    def test_preset_verifies_clean(self, preset):
        report = repro.verify_plan(preset.build_plan())
        assert report.ok, [f.as_dict() for f in report.errors()]

    def test_pallas_envelope_matches_hand_bookkeeping(self):
        report = repro.verify_plan(_plan("n64_t3_v30_pallas_radix2"))
        assert report.ok
        assert set(report.envelopes) == {"ntt", "intt", "polymul"}
        for env in report.envelopes.values():
            assert env["events"] > 0
            for direction, d in env["derived"].items():
                hand = env["hand"][direction]
                assert d["value"] <= hand["value"]
                assert d["peak"] <= hand["peak"]

    def test_report_round_trips_json(self):
        import json

        report = repro.verify_plan(_plan("n64_t3_v30_jnp_radix2"))
        blob = json.loads(report.to_json())
        assert blob["ok"] is True
        assert blob["plan"]["n"] == 64


# --------------------------------------------------------------------------
# adversarial: the analyzer must catch planted violations
# --------------------------------------------------------------------------


class TestAdversarial:
    def test_mutation_selfcheck(self):
        result = verify.mutation_selfcheck()
        assert result["passed"], result

    def test_planted_overflow_is_flagged(self):
        """Cubing a canonical v30 residue exceeds 63 bits — the abstract
        walk must prove the overflow, not assume int64 wraps away."""
        pl = _plan("n64_t3_v30_jnp_radix2")
        closed = jax.make_jaxpr(lambda x: x * x * x)(
            jnp.zeros((3, 64), jnp.int64)
        )
        ctx = verify._fresh_ctx(passes.build_context(pl), 64)
        analyze_closed_jaxpr(
            closed, [verify._canonical_seed(ctx.qctx)], ctx, where="cube"
        )
        assert any(f.code == "overflow" for f in ctx.findings)

    def test_planted_staticness_violation_is_flagged(self):
        """A baked COPY of a plan leaf (vs the threaded leaf itself) must
        trip the staticness lint — that is the PR 5 invariant."""
        pl = _plan("n64_t3_v30_jnp_radix2")
        baked = np.array(pl.params.tables.fwd)  # copy, not the leaf

        def leaky(x):
            return x + jnp.asarray(baked)[:, :64]

        closed = jax.make_jaxpr(leaky)(jnp.zeros((3, 64), jnp.int64))
        ctx = verify._fresh_ctx(passes.build_context(pl), 64)
        flagged = passes.staticness_lint(closed, ctx, "leaky")
        assert flagged and flagged[0]["copy_of"] is not None
        assert any(f.code == "staticness" for f in ctx.findings)

    def test_unknown_primitive_fails_closed(self):
        pl = _plan("n64_t3_v30_jnp_radix2")
        closed = jax.make_jaxpr(lambda x: jnp.sin(x.astype(jnp.float32)))(
            jnp.zeros((8,), jnp.int64)
        )
        ctx = verify._fresh_ctx(passes.build_context(pl), 64)
        outs = analyze_closed_jaxpr(
            closed, [verify._canonical_seed(ctx.qctx)], ctx, where="f32"
        )
        # float outputs are outside the domain: result is unconstrained,
        # never a silently-trusted bound
        assert all(
            not isinstance(o, AbsVal) or o.lo is None or o.hi is None
            for o in outs
        ) or not ctx.ok


# --------------------------------------------------------------------------
# structural walk helpers
# --------------------------------------------------------------------------


class TestWalk:
    def test_count_prim_matches_dispatch_claim(self):
        pl = _plan("n64_t3_v30_pallas_radix2")
        a = jnp.zeros((3, 2, 64), jnp.int64)
        closed = jax.make_jaxpr(lambda x: repro.ntt(pl, x))(a)
        assert walk.count_prim(closed, "pallas_call") == 1
        inside = walk.count_prim(closed, "select_n", inside_pallas_only=True)
        total = walk.count_prim(closed, "select_n")
        assert 0 < inside <= total


# --------------------------------------------------------------------------
# soundness property: concrete execution never escapes predicted bounds
# --------------------------------------------------------------------------


def _eval_checking_bounds(closed, concrete, bounds, where):
    """Evaluate the jaxpr eqn-by-eqn; assert every top-level integer
    intermediate lands inside the analyzer's predicted interval."""
    env = {}

    def read(atom):
        return atom.val if hasattr(atom, "val") else env[atom]

    for var, val in zip(closed.jaxpr.constvars, closed.consts):
        env[var] = val
    for var, val in zip(closed.jaxpr.invars, concrete):
        env[var] = val
    for idx, eqn in enumerate(closed.jaxpr.eqns):
        ins = [read(x) for x in eqn.invars]
        subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
        outs = eqn.primitive.bind(*subfuns, *ins, **bind_params)
        if not eqn.primitive.multiple_results:
            outs = [outs]
        for oi, (var, val) in enumerate(zip(eqn.outvars, outs)):
            env[var] = val
            lo, hi = bounds.get((where, idx, oi), (None, None))
            arr = np.asarray(val)
            if not np.issubdtype(arr.dtype, np.integer) or arr.size == 0:
                continue
            if lo is not None:
                assert int(arr.min()) >= lo, (idx, eqn.primitive.name)
            if hi is not None:
                assert int(arr.max()) <= hi, (idx, eqn.primitive.name)
    return [read(v) for v in closed.jaxpr.outvars]


def test_soundness_deterministic_smoke():
    """Non-hypothesis twin of the property below: one fixed draw, so the
    soundness machinery is exercised even where hypothesis is absent."""
    pl = _plan("n64_t3_v30_jnp_radix2")
    cfg = pl.config
    closed = jax.make_jaxpr(lambda a: repro.intt(pl, a))(
        jnp.zeros((cfg.t, cfg.n), jnp.int64)
    )
    ctx = verify._fresh_ctx(passes.build_context(pl), 64)
    ctx.bounds_out = {}
    analyze_closed_jaxpr(
        closed, [verify._canonical_seed(ctx.qctx)], ctx, where="intt"
    )
    assert ctx.ok and ctx.bounds_out
    rng = np.random.RandomState(20260809)
    qs = np.asarray(pl.params.plan.qs, dtype=np.int64)
    a = np.stack(
        [rng.randint(0, int(q), size=cfg.n).astype(np.int64) for q in qs]
    )
    _eval_checking_bounds(closed, [jnp.asarray(a)], ctx.bounds_out, "intt")


class TestSoundnessProperty:
    @pytest.mark.parametrize(
        "preset_name",
        ["n64_t3_v30_jnp_radix2", "n64_t3_v29_jnp_radix2"],
    )
    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_concrete_intt_within_predicted_intervals(self, preset_name, data):
        pl = _plan(preset_name)
        cfg = pl.config
        closed = jax.make_jaxpr(lambda a: repro.intt(pl, a))(
            jnp.zeros((cfg.t, cfg.n), jnp.int64)
        )
        ctx = verify._fresh_ctx(passes.build_context(pl), 64)
        ctx.bounds_out = {}
        analyze_closed_jaxpr(
            closed, [verify._canonical_seed(ctx.qctx)], ctx, where="intt"
        )
        assert ctx.ok, [f.as_dict() for f in ctx.findings]
        seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.RandomState(seed)
        qs = np.asarray(pl.params.plan.qs, dtype=np.int64)
        a = np.stack(
            [rng.randint(0, int(q), size=cfg.n).astype(np.int64) for q in qs]
        )
        outs = _eval_checking_bounds(
            closed, [jnp.asarray(a)], ctx.bounds_out, "intt"
        )
        out = np.asarray(outs[0])
        assert (out >= 0).all() and (out < qs[:, None]).all()

    @settings(max_examples=5, deadline=None)
    @given(data=st.data())
    def test_concrete_polymul_within_predicted_intervals(self, data):
        pl = _plan("n64_t3_v30_jnp_radix2")
        cfg = pl.config
        S = cfg.seg_count
        closed = jax.make_jaxpr(lambda za, zb: repro.polymul(pl, za, zb))(
            jnp.zeros((cfg.n, S), jnp.int64), jnp.zeros((cfg.n, S), jnp.int64)
        )
        ctx = verify._fresh_ctx(passes.build_context(pl), 64)
        ctx.bounds_out = {}
        seeds = [
            verify._seed_for("polymul", i, pl, ctx.qctx) for i in range(2)
        ]
        analyze_closed_jaxpr(closed, seeds, ctx, where="polymul")
        assert ctx.ok, [f.as_dict() for f in ctx.findings]
        seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.RandomState(seed)
        za, zb = (
            jnp.asarray(
                rng.randint(0, 1 << cfg.v, size=(cfg.n, S)).astype(np.int64)
            )
            for _ in range(2)
        )
        _eval_checking_bounds(closed, [za, zb], ctx.bounds_out, "polymul")


# --------------------------------------------------------------------------
# CLI front doors
# --------------------------------------------------------------------------


class TestCLIs:
    def test_verify_kernels_cli_single_preset(self, tmp_path, capsys):
        from repro.launch import verify_kernels

        out = tmp_path / "report.json"
        rc = verify_kernels.main(
            ["--preset", "n64_t3_v30_jnp_radix2", "--out", str(out)]
        )
        assert rc == 0
        import json

        blob = json.loads(out.read_text())
        assert blob["ok"] and blob["presets"][0]["ok"]

    def test_dead_modules_cli(self, tmp_path):
        from repro.launch import dead_modules

        out = tmp_path / "dead.json"
        rc = dead_modules.main(["--out", str(out)])
        assert rc == 0  # non-blocking by design
        import json

        blob = json.loads(out.read_text())
        assert blob["reachable_count"] > 0
        # the verifier stack itself must be reachable from the surface
        assert "repro.analysis.verify" not in blob["dead_modules"]

    def test_mutated_shoup_plan_fails_verification(self):
        pl = _plan("n64_t3_v30_pallas_radix2")
        bad = verify._mutated_shoup_plan(pl)
        report = repro.verify_plan(bad)
        assert not report.ok
        assert "table-integrity" in report.codes()


def test_verify_plan_is_exported():
    assert hasattr(repro, "verify_plan")
    assert "verify_plan" in repro.__all__
