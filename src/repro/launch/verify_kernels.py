"""CLI front door for the kernel verifier (DESIGN.md §9).

Sweeps registered presets through :func:`repro.analysis.verify.verify_plan`
(abstract-interpretation overflow / envelope / canonicalize proof + lane
and staticness lints over the traced kernel jaxprs) and optionally runs
the mutation self-check (corrupt a Shoup constant / widen the lazy window
in-memory and assert the verifier flags it).  Exit status is nonzero on
any verification failure, so the ``verify-kernels`` CI job is blocking.

Usage::

    python -m repro.launch.verify_kernels --all-presets --mutation-check \
        --out VERIFY_report.json
    python -m repro.launch.verify_kernels --preset n64_t3_v30_pallas_radix2
    python -m repro.launch.verify_kernels --list
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List, Optional


def _run_preset(preset: Any) -> Dict[str, Any]:
    from repro.analysis.verify import verify_plan

    t0 = time.time()
    try:
        report = verify_plan(preset.build_plan())
        entry = report.as_dict()
        entry["ok"] = report.ok
    except Exception as exc:  # surface crashes as failures, not green runs
        entry = {"ok": False, "crash": f"{type(exc).__name__}: {exc}"}
    entry["preset"] = preset.name
    entry["seconds"] = round(time.time() - t0, 2)
    return entry


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.verify_kernels",
        description="Static verification sweep over registered kernel presets",
    )
    ap.add_argument(
        "--all-presets", action="store_true", help="verify every registered preset"
    )
    ap.add_argument(
        "--preset", action="append", default=[],
        help="verify one preset by name (repeatable)",
    )
    ap.add_argument(
        "--mutation-check", action="store_true",
        help="run the corrupted-table self-check",
    )
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--list", action="store_true", help="list registered presets and exit")
    args = ap.parse_args(argv)

    from repro.analysis.verify import PRESETS, mutation_selfcheck

    by_name = {p.name: p for p in PRESETS}
    if args.list:
        for p in PRESETS:
            print(f"{p.name}  n={p.n} t={p.t} v={p.v} backend={p.backend} schedule={p.schedule}")
        return 0

    selected = list(PRESETS) if args.all_presets or not args.preset else []
    for name in args.preset:
        if name not in by_name:
            ap.error(f"unknown preset {name!r}; --list shows the registry")
        if by_name[name] not in selected:
            selected.append(by_name[name])

    report: Dict[str, Any] = {"presets": [], "ok": True}
    for preset in selected:
        entry = _run_preset(preset)
        report["presets"].append(entry)
        status = "ok" if entry["ok"] else "FAIL"
        print(f"[verify-kernels] {preset.name:<28} {status}  ({entry['seconds']}s)")
        if not entry["ok"]:
            report["ok"] = False
            for f in entry.get("findings", [])[:6]:
                print(
                    f"    {f.get('severity')}/{f.get('code')} @ "
                    f"{f.get('where')}: {f.get('message')}"
                )
            if "crash" in entry:
                print(f"    crash: {entry['crash']}")

    if args.mutation_check:
        mc = mutation_selfcheck()
        report["mutation_selfcheck"] = mc
        status = "ok" if mc["passed"] else "FAIL"
        print(f"[verify-kernels] mutation-selfcheck           {status}")
        if not mc["passed"]:
            report["ok"] = False
            print(f"    {json.dumps(mc, default=str)}")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=1, default=str)
        print(f"[verify-kernels] report -> {args.out}")

    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
