"""The persistent tuning table: versioned JSON, schema-validated, written
atomically, consulted by ``repro.plan(..., tuning=...)``.

Layout (``TABLE_SCHEMA`` = ``repro.tune.table/v1``)::

    {
      "version": 1,
      "schema": "repro.tune.table/v1",
      "device_kinds": {
        "cpu": {
          "n256_t6_v30_b2": {
            "workload": {"n": 256, "t": 6, "v": 30, "batch": 2},
            "winner":   {"backend": "jnp", "schedule": "four_step",
                         "row_blk": null, "channel_grid": null},
            "winner_us": 123.4,          # measured, per poly
            "default_us": 150.0,         # the static-default candidate
            "mode": "compiled",          # "compiled" | "eager"
            "measured_at": 1754740000.0, # unix seconds
            "candidates_measured": 8,
            "candidates_pruned": 4,
            "rank_correlation": 0.9      # HLO cost model vs stopwatch
          }, ...
        }
      }
    }

Keying is **device kind + workload key**: a table seeded on a CPU dev box
never silently steers a TPU run — ``lookup`` only returns entries for
the current (or requested) device kind.  ``winner`` holds exactly the
four tunable plan knobs (``TUNABLE_KNOBS``); ``backend``/``schedule``
are recorded RESOLVED (never ``"auto"``), so replaying them through
``plan()`` reproduces the measured :class:`repro.api.PlanConfig`
bit-for-bit on any box of the same device kind.

Writes go through ``tmp + os.replace`` (atomic on POSIX): a crashed
sweep can never leave a half-written table for ``plan()`` to trip over.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
import time
from pathlib import Path
from typing import Any

TABLE_VERSION = 1
TABLE_SCHEMA = "repro.tune.table/v1"

# The plan() knobs a table entry may set — nothing else (the workload key
# pins n/t/v; use_sau &c. stay caller-owned).  plan_key of a tuned plan
# differs from the untuned plan in at most these fields (+ the resolved
# `schedule` spec they imply) — asserted by `autotune check` in CI.
TUNABLE_KNOBS = ("backend", "schedule", "row_blk", "channel_grid")

# The committed dev-box seed (see launch/autotune.py --seed-default).
DEFAULT_TABLE_PATH = Path(__file__).resolve().parent / "TUNING_default.json"

_KEY_RE = re.compile(r"^n(\d+)_t(\d+)_v(\d+)_b(\d+)$")


class TuningTableError(ValueError):
    """A tuning table failed to load or validate (missing file for an
    explicit path, malformed JSON, wrong schema/version, bad entry)."""


def device_kind() -> str:
    """The platform bucket a measurement belongs to ("cpu" | "gpu" |
    "tpu") — ``jax.default_backend()`` of the measuring process."""
    import jax

    return str(jax.default_backend())


def workload_key(n: int, t: int, v: int, batch: int) -> str:
    return f"n{n}_t{t}_v{v}_b{batch}"


def parse_workload_key(key: str) -> dict[str, int]:
    m = _KEY_RE.match(key)
    if not m:
        raise TuningTableError(
            f"bad workload key {key!r} (want 'n<n>_t<t>_v<v>_b<batch>', "
            f"e.g. 'n256_t6_v30_b2')"
        )
    n, t, v, b = map(int, m.groups())
    return {"n": n, "t": t, "v": v, "batch": b}


def _validate_winner(key: str, winner: Any) -> None:
    if not isinstance(winner, dict):
        raise TuningTableError(f"entry {key!r}: winner must be a dict")
    unknown = set(winner) - set(TUNABLE_KNOBS)
    if unknown:
        raise TuningTableError(
            f"entry {key!r}: winner sets non-tunable knobs {sorted(unknown)} "
            f"(tunable: {TUNABLE_KNOBS})"
        )
    be = winner.get("backend")
    if be is not None and (not isinstance(be, str) or be == "auto"):
        raise TuningTableError(
            f"entry {key!r}: winner backend must be a resolved backend "
            f"string, got {be!r}"
        )
    sc = winner.get("schedule")
    if sc is not None and (not isinstance(sc, str) or sc == "auto"):
        raise TuningTableError(
            f"entry {key!r}: winner schedule must be a resolved schedule "
            f"string, got {sc!r}"
        )
    rb = winner.get("row_blk")
    if rb is not None and (not isinstance(rb, int) or isinstance(rb, bool) or rb < 1):
        raise TuningTableError(
            f"entry {key!r}: winner row_blk must be a positive int or "
            f"null, got {rb!r}"
        )
    cg = winner.get("channel_grid")
    if cg is not None and not isinstance(cg, bool):
        raise TuningTableError(
            f"entry {key!r}: winner channel_grid must be true/false/null, "
            f"got {cg!r}"
        )


@dataclasses.dataclass
class TuningTable:
    """In-memory view of one tuning table file.

    ``entries`` maps device kind -> workload key -> entry dict (the JSON
    layout's ``device_kinds`` subtree, validated).
    """

    entries: dict[str, dict[str, dict[str, Any]]] = dataclasses.field(
        default_factory=dict
    )
    path: str | None = None  # where this table was loaded from, if anywhere

    # ------------------------------------------------------------ load/save
    @classmethod
    def from_dict(cls, doc: Any, *, path: str | None = None) -> "TuningTable":
        if not isinstance(doc, dict):
            raise TuningTableError(f"tuning table must be a JSON object, got {type(doc).__name__}")
        if doc.get("schema") != TABLE_SCHEMA:
            raise TuningTableError(
                f"unknown tuning-table schema {doc.get('schema')!r} "
                f"(this build reads {TABLE_SCHEMA!r})"
            )
        if doc.get("version") != TABLE_VERSION:
            raise TuningTableError(
                f"unknown tuning-table version {doc.get('version')!r} "
                f"(this build reads {TABLE_VERSION})"
            )
        kinds = doc.get("device_kinds", {})
        if not isinstance(kinds, dict):
            raise TuningTableError("device_kinds must be an object")
        entries: dict[str, dict[str, dict[str, Any]]] = {}
        for kind, table in kinds.items():
            if not isinstance(table, dict):
                raise TuningTableError(f"device kind {kind!r}: must be an object")
            entries[kind] = {}
            for key, entry in table.items():
                wl = parse_workload_key(key)
                if not isinstance(entry, dict):
                    raise TuningTableError(f"entry {key!r}: must be an object")
                got = entry.get("workload")
                if got is not None and dict(got) != wl:
                    raise TuningTableError(
                        f"entry {key!r}: workload {got!r} disagrees with its key"
                    )
                _validate_winner(key, entry.get("winner", {}))
                entries[kind][key] = dict(entry)
        return cls(entries=entries, path=path)

    @classmethod
    def load(cls, path: str | os.PathLike[str]) -> "TuningTable":
        p = Path(path)
        if not p.exists():
            raise TuningTableError(f"no tuning table at {p}")
        try:
            doc = json.loads(p.read_text())
        except json.JSONDecodeError as e:
            raise TuningTableError(f"malformed tuning table {p}: {e}") from e
        return cls.from_dict(doc, path=str(p))

    def to_dict(self) -> dict[str, Any]:
        return {
            "version": TABLE_VERSION,
            "schema": TABLE_SCHEMA,
            "device_kinds": {
                kind: dict(sorted(table.items()))
                for kind, table in sorted(self.entries.items())
            },
        }

    def save(self, path: str | os.PathLike[str]) -> None:
        """Atomic write: serialize next to the target, fsync, then
        ``os.replace`` — readers only ever see a complete table."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(p.parent), prefix=p.name + ".", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=2, sort_keys=False)
                f.write("\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, p)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # ------------------------------------------------------------ access
    def put(
        self,
        *,
        n: int,
        t: int,
        v: int,
        batch: int,
        winner: dict[str, Any],
        kind: str | None = None,
        **extra: Any,
    ) -> dict[str, Any]:
        """Insert/overwrite one entry; returns the stored entry dict."""
        key = workload_key(n, t, v, batch)
        _validate_winner(key, winner)
        entry: dict[str, Any] = {
            "workload": {"n": n, "t": t, "v": v, "batch": batch},
            "winner": {k: winner.get(k) for k in TUNABLE_KNOBS},
            "measured_at": extra.pop("measured_at", time.time()),
        }
        entry.update(extra)
        self.entries.setdefault(kind or device_kind(), {})[key] = entry
        return entry

    def lookup(
        self,
        *,
        n: int,
        t: int,
        v: int,
        batch: int | None = None,
        kind: str | None = None,
    ) -> dict[str, Any] | None:
        """The winner-knob dict for a workload on this device kind, or
        ``None``.  ``batch=None`` (the plan-time call — plans are
        batch-agnostic) returns the smallest-batch entry for (n, t, v)."""
        table = self.entries.get(kind or device_kind())
        if not table:
            return None
        if batch is not None:
            entry = table.get(workload_key(n, t, v, batch))
            return dict(entry["winner"]) if entry else None
        best_b: int | None = None
        best: dict[str, Any] | None = None
        for key, entry in table.items():
            wl = entry.get("workload") or parse_workload_key(key)
            if (wl["n"], wl["t"], wl["v"]) != (n, t, v):
                continue
            if best_b is None or wl["batch"] < best_b:
                best_b, best = wl["batch"], entry
        return dict(best["winner"]) if best else None

    def entry(
        self, *, n: int, t: int, v: int, batch: int, kind: str | None = None
    ) -> dict[str, Any] | None:
        table = self.entries.get(kind or device_kind(), {})
        e = table.get(workload_key(n, t, v, batch))
        return dict(e) if e else None

    def prune_stale(
        self, *, max_age_s: float, now: float | None = None
    ) -> list[tuple[str, str]]:
        """Drop entries older than ``max_age_s`` (by ``measured_at``);
        entries with no timestamp count as stale.  Returns the removed
        ``(device_kind, workload_key)`` pairs."""
        cutoff = (time.time() if now is None else now) - max_age_s
        removed: list[tuple[str, str]] = []
        for kind, table in list(self.entries.items()):
            for key, entry in list(table.items()):
                at = entry.get("measured_at")
                if not isinstance(at, (int, float)) or at < cutoff:
                    removed.append((kind, key))
                    del table[key]
            if not table:
                del self.entries[kind]
        return removed


# --------------------------------------------------------------------------
# plan()-side loaders (cached so planning in a loop re-reads nothing)
# --------------------------------------------------------------------------

_CACHE: dict[tuple[str, float], TuningTable] = {}


def load_cached(path: str) -> TuningTable:
    """Load a table with an mtime-keyed cache: ``plan()`` calls in a hot
    loop hit the parsed table; an updated file is picked up on the next
    call.  Raises :class:`TuningTableError` if missing or invalid."""
    p = Path(path)
    if not p.exists():
        raise TuningTableError(f"no tuning table at {p}")
    key = (str(p.resolve()), p.stat().st_mtime)
    tab = _CACHE.get(key)
    if tab is None:
        tab = TuningTable.load(p)
        _CACHE.clear()  # one live parse per path generation is plenty
        _CACHE[key] = tab
    return tab


def load_default() -> TuningTable | None:
    """The committed dev-box seed table, or ``None`` when absent —
    ``tuning="auto"`` degrades to the static defaults silently."""
    if not DEFAULT_TABLE_PATH.exists():
        return None
    return load_cached(str(DEFAULT_TABLE_PATH))
