"""Serving throughput: the batched PolymulEngine vs the unbatched
per-request loop.

The paper positions the feed-forward PaReNTT datapath for "low latency
and high sample rate"; this benchmark measures the sample-rate half on
the serving layer: R requests stream through (a) a sequential loop of
jitted single-request ``repro.polymul`` calls and (b) the
shape-bucketed batching engine at a fixed slot count.  Reported:
requests/s for both, the batched/loop speedup, and the engine's
p50/p99 submit-to-result latency plus padding/dispatch accounting.
``--deadline-ms`` and ``--fault-rate`` turn the same driver into a
degraded-mode benchmark: goodput (requests resolved with a value —
deadline met, retries survived) is reported alongside raw req/s, with
the shed/retry/failure counters that explain the gap.

``--ci-smoke`` is the ``serve-smoke`` CI gate: it runs the small
preset at batch 8, verifies the engine's mixed-preset stream bit-exact
against the eager plan executor, MERGES a ``"serve"`` record into the
BENCH_ci.json artifact written by ``benchmarks/polymul_e2e.py``, and
exits non-zero if batched throughput falls below the unbatched loop
(the existence proof of the batching win — off-TPU both sides run the
same jnp datapath, so dispatch amortization is all that is measured).
"""
import argparse
import json
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro
from repro import api
from repro.serve.crypto_engine import PolymulEngine


def _requests(pl, count: int, rng) -> list:
    shape = (pl.n, pl.config.seg_count)
    return [
        (
            rng.integers(0, 1 << pl.v, size=shape),
            rng.integers(0, 1 << pl.v, size=shape),
        )
        for _ in range(count)
    ]


def _time_loop(pl, reqs, repeats: int) -> float:
    """Best-of-N wall seconds for the sequential per-request loop
    through the shared jitted executor (the unbatched baseline)."""
    za0, zb0 = jnp.asarray(reqs[0][0]), jnp.asarray(reqs[0][1])
    jax.block_until_ready(api.execute(pl, za0, zb0))  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for za, zb in reqs:
            jax.block_until_ready(
                api.execute(pl, jnp.asarray(za), jnp.asarray(zb))
            )
        best = min(best, time.perf_counter() - t0)
    return best


def _time_engine(pl, reqs, batch: int, repeats: int, *,
                 deadline_s=None, fault_rate: float = 0.0, seed: int = 7,
                 span_log=None):
    """(best wall seconds, latency ms array over successful futures,
    stats, traces, goodput count) for the batching engine serving the
    same request list — optionally under per-request deadlines and a
    Bernoulli dispatch-fault rate (the engine's retry/shed machinery
    then shows up in the stats and the goodput gap).  ``span_log``
    turns on request tracing (the overhead under test in
    :func:`tracing_overhead`)."""
    eng = PolymulEngine(batch_slots=batch, backoff_base_s=1e-4,
                        span_log=span_log)
    shape = (pl.n, pl.config.seg_count)
    eng.submit(pl, np.zeros(shape, np.int64), np.zeros(shape, np.int64))
    eng.run_until_idle()  # compile the padded-batch executable
    if fault_rate > 0.0:
        from repro.serve.faults import FaultInjector, FaultRule

        FaultInjector(
            [FaultRule("raise", rate=fault_rate)], seed=seed
        ).install(eng)
    best, lat, stats, good = float("inf"), None, {}, 0
    for _ in range(repeats):
        eng.reset_stats()
        t0 = time.perf_counter()
        futs = [
            eng.submit(pl, za, zb, deadline=deadline_s)
            for za, zb in reqs
        ]
        eng.run_until_idle()
        wall = time.perf_counter() - t0
        ok = [f for f in futs if f.exception() is None]
        if wall < best:
            best = wall
            lat = np.array([f.latency_s for f in ok]) * 1e3
            stats = dict(eng.stats)
            good = len(ok)
    return best, lat, stats, eng.trace_count, good


def bench(n: int, t: int, v: int, *, batch: int, requests: int,
          repeats: int, seed: int = 7, deadline_ms: float = 0.0,
          fault_rate: float = 0.0) -> dict:
    rng = np.random.default_rng(seed)
    pl = repro.plan(n=n, t=t, v=v)
    reqs = _requests(pl, requests, rng)
    loop_s = _time_loop(pl, reqs, repeats)
    eng_s, lat, stats, traces, good = _time_engine(
        pl, reqs, batch, repeats, seed=seed,
        deadline_s=deadline_ms / 1e3 if deadline_ms > 0 else None,
        fault_rate=fault_rate,
    )
    rec = {
        "preset": {"n": n, "t": t, "v": v},
        "batch_slots": batch,
        "requests": requests,
        "seed": seed,
        "loop_rps": requests / loop_s,
        "batched_rps": requests / eng_s,
        "batched_vs_loop_speedup": loop_s / eng_s,
        # goodput: requests that resolved with a value (deadline met,
        # retries survived) per second — equals batched_rps when no
        # deadline/fault knobs are set
        "goodput_rps": good / eng_s,
        "latency_p50_ms": (
            float(np.percentile(lat, 50)) if lat.size else float("nan")
        ),
        "latency_p99_ms": (
            float(np.percentile(lat, 99)) if lat.size else float("nan")
        ),
        "dispatches": stats["dispatches"],
        "padded_slots": stats["padded_slots"],
        "jit_traces": traces,
    }
    if deadline_ms > 0 or fault_rate > 0:
        rec["deadline_ms"] = deadline_ms
        rec["fault_rate"] = fault_rate
        rec["shed"] = stats["shed"]
        rec["failed"] = stats["failed"]
        rec["retried"] = stats["retried"]
        rec["dispatch_failures"] = stats["dispatch_failures"]
    return rec


def mixed_stream_check(requests: int = 12, seed: int = 3) -> dict:
    """Serve BOTH paper presets interleaved through one engine and
    verify every result bit-exact against the eager plan executor
    (itself oracle-gated by the tier-1 suite); also assert one jit
    trace per distinct config."""
    rng = np.random.default_rng(seed)
    eng = PolymulEngine(batch_slots=4)
    plans = [eng.plan(n=64, t=3, v=30), eng.plan(n=32, t=4, v=45)]
    reqs = []
    for i in range(requests):
        pl = plans[i % 2]
        za, zb = _requests(pl, 1, rng)[0]
        reqs.append((pl, za, zb))
    futs = [eng.submit(pl, za, zb) for pl, za, zb in reqs]
    eng.run_until_idle()
    exact = all(
        np.array_equal(
            f.result(),
            np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb))),
        )
        for f, (pl, za, zb) in zip(futs, reqs)
    )
    return {
        "requests": requests,
        "configs": len({api.plan_key(pl) for pl, _, _ in reqs}),
        "bit_exact": bool(exact),
        "jit_traces": eng.trace_count,
    }


def tracing_overhead(n: int, t: int, v: int, *, batch: int,
                     requests: int, repeats: int, seed: int = 7,
                     span_log_path: str | None = None,
                     max_overhead: float = 0.05) -> dict:
    """Closed-loop throughput with request tracing ON vs OFF through the
    same engine configuration — the ``obs-smoke`` CI gate that keeps the
    span log an always-on-able tool rather than a debug mode.

    Both sides are best-of-``repeats`` so scheduler noise has to be
    reproducibly in the tracing path to fail the gate.  ``overhead`` is
    ``1 - traced_rps / plain_rps`` (negative means tracing measured
    faster, i.e. pure noise); the gate fails when it exceeds
    ``max_overhead``.
    """
    from repro import obs

    rng = np.random.default_rng(seed)
    pl = repro.plan(n=n, t=t, v=v)
    reqs = _requests(pl, requests, rng)
    plain_s, _, _, _, _ = _time_engine(pl, reqs, batch, repeats, seed=seed)
    span_log = obs.SpanLog(span_log_path)
    traced_s, _, _, _, _ = _time_engine(pl, reqs, batch, repeats,
                                        seed=seed, span_log=span_log)
    span_log.flush()
    cons = obs.conservation(span_log.records)
    overhead = 1.0 - (plain_s / traced_s)
    failures = list(cons["violations"])
    if overhead > max_overhead:
        failures.append(
            f"tracing overhead {overhead:.1%} exceeds the "
            f"{max_overhead:.0%} budget: {requests / traced_s:.1f} "
            f"traced vs {requests / plain_s:.1f} plain req/s"
        )
    return {
        "preset": {"n": n, "t": t, "v": v},
        "batch_slots": batch,
        "requests": requests,
        "repeats": repeats,
        "seed": seed,
        "plain_rps": requests / plain_s,
        "traced_rps": requests / traced_s,
        "overhead": overhead,
        "max_overhead": max_overhead,
        "spans": cons["spans"],
        "span_violations": cons["violations"],
        "failures": failures,
    }


def run_ci_smoke(out_path: str, *, batch: int = 8, requests: int = 64,
                 repeats: int = 3) -> dict:
    rec = bench(64, 3, 30, batch=batch, requests=requests, repeats=repeats)
    rec["mixed_stream"] = mixed_stream_check()
    failures = []
    if rec["batched_vs_loop_speedup"] < 1.0:
        failures.append(
            f"batched engine is SLOWER than the unbatched loop at batch "
            f"{batch}: {rec['batched_rps']:.1f} vs {rec['loop_rps']:.1f} "
            f"req/s — the batching win regressed"
        )
    if not rec["mixed_stream"]["bit_exact"]:
        failures.append("mixed-preset stream is not bit-exact vs polymul")
    if rec["mixed_stream"]["jit_traces"] != rec["mixed_stream"]["configs"]:
        failures.append(
            f"mixed stream traced {rec['mixed_stream']['jit_traces']} "
            f"times for {rec['mixed_stream']['configs']} configs — the "
            f"plan-bucket cache regressed"
        )
    rec["failures"] = failures
    # merge into the bench-smoke artifact (polymul_e2e writes it first
    # in CI; standalone runs create a serve-only record)
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc["serve"] = rec
    doc["failures"] = doc.get("failures", []) + failures
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
    print(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci-smoke", action="store_true",
                    help="small-preset gate for the serve-smoke CI step")
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="JSON artifact to merge the 'serve' record into")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--t", type=int, default=6)
    ap.add_argument("--v", type=int, default=30)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; goodput counts only "
                         "deadline-met requests (0 = no deadline)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="Bernoulli transient-raise rate per dispatch "
                         "via the fault injector (0 = no faults)")
    ap.add_argument("--seed", type=int, default=7,
                    help="seeds request payloads and the fault schedule; "
                         "stamped into output records")
    ap.add_argument("--tracing-overhead", action="store_true",
                    help="measure tracing-on vs tracing-off throughput "
                         "and gate the overhead (the obs-smoke CI step); "
                         "merges a 'tracing_overhead' record into --out")
    ap.add_argument("--span-log", default=None, metavar="FILE",
                    help="JSONL span log path for --tracing-overhead")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="tracing overhead budget as a fraction "
                         "(--tracing-overhead)")
    args = ap.parse_args(argv)
    if args.tracing_overhead:
        rec = tracing_overhead(
            args.n, args.t, args.v, batch=args.batch,
            requests=args.requests, repeats=args.repeats, seed=args.seed,
            span_log_path=args.span_log, max_overhead=args.max_overhead,
        )
        print(json.dumps(rec, indent=1))
        doc = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                doc = json.load(f)
        doc["tracing_overhead"] = rec
        doc["failures"] = doc.get("failures", []) + rec["failures"]
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        for msg in rec["failures"]:
            print(f"[FAIL] {msg}", file=sys.stderr)
        return 1 if rec["failures"] else 0
    if args.ci_smoke:
        rec = run_ci_smoke(args.out, batch=args.batch,
                           requests=args.requests, repeats=args.repeats)
        for msg in rec["failures"]:
            print(f"[FAIL] {msg}", file=sys.stderr)
        return 1 if rec["failures"] else 0
    rec = bench(args.n, args.t, args.v, batch=args.batch,
                requests=args.requests, repeats=args.repeats, seed=args.seed,
                deadline_ms=args.deadline_ms, fault_rate=args.fault_rate)
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
