"""Batched serving engine: prefill + decode with fixed batch slots
(continuous-batching style admission), greedy or temperature sampling.

The decode step is the ``serve_step`` the dry-run lowers for the
``decode_*`` / ``long_*`` shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4, max_len: int = 256):
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_slots, max_len
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(p, cfg, c, b)
        )

    def generate(self, prompts: list[np.ndarray], max_new: int = 16, greedy=True):
        """Simple batched generation: pads prompts into the slot batch,
        prefills token-by-token (shared path with decode for correctness),
        then decodes max_new tokens."""
        assert len(prompts) <= self.B
        reqs = [Request(p, max_new) for p in prompts]
        while len(reqs) < self.B:
            reqs.append(Request(np.zeros(1, np.int32), 0, done=True))
        maxlen = max(len(r.prompt) for r in reqs)
        cache = M.init_cache(self.cfg, self.B, self.T)
        toks = np.zeros((self.B, maxlen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, maxlen - len(r.prompt) :] = r.prompt  # left-pad
        logits, cache = self._prefill(toks, cache)
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done:
                    r.out.append(int(last[i]))
            logits, cache = self._decode(
                self.params, cache, {"tokens": last[:, None]}
            )
            last = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return [r.out for r in reqs if not r.done]

    def _prefill(self, toks, cache):
        # chunked prefill through the decode path (exactness over speed on CPU)
        logits = None
        B, S = toks.shape
        logits, cache = self._decode(self.params, cache, {"tokens": jnp.asarray(toks)})
        return logits, cache


def decode_throughput_model(cfg: ModelConfig, batch: int, kv_len: int) -> dict:
    """Analytical bytes/token for the decode step (roofline helper)."""
    hk, dh = cfg.n_kv_heads, cfg.head_dim_
    kv_bytes = 2 * cfg.n_layers * batch * kv_len * hk * dh * 2  # bf16
    param_bytes = 0  # filled by caller with actual param count
    return {"kv_bytes_per_step": kv_bytes}
