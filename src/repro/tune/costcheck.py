"""HLO cost-model cross-check: does the static analyzer order candidates
the way the stopwatch does?

For every candidate that compiled, the sweep keeps the optimized HLO
dump; :func:`predicted_cost` runs :mod:`repro.launch.hlo_analyzer` over
it and folds flops / hbm_bytes / pallas ``custom-call`` boundary bytes
into one roofline-style scalar.  :func:`cross_check` then reports the
Spearman rank correlation between the model's ordering and the measured
ordering per workload key, and flags candidates whose normalized rank
disagrees badly — the "model says fast, stopwatch says slow" cases worth
a human look (on CPU today the usual flag is an interpret-mode Pallas
candidate whose inlined kernel body the byte model undercounts).

The nominal throughput numbers are deliberately round: only the ORDERING
feeds the cross-check, so absolute calibration cancels out.
"""
from __future__ import annotations

from typing import Any

from repro.launch import hlo_analyzer

# Nominal device throughputs (bytes/s, flop/s) per device kind — ordering
# fodder only (see module docstring), not measured claims.
NOMINAL = {
    "cpu": {"bytes_per_s": 2.0e10, "flops_per_s": 5.0e10},
    "gpu": {"bytes_per_s": 1.5e12, "flops_per_s": 5.0e13},
    "tpu": {"bytes_per_s": 1.2e12, "flops_per_s": 2.0e14},
}

# Normalized-rank disagreement beyond this flags the candidate.
FLAG_RANK_GAP = 0.5


def predicted_cost(hlo_text: str, kind: str = "cpu") -> dict[str, Any]:
    """Roofline-style model time (us) for one optimized HLO dump.

    ``max(bytes / BW, flops / FLOPS)``; pallas custom-call operand +
    result bytes (kernel-boundary traffic the fusion-level byte walk
    attributes to one opaque op) ride in ``hbm_bytes`` via the
    analyzer's per-instruction accounting and are also reported
    separately for the sweep report.
    """
    a = hlo_analyzer.analyze(hlo_text)
    nominal = NOMINAL.get(kind, NOMINAL["cpu"])
    bytes_s = a["hbm_bytes"] / nominal["bytes_per_s"]
    flops_s = a["flops"] / nominal["flops_per_s"]
    return {
        "flops": a["flops"],
        "hbm_bytes": a["hbm_bytes"],
        "custom_call_bytes": (
            a["custom_calls"]["operand_bytes"] + a["custom_calls"]["result_bytes"]
        ),
        "custom_call_count": a["custom_calls"]["count"],
        "model_us": max(bytes_s, flops_s) * 1e6,
    }


def _ranks(xs: list[float]) -> list[float]:
    """Average ranks (1-based, ties averaged — standard Spearman)."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman(xs: list[float], ys: list[float]) -> float | None:
    """Spearman rank correlation (Pearson over average ranks); ``None``
    when fewer than two points or either side is constant."""
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    n = len(xs)
    if n < 2:
        return None
    rx, ry = _ranks(xs), _ranks(ys)
    mx, my = sum(rx) / n, sum(ry) / n
    sxx = sum((a - mx) ** 2 for a in rx)
    syy = sum((b - my) ** 2 for b in ry)
    if sxx == 0.0 or syy == 0.0:
        return None
    sxy = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    return sxy / (sxx * syy) ** 0.5


def cross_check(candidates: list[dict[str, Any]]) -> dict[str, Any]:
    """Rank-correlate model vs stopwatch over one workload's measured
    candidates.

    Each input dict needs ``name``, ``measured_us`` and ``model_us``
    (candidates without a model prediction — eager fallbacks with no HLO
    — are skipped and counted).  Returns ``rank_correlation`` (Spearman,
    ``None`` when undefined), and ``flagged``: candidates whose
    normalized rank under the model vs the stopwatch differs by more
    than :data:`FLAG_RANK_GAP`.
    """
    scored = [
        c for c in candidates
        if c.get("model_us") is not None and c.get("measured_us") is not None
    ]
    out: dict[str, Any] = {
        "rank_correlation": None,
        "flagged": [],
        "modeled": len(scored),
        "unmodeled": len(candidates) - len(scored),
    }
    if len(scored) < 2:
        return out
    measured = [float(c["measured_us"]) for c in scored]
    modeled = [float(c["model_us"]) for c in scored]
    out["rank_correlation"] = spearman(modeled, measured)
    rm, rp = _ranks(measured), _ranks(modeled)
    span = float(len(scored) - 1)
    for c, a, b in zip(scored, rm, rp):
        gap = abs(a - b) / span
        if gap > FLAG_RANK_GAP:
            out["flagged"].append(
                {
                    "name": c.get("name"),
                    "measured_us": float(c["measured_us"]),
                    "model_us": float(c["model_us"]),
                    "rank_gap": gap,
                }
            )
    return out
