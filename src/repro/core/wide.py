"""Wide-modulus (v > 31, up to v = 46) modular arithmetic in int64 JAX —
the paper's t=4 / v=45 configuration as a first-class jit datapath.

A 45-bit x 45-bit product needs 90 bits; there is no int128 on TPU or in
jnp.  The special-prime form q = 2^v - beta (contribution 2) makes the
fold cheap: products are built from 23-bit digit partials (all < 2^63)
and bits >= v are folded with  2^v ≡ beta (mod q)  a bounded number of
times.  This is exactly why the paper's low-Hamming-weight moduli matter
beyond FPGA area: they keep wide modular arithmetic inside a 64-bit
(or, on TPU, 32-bit-pair) integer unit.

All ops are elementwise/broadcastable; a WideSpec carries the per-prime
constants.  Validated against Python bigints (hypothesis sweeps) and the
schoolbook polynomial oracle (tests/test_wide.py).

The end-to-end pipeline lives behind :mod:`repro.api` (width dispatch at
plan time); the ``*_channels`` functions below are the array-in/array-out
building blocks it executes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import bigint

D = 23  # digit width
M = (1 << D) - 1

# Post-processing limb width: y(46b) x limb(14b) x t(4) stays inside
# int64.  repro.api repacks pairs of these into the standard base-2^w
# (w = 28) output limbs so every width path shares one output contract.
POST_W = 14


@dataclasses.dataclass(frozen=True)
class WideSpec:
    q: int
    v: int
    beta: int  # q = 2^v - beta, 0 < beta < 2^{v1+1}, low Hamming weight

    def __post_init__(self):
        assert self.q == (1 << self.v) - self.beta
        assert 32 <= self.v <= 46, self.v
        # fold-safety: terms in mul_mod stay < 2^62 (see derivation below)
        assert self.beta < 1 << 30, hex(self.beta)


def from_special(prime) -> WideSpec:
    """Build from primes.SpecialPrime."""
    return WideSpec(q=prime.q, v=prime.v, beta=prime.beta)


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Assert-free :class:`WideSpec` twin for shard-local execution.

    The ``*_channels`` host spec tuple is keyed by GLOBAL channel index;
    under ``shard_map`` each shard sees a channel slice of the Plan's
    leaves, so its specs must be rebuilt from the sliced ``wide_qs``/
    ``wide_betas`` leaves (the channel-offset view).  Those are device
    scalars (tracers under jit), which cannot satisfy WideSpec's host-int
    ``__post_init__`` invariants — they were already validated at plan
    time on the global specs.  ``v`` stays a static python int because it
    parameterizes shift amounts; ``q``/``beta`` broadcast through the
    scalar mod-arithmetic like any jnp operand."""

    q: object  # jnp scalar (possibly traced)
    v: int
    beta: object  # jnp scalar (possibly traced)


def add_mod(a, b, q):
    s = a + b
    return jnp.where(s >= q, s - q, s)


def sub_mod(a, b, q):
    d_ = a - b
    return jnp.where(d_ < 0, d_ + q, d_)


def div2_mod(x, q):
    return (x >> 1) + (x & 1) * ((q + 1) // 2)


def _fold_v(x, spec: WideSpec):
    """x < 2^62 -> x mod-equivalent < 2^{v+1}ish via two 2^v folds."""
    v, beta = spec.v, spec.beta
    mask = (1 << v) - 1
    x = (x & mask) + (x >> v) * beta  # x>>v < 2^17, *beta < 2^47 -> < 2^48
    x = (x & mask) + (x >> v) * beta  # second pass: < 2^v + 2^32
    return x


def reduce_mod(x, spec: WideSpec):
    """x < 2^62 -> x mod q (canonical)."""
    x = _fold_v(x, spec)
    x = jnp.where(x >= spec.q, x - spec.q, x)
    x = jnp.where(x >= spec.q, x - spec.q, x)
    return x


def mul_mod(a, b, spec: WideSpec):
    """(a * b) mod q for a, b < q < 2^46, int64-safe throughout.

    Derivation of bounds (b2 = 2*beta < 2^31):
      partials p00 < 2^46, p01 < 2^47, p11 < 2^46
      hi46 = p01>>23 + p11   (value of x >> 46)        < 2^47
      lo46 = p00 + (p01 & M)<<23                        < 2^47
      x ≡ lo46 + b2^{v-46 adj} ... we fold at 46 bits with
      2^46 ≡ 2^{46-v} * beta * 2^{?}: for v <= 46, 2^46 = 2^{46-v} 2^v
      ≡ 2^{46-v} beta  (mod q), so with g = 2^{46-v} beta (< 2^31):
      x ≡ lo46 + g*h0 + ((g*h1 & M)<<23) + g2*(g*h1 >> 23)
      where h0 = hi46 & M (< 2^23), h1 = hi46 >> 23 (< 2^24),
      g*h0 < 2^54, g*h1 < 2^55, (…&M)<<23 < 2^46, g*(g*h1>>23) < 2^63?
      g*h1>>23 < 2^32, times g < 2^31 -> 2^63: tightened by beta < 2^30
      (asserted), giving g <= 2*beta < 2^31 only for v=45; then the last
      term < 2^62.  Total < 2^62.5 -> one extra fold pass keeps us exact
      because _fold_v only needs x < 2^63.
    """
    a0, a1 = a & M, a >> D
    b0, b1 = b & M, b >> D
    p00 = a0 * b0
    p01 = a0 * b1 + a1 * b0
    p11 = a1 * b1
    lo46 = p00 + ((p01 & M) << D)  # bits [0, 47)
    hi46 = (p01 >> D) + p11  # value of x >> 46
    g = (1 << (46 - spec.v)) * spec.beta  # 2^46 ≡ g (mod q)
    h0, h1 = hi46 & M, hi46 >> D
    t1 = g * h0  # < 2^54
    z = g * h1  # < 2^55
    acc = lo46 + t1 + ((z & M) << D) + g * (z >> D)
    return reduce_mod(acc, spec)


# --------------------------------------------------------------------------
# NTT over a wide modulus (same flow graphs as core/ntt.py)
# --------------------------------------------------------------------------


def ntt_raw(a, fwd, spec: WideSpec):
    n = a.shape[-1]
    lead = a.shape[:-1]
    q = spec.q
    m, t = 1, n
    while m < n:
        t //= 2
        w = fwd[m : 2 * m]
        x = a.reshape(lead + (m, 2, t))
        u = x[..., 0, :]
        vv = mul_mod(x[..., 1, :], w[:, None], spec)
        a = jnp.stack([add_mod(u, vv, q), sub_mod(u, vv, q)], axis=-2)
        a = a.reshape(lead + (n,))
        m *= 2
    return a


def intt_raw(a, inv, spec: WideSpec):
    n = a.shape[-1]
    lead = a.shape[:-1]
    q = spec.q
    h, t = n // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        x = a.reshape(lead + (h, 2, t))
        u, vv = x[..., 0, :], x[..., 1, :]
        s = add_mod(u, vv, q)
        d_ = mul_mod(sub_mod(u, vv, q), w[:, None], spec)
        a = jnp.stack([div2_mod(s, q), div2_mod(d_, q)], axis=-2)
        a = a.reshape(lead + (n,))
        h //= 2
        t *= 2
    return a


def negacyclic_mul(a, b, fwd, inv, spec: WideSpec):
    fa = ntt_raw(a, fwd, spec)
    fb = ntt_raw(b, fwd, spec)
    return intt_raw(mul_mod(fa, fb, spec), inv, spec)


# --------------------------------------------------------------------------
# Multi-channel building blocks (executed by repro.api's "wide" width
# path).  Leading axis = RNS channel; per-channel twiddle tables and
# RNS constants arrive as stacked arrays (the Plan pytree's leaves), so
# the same code serves eager calls, jit traces, and vmapped batches
# without re-uploading tables.
# --------------------------------------------------------------------------


def decompose_channels(z, specs, beta_pows):
    """z: (..., S) base-2^v segments -> residues (t, ...).

    Per channel i:  a mod q_i = sum_k z_k * (B^k mod q_i)  with the
    digit-split wide mul.  beta_pows: (t, S) device array of B^k mod q_i.
    """
    outs = []
    for i, spec in enumerate(specs):
        acc = z[..., 0].astype(jnp.int64)
        for k in range(1, z.shape[-1]):
            acc = add_mod(
                acc, mul_mod(z[..., k].astype(jnp.int64), beta_pows[i, k], spec),
                spec.q,
            )
        outs.append(acc)
    return jnp.stack(outs)


def ntt_channels(a, fwd, specs):
    """a: (t, ..., n) -> forward wide NTT per channel; fwd: (t, n)."""
    return jnp.stack(
        [ntt_raw(a[i], fwd[i], spec) for i, spec in enumerate(specs)]
    )


def intt_channels(a, inv, specs):
    """a: (t, ..., n) bit-reversed spectra -> natural order; inv: (t, n)."""
    return jnp.stack(
        [intt_raw(a[i], inv[i], spec) for i, spec in enumerate(specs)]
    )


def negacyclic_mul_channels(a, b, fwd, inv, specs):
    """(t, ..., n) x (t, ..., n) -> per-channel negacyclic products."""
    return jnp.stack(
        [
            negacyclic_mul(a[i], b[i], fwd[i], inv[i], spec)
            for i, spec in enumerate(specs)
        ]
    )


def compose_channels(residues, specs, qi_tilde, qi_star_limbs, q_limbs):
    """Inverse CRT (Eq 10) with POST_W-bit limbs: residues (t, ...) ->
    (..., L14) base-2^POST_W limbs of p mod q (canonical).

    qi_star_limbs: (t, L14) limbs of q/q_i; q_limbs: (L14,).  Limb width
    POST_W = 14 keeps y(46b) x limb(14b) x t products inside int64.
    """
    t = len(specs)
    W, L = POST_W, qi_star_limbs.shape[-1]
    ys = [
        mul_mod(residues[i], qi_tilde[i], spec) for i, spec in enumerate(specs)
    ]
    y = jnp.stack(ys)  # (t, ..., n) each < q_i < 2^46
    star_b = qi_star_limbs.reshape((t,) + (1,) * (y.ndim - 1) + (L,))
    contrib = y[..., None] * star_b  # < 2^60, t-sum < 2^62
    acc = bigint.carry_normalize(contrib.sum(axis=0), W)
    q_b = q_limbs.reshape((1,) * (acc.ndim - 1) + (L,))
    return bigint.mod_by_subtraction(
        acc, jnp.broadcast_to(q_b, acc.shape), W, t - 1
    )


def repack_limbs(limbs, w_in: int, w_out: int):
    """Exact repack of canonical base-2^w_in limbs into base-2^w_out
    (w_out a multiple of w_in), zero-padding the tail group.  Because
    ceil(ceil(B/w_in) / k) == ceil(B/(k*w_in)), repacking the wide
    path's POST_W=14 limbs with w_out=28 yields exactly the standard
    plan.L output limbs."""
    if w_out % w_in:
        raise ValueError(f"w_out={w_out} must be a multiple of w_in={w_in}")
    k = w_out // w_in
    L = limbs.shape[-1]
    pad = (-L) % k
    if pad:
        limbs = jnp.concatenate(
            [limbs, jnp.zeros(limbs.shape[:-1] + (pad,), limbs.dtype)], axis=-1
        )
    grouped = limbs.reshape(limbs.shape[:-1] + (-1, k))
    shifts = jnp.asarray(
        [1 << (w_in * j) for j in range(k)], dtype=limbs.dtype
    )
    return (grouped * shifts).sum(axis=-1)
