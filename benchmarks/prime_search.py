"""Paper Table III: number of special NTT-compatible, CRT-friendly primes
under each (t, v, mu, #PoT) setting.  Reproduces all eight counts exactly
with the word-length constraint (mu >= v + n_beta*(v1+1) + 1, n_beta=2);
also reports the counts under Eq 6 *as printed*, documenting the erratum.
"""
import time

from repro.core import primes as P

ROWS = [
    # (t, v, mu, pot, paper_count)
    (4, 45, 105, 4, 12),
    (4, 45, 120, 4, 33),
    (4, 45, 105, 5, 126),
    (4, 45, 120, 5, 480),
    (6, 30, 75, 4, 8),
    (6, 30, 90, 4, 26),
    (6, 30, 75, 5, 23),
    (6, 30, 90, 5, 169),
]


def run():
    out = []
    for t, v, mu, pot, paper in ROWS:
        t0 = time.perf_counter()
        found = P.find_special_primes(v=v, n=4096, mu=mu, pot=pot, n_beta=2)
        us = (time.perf_counter() - t0) * 1e6
        out.append(
            (
                f"tableIII_t{t}_v{v}_mu{mu}_pot{pot}",
                us,
                f"found={len(found)} paper={paper} match={len(found) == paper}",
            )
        )
        eq6 = P.find_special_primes(
            v=v, n=4096, mu=mu, pot=pot, n_beta=2, constraint="eq6"
        )
        out.append(
            (
                f"tableIII_eq6_as_printed_t{t}_v{v}_mu{mu}_pot{pot}",
                0.0,
                f"found={len(eq6)} (erratum: printed Eq6 inconsistent w/ Table III)",
            )
        )
    return out
