"""zamba2-2.7b — 54L hybrid: Mamba2 backbone (ssm_state=64) + ONE shared
attention/MLP block (32H, kv=32) applied every 6 layers, d_model=2560,
d_ff=10240, vocab=32000.  [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    shared_attn_every=6,
)
