"""The plan/execute front door (repro.api): width dispatch through ONE
entry point, plan-time validation of every knob, pytree/jit/vmap
semantics of Plan, and the delegation contract of the legacy class
shims."""
import random
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro import api
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.core import wide as wide_mod


def _rand_ints(pl, seed, n=None):
    rng = random.Random(seed)
    n = n or pl.n
    a = [rng.randrange(pl.q) for _ in range(n)]
    b = [rng.randrange(pl.q) for _ in range(n)]
    return a, b


def _rand_segments(pl, seed, batch=2):
    rng = np.random.default_rng(seed)
    shape = (batch, pl.n, pl.config.seg_count)
    return (
        jnp.asarray(rng.integers(0, 1 << pl.v, size=shape)),
        jnp.asarray(rng.integers(0, 1 << pl.v, size=shape)),
    )


class TestWidthDispatch:
    """One polymul signature serving all three modulus-width datapaths,
    bit-exact vs the Python-bigint oracle."""

    @pytest.mark.parametrize(
        "t,v,n,width",
        [
            (6, 30, 64, "int64"),  # the paper's preferred preset
            (4, 45, 64, "wide"),  # the paper's wide-word preset
        ],
    )
    def test_paper_presets_one_code_path(self, t, v, n, width):
        pl = repro.plan(n=n, t=t, v=v)
        assert pl.config.width == width
        a, b = _rand_ints(pl, seed=v * n)
        assert repro.polymul_ints(pl, a, b) == pm.oracle_multiply(a, b, pl.params)

    def test_oracle_width_beyond_wide(self):
        pl = repro.plan(n=32, t=2, v=50)
        assert pl.config.width == "oracle"
        assert pl.config.backend == "oracle"
        a, b = _rand_ints(pl, seed=50)
        # the oracle width EXECUTES oracle_multiply, so the independent
        # check is the schoolbook oracle (different algorithm entirely)
        got = repro.polymul_ints(pl, a, b)
        assert got == pm.schoolbook_negacyclic(a, b, pl.q)

    def test_output_contract_shared_across_widths(self):
        """Every width returns (..., n, L) base-2^w limbs with the SAME
        w (the wide path's internal 14-bit limbs are repacked)."""
        for t, v in ((3, 30), (4, 45), (2, 50)):
            pl = repro.plan(n=32, t=t, v=v)
            assert pl.config.w == 28
            za, zb = _rand_segments(pl, seed=t)
            out = repro.polymul(pl, za, zb)
            assert out.shape == (2, 32, pl.config.L)
            assert int(jnp.max(out)) < (1 << pl.config.w)

    def test_wide_batch_rows_match_host_oracle(self):
        from repro.core import bigint

        pl = repro.plan(n=32, t=4, v=45)
        za, zb = _rand_segments(pl, seed=9, batch=2)
        got = np.asarray(repro.polymul(pl, za, zb))
        for r in range(2):
            a = [
                bigint.limbs_to_int(row, pl.v) for row in np.asarray(za[r])
            ]
            b = [
                bigint.limbs_to_int(row, pl.v) for row in np.asarray(zb[r])
            ]
            want = pm.oracle_multiply(a, b, pl.params)
            assert bigint.limbs_to_ints(got[r], pl.config.w) == want


class TestPlanValidation:
    """Every invalid combination fails at plan time with a ValueError —
    never mid-execution."""

    def test_bad_v(self):
        with pytest.raises(ValueError, match="v must be"):
            repro.plan(n=64, t=3, v=4)
        with pytest.raises(ValueError, match="v must be"):
            repro.plan(n=64, t=3, v=99)

    def test_bad_n(self):
        with pytest.raises(ValueError, match="power of two"):
            repro.plan(n=48, t=3, v=30)
        with pytest.raises(ValueError, match="power of two"):
            repro.plan(n=2, t=3, v=30, schedule="four_step")

    def test_unknown_backend_and_schedule(self):
        with pytest.raises(ValueError, match="unknown backend"):
            repro.plan(n=64, t=3, v=30, backend="cuda")
        with pytest.raises(ValueError, match="unknown schedule"):
            repro.plan(n=64, t=3, v=30, schedule="five_step")

    def test_wide_width_rejects_pallas_and_four_step(self):
        with pytest.raises(ValueError, match="pure-jnp"):
            repro.plan(n=64, t=4, v=45, backend="pallas_fused_e2e")
        with pytest.raises(ValueError, match="radix2"):
            repro.plan(n=64, t=4, v=45, schedule="four_step")

    def test_oracle_width_rejects_device_backends(self):
        with pytest.raises(ValueError, match="oracle"):
            repro.plan(n=32, t=2, v=50, backend="jnp")

    def test_bad_row_blk(self):
        with pytest.raises(ValueError, match="row_blk"):
            repro.plan(n=64, t=3, v=30, row_blk=0)

    def test_row_blk_threads_into_params(self):
        """The kernel tile knob must reach the execution params (the
        kernels read params.row_blk), not just the config record."""
        pl = repro.plan(n=64, t=3, v=30, backend="pallas_fused", row_blk=2)
        assert pl.config.row_blk == 2
        assert pl.params.row_blk == 2
        za, zb = _rand_segments(pl, seed=37)
        want = repro.polymul(repro.plan(n=64, t=3, v=30), za, zb)
        assert np.array_equal(
            np.asarray(repro.polymul(pl, za, zb)), np.asarray(want)
        )

    def test_wide_inverse_crt_envelope_rejected_at_plan_time(self):
        """t * 2^(v+14) > 2^63 would silently overflow the wide path's
        int64 CRT accumulator — must be rejected at plan time."""
        with pytest.raises(ValueError, match="inverse-CRT accumulator"):
            repro.plan(n=16, t=12, v=46)
        # the legacy adapter path must enforce the same envelope
        with pytest.raises(ValueError, match="inverse-CRT accumulator"):
            api.plan_from_params(params_mod.make_params(n=16, t=12, v=46))
        # the paper's wide preset and the t=8/v=46 boundary stay valid
        assert repro.plan(n=16, t=4, v=45).config.width == "wide"

    def test_oracle_width_is_host_only(self):
        pl = repro.plan(n=32, t=2, v=50)
        za, zb = _rand_segments(pl, seed=1)
        with pytest.raises(ValueError, match="cannot be traced"):
            jax.jit(repro.polymul)(pl, za, zb)
        with pytest.raises(ValueError, match="no device transform"):
            repro.ntt(pl, jnp.zeros((2, 1, 32), jnp.int64))

    def test_polymul_requires_a_plan(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        with pytest.raises(TypeError, match="repro.api.Plan"):
            repro.polymul(p, jnp.zeros((64, 3)), jnp.zeros((64, 3)))


class TestPlanPytree:
    """Plan is a registered pytree: device constants as leaves, config
    as static aux — the property that makes jit/vmap/shard_map native."""

    def test_flatten_roundtrip(self):
        pl = repro.plan(n=64, t=3, v=30)
        leaves, treedef = jax.tree_util.tree_flatten(pl)
        assert leaves and all(hasattr(x, "dtype") for x in leaves)
        pl2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert pl2.config == pl.config
        za, zb = _rand_segments(pl, seed=3)
        assert np.array_equal(
            np.asarray(repro.polymul(pl2, za, zb)),
            np.asarray(repro.polymul(pl, za, zb)),
        )

    def test_same_config_same_treedef(self):
        t1 = jax.tree_util.tree_structure(repro.plan(n=64, t=3, v=30))
        t2 = jax.tree_util.tree_structure(repro.plan(n=64, t=3, v=30))
        assert t1 == t2
        t3 = jax.tree_util.tree_structure(
            repro.plan(n=64, t=3, v=30, backend="pallas_fused")
        )
        assert t1 != t3  # different config -> different static aux

    def test_tables_shared_not_rebuilt(self):
        """Same (n, t, v) -> the very same device buffers (no re-upload),
        across plans and across backend variants."""
        a = repro.plan(n=64, t=3, v=30)
        b = repro.plan(n=64, t=3, v=30, backend="pallas_fused")
        assert a.consts["ntt_fwd"] is b.consts["ntt_fwd"]
        assert a.params is b.params


class TestRetraceAndVmap:
    def test_jit_compiles_once_across_same_config_plans(self):
        """The retrace probe: repeated calls with a shared plan AND with
        a rebuilt same-config plan hit one trace."""
        traces = []

        def f(pl, za, zb):
            traces.append(1)
            return repro.polymul(pl, za, zb)

        fj = jax.jit(f)
        pl = repro.plan(n=64, t=3, v=30)
        za, zb = _rand_segments(pl, seed=11)
        fj(pl, za, zb)
        fj(pl, za, zb)
        fj(repro.plan(n=64, t=3, v=30), za, zb)  # rebuilt, same config
        assert len(traces) == 1
        # a different config must (correctly) retrace
        fj(repro.plan(n=64, t=3, v=30, use_sau=False), za, zb)
        assert len(traces) == 2

    def test_vmap_over_batch_matches_loop(self):
        pl = repro.plan(n=64, t=3, v=30)
        za, zb = _rand_segments(pl, seed=13, batch=3)
        vm = jax.vmap(repro.polymul, in_axes=(None, 0, 0))(pl, za, zb)
        loop = jnp.stack(
            [repro.polymul(pl, za[i], zb[i]) for i in range(3)]
        )
        assert np.array_equal(np.asarray(vm), np.asarray(loop))

    def test_vmap_wide_width(self):
        pl = repro.plan(n=32, t=4, v=45)
        za, zb = _rand_segments(pl, seed=17, batch=3)
        vm = jax.jit(jax.vmap(repro.polymul, in_axes=(None, 0, 0)))(pl, za, zb)
        loop = jnp.stack(
            [repro.polymul(pl, za[i], zb[i]) for i in range(3)]
        )
        assert np.array_equal(np.asarray(vm), np.asarray(loop))


class TestLeafThreading:
    """The int64 ops layer binds its device tables from the Plan's
    pytree LEAVES (api._bound_params), not from the static params — so
    tree.map/device_put/sharding of the leaves is load-bearing for
    every width (DESIGN §7; the serving layer's model-axis shard_map
    depends on this)."""

    @pytest.mark.parametrize(
        "backend", ["jnp", "pallas", "pallas_fused", "pallas_fused_e2e"]
    )
    def test_int64_leaves_are_the_dataflow(self, backend):
        """Corrupting a leaf must corrupt the output on every backend;
        if the kernels read jit-constant tables this is a no-op."""
        pl = repro.plan(n=64, t=3, v=30, backend=backend)
        za, zb = _rand_segments(pl, seed=41)
        want = np.asarray(repro.polymul(pl, za, zb))
        broken_consts = dict(pl.consts)
        broken_consts["ntt_fwd"] = broken_consts["ntt_fwd"] ^ 1
        broken = api.Plan(
            config=pl.config, params=pl.params, consts=broken_consts
        )
        got = np.asarray(repro.polymul(broken, za, zb))
        assert not np.array_equal(got, want), backend

    def test_compose_star_tables_ride_leaves(self):
        """The inverse-CRT star-limb tables are leaf-bound too (the
        compose kernels take them as traced operands)."""
        pl = repro.plan(n=64, t=3, v=30)
        rng = np.random.default_rng(43)
        res = jnp.asarray(
            np.stack(
                [
                    rng.integers(0, int(q), size=(2, 64))
                    for q in pl.params.plan.qs
                ]
            )
        )
        want = np.asarray(repro.compose(pl, res))
        broken_consts = dict(pl.consts)
        broken_consts["rns_qi_star_limbs"] = (
            broken_consts["rns_qi_star_limbs"] ^ 1
        )
        broken = api.Plan(
            config=pl.config, params=pl.params, consts=broken_consts
        )
        assert not np.array_equal(
            np.asarray(repro.compose(broken, res)), want
        )

    def test_device_put_roundtrip_still_exact(self):
        """device_put over the leaves (the serving resharding motion)
        keeps execution bit-exact."""
        pl = repro.plan(n=64, t=3, v=30)
        za, zb = _rand_segments(pl, seed=47)
        want = np.asarray(repro.polymul(pl, za, zb))
        moved = jax.tree.map(jax.device_put, pl)
        assert np.array_equal(np.asarray(repro.polymul(moved, za, zb)), want)


class TestStageEntries:
    def test_int64_stage_composition_equals_polymul(self):
        pl = repro.plan(n=64, t=3, v=30)
        za, zb = _rand_segments(pl, seed=19)
        ra, rb = repro.decompose(pl, za), repro.decompose(pl, zb)
        out = repro.compose(pl, repro.negacyclic_mul(pl, ra, rb))
        assert np.array_equal(
            np.asarray(out), np.asarray(repro.polymul(pl, za, zb))
        )

    def test_ntt_intt_roundtrip_both_device_widths(self):
        for t, v in ((3, 30), (4, 45)):
            pl = repro.plan(n=64, t=t, v=v)
            rng = np.random.default_rng(v)
            a = jnp.asarray(
                np.stack(
                    [
                        rng.integers(0, int(q), size=(2, 64))
                        for q in pl.params.plan.qs
                    ]
                )
            )
            back = repro.intt(pl, repro.ntt(pl, a))
            assert np.array_equal(np.asarray(back), np.asarray(a))

    def test_oracle_stage_roundtrip_on_host(self):
        pl = repro.plan(n=32, t=2, v=50)
        a, _ = _rand_ints(pl, seed=23)
        za = repro.to_segments(pl, a)  # (n, S)
        res = repro.decompose(pl, za)
        assert res.shape == (pl.t, pl.n)
        limbs = repro.compose(pl, res)
        assert repro.from_limbs(pl, limbs) == [x % pl.q for x in a]


class TestApiSurface:
    """The plan/execute API is the only front door: the class shims are
    gone, and the exported surface matches the committed snapshot."""

    def test_class_shims_are_gone(self):
        assert not hasattr(pm, "ParenttMultiplier")
        assert not hasattr(wide_mod, "WideParenttMultiplier")

    def test_api_surface_matches_committed_snapshot(self):
        snap = Path(__file__).resolve().parent.parent / "API_SURFACE.txt"
        want = sorted(snap.read_text().split())
        assert sorted(repro.__all__) == want

    def test_all_exports_resolve(self):
        for name in repro.__all__:
            assert getattr(repro, name) is not None

    def test_plan_from_params_respects_params_knobs(self):
        p = params_mod.make_params(
            n=64, t=3, v=30, backend="pallas_fused", schedule="four_step",
            row_blk=2,
        )
        pl = api.plan_from_params(p)
        assert pl.config.backend == "pallas_fused"
        assert pl.config.schedule.kind == "four_step"
        assert pl.config.schedule.row_blk == 2
        assert pl.config.row_blk == 2
