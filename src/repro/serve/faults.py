"""Deterministic, seedable fault injection for the serving engine.

The robustness claims of :class:`repro.serve.crypto_engine.PolymulEngine`
— exactly-once resolution, bounded retries, circuit breaking onto a
bit-exact fallback backend — are only worth stating if they hold under
*actual* faults.  This module supplies them on a reproducible schedule:
a :class:`FaultInjector` wraps ``engine.executor`` (the single funnel
every dispatch attempt passes through) and can **raise** a transient
error, **delay** the dispatch, or **corrupt** the returned limbs,
according to a list of :class:`FaultRule` triggers driven by one seeded
``numpy`` generator.

Design points:

* **Deterministic.**  All randomness comes from ``seed``; the injector
  counts executor calls itself, and the engine stamps every resolved
  future with the ``dispatch_index`` of the call that produced it —
  the two counters advance in lock-step (install the injector before
  any dispatch), so the injector's ``log`` can be joined against
  resolved futures after the fact.  That join is how the soak driver
  *detects* injected corruption rather than merely surviving it.
* **Raise beats corrupt.**  When several rules match one call, the
  first matching ``raise`` rule wins; otherwise every matching
  ``delay`` sleeps and every matching ``corrupt`` XORs the output.
* **Corruption is engine-invisible.**  A corrupt rule flips the low bit
  of the result limbs *after* the wrapped executor returns — the engine
  serves it as a success.  Catching it is the oracle spot-check's job
  (:func:`spot_check`), mirroring how a real silent-data-corruption
  event would have to be caught downstream.

Usage::

    inj = FaultInjector([
        FaultRule("raise", backend="pallas_fused_e2e", max_count=3),
        FaultRule("delay", rate=0.05, delay_s=0.01),
        FaultRule("corrupt", rate=0.01),
    ], seed=7)
    inj.install(eng)          # wraps eng.executor
    ... drive traffic ...
    corrupted = {i for i, kind, _ in inj.log if kind == "corrupt"}
    # futures with fut.dispatch_index in `corrupted` carry flipped limbs
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import api
from repro.obs import metrics as obs_metrics

__all__ = ["FaultInjector", "FaultRule", "InjectedFault", "spot_check"]


class InjectedFault(RuntimeError):
    """The transient error a ``raise`` rule throws inside the executor.

    Deliberately a plain ``RuntimeError`` subclass — the engine must
    treat it like any unexpected dispatch failure; nothing in the
    retry/breaker path is allowed to special-case it."""

    def __init__(self, message: str, *, dispatch_index: int = -1) -> None:
        super().__init__(message)
        self.dispatch_index = dispatch_index


@dataclasses.dataclass
class FaultRule:
    """One trigger in an injection schedule.

    Parameters
    ----------
    kind:
        ``"raise"`` (throw :class:`InjectedFault`), ``"delay"`` (sleep
        ``delay_s`` before executing) or ``"corrupt"`` (XOR 1 into the
        returned limbs — silent data corruption).
    rate:
        Bernoulli firing probability per *eligible* call (default 1.0:
        fire on every eligible call).
    backend:
        Only fire on dispatches whose plan uses this backend (``None``:
        any).  This is how a soak pins faults to one chain level, e.g.
        "the fused-e2e kernel is broken, its fallbacks are fine".
    after / until:
        Eligible window in executor-call indices: ``after <= idx`` and
        (when ``until`` is set) ``idx < until``.
    max_count:
        Stop firing after this many hits (``None``: unbounded).
    at:
        Explicit call indices that *force* the rule to fire (still
        subject to ``backend``) regardless of ``rate`` — pins the
        schedule's must-happen events, e.g. "call 17 is corrupted".
    delay_s:
        Sleep length for ``delay`` rules.
    """

    kind: str
    rate: float = 1.0
    backend: str | None = None
    after: int = 0
    until: int | None = None
    max_count: int | None = None
    at: tuple = ()
    delay_s: float = 0.02
    fired: int = 0  # hits so far (mutated by the injector)

    def __post_init__(self):
        if self.kind not in ("raise", "delay", "corrupt"):
            raise ValueError(
                f"FaultRule kind must be raise/delay/corrupt, "
                f"got {self.kind!r}"
            )
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


class FaultInjector:
    """Wraps an engine executor with a seeded fault schedule.

    Attributes
    ----------
    calls:
        Executor calls seen so far; the current call's index is
        ``calls - 1`` inside the wrapper and equals the engine's
        ``dispatch_index`` stamp when installed before any dispatch.
    log:
        ``(call_index, kind, backend)`` for every fault fired — the
        ground truth the soak driver joins against resolved futures.
    """

    def __init__(self, rules, *, seed: int = 0):
        self.rules = list(rules)
        self.rng = np.random.default_rng(seed)
        self.calls = 0
        self.log: list[tuple[int, str, str]] = []
        self._counter = obs_metrics.registry().counter(
            "repro_faults_injected_total",
            "faults fired by the injection schedule",
            ("kind", "backend"),
        )

    def _matches(self, rule: FaultRule, idx: int, backend: str) -> bool:
        if rule.backend is not None and backend != rule.backend:
            return False
        if rule.max_count is not None and rule.fired >= rule.max_count:
            return False
        if idx in rule.at:
            return True
        if idx < rule.after:
            return False
        if rule.until is not None and idx >= rule.until:
            return False
        # One rng draw per (rule, call) in rule order: the schedule is a
        # pure function of (rules, seed, call sequence).
        return bool(self.rng.random() < rule.rate)

    def _fire(self, rule: FaultRule, idx: int, backend: str) -> None:
        rule.fired += 1
        self.log.append((idx, rule.kind, backend))
        self._counter.labels(kind=rule.kind, backend=backend).inc()

    def wrap(self, fn):
        """The wrapped executor: ``fn`` with faults injected per the
        schedule.  Pure pass-through once every rule is exhausted."""

        def _injected(pl, za, zb):
            idx = self.calls
            self.calls += 1
            backend = api.plan_key(pl).backend
            hits = [
                r for r in self.rules if self._matches(r, idx, backend)
            ]
            for r in hits:
                if r.kind == "delay":
                    self._fire(r, idx, backend)
                    time.sleep(r.delay_s)
            for r in hits:
                if r.kind == "raise":
                    self._fire(r, idx, backend)
                    raise InjectedFault(
                        f"injected transient fault at dispatch {idx} "
                        f"(backend {backend!r})",
                        dispatch_index=idx,
                    )
            out = fn(pl, za, zb)
            for r in hits:
                if r.kind == "corrupt":
                    self._fire(r, idx, backend)
                    out = np.asarray(out) ^ 1  # silent low-bit flip
            return out

        return _injected

    def install(self, engine) -> "FaultInjector":
        """Wrap ``engine.executor`` in place.  Install before the first
        dispatch so call indices align with the engine's
        ``dispatch_index`` stamps."""
        engine.executor = self.wrap(engine.executor)
        return self

    def indices(self, kind: str) -> set:
        """Call indices at which faults of ``kind`` fired."""
        return {i for i, k, _ in self.log if k == kind}

    def quiesce(self, kind: str | None = None) -> None:
        """Exhaust matching rules (all of them when ``kind`` is None):
        each rule's ``max_count`` is pinned to its fired count, so it
        never fires again.  The soak driver calls this before its
        recovery phase so breaker probes deterministically succeed."""
        for r in self.rules:
            if kind is None or r.kind == kind:
                r.max_count = r.fired


def spot_check(pl, za, zb, limbs, *, use_oracle: bool = False) -> bool:
    """Does a served result match ground truth?  ``za``/``zb``: the
    request's ``(n, S)`` segments; ``limbs``: the future's ``(n, L)``
    result.  Recomputes through :func:`api.polymul` on the request's
    *original* plan (bit-exact across the degradation chain), or — with
    ``use_oracle`` — through the host bigint schoolbook oracle,
    independent of every device datapath.  This is the detection arm of
    the fault harness: a ``corrupt`` rule's flipped limbs make it
    return ``False``."""
    if use_oracle:
        from repro.core import bigint
        from repro.core import polymul as core_polymul

        cfg = api.plan_key(pl)
        a_ints = bigint.limbs_to_ints(np.asarray(za), cfg.v)
        b_ints = bigint.limbs_to_ints(np.asarray(zb), cfg.v)
        ref_ints = core_polymul.oracle_multiply(a_ints, b_ints, pl.params)
        return api.from_limbs(pl, limbs) == ref_ints
    ref = np.asarray(api.polymul(pl, np.asarray(za)[None],
                                 np.asarray(zb)[None]))[0]
    return bool(np.array_equal(np.asarray(limbs), ref))
