"""Four-step (n = n1 x n2) negacyclic NTT — the distributed/blocked form.

The paper raises throughput by adding datapath lanes (2-parallel folding);
at chip scale the analogous lever splits ONE long polynomial across
devices.  Decomposition (cyclic DFT after the negacyclic pre-weight):

    a_hat = a ⊙ psi^j                      (elementwise)
    X[j1, j2] = a_hat[j1*n2 + j2]
    C = DFT_n1 over j1 (columns)           (local: shard j2)
    C = C ⊙ omega^{brv(p1) * j2}           (twiddle correction)
    T = transpose(C)                       (the ONLY communication:
                                            an (n1, n2) all-to-all)
    Y = DFT_n2 over j2 (columns of T)      (local: shard p1)

Both operands of a product use the same scrambled output order
(bit-reversed within each factor, factors transposed), so the pointwise
product needs no reordering — the four-step cascade keeps the paper's
zero-shuffle property at the distributed level: ONE all-to-all per
transform, nothing else.

Inner transforms: cyclic radix-2 DIF (natural-in, bit-reversed-out) and
its DIT mirror (bit-reversed-in, natural-out) with the per-stage halving
trick (Eq 24) folding in m^{-1}; validated against the naive DFT and the
single-step NWC transform (tests/test_dntt.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primes as primes_mod
from repro.core.ntt import bit_reverse_indices, mul_mod


# --------------------------------------------------------------------------
# cyclic DIF / DIT kernels (last-axis transforms, per-stage twiddle tuples)
# --------------------------------------------------------------------------


def _stage_tables(q: int, m: int, w: int) -> tuple[np.ndarray, ...]:
    """DIF stage twiddles for a length-m cyclic transform with root w:
    stage sizes m, m/2, ..., 2; stage s has (size/2) twiddles w^{j*(m/size)}."""
    out = []
    size = m
    while size >= 2:
        half, stride = size // 2, m // size
        out.append(
            np.array([pow(w, j * stride, q) for j in range(half)], dtype=np.int64)
        )
        size //= 2
    return tuple(out)


def cyclic_dif(a, stages, q):
    """Cyclic DFT, natural-in -> bit-reversed-out, over the last axis."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    size = n
    for W in stages:
        half = size // 2
        x = a.reshape(lead + (n // size, size))
        u, v = x[..., :half], x[..., half:]
        s = (u + v) % q
        d = mul_mod((u - v) % q, jnp.asarray(W), q)
        a = jnp.concatenate([s, d], axis=-1).reshape(lead + (n,))
        size //= 2
    return a


def cyclic_dit_inv(a, inv_stages, q, half_q):
    """Inverse cyclic DFT, bit-reversed-in -> natural-out, m^{-1} folded via
    the per-stage halving (paper Eq 24)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    size = 2
    for Wi in reversed(inv_stages):
        half = size // 2
        x = a.reshape(lead + (n // size, size))
        p, r = x[..., :half], mul_mod(x[..., half:], jnp.asarray(Wi), q)
        s = (p + r) % q
        d = (p - r) % q
        s = (s >> 1) + (s & 1) * half_q
        d = (d >> 1) + (d & 1) * half_q
        a = jnp.concatenate([s, d], axis=-1).reshape(lead + (n,))
        size *= 2
    return a


# --------------------------------------------------------------------------
# four-step tables
# --------------------------------------------------------------------------


class FourStepTables(NamedTuple):
    q: int
    n: int
    n1: int
    n2: int
    psi_w: np.ndarray  # (n,) psi^j       negacyclic pre-weight
    psi_iw: np.ndarray  # (n,) psi^{-j}    post-weight (n^{-1} is in the stages)
    st1: tuple  # DIF stages, length n1
    ist1: tuple
    st2: tuple  # DIF stages, length n2
    ist2: tuple
    tw: np.ndarray  # (n1, n2) omega^{brv(p1) * j2}
    itw: np.ndarray
    half: int


@functools.lru_cache(maxsize=None)
def make_fourstep_tables(q: int, n: int, n1: int) -> FourStepTables:
    n2 = n // n1
    assert n1 * n2 == n and n1 & (n1 - 1) == 0 and n2 & (n2 - 1) == 0
    psi = primes_mod.root_of_unity(q, 2 * n)
    omega = pow(psi, 2, q)
    omega_inv = pow(omega, q - 2, q)
    psi_inv = pow(psi, q - 2, q)
    psi_w = np.array([pow(psi, j, q) for j in range(n)], dtype=np.int64)
    psi_iw = np.array([pow(psi_inv, j, q) for j in range(n)], dtype=np.int64)
    w1, w2 = pow(omega, n2, q), pow(omega, n1, q)
    brv1 = bit_reverse_indices(n1)
    tw = np.empty((n1, n2), dtype=np.int64)
    itw = np.empty((n1, n2), dtype=np.int64)
    for p1 in range(n1):
        k1 = int(brv1[p1])
        base, ibase = pow(omega, k1, q), pow(omega_inv, k1, q)
        row, irow = 1, 1
        for j in range(n2):
            tw[p1, j], itw[p1, j] = row, irow
            row = (row * base) % q
            irow = (irow * ibase) % q
    return FourStepTables(
        q=q, n=n, n1=n1, n2=n2, psi_w=psi_w, psi_iw=psi_iw,
        st1=_stage_tables(q, n1, w1),
        ist1=_stage_tables(q, n1, pow(w1, q - 2, q)),
        st2=_stage_tables(q, n2, w2),
        ist2=_stage_tables(q, n2, pow(w2, q - 2, q)),
        tw=tw, itw=itw, half=(q + 1) // 2,
    )


# --------------------------------------------------------------------------
# transforms
# --------------------------------------------------------------------------


def fourstep_ntt(a, t: FourStepTables, constrain=lambda x, k: x):
    """a: (..., n) -> scrambled NWC spectrum (..., n) (order: (p2, p1))."""
    q = t.q
    a = mul_mod(a, jnp.asarray(t.psi_w), q)
    x = a.reshape(a.shape[:-1] + (t.n1, t.n2))
    x = constrain(x, "cols")
    # columns over j1: transform the last axis of the transposed view
    x = cyclic_dif(x.swapaxes(-1, -2), t.st1, q).swapaxes(-1, -2)  # (p1, j2)
    x = mul_mod(x, jnp.asarray(t.tw), q)
    x = x.swapaxes(-1, -2)  # ALL-TO-ALL: (p1, j2) -> (j2, p1)
    x = constrain(x, "cols")
    x = cyclic_dif(x.swapaxes(-1, -2), t.st2, q).swapaxes(-1, -2)  # (p2, p1)
    return x.reshape(a.shape[:-1] + (t.n,))


def fourstep_intt(y, t: FourStepTables, constrain=lambda x, k: x):
    q = t.q
    x = y.reshape(y.shape[:-1] + (t.n2, t.n1))
    x = constrain(x, "cols")
    x = cyclic_dit_inv(x.swapaxes(-1, -2), t.ist2, q, t.half).swapaxes(-1, -2)
    x = x.swapaxes(-1, -2)  # all-to-all back: (j2, p1) -> (p1, j2)
    x = constrain(x, "cols")
    x = mul_mod(x, jnp.asarray(t.itw), q)
    x = cyclic_dit_inv(x.swapaxes(-1, -2), t.ist1, q, t.half).swapaxes(-1, -2)
    out = x.reshape(y.shape[:-1] + (t.n,))
    return mul_mod(out, jnp.asarray(t.psi_iw), q)


def negacyclic_mul_fourstep(a, b, t: FourStepTables, constrain=lambda x, k: x):
    fa = fourstep_ntt(a, t, constrain)
    fb = fourstep_ntt(b, t, constrain)
    return fourstep_intt(mul_mod(fa, fb, t.q), t, constrain)


def make_shard_constrain(mesh, axis: str = "model"):
    """Shard the trailing axis of the (..., m, k) views over `axis` —
    inner transforms become device-local; the swapaxes between them lowers
    to one all-to-all."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def constrain(x, kind):
        if kind == "cols" and x.shape[-1] % mesh.shape[axis] == 0:
            spec = P(*([None] * (x.ndim - 1) + [axis]))
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return x

    return constrain
