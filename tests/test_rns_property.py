"""Property tests for RNS pre/post-processing (paper Alg 2 / Eq 10):
decompose/compose round-trips over random residues for EVERY registered
special modulus — each channel's SAU circuit gets its own property, not
just the two end-to-end pipeline presets.

Uses hypothesis when installed; otherwise the fallback shim turns each
property into an individual skip (see tests/_hypothesis_fallback.py).
"""
import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core import bigint
from repro.core import params as params_mod
from repro.core import primes as primes_mod
from repro.core import rns as rns_mod
from repro.kernels import crt as crt_kernels

# Registered configurations served by the int64 datapaths; their
# default_prime_set members are "every registered special modulus".
CONFIGS = [(64, 3, 30), (256, 6, 30)]


def _registered_moduli():
    out = []
    for n, t, v in CONFIGS:
        for i, sp in enumerate(primes_mod.default_prime_set(n, t, v)):
            out.append(pytest.param(n, t, v, i, id=f"n{n}_t{t}_q{sp.q:#x}"))
    return out


MODULI = _registered_moduli()


def _segments_of(x: int, plan) -> jnp.ndarray:
    return jnp.asarray(
        np.array([bigint.int_to_limbs(x, plan.v, plan.seg_count)])
    )


class TestDecomposePerModulus:
    @pytest.mark.parametrize("n,t,v,i", MODULI)
    @settings(max_examples=15, deadline=None)
    @given(data=st.data())
    def test_decompose_channel_matches_bigint(self, n, t, v, i, data):
        """Both decompose datapaths (generic and SAU/Alg-2) must send a
        random x < q to x mod q_i on channel i."""
        plan = params_mod.make_params(n=n, t=t, v=v).plan
        x = data.draw(st.integers(min_value=0, max_value=plan.q - 1))
        seg = _segments_of(x, plan)
        qi = int(plan.qs[i])
        assert int(rns_mod.decompose_sau(seg, plan)[i, 0]) == x % qi
        assert int(rns_mod.decompose(seg, plan)[i, 0]) == x % qi

    @pytest.mark.parametrize("n,t,v,i", MODULI)
    @settings(max_examples=10, deadline=None)
    @given(data=st.data())
    def test_decompose_pallas_channel_matches_bigint(self, n, t, v, i, data):
        """The per-channel specialized Pallas circuit (interpret mode)
        agrees with the bigint ground truth on channel i."""
        plan = params_mod.make_params(n=n, t=t, v=v).plan
        x = data.draw(st.integers(min_value=0, max_value=plan.q - 1))
        seg = _segments_of(x, plan)
        res = crt_kernels.decompose_pallas(seg, plan=plan, interpret=True)
        assert int(res[i, 0]) == x % int(plan.qs[i])


class TestComposeRoundTripPerModulus:
    @pytest.mark.parametrize("n,t,v,i", MODULI)
    @settings(max_examples=15, deadline=None)
    @given(data=st.data())
    def test_compose_then_decompose_is_identity(self, n, t, v, i, data):
        """Random residues -> Eq-10 compose -> segments -> decompose must
        reproduce channel i exactly (CRT uniqueness, canonical range)."""
        plan = params_mod.make_params(n=n, t=t, v=v).plan
        residues = [
            data.draw(st.integers(min_value=0, max_value=int(q) - 1))
            for q in plan.qs
        ]
        r = jnp.asarray(np.array(residues, dtype=np.int64).reshape(plan.t, 1))
        limbs = rns_mod.compose(r, plan)
        x = bigint.limbs_to_int(np.asarray(limbs)[0], plan.w)
        assert 0 <= x < plan.q  # canonical: all t-1 cond-subs applied
        assert x % int(plan.qs[i]) == residues[i]
        back = rns_mod.decompose_sau(_segments_of(x, plan), plan)
        assert int(back[i, 0]) == residues[i]

    @pytest.mark.parametrize("n,t,v,i", MODULI)
    @settings(max_examples=10, deadline=None)
    @given(data=st.data())
    def test_compose_pallas_matches_bigint(self, n, t, v, i, data):
        """The Pallas compose kernel (interpret mode) recombines random
        residues to a value congruent on channel i and below q."""
        plan = params_mod.make_params(n=n, t=t, v=v).plan
        residues = [
            data.draw(st.integers(min_value=0, max_value=int(q) - 1))
            for q in plan.qs
        ]
        r = jnp.asarray(np.array(residues, dtype=np.int64).reshape(plan.t, 1))
        limbs = crt_kernels.compose_pallas(r, plan=plan, interpret=True)
        x = bigint.limbs_to_int(np.asarray(limbs)[0], plan.w)
        assert 0 <= x < plan.q
        assert x % int(plan.qs[i]) == residues[i]


class TestBatchedAgreement:
    @pytest.mark.parametrize("n,t,v", CONFIGS)
    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_pallas_pre_post_match_jnp_on_batches(self, n, t, v, data):
        """Kernel and jnp datapaths agree on whole random batches (the
        property the e2e bit-exactness gates sample only pointwise)."""
        plan = params_mod.make_params(n=n, t=t, v=v).plan
        seed = data.draw(st.integers(min_value=0, max_value=2**32 - 1))
        rng = np.random.default_rng(seed)
        z = jnp.asarray(
            rng.integers(0, 1 << v, size=(4, plan.seg_count), dtype=np.int64)
        )
        want = rns_mod.decompose_sau(z, plan)
        got = crt_kernels.decompose_pallas(z, plan=plan, interpret=True)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        limbs_want = rns_mod.compose(want, plan)
        limbs_got = crt_kernels.compose_pallas(got, plan=plan, interpret=True)
        assert np.array_equal(np.asarray(limbs_got), np.asarray(limbs_want))
