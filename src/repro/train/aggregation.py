"""Cross-pod gradient aggregation paths (distributed-optimization tricks):

1. ``compressed_psum``  — int8 stochastic-rounding gradient compression for
   the inter-pod hop (16x less ICI traffic than f32, 4x less than bf16);
   wraps a shard_map psum over the ``pod`` axis.
2. HE-secured aggregation — the paper's own motivating application [1]:
   gradients are quantized, packed into R_{n,q} plaintext polynomials,
   BFV-encrypted, summed *as ciphertexts* (the untrusted reducer never
   sees plaintext gradients), then decrypted by the trusted party.
   Every homomorphic op rides the PaReNTT multiplier.

At container scale these run on a 1-device mesh / host loop; the dry-run
exercises the multi-pod lowering of (1).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfv


# --------------------------------------------------------------------------
# int8 stochastic-rounding compression
# --------------------------------------------------------------------------


def quantize_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    """Per-tensor scale, stochastic rounding (unbiased)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    lo = jnp.floor(y)
    p = y - lo
    r = jax.random.uniform(key, x.shape)
    q = lo + (r < p).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, key, mesh, axis: str = "pod"):
    """All-reduce ``grads`` over ``axis`` with int8 payload.  Scales are
    reduced in f32 (tiny); values int32-summed after widening (sum of int8
    over <= 2^23 pods cannot overflow int32)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    leaves, treedef = jax.tree.flatten(grads)
    keys = list(jax.random.split(key, len(leaves)))

    def body(*leaves_in):
        out = []
        for leaf, k in zip(leaves_in, keys):
            q, s = quantize_int8(leaf, k)
            ssum = jax.lax.psum(s, axis)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            # unbiased mean: each pod's scale averaged; payload mean
            out.append((qsum.astype(jnp.float32) * (ssum / n) / n).astype(leaf.dtype))
        return tuple(out)

    specs = tuple(P() for _ in leaves)  # grads replicated over pod axis here
    fn = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs)
    return jax.tree.unflatten(treedef, list(fn(*leaves)))


# --------------------------------------------------------------------------
# HE-secured aggregation (BFV, PaReNTT-powered)
# --------------------------------------------------------------------------


class HeAggregator:
    """Packs flat gradients into BFV plaintexts and aggregates ciphertexts.

    Quantization: symmetric fixed-point with ``frac_bits``; the plaintext
    modulus must hold sum_i |q_i| < pt_mod/2 across workers."""

    def __init__(self, n: int = 1024, t: int = 3, v: int = 30,
                 pt_mod: int = 1 << 24, frac_bits: int = 12):
        self.ctx = bfv.make_context(n=n, t=t, v=v, pt_mod=pt_mod)
        self.frac = frac_bits
        self.n = n

    def keygen(self, key):
        return bfv.keygen(key, self.ctx)

    def _quantize(self, flat: np.ndarray) -> np.ndarray:
        q = np.round(flat * (1 << self.frac)).astype(np.int64)
        lim = self.ctx.pt_mod // 4
        return np.clip(q, -lim, lim)

    def _pack(self, qvals: np.ndarray) -> np.ndarray:
        pad = (-len(qvals)) % self.n
        qp = np.pad(qvals, (0, pad))
        # signed -> mod pt
        return (qp % self.ctx.pt_mod).reshape(-1, self.n)

    def encrypt_grads(self, key, flat: np.ndarray, keys) -> bfv.Ciphertext:
        polys = self._pack(self._quantize(flat))
        return bfv.encrypt(key, jnp.asarray(polys), keys, self.ctx)

    def aggregate(self, cts: Sequence[bfv.Ciphertext]) -> bfv.Ciphertext:
        """The untrusted-reducer step: ciphertext-only addition."""
        return bfv.add_many(list(cts), self.ctx)

    def decrypt_mean(self, ct, keys, num_workers: int, size: int) -> np.ndarray:
        dec = bfv.decrypt(ct, keys, self.ctx)  # (num_ct, n) in [0, pt)
        flat = np.asarray(dec).reshape(-1)[:size].astype(np.int64)
        half = self.ctx.pt_mod // 2
        signed = np.where(flat > half, flat - self.ctx.pt_mod, flat)
        return signed.astype(np.float64) / (1 << self.frac) / num_workers


def he_aggregate_gradients(agg: HeAggregator, worker_grads, key, keys):
    """Full round: each worker encrypts its flat gradient; the reducer sums
    ciphertexts; returns the decrypted mean.  worker_grads: list of
    same-structure pytrees."""
    flats = []
    for g in worker_grads:
        leaves = [np.asarray(x, dtype=np.float32).ravel() for x in jax.tree.leaves(g)]
        flats.append(np.concatenate(leaves))
    size = len(flats[0])
    cts = [
        agg.encrypt_grads(jax.random.fold_in(key, i), f, keys)
        for i, f in enumerate(flats)
    ]
    summed = agg.aggregate(cts)
    mean = agg.decrypt_mean(summed, keys, len(flats), size)
    # unflatten back into the gradient structure
    out_leaves = []
    off = 0
    ref_leaves, treedef = jax.tree.flatten(worker_grads[0])
    for ref in ref_leaves:
        k = int(np.prod(ref.shape)) if ref.ndim else 1
        out_leaves.append(
            jnp.asarray(mean[off : off + k].reshape(ref.shape), dtype=jnp.float32)
        )
        off += k
    return jax.tree.unflatten(treedef, out_leaves)
