"""Pallas kernels for RNS pre-processing (residual computation, Alg 1/2
with SAU strength reduction) and post-processing (inverse CRT, Eq 10).

Hardware mapping notes
----------------------
* Pre-processing: each RNS channel is its *own specialized circuit* in the
  paper (the SAU wiring is fixed by beta_i's signed-PoT terms).  We mirror
  that exactly: one pallas_call per channel with the shift/add network
  baked in statically — shifts and adds only, no integer multiplier, on
  the VPU int lanes.
* Post-processing: the (t -> limbs) recombination is a static einsum-like
  network: v-bit x w-bit limb products, a carry ripple (static L-step
  loop), and (t-1) conditional big-int subtractions.  No reduction over
  the wide modulus q ever materializes (Fig 16(b)).
* Both halves are factored as reusable *in-kernel stages*
  (:func:`decompose_stage`, :func:`compose_finalize`) so the fused
  end-to-end kernel in :mod:`repro.kernels.ntt` runs the identical
  circuits with the residues held in VMEM instead of round-tripping HBM
  between three pallas_calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import modmath
from repro.core import rns as rns_mod

BLK = 256  # coefficients per grid step


# --------------------------------------------------------------------------
# pre-processing (one specialized circuit per channel, SAU network static)
# --------------------------------------------------------------------------


def decompose_stage(z, ch: rns_mod.ChannelDecompose, *, seg_count: int,
                    t_prime: int):
    """In-kernel pre-processing stage for one RNS channel.

    z: (..., S) base-2^v segments -> residues (...) mod ``ch.qi``.  The
    SAU shift/add network and both Barrett constant sets arrive baked in
    ``ch`` (a :class:`repro.core.rns.ChannelDecompose` off the plan), so
    this traces to shifts, adds and the per-block v x v multiply only —
    usable both from the standalone per-channel ``pallas_call`` below
    and inside the fused e2e kernel, where the residues it produces stay
    VMEM-resident.
    """
    qi = ch.qi
    eps, s1, s2 = ch.sau_barrett
    epsa, sa1, sa2 = ch.acc_barrett
    n_blocks = -(-seg_count // t_prime)

    def sau(x):
        acc = -x
        for e, s in ch.beta_terms:
            acc = acc + s * (x << e)
        return acc

    def red(x):
        return modmath.barrett_reduce(x, qi, eps, s1, s2)

    acc = jnp.zeros(z.shape[:-1], dtype=z.dtype)
    for rho in range(n_blocks):
        blk = z[..., rho * t_prime]
        if t_prime > 1 and rho * t_prime + 1 < seg_count:
            blk = blk + sau(z[..., rho * t_prime + 1])
        for k in range(2, t_prime):
            if rho * t_prime + k >= seg_count:
                break
            x = red(sau(z[..., rho * t_prime + k]))
            for _ in range(k - 1):
                x = red(sau(x))
            blk = blk + x
        blk = red(blk)
        if rho == 0:
            acc = acc + blk
        else:
            acc = acc + (blk * ch.block_consts[rho]) % qi
    return modmath.barrett_reduce(acc, qi, epsa, sa1, sa2)


def decompose_stage_dyn(z, *, qi, sau_eps, sau_s2, acc_eps, beta_e, beta_s,
                        block_consts, v: int, seg_count: int, t_prime: int):
    """Data-driven twin of :func:`decompose_stage` for the channel-tiled
    e2e grid: per-channel constants arrive as traced scalars/vectors
    (read from channel-indexed blocks) instead of python ints baked into
    the closure, so ONE kernel body serves every RNS channel.

    The SAU network becomes ``sum_k beta_s[k] * (x << beta_e[k]) - x``
    with zero-signed padding entries contributing nothing; the only
    per-channel Barrett shift that varies (s2 of the SAU window, v1 + 4)
    is applied as a traced scalar shift.  Bit-identical to the
    specialized circuits — asserted by the backend tests."""
    s1 = v - 1

    def sau(x):
        return (beta_s * (x[..., None] << beta_e)).sum(axis=-1) - x

    def red(x):
        return modmath.barrett_reduce(x, qi, sau_eps, s1, sau_s2)

    n_blocks = -(-seg_count // t_prime)
    acc = jnp.zeros(z.shape[:-1], dtype=z.dtype)
    for rho in range(n_blocks):
        blk = z[..., rho * t_prime]
        if t_prime > 1 and rho * t_prime + 1 < seg_count:
            blk = blk + sau(z[..., rho * t_prime + 1])
        for k in range(2, t_prime):
            if rho * t_prime + k >= seg_count:
                break
            x = red(sau(z[..., rho * t_prime + k]))
            for _ in range(k - 1):
                x = red(sau(x))
            blk = blk + x
        blk = red(blk)
        if rho == 0:
            acc = acc + blk
        else:
            acc = acc + (blk * block_consts[rho]) % qi
    # accumulator window is c = v + 3 for every channel => s2 = 4 static
    return modmath.barrett_reduce(acc, qi, acc_eps, s1, 4)


@functools.lru_cache(maxsize=None)
def plan_dec_arrays(plan: rns_mod.RnsPlan) -> dict:
    """Stacked (t, ...) numpy views of ``plan.dec`` for the channel-tiled
    e2e grid (one row per channel, SAU terms zero-padded to the widest
    channel).  Cached per plan object (plans hash by identity)."""
    dec = require_dec(plan)
    t = plan.t
    t_max = max(len(c.beta_terms) for c in dec)
    beta_e = np.zeros((t, t_max), dtype=np.int64)
    beta_s = np.zeros((t, t_max), dtype=np.int64)
    for i, c in enumerate(dec):
        for j, (e, s) in enumerate(c.beta_terms):
            beta_e[i, j] = e
            beta_s[i, j] = s
    return {
        "sau_eps": np.array([c.sau_barrett[0] for c in dec], dtype=np.int64),
        "sau_s2": np.array([c.sau_barrett[2] for c in dec], dtype=np.int64),
        "acc_eps": np.array([c.acc_barrett[0] for c in dec], dtype=np.int64),
        "beta_e": beta_e,
        "beta_s": beta_s,
        "block_consts": np.array(
            [c.block_consts for c in dec], dtype=np.int64
        ),
    }


def require_dec(plan: rns_mod.RnsPlan):
    """The shared guard for every kernel needing in-kernel decompose
    constants (standalone decompose and the fused e2e kernel)."""
    if plan.dec is None:
        raise ValueError(
            f"plan (v={plan.v}) has no in-kernel decompose constants: the "
            "int64 Pallas datapaths require v <= 31 and SAU words inside "
            "the 63-bit-safe Barrett window (2*(v1 + 4) <= 63)"
        )
    return plan.dec


def _make_decompose_kernel(ch: rns_mod.ChannelDecompose, seg_count: int,
                           t_prime: int):
    """Kernel closure with the channel's SAU circuit baked in."""

    def kernel(z_ref, o_ref):
        o_ref[...] = decompose_stage(
            z_ref[...], ch, seg_count=seg_count, t_prime=t_prime
        )

    return kernel


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def decompose_pallas(z, *, plan: rns_mod.RnsPlan, interpret: bool = True):
    """z: (rows, S) segments -> residues (t, rows).  One specialized
    pallas_call per RNS channel (= per hardware circuit)."""
    rows, S = z.shape
    dec = require_dec(plan)
    pad = (-rows) % BLK
    zp = jnp.pad(z, ((0, pad), (0, 0))) if pad else z
    outs = []
    for i in range(plan.t):
        kern = _make_decompose_kernel(dec[i], plan.seg_count, plan.t_prime)
        out = pl.pallas_call(
            kern,
            grid=(zp.shape[0] // BLK,),
            in_specs=[pl.BlockSpec((BLK, S), lambda r: (r, 0))],
            out_specs=pl.BlockSpec((BLK,), lambda r: (r,)),
            out_shape=jax.ShapeDtypeStruct((zp.shape[0],), z.dtype),
            interpret=interpret,
        )(zp)
        outs.append(out[:rows])
    return jnp.stack(outs)


# --------------------------------------------------------------------------
# post-processing (Eq 10)
# --------------------------------------------------------------------------


def compose_finalize(acc, q_limbs, *, w: int, t: int):
    """In-kernel post-processing tail: raw limb-product sums -> canonical
    base-2^w limbs of the composed value mod q.

    acc: (..., L) per-limb accumulations of ``y_i * q_i^`` products (each
    < t * 2^{v+w}); q_limbs: (L,).  Static carry ripple followed by the
    (t-1) conditional big-int subtractions of Fig 16(b) — no reduction
    over the wide q ever materializes.  Shared by the standalone compose
    ``pallas_call`` and the fused e2e kernel.
    """
    L = acc.shape[-1]
    mask = (1 << w) - 1
    # carry ripple (static)
    outs = []
    carry = jnp.zeros_like(acc[..., 0])
    for i in range(L):
        s = acc[..., i] + carry
        outs.append(s & mask)
        carry = s >> w
    acc = jnp.stack(outs, axis=-1)
    # (t-1) conditional big-int subtractions of q
    for _ in range(t - 1):
        ge = jnp.ones(acc.shape[:-1], dtype=bool)
        decided = jnp.zeros(acc.shape[:-1], dtype=bool)
        for i in range(L - 1, -1, -1):
            gt = acc[..., i] > q_limbs[i]
            lt = acc[..., i] < q_limbs[i]
            ge = jnp.where(~decided & gt, True, ge)
            ge = jnp.where(~decided & lt, False, ge)
            decided = decided | gt | lt
        borrow = jnp.zeros_like(acc[..., 0])
        subbed = []
        for i in range(L):
            d = acc[..., i] - q_limbs[i] - borrow
            neg = d < 0
            subbed.append(jnp.where(neg, d + (1 << w), d))
            borrow = neg.astype(acc.dtype)
        sub = jnp.stack(subbed, axis=-1)
        acc = jnp.where(ge[..., None], sub, acc)
    return acc


def _make_compose_kernel(plan: rns_mod.RnsPlan):
    t, w = plan.t, plan.w

    def kernel(res_ref, qs_ref, tilde_ref, star_ref, qlimb_ref, o_ref):
        res = res_ref[...]  # (t, blk)
        tilde = tilde_ref[...]  # (t, 1)
        star = star_ref[...]  # (t, L)
        qs = qs_ref[...]  # (t, 1)
        y = (res * tilde) % qs  # (t, blk)
        contrib = y[:, :, None] * star[:, None, :]  # (t, blk, L)
        acc = contrib.sum(axis=0)  # (blk, L)
        o_ref[...] = compose_finalize(acc, qlimb_ref[0], w=w, t=t)

    return kernel


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def compose_pallas(residues, *, plan: rns_mod.RnsPlan, qs=None, qi_tilde=None,
                   star=None, q_limbs=None, interpret: bool = True):
    """residues: (t, rows) -> limbs (rows, L) of the composed value mod q.

    The CRT table operands default to the plan's own device uploads;
    the ops layer overrides them with a Plan's pytree leaves so that
    ``device_put``/sharding of the leaves redirects this kernel too
    (``plan`` itself stays jit-static — circuit structure only)."""
    t, rows = residues.shape
    L = plan.L
    qs = plan.qs_d if qs is None else qs
    qi_tilde = plan.qi_tilde_d if qi_tilde is None else qi_tilde
    star = plan.qi_star_limbs_d if star is None else star
    q_limbs = plan.q_limbs_d if q_limbs is None else q_limbs
    pad = (-rows) % BLK
    rp = jnp.pad(residues, ((0, 0), (0, pad))) if pad else residues
    kern = _make_compose_kernel(plan)
    out = pl.pallas_call(
        kern,
        grid=(rp.shape[1] // BLK,),
        in_specs=[
            pl.BlockSpec((t, BLK), lambda r: (0, r)),
            pl.BlockSpec((t, 1), lambda r: (0, 0)),
            pl.BlockSpec((t, 1), lambda r: (0, 0)),
            pl.BlockSpec((t, L), lambda r: (0, 0)),
            pl.BlockSpec((1, L), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLK, L), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rp.shape[1], L), residues.dtype),
        interpret=interpret,
    )(
        rp,
        qs.reshape(t, 1),
        qi_tilde.reshape(t, 1),
        star,
        q_limbs.reshape(1, L),
    )
    return out[:rows]
