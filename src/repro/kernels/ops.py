"""jit'd public wrappers for the Pallas kernels with a pure-jnp fallback.

`use_pallas=True` (default) runs the kernels in interpret mode on CPU and
compiled mode on TPU; `use_pallas=False` routes to the ref oracles (used
by the dry-run lowering, where interpret-mode python loops would bloat
the HLO on the 512-device mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ntt as ntt_mod
from repro.core.params import ParenttParams
from repro.kernels import crt as crt_kernels
from repro.kernels import ntt as ntt_kernels
from repro.kernels import ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ntt_forward(a, params: ParenttParams, *, use_pallas: bool = True):
    """a: (t, rows, n) -> NTT per RNS channel."""
    ct = params.tables
    if use_pallas:
        return ntt_kernels.ntt_channels_pallas(
            a, jnp.asarray(ct.qs), jnp.asarray(ct.fwd), interpret=not _is_tpu()
        )
    return ntt_mod.ntt_channels(a, ct)


def ntt_inverse(a, params: ParenttParams, *, use_pallas: bool = True):
    ct = params.tables
    if use_pallas:
        return ntt_kernels.intt_channels_pallas(
            a,
            jnp.asarray(ct.qs),
            jnp.asarray(ct.half),
            jnp.asarray(ct.inv),
            interpret=not _is_tpu(),
        )
    return ntt_mod.intt_channels(a, ct)


def negacyclic_mul(a, b, params: ParenttParams, *, use_pallas: bool = True):
    """(t, rows, n) x (t, rows, n): the fused no-shuffle cascade."""
    ct = params.tables
    if use_pallas:
        return ntt_kernels.fused_polymul_pallas(
            a,
            b,
            jnp.asarray(ct.qs),
            jnp.asarray(ct.half),
            jnp.asarray(ct.fwd),
            jnp.asarray(ct.inv),
            interpret=not _is_tpu(),
        )
    return ntt_mod.negacyclic_mul_channels(a, b, ct)


def rns_decompose(z, params: ParenttParams, *, use_pallas: bool = True):
    """z: (rows, S) -> (t, rows)."""
    if use_pallas:
        return crt_kernels.decompose_pallas(
            z, plan=params.plan, interpret=not _is_tpu()
        )
    from repro.core import rns as rns_mod

    return rns_mod.decompose_sau(z, params.plan)


def rns_compose(residues, params: ParenttParams, *, use_pallas: bool = True):
    """(t, rows) -> (rows, L)."""
    if use_pallas:
        return crt_kernels.compose_pallas(
            residues, plan=params.plan, interpret=not _is_tpu()
        )
    from repro.core import rns as rns_mod

    return rns_mod.compose(residues, params.plan)
