"""Single source of truth for modular scalar arithmetic (int64 lanes).

Both datapaths import from here — the pure-jnp reference oracle
(:mod:`repro.core.ntt`, :mod:`repro.core.rns`) and the Pallas kernels
(:mod:`repro.kernels.ntt`, :mod:`repro.kernels.crt`) — so the oracle the
kernels are validated against can never drift from the kernel math.

Two reduction strategies for the butterfly multiply:

* generic ``%`` — correct for any modulus, but lowers to an integer
  divide on every butterfly (the hot-loop cost the paper's Barrett PEs
  exist to avoid);
* precomputed Barrett — ``eps = floor(2^(2b) / q)`` per channel (b =
  bit-length of q), shift/multiply/3-conditional-subtract.  Valid for
  products ``x*y`` with ``x, y < q < 2^31`` and requires
  ``2*(b+1) <= 63`` (b <= 30, the paper's preferred v=30 operating
  point).  The (s1, s2) shift pair is static per configuration; only
  ``eps`` varies per RNS channel, so the same vectorized code serves all
  t channels.

Every helper accepts scalars or broadcastable arrays for ``q`` / ``eps``
so one implementation serves single-modulus, vmapped multi-channel, and
in-kernel (Pallas ref-value) call sites.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

# Every helper accepts python ints, numpy arrays, or traced jnp arrays
# interchangeably (scalar/vmapped/in-kernel call sites) — that union has
# no precise static type, so the lane-value alias is Any by design.
Lanes = Any

# --------------------------------------------------------------------------
# add / sub / halve
# --------------------------------------------------------------------------


def add_mod(x: Lanes, y: Lanes, q: Lanes) -> Lanes:
    """(x + y) mod q for x, y in [0, q)."""
    s = x + y
    return jnp.where(s >= q, s - q, s)


def sub_mod(x: Lanes, y: Lanes, q: Lanes) -> Lanes:
    """(x - y) mod q for x, y in [0, q)."""
    d = x - y
    return jnp.where(d < 0, d + q, d)


def div2_mod(x: Lanes, q_half: Lanes) -> Lanes:
    """x * 2^{-1} mod q via paper Eq 24: (x >> 1) + (x & 1) * (q+1)/2.
    Result < q whenever x < q (no reduction needed)."""
    return (x >> 1) + (x & 1) * q_half


# --------------------------------------------------------------------------
# Barrett reduction
# --------------------------------------------------------------------------


def barrett_constants(q: int, c: int, v: int) -> tuple[int, int, int]:
    """Constants for reducing x < 2^c mod q (q of v bits), 63-bit safe.

    q_hat = ((x >> (v-1)) * eps) >> (c - v + 1),  eps = floor(2^c / q).
    Requires 2*(c - v + 1) <= 63.  Quotient undershoots by < 4 =>
    three conditional subtractions complete the reduction.
    """
    assert 2 * (c - v + 1) <= 63, (q, c, v)
    eps = (1 << c) // q
    return eps, v - 1, c - v + 1


def barrett_reduce(x: Lanes, q: Lanes, eps: Lanes, s1: int, s2: int) -> Lanes:
    """x mod q for x < 2^c (see barrett_constants). Arrays or scalars."""
    qhat = ((x >> s1) * eps) >> s2
    r = x - qhat * q
    for _ in range(3):
        r = jnp.where(r >= q, r - q, r)
    return r


def mul_barrett_constants(
    qs: Lanes,
) -> tuple[np.ndarray, tuple[int, int]] | tuple[None, None]:
    """Per-channel constants for reducing residue products x*y, x, y < q_i.

    Returns ``(eps, (s1, s2))`` with ``eps`` an int64 array aligned with
    ``qs`` and one static shift pair shared by all channels, or
    ``(None, None)`` when the configuration is outside the 63-bit-safe
    envelope (mixed modulus widths, or q >= 2^31 — those paths keep the
    generic ``%``).
    """
    qs = np.atleast_1d(np.asarray(qs, dtype=np.int64))
    widths = {int(q).bit_length() for q in qs}
    if len(widths) != 1:
        return None, None
    b = widths.pop()
    c = 2 * b
    if 2 * (c - b + 1) > 63:
        return None, None
    eps = np.array([(1 << c) // int(q) for q in qs], dtype=np.int64)
    return eps, (b - 1, b + 1)


def channel_mul_constants(
    qs: Lanes,
) -> tuple[tuple[tuple[int, int, int | None], ...], tuple[int, int] | None]:
    """Static per-channel ``(qi, half, eps)`` triples plus the shared
    shift pair, as plain python ints.

    This is the scalar layout kernels that specialize per channel bake
    into their closures (one circuit per RNS channel, paper-style): the
    fused e2e kernel unrolls its channel loop over these, so no scalar
    SMEM blocks are needed.  ``eps`` entries are None outside the
    63-bit-safe Barrett envelope (the butterflies then fall back to
    generic ``%``).
    """
    eps, shifts = mul_barrett_constants(qs)
    qs = np.atleast_1d(np.asarray(qs, dtype=np.int64))
    triples = tuple(
        (int(q), (int(q) + 1) // 2, None if eps is None else int(eps[i]))
        for i, q in enumerate(qs)
    )
    return triples, shifts


def mul_mod(
    x: Lanes, y: Lanes, q: Lanes, eps: Lanes = None, shifts: tuple[int, int] | None = None
) -> Lanes:
    """(x * y) mod q for x, y in [0, q).

    With ``eps``/``shifts`` (from :func:`mul_barrett_constants`,
    broadcastable against x*y) the reduction is the paper's Barrett PE;
    without them it falls back to a generic ``%``.
    """
    p = x * y
    if eps is None or shifts is None:
        return p % q
    s1, s2 = shifts
    return barrett_reduce(p, q, eps, s1, s2)


# --------------------------------------------------------------------------
# Harvey-style lazy reduction (Shoup multiplication, deferred canonicalize)
#
# The strict butterfly above pays 5 conditional subtractions (jnp.where
# chains) per stage: 3 in the Barrett reduce, 1 each in add_mod/sub_mod.
# The lazy butterflies keep values in a window [0, W*q) with W = 2 or 4
# and reduce the twiddle product with a precomputed Shoup constant
#     w' = floor(w * 2^beta / q)   (per twiddle, host-side)
#     shoup_mul(v, w) = v*w - (v*w' >> beta) * q   in  [0, 2q)
# which needs NO conditional subtraction at all.  Only 1-2 window
# subtractions remain per stage, plus ONE canonicalizing reduce at
# transform (or cascade) exit — the O(1)-per-transform reduce the issue
# asks for.  63-bit-safe windows on int64 lanes (b = bit_length(q)):
#
#   b <= 29:  W = 4, beta = b + 2   (Harvey's original window; 1 where
#             per CT butterfly)
#   b == 30:  W = 2, beta = 32      (the paper's v=30 point; v*w' peaks
#             at 2^(31+32) < 2^63; 2 wheres per CT butterfly)
#   else:     lazy unavailable — strict butterflies only.
# --------------------------------------------------------------------------

STRICT_SELECTS_PER_STAGE = 5  # Barrett 3 + add_mod 1 + sub_mod 1


def lazy_params(qs: Lanes) -> tuple[int, int] | tuple[None, None]:
    """(window, beta) for the lazy butterflies, or (None, None) when the
    configuration is outside the 63-bit-safe envelope (mixed widths or
    q >= 2^31 — exactly the configurations strict Barrett also rejects)."""
    qs = np.atleast_1d(np.asarray(qs, dtype=np.int64))
    widths = {int(q).bit_length() for q in qs}
    if len(widths) != 1:
        return None, None
    b = widths.pop()
    if b <= 29:
        return 4, b + 2
    if b == 30:
        return 2, 32
    return None, None


def validate_lazy_envelope(q: int, window: int, beta: int) -> None:
    """Proof obligations of the lazy window, checked once per table set
    (the per-stage bound bookkeeping ChannelTables bakes in).

    * every butterfly value stays < window*q and window*q <= 2^beta, so
      Shoup operands are always in range;
    * the Shoup product v*w' (v < window*q, w' < 2^beta) fits 63 bits;
    * the in-stage peak (u + t, resp. u + v before the window subtract)
      fits 63 bits trivially alongside it.
    """
    if window not in (2, 4):
        raise ValueError(f"lazy window must be 2 or 4, got {window}")
    b = int(q).bit_length()
    if window * q > 1 << beta:
        raise ValueError(
            f"lazy window overflows the Shoup operand range: "
            f"window*q = {window * q} > 2^{beta}"
        )
    if b + (window.bit_length() - 1) + beta > 63:
        raise ValueError(
            f"Shoup product v*w' exceeds 63 bits: b={b}, window={window}, "
            f"beta={beta}"
        )


def lazy_stage_bounds(
    window: int, n_stages: int, inverse: bool = False
) -> tuple[tuple[int, int], ...]:
    """(value_bound, in_stage_peak) per stage, in units of q.  The
    butterflies below maintain value_bound = window across every stage;
    the peak is the transient before the window subtract (CT: u + t <
    window*q + 2q; GS: u + v < 2*window*q).  Baked into ChannelTables so
    the invariant the kernels rely on is recorded next to the tables it
    governs, and testable stage by stage."""
    peak = 2 * window if inverse else window + 2
    return tuple((window, peak) for _ in range(n_stages))


def lazy_selects_per_stage(window: int, inverse: bool = False) -> int:
    """Conditional subtractions (jnp.where -> select_n) one lazy butterfly
    stage traces to — the unit of the ``reduction_ops`` cost model."""
    if inverse:
        return 2  # sum + difference window subtracts (both windows)
    return 1 if window == 4 else 2


def canonicalize_selects(window: int) -> int:
    return 1 if window == 2 else 2


def shoup_constants(table: Lanes, q: int, beta: int) -> np.ndarray:
    """w' = floor(w * 2^beta / q) per twiddle (host bigints, any shape)."""
    tab = np.asarray(table, dtype=np.int64)
    flat = [((int(w) << beta) // int(q)) for w in tab.reshape(-1)]
    return np.array(flat, dtype=np.int64).reshape(tab.shape)


def cond_sub(x: Lanes, m: Lanes) -> Lanes:
    """x - m if x >= m else x: ONE conditional (window) subtraction."""
    return jnp.where(x >= m, x - m, x)


def shoup_mul(v: Lanes, w: Lanes, w_shoup: Lanes, q: Lanes, beta: int) -> Lanes:
    """v * w mod q up to one extra q: output in [0, 2q), no conditional
    subtraction.  Requires v <= 2^beta and w in [0, q) canonical (w is a
    precomputed twiddle; w_shoup its Shoup constant)."""
    return v * w - ((v * w_shoup) >> beta) * q


def lazy_ct_butterfly(
    u: Lanes, v: Lanes, w: Lanes, w_shoup: Lanes, q: Lanes, *, beta: int, window: int
) -> tuple[Lanes, Lanes]:
    """DIT/CT butterfly keeping both outputs in [0, window*q).

    window=4: 1 conditional subtraction (vs 5 strict); window=2: 2."""
    t = shoup_mul(v, w, w_shoup, q, beta)  # [0, 2q)
    if window == 4:
        u = cond_sub(u, 2 * q)  # [0, 2q)
        return u + t, u - t + 2 * q  # both [0, 4q)
    x = cond_sub(u + t, 2 * q)
    y = cond_sub(u - t + 2 * q, 2 * q)
    return x, y


def lazy_gs_butterfly(
    u: Lanes, v: Lanes, w: Lanes, w_shoup: Lanes, q: Lanes, half: Lanes, *, beta: int, window: int
) -> tuple[Lanes, Lanes]:
    """Mirror-order GS butterfly with the Eq-24 halving folded in; values
    stay in [0, window*q).  2 conditional subtractions either window."""
    wq = window * q
    s = cond_sub(u + v, wq)  # [0, window*q)
    d = cond_sub(u - v + wq, wq)
    d = shoup_mul(d, w, w_shoup, q, beta)  # [0, 2q) subset of window
    return div2_mod(s, half), div2_mod(d, half)


def canonicalize(x: Lanes, q: Lanes, window: int) -> Lanes:
    """[0, window*q) -> [0, q): the single exit reduce of a lazy
    transform (O(1) selects per transform instead of O(log n))."""
    if window == 4:
        x = cond_sub(x, 2 * q)
    return cond_sub(x, q)
