"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared transformer block
(attention + MLP, weights reused) applied every `shared_attn_every` layers
(arXiv:2411.15242).  Simplification noted in DESIGN.md: the concatenated
embedding re-injection and per-application LoRA deltas of the original are
omitted; the shared block is applied residually at each interval.

Scan structure: groups of (shared_attn_every) mamba layers form one scan
step; the shared block runs between groups with its own KV-cache slot per
application.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as tfm


def _groups(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


def init_params(key, cfg: ModelConfig):
    k_embed, k_m, k_shared, k_head = jax.random.split(key, 4)
    keys = jax.random.split(k_m, cfg.n_layers)
    mamba_layers = jax.vmap(
        lambda k: {"ln": L.rmsnorm_init(cfg.d_model), "mixer": ssm.mamba2_init(k, cfg)}
    )(keys)
    shared = tfm.block_init(k_shared, cfg, moe=False)
    p = {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), scale=0.02),
        "mamba_layers": mamba_layers,
        "shared": shared,
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab)),
    }
    return p


def _mamba_block(lp, x, cfg, ssm_state=None, conv_state=None):
    h, states = ssm.mamba2_apply(
        lp["mixer"], L.rmsnorm(lp["ln"], x, cfg.norm_eps), cfg,
        ssm_state=ssm_state, conv_state=conv_state,
    )
    return x + h, states


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            last_only: bool = False):
    x = tfm.embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    G = _groups(cfg)
    k = cfg.shared_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape((G, k) + a.shape[1:]), params["mamba_layers"]
    )

    def body(x, group_params):
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], group_params)
            x, _ = _mamba_block(lp, x, cfg)
        x, _ = tfm.block_apply(params["shared"], x, cfg, positions, moe=False)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, grouped)
    if last_only:
        x = x[:, -1:]
    return tfm.unembed(params, cfg, x)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    d_in, H, P, N = ssm.dims(cfg)
    G = _groups(cfg)
    conv_dim = d_in + 2 * N
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim), L.CDTYPE),
        "k": jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), L.CDTYPE),
        "v": jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), L.CDTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, batch):
    x = tfm.embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    pos = cache["pos"]
    positions = pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    G = _groups(cfg)
    k = cfg.shared_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape((G, k) + a.shape[1:]), params["mamba_layers"]
    )
    ssm_g = cache["ssm"].reshape((G, k) + cache["ssm"].shape[1:])
    conv_g = cache["conv"].reshape((G, k) + cache["conv"].shape[1:])

    def body(x, inp):
        gp, s_states, c_states, ck, cv = inp
        new_s, new_c = [], []
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], gp)
            x, (hs, hc) = _mamba_block(lp, x, cfg, ssm_state=s_states[i], conv_state=c_states[i])
            new_s.append(hs)
            new_c.append(hc)
        x, nc = tfm.block_apply(
            params["shared"], x, cfg, positions, moe=False,
            cache={"k": ck, "v": cv, "pos": pos},
        )
        return x, (jnp.stack(new_s), jnp.stack(new_c), nc["k"], nc["v"])

    x, (ns, ncv, nk, nv) = jax.lax.scan(body, x, (grouped, ssm_g, conv_g, cache["k"], cache["v"]))
    new_cache = {
        "ssm": ns.reshape(cache["ssm"].shape),
        "conv": ncv.reshape(cache["conv"].shape),
        "k": nk,
        "v": nv,
        "pos": pos + S,
    }
    return tfm.unembed(params, cfg, x), new_cache
