"""Verifier passes: concrete table integrity, constant seeding/tagging,
envelope comparison, lane/VMEM lint, staticness lint.

The abstract interpreter only trusts host constants that were verified
*concretely* here, once per plan: twiddles canonical per channel, Shoup
companions exactly ``(w << beta) // q``, Barrett ``eps`` exactly
``floor(2^c / q)`` per family, SAU signed-PoT terms summing to
``beta_i + 1``.  Each verified array is entered in a registry; when the
traced jaxpr closes over it (matched by identity, then by equality),
its abstraction carries the corresponding tag, which is what arms the
Shoup/Barrett pattern transfers in :mod:`repro.analysis.interp`.
A mutated table therefore fails twice: the integrity check reports the
corrupt entry, and the untagged constant disarms the semantic transfer
so the interval blow-up surfaces as overflow/precondition findings —
the analyzer cannot silently go vacuous.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import walk
from repro.analysis.domain import AbsVal, QCtx
from repro.analysis.interp import AnalysisContext, Finding
from repro.core.schedule import VMEM_BUDGET_BYTES  # per-core VMEM (pallas guide)

SMALL_CONST_ELEMS = 64  # <= this many elements: per-channel circuit scalars


# --------------------------------------------------------------------------
# registry + integrity
# --------------------------------------------------------------------------


class ConstRegistry:
    """Maps concrete host/device constants to tagged abstractions."""

    def __init__(self) -> None:
        self._by_id: Dict[int, AbsVal] = {}
        self._entries: List[Tuple[np.ndarray, AbsVal]] = []
        self.leaf_ids: Dict[int, str] = {}
        self.leaf_arrays: List[Tuple[str, np.ndarray]] = []

    def add(self, arr: Any, proto: AbsVal) -> None:
        if arr is None:
            return
        self._by_id[id(arr)] = proto
        self._entries.append((np.asarray(arr), proto))

    def add_leaf(self, name: str, arr: Any) -> None:
        if arr is None:
            return
        self.leaf_ids[id(arr)] = name
        self.leaf_arrays.append((name, np.asarray(arr)))

    def seed(self, const: Any) -> AbsVal:
        proto = self._by_id.get(id(const))
        if proto is not None:
            return proto.view()
        try:
            arr = np.asarray(const)
        except (TypeError, ValueError):
            return AbsVal(None, None)
        if arr.dtype == np.bool_:
            return AbsVal(0, 1)
        if not np.issubdtype(arr.dtype, np.integer) or arr.size == 0:
            return AbsVal(None, None)
        for known, proto in self._entries:
            if known.shape == arr.shape and known.dtype == arr.dtype and np.array_equal(
                known, arr
            ):
                return proto.view()
        # Unregistered integer constant: concrete values are still known,
        # so its exact min/max is a sound (untagged) abstraction; small
        # arrays also keep their concrete values for weighted-sum bounds.
        av = AbsVal(int(arr.min()), int(arr.max()))
        if arr.size <= 65536:
            av.prov = ("carr", arr)
        return av


def _tagged(
    arr: Any,
    tag: Optional[Tuple[Any, ...]],
    qctx: QCtx,
    qlin: Optional[Tuple[Fraction, Fraction]] = None,
    qlo: Optional[Tuple[Fraction, Fraction]] = None,
) -> AbsVal:
    a = np.asarray(arr)
    av = AbsVal(int(a.min()), int(a.max()), tag=tag)
    if qlin is not None:
        av = av.with_qlin(qlin[0], qlin[1], qctx)
    if qlo is not None:
        av = av.with_qlo(qlo[0], qlo[1], qctx)
    av.tag = tag
    return av


def build_context(pl: Any, *, grid_cap: int = 64) -> AnalysisContext:
    """Concrete integrity pass + tagged-constant registry for one Plan.

    Any integrity violation lands as an ``error`` finding on the
    returned context (and the corresponding tag is withheld, so the
    traced-code analysis independently degrades to 'could not prove').
    """
    params = pl.params
    rns = params.plan
    qs = [int(q) for q in np.asarray(rns.qs)]
    qctx = QCtx(min(qs), max(qs))
    ct = params.tables
    beta = int(ct.shoup_beta) if ct is not None and ct.shoup_beta is not None else None
    registry = ConstRegistry()
    families: Dict[Tuple[Any, ...], Dict[str, Any]] = {}
    ctx = AnalysisContext(
        qctx=qctx,
        beta=beta,
        q_set=frozenset(qs),
        families=families,
        seed_const=registry.seed,
        grid_cap=grid_cap,
    )
    ctx.registry = registry

    def bad(msg: str) -> None:
        ctx.finding("error", "table-integrity", "plan", msg)

    qs_arr = np.asarray(rns.qs)
    q_col = qs_arr.reshape((len(qs),) + (1,) * 0)

    # channel moduli ---------------------------------------------------
    for host, dev in ((rns.qs, getattr(rns, "qs_d", None)),):
        for obj in (host, dev):
            registry.add(
                obj,
                _tagged(
                    qs_arr, ("q",), qctx,
                    (Fraction(1), Fraction(0)), (Fraction(1), Fraction(0)),
                ),
            )
    v = int(params.v)
    for q in qs:
        if not (1 << (v - 1)) < q < (1 << v):
            bad(f"modulus {q} is not a {v}-bit prime")
        if q % 2 == 0:
            bad(f"modulus {q} is even")

    # NTT twiddle tables + Shoup companions ----------------------------
    if ct is not None:
        half = np.asarray(ct.half)
        if not np.array_equal(half, (qs_arr + 1) // 2):
            bad("half table != (q+1)/2")
        else:
            for obj in (ct.half, getattr(ct, "half_d", None)):
                registry.add(
                    obj,
                    _tagged(
                        half, ("half",), qctx,
                        (Fraction(1, 2), Fraction(1, 2)),
                        (Fraction(1, 2), Fraction(1, 2)),
                    ),
                )
        if ct.lazy_window is not None:
            try:
                from repro.core import modmath

                for q in qs:
                    modmath.validate_lazy_envelope(q, int(ct.lazy_window), int(beta))
            except ValueError as e:
                bad(f"lazy envelope invalid: {e}")
        # Scalar-per-direction tables, then the per-level hierarchical
        # sub-row tables (tuple-valued attrs, one entry per sub level).
        named: List[Tuple[str, Any, Any, Any, Any]] = []
        for name in ("fwd", "inv", "fs_row_fwd", "fs_row_inv"):
            named.append((
                name,
                getattr(ct, name, None),
                getattr(ct, name + "_d", None),
                getattr(ct, name + "_shoup", None),
                getattr(ct, name + "_shoup_d", None),
            ))
        for name in ("fs_sub_fwd", "fs_sub_inv"):
            tabs = getattr(ct, name, None) or ()
            devs = getattr(ct, name + "_d", None) or ()
            shs = getattr(ct, name + "_shoup", None) or ()
            shds = getattr(ct, name + "_shoup_d", None) or ()
            for lvl in range(len(tabs)):
                named.append((
                    f"{name}[{lvl}]",
                    tabs[lvl],
                    devs[lvl] if lvl < len(devs) else None,
                    shs[lvl] if lvl < len(shs) else None,
                    shds[lvl] if lvl < len(shds) else None,
                ))
        for name, host, dev, sh, sh_dev in named:
            if host is None:
                continue
            w = np.asarray(host)
            qb = qs_arr.reshape((len(qs),) + (1,) * (w.ndim - 1))
            if not bool(np.all((w >= 0) & (w < qb))):
                bad(f"twiddle table '{name}' has non-canonical entries")
                continue
            twid = _tagged(
                w, ("twiddle", name), qctx,
                (Fraction(1), Fraction(-1)), (Fraction(0), Fraction(0)),
            )
            for obj in (host, dev):
                registry.add(obj, twid)
            if sh is not None and beta is not None:
                sh_np = np.asarray(sh)
                expect = (w.astype(object) << beta) // qb.astype(object)
                if not bool(np.all(sh_np.astype(object) == expect)):
                    bad(f"Shoup table '{name}_shoup' != (w << beta) // q")
                    continue
                proto = _tagged(sh_np, ("shoup", name), qctx)
                for obj in (sh, sh_dev):
                    registry.add(obj, proto)

        # strict-mode / pointwise Barrett family ------------------------
        if getattr(ct, "mul_eps", None) is not None and ct.mul_shifts is not None:
            eps = np.asarray(ct.mul_eps)
            s1, s2 = (int(s) for s in ct.mul_shifts)
            ok = all(
                int(eps[i]) == (1 << (s1 + s2)) // qs[i]
                and s1 == qs[i].bit_length() - 1
                for i in range(len(qs))
            )
            if not ok:
                bad("mul_eps != floor(2^c / q) for its (s1, s2) window")
            else:
                families[("brt", "mulmod")] = {"s1": s1, "s2_lo": s2, "s2_hi": s2}
                proto = _tagged(eps, ("brt", "mulmod"), qctx)
                for obj in (ct.mul_eps, getattr(ct, "mul_eps_d", None)):
                    registry.add(obj, proto)

    # decompose (SAU) / compose constants ------------------------------
    registry.add(
        rns.qi_tilde,
        _tagged(
            rns.qi_tilde, None, qctx,
            (Fraction(1), Fraction(-1)), (Fraction(0), Fraction(0)),
        ),
    )
    registry.add(
        getattr(rns, "qi_tilde_d", None),
        _tagged(
            rns.qi_tilde, None, qctx,
            (Fraction(1), Fraction(-1)), (Fraction(0), Fraction(0)),
        ),
    )
    if not bool(np.all(np.asarray(rns.qi_tilde) < qs_arr)):
        bad("qi_tilde has entries >= q_i")
    if rns.dec is not None:
        try:
            from repro.kernels.crt import plan_dec_arrays

            dec_arrs = plan_dec_arrays(rns)
        except Exception as e:  # pragma: no cover - defensive
            bad(f"plan_dec_arrays failed: {e}")
            dec_arrs = None
        if dec_arrs is not None:
            s1 = v - 1
            sau_eps = np.asarray(dec_arrs["sau_eps"])
            sau_s2 = np.asarray(dec_arrs["sau_s2"])
            acc_eps = np.asarray(dec_arrs["acc_eps"])
            ok = all(
                int(sau_eps[i]) == (1 << (s1 + int(sau_s2[i]))) // qs[i]
                for i in range(len(qs))
            )
            if not ok:
                bad("sau_eps != floor(2^(s1+s2) / q) per channel")
            else:
                families[("brt", "sau")] = {
                    "s1": s1,
                    "s2_lo": int(sau_s2.min()),
                    "s2_hi": int(sau_s2.max()),
                }
                registry.add(dec_arrs["sau_eps"], _tagged(sau_eps, ("brt", "sau"), qctx))
                registry.add(dec_arrs["sau_s2"], _tagged(sau_s2, ("brt_s2", "sau"), qctx))
            if all(int(acc_eps[i]) == (1 << (s1 + 4)) // qs[i] for i in range(len(qs))):
                families[("brt", "acc")] = {"s1": s1, "s2_lo": 4, "s2_hi": 4}
                registry.add(dec_arrs["acc_eps"], _tagged(acc_eps, ("brt", "acc"), qctx))
            else:
                bad("acc_eps != floor(2^(s1+4) / q) per channel")
            beta_e = np.asarray(dec_arrs["beta_e"])
            beta_s = np.asarray(dec_arrs["beta_s"])
            coeffs = [
                sum(int(beta_s[i, j]) << int(beta_e[i, j]) for j in range(beta_e.shape[1]))
                for i in range(len(qs))
            ]
            if all(c - 1 == pow(2, v, qs[i]) for i, c in enumerate(coeffs)):
                families[("sau", "dyn")] = {"c_lo": min(coeffs), "c_hi": max(coeffs)}
                registry.add(dec_arrs["beta_s"], _tagged(beta_s, ("sau_s", "dyn"), qctx))
                registry.add(dec_arrs["beta_e"], _tagged(beta_e, ("sau_e", "dyn"), qctx))
            else:
                bad("SAU signed-PoT terms do not sum to beta_i + 1 per channel")

    # Plan pytree leaves (identity set for the staticness lint) ---------
    for name, leaf in dict(getattr(pl, "consts", {}) or {}).items():
        registry.add_leaf(name, leaf)
    return ctx


# --------------------------------------------------------------------------
# envelope comparison
# --------------------------------------------------------------------------


def check_envelope(
    ctx: AnalysisContext,
    ct: Any,
    where: str,
    *,
    min_events: int,
) -> Dict[str, Any]:
    """Compare the Shoup-event stream against the hand bookkeeping.

    Derived facts must match or tighten ``ChannelTables.stage_bounds``
    (which is uniform per stage): every Shoup multiplicand within the
    lazy window in units of q, every inter-stage segment peak within the
    direction's transient bound (CT peaks ``u + t`` land *after* their
    stage's Shoup multiply, GS peaks ``u + v`` land *before* it — each
    segment is checked against the strongest applicable rule).
    Direction is classified structurally per event: a GS difference
    operand reaches the Shoup multiply through a conditional-subtract
    ``select_n``, a CT operand arrives straight from the previous stage.
    ``min_events`` is the anti-vacuity floor: a lazy-plan trace that
    produced fewer recognized butterfly stages than transforms*log2(n)
    means the analyzer lost pattern coverage, and that is an error."""
    events = list(ctx.stream)
    summary: Dict[str, Any] = {"events": len(events), "derived": {}, "hand": {}}
    window = getattr(ct, "lazy_window", None) if ct is not None else None
    if window is None:
        if events:
            ctx.finding(
                "error",
                "envelope-mismatch",
                where,
                f"{len(events)} Shoup stages recognized in a strict plan",
            )
        return summary
    window = int(window)
    if len(events) < min_events:
        ctx.finding(
            "error",
            "vacuous-analysis",
            where,
            f"lazy plan traced but only {len(events)} Shoup butterfly stages "
            f"recognized (expected >= {min_events}) — analyzer pattern "
            "coverage lost",
        )
        return summary
    fwd_bounds = ct.stage_bounds(inverse=False)
    inv_bounds = ct.stage_bounds(inverse=True)
    fwd_peak, inv_peak = fwd_bounds[0][1], inv_bounds[0][1]
    derived: Dict[str, Dict[str, int]] = {}
    for k, ev in enumerate(events):
        direction = "inv" if ev["gs"] else "fwd"
        d = derived.setdefault(direction, {"value": 0, "peak": 0})
        d["value"] = max(d["value"], ev["units_in"])
        if ev["units_in"] > window:
            ctx.finding(
                "error",
                "envelope-violation",
                where,
                f"Shoup operand at stage event {k} ({direction}) spans "
                f"{ev['units_in']} units of q > window {window}",
            )
        # The segment preceding event k: bounded by the GS transient if
        # event k is GS, and/or by the CT transient if event k-1 was CT.
        seg = ev["peak_before"] if k > 0 else None
        if seg is not None:
            bound = 0
            if ev["gs"]:
                bound = max(bound, inv_peak)
            if not events[k - 1]["gs"]:
                bound = max(bound, fwd_peak)
            if bound == 0:  # GS -> CT boundary: either transient may sit here
                bound = max(fwd_peak, inv_peak)
            owner = derived.setdefault(
                "inv" if ev["gs"] else "fwd", {"value": 0, "peak": 0}
            )
            owner["peak"] = max(owner["peak"], seg)
            if seg > bound:
                ctx.finding(
                    "error",
                    "envelope-violation",
                    where,
                    f"inter-stage peak before event {k} spans {seg} units "
                    f"of q > transient bound {bound}",
                )
    tail_bound = inv_peak if events[-1]["gs"] else fwd_peak
    if ctx.tail_peak > tail_bound:
        ctx.finding(
            "error",
            "envelope-violation",
            where,
            f"post-transform peak {ctx.tail_peak} units of q > transient "
            f"bound {tail_bound}",
        )
    summary["derived"] = derived
    summary["hand"] = {
        "fwd": {"value": fwd_bounds[0][0], "peak": fwd_peak},
        "inv": {"value": inv_bounds[0][0], "peak": inv_peak},
    }
    for direction, d in derived.items():
        hand = summary["hand"][direction]
        if d["value"] < hand["value"] or (d["peak"] and d["peak"] < hand["peak"]):
            ctx.finding(
                "info",
                "envelope-tightens",
                where,
                f"derived {direction} envelope (value {d['value']}, peak "
                f"{d['peak']}) tightens hand bookkeeping (value "
                f"{hand['value']}, peak {hand['peak']})",
            )
    return summary


# --------------------------------------------------------------------------
# lane / VMEM lint
# --------------------------------------------------------------------------


def _aval_bytes(aval: Any) -> int:
    inner = getattr(aval, "inner_aval", aval)
    shape = getattr(inner, "shape", None)
    dtype = getattr(inner, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for s in shape:
        n *= int(s)
    return n * np.dtype(dtype).itemsize


def lane_vmem_lint(closed: Any, pl: Any, ctx: AnalysisContext, where: str) -> List[Dict[str, Any]]:
    """Structural lane checks over every ``pallas_call`` in the trace:

    * four-step schedule must keep ``sublane_stages == 0`` (lane-aligned
      strides only — the PR 3 contract);
    * per-kernel VMEM footprint estimate (sum of ref block avals) vs the
      16 MiB budget the big-n tiling work must fit in.
    """
    report: List[Dict[str, Any]] = []
    sched = pl.config.schedule
    if pl.config.width == "int64" and getattr(sched, "kind", sched) == "four_step":
        from repro.kernels import ops as ops_mod

        for direction in ("fwd", "inv"):
            cost = ops_mod.transform_cost_model(
                pl.params, schedule=sched, direction=direction
            )
            if cost.get("sublane_stages", 0) != 0:
                ctx.finding(
                    "error",
                    "lane-lint",
                    where,
                    f"four_step {direction} schedule has "
                    f"{cost['sublane_stages']} sublane stages (want 0)",
                )
    for path, eqn in walk.iter_pallas_calls(closed):
        body = walk.raw(eqn.params.get("jaxpr"))
        vmem = sum(_aval_bytes(var.aval) for var in body.invars)
        entry = {
            "path": "/".join(path) or "top",
            "vmem_bytes": int(vmem),
            "budget_bytes": VMEM_BUDGET_BYTES,
        }
        report.append(entry)
        if vmem > VMEM_BUDGET_BYTES:
            ctx.finding(
                "error",
                "vmem-budget",
                where,
                f"pallas kernel at {entry['path']} holds ~{vmem} bytes of "
                f"refs > {VMEM_BUDGET_BYTES} VMEM budget",
            )
        elif vmem > VMEM_BUDGET_BYTES // 2:
            ctx.finding(
                "warning",
                "vmem-budget",
                where,
                f"pallas kernel at {entry['path']} holds ~{vmem} bytes of "
                f"refs (> 50% of VMEM budget)",
            )
    return report


# --------------------------------------------------------------------------
# staticness lint
# --------------------------------------------------------------------------


def staticness_lint(
    closed: Any,
    ctx: AnalysisContext,
    where: str,
    *,
    small_elems: int = SMALL_CONST_ELEMS,
) -> List[Dict[str, Any]]:
    """Flag big host constants baked into the trace that are not Plan
    pytree leaves (the PR 5 leaf-threading invariant, mechanized).

    Small constants (<= ``small_elems`` elements) are the per-channel
    SAU circuit scalars the design intentionally bakes; everything
    larger must be threaded as a leaf so serving can redirect it without
    retracing.  An equality-but-not-identity match to a leaf is the
    sharpest violation: a baked *copy* of a table silently breaks leaf
    redirection."""
    registry = getattr(ctx, "registry")
    flagged: List[Dict[str, Any]] = []
    for path, const in walk.iter_consts(closed):
        try:
            arr = np.asarray(const)
        except (TypeError, ValueError):
            continue
        if not np.issubdtype(arr.dtype, np.integer) or arr.size <= small_elems:
            continue
        if id(const) in registry.leaf_ids:
            continue
        loc = "/".join(path) or "top"
        copy_of = next(
            (
                name
                for name, leaf in registry.leaf_arrays
                if leaf.shape == arr.shape
                and leaf.dtype == arr.dtype
                and np.array_equal(leaf, arr)
            ),
            None,
        )
        if copy_of is not None:
            msg = (
                f"baked copy of plan leaf '{copy_of}' at {loc} "
                f"(shape {arr.shape}) — breaks leaf redirection"
            )
        else:
            msg = (
                f"host constant of shape {arr.shape} baked at {loc} "
                "is not a Plan leaf"
            )
        ctx.finding("error", "staticness", where, msg)
        flagged.append({"path": loc, "shape": list(arr.shape), "copy_of": copy_of})
    return flagged
