"""Data pipeline: deterministic synthetic LM stream (seeded, resumable) and
an optional memory-mapped token-file backend.

Determinism contract: ``batch_at(step)`` is a pure function of
(seed, step, shape) — resuming from a checkpoint at step k reproduces the
exact remaining stream, which the fault-tolerance test relies on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    token_file: str = ""  # optional np.memmap int32 corpus


class SyntheticLM:
    """Markov-ish synthetic tokens: learnable (next = f(prev)) so a real
    training signal exists for the examples."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg, self.dc = cfg, dc
        self._mm = (
            np.memmap(dc.token_file, dtype=np.int32, mode="r")
            if dc.token_file
            else None
        )

    def batch_at(self, step: int) -> dict:
        dc, cfg = self.dc, self.cfg
        if self._mm is not None:
            N = len(self._mm) - dc.seq_len - 1
            rng = np.random.default_rng((dc.seed, step))
            starts = rng.integers(0, N, size=dc.batch)
            toks = np.stack([self._mm[s : s + dc.seq_len + 1] for s in starts])
        else:
            rng = np.random.default_rng((dc.seed, step))
            first = rng.integers(0, cfg.vocab, size=(dc.batch, 1))
            steps = rng.integers(1, 7, size=(dc.batch, dc.seq_len))
            toks = np.concatenate([first, steps], axis=1).cumsum(axis=1) % cfg.vocab
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.frontend and self.cfg.family != "encdec":
            rng2 = np.random.default_rng((dc.seed, step, 7))
            batch["embeddings"] = rng2.normal(
                size=(dc.batch, dc.seq_len, cfg.d_model)
            ).astype(np.float32)
            del batch["tokens"]
        if self.cfg.family == "encdec":
            rng2 = np.random.default_rng((dc.seed, step, 9))
            batch["enc_embeddings"] = rng2.normal(
                size=(dc.batch, dc.seq_len, cfg.d_model)
            ).astype(np.float32)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
