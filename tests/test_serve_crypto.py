"""The crypto serving subsystem: shape-bucketed continuous batching
(PolymulEngine), the mesh-sharded cascade (`model` x `data` shard_map
with plan tables resident per-shard), and the crypto partition rules.

Mesh tests run on REAL 4-device host meshes — conftest.py forces
``--xla_force_host_platform_device_count=4`` before jax initializes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro
from repro import api
from repro.core import polymul as pm
from repro.serve.crypto_engine import (
    PolymulEngine,
    negacyclic_mul_sharded,
    polymul_sharded,
)
from repro.sharding import partition


def _rand_segments(pl, rng, batch=None):
    shape = (pl.n, pl.config.seg_count)
    if batch is not None:
        shape = (batch,) + shape
    return (
        rng.integers(0, 1 << pl.v, size=shape),
        rng.integers(0, 1 << pl.v, size=shape),
    )


def _rand_residues(pl, rng, batch):
    return jnp.asarray(
        np.stack(
            [
                rng.integers(0, int(q), size=(batch, pl.n))
                for q in pl.params.plan.qs
            ]
        )
    )


class TestEngineBatching:
    def test_mixed_preset_stream_bit_exact_vs_oracle(self):
        """Both paper presets interleaved through ONE engine: every
        result bit-exact vs the bigint oracle, and exactly one jit
        trace per distinct PlanConfig (the acceptance criterion)."""
        eng = PolymulEngine(batch_slots=4)
        plans = [eng.plan(n=64, t=3, v=30), eng.plan(n=32, t=4, v=45)]
        import random

        r = random.Random(0)
        reqs = []
        for i in range(10):
            pl = plans[i % 2]
            a = [r.randrange(pl.q) for _ in range(pl.n)]
            b = [r.randrange(pl.q) for _ in range(pl.n)]
            za = np.asarray(api.to_segments(pl, a))
            zb = np.asarray(api.to_segments(pl, b))
            reqs.append((pl, a, b, eng.submit(pl, za, zb)))
        eng.run_until_idle()
        for pl, a, b, fut in reqs:
            got = api.from_limbs(pl, fut.result())
            assert got == pm.oracle_multiply(a, b, pl.params)
        assert eng.trace_count == 2  # one compile per distinct config
        assert sorted(
            set(eng.traced_configs), key=lambda c: c.v
        ) == sorted({api.plan_key(p) for p in plans}, key=lambda c: c.v)

    def test_padding_and_slot_reuse_invariants(self):
        """9 requests through 4 slots -> 3 dispatches (4+4+1), 3 padded
        slots total, still ONE trace: the padded batch shape is stable
        across dispatches."""
        rng = np.random.default_rng(1)
        eng = PolymulEngine(batch_slots=4)
        pl = eng.plan(n=64, t=3, v=30)
        futs = []
        want = []
        for _ in range(9):
            za, zb = _rand_segments(pl, rng)
            futs.append(eng.submit(pl, za, zb))
            want.append(
                np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
            )
        assert eng.pending() == 9
        assert eng.step() == 4
        assert eng.pending() == 5
        eng.run_until_idle()
        assert eng.stats["dispatches"] == 3
        assert eng.stats["padded_slots"] == 3
        assert eng.stats["served"] == 9
        assert eng.trace_count == 1
        for fut, w in zip(futs, want):
            assert np.array_equal(fut.result(), w)
            assert fut.latency_s >= 0

    def test_plan_cache_hits(self):
        eng = PolymulEngine()
        a = eng.plan(n=64, t=3, v=30)
        b = eng.plan(n=64, t=3, v=30)
        assert a is b  # cached by plan_key
        c = eng.plan(n=64, t=3, v=30, backend="pallas_fused")
        assert c is not a

    def test_future_unserved_raises(self):
        rng = np.random.default_rng(2)
        eng = PolymulEngine(batch_slots=2)
        pl = eng.plan(n=64, t=3, v=30)
        fut = eng.submit(pl, *_rand_segments(pl, rng))
        assert not fut.done()
        with pytest.raises(RuntimeError, match="not served"):
            fut.result()
        eng.run_until_idle()
        assert fut.done()

    def test_submit_shape_validation(self):
        eng = PolymulEngine()
        pl = eng.plan(n=64, t=3, v=30)
        bad = np.zeros((32, pl.config.seg_count), np.int64)
        ok = np.zeros((64, pl.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="expected za segments"):
            eng.submit(pl, bad, ok)

    def test_oracle_width_requests_served_eagerly(self):
        """v > 46 buckets run the host oracle: no tracing, no padding,
        results still exact (vs the schoolbook)."""
        import random

        r = random.Random(3)
        eng = PolymulEngine(batch_slots=4)
        pl = eng.plan(n=32, t=2, v=50)
        a = [r.randrange(pl.q) for _ in range(pl.n)]
        b = [r.randrange(pl.q) for _ in range(pl.n)]
        fut = eng.submit(
            pl,
            np.asarray(api.to_segments(pl, a)),
            np.asarray(api.to_segments(pl, b)),
        )
        eng.run_until_idle()
        assert api.from_limbs(pl, fut.result()) == pm.schoolbook_negacyclic(
            a, b, pl.q
        )
        assert eng.trace_count == 0
        assert eng.stats["padded_slots"] == 0

    def test_execute_hook_and_plan_key(self):
        rng = np.random.default_rng(4)
        pl = repro.plan(n=64, t=3, v=30)
        assert api.plan_key(pl) == pl.config
        za, zb = _rand_segments(pl, rng, batch=2)
        want = np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
        got = api.execute(pl, jnp.asarray(za), jnp.asarray(zb))
        assert np.array_equal(np.asarray(got), want)
        # donating twin: operands are consumed, result identical
        got_d = api.execute(
            pl, jnp.asarray(za), jnp.asarray(zb), donate=True
        )
        assert np.array_equal(np.asarray(got_d), want)


class TestCryptoPartitionRules:
    def test_polymul_specs_layout(self, host_mesh_4):
        pl = repro.plan(n=64, t=6, v=30)
        specs = partition.polymul_specs(host_mesh_4, pl)
        assert specs["segments"] == P(("data",), None, None)
        assert specs["residues"] == P("model", ("data",), None)
        assert specs["limbs"] == P(("data",), None, None)

    def test_polymul_specs_nondivisible_channel_fallback(self, host_mesh_4):
        pl = repro.plan(n=64, t=3, v=30)  # 3 % 2 != 0 -> replicate channels
        specs = partition.polymul_specs(host_mesh_4, pl)
        assert specs["residues"] == P(None, ("data",), None)

    def test_plan_leaf_specs_channel_major(self, host_mesh_4):
        pl = repro.plan(n=64, t=6, v=30)
        specs = partition.plan_leaf_specs(host_mesh_4, pl)
        for name, leaf in pl.consts.items():
            if name == "rns_q_limbs":
                assert specs[name] == P(*([None] * leaf.ndim)), name
            else:
                assert specs[name][0] == "model", name
                assert len(specs[name]) == leaf.ndim

    def test_plan_tables_resident_per_shard(self, host_mesh_4):
        """device_put with the leaf shardings leaves each model shard
        holding exactly its channels' tables (t/2 rows per shard on the
        2-way model axis) — 'plan tables resident per-shard'."""
        pl = repro.plan(n=64, t=6, v=30)
        consts = jax.device_put(
            pl.consts, partition.plan_leaf_shardings(host_mesh_4, pl)
        )
        fwd = consts["ntt_fwd"]  # (t, n)
        assert not fwd.sharding.is_fully_replicated
        shard_shapes = {s.data.shape for s in fwd.addressable_shards}
        assert shard_shapes == {(3, 64)}
        assert consts["rns_q_limbs"].sharding.is_fully_replicated


class TestMeshShardedCascade:
    def test_model_axis_shard_map_bit_exact(self, host_mesh_4):
        """The acceptance criterion: the model-axis shard_map path of
        negacyclic_mul is bit-exact vs the single-device path."""
        rng = np.random.default_rng(5)
        pl = repro.plan(n=64, t=6, v=30)
        a = _rand_residues(pl, rng, batch=4)
        b = _rand_residues(pl, rng, batch=4)
        want = np.asarray(repro.negacyclic_mul(pl, a, b))
        got = negacyclic_mul_sharded(pl, a, b, mesh=host_mesh_4)
        assert np.array_equal(np.asarray(got), want)

    def test_sharded_cascade_reads_leaves_not_constants(self, host_mesh_4):
        """int64 leaves threaded, not jit constants: mutating a plan's
        twiddle leaf MUST change the sharded result — if the kernels
        bound tables from the static params, this would be a no-op."""
        rng = np.random.default_rng(6)
        pl = repro.plan(n=64, t=6, v=30)
        a = _rand_residues(pl, rng, batch=2)
        b = _rand_residues(pl, rng, batch=2)
        want = np.asarray(negacyclic_mul_sharded(pl, a, b, mesh=host_mesh_4))
        broken_consts = dict(pl.consts)
        broken_consts["ntt_fwd"] = (
            broken_consts["ntt_fwd"] ^ 1
        )  # flip low bits
        broken = api.Plan(
            config=pl.config, params=pl.params, consts=broken_consts
        )
        got = np.asarray(
            negacyclic_mul_sharded(broken, a, b, mesh=host_mesh_4)
        )
        assert not np.array_equal(got, want)

    def test_polymul_sharded_jit_bit_exact(self, host_mesh_4):
        rng = np.random.default_rng(7)
        pl = repro.plan(n=64, t=6, v=30)
        za, zb = _rand_segments(pl, rng, batch=4)
        za, zb = jnp.asarray(za), jnp.asarray(zb)
        want = np.asarray(repro.polymul(pl, za, zb))
        fn = jax.jit(
            lambda p, x, y: polymul_sharded(p, x, y, mesh=host_mesh_4)
        )
        assert np.array_equal(np.asarray(fn(pl, za, zb)), want)

    def test_sharded_rejects_bad_configs(self, host_mesh_4):
        rng = np.random.default_rng(8)
        pl = repro.plan(n=64, t=3, v=30)  # 3 channels % 2-way model != 0
        a = _rand_residues(pl, rng, batch=2)
        with pytest.raises(ValueError, match="do not divide the model"):
            negacyclic_mul_sharded(pl, a, a, mesh=host_mesh_4)
        wide = repro.plan(n=32, t=4, v=45)
        res = jnp.zeros((4, 2, 32), jnp.int64)
        with pytest.raises(ValueError, match="int64-width plans only"):
            negacyclic_mul_sharded(wide, res, res, mesh=host_mesh_4)
        pl6 = repro.plan(n=64, t=6, v=30)
        odd = _rand_residues(pl6, rng, batch=3)  # 3 % data-size 2 != 0
        with pytest.raises(ValueError, match="does not divide the data"):
            negacyclic_mul_sharded(pl6, odd, odd, mesh=host_mesh_4)

    def test_engine_mesh_mode_end_to_end(self, host_mesh_4):
        rng = np.random.default_rng(9)
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        pl = eng.plan(n=64, t=6, v=30)
        futs, want = [], []
        for _ in range(6):
            za, zb = _rand_segments(pl, rng)
            futs.append(eng.submit(pl, za, zb))
            want.append(
                np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
            )
        eng.run_until_idle()
        for fut, w in zip(futs, want):
            assert np.array_equal(fut.result(), w)
        assert eng.trace_count == 1
        assert eng.stats["dispatches"] == 2
        assert eng.stats["padded_slots"] == 2

    def test_engine_mesh_mode_rejects_nonsharding_slots(self, host_mesh_4):
        with pytest.raises(ValueError, match="batch_slots"):
            PolymulEngine(batch_slots=3, mesh=host_mesh_4)
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        wide = repro.plan(n=32, t=4, v=45)
        z = np.zeros((32, wide.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="int64-width plans only"):
            eng.submit(wide, z, z)

    def test_engine_mesh_mode_rejects_indivisible_t_at_submit(
        self, host_mesh_4
    ):
        """A config that could only fail at trace time would lose its
        already-popped requests — the engine must refuse it at submit
        (the queue stays intact, no future is ever orphaned)."""
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        pl = repro.plan(n=64, t=3, v=30)  # 3 % 2-way model != 0
        z = np.zeros((64, pl.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="do not divide"):
            eng.submit(pl, z, z)
        assert eng.pending() == 0
        assert eng.stats["submitted"] == 0
