"""End-to-end PaReNTT modular polynomial multiplier (paper Fig 10).

Pipeline (Step 1/2/3 of Fig 10):
    segments --decompose--> residues --NTT ⊙ iNTT (no shuffle)--> residues
             --compose--> limbs of p(x) mod q

plus ground-truth oracles:
  * ``schoolbook_negacyclic`` — O(n^2) Python-bigint negacyclic product.
  * ``oracle_multiply``       — same pipeline in Python bigints (any v,
    including the t=4 / v=45 config whose products exceed int64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigint, rns as rns_mod
from repro.core.params import ParenttParams
from repro.kernels import ops as ops_mod

# --------------------------------------------------------------------------
# Oracles (host, exact)
# --------------------------------------------------------------------------


def schoolbook_negacyclic(a: list[int], b: list[int], q: int) -> list[int]:
    """p = a*b mod (x^n + 1, q), Python bigints."""
    n = len(a)
    p = [0] * n
    for i in range(n):
        ai = a[i] % q
        if not ai:
            continue
        for j in range(n):
            k = i + j
            if k >= n:
                p[k - n] = (p[k - n] - ai * b[j]) % q
            else:
                p[k] = (p[k] + ai * b[j]) % q
    return p


def oracle_multiply(a: list[int], b: list[int], params: ParenttParams) -> list[int]:
    """RNS+NTT pipeline in Python bigints (reference for any v)."""
    plan = params.plan
    out = [0] * params.n
    for i in range(params.t):
        qi = int(plan.qs[i])
        pi = schoolbook_negacyclic([x % qi for x in a], [x % qi for x in b], qi)
        star = plan.q // qi
        tilde = int(plan.qi_tilde[i])
        for j in range(params.n):
            out[j] = (out[j] + ((pi[j] * tilde) % qi) * star) % plan.q
    return out


# --------------------------------------------------------------------------
# Host <-> device formats
# --------------------------------------------------------------------------


def ints_to_segments(xs: list[int], plan: rns_mod.RnsPlan) -> np.ndarray:
    return bigint.ints_to_limbs(xs, plan.v, plan.seg_count)


def limbs_out_to_ints(limbs, plan: rns_mod.RnsPlan) -> list[int]:
    return bigint.limbs_to_ints(limbs, plan.w)


# --------------------------------------------------------------------------
# jit pipeline
# --------------------------------------------------------------------------


class ParenttMultiplier:
    """The paper's architecture as a batched JAX transform.

    All methods operate on the last axis = polynomial coefficients; the
    RNS channel axis is the leading axis of residue-domain arrays.

    ``backend`` selects the datapath for all three steps (see
    :mod:`repro.kernels.ops`): ``"jnp"`` (pure-jnp reference),
    ``"pallas"`` (per-stage kernels), ``"pallas_fused"`` (the paper's
    single-kernel NTT -> ⊙ -> iNTT cascade) or ``"pallas_fused_e2e"``
    (the full decompose -> cascade -> compose pipeline in ONE kernel —
    under it, ``__call__`` fuses end to end while the three stage
    methods degrade to the closest per-stage kernels).  ``None`` defers
    to ``params.backend``.
    """

    def __init__(
        self,
        params: ParenttParams,
        use_sau: bool = True,
        backend: str | None = None,
    ):
        if params.tables is None:
            raise ValueError(
                f"ParenttMultiplier requires int64-safe NTT tables, but params "
                f"(n={params.n}, t={params.t}, v={params.v}) have none: v > 31 "
                f"means residue products overflow int64.  Use "
                f"polymul.oracle_multiply (exact host bigints, any v) or "
                f"repro.core.wide.WideParenttMultiplier (digit-split v=45 "
                f"datapath) instead."
            )
        self.params = params
        self.use_sau = use_sau
        self.backend = ops_mod.resolve_backend(params, backend)

    # -- step 1: pre-processing ------------------------------------------
    def preprocess(self, z: jax.Array) -> jax.Array:
        """z: (..., n, S) segments -> residues (t, ..., n)."""
        return ops_mod.rns_decompose(
            z, self.params, backend=self.backend, use_sau=self.use_sau
        )

    # -- step 2: evaluation in the residue domain ------------------------
    def residue_mul(self, ra: jax.Array, rb: jax.Array) -> jax.Array:
        """(t, ..., n) x (t, ..., n) -> (t, ..., n): parallel no-shuffle
        NTT cascades, one per RNS channel."""
        return ops_mod.negacyclic_mul(ra, rb, self.params, backend=self.backend)

    # -- step 3: post-processing ------------------------------------------
    def postprocess(self, residues: jax.Array) -> jax.Array:
        """(t, ..., n) -> (..., n, L) limbs of p mod q."""
        return ops_mod.rns_compose(residues, self.params, backend=self.backend)

    # -- full pipeline ----------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def __call__(self, za: jax.Array, zb: jax.Array) -> jax.Array:
        """za, zb: (..., n, S) segment arrays -> (..., n, L) limb array.

        Routed through :func:`repro.kernels.ops.fused_polymul_e2e`: on
        ``backend="pallas_fused_e2e"`` the whole pipeline is one
        pallas_call (residues never touch HBM); otherwise it is the
        preprocess/residue_mul/postprocess composition."""
        return ops_mod.fused_polymul_e2e(
            za, zb, self.params, backend=self.backend, use_sau=self.use_sau
        )

    # -- host convenience ---------------------------------------------------
    def multiply_ints(self, a: list[int], b: list[int]) -> list[int]:
        plan = self.params.plan
        za = jnp.asarray(ints_to_segments(a, plan))
        zb = jnp.asarray(ints_to_segments(b, plan))
        limbs = self(za, zb)
        return limbs_out_to_ints(np.asarray(limbs), plan)
