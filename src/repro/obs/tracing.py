"""Request-scoped tracing: spans with trace IDs, an append-only span
log, and a JSONL wire format the report CLI and CI gates consume.

A *span* is one request's lifecycle: minted at
``PolymulEngine.submit()`` (the trace ID lands on the returned future as
``fut.trace_id``), carried through queueing, EDF dispatch, retries and
breaker transitions as timestamped *events*, and closed exactly once
with a terminal status.  The span-conservation invariant — every
admitted request has exactly ONE terminal span, one of
``resolved`` / ``shed`` / ``failed`` — is what the ``obs-smoke`` CI gate
asserts over a soak run's log (:mod:`repro.launch.obs_report`).

Span state machine (DESIGN.md §12)::

    submit -> [rejected]                      # backpressure, never admitted
    submit -> admit -> (queue ...) -> dispatch -> resolved
                    \\-> shed                  # deadline passed / unmeetable
                    \\-> ... retry/breaker_open events ... -> failed

Engine-level happenings that are not tied to one request (circuit
breaker opening/closing, probe dispatches) are logged as *event*
records, so a log line is one of two kinds::

    {"kind": "span",  "trace_id": "...", "name": "request", "status": ...,
     "t_start": ..., "t_end": ..., "attrs": {...}, "events": [...]}
    {"kind": "event", "name": "breaker_open", "t": ..., "attrs": {...}}

Timestamps are ``time.perf_counter()`` seconds (monotonic, same clock
as the engine's deadlines) plus one ``t_unix`` wall anchor on each
record — derived from a single per-log wall/monotonic anchor pair, not
a syscall per span — so logs from one process are internally orderable
and roughly placeable in wall time.

Overhead: recording is append-to-list under one lock, no I/O; the JSONL
serialization happens only at :meth:`SpanLog.flush`.  Trace IDs come
from one ``itertools.count`` (``next()`` is atomic under the GIL — no
extra lock) behind a precomputed ``prefix-pid-`` string.  With no span
log installed the engine's tracing branches are single ``is None``
checks — the ``obs-smoke`` gate bounds the enabled cost at <= 5% of
closed-loop throughput.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any, IO, Iterable

__all__ = [
    "Span",
    "SpanLog",
    "TERMINAL_STATUSES",
    "conservation",
    "read_jsonl",
]

# The one-terminal-per-admitted-request vocabulary (conservation gate).
TERMINAL_STATUSES = ("resolved", "shed", "failed")
# "rejected" spans exist too, but the request was never admitted (no
# future obligations), so conservation counts them separately.

# next() on itertools.count is atomic in CPython; no lock needed.
_trace_counter = itertools.count()


def _mint_trace_id(prefix: str) -> str:
    return f"{prefix}-{os.getpid():x}-{next(_trace_counter):08x}"


class Span:
    """One in-flight request trace.  Engine-internal mutation only; the
    record becomes immutable once :meth:`finish` hands it to the log."""

    __slots__ = ("trace_id", "name", "t_start", "attrs",
                 "events", "status", "t_end", "_log")

    def __init__(self, log: "SpanLog", name: str, trace_id: str,
                 attrs: dict[str, Any]) -> None:
        self._log = log
        self.trace_id = trace_id
        self.name = name
        self.t_start = time.perf_counter()
        self.attrs = attrs
        self.events: list[dict[str, Any]] = []
        self.status: str | None = None
        self.t_end: float | None = None

    def event(self, name: str, **attrs: Any) -> None:
        """Append a timestamped event; no-op after the span finished
        (a late event cannot reopen a terminal span)."""
        if self.status is not None:
            return
        ev = {"t": time.perf_counter(), "name": name}
        if attrs:
            ev["attrs"] = attrs
        self.events.append(ev)

    def finish(self, status: str, **attrs: Any) -> None:
        """Close the span exactly once and emit it to the log.  A second
        finish raises — the tracing twin of the future's resolve-once
        invariant."""
        if self.status is not None:
            raise RuntimeError(
                f"span {self.trace_id} finished twice "
                f"({self.status!r} then {status!r})"
            )
        self.status = status
        self.t_end = time.perf_counter()
        if attrs:
            self.attrs.update(attrs)
        self._log._emit(self.to_record())

    def to_record(self) -> dict[str, Any]:
        return {
            "kind": "span",
            "trace_id": self.trace_id,
            "name": self.name,
            "status": self.status,
            "t_start": self.t_start,
            "t_end": self.t_end,
            # wall placement from the log's one-time anchor pair: no
            # time.time() syscall on the per-span hot path
            "t_unix": self._log.to_unix(self.t_start),
            "attrs": self.attrs,
            "events": self.events,
        }


class SpanLog:
    """Thread-safe span/event collector with an optional JSONL sink.

    ``path=None`` keeps records in memory only (tests, ad-hoc probes);
    with a path, :meth:`flush` appends every record accumulated since
    the last flush.  ``SpanLog`` is also a context manager (flushes on
    exit)."""

    def __init__(self, path: str | os.PathLike[str] | None = None,
                 *, trace_prefix: str = "req") -> None:
        self.path = os.fspath(path) if path is not None else None
        self.trace_prefix = trace_prefix
        # precomputed so minting a trace ID is one format, zero syscalls
        self._id_prefix = f"{trace_prefix}-{os.getpid():x}-"
        # one wall/monotonic anchor pair; every record's t_unix derives
        # from it instead of a per-record time.time() call
        self._anchor_perf = time.perf_counter()
        self._anchor_unix = time.time()
        self._lock = threading.Lock()
        self._records: list[dict[str, Any]] = []
        self._unflushed: list[dict[str, Any]] = []

    def to_unix(self, t_perf: float) -> float:
        """Map a ``perf_counter`` timestamp to wall time via the log's
        anchor pair."""
        return self._anchor_unix + (t_perf - self._anchor_perf)

    # -- recording -----------------------------------------------------
    def start_span(self, name: str, **attrs: Any) -> Span:
        tid = f"{self._id_prefix}{next(_trace_counter):08x}"
        return Span(self, name, tid, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an engine-level (non-request) event."""
        t = time.perf_counter()
        self._emit({
            "kind": "event",
            "name": name,
            "t": t,
            "t_unix": self.to_unix(t),
            "attrs": attrs,
        })

    def _emit(self, record: dict[str, Any]) -> None:
        with self._lock:
            self._records.append(record)
            self._unflushed.append(record)

    # -- reading / sinking ---------------------------------------------
    @property
    def records(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._records)

    def spans(self, status: str | None = None) -> list[dict[str, Any]]:
        return [
            r for r in self.records
            if r["kind"] == "span" and (status is None or r["status"] == status)
        ]

    def flush(self, fp: IO[str] | None = None) -> int:
        """Append unflushed records as JSONL to ``fp`` or ``self.path``;
        returns the number of records written (0 when neither sink
        exists — records stay readable in memory)."""
        if fp is None and self.path is None:
            return 0
        with self._lock:
            batch, self._unflushed = self._unflushed, []
        if not batch:
            return 0
        lines = "".join(json.dumps(r, sort_keys=True) + "\n" for r in batch)
        if fp is not None:
            fp.write(lines)
        else:
            assert self.path is not None
            with open(self.path, "a") as f:
                f.write(lines)
        return len(batch)

    def __enter__(self) -> "SpanLog":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.flush()


def read_jsonl(path: str | os.PathLike[str]) -> list[dict[str, Any]]:
    """Parse a span-log JSONL file back into records (report CLI / CI
    gate input).  Raises ``ValueError`` naming the offending line on
    malformed input — a truncated log should fail loudly."""
    out: list[dict[str, Any]] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not valid JSON: {e}") from e
            if not isinstance(rec, dict) or rec.get("kind") not in (
                "span", "event"
            ):
                raise ValueError(
                    f"{path}:{i}: not a span/event record: {line[:80]}"
                )
            out.append(rec)
    return out


def conservation(records: Iterable[dict[str, Any]]) -> dict[str, Any]:
    """Span-conservation accounting over a log: every admitted request
    span must carry exactly one terminal status.  Returns the counts and
    a ``violations`` list (empty = the invariant holds) — the core of
    the ``obs-smoke`` gate (see :mod:`repro.launch.obs_report`)."""
    by_status: dict[str, int] = {}
    violations: list[str] = []
    seen_ids: set[str] = set()
    admitted = 0
    for r in records:
        if r.get("kind") != "span" or r.get("name") != "request":
            continue
        tid = r.get("trace_id", "?")
        if tid in seen_ids:
            violations.append(f"trace {tid}: more than one span record")
        seen_ids.add(tid)
        status = r.get("status")
        by_status[status] = by_status.get(status, 0) + 1
        if status == "rejected":
            continue  # never admitted: no terminal obligation
        admitted += 1
        if status not in TERMINAL_STATUSES:
            violations.append(
                f"trace {tid}: non-terminal status {status!r} "
                f"(want one of {TERMINAL_STATUSES})"
            )
    terminal = sum(by_status.get(s, 0) for s in TERMINAL_STATUSES)
    if terminal != admitted:
        violations.append(
            f"{admitted} admitted spans but {terminal} terminal statuses"
        )
    return {
        "spans": len(seen_ids),
        "admitted": admitted,
        "by_status": by_status,
        "violations": violations,
    }
