import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape) cell against the
# production meshes, record memory/cost/collective analysis for §Roofline.
#
# MUST be run as its own process (the two lines above must execute before
# any jax initialization):
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#
# Artifacts: benchmarks/artifacts/dryrun_<mesh>_<arch>_<shape>.json

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, RunConfig
from repro.launch import analysis, hlo_analyzer, specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.sharding import ctx as shard_ctx
from repro.sharding import partition
from repro.train import train_step as ts_mod

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts")


def _sharding_tree(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)


def _param_shardings(shapes, mesh):
    specs = partition.enforce_divisibility(
        partition.param_specs(shapes), shapes, mesh
    )
    return _sharding_tree(specs, mesh)


def _batch_shardings(batch_specs, mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, partition.batch_shard_spec(mesh, leaf.shape)),
        batch_specs,
    )


def lower_cell(cfg, shape, mesh, run_overrides: dict | None = None,
               strategy: str = "baseline"):
    """Lower + compile one cell; returns (compiled, lowered, specs)."""
    run = RunConfig(model=cfg, remat=True, **(run_overrides or {}))
    cell = specs_mod.input_specs(cfg, shape)
    if strategy == "dp_only":
        # small models: pure data parallel, params/opt replicated
        repl = lambda shapes: jax.tree.map(
            lambda _: NamedSharding(mesh, P()), shapes)
        params_sh = repl(cell["params"])
        ba = tuple(mesh.axis_names)
        batch_sh = jax.tree.map(
            lambda leaf: NamedSharding(
                mesh, P(ba, *([None] * (len(leaf.shape) - 1)))
                if leaf.shape[0] % (512 if "pod" in ba else 256) == 0
                else P(*([None] * len(leaf.shape)))),
            cell["batch"])
    else:
        params_sh = _param_shardings(cell["params"], mesh)
        batch_sh = _batch_shardings(cell["batch"], mesh)

    if shape.kind == "train":
        if strategy == "dp_only":
            opt_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                  cell["opt_state"])
        else:
            opt_sh = type(cell["opt_state"])(
                step=NamedSharding(mesh, P()),
                m=_param_shardings(cell["opt_state"].m, mesh),
                v=_param_shardings(cell["opt_state"].v, mesh),
            )
        fn = ts_mod.make_train_step(run)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
        )
        lowered = jitted.lower(cell["params"], cell["opt_state"], cell["batch"])
    elif shape.kind == "prefill":
        def prefill(params, batch):
            return M.forward(params, cfg, batch, remat=False, last_only=True)

        jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(cell["params"], cell["batch"])
    else:  # decode
        cache_sh = _sharding_tree(partition.cache_specs(cell["cache"], mesh), mesh)

        def serve_step(params, cache, batch):
            return M.decode_step(params, cfg, cache, batch)

        jitted = jax.jit(
            serve_step,
            in_shardings=(params_sh, cache_sh, batch_sh),
            out_shardings=(None, cache_sh),
        )
        lowered = jitted.lower(cell["params"], cell["cache"], cell["batch"])
    compiled = lowered.compile()
    return compiled, lowered


def run_cell(cfg, shape, mesh_kind: str, out_dir: str, run_overrides=None, tag="",
             strategy: str = "baseline"):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = 512 if mesh_kind == "multi" else 256
    t0 = time.time()
    record = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": mesh_kind,
        "n_devices": n_dev,
        "tag": tag,
        "strategy": strategy,
    }
    try:
        with mesh, shard_ctx.activation_policy(
            shard_ctx.make_mesh_policy(mesh, strategy=strategy)
        ):
            compiled, lowered = lower_cell(cfg, shape, mesh, run_overrides, strategy)
        record["memory"] = analysis.memory_stats(compiled)
        record["cost_raw"] = analysis.cost_stats(compiled)  # scan-body-once caveat
        hlo = hlo_analyzer.analyze(compiled.as_text())  # loop-aware, per-device
        record["hlo"] = {
            "flops": hlo["flops"],
            "hbm_bytes": hlo["hbm_bytes"],
        }
        coll = hlo["collectives"]
        record["collectives"] = coll
        rl = analysis.Roofline(
            flops=hlo["flops"],
            hbm_bytes=hlo["hbm_bytes"],
            coll_bytes=coll["total"],
            compute_s=hlo["flops"] / analysis.PEAK_FLOPS,
            memory_s=hlo["hbm_bytes"] / analysis.HBM_BW,
            collective_s=coll["total"] / analysis.ICI_BW,
        )
        record["roofline"] = rl.as_dict()
        record["model_flops"] = analysis.model_flops(cfg, shape)
        record["model_flops_ratio"] = (
            record["model_flops"] / max(rl.flops * n_dev, 1.0)
        )
        record["status"] = "ok"
        print(
            f"[ok] {cfg.name} x {shape.name} x {mesh_kind}: "
            f"dominant={rl.dominant} compute={rl.compute_s:.4f}s "
            f"memory={rl.memory_s:.4f}s coll={rl.collective_s:.4f}s "
            f"({time.time()-t0:.0f}s)"
        )
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {cfg.name} x {shape.name} x {mesh_kind}: {e}")
    record["elapsed_s"] = time.time() - t0
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fn = os.path.join(
        out_dir, f"dryrun_{mesh_kind}_{cfg.name}_{shape.name}{suffix}.json"
    )
    with open(fn, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACTS))
    ap.add_argument("--remat-group", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--strategy", default="baseline",
                    choices=["baseline", "seqpar", "dp_only"])
    args = ap.parse_args()

    overrides = {}
    if args.remat_group > 1:
        overrides["remat_group"] = args.remat_group
    if args.grad_accum > 1:
        overrides["grad_accum_steps"] = args.grad_accum

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = registry.cells()
    else:
        cfg = registry.get(args.arch)
        cells = [(cfg, SHAPES[args.shape], "")]
    n_fail = 0
    for cfg, shape, skip in cells:
        if skip:
            continue
        for mk in meshes:
            rec = run_cell(cfg, shape, mk, args.out, overrides or None, args.tag,
                           args.strategy)
            n_fail += rec["status"] != "ok"
            jax.clear_caches()
    print(f"dryrun complete: {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
