"""Low-complexity negative-wrapped-convolution NTT / iNTT (paper §II-D, Fig 1,
supplementary Eq 14-25) with the *no-shuffle cascade* (contribution 1).

Design notes
------------
* Forward transform: decimation-in-time (CT) butterflies with the weights
  psi_{2n}^{(2k+1)} merged into the twiddles (Eq 16-19).  Natural-order
  input -> **bit-reversed** output.
* Inverse transform retraces the forward flow graph in reverse stage order
  (first inverse stage undoes the forward's last), with the inverse
  twiddles psi^{-brv(h+i)} and the factor n^{-1} folded in: every stage
  halves both butterfly outputs with the shift-and-conditional-add trick
  of Eq 24/25 (the paper's Fig 9 PE).  **Bit-reversed** input ->
  natural-order output.
* Because the pointwise product is order-agnostic, the cascade
  ``intt(ntt(a) * ntt(b))`` needs **zero permutations** — this is the
  data-flow-level content of the paper's different-folding-sets trick
  (the hardware folding/latency model itself lives in
  :mod:`repro.core.schedule`).
* Butterfly reduction: the scalar helpers live in
  :mod:`repro.core.modmath` (shared with the Pallas kernels so the two
  datapaths cannot drift).  When a configuration's moduli fit the
  63-bit-safe envelope (q < 2^31, uniform width — the paper's v=30
  preferred point), the butterfly multiply reduces with a precomputed
  per-channel Barrett constant instead of a generic ``%``.

All arithmetic is int64; residues must satisfy q < 2**31 so products fit
(the v<=30 fast path; the paper's preferred config).  The v=45 config is
served by the numpy-object oracle in :mod:`repro.core.polymul`.

Shapes: transforms operate on the last axis; any leading batch dims.  The
`*_channels` variants vmap over a leading RNS-channel axis with per-channel
moduli/tables; twiddles and moduli are device-resident (uploaded once per
table object, not per call).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import modmath
from repro.core import primes as primes_mod

# Re-exported so existing call sites (benchmarks, notebooks) keep working;
# the implementations live in modmath.
add_mod = modmath.add_mod
sub_mod = modmath.sub_mod
mul_mod = modmath.mul_mod
div2_mod = modmath.div2_mod


def bit_reverse_indices(n: int) -> np.ndarray:
    """Permutation p with p[i] = bit-reverse of i over log2(n) bits."""
    m = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    out = np.zeros_like(idx)
    for b in range(m):
        out |= ((idx >> b) & 1) << (m - 1 - b)
    return out


class NttTables(NamedTuple):
    """Per-modulus twiddle tables for the merged-weight NWC transforms."""

    q: int
    n: int
    psi: int  # primitive 2n-th root of unity mod q
    fwd: np.ndarray  # (n,)  fwd[i] = psi^{brv(i)}    (CT/DIT stage tables)
    inv: np.ndarray  # (n,)  inv[i] = psi^{-brv(i)}   (mirror-order inverse)
    half: int  # (q + 1) / 2, for the div-by-2 PE (Eq 24)
    mul_eps: int | None = None  # Barrett eps for residue products (q<2^31)
    mul_shifts: tuple[int, int] | None = None


@functools.lru_cache(maxsize=None)
def make_tables(q: int, n: int) -> NttTables:
    """Precompute twiddles (host-side Python bigints, cached)."""
    psi = primes_mod.root_of_unity(q, 2 * n)
    brv = bit_reverse_indices(n)
    fwd = np.array([pow(psi, int(b), q) for b in brv], dtype=np.int64)
    psi_inv = pow(psi, q - 2, q)
    inv = np.array([pow(psi_inv, int(b), q) for b in brv], dtype=np.int64)
    eps, shifts = modmath.mul_barrett_constants([q])
    return NttTables(
        q=q,
        n=n,
        psi=psi,
        fwd=fwd,
        inv=inv,
        half=(q + 1) // 2,
        mul_eps=int(eps[0]) if eps is not None else None,
        mul_shifts=shifts,
    )


# --------------------------------------------------------------------------
# Transforms (single modulus; q/half/eps scalars or 0-d arrays, shifts
# static python ints)
# --------------------------------------------------------------------------


def ntt_raw(a: jax.Array, fwd: jax.Array, q, eps=None, shifts=None) -> jax.Array:
    """Forward NWC NTT, natural-in, bit-reversed-out. Last-axis transform."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    m, t = 1, n
    while m < n:
        t //= 2
        w = fwd[m : 2 * m]  # static slice
        x = a.reshape(lead + (m, 2, t))
        u = x[..., 0, :]
        v = mul_mod(x[..., 1, :], w[:, None], q, eps, shifts)
        a = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-2)
        a = a.reshape(lead + (n,))
        m *= 2
    return a


def intt_raw(a: jax.Array, inv: jax.Array, q, half, eps=None, shifts=None) -> jax.Array:
    """Inverse NWC NTT, bit-reversed-in, natural-out; n^{-1} folded into the
    per-stage halving (paper Fig 9 / Eq 20-25)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    h, t = n // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        x = a.reshape(lead + (h, 2, t))
        u, v = x[..., 0, :], x[..., 1, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[:, None], q, eps, shifts)
        a = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-2)
        a = a.reshape(lead + (n,))
        h //= 2
        t *= 2
    return a


def ntt(a: jax.Array, tables: NttTables) -> jax.Array:
    return ntt_raw(
        a, jnp.asarray(tables.fwd), tables.q, tables.mul_eps, tables.mul_shifts
    )


def intt(a: jax.Array, tables: NttTables) -> jax.Array:
    return intt_raw(
        a,
        jnp.asarray(tables.inv),
        tables.q,
        tables.half,
        tables.mul_eps,
        tables.mul_shifts,
    )


def negacyclic_mul(a: jax.Array, b: jax.Array, tables: NttTables) -> jax.Array:
    """The no-shuffle cascade: NTT(a) ⊙ NTT(b) -> iNTT, zero permutations."""
    fa = ntt(a, tables)
    fb = ntt(b, tables)
    prod = mul_mod(fa, fb, tables.q, tables.mul_eps, tables.mul_shifts)
    return intt(prod, tables)


# --------------------------------------------------------------------------
# Multi-channel (RNS) variants: leading axis = RNS channel, one modulus each.
# This is the paper's "t parallel residue datapaths"; under pjit the channel
# axis shards over the `model` mesh axis.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static-safe
class ChannelTables:
    """Stacked per-channel twiddle tables + Barrett mul constants.

    Host arrays are the canonical values; the ``*_d`` cached properties
    hold the device-resident copies, uploaded exactly once per table
    object (call sites must NOT re-wrap the host arrays in
    ``jnp.asarray`` — that is the per-call H2D re-upload this class
    exists to eliminate).
    """

    qs: np.ndarray  # (t,)
    fwd: np.ndarray  # (t, n)
    inv: np.ndarray  # (t, n)
    half: np.ndarray  # (t,)
    mul_eps: np.ndarray | None = None  # (t,) Barrett eps, None outside envelope
    mul_shifts: tuple[int, int] | None = None  # static shift pair

    @property
    def n(self) -> int:
        return self.fwd.shape[-1]

    @property
    def t(self) -> int:
        return self.fwd.shape[0]

    # -- device-resident copies, uploaded once at construction time.
    # Eager (not lazy/cached) on purpose: a lazy first touch could happen
    # inside a jit trace, where jnp.asarray yields a tracer that must not
    # be cached.  Constructed host-side, these are concrete device arrays
    # that close over traces as constants.
    def __post_init__(self):
        object.__setattr__(self, "qs_d", jnp.asarray(self.qs))
        object.__setattr__(self, "fwd_d", jnp.asarray(self.fwd))
        object.__setattr__(self, "inv_d", jnp.asarray(self.inv))
        object.__setattr__(self, "half_d", jnp.asarray(self.half))
        object.__setattr__(
            self,
            "mul_eps_d",
            None if self.mul_eps is None else jnp.asarray(self.mul_eps),
        )


def make_channel_tables(qs, n: int) -> ChannelTables:
    tabs = [make_tables(int(q), n) for q in qs]
    eps, shifts = modmath.mul_barrett_constants([t.q for t in tabs])
    return ChannelTables(
        qs=np.array([t.q for t in tabs], dtype=np.int64),
        fwd=np.stack([t.fwd for t in tabs]),
        inv=np.stack([t.inv for t in tabs]),
        half=np.array([t.half for t in tabs], dtype=np.int64),
        mul_eps=eps,
        mul_shifts=shifts,
    )


def _eps_axes(ct: ChannelTables):
    """(eps array | dummy, vmap axis) — vmap needs a concrete operand."""
    if ct.mul_eps is None:
        return None, None
    return ct.mul_eps_d, 0


def ntt_channels(a: jax.Array, ct: ChannelTables) -> jax.Array:
    """a: (t, ..., n) -> (t, ..., n), channel c transformed mod qs[c]."""
    eps, ax = _eps_axes(ct)
    fn = functools.partial(ntt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, ax))(a, ct.fwd_d, ct.qs_d, eps)


def intt_channels(a: jax.Array, ct: ChannelTables) -> jax.Array:
    eps, ax = _eps_axes(ct)
    fn = functools.partial(intt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, ax))(
        a, ct.inv_d, ct.qs_d, ct.half_d, eps
    )


def negacyclic_mul_channels(a, b, ct: ChannelTables) -> jax.Array:
    """(t, ..., n) x (t, ..., n) — the full RNS-parallel no-shuffle cascade."""
    bshape = (ct.t,) + (1,) * (a.ndim - 1)
    q_b = ct.qs_d.reshape(bshape)
    eps_b = None if ct.mul_eps is None else ct.mul_eps_d.reshape(bshape)
    fa = ntt_channels(a, ct)
    fb = ntt_channels(b, ct)
    prod = mul_mod(fa, fb, q_b, eps_b, ct.mul_shifts)
    return intt_channels(prod, ct)
