"""RLWE/BFV somewhat-homomorphic layer on top of the PaReNTT multiplier.

The paper builds the *modular polynomial multiplier* that dominates HE
evaluation cost; this module is the HE scheme that consumes it, providing
the two applications shipped with the framework:

  * additively-homomorphic secure gradient aggregation (enc / ⊞ / dec) —
    the paper's federated-learning motivation [1];
  * encrypted linear-layer inference (ct x plaintext ⊠) — evaluation-side
    polynomial products running on the PaReNTT cascade.

Everything stays in RNS residue form (t, n); composition to bigints
happens only inside ``decrypt`` (client side).  ct x ct multiplication
with relinearization requires the BFV scaling step; a bigint reference
implementation lives in :mod:`repro.core.bfv_ref` (host-side, tested) —
matching paper scope, which cites HPS [33] for the full RNS variant.

The context is built on a :class:`repro.api.Plan` (``make_context``
resolves it once); every homomorphic product runs
:func:`repro.api.negacyclic_mul` on that plan.  Because the BFV layer
works on residue-domain tensors (it never re-enters segment form
between ops), ``backend="pallas_fused_e2e"`` degrades here to the fused
cascade for each product — the end-to-end single-kernel path serves the
segments->limbs pipeline of :func:`repro.api.polymul`.

SECURITY NOTE: parameters here are sized for systems evaluation, not for
a production 128-bit security level (that needs the full error analysis
of an audited library).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import bigint, rns as rns_mod
from repro.core.params import ParenttParams


class BfvContext(NamedTuple):
    plan: api.Plan
    pt_mod: int  # plaintext modulus p_t
    delta_res: np.ndarray  # (t,) floor(q / p_t) mod q_i
    noise_bound: int  # max magnitude of fresh noise samples

    @property
    def params(self) -> ParenttParams:
        """Host-side parameter object (kept for existing call sites)."""
        return self.plan.params


@dataclasses.dataclass
class Ciphertext:
    """BFV ciphertext in RNS coefficient form: c: (2, t, ..., n)."""

    c: jax.Array

    @property
    def batch_shape(self):
        return self.c.shape[2:-1]


def make_context(
    n: int = 4096, t: int = 6, v: int = 30, pt_mod: int = 1 << 24,
    backend: str = "jnp",
) -> BfvContext:
    plan = api.plan(n=n, t=t, v=v, backend=backend)
    delta = plan.q // pt_mod
    delta_res = np.array(
        [delta % int(q) for q in plan.params.plan.qs], dtype=np.int64
    )
    return BfvContext(
        plan=plan, pt_mod=pt_mod, delta_res=delta_res, noise_bound=8
    )


# --------------------------------------------------------------------------
# sampling (all RNS-resident; negatives lifted per channel)
# --------------------------------------------------------------------------


def _lift(x: jax.Array, qs: jax.Array) -> jax.Array:
    """Small signed values -> per-channel residues (t, ...)."""
    return (x[None, ...] + qs.reshape((-1,) + (1,) * x.ndim)) % qs.reshape(
        (-1,) + (1,) * x.ndim
    )


def _ternary(key, shape) -> jax.Array:
    return jax.random.randint(key, shape, -1, 2, dtype=jnp.int64)


def _noise(key, shape, bound: int) -> jax.Array:
    """Centered binomial-ish small noise in [-bound, bound]."""
    a = jax.random.randint(key, shape, 0, bound + 1, dtype=jnp.int64)
    b = jax.random.randint(jax.random.fold_in(key, 1), shape, 0, bound + 1, dtype=jnp.int64)
    return a - b


def _uniform_res(key, ctx: BfvContext, shape) -> jax.Array:
    """Uniform element of R_q in residue form (t, *shape)."""
    qs = np.asarray(ctx.params.plan.qs)
    chans = []
    for i, qi in enumerate(qs):
        chans.append(
            jax.random.randint(jax.random.fold_in(key, i), shape, 0, int(qi), dtype=jnp.int64)
        )
    return jnp.stack(chans)


# --------------------------------------------------------------------------
# keygen / encrypt / decrypt
# --------------------------------------------------------------------------


class KeyPair(NamedTuple):
    sk: jax.Array  # (t, n) residues of ternary secret
    pk: jax.Array  # (2, t, n)


def keygen(key: jax.Array, ctx: BfvContext) -> KeyPair:
    n = ctx.params.n
    qs = jnp.asarray(ctx.params.plan.qs)
    k_s, k_a, k_e = jax.random.split(key, 3)
    s = _ternary(k_s, (n,))
    s_res = _lift(s, qs)
    a = _uniform_res(k_a, ctx, (n,))
    e = _lift(_noise(k_e, (n,), ctx.noise_bound), qs)
    q_b = qs[:, None]
    # pk0 = -(a*s + e)
    as_ = api.negacyclic_mul(ctx.plan, a, s_res)
    pk0 = (q_b - (as_ + e) % q_b) % q_b
    return KeyPair(sk=s_res, pk=jnp.stack([pk0, a]))


def encrypt(key: jax.Array, m: jax.Array, kp: KeyPair, ctx: BfvContext) -> Ciphertext:
    """m: (..., n) ints in [0, pt_mod) -> ct (2, t, ..., n)."""
    qs = jnp.asarray(ctx.params.plan.qs)
    lead = m.shape[:-1]
    n = ctx.params.n
    k_u, k_e1, k_e2 = jax.random.split(key, 3)
    u = _lift(_ternary(k_u, lead + (n,)), qs)
    e1 = _lift(_noise(k_e1, lead + (n,), ctx.noise_bound), qs)
    e2 = _lift(_noise(k_e2, lead + (n,), ctx.noise_bound), qs)
    q_b = qs.reshape((-1,) + (1,) * (len(lead) + 1))
    pk0 = kp.pk[0].reshape((ctx.params.t,) + (1,) * len(lead) + (n,))
    pk1 = kp.pk[1].reshape((ctx.params.t,) + (1,) * len(lead) + (n,))
    pk0 = jnp.broadcast_to(pk0, (ctx.params.t,) + lead + (n,))
    pk1 = jnp.broadcast_to(pk1, (ctx.params.t,) + lead + (n,))
    dm = (m[None, ...] % ctx.pt_mod) * jnp.asarray(ctx.delta_res).reshape(q_b.shape)
    c0 = (api.negacyclic_mul(ctx.plan, pk0, u) + e1 + dm % q_b) % q_b
    c1 = (api.negacyclic_mul(ctx.plan, pk1, u) + e2) % q_b
    return Ciphertext(c=jnp.stack([c0, c1]))


def decrypt(ct: Ciphertext, kp: KeyPair, ctx: BfvContext) -> np.ndarray:
    """Host-side (client) decryption with exact bigint rounding."""
    phase = _phase(ct, kp, ctx)  # (t, ..., n) residues
    limbs = rns_mod.compose(phase, ctx.params.plan)  # (..., n, L)
    arr = np.asarray(limbs)
    flat = arr.reshape(-1, arr.shape[-1])
    q, pt = ctx.params.q, ctx.pt_mod
    out = np.empty(flat.shape[0], dtype=np.int64)
    for i, row in enumerate(flat):
        x = bigint.limbs_to_int(row, ctx.params.plan.w)
        out[i] = ((pt * x + q // 2) // q) % pt
    return out.reshape(arr.shape[:-1])


def _phase(ct: Ciphertext, kp: KeyPair, ctx: BfvContext) -> jax.Array:
    qs = jnp.asarray(ctx.params.plan.qs)
    lead = ct.c.shape[2:-1]
    n = ctx.params.n
    sk = jnp.broadcast_to(
        kp.sk.reshape((ctx.params.t,) + (1,) * len(lead) + (n,)),
        (ctx.params.t,) + lead + (n,),
    )
    q_b = qs.reshape((-1,) + (1,) * (len(lead) + 1))
    c1s = api.negacyclic_mul(ctx.plan, ct.c[1], sk)
    return (ct.c[0] + c1s) % q_b


def noise_budget_bits(ct: Ciphertext, kp: KeyPair, ctx: BfvContext, m: np.ndarray) -> float:
    """log2(q / (2*|noise|)) — remaining headroom (diagnostic, host)."""
    phase = _phase(ct, kp, ctx)
    limbs = rns_mod.compose(phase, ctx.params.plan)
    arr = np.asarray(limbs).reshape(-1, int(limbs.shape[-1]))
    q, pt = ctx.params.q, ctx.pt_mod
    delta = q // pt
    mm = np.asarray(m).reshape(-1)
    worst = 1
    for row, mi in zip(arr, mm):
        x = bigint.limbs_to_int(row, ctx.params.plan.w)
        noise = (x - delta * int(mi)) % q
        noise = min(noise, q - noise)
        worst = max(worst, noise)
    import math

    return math.log2(q) - 1 - math.log2(max(worst, 1))


# --------------------------------------------------------------------------
# homomorphic ops (evaluation side — this is what the cloud runs; every
# polynomial product goes through the PaReNTT cascade)
# --------------------------------------------------------------------------


def add(a: Ciphertext, b: Ciphertext, ctx: BfvContext) -> Ciphertext:
    qs = jnp.asarray(ctx.params.plan.qs)
    q_b = qs.reshape((1, -1) + (1,) * (a.c.ndim - 2))
    return Ciphertext(c=(a.c + b.c) % q_b)


def add_many(cts: list[Ciphertext], ctx: BfvContext) -> Ciphertext:
    qs = jnp.asarray(ctx.params.plan.qs)
    q_b = qs.reshape((1, -1) + (1,) * (cts[0].c.ndim - 2))
    acc = cts[0].c
    for ct in cts[1:]:
        acc = (acc + ct.c) % q_b
    return Ciphertext(c=acc)


def mul_plain(ct: Ciphertext, pt_poly: jax.Array, ctx: BfvContext) -> Ciphertext:
    """ct ⊠ plaintext polynomial (signed ints, small).  pt_poly: (..., n),
    broadcast against the ciphertext batch.  Both ciphertext components
    ride the PaReNTT multiplier."""
    qs = jnp.asarray(ctx.params.plan.qs)
    w = _lift(pt_poly, qs)  # (t, ..., n)
    tgt = ct.c[0].shape  # (t, ..., n)
    while w.ndim < len(tgt):
        w = w[:, None]
    w = jnp.broadcast_to(w, tgt)
    c0 = api.negacyclic_mul(ctx.plan, ct.c[0], w)
    c1 = api.negacyclic_mul(ctx.plan, ct.c[1], w)
    return Ciphertext(c=jnp.stack([c0, c1]))
