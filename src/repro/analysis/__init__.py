"""Static verification of the crypto kernel paths (DESIGN.md §9).

Three passes over the *traced jaxprs* of every kernel datapath, per
registered ``(n, t, v, backend, schedule)`` preset:

* **overflow / envelope** (:mod:`repro.analysis.interp`) — an interval
  abstract interpretation with a q-linear bound domain that proves no
  int64/int32 intermediate can overflow, derives the per-stage lazy
  window envelope and checks it against the hand-kept
  :class:`repro.core.ntt.ChannelTables` bookkeeping, and proves the
  single exit ``canonicalize`` suffices (transform outputs canonical);
* **lane / layout lint** (:mod:`repro.analysis.passes`) — re-verifies
  ``sublane_stages == 0`` structurally for the four-step schedule and
  estimates per-``pallas_call`` VMEM footprint against the budget;
* **staticness lint** (:mod:`repro.analysis.passes`) — flags host table
  constants baked into int64 kernel traces that should be Plan pytree
  leaves (mechanizing the PR-5 leaf-threading invariant).

Front doors: :func:`repro.analysis.verify.verify_plan` (re-exported as
``repro.verify_plan``) and the ``repro.launch.verify_kernels`` CLI; the
``verify-kernels`` CI job sweeps every registered preset and runs the
mutation self-check (:func:`repro.analysis.verify.mutation_selfcheck`).
"""
from typing import Any

# Submodule attributes resolve lazily (PEP 562): kernels/ops.py imports
# repro.analysis.walk for its structural counters, while the verify pass
# imports kernels/ops.py for its cost models — eager imports here would
# close that cycle during package init.
_LAZY = {
    "AbsVal": ("repro.analysis.domain", "AbsVal"),
    "units_of_q": ("repro.analysis.domain", "units_of_q"),
    "AnalysisContext": ("repro.analysis.interp", "AnalysisContext"),
    "Finding": ("repro.analysis.interp", "Finding"),
    "analyze_closed_jaxpr": ("repro.analysis.interp", "analyze_closed_jaxpr"),
    "PRESETS": ("repro.analysis.verify", "PRESETS"),
    "Preset": ("repro.analysis.verify", "Preset"),
    "VerifyReport": ("repro.analysis.verify", "VerifyReport"),
    "mutation_selfcheck": ("repro.analysis.verify", "mutation_selfcheck"),
    "registered_presets": ("repro.analysis.verify", "registered_presets"),
    "verify_plan": ("repro.analysis.verify", "verify_plan"),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str) -> Any:
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)
