"""Pallas kernels for RNS pre-processing (residual computation, Alg 1/2
with SAU strength reduction) and post-processing (inverse CRT, Eq 10).

Hardware mapping notes
----------------------
* Pre-processing: each RNS channel is its *own specialized circuit* in the
  paper (the SAU wiring is fixed by beta_i's signed-PoT terms).  We mirror
  that exactly: one pallas_call per channel with the shift/add network
  baked in statically — shifts and adds only, no integer multiplier, on
  the VPU int lanes.
* Post-processing: the (t -> limbs) recombination is a static einsum-like
  network: v-bit x w-bit limb products, a carry ripple (static L-step
  loop), and (t-1) conditional big-int subtractions.  No reduction over
  the wide modulus q ever materializes (Fig 16(b)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import modmath
from repro.core import rns as rns_mod

BLK = 256  # coefficients per grid step


# --------------------------------------------------------------------------
# pre-processing (one specialized kernel per channel, SAU network static)
# --------------------------------------------------------------------------


def _make_decompose_kernel(qi: int, v: int, beta_terms, seg_count: int, t_prime: int,
                           block_consts):
    """Returns a kernel closure with the channel's SAU circuit baked in."""
    v1 = beta_terms[0][0]
    c_sau = v + v1 + 3
    eps, s1, s2 = modmath.barrett_constants(qi, c_sau, v)
    epsa, sa1, sa2 = modmath.barrett_constants(qi, v + 3, v)
    n_blocks = -(-seg_count // t_prime)

    def sau(z):
        acc = -z
        for e, s in beta_terms:
            acc = acc + s * (z << e)
        return acc

    def red(x):
        return modmath.barrett_reduce(x, qi, eps, s1, s2)

    def kernel(z_ref, o_ref):
        z = z_ref[...]  # (blk, S)
        acc = jnp.zeros(z.shape[:-1], dtype=z.dtype)
        for rho in range(n_blocks):
            blk = z[..., rho * t_prime]
            if t_prime > 1 and rho * t_prime + 1 < seg_count:
                blk = blk + sau(z[..., rho * t_prime + 1])
            for k in range(2, t_prime):
                if rho * t_prime + k >= seg_count:
                    break
                x = red(sau(z[..., rho * t_prime + k]))
                for _ in range(k - 1):
                    x = red(sau(x))
                blk = blk + x
            blk = red(blk)
            if rho == 0:
                acc = acc + blk
            else:
                acc = acc + (blk * int(block_consts[rho])) % qi
        o_ref[...] = modmath.barrett_reduce(acc, qi, epsa, sa1, sa2)

    return kernel


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def decompose_pallas(z, *, plan: rns_mod.RnsPlan, interpret: bool = True):
    """z: (rows, S) segments -> residues (t, rows).  One specialized
    pallas_call per RNS channel (= per hardware circuit)."""
    rows, S = z.shape
    pad = (-rows) % BLK
    zp = jnp.pad(z, ((0, pad), (0, 0))) if pad else z
    outs = []
    for i in range(plan.t):
        kern = _make_decompose_kernel(
            int(plan.qs[i]),
            plan.v,
            plan.beta_terms[i],
            plan.seg_count,
            plan.t_prime,
            plan.block_consts[i],
        )
        out = pl.pallas_call(
            kern,
            grid=(zp.shape[0] // BLK,),
            in_specs=[pl.BlockSpec((BLK, S), lambda r: (r, 0))],
            out_specs=pl.BlockSpec((BLK,), lambda r: (r,)),
            out_shape=jax.ShapeDtypeStruct((zp.shape[0],), z.dtype),
            interpret=interpret,
        )(zp)
        outs.append(out[:rows])
    return jnp.stack(outs)


# --------------------------------------------------------------------------
# post-processing (Eq 10)
# --------------------------------------------------------------------------


def _make_compose_kernel(plan: rns_mod.RnsPlan):
    t, L, w = plan.t, plan.L, plan.w
    mask = (1 << w) - 1

    def kernel(res_ref, qs_ref, tilde_ref, star_ref, qlimb_ref, o_ref):
        res = res_ref[...]  # (t, blk)
        tilde = tilde_ref[...]  # (t, 1)
        star = star_ref[...]  # (t, L)
        qs = qs_ref[...]  # (t, 1)
        y = (res * tilde) % qs  # (t, blk)
        contrib = y[:, :, None] * star[:, None, :]  # (t, blk, L)
        acc = contrib.sum(axis=0)  # (blk, L)
        # carry ripple (static)
        outs = []
        carry = jnp.zeros_like(acc[:, 0])
        for i in range(L):
            s = acc[:, i] + carry
            outs.append(s & mask)
            carry = s >> w
        acc = jnp.stack(outs, axis=-1)
        # (t-1) conditional big-int subtractions of q
        qlimbs = qlimb_ref[0]  # (L,)
        for _ in range(t - 1):
            ge = jnp.ones(acc.shape[:1], dtype=bool)
            decided = jnp.zeros(acc.shape[:1], dtype=bool)
            for i in range(L - 1, -1, -1):
                gt = acc[:, i] > qlimbs[i]
                lt = acc[:, i] < qlimbs[i]
                ge = jnp.where(~decided & gt, True, ge)
                ge = jnp.where(~decided & lt, False, ge)
                decided = decided | gt | lt
            borrow = jnp.zeros_like(acc[:, 0])
            subbed = []
            for i in range(L):
                d = acc[:, i] - qlimbs[i] - borrow
                neg = d < 0
                subbed.append(jnp.where(neg, d + (1 << w), d))
                borrow = neg.astype(acc.dtype)
            sub = jnp.stack(subbed, axis=-1)
            acc = jnp.where(ge[:, None], sub, acc)
        o_ref[...] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def compose_pallas(residues, *, plan: rns_mod.RnsPlan, interpret: bool = True):
    """residues: (t, rows) -> limbs (rows, L) of the composed value mod q."""
    t, rows = residues.shape
    L = plan.L
    pad = (-rows) % BLK
    rp = jnp.pad(residues, ((0, 0), (0, pad))) if pad else residues
    kern = _make_compose_kernel(plan)
    out = pl.pallas_call(
        kern,
        grid=(rp.shape[1] // BLK,),
        in_specs=[
            pl.BlockSpec((t, BLK), lambda r: (0, r)),
            pl.BlockSpec((t, 1), lambda r: (0, 0)),
            pl.BlockSpec((t, 1), lambda r: (0, 0)),
            pl.BlockSpec((t, L), lambda r: (0, 0)),
            pl.BlockSpec((1, L), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLK, L), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rp.shape[1], L), residues.dtype),
        interpret=interpret,
    )(
        rp,
        plan.qs_d.reshape(t, 1),
        plan.qi_tilde_d.reshape(t, 1),
        plan.qi_star_limbs_d,
        plan.q_limbs_d.reshape(1, L),
    )
    return out[:rows]
