"""The fault-injection harness and the engine's exactly-once contract
under arbitrary seeded fault schedules.

The property test proper runs under ``hypothesis`` when installed; a
seeded parametrized sweep covers the same invariant unconditionally, so
the contract is exercised in every environment (the shim in
``_hypothesis_fallback`` turns ``@given`` tests into skips when the
package is absent)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised on bare CI images
    from _hypothesis_fallback import given, settings, st

import repro
from repro import api
from repro.errors import EngineError
from repro.serve.crypto_engine import PolymulEngine
from repro.serve.faults import (
    FaultInjector,
    FaultRule,
    InjectedFault,
    spot_check,
)


def _mk(pl, rng):
    shape = (pl.n, pl.config.seg_count)
    return (
        rng.integers(0, 1 << pl.v, size=shape),
        rng.integers(0, 1 << pl.v, size=shape),
    )


class TestInjector:
    def test_rule_validation(self):
        with pytest.raises(ValueError, match="raise/delay/corrupt"):
            FaultRule("explode")
        with pytest.raises(ValueError, match="rate"):
            FaultRule("raise", rate=1.5)

    def test_schedule_is_deterministic(self):
        """Same (rules, seed, call sequence) -> identical fault log."""
        pl = repro.plan(n=64, t=3, v=30)

        def run(seed):
            inj = FaultInjector(
                [
                    FaultRule("raise", rate=0.3, max_count=3),
                    FaultRule("corrupt", rate=0.3, at=(5,)),
                ],
                seed=seed,
            )
            fn = inj.wrap(lambda p, a, b: np.zeros((1,), np.int64))
            for _ in range(20):
                try:
                    fn(pl, None, None)
                except InjectedFault:
                    pass
            return list(inj.log)

        assert run(7) == run(7)
        assert run(7) != run(8)  # the seed actually matters
        assert any(i == 5 and k == "corrupt" for i, k, _ in run(7))

    def test_raise_beats_corrupt_and_quiesce(self):
        pl = repro.plan(n=64, t=3, v=30)
        inj = FaultInjector(
            [FaultRule("raise", at=(0,), rate=0.0),
             FaultRule("corrupt", at=(0, 1), rate=0.0)],
            seed=0,
        )
        fn = inj.wrap(lambda p, a, b: np.zeros((2,), np.int64))
        with pytest.raises(InjectedFault):
            fn(pl, None, None)  # call 0: raise wins, corrupt never fires
        assert inj.indices("corrupt") == set()
        out = fn(pl, None, None)  # call 1: corrupt fires
        assert inj.indices("corrupt") == {1}
        assert np.array_equal(out, np.ones((2,), np.int64))
        inj.quiesce()
        fn(pl, None, None)
        assert len(inj.log) == 2  # nothing fires after quiesce

    def test_corruption_detected_by_spot_check(self):
        rng = np.random.default_rng(0)
        eng = PolymulEngine(batch_slots=2)
        pl = eng.plan(n=64, t=3, v=30)
        inj = FaultInjector([FaultRule("corrupt", at=(0,), rate=0.0)],
                            seed=0).install(eng)
        za, zb = _mk(pl, rng)
        fut = eng.submit(pl, za, zb)
        eng.run_until_idle()
        assert fut.exception() is None  # corruption is engine-invisible
        assert fut.dispatch_index in inj.indices("corrupt")
        assert not spot_check(pl, za, zb, fut.result())
        # a clean re-serve passes both detection arms
        za2, zb2 = _mk(pl, rng)
        fut2 = eng.submit(pl, za2, zb2)
        eng.run_until_idle()
        assert spot_check(pl, za2, zb2, fut2.result())
        assert spot_check(pl, za2, zb2, fut2.result(), use_oracle=True)


def _exactly_once_under_schedule(seed: int) -> None:
    """THE property: under an arbitrary seeded schedule of raises,
    delays, and corruptions, every submitted request resolves exactly
    once — a value or a typed EngineError, no losses, no duplicates —
    and every un-corrupted result is bit-exact vs api.polymul."""
    rng = np.random.default_rng(seed)
    eng = PolymulEngine(
        batch_slots=4, max_retries=8, breaker_threshold=2,
        breaker_cooldown_s=0.02, backoff_base_s=1e-4,
    )
    plans = [eng.plan(n=64, t=3, v=30), eng.plan(n=32, t=4, v=45)]
    inj = FaultInjector(
        [
            FaultRule("raise", rate=float(rng.uniform(0.05, 0.3)),
                      max_count=int(rng.integers(1, 6))),
            FaultRule("delay", rate=0.1, delay_s=0.001, max_count=4),
            FaultRule("corrupt", rate=float(rng.uniform(0.05, 0.3)),
                      max_count=int(rng.integers(1, 5))),
        ],
        seed=seed,
    ).install(eng)
    entries = []
    for i in range(24):
        pl = plans[i % 2]
        za, zb = _mk(pl, rng)
        entries.append((pl, za, zb, eng.submit(pl, za, zb)))
    eng.run_until_idle()

    assert eng.pending() == 0
    s = eng.stats
    assert s["served"] + s["shed"] + s["failed"] == s["submitted"] == 24
    corrupt_idx = inj.indices("corrupt")
    for pl, za, zb, fut in entries:
        assert fut.done(), "future lost (never resolved)"
        if fut.state == "FAILED":
            assert isinstance(fut.exception(), EngineError)
            continue
        want = np.asarray(api.polymul(pl, za[None], zb[None]))[0]
        if fut.dispatch_index in corrupt_idx:
            assert not np.array_equal(fut.result(), want)
        else:
            assert np.array_equal(fut.result(), want)
    # exactly-once: the lifecycle refuses a second transition
    fut = entries[0][3]
    with pytest.raises(RuntimeError, match="resolved twice"):
        fut._fail(RuntimeError("dup"))


class TestExactlyOnceProperty:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_exactly_once_seeded(self, seed):
        _exactly_once_under_schedule(seed)

    @given(seed=st.integers(min_value=0, max_value=2**20))
    @settings(max_examples=8, deadline=None)
    def test_exactly_once_property(self, seed):
        _exactly_once_under_schedule(seed)


@pytest.mark.slow
def test_soak_smoke_end_to_end():
    """An importable mini-run of the CI soak driver: all gates green on
    a reduced request count (the full 500+-request soak is the
    serve-soak CI step)."""
    from repro.launch.serve_soak import run_soak

    record = run_soak(requests=120, seed=0)
    assert record["failures"] == []
    assert record["breaker_opened"] >= 1
    assert record["breaker_recovered"] >= 1
    assert record["faults"]["corrupted"] >= 1
