"""Pallas TPU kernels for the batched NWC NTT / iNTT and the fused
no-shuffle polynomial-multiplication cascade (paper contribution 1 mapped
to the TPU memory hierarchy).

TPU mapping
-----------
* One grid step processes a (ROWS, n) tile of polynomials for one RNS
  channel, resident in VMEM; twiddles (n,) for that channel are also VMEM
  blocks.  Per-channel moduli arrive as (1, 1) SMEM-style scalar blocks.
* The fused kernel runs NTT(a), NTT(b), the pointwise product and the
  iNTT inside ONE pallas_call: the NTT-domain product never exists in HBM.
  This is the TPU analogue of the paper's buffer-free NTT->iNTT cascade —
  on the FPGA the eliminated resource is the DSD shuffle buffer; here it
  is an HBM round-trip of 2 x ROWS x n x 8 bytes per channel.
* Butterfly pairing is expressed as reshapes (m, 2, t) of the trailing
  axis.  Stages with pair stride >= 128 keep the lane dimension intact;
  for stride < 128 a real-TPU deployment flips to the transposed-tile
  schedule (see DESIGN.md §6) — numerically identical, validated here in
  interpret mode.

VMEM budget per grid step (n = 4096, ROWS = 8, int64):
  a, b tiles 2 x 256 KiB + twiddles 2 x 32 KiB + scratch ≈ 0.8 MiB << 128 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8


def _fwd_stages(a, fwd, q):
    """CT/DIT stages on the last axis of a (rows, n) tile."""
    rows, n = a.shape
    m, t = 1, n
    while m < n:
        t //= 2
        w = jax.lax.slice_in_dim(fwd, m, 2 * m)  # static bounds
        x = a.reshape(rows, m, 2, t)
        u = x[:, :, 0, :]
        v = (x[:, :, 1, :] * w[None, :, None]) % q
        s = u + v
        s = jnp.where(s >= q, s - q, s)
        d = u - v
        d = jnp.where(d < 0, d + q, d)
        a = jnp.stack([s, d], axis=2).reshape(rows, n)
        m *= 2
    return a


def _inv_stages(a, inv, q, half):
    """Mirror-order GS stages with the per-stage halving (Fig 9 PE)."""
    rows, n = a.shape
    h, t = n // 2, 1
    while h >= 1:
        w = jax.lax.slice_in_dim(inv, h, 2 * h)
        x = a.reshape(rows, h, 2, t)
        u, v = x[:, :, 0, :], x[:, :, 1, :]
        s = u + v
        s = jnp.where(s >= q, s - q, s)
        d = u - v
        d = jnp.where(d < 0, d + q, d)
        d = (d * w[None, :, None]) % q
        s = (s >> 1) + (s & 1) * half
        d = (d >> 1) + (d & 1) * half
        a = jnp.stack([s, d], axis=2).reshape(rows, n)
        h //= 2
        t *= 2
    return a


# --------------------------------------------------------------------------
# kernels
# --------------------------------------------------------------------------


def _ntt_kernel(q_ref, fwd_ref, a_ref, o_ref):
    q = q_ref[0]
    o_ref[...] = _fwd_stages(a_ref[...], fwd_ref[...], q)


def _intt_kernel(q_ref, half_ref, inv_ref, a_ref, o_ref):
    q = q_ref[0]
    half = half_ref[0]
    o_ref[...] = _inv_stages(a_ref[...], inv_ref[...], q, half)


def _fused_kernel(q_ref, half_ref, fwd_ref, inv_ref, a_ref, b_ref, o_ref):
    q = q_ref[0]
    half = half_ref[0]
    fa = _fwd_stages(a_ref[...], fwd_ref[...], q)
    fb = _fwd_stages(b_ref[...], fwd_ref[...], q)
    prod = (fa * fb) % q  # never leaves VMEM
    o_ref[...] = _inv_stages(prod, inv_ref[...], q, half)


# --------------------------------------------------------------------------
# pallas_call wrappers (grid = (channels, row_blocks))
# --------------------------------------------------------------------------


def _grid_specs(t: int, rows: int, n: int, row_blk: int):
    """Common BlockSpecs (leading channel axis squeezed with None):
    per-channel scalars, (n,) tables, (row_blk, n) data tiles."""
    scalar = pl.BlockSpec((None, 1), lambda c, r: (c, 0))
    table = pl.BlockSpec((None, n), lambda c, r: (c, 0))
    data = pl.BlockSpec((None, row_blk, n), lambda c, r: (c, r, 0))
    return scalar, table, data


def _pad_rows(x, row_blk):
    rows = x.shape[1]
    pad = (-rows) % row_blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, rows


@functools.partial(jax.jit, static_argnames=("row_blk", "interpret"))
def ntt_channels_pallas(a, qs, fwd, *, row_blk: int = DEFAULT_ROWS, interpret: bool = True):
    """a: (t, rows, n) -> forward NTT per channel.  qs: (t,), fwd: (t, n)."""
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        _ntt_kernel,
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, table, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(qs.reshape(t, 1), fwd, a)
    return out[:, :rows]


@functools.partial(jax.jit, static_argnames=("row_blk", "interpret"))
def intt_channels_pallas(a, qs, half, inv, *, row_blk: int = DEFAULT_ROWS, interpret: bool = True):
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        _intt_kernel,
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, scalar, table, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(qs.reshape(t, 1), half.reshape(t, 1), inv, a)
    return out[:, :rows]


@functools.partial(jax.jit, static_argnames=("row_blk", "interpret"))
def fused_polymul_pallas(
    a, b, qs, half, fwd, inv, *, row_blk: int = DEFAULT_ROWS, interpret: bool = True
):
    """(t, rows, n) x (t, rows, n) -> negacyclic products, fused cascade."""
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    b, _ = _pad_rows(b, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        _fused_kernel,
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, scalar, table, table, data, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(qs.reshape(t, 1), half.reshape(t, 1), fwd, inv, a, b)
    return out[:, :rows]
