"""Low-complexity negative-wrapped-convolution NTT / iNTT (paper §II-D, Fig 1,
supplementary Eq 14-25) with the *no-shuffle cascade* (contribution 1).

Design notes
------------
* Forward transform: decimation-in-time (CT) butterflies with the weights
  psi_{2n}^{(2k+1)} merged into the twiddles (Eq 16-19).  Natural-order
  input -> **bit-reversed** output.
* Inverse transform retraces the forward flow graph in reverse stage order
  (first inverse stage undoes the forward's last), with the inverse
  twiddles psi^{-brv(h+i)} and the factor n^{-1} folded in: every stage
  halves both butterfly outputs with the shift-and-conditional-add trick
  of Eq 24/25 (the paper's Fig 9 PE).  **Bit-reversed** input ->
  natural-order output.
* Because the pointwise product is order-agnostic, the cascade
  ``intt(ntt(a) * ntt(b))`` needs **zero permutations** — this is the
  data-flow-level content of the paper's different-folding-sets trick
  (the hardware folding/latency model itself lives in
  :mod:`repro.core.schedule`).
* Butterfly reduction: the scalar helpers live in
  :mod:`repro.core.modmath` (shared with the Pallas kernels so the two
  datapaths cannot drift).  When a configuration's moduli fit the
  63-bit-safe envelope (q < 2^31, uniform width — the paper's v=30
  preferred point), the butterfly multiply reduces with a precomputed
  per-channel Barrett constant instead of a generic ``%``.

All arithmetic is int64; residues must satisfy q < 2**31 so products fit
(the v<=30 fast path; the paper's preferred config).  The v=45 config is
served by the numpy-object oracle in :mod:`repro.core.polymul`.

Shapes: transforms operate on the last axis; any leading batch dims.  The
`*_channels` variants vmap over a leading RNS-channel axis with per-channel
moduli/tables; twiddles and moduli are device-resident (uploaded once per
table object, not per call).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import modmath
from repro.core import primes as primes_mod

# Re-exported so existing call sites (benchmarks, notebooks) keep working;
# the implementations live in modmath.
add_mod = modmath.add_mod
sub_mod = modmath.sub_mod
mul_mod = modmath.mul_mod
div2_mod = modmath.div2_mod


def bit_reverse_indices(n: int) -> np.ndarray:
    """Permutation p with p[i] = bit-reverse of i over log2(n) bits."""
    m = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    out = np.zeros_like(idx)
    for b in range(m):
        out |= ((idx >> b) & 1) << (m - 1 - b)
    return out


# --------------------------------------------------------------------------
# Four-step (Bailey) schedule: the SAME flow graph as the radix-2 loops,
# re-grouped for the TPU lane geometry.  View the length-n polynomial as
# an (n1, n2) tile (n2 = the lane-width factor): the first log2(n1)
# radix-2 stages pair at strides that are multiples of n2 — they are
# independent length-n1 column transforms whose twiddles are exactly the
# fwd[:n1] prefix (brv of i < n1 over log2(n) bits = brv_{n1}(i) * n2, so
# fwd[i] = (psi^{n2})^{brv_{n1}(i)}: the length-n1 NWC table for the root
# psi^{n2}).  The remaining log2(n2) stages pair INSIDE each row; after a
# tile transpose they pair along the sublane axis instead, with the
# twist correction merged into per-row twiddle tables (gather below) the
# same way the NWC weights psi are merged into the radix-2 twiddles —
# zero extra multiplies and bit-identical output order.  Result: no
# butterfly stage ever pairs along the lane axis at stride < n2.
# --------------------------------------------------------------------------


def four_step_split(n: int) -> tuple[int, int]:
    """(n1, n2) tile for the lane-aligned schedule: n2 = 128 (the TPU
    lane width) when n >= 256, else n // 2 so at least one column stage
    exists.  Requires n a power of two >= 4."""
    if n < 4 or n & (n - 1):
        raise ValueError(
            f"four_step schedule needs a power-of-two n >= 4, got n={n}"
        )
    n2 = 128 if n >= 256 else n // 2
    return n // n2, n2


# Column lengths above this recurse into a further four-step split (the
# hierarchical schedule): at n = 2^13 the level-0 column length n1 = n/128
# crosses the 8-sublane vreg height and a (n1, 128) tile stops being a
# single-tile transpose, so the column transforms themselves re-split with
# the sublane factor 8 until the remaining column fits.
MAX_FS_COL = 32
SUB_ROW_FACTOR = 8  # TPU sublane height: deeper-level row length


def four_step_chain(n: int) -> tuple[tuple[int, int], ...]:
    """The canonical hierarchical split chain for a transform length n:
    per-level ``(columns, rows)`` factors, outermost first.

    Level 0 is :func:`four_step_split` (rows = the 128-lane factor);
    while the remaining column length exceeds ``MAX_FS_COL`` it re-splits
    with ``SUB_ROW_FACTOR`` rows.  Level ``l``'s columns are level
    ``l+1``'s transform length, so ``chain[l][0] == prod(chain[l+1:])``
    holds by construction.  Examples: n=4096 -> ((32, 128),); n=8192 ->
    ((64, 128), (8, 8)); n=65536 -> ((512, 128), (64, 8), (8, 8)).
    """
    n1, n2 = four_step_split(n)
    chain = [(n1, n2)]
    c = n1
    while c > MAX_FS_COL:
        c //= SUB_ROW_FACTOR
        chain.append((c, SUB_ROW_FACTOR))
    return tuple(chain)


def four_step_row_indices(n1: int, n2: int) -> np.ndarray:
    """(n2, n1) gather into a length-n stage table: the row-stage twiddle
    for transposed-tile entry (m', j) — m' = 2^k + l the DIT block index
    of a length-n2 transform, j the original row — is
    base[(n1 + j) * 2^k + l].  Applying this gather to ``fwd``/``inv``
    yields the twist-merged row tables; entry m' = 0 is never read (the
    stage loops slice [m : 2m] with m >= 1)."""
    idx = np.zeros((n2, n1), dtype=np.int64)
    for mp in range(1, n2):
        k = mp.bit_length() - 1
        low = mp - (1 << k)
        for j in range(n1):
            idx[mp, j] = ((n1 + j) << k) + low
    return idx


def stage_lane_strides(n: int, schedule) -> tuple[int, ...]:
    """Butterfly pair distance along the LANE (last tile) axis per stage
    of one transform — the structural definition the cost model's
    ``sublane_stages`` count is computed from.  radix2 pairs in the flat
    coefficient axis at strides n/2 .. 1; four_step pairs only along
    sublane-side axes of its tiles (at any hierarchy depth — deeper
    levels pair along reshaped sublane factors), so its lane-axis
    distance is 0 at every stage.  ``schedule`` may be a concrete string
    or a resolved :class:`repro.core.schedule.ScheduleSpec`."""
    stages = n.bit_length() - 1
    kind = getattr(schedule, "kind", schedule)
    if kind == "four_step":
        four_step_split(n)  # validate n
        return (0,) * stages
    if kind != "radix2":
        raise ValueError(f"unknown concrete schedule {schedule!r}")
    return tuple(n >> (s + 1) for s in range(stages))


class NttTables(NamedTuple):
    """Per-modulus twiddle tables for the merged-weight NWC transforms."""

    q: int
    n: int
    psi: int  # primitive 2n-th root of unity mod q
    fwd: np.ndarray  # (n,)  fwd[i] = psi^{brv(i)}    (CT/DIT stage tables)
    inv: np.ndarray  # (n,)  inv[i] = psi^{-brv(i)}   (mirror-order inverse)
    half: int  # (q + 1) / 2, for the div-by-2 PE (Eq 24)
    mul_eps: int | None = None  # Barrett eps for residue products (q<2^31)
    mul_shifts: tuple[int, int] | None = None


@functools.lru_cache(maxsize=None)
def make_tables(q: int, n: int) -> NttTables:
    """Precompute twiddles (host-side Python bigints, cached)."""
    psi = primes_mod.root_of_unity(q, 2 * n)
    brv = bit_reverse_indices(n)
    fwd = np.array([pow(psi, int(b), q) for b in brv], dtype=np.int64)
    psi_inv = pow(psi, q - 2, q)
    inv = np.array([pow(psi_inv, int(b), q) for b in brv], dtype=np.int64)
    eps, shifts = modmath.mul_barrett_constants([q])
    return NttTables(
        q=q,
        n=n,
        psi=psi,
        fwd=fwd,
        inv=inv,
        half=(q + 1) // 2,
        mul_eps=int(eps[0]) if eps is not None else None,
        mul_shifts=shifts,
    )


# --------------------------------------------------------------------------
# Transforms (single modulus; q/half/eps scalars or 0-d arrays, shifts
# static python ints)
# --------------------------------------------------------------------------


def ntt_raw(a: jax.Array, fwd: jax.Array, q, eps=None, shifts=None) -> jax.Array:
    """Forward NWC NTT, natural-in, bit-reversed-out. Last-axis transform."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    m, t = 1, n
    while m < n:
        t //= 2
        w = fwd[m : 2 * m]  # static slice
        x = a.reshape(lead + (m, 2, t))
        u = x[..., 0, :]
        v = mul_mod(x[..., 1, :], w[:, None], q, eps, shifts)
        a = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-2)
        a = a.reshape(lead + (n,))
        m *= 2
    return a


def intt_raw(a: jax.Array, inv: jax.Array, q, half, eps=None, shifts=None) -> jax.Array:
    """Inverse NWC NTT, bit-reversed-in, natural-out; n^{-1} folded into the
    per-stage halving (paper Fig 9 / Eq 20-25)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    h, t = n // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        x = a.reshape(lead + (h, 2, t))
        u, v = x[..., 0, :], x[..., 1, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[:, None], q, eps, shifts)
        a = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-2)
        a = a.reshape(lead + (n,))
        h //= 2
        t *= 2
    return a


def _hier_cols_fwd(x, fwd, sub_tabs, q, eps, shifts):
    """Length-c forward NWC NTT along axis -2 of a ``lead + (c, B)`` tile.

    ``sub_tabs`` is the tuple of twist-merged row tables for the remaining
    sub-splits of c (outermost first, each ``(sr, sc)``).  Empty tuple ->
    plain radix-2 column stages (twiddles = the ``fwd[:c]`` prefix).
    Non-empty -> recurse: view c = sc * sr, run the length-sc sub-column
    transform with sr folded into the batch axis (a pure reshape — only
    level 0 of the whole transform ever needs a physical transpose), then
    the length-sr sub-row stages with per-sub-column twist tables."""
    c, B = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    if not sub_tabs:
        m, tc = 1, c
        while m < c:
            tc //= 2
            w = fwd[m : 2 * m]
            y = x.reshape(lead + (m, 2, tc, B))
            u = y[..., 0, :, :]
            v = mul_mod(y[..., 1, :, :], w[:, None, None], q, eps, shifts)
            x = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-3)
            x = x.reshape(lead + (c, B))
            m *= 2
        return x
    rtab = sub_tabs[0]  # (sr, sc)
    sr, sc = rtab.shape
    # sub-columns: length-sc transform with sr collapsed into the batch
    x = x.reshape(lead + (sc, sr * B))
    x = _hier_cols_fwd(x, fwd, sub_tabs[1:], q, eps, shifts)
    x = x.reshape(lead + (sc, sr, B))
    # sub-rows: pair along the sr axis; twist tables indexed per sub-column
    m, tr = 1, sr
    while m < sr:
        tr //= 2
        w = jnp.swapaxes(rtab[m : 2 * m], 0, 1)[:, :, None, None]  # (sc,m,1,1)
        y = x.reshape(lead + (sc, m, 2, tr, B))
        u = y[..., 0, :, :]
        v = mul_mod(y[..., 1, :, :], w, q, eps, shifts)
        x = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-3)
        x = x.reshape(lead + (sc, sr, B))
        m *= 2
    return x.reshape(lead + (c, B))


def _hier_cols_inv(x, inv, sub_tabs, q, half, eps, shifts):
    """Inverse mirror of :func:`_hier_cols_fwd`: sub-row GS stages first,
    then the sub-column recursion, retracing the forward flow in reverse
    stage order (per-stage halving folds in the length factor)."""
    c, B = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    if not sub_tabs:
        h, tc = c // 2, 1
        while h >= 1:
            w = inv[h : 2 * h]
            y = x.reshape(lead + (h, 2, tc, B))
            u, v = y[..., 0, :, :], y[..., 1, :, :]
            s = add_mod(u, v, q)
            d = mul_mod(sub_mod(u, v, q), w[:, None, None], q, eps, shifts)
            x = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-3)
            x = x.reshape(lead + (c, B))
            h //= 2
            tc *= 2
        return x
    rtab = sub_tabs[0]  # (sr, sc)
    sr, sc = rtab.shape
    x = x.reshape(lead + (sc, sr, B))
    h, tr = sr // 2, 1
    while h >= 1:
        w = jnp.swapaxes(rtab[h : 2 * h], 0, 1)[:, :, None, None]  # (sc,h,1,1)
        y = x.reshape(lead + (sc, h, 2, tr, B))
        u, v = y[..., 0, :, :], y[..., 1, :, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w, q, eps, shifts)
        x = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-3)
        x = x.reshape(lead + (sc, sr, B))
        h //= 2
        tr *= 2
    x = x.reshape(lead + (sc, sr * B))
    x = _hier_cols_inv(x, inv, sub_tabs[1:], q, half, eps, shifts)
    return x.reshape(lead + (c, B))


def ntt_raw_hier(a, fwd, row_tabs, q, eps=None, shifts=None) -> jax.Array:
    """Forward NWC NTT via the (possibly hierarchical) four-step schedule
    — bit-identical to :func:`ntt_raw` at any depth (same flow graph,
    re-grouped).

    ``row_tabs`` is the per-level tuple of twist-merged row tables,
    outermost first: ``row_tabs[0]`` is the (n2, n1) level-0 table
    (``fwd[four_step_row_indices(n1, n2)]``); ``row_tabs[1:]`` are the
    (r_l, c_l) sub-level tables the column recursion consumes.  Column
    stages pair along sublane-side axes at every depth; level-0 rows pair
    along the former n2 axis after the one tile transpose — no butterfly
    ever pairs along the lane axis."""
    n = a.shape[-1]
    n2, n1 = row_tabs[0].shape
    lead = a.shape[:-1]
    x = a.reshape(lead + (n1, n2))
    x = _hier_cols_fwd(x, fwd, tuple(row_tabs[1:]), q, eps, shifts)
    xt = jnp.swapaxes(x, -1, -2)  # (n2, n1): row stages pair on sublanes
    row_fwd = row_tabs[0]
    m, tr = 1, n2
    while m < n2:
        tr //= 2
        wr = row_fwd[m : 2 * m]  # (m, n1): per-row twist-merged twiddles
        y = xt.reshape(lead + (m, 2, tr, n1))
        u = y[..., 0, :, :]
        v = mul_mod(y[..., 1, :, :], wr[:, None, :], q, eps, shifts)
        xt = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-3)
        xt = xt.reshape(lead + (n2, n1))
        m *= 2
    return jnp.swapaxes(xt, -1, -2).reshape(lead + (n,))


def intt_raw_hier(a, inv, row_tabs, q, half, eps=None, shifts=None) -> jax.Array:
    """Inverse mirror of :func:`ntt_raw_hier` — bit-identical to
    :func:`intt_raw` at any depth.  Level-0 row stages (transposed tile)
    first, then the hierarchical column inverse, retracing the forward
    flow in reverse stage order."""
    n = a.shape[-1]
    n2, n1 = row_tabs[0].shape
    lead = a.shape[:-1]
    xt = jnp.swapaxes(a.reshape(lead + (n1, n2)), -1, -2)  # (n2, n1)
    row_inv = row_tabs[0]
    h, tr = n2 // 2, 1
    while h >= 1:
        wr = row_inv[h : 2 * h]  # (h, n1)
        y = xt.reshape(lead + (h, 2, tr, n1))
        u, v = y[..., 0, :, :], y[..., 1, :, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), wr[:, None, :], q, eps, shifts)
        xt = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-3)
        xt = xt.reshape(lead + (n2, n1))
        h //= 2
        tr *= 2
    x = jnp.swapaxes(xt, -1, -2)  # back to (n1, n2)
    x = _hier_cols_inv(x, inv, tuple(row_tabs[1:]), q, half, eps, shifts)
    return x.reshape(lead + (n,))


def ntt_raw_four_step(a, fwd, row_fwd, q, eps=None, shifts=None) -> jax.Array:
    """Depth-1 four-step forward NTT (kept as the historical entry point;
    the general machinery is :func:`ntt_raw_hier`)."""
    return ntt_raw_hier(a, fwd, (row_fwd,), q, eps, shifts)


def intt_raw_four_step(a, inv, row_inv, q, half, eps=None, shifts=None) -> jax.Array:
    """Depth-1 four-step inverse NTT (see :func:`intt_raw_hier`)."""
    return intt_raw_hier(a, inv, (row_inv,), q, half, eps, shifts)


def ntt(a: jax.Array, tables: NttTables) -> jax.Array:
    return ntt_raw(
        a, jnp.asarray(tables.fwd), tables.q, tables.mul_eps, tables.mul_shifts
    )


def intt(a: jax.Array, tables: NttTables) -> jax.Array:
    return intt_raw(
        a,
        jnp.asarray(tables.inv),
        tables.q,
        tables.half,
        tables.mul_eps,
        tables.mul_shifts,
    )


def negacyclic_mul(a: jax.Array, b: jax.Array, tables: NttTables) -> jax.Array:
    """The no-shuffle cascade: NTT(a) ⊙ NTT(b) -> iNTT, zero permutations."""
    fa = ntt(a, tables)
    fb = ntt(b, tables)
    prod = mul_mod(fa, fb, tables.q, tables.mul_eps, tables.mul_shifts)
    return intt(prod, tables)


# --------------------------------------------------------------------------
# Multi-channel (RNS) variants: leading axis = RNS channel, one modulus each.
# This is the paper's "t parallel residue datapaths"; under pjit the channel
# axis shards over the `model` mesh axis.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static-safe
class ChannelTables:
    """Stacked per-channel twiddle tables + Barrett mul constants, plus
    the four-step row-table layout and the Harvey lazy-reduction
    (Shoup) constants with their window bookkeeping.

    Host arrays are the canonical values; the ``*_d`` cached properties
    hold the device-resident copies, uploaded exactly once per table
    object (call sites must NOT re-wrap the host arrays in
    ``jnp.asarray`` — that is the per-call H2D re-upload this class
    exists to eliminate).
    """

    qs: np.ndarray  # (t,)
    fwd: np.ndarray  # (t, n)
    inv: np.ndarray  # (t, n)
    half: np.ndarray  # (t,)
    mul_eps: np.ndarray | None = None  # (t,) Barrett eps, None outside envelope
    mul_shifts: tuple[int, int] | None = None  # static shift pair
    # four-step layout: (t, n2, n1) twist-merged row tables (columns use
    # the fwd/inv [:, :n1] prefixes — no extra storage); None when n < 4
    fs_row_fwd: np.ndarray | None = None
    fs_row_inv: np.ndarray | None = None
    # hierarchical levels >= 1 of the canonical chain: per-level
    # (t, r_l, c_l) twist-merged sub-row tables; () when depth is 1
    fs_sub_fwd: tuple[np.ndarray, ...] = ()
    fs_sub_inv: tuple[np.ndarray, ...] = ()
    # Harvey lazy reduction: per-twiddle Shoup constants, same layouts as
    # their twiddle tables; None outside the 63-bit-safe lazy envelope
    fwd_shoup: np.ndarray | None = None
    inv_shoup: np.ndarray | None = None
    fs_row_fwd_shoup: np.ndarray | None = None
    fs_row_inv_shoup: np.ndarray | None = None
    fs_sub_fwd_shoup: tuple[np.ndarray, ...] | None = None
    fs_sub_inv_shoup: tuple[np.ndarray, ...] | None = None
    lazy_window: int | None = None  # butterfly values stay in [0, window*q)
    shoup_beta: int | None = None  # static Shoup shift

    @property
    def n(self) -> int:
        return self.fwd.shape[-1]

    @property
    def t(self) -> int:
        return self.fwd.shape[0]

    @property
    def fs_split(self) -> tuple[int, int]:
        return four_step_split(self.n)

    @property
    def fs_chain(self) -> tuple[tuple[int, int], ...]:
        return four_step_chain(self.n)

    def stage_bounds(self, inverse: bool = False):
        """Per-stage (value_bound, peak) in units of q under the lazy
        window — the bound bookkeeping validated at construction; None
        when lazy reduction is unavailable (strict butterflies keep
        everything canonical, bound 1)."""
        if self.lazy_window is None:
            return None
        return modmath.lazy_stage_bounds(
            self.lazy_window, self.n.bit_length() - 1, inverse=inverse
        )

    # -- device-resident copies, uploaded once at construction time.
    # Eager (not lazy/cached) on purpose: a lazy first touch could happen
    # inside a jit trace, where jnp.asarray yields a tracer that must not
    # be cached.  Constructed host-side, these are concrete device arrays
    # that close over traces as constants.
    def __post_init__(self):
        if self.lazy_window is not None:
            for q in np.atleast_1d(self.qs):
                modmath.validate_lazy_envelope(
                    int(q), self.lazy_window, self.shoup_beta
                )
        for name in (
            "qs",
            "fwd",
            "inv",
            "half",
            "mul_eps",
            "fs_row_fwd",
            "fs_row_inv",
            "fwd_shoup",
            "inv_shoup",
            "fs_row_fwd_shoup",
            "fs_row_inv_shoup",
        ):
            host = getattr(self, name)
            object.__setattr__(
                self, name + "_d", None if host is None else jnp.asarray(host)
            )
        for name in (
            "fs_sub_fwd",
            "fs_sub_inv",
            "fs_sub_fwd_shoup",
            "fs_sub_inv_shoup",
        ):
            host = getattr(self, name)
            object.__setattr__(
                self,
                name + "_d",
                None if host is None else tuple(jnp.asarray(h) for h in host),
            )


def make_channel_tables(qs, n: int) -> ChannelTables:
    tabs = [make_tables(int(q), n) for q in qs]
    eps, shifts = modmath.mul_barrett_constants([t.q for t in tabs])
    fwd = np.stack([t.fwd for t in tabs])
    inv = np.stack([t.inv for t in tabs])
    fs_row_fwd = fs_row_inv = None
    fs_sub_fwd: tuple[np.ndarray, ...] = ()
    fs_sub_inv: tuple[np.ndarray, ...] = ()
    if n >= 4:
        chain = four_step_chain(n)
        idx = four_step_row_indices(*chain[0])
        fs_row_fwd = fwd[:, idx]  # (t, n2, n1)
        fs_row_inv = inv[:, idx]
        # deeper levels: the level-l split factors a level-(l-1) COLUMN,
        # whose twiddles are the fwd[:c_{l-1}] prefix — so the sub-row
        # gather indexes straight into the full stage table as well
        # (all indices < c_{l-1} <= n).
        sub_f, sub_i = [], []
        for c_l, r_l in chain[1:]:
            sidx = four_step_row_indices(c_l, r_l)  # (r_l, c_l)
            sub_f.append(fwd[:, sidx])  # (t, r_l, c_l)
            sub_i.append(inv[:, sidx])
        fs_sub_fwd, fs_sub_inv = tuple(sub_f), tuple(sub_i)
    window, beta = modmath.lazy_params([t.q for t in tabs])
    shoups = {}

    def _shoup_stack(tab):
        return np.stack(
            [
                modmath.shoup_constants(tab[i], int(t.q), beta)
                for i, t in enumerate(tabs)
            ]
        )

    if window is not None:
        for name, tab in (
            ("fwd_shoup", fwd), ("inv_shoup", inv),
            ("fs_row_fwd_shoup", fs_row_fwd), ("fs_row_inv_shoup", fs_row_inv),
        ):
            if tab is not None:
                shoups[name] = _shoup_stack(tab)
        shoups["fs_sub_fwd_shoup"] = tuple(_shoup_stack(t_) for t_ in fs_sub_fwd)
        shoups["fs_sub_inv_shoup"] = tuple(_shoup_stack(t_) for t_ in fs_sub_inv)
    return ChannelTables(
        qs=np.array([t.q for t in tabs], dtype=np.int64),
        fwd=fwd,
        inv=inv,
        half=np.array([t.half for t in tabs], dtype=np.int64),
        mul_eps=eps,
        mul_shifts=shifts,
        fs_row_fwd=fs_row_fwd,
        fs_row_inv=fs_row_inv,
        fs_sub_fwd=fs_sub_fwd,
        fs_sub_inv=fs_sub_inv,
        lazy_window=window,
        shoup_beta=beta,
        **shoups,
    )


def _eps_axes(ct: ChannelTables):
    """(eps array | dummy, vmap axis) — vmap needs a concrete operand."""
    if ct.mul_eps is None:
        return None, None
    return ct.mul_eps_d, 0


def _hier_depth(ct: ChannelTables, schedule) -> int:
    """Resolve the four-step hierarchy depth for a schedule string or
    resolved spec: specs carry their depth; the plain ``"four_step"``
    string means the full canonical chain (depth 1 below n=8192)."""
    depth = getattr(schedule, "depth", None)
    if depth is None:
        depth = 1 + len(ct.fs_sub_fwd)
    return depth


def ntt_channels(
    a: jax.Array, ct: ChannelTables, schedule="radix2"
) -> jax.Array:
    """a: (t, ..., n) -> (t, ..., n), channel c transformed mod qs[c].

    ``schedule``: concrete string (``"radix2"``/``"four_step"``) or a
    resolved :class:`repro.core.schedule.ScheduleSpec`."""
    eps, ax = _eps_axes(ct)
    if getattr(schedule, "kind", schedule) == "four_step":
        depth = _hier_depth(ct, schedule)
        tabs = (ct.fs_row_fwd_d,) + tuple(ct.fs_sub_fwd_d[: depth - 1])
        fn = functools.partial(ntt_raw_hier, shifts=ct.mul_shifts)
        return jax.vmap(fn, in_axes=(0, 0, 0, 0, ax))(
            a, ct.fwd_d, tabs, ct.qs_d, eps
        )
    fn = functools.partial(ntt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, ax))(a, ct.fwd_d, ct.qs_d, eps)


def intt_channels(
    a: jax.Array, ct: ChannelTables, schedule="radix2"
) -> jax.Array:
    eps, ax = _eps_axes(ct)
    if getattr(schedule, "kind", schedule) == "four_step":
        depth = _hier_depth(ct, schedule)
        tabs = (ct.fs_row_inv_d,) + tuple(ct.fs_sub_inv_d[: depth - 1])
        fn = functools.partial(intt_raw_hier, shifts=ct.mul_shifts)
        return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, ax))(
            a, ct.inv_d, tabs, ct.qs_d, ct.half_d, eps
        )
    fn = functools.partial(intt_raw, shifts=ct.mul_shifts)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, ax))(
        a, ct.inv_d, ct.qs_d, ct.half_d, eps
    )


def negacyclic_mul_channels(
    a, b, ct: ChannelTables, schedule="radix2"
) -> jax.Array:
    """(t, ..., n) x (t, ..., n) — the full RNS-parallel no-shuffle cascade."""
    bshape = (ct.t,) + (1,) * (a.ndim - 1)
    q_b = ct.qs_d.reshape(bshape)
    eps_b = None if ct.mul_eps is None else ct.mul_eps_d.reshape(bshape)
    fa = ntt_channels(a, ct, schedule)
    fb = ntt_channels(b, ct, schedule)
    prod = mul_mod(fa, fb, q_b, eps_b, ct.mul_shifts)
    return intt_channels(prod, ct, schedule)
