"""Hand-rolled AdamW with global-norm clipping and cosine schedule.

Optimizer state mirrors param sharding (m/v are zeros_like(params) in f32),
so FSDP sharding of params extends to the optimizer for free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x * factor).astype(x.dtype), grads), g


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm,
        "lr": lr,
    }
