"""Post-SPMD HLO text analyzer: loop-aware FLOPs / HBM-traffic /
collective-bytes extraction.

Why: ``compiled.cost_analysis()`` counts a while-loop body ONCE, and every
model here scans over layers, so module-level numbers under-report by the
layer count (verified experimentally; see EXPERIMENTS §Dry-run notes).
This analyzer walks the computation call graph, multiplies while bodies by
their trip counts (parsed from the loop-condition compare), and sums:

  * flops            — dot ops only (2*M*N*K incl. batch dims): the
                       MXU-relevant count, matching MFU conventions.
  * hbm_bytes        — per top-level instruction of ENTRY / while bodies:
                       result + operand bytes of fusions/dots/collectives
                       (fusion interiors excluded = post-fusion traffic).
  * collective bytes — per collective op kind, result bytes, loop-scaled.

All shapes in post-SPMD HLO are per-device, so results are per-device.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce-start", "all-reduce", "all-gather-start", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute-start",
    "collective-permute",
)
_COLLECTIVE_CANON = {
    "all-reduce-start": "all-reduce",
    "all-gather-start": "all-gather",
    "collective-permute-start": "collective-permute",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}


def _shape_dims(type_str: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, dims, n))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, _, n in _shape_dims(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    line: str
    result_type: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    types: dict = dataclasses.field(default_factory=dict)  # %name -> type str
    ops: dict = dataclasses.field(default_factory=dict)  # %name -> op


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[^(\s])*?)\s*([\w\-]+)\(")


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            st = line.strip()
            # computation headers end with '{' and declare '(params) -> type'
            if st.endswith("{") and " -> " in st and " = " not in st:
                toks = st.split()
                nm = (toks[1] if toks[0] == "ENTRY" else toks[0]).split("(")[0]
                nm = nm.lstrip("%")
                if nm:
                    cur = Computation(name=nm, instrs=[])
                    if toks[0] == "ENTRY":
                        entry_name = nm
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result_type, op = om.groups()
        cur.instrs.append(Instr(name=name, op=op, line=line, result_type=result_type))
        cur.types[name] = result_type
        cur.ops[name] = op
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


_OPERAND_RE = re.compile(r"%[\w.\-]+")


def _operands(ins: Instr) -> list[str]:
    m = re.search(r"\((.*)$", ins.line)
    if not m:
        return []
    # stop at metadata/config annotations
    args = m.group(1)
    args = args.split("), ")[0]
    return _OPERAND_RE.findall(args)


def _called(line: str, key: str) -> str | None:
    m = re.search(rf"{key}=(%?[\w.\-]+)", line)
    return m.group(1).lstrip("%") if m else None


def trip_count(comps, while_line: str, cond_name: str) -> int:
    """Prefer the backend_config known_trip_count on the while op itself;
    fall back to parsing the condition's compare-against-constant."""
    m = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"', while_line)
    if m:
        return int(m.group(1))
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: dict[str, int] = {}
    direction = None
    for ins in cond.instrs:
        mc = re.search(r"constant\((\d+)\)", ins.line)
        if mc:
            consts[ins.name] = int(mc.group(1))
        md = re.search(r"direction=(LT|LE|GT|GE)", ins.line)
        if md:
            direction = md.group(1)
        if ins.op == "fusion":
            callee = _called(ins.line, "calls")
            if callee and callee in comps:
                for ins2 in comps[callee].instrs:
                    md2 = re.search(r"direction=(LT|LE|GT|GE)", ins2.line)
                    if md2:
                        direction = md2.group(1)
    if consts:
        c = max(consts.values())
        return c + 1 if direction in ("LE", "GE") else max(c, 1)
    return 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 * prod(result dims) * prod(contracting dims of lhs)."""
    res = _shape_dims(ins.result_type)
    if not res:
        return 0.0
    out_elems = res[0][2]
    ops = _operands(ins)
    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not ops or lc is None:
        return 0.0
    lhs_type = comp.types.get(ops[0], "")
    lhs_shapes = _shape_dims(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1] else []
    k = 1
    for idx in lc.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            k *= int(lhs_dims[int(idx)])
    return 2.0 * out_elems * k


_PASSTHROUGH = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast", ""}


def _instr_bytes(ins: Instr, comp: Computation) -> int:
    """result bytes (write) + operand bytes (reads), EXCLUDING operands that
    are loop-carried / parameter pass-throughs: a stacked-weights tensor
    entering a while body via get-tuple-element is physically read through
    its dynamic-slice fusion (whose *result* we count), not in full each
    iteration.

    dynamic-update-slice special case: the result aliases the input buffer
    (in-place update); physical traffic is ~2x the UPDATE slice, not the
    whole buffer (a scan writing 0.8 MB/iter into a 26 MB stacked buffer
    must not count 26 MB/iter)."""
    op_bytes = 0
    for op in _operands(ins):
        if comp.ops.get(op, "") in _PASSTHROUGH:
            continue
        op_bytes += _shape_bytes(comp.types.get(op, ""))
    if "dynamic-update-slice" in ins.name or ins.op == "dynamic-update-slice":
        result = _shape_bytes(ins.result_type)
        update = min(op_bytes, result)
        # buffer operand (== result size) may have been non-passthrough:
        if op_bytes >= result:
            update = op_bytes - result
        return 2 * update
    return _shape_bytes(ins.result_type) + op_bytes


class HloAnalysis:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self._flops_memo: dict[str, float] = {}

    # -------------------------------------------------------------- flops
    def flops(self, comp_name: str = "__entry__") -> float:
        if comp_name in self._flops_memo:
            return self._flops_memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        self._flops_memo[comp_name] = 0.0  # cycle guard
        total = 0.0
        for ins in comp.instrs:
            if ins.op == "dot":
                total += _dot_flops(ins, comp)
            elif ins.op == "fusion":
                callee = _called(ins.line, "calls")
                if callee:
                    total += self.flops(callee)
            elif ins.op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                if body:
                    total += trip_count(self.comps, ins.line, cond or "") * self.flops(body)
            elif ins.op in ("call", "conditional", "custom-call"):
                callee = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if callee:
                    total += self.flops(callee)
        self._flops_memo[comp_name] = total
        return total

    # -------------------------------------------------------------- bytes
    def hbm_bytes(self, comp_name: str = "__entry__", _depth: int = 0) -> float:
        comp = self.comps.get(comp_name)
        if comp is None or _depth > 32:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                if body:
                    total += trip_count(self.comps, ins.line, cond or "") * self.hbm_bytes(
                        body, _depth + 1
                    )
            elif ins.op in ("call", "conditional"):
                callee = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if callee:
                    total += self.hbm_bytes(callee, _depth + 1)
            elif ins.op in _SKIP_BYTES_OPS:
                continue
            else:
                total += _instr_bytes(ins, comp)
        return total

    # -------------------------------------------------- collective bytes
    def collectives(self, comp_name: str = "__entry__", _depth: int = 0) -> dict:
        comp = self.comps.get(comp_name)
        out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
               "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
        if comp is None or _depth > 32:
            return out

        def merge(d, mult=1.0):
            for k in d:
                if k == "total":
                    continue
                if k == "count":
                    out[k] += d[k]
                else:
                    out[k] += d[k] * mult

        for ins in comp.instrs:
            if ins.op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                if body:
                    merge(
                        self.collectives(body, _depth + 1),
                        trip_count(self.comps, ins.line, cond or ""),
                    )
            elif ins.op in ("call", "conditional", "fusion"):
                callee = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if callee:
                    merge(self.collectives(callee, _depth + 1))
            elif ins.op in _COLLECTIVES:
                kind = _COLLECTIVE_CANON.get(ins.op, ins.op)
                if kind in out:
                    out[kind] += _shape_bytes(ins.result_type)
                    out["count"] += 1
        out["total"] = sum(v for k, v in out.items() if k not in ("count", "total"))
        return out

    # ---------------------------------------------------- custom-call bytes
    def custom_calls(self, comp_name: str = "__entry__", _depth: int = 0) -> dict:
        """Per-call-target operand/result byte attribution for ``custom-call``
        ops, loop-scaled like :meth:`hbm_bytes`.

        Pallas kernels lower to ``custom-call`` on real accelerators
        (``tpu_custom_call`` under Mosaic, ``__gpu$xla.gpu.triton`` under
        Triton); on CPU interpret mode inlines the kernel body into plain
        HLO, so targets is empty there.  Operand + result bytes are the
        kernel's HBM contract: XLA cannot fuse across the call boundary, so
        everything crossing it is physical traffic."""
        out: dict = {"targets": {}, "count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
        comp = self.comps.get(comp_name)
        if comp is None or _depth > 32:
            return out

        def merge(d: dict, mult: float = 1.0) -> None:
            out["count"] += d["count"]
            out["operand_bytes"] += d["operand_bytes"] * mult
            out["result_bytes"] += d["result_bytes"] * mult
            for tgt, rec in d["targets"].items():
                cur = out["targets"].setdefault(
                    tgt, {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
                )
                cur["count"] += rec["count"]
                cur["operand_bytes"] += rec["operand_bytes"] * mult
                cur["result_bytes"] += rec["result_bytes"] * mult

        for ins in comp.instrs:
            if ins.op == "while":
                body = _called(ins.line, "body")
                cond = _called(ins.line, "condition")
                if body:
                    merge(
                        self.custom_calls(body, _depth + 1),
                        trip_count(self.comps, ins.line, cond or ""),
                    )
            elif ins.op in ("call", "conditional", "fusion"):
                callee = _called(ins.line, "calls") or _called(ins.line, "to_apply")
                if callee:
                    merge(self.custom_calls(callee, _depth + 1))
            elif ins.op == "custom-call":
                mt = re.search(r'custom_call_target="([^"]+)"', ins.line)
                tgt = mt.group(1) if mt else "<unknown>"
                op_bytes = 0
                for op in _operands(ins):
                    op_bytes += _shape_bytes(comp.types.get(op, ""))
                res_bytes = _shape_bytes(ins.result_type)
                rec = out["targets"].setdefault(
                    tgt, {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
                )
                rec["count"] += 1
                rec["operand_bytes"] += op_bytes
                rec["result_bytes"] += res_bytes
                out["count"] += 1
                out["operand_bytes"] += op_bytes
                out["result_bytes"] += res_bytes
        return out


def analyze(text: str) -> dict:
    h = HloAnalysis(text)
    coll = h.collectives()
    return {
        "flops": h.flops(),
        "hbm_bytes": h.hbm_bytes(),
        "collectives": coll,
        "custom_calls": h.custom_calls(),
    }
