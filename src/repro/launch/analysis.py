"""Compiled-artifact analysis: cost/memory extraction + collective-byte
parsing from post-SPMD HLO, and the three-term roofline model.

Hardware constants (TPU v5e target):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (per-chip effective, 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (handles tuples by summing)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in post-SPMD HLO (per-device
    shapes).  Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}."""
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        for kind in _COLLECTIVES:
            # match `<type> <op-name>(` with op kind at the start of rhs ops
            if re.match(rf"(\(|\w|\[|,|\s)*{kind}(\.\d+)?\(", rhs) or rhs.startswith(kind):
                out[kind] += _shape_bytes(rhs.split(kind)[0])
                count += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    coll_bytes: float  # per-device collective bytes
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def roofline_terms(cost: dict, coll: dict, n_devices: int) -> Roofline:
    """cost_analysis flops/bytes are for the whole computation on CPU
    backend (unpartitioned program flops); divide by device count.
    Collective bytes come from per-device post-SPMD HLO already."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    per_dev_flops = flops / n_devices
    per_dev_bytes = byts / n_devices
    return Roofline(
        flops=per_dev_flops,
        hbm_bytes=per_dev_bytes,
        coll_bytes=float(coll.get("total", 0)),
        compute_s=per_dev_flops / PEAK_FLOPS,
        memory_s=per_dev_bytes / HBM_BW,
        collective_s=float(coll.get("total", 0)) / ICI_BW,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference fwd) with N = active
    params, D = tokens processed by the step."""
    from repro.models import model as M
    import jax

    params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg)
    )
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    n_active = n_params
    if cfg.n_experts:
        # active = non-expert params + expert params * topk / E
        leaves = jax.tree_util.tree_leaves_with_path(params)
        expert = sum(
            int(x.size)
            for path, x in leaves
            if any(
                isinstance(p, jax.tree_util.DictKey) and p.key.startswith("we_")
                for p in path
            )
        )
        n_active = n_params - expert + expert * cfg.top_k / cfg.n_experts
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend quirks
        return {"error": str(e)}


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": str(e)}
