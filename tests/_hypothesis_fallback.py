"""Degrade-to-skip stand-ins for ``hypothesis`` (see pyproject `test` extra).

The property-test modules guard their import (the tier-1 suite previously
died at collection with ``ModuleNotFoundError: hypothesis``).  When the
real package is absent, these stubs keep every non-property test running
and turn each ``@given`` test into an individual skip instead of a
module-level collection error.
"""
from __future__ import annotations

import functools
import inspect

import pytest


class _AnyStrategy:
    """Accepts any ``st.<name>(...)`` call chain; never generates values."""

    def __getattr__(self, name):
        def make(*args, **kwargs):
            return self

        return make

    def __call__(self, *args, **kwargs):
        return self

    def map(self, fn):
        return self

    def filter(self, fn):
        return self


st = _AnyStrategy()


def given(*g_args, **g_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def skipped(*args, **kwargs):
            pytest.skip("hypothesis not installed")

        # Present the signature MINUS the hypothesis-provided arguments,
        # exactly as the real @given does: otherwise pytest either fails
        # to find @parametrize arguments on the wrapper or demands
        # fixtures for the strategy kwargs.
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in g_kwargs]
        if g_args:  # positional strategies consume trailing parameters
            params = params[: len(params) - len(g_args)]
        del skipped.__wrapped__  # stop inspect following back to fn
        skipped.__signature__ = sig.replace(parameters=params)
        return skipped

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco
