"""Serving engines: the LM slot-batching decode engine and the crypto
polymul batching engine (shape-bucketed continuous batching over the
plan/execute API, DESIGN §8), plus the deterministic fault-injection
harness that soaks the engine's failure semantics."""
from repro.serve.crypto_engine import (
    FALLBACK_NEXT,
    PolymulEngine,
    PolymulFuture,
    negacyclic_mul_sharded,
    polymul_sharded,
)
from repro.serve.engine import Engine
from repro.serve.faults import FaultInjector, FaultRule, InjectedFault, spot_check

__all__ = [
    "Engine",
    "FALLBACK_NEXT",
    "FaultInjector",
    "FaultRule",
    "InjectedFault",
    "PolymulEngine",
    "PolymulFuture",
    "negacyclic_mul_sharded",
    "polymul_sharded",
    "spot_check",
]
