"""Folding-set schedule model for the 2-parallel NTT -> iNTT cascade
(paper §III, Eq 1/2, Tables I/II, Fig 17; timing Eq 11-13).

The container has no FPGA, so contribution 1 is validated at the level the
paper itself argues it: the *schedule*.  We model the 2-parallel folded
pipeline exactly:

* Forward NTT last PE (PE_{m-1}) emits butterfly-pair k at clock
  (k - 1) mod n/2  (Table I row PE_{m-1}: folding order l -> node l+1).
* The iNTT's first stage needs, for its drawn-DFG node j (which pairs
  frequencies j and j + n/2), the *physical* pair produced by forward
  node rev(j): the forward output wire 2k carries frequency brv(k) and
  wire 2k+1 carries brv(k) + n/2.
* Therefore consuming with the **bit-reversed folding set** (Table II:
  folding order l -> node <l+1>) makes every pair's consumption clock
  equal its production clock — zero buffer, zero added latency.  With the
  *same* folding set as the NTT (the conventional choice) the pairs must
  wait, requiring an n/4-deep delay-switch-delay buffer and n/4 extra
  clocks (Fig 17).

``simulate_cascade`` computes production/consumption clocks and the
buffer occupancy for both schedules; tests assert the paper's claims
(0 vs n/4) for a sweep of n.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ntt import bit_reverse_indices


# --------------------------------------------------------------------------
# Timing model (Eq 11-13)
# --------------------------------------------------------------------------


def bpp_cycles(n: int) -> int:
    """Block processing period of the 2-parallel multiplier (Eq 11)."""
    return n // 2


def latency_cycles(n: int, t_pipe: int = 0, with_shuffle: bool = False) -> int:
    """Latency of one modular polynomial multiplication (Eq 12); the
    conventional shuffled cascade pays an extra n/4 (Fig 17)."""
    extra = n // 4 if with_shuffle else 0
    return (n - 2) + extra + t_pipe


def total_cycles(n: int, L: int, t_pipe: int = 0, with_shuffle: bool = False) -> int:
    """Clock cycles for L back-to-back multiplications (Eq 13)."""
    return latency_cycles(n, t_pipe, with_shuffle) + bpp_cycles(n) * L


# --------------------------------------------------------------------------
# Folding sets (Tables I and II)
# --------------------------------------------------------------------------


def ntt_folding_order(n: int, s: int) -> np.ndarray:
    """Table I: node index processed by PE_s at each folding clock l.
    PE_s at clock l processes node (2^{m-s-1} + l) mod n/2."""
    m = n.bit_length() - 1
    half = n // 2
    l = np.arange(half)
    return (2 ** (m - s - 1) + l) % half if s < m - 1 else (l + 1) % half


def intt_folding_order(n: int, s: int) -> np.ndarray:
    """Table II: node processed by iNTT PE_s at folding clock l; <x> is the
    bit-reverse over (m-1) bits."""
    m = n.bit_length() - 1
    half = n // 2
    brv = bit_reverse_indices(half)
    l = np.arange(half)
    if s == 0:
        return brv[(l + 1) % half]
    return brv[(2 - 2**s + l) % half]


# --------------------------------------------------------------------------
# Cascade buffer simulation
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CascadeSim:
    n: int
    max_buffer_pairs: int  # peak # of product pairs parked between NTT & iNTT
    added_latency: int  # extra clocks before iNTT can start consuming


def simulate_cascade(n: int, bit_reversed_intt: bool = True) -> CascadeSim:
    """Clock-accurate production/consumption simulation at the NTT->iNTT
    boundary of the 2-parallel cascade."""
    half = n // 2
    brv_half = bit_reverse_indices(half)
    # Production: forward PE_{m-1} emits physical pair k at clock (k-1) mod half.
    prod_clock = np.empty(half, dtype=np.int64)
    order = ntt_folding_order(n, n.bit_length() - 2)  # PE_{m-1} row
    for clock, node in enumerate(order):
        prod_clock[node] = clock
    # Consumption: iNTT drawn-node j needs physical pair rev(j).
    cons_clock = np.empty(half, dtype=np.int64)
    if bit_reversed_intt:
        intt_order = intt_folding_order(n, 0)  # Table II PE_0
    else:
        intt_order = (np.arange(half) + 1) % half  # same folding as NTT
    for clock, node in enumerate(intt_order):
        cons_clock[brv_half[node]] = clock
    # A pair produced at clock p and consumed at clock c >= p occupies the
    # buffer during [p, c).  If any c < p the schedule is infeasible in the
    # same period; it slips by `slip` full periods handled as added latency.
    slip = int(np.max(prod_clock - cons_clock).clip(min=0))
    cons_eff = cons_clock + slip
    occupancy = np.zeros(2 * half + 1, dtype=np.int64)
    for p, c in zip(prod_clock, cons_eff):
        occupancy[p] += 1
        occupancy[c] -= 1
    peak = int(np.max(np.cumsum(occupancy))) - 1  # pass-through pair not buffered
    return CascadeSim(n=n, max_buffer_pairs=max(peak, 0), added_latency=slip)


# --------------------------------------------------------------------------
# Resolved schedule specs (PR 7): the plan-time-frozen form of the
# `schedule=` knob.  `plan()` accepts the string vocabulary
# ("auto" | "radix2" | "four_step" | "four_step:h") plus an optional
# `tiling=` hint and resolves them HERE into a concrete, hashable
# ScheduleSpec — depth, per-level (columns, rows) splits, the e2e
# row-block streamed per grid step, and the VMEM accounting that chose
# it.  Jit keys, `plan_key`, verifier presets and serving bucket keys
# all see this one canonical form; no "auto" survives planning.
# --------------------------------------------------------------------------

from repro.core.ntt import four_step_chain  # noqa: E402
from repro.errors import UnknownKnobError, UnservableConfigError  # noqa: E402

#: Per-core VMEM budget the tile model resolves row blocks against
#: (mirrors the pallas accelerator guide; analysis.passes re-exports it).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

#: Preferred polynomials per grid step of the channel-tiled fused-e2e
#: kernel; halved until the tile model fits the budget.
DEFAULT_E2E_ROW_BLK = 4

SCHEDULE_STRINGS = ("auto", "radix2", "four_step", "four_step:h")


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """A fully-resolved NTT schedule: the hashable value frozen into
    ``PlanConfig.schedule``.

    Attributes
    ----------
    kind:
        Concrete schedule family, ``"radix2"`` or ``"four_step"`` —
        never ``"auto"``.
    splits:
        Per-level ``(columns, rows)`` tile factors of the hierarchical
        four-step chain, outermost first (``()`` for radix2).  Level 0's
        rows is the 128-lane factor; deeper levels re-split the column
        transform with the sublane factor.  Always the canonical
        :func:`repro.core.ntt.four_step_chain` — depth is decided at
        plan time from n alone, which is what keeps jit keys and
        verifier presets static.
    row_blk:
        Polynomials streamed per grid step of the channel-tiled
        fused-e2e kernel, resolved against the VMEM budget (0 when the
        config has no Pallas datapath, e.g. the wide width).
    vmem_budget:
        Budget in bytes the resolution was performed against.
    tile_bytes:
        The tile model's footprint for one grid step at ``row_blk``
        (0 when ``row_blk`` is 0).
    """

    kind: str
    splits: tuple[tuple[int, int], ...] = ()
    row_blk: int = 0
    vmem_budget: int = VMEM_BUDGET_BYTES
    tile_bytes: int = 0

    @property
    def depth(self) -> int:
        return len(self.splits)

    @property
    def canonical(self) -> str:
        """Round-trippable string form: the string vocabulary member this
        spec is the resolution of (plus the tile chain for display)."""
        if self.kind != "four_step":
            return self.kind
        if self.depth <= 1:
            return "four_step"
        return "four_step:h"

    def __str__(self) -> str:  # compact display for logs / bench tables
        if self.kind != "four_step":
            return self.kind
        tiles = "x".join(f"{c}.{r}" for c, r in self.splits)
        return f"four_step[{tiles}]"


def parse_schedule(schedule: str) -> tuple[str, bool]:
    """Validate a schedule string -> ``(request, hier_required)`` where
    request is ``"auto" | "radix2" | "four_step"``."""
    if not isinstance(schedule, str):
        raise UnknownKnobError(
            f"unknown schedule {schedule!r}: expected one of "
            f"{SCHEDULE_STRINGS} or a ScheduleSpec",
            knob="schedule",
            value=schedule,
            alternatives=SCHEDULE_STRINGS,
        )
    if schedule == "four_step:h":
        return "four_step", True
    if schedule in ("auto", "radix2", "four_step"):
        return schedule, False
    raise UnknownKnobError(
        f"unknown schedule {schedule!r}: expected one of {SCHEDULE_STRINGS}",
        knob="schedule",
        value=schedule,
        alternatives=SCHEDULE_STRINGS,
    )


def concrete_spec(n: int, schedule) -> ScheduleSpec:
    """Kernel-side normalization: a string or spec -> a ScheduleSpec with
    the canonical splits for n (row_blk/tile accounting left at 0 — use
    :func:`resolve_spec` for the full plan-time resolution)."""
    if isinstance(schedule, ScheduleSpec):
        return schedule
    kind, hier = parse_schedule(schedule)
    if kind == "auto":
        kind = "four_step" if n >= 256 else "radix2"
    if kind == "radix2":
        if hier:  # unreachable today ("radix2:h" is not vocabulary) — guard
            raise UnknownKnobError(
                "radix2 has no hierarchical form",
                knob="schedule", value=schedule, alternatives=("radix2",),
            )
        return ScheduleSpec(kind="radix2")
    splits = four_step_chain(n)
    if hier and len(splits) < 2:
        raise UnservableConfigError(
            f"schedule='four_step:h' requires a hierarchical chain but "
            f"n={n} resolves to the single-level split {splits[0]} "
            f"(hierarchy starts at n=8192)",
            knob="schedule",
            value="four_step:h",
            alternatives=("four_step", "auto"),
        )
    return ScheduleSpec(kind="four_step", splits=splits)


def tile_bytes_model(
    kind: str,
    n: int,
    splits: tuple[tuple[int, int], ...],
    row_blk: int,
    seg_count: int,
    limb_count: int,
    lazy: bool,
) -> int:
    """Per-grid-step VMEM footprint of the channel-tiled fused-e2e
    kernel (int64 elements x 8 bytes), mirroring what
    ``analysis.passes.lane_vmem_lint`` sums over the traced kernel's ref
    avals: one channel's twiddle tables (fwd + inv + both level-0 row
    tables = 4n entries for four_step, 2n for radix2, plus the small
    per-level sub-row tables; doubled again for the Shoup companions
    when the lazy envelope holds) plus ``row_blk`` rows of the two
    decomposed input operands (seg_count segment columns each) and the
    output limbs."""
    if kind == "four_step":
        tables = 4 * n + 2 * sum(c * r for c, r in splits[1:])
    else:
        tables = 2 * n
    if lazy:
        tables *= 2
    data = row_blk * n * (2 * seg_count + limb_count)
    return 8 * (tables + data)


def resolve_spec(
    n: int,
    schedule,
    *,
    tiling=None,
    row_blk: int | None = None,
    seg_count: int = 1,
    limb_count: int = 1,
    lazy: bool = True,
    budget: int = VMEM_BUDGET_BYTES,
) -> ScheduleSpec:
    """Full plan-time resolution of the schedule knobs into a
    :class:`ScheduleSpec`.

    ``tiling`` is an optional hint: an int is a row-block request
    (equivalent to ``row_blk=``); a tuple of per-level ``(columns,
    rows)`` pairs asserts the expected tile chain and is validated
    against the canonical one (the chain is a function of n alone — a
    mismatching assertion is an unservable config, not a knob we honor).
    When no row block is requested, ``DEFAULT_E2E_ROW_BLK`` is halved
    until the tile model fits the budget; if even ``row_blk=1`` does not
    fit, the config is unservable."""
    spec = concrete_spec(n, schedule)
    if tiling is not None:
        if isinstance(tiling, int):
            if row_blk is None:
                row_blk = tiling
        else:
            tiling = tuple(tuple(map(int, lvl)) for lvl in tiling)
            if tiling != spec.splits:
                raise UnservableConfigError(
                    f"tiling hint {tiling} does not match the canonical "
                    f"chain {spec.splits} for n={n}, schedule="
                    f"{spec.canonical!r} (splits are plan-time-static "
                    f"functions of n)",
                    knob="tiling",
                    value=tiling,
                    alternatives=(spec.splits,),
                )

    def fit(rb: int) -> int:
        return tile_bytes_model(
            spec.kind, n, spec.splits, rb, seg_count, limb_count, lazy
        )

    if row_blk is not None:
        if row_blk < 1 or row_blk & (row_blk - 1):
            raise UnknownKnobError(
                f"row_blk must be a positive power of two, got {row_blk}",
                knob="row_blk",
                value=row_blk,
                alternatives=(1, 2, 4, 8),
            )
        rb = row_blk
        if fit(rb) > budget:
            alts = [r for r in (1, 2, 4, 8) if r < rb and fit(r) <= budget]
            raise UnservableConfigError(
                f"row_blk={rb} needs {fit(rb)} bytes of VMEM per grid "
                f"step (> budget {budget}) at n={n}, S={seg_count}, "
                f"L={limb_count}",
                knob="row_blk",
                value=rb,
                alternatives=tuple(alts),
            )
    else:
        rb = DEFAULT_E2E_ROW_BLK
        while rb > 1 and fit(rb) > budget:
            rb //= 2
        if fit(rb) > budget:
            raise UnservableConfigError(
                f"no servable row block: even row_blk=1 needs {fit(1)} "
                f"bytes of VMEM per grid step (> budget {budget}) at "
                f"n={n}, S={seg_count}, L={limb_count}",
                knob="n",
                value=n,
                alternatives=(),
            )
    return dataclasses.replace(
        spec, row_blk=rb, vmem_budget=budget, tile_bytes=fit(rb)
    )
