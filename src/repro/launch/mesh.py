"""Production mesh construction.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the same axis names (tests / CPU execution)."""
    import numpy as np

    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def validate_mesh(mesh) -> dict:
    """Basic sanity facts recorded into EXPERIMENTS §Dry-run."""
    return {
        "axis_names": tuple(mesh.axis_names),
        "shape": dict(mesh.shape),
        "n_devices": int(np.prod(list(mesh.shape.values()))) if (np := __import__("numpy")) else 0,
    }
