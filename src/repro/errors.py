"""Structured error taxonomy: plan-time rejections (PR 7) and
serving-time failures (PR 8).

Every rejection in :func:`repro.plan` raises one of these instead of a
bare ``ValueError`` so callers (and serving front ends) can react to the
*shape* of the failure, not a message string:

* :class:`PlanError` — base class; subclasses ``ValueError`` so existing
  ``except ValueError`` call sites keep working.
* :class:`UnknownKnobError` — the value of a single knob is not in its
  vocabulary (unknown backend/schedule string, malformed ``n``/``v``).
* :class:`UnservableConfigError` — every knob is individually valid but
  the combination cannot be served (four_step depth beyond the canonical
  chain, a tile that cannot fit the VMEM budget at ``row_blk=1``, a
  Pallas backend on the wide width, the wide inverse-CRT overflow).

All three carry the offending ``knob`` name, the rejected ``value`` and
a tuple of nearest valid ``alternatives`` (may be empty when nothing is
close).

The serving layer (:mod:`repro.serve.crypto_engine`) has its own branch:
a request that cannot be served is *resolved* with one of these — its
future carries the error, it is never silently dropped:

* :class:`EngineError` — base class; subclasses ``RuntimeError`` (these
  are execution-time conditions, not configuration mistakes).
* :class:`QueueFullError` — bounded-submission-queue backpressure: the
  blocking ``submit(timeout=)`` expired while the queue stayed full.
  The only taxonomy member *raised at* the caller rather than stored on
  a future (the request was never admitted, so no future exists).
* :class:`DeadlineExceededError` — admission control shed the request:
  its deadline passed, or could not be met, before dispatch.
* :class:`BackendFailedError` — every dispatch attempt (bounded retry +
  backend degradation) failed; the last underlying exception rides in
  ``__cause__`` and the attribute fields say where it died.
"""
from __future__ import annotations

from typing import Any, Iterable


class PlanError(ValueError):
    """A configuration was rejected at plan time.

    Attributes
    ----------
    knob:
        Name of the offending keyword (``"backend"``, ``"schedule"``,
        ``"tiling"``, ``"row_blk"``, ``"n"``, ``"v"``, ...), or ``None``
        when the failure is not attributable to a single knob.
    value:
        The rejected value, verbatim.
    alternatives:
        Nearest valid values for that knob (possibly empty).
    """

    def __init__(
        self,
        message: str,
        *,
        knob: str | None = None,
        value: Any = None,
        alternatives: Iterable[Any] = (),
    ) -> None:
        super().__init__(message)
        self.knob = knob
        self.value = value
        self.alternatives = tuple(alternatives)


class UnknownKnobError(PlanError):
    """A single knob's value is outside its vocabulary."""


class UnservableConfigError(PlanError):
    """Individually-valid knobs combine into a config no datapath serves."""


class EngineError(RuntimeError):
    """A request failed at serving time (see module docstring).

    Attributes
    ----------
    request_seq:
        The engine-assigned submission sequence number of the affected
        request, or ``None`` when the failure is not per-request
        (``QueueFullError`` — the request was never admitted).
    """

    def __init__(self, message: str, *, request_seq: int | None = None) -> None:
        super().__init__(message)
        self.request_seq = request_seq


class QueueFullError(EngineError):
    """The bounded submission queue stayed full past the submit timeout.

    Attributes
    ----------
    queue_depth:
        Queued requests at the moment the timeout expired.
    max_pending:
        The engine's configured bound.
    """

    def __init__(
        self, message: str, *, queue_depth: int = 0, max_pending: int = 0
    ) -> None:
        super().__init__(message)
        self.queue_depth = queue_depth
        self.max_pending = max_pending


class DeadlineExceededError(EngineError):
    """Admission control shed the request: its deadline passed (or the
    estimated service time could not meet it) before dispatch.

    Attributes
    ----------
    deadline_s:
        The absolute deadline (engine clock) the request carried.
    late_s:
        How far past (or, for a cannot-be-met shed, short of) the
        deadline the shed decision fell, in seconds (>= 0).
    """

    def __init__(
        self,
        message: str,
        *,
        request_seq: int | None = None,
        deadline_s: float = 0.0,
        late_s: float = 0.0,
    ) -> None:
        super().__init__(message, request_seq=request_seq)
        self.deadline_s = deadline_s
        self.late_s = late_s


class BackendFailedError(EngineError):
    """Every dispatch attempt for the request failed — bounded retries
    (and any backend degradation the bucket's circuit breaker performed)
    included.  The final underlying exception is chained as
    ``__cause__``.

    Attributes
    ----------
    backend:
        The backend string of the last attempted dispatch.
    attempts:
        Dispatch attempts this request rode before being failed.
    """

    def __init__(
        self,
        message: str,
        *,
        request_seq: int | None = None,
        backend: str = "",
        attempts: int = 0,
    ) -> None:
        super().__init__(message, request_seq=request_seq)
        self.backend = backend
        self.attempts = attempts
