"""Host-side (Python bigint) BFV reference, including ct x ct multiplication
with relinearization — the piece the in-JAX layer delegates (the BFV
scaling step needs exact rational rounding; the paper likewise cites the
HPS RNS variant [33] rather than re-deriving it).

Used by tests as the oracle for the JAX layer and by examples needing a
multiplicative depth of 1+.  O(n^2) schoolbook products: keep n small.
"""
from __future__ import annotations

import dataclasses
import random

from repro.core import polymul as pm
from repro.core.params import ParenttParams, make_params


@dataclasses.dataclass
class RefContext:
    params: ParenttParams
    pt_mod: int
    noise_bound: int = 4
    decomp_bits: int = 30  # relinearization base T = 2^decomp_bits

    @property
    def q(self):
        return self.params.q

    @property
    def n(self):
        return self.params.n

    @property
    def delta(self):
        return self.q // self.pt_mod


def make_ref_context(n: int = 32, t: int = 3, v: int = 30, pt_mod: int = 257):
    return RefContext(params=make_params(n=n, t=t, v=v), pt_mod=pt_mod)


# polynomial helpers (coefficient lists, mod q)


def _padd(a, b, q):
    return [(x + y) % q for x, y in zip(a, b)]


def _pneg(a, q):
    return [(-x) % q for x in a]


def _pmul(a, b, q):
    return pm.schoolbook_negacyclic(a, b, q)


def _centered(a, q):
    return [x - q if x > q // 2 else x for x in a]


def _negacyclic_int(a, b):
    """Exact integer negacyclic product (no modulus)."""
    n = len(a)
    p = [0] * n
    for i in range(n):
        if not a[i]:
            continue
        for j in range(n):
            k = i + j
            if k >= n:
                p[k - n] -= a[i] * b[j]
            else:
                p[k] += a[i] * b[j]
    return p


def _small(rng, n, bound):
    return [rng.randint(-bound, bound) for _ in range(n)]


def _ternary(rng, n):
    return [rng.randint(-1, 1) for _ in range(n)]


@dataclasses.dataclass
class RefKeys:
    s: list[int]
    pk: tuple[list[int], list[int]]
    evk: list[tuple[list[int], list[int]]]  # relinearization key, base-T


def keygen(rng: random.Random, ctx: RefContext) -> RefKeys:
    q, n = ctx.q, ctx.n
    s = _ternary(rng, n)
    s_q = [x % q for x in s]
    a = [rng.randrange(q) for _ in range(n)]
    e = [x % q for x in _small(rng, n, ctx.noise_bound)]
    pk0 = _pneg(_padd(_pmul(a, s_q, q), e, q), q)
    # evk_j = (-(a_j s + e_j) + T^j s^2, a_j)
    s2 = _pmul(s_q, s_q, q)
    evk = []
    T = 1 << ctx.decomp_bits
    levels = -(-q.bit_length() // ctx.decomp_bits)
    for j in range(levels):
        aj = [rng.randrange(q) for _ in range(n)]
        ej = [x % q for x in _small(rng, n, ctx.noise_bound)]
        b = _pneg(_padd(_pmul(aj, s_q, q), ej, q), q)
        b = _padd(b, [(pow(T, j, q) * x) % q for x in s2], q)
        evk.append((b, aj))
    return RefKeys(s=s_q, pk=(pk0, a), evk=evk)


def encrypt(rng: random.Random, m: list[int], keys: RefKeys, ctx: RefContext):
    q, n = ctx.q, ctx.n
    u = [x % q for x in _ternary(rng, n)]
    e1 = [x % q for x in _small(rng, n, ctx.noise_bound)]
    e2 = [x % q for x in _small(rng, n, ctx.noise_bound)]
    dm = [(ctx.delta * (x % ctx.pt_mod)) % q for x in m]
    c0 = _padd(_padd(_pmul(keys.pk[0], u, q), e1, q), dm, q)
    c1 = _padd(_pmul(keys.pk[1], u, q), e2, q)
    return (c0, c1)


def decrypt(ct, keys: RefKeys, ctx: RefContext) -> list[int]:
    q = ctx.q
    phase = _padd(ct[0], _pmul(ct[1], keys.s, q), q)
    return [((ctx.pt_mod * x + q // 2) // q) % ctx.pt_mod for x in phase]


def add(a, b, ctx: RefContext):
    return (_padd(a[0], b[0], ctx.q), _padd(a[1], b[1], ctx.q))


def mul_plain(ct, w: list[int], ctx: RefContext):
    wq = [x % ctx.q for x in w]
    return (_pmul(ct[0], wq, ctx.q), _pmul(ct[1], wq, ctx.q))


def mul(ct_a, ct_b, keys: RefKeys, ctx: RefContext):
    """ct x ct with BFV scaling (exact bigint rounding) + relinearization."""
    q, pt = ctx.q, ctx.pt_mod
    a0, a1 = (_centered(c, q) for c in ct_a)
    b0, b1 = (_centered(c, q) for c in ct_b)

    def scale(poly_int):
        return [(((pt * x) + (q // 2) * (1 if x >= 0 else -1)) // q) % q for x in poly_int]

    e0 = scale(_negacyclic_int(a0, b0))
    e1 = scale(
        [x + y for x, y in zip(_negacyclic_int(a0, b1), _negacyclic_int(a1, b0))]
    )
    e2 = scale(_negacyclic_int(a1, b1))
    # relinearize e2 via base-T digits
    T = 1 << ctx.decomp_bits
    c0, c1 = e0, e1
    rem = list(e2)
    for j, (b, aj) in enumerate(keys.evk):
        digit = [x % T for x in rem]
        rem = [x // T for x in rem]
        c0 = _padd(c0, _pmul(digit, b, q), q)
        c1 = _padd(c1, _pmul(digit, aj, q), q)
    return (c0, c1)
