"""The plan/execute front door: ONE entry point for the paper's whole
feed-forward multiplier, with modulus-width dispatch as an internal
plan-time decision instead of a user-facing class choice.

Usage::

    import repro

    pl = repro.plan(n=4096, t=6, v=30)          # paper's preferred point
    limbs = repro.polymul(pl, za, zb)           # (..., n, S) -> (..., n, L)

    pl45 = repro.plan(n=4096, t=4, v=45)        # wide-word alternative:
    limbs = repro.polymul(pl45, za, zb)         # same signature, same
                                                # base-2^w output limbs

Width dispatch (resolved once, inside :func:`plan`):

* ``v <= 31``  — the int64 Pallas datapath (``jnp`` / ``pallas`` /
  ``pallas_fused`` / ``pallas_fused_e2e`` backends, radix-2 or
  lane-aligned four-step schedules);
* ``31 < v <= 46`` — the digit-split wide datapath (the paper's t=4 /
  v=45 configuration, :mod:`repro.core.wide`), pure-jnp;
* ``v > 46``   — the host Python-bigint oracle (exact for any width;
  eager-only, cannot be traced).

All three widths share one contract: segments in (``(..., n, S)``
base-``2^v``), product limbs out (``(..., n, L)`` base-``2^w`` with
``w = plan.config.w``), bit-exact against the bigint oracle.

Plan/execute semantics
----------------------
:func:`plan` performs *every* resolution that used to travel as loose
kwargs (``backend``, ``schedule``, ``row_blk``, ``use_sau``) and freezes
the result into a hashable :class:`PlanConfig`.  The returned
:class:`Plan` is a registered JAX pytree:

* **leaves** — the device-resident constants (twiddle/Shoup/SAU/Barrett
  tables, RNS decompose/compose arrays), uploaded once per ``(n, t, v)``
  and shared across plans via the params cache;
* **static aux** — the ``PlanConfig`` plus the host-side parameter
  object (python ints for the kernels' closed-over constants).

So ``jax.jit(polymul)`` treats a plan as an ordinary argument: two plans
with the same config flatten to the same treedef and the jitted function
does **not** retrace; ``jax.vmap``/``shard_map`` thread batch axes of
``za``/``zb`` through with ``in_axes=None`` for the plan (no table
rebuilds, no re-uploads).  Tested by ``tests/test_api.py``.

Leaf use is load-bearing for **both** device widths: the wide width
consumes the leaves directly, and the int64 width executes through
:mod:`repro.kernels.ops` with its table bindings rebuilt from the
Plan's pytree leaves (:func:`_bound_params` — a lightweight view over
``params`` whose device arrays are the plan's leaves, with the channel
count re-derived from the leaf shapes).  So ``jax.tree.map`` /
``device_put`` / sharding of an int64 plan's leaves redirects the
kernels too — the property the serving layer's ``model``-axis
``shard_map`` of :func:`negacyclic_mul` relies on to keep each shard's
NTT/Shoup/CRT tables resident next to its RNS channels
(:mod:`repro.serve.crypto_engine`).  The only constants that stay baked
into kernel closures are the per-channel SAU decompose *circuits*
(python-int shift/add networks — the paper's specialized hardware, not
tables).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigint
from repro.core import ntt as ntt_mod
from repro.core import polymul as polymul_mod
from repro.core import schedule as schedule_mod
from repro.core import wide as wide_mod
from repro.core.params import (
    BACKENDS,
    SCHEDULES,
    ParenttParams,
    make_params,
    validate_backend,
)
from repro.core.schedule import ScheduleSpec
from repro.errors import PlanError, UnknownKnobError, UnservableConfigError
from repro.kernels import ops as ops_mod

__all__ = [
    "BACKENDS",
    "SCHEDULES",
    "WIDTHS",
    "Plan",
    "PlanConfig",
    "PlanError",
    "ScheduleSpec",
    "UnknownKnobError",
    "UnservableConfigError",
    "plan",
    "plan_from_params",
    "plan_key",
    "execute",
    "polymul",
    "polymul_ints",
    "ntt",
    "intt",
    "negacyclic_mul",
    "decompose",
    "compose",
    "to_segments",
    "from_limbs",
]

# Width paths, in increasing modulus width (see module docstring).
WIDTHS = ("int64", "wide", "oracle")

# The oracle path has no kernel backend; this sentinel is the only value
# PlanConfig.backend takes for width="oracle".
ORACLE_BACKEND = "oracle"

_V_MIN, _V_MAX = 8, 60


def width_for(v: int) -> str:
    """The datapath a modulus width rides: the int64 kernels need
    q_i < 2^31 (residue products fit int64), the digit-split wide path
    serves the 46-bit fold window, bigger moduli fall back to the exact
    host oracle."""
    if v <= 31:
        return "int64"
    if v <= 46:
        return "wide"
    return "oracle"


# --------------------------------------------------------------------------
# PlanConfig: every knob, resolved once, hashable (jit-static-safe)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Frozen, fully-resolved execution config — the static aux data of a
    :class:`Plan`.  No ``"auto"`` survives into a PlanConfig: ``backend``
    is a concrete string and ``schedule`` a fully-resolved (hashable)
    :class:`repro.core.schedule.ScheduleSpec` — kind, hierarchical tile
    chain, row block and VMEM accounting are all frozen here, so jit
    keys, :func:`plan_key` buckets and verifier presets see one
    canonical form and executing never re-resolves."""

    n: int
    t: int
    v: int
    width: str  # "int64" | "wide" | "oracle"
    backend: str  # BACKENDS entry, or "oracle" for the oracle width
    schedule: ScheduleSpec  # concrete spec (kind + splits + row_blk)
    row_blk: int | None
    channel_grid: bool | None  # fused-e2e RNS-channel grid axis (None = kernel default)
    use_sau: bool
    # derived I/O format (duplicated from the RnsPlan for self-description)
    seg_count: int  # S: base-2^v segments per input coefficient
    w: int  # output limb width (base 2^w)
    L: int  # output limb count


# --------------------------------------------------------------------------
# Plan: pytree of device constants + static config
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)  # identity eq: leaves are arrays
class Plan:
    """An executable multiplier plan (see module docstring).

    ``consts`` holds the device-resident constant arrays (the pytree
    leaves); ``config`` and ``params`` ride in the static aux data.
    Build with :func:`plan` — the constructor performs no validation.
    """

    config: PlanConfig
    params: ParenttParams
    consts: dict[str, Any]

    # -- convenience ---------------------------------------------------
    @property
    def n(self) -> int:
        return self.config.n

    @property
    def t(self) -> int:
        return self.config.t

    @property
    def v(self) -> int:
        return self.config.v

    @property
    def q(self) -> int:
        return self.params.q

    # -- pytree protocol ----------------------------------------------
    def tree_flatten(self) -> tuple[Any, Any]:
        keys = tuple(sorted(self.consts))
        return tuple(self.consts[k] for k in keys), (self.config, self.params, keys)

    @classmethod
    def tree_unflatten(cls, aux: Any, leaves: Any) -> "Plan":
        config, params, keys = aux
        return cls(config=config, params=params, consts=dict(zip(keys, leaves)))


# --------------------------------------------------------------------------
# leaf-bound execution views: the int64 ops layer reads its device tables
# through these, so the Plan's pytree leaves are the dataflow (DESIGN §7)
# --------------------------------------------------------------------------


class _LeafBound:
    """Attribute view of a host params/tables/plan object with selected
    attributes (the device-resident ``*_d`` arrays, plus the channel
    count ``t``) rebound to a Plan's pytree leaves.

    Everything else — python-int constants, shapes, SAU circuits —
    delegates to the wrapped base object, which stays the stable,
    identity-hashable value for jit-static kernel arguments
    (:func:`repro.kernels.ops.unbind` recovers it).  Under ``shard_map``
    the leaves arrive shard-local, so ``t`` is re-derived from the leaf
    shapes and every kernel runs on exactly its shard's RNS channels.
    """

    __slots__ = ("_base", "_over")

    def __init__(self, base: Any, over: dict[str, Any]) -> None:
        self._base = base
        self._over = over

    def __getattr__(self, name: str) -> Any:  # called only when not found on self
        over = object.__getattribute__(self, "_over")
        if name in over:
            return over[name]
        return getattr(object.__getattribute__(self, "_base"), name)

    def __repr__(self) -> str:
        return f"_LeafBound({self._base!r}, over={sorted(self._over)})"


# ChannelTables / RnsPlan attribute stems whose ``<stem>_d`` device arrays
# live in an int64 plan's leaf dict (as "ntt_<stem>" / "rns_<stem>").
_CT_LEAF_STEMS = (
    "qs", "fwd", "inv", "half", "mul_eps", "fs_row_fwd", "fs_row_inv",
    "fwd_shoup", "inv_shoup", "fs_row_fwd_shoup", "fs_row_inv_shoup",
)
# Tuple-valued stems (one device array per hierarchical sub level); each
# level flattens to its own leaf "ntt_<stem><level>" so the pytree stays
# array-leaved and shard_map/device_put treat every level independently.
_CT_TUPLE_STEMS = (
    "fs_sub_fwd", "fs_sub_inv", "fs_sub_fwd_shoup", "fs_sub_inv_shoup",
)
_RNS_LEAF_STEMS = ("qs", "beta_pows", "qi_tilde", "qi_star_limbs", "q_limbs")


def _bound_params(pl: Plan) -> Any:
    """A ParenttParams view whose NTT/RNS device tables are THIS plan's
    pytree leaves (int64 width; other widths return the params as-is).

    Cached per Plan instance: eager plans are long-lived so their view
    is built once; under jit, ``tree_unflatten`` makes a fresh Plan per
    trace, so tracer-bearing views never outlive their trace.
    """
    if pl.config.width != "int64":
        return pl.params
    cached = pl.__dict__.get("_bound_params_cache")
    if cached is not None:
        return cached
    c = pl.consts
    t_local = int(c["ntt_qs"].shape[0])
    ct_over = {"t": t_local}
    for stem in _CT_LEAF_STEMS:
        leaf = c.get("ntt_" + stem)
        if leaf is not None:
            ct_over[stem + "_d"] = leaf
    for stem in _CT_TUPLE_STEMS:
        levels = []
        while (leaf := c.get(f"ntt_{stem}{len(levels)}")) is not None:
            levels.append(leaf)
        if levels:
            ct_over[stem + "_d"] = tuple(levels)
    rns_over = {"t": t_local}
    for stem in _RNS_LEAF_STEMS:
        rns_over[stem + "_d"] = c["rns_" + stem]
    params = pl.params
    bound = _LeafBound(
        params,
        {
            "t": t_local,
            "tables": _LeafBound(params.tables, ct_over),
            "plan": _LeafBound(params.plan, rns_over),
        },
    )
    object.__setattr__(pl, "_bound_params_cache", bound)
    return bound


# --------------------------------------------------------------------------
# plan(): resolve everything once
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _int64_consts(params: ParenttParams) -> dict[str, Any]:
    """Device constants of the int64 datapath as a named leaf dict.  The
    arrays are the very same device buffers ChannelTables/RnsPlan
    uploaded at construction — building a Plan never re-uploads."""
    ct, rp = params.tables, params.plan
    out = {}
    for name in _CT_LEAF_STEMS:
        dev = getattr(ct, name + "_d")
        if dev is not None:
            out["ntt_" + name] = dev
    for name in _CT_TUPLE_STEMS:
        dev = getattr(ct, name + "_d")
        if dev is not None:
            for lvl, arr in enumerate(dev):
                out[f"ntt_{name}{lvl}"] = arr
    out["rns_qs"] = rp.qs_d
    out["rns_beta_pows"] = rp.beta_pows_d
    out["rns_qi_tilde"] = rp.qi_tilde_d
    out["rns_qi_star_limbs"] = rp.qi_star_limbs_d
    out["rns_q_limbs"] = rp.q_limbs_d
    return out


@functools.lru_cache(maxsize=None)
def _wide_consts(params: ParenttParams) -> dict[str, Any]:
    """Device constants of the digit-split wide datapath: stacked
    per-channel twiddle tables plus POST_W-limb CRT constants, uploaded
    once per params object (cached)."""
    rp = params.plan
    tabs = [ntt_mod.make_tables(int(q), params.n) for q in rp.qs]
    W = wide_mod.POST_W
    L14 = -(-(rp.q.bit_length() + rp.t.bit_length()) // W)
    return {
        "wide_fwd": jnp.asarray(np.stack([tb.fwd for tb in tabs])),
        "wide_inv": jnp.asarray(np.stack([tb.inv for tb in tabs])),
        "wide_beta_pows": rp.beta_pows_d,
        "wide_qi_tilde": rp.qi_tilde_d,
        "wide_qi_star_limbs": jnp.asarray(
            bigint.ints_to_limbs(
                [rp.q // int(qi) for qi in rp.qs], W, L14
            )
        ),
        "wide_q_limbs": jnp.asarray(bigint.int_to_limbs(rp.q, W, L14)),
        # per-channel special-prime constants as (t,)-leading leaves, so a
        # shard_map channel slice carries its own q_i = 2^v - beta_i and
        # shard-local ChannelSpecs can be rebuilt (see _wide_exec_specs)
        "wide_qs": rp.qs_d,
        "wide_betas": jnp.asarray(
            [p.beta for p in params.primes], dtype=jnp.int64
        ),
    }


@functools.lru_cache(maxsize=None)
def _wide_specs(params: ParenttParams) -> tuple[Any, ...]:
    return tuple(wide_mod.from_special(p) for p in params.primes)


def _wide_exec_specs(pl: Plan) -> tuple[Any, ...]:
    """The per-channel specs THIS call should execute.

    Full-width plans (the common case, including jit traces of unsharded
    plans) return the cached host :class:`repro.core.wide.WideSpec`
    tuple, keyed by global channel index.  Under ``shard_map`` the
    plan's leaves arrive as a channel slice, so the host tuple would be
    mis-keyed; the ``wide_qs``/``wide_betas`` leaves travel with the
    slice, and rebuilding :class:`repro.core.wide.ChannelSpec` views
    from them IS the channel-offset view — shard-local index i reads the
    globally-correct q_i/beta_i.
    """
    qs = pl.consts.get("wide_qs")
    t_local = None if qs is None else int(qs.shape[0])
    if t_local is None or t_local == pl.params.t:
        return _wide_specs(pl.params)
    betas = pl.consts["wide_betas"]
    v = pl.config.v
    return tuple(
        wide_mod.ChannelSpec(q=qs[i], v=v, beta=betas[i])
        for i in range(t_local)
    )


def _consts_for(params: ParenttParams, width: str) -> dict[str, Any]:
    if width == "int64":
        return _int64_consts(params)
    if width == "wide":
        return _wide_consts(params)
    return {}  # oracle: host bigints, nothing device-resident


def _resolve_backend(width: str, backend: str) -> str:
    if backend == "auto":
        if width == "int64":
            return ops_mod.auto_backend()
        return "jnp" if width == "wide" else ORACLE_BACKEND
    if width == "int64":
        return validate_backend(backend)
    if width == "wide":
        if backend != "jnp":
            raise UnservableConfigError(
                f"the wide (v in (31, 46]) datapath is pure-jnp: "
                f"backend={backend!r} is not available (use 'auto' or 'jnp')",
                knob="backend", value=backend, alternatives=("auto", "jnp"),
            )
        return backend
    if backend != ORACLE_BACKEND:
        raise UnservableConfigError(
            f"v > 46 is served by the host bigint oracle only: "
            f"backend={backend!r} is not available (use 'auto' or 'oracle')",
            knob="backend", value=backend,
            alternatives=("auto", ORACLE_BACKEND),
        )
    return backend


def _check_wide_envelope(width: str, t: int, v: int) -> None:
    """Wide inverse-CRT envelope: the t-fold sum of y(<2^v) x
    limb(<2^POST_W) contributions must stay inside int64 — reject at
    plan time, never corrupt at execution time."""
    if width == "wide" and t * (1 << (v + wide_mod.POST_W)) > (1 << 63):
        raise UnservableConfigError(
            f"t={t} channels of v={v}-bit moduli overflow the wide "
            f"datapath's int64 inverse-CRT accumulator (need "
            f"t * 2^(v+{wide_mod.POST_W}) <= 2^63); use fewer/narrower "
            f"channels",
            knob="t", value=t, alternatives=(),
        )


def _resolve_spec(
    width: str,
    n: int,
    schedule,
    *,
    tiling=None,
    row_blk: int | None = None,
    params: ParenttParams | None = None,
) -> ScheduleSpec:
    """Resolve the schedule knobs into a concrete :class:`ScheduleSpec`.

    Called twice by :func:`plan`: once with ``params=None`` as the cheap
    pre-params pass (vocabulary + hierarchical-chain servability, so bad
    combos fail before the prime search), and once after ``make_params``
    for the full VMEM-budget row-block resolution (which needs S, L and
    the lazy-reduction flag off the built tables).  The wide and oracle
    widths have no kernel schedule — they serve radix2 with no tile
    accounting (``row_blk=0``)."""
    if width != "int64":
        kind = getattr(schedule, "kind", schedule)
        if kind not in ("auto", "radix2"):
            raise UnservableConfigError(
                f"the {width} datapath serves schedule='radix2' only, "
                f"got {schedule!r}",
                knob="schedule", value=schedule,
                alternatives=("auto", "radix2"),
            )
        if tiling is not None:
            raise UnservableConfigError(
                f"tiling= is a kernel-schedule hint; the {width} datapath "
                f"has no Pallas tile schedule",
                knob="tiling", value=tiling, alternatives=(),
            )
        return ScheduleSpec(kind="radix2")
    if params is None:
        return schedule_mod.concrete_spec(n, schedule)
    ct = params.tables
    return schedule_mod.resolve_spec(
        n, schedule, tiling=tiling, row_blk=row_blk,
        seg_count=params.plan.seg_count, limb_count=params.plan.L,
        lazy=ct is not None and ct.lazy_window is not None,
    )


def _tuning_winner(tuning: Any, n: int, t: int, v: int) -> dict[str, Any] | None:
    """Resolve the ``tuning=`` knob into the table's winner-knob dict for
    this workload (or ``None`` for no tuning / no matching entry).

    ``"off"``/``None`` disables lookup; ``"auto"`` consults the committed
    :data:`repro.tune.table.DEFAULT_TABLE_PATH` and degrades silently to
    the static defaults when the file or entry is missing; an explicit
    path (or a :class:`repro.tune.table.TuningTable`) must exist and
    validate.  Entries are keyed by device kind + ``(n, t, v, batch)``;
    the lookup returns the smallest-batch entry for ``(n, t, v)``.
    """
    if tuning is None or tuning == "off":
        return None
    from repro.tune import table as table_mod  # deferred: keep plan() light

    if isinstance(tuning, table_mod.TuningTable):
        tab: Any = tuning
    elif tuning == "auto":
        tab = table_mod.load_default()
        if tab is None:
            return None
    elif isinstance(tuning, str):
        tab = table_mod.load_cached(tuning)
    else:
        raise UnknownKnobError(
            f"tuning must be 'auto', 'off', a table path or a TuningTable, "
            f"got {tuning!r}",
            knob="tuning", value=tuning, alternatives=("auto", "off"),
        )
    winner = tab.lookup(n=n, t=t, v=v)
    return dict(winner) if winner is not None else None


def plan(
    n: int = 4096,
    t: int = 6,
    v: int = 30,
    *,
    backend: str = "auto",
    schedule="auto",
    tiling=None,
    row_blk: int | None = None,
    channel_grid: bool | None = None,
    use_sau: bool = True,
    tuning: Any = "off",
) -> Plan:
    """Build an executable plan: search/validate primes, precompute and
    upload every table, and resolve all execution knobs into a frozen
    :class:`PlanConfig`.

    ``backend="auto"`` picks the fused single-kernel Pallas path on TPU
    and the pure-jnp reference elsewhere (for v <= 31); the wide and
    oracle widths have exactly one datapath each.  ``schedule`` accepts
    ``"auto"`` (lane-aligned four-step for n >= 256), ``"radix2"``,
    ``"four_step"``, ``"four_step:h"`` (asserts the hierarchical
    depth >= 2 chain, available from n = 8192) or an explicit
    :class:`ScheduleSpec`; whichever is given, the config freezes one
    fully-resolved spec — tile chain, row block and VMEM accounting
    included.  ``tiling`` is an optional hint: an int is a row-block
    request, a tuple of per-level ``(columns, rows)`` pairs asserts the
    expected tile chain.  ``channel_grid`` pins the fused-e2e kernel's
    RNS-channel grid axis (True = grid over channels, False = unrolled,
    None = kernel default); it is a knob of ``backend="pallas_fused_e2e"``
    only.

    ``tuning`` consults the profile-driven tuning table
    (:mod:`repro.tune`): ``"off"`` (default) keeps the static defaults,
    ``"auto"`` uses the committed ``TUNING_default.json``, and a path (or
    a ``TuningTable``) uses that table.  Resolution order is **explicit
    knob > tuning table > static default** — the table only fills knobs
    still at their defaults (``backend="auto"``, ``schedule="auto"``,
    ``row_blk=None``, ``channel_grid=None``), and the winner lands in the
    frozen :class:`PlanConfig` like any hand-set knob, so jit keys,
    :func:`plan_key` buckets and the verifier see it first-class.

    Invalid knobs raise :class:`repro.errors.UnknownKnobError` and
    structurally valid but unservable combinations (four_step on a tiny
    n, a Pallas backend on the wide width, a row block that overflows
    VMEM, ...) raise :class:`repro.errors.UnservableConfigError` — both
    ``ValueError`` subclasses, both at plan time, never mid-execution.
    """
    if not isinstance(n, int) or n < 4 or n & (n - 1):
        raise UnknownKnobError(
            f"n must be a power of two >= 4, got n={n!r}",
            knob="n", value=n, alternatives=(),
        )
    if not isinstance(t, int) or t < 1:
        raise UnknownKnobError(
            f"t must be a positive int, got t={t!r}",
            knob="t", value=t, alternatives=(),
        )
    if not isinstance(v, int) or not (_V_MIN <= v <= _V_MAX):
        raise UnknownKnobError(
            f"v must be an int in [{_V_MIN}, {_V_MAX}], got v={v!r} "
            f"(the paper's configs are v=30 and v=45)",
            knob="v", value=v, alternatives=(),
        )
    tuned = _tuning_winner(tuning, n, t, v)
    if tuned is not None:
        # explicit knob > tuning table > static default: the table fills
        # only knobs the caller left at their defaults.
        if backend == "auto" and tuned.get("backend"):
            backend = tuned["backend"]
        if (
            isinstance(schedule, str)
            and schedule == "auto"
            and tuned.get("schedule")
        ):
            schedule = tuned["schedule"]
        if row_blk is None and tiling is None and tuned.get("row_blk") is not None:
            row_blk = tuned["row_blk"]
        if channel_grid is None and tuned.get("channel_grid") is not None:
            channel_grid = tuned["channel_grid"]
    if row_blk is not None and (not isinstance(row_blk, int) or row_blk < 1):
        raise UnknownKnobError(
            f"row_blk must be >= 1, got {row_blk}",
            knob="row_blk", value=row_blk, alternatives=(1, 2, 4, 8),
        )
    if channel_grid is not None and not isinstance(channel_grid, bool):
        raise UnknownKnobError(
            f"channel_grid must be True, False or None, got {channel_grid!r}",
            knob="channel_grid", value=channel_grid, alternatives=(True, False, None),
        )
    width = width_for(v)
    # resolve the cheap knobs BEFORE the prime search so bad combos fail fast
    backend = _resolve_backend(width, backend)
    if channel_grid is not None and backend != "pallas_fused_e2e":
        raise UnservableConfigError(
            f"channel_grid= schedules the fused-e2e kernel's RNS-channel "
            f"grid axis; backend={backend!r} has no such grid "
            f"(use backend='pallas_fused_e2e' or leave channel_grid=None)",
            knob="channel_grid", value=channel_grid, alternatives=(None,),
        )
    _resolve_spec(width, n, schedule, tiling=tiling)
    _check_wide_envelope(width, t, v)
    params = make_params(n=n, t=t, v=v, row_blk=row_blk)
    spec = _resolve_spec(
        width, n, schedule, tiling=tiling, row_blk=row_blk, params=params
    )
    cfg = PlanConfig(
        n=n, t=t, v=v, width=width, backend=backend, schedule=spec,
        row_blk=row_blk, channel_grid=channel_grid, use_sau=use_sau,
        seg_count=params.plan.seg_count, w=params.plan.w, L=params.plan.L,
    )
    return Plan(config=cfg, params=params, consts=_consts_for(params, width))


def plan_from_params(
    params: ParenttParams,
    *,
    backend: str | None = None,
    use_sau: bool = True,
) -> Plan:
    """Adapter for the legacy class front doors: wrap an existing
    :class:`ParenttParams` (honouring its ``backend``/``schedule``/
    ``row_blk`` fields) into a :class:`Plan`."""
    width = width_for(params.v)
    if width == "int64":
        backend = ops_mod.resolve_backend(params, backend)
    else:
        backend = _resolve_backend(width, backend or "auto")
    spec = _resolve_spec(
        width, params.n, params.schedule, row_blk=params.row_blk,
        params=params,
    )
    _check_wide_envelope(width, params.t, params.v)
    cfg = PlanConfig(
        n=params.n, t=params.t, v=params.v, width=width, backend=backend,
        schedule=spec, row_blk=params.row_blk, channel_grid=None,
        use_sau=use_sau,
        seg_count=params.plan.seg_count, w=params.plan.w, L=params.plan.L,
    )
    return Plan(config=cfg, params=params, consts=_consts_for(params, width))


# --------------------------------------------------------------------------
# shape contracts (the wide/oracle mirrors of kernels/ops.py's checks)
# --------------------------------------------------------------------------


def _require_plan(pl: Plan, fn: str) -> PlanConfig:
    if not isinstance(pl, Plan):
        raise TypeError(
            f"{fn}: first argument must be a repro.api.Plan "
            f"(build one with repro.plan(...)), got {type(pl).__name__}"
        )
    return pl.config


def _check_residues(
    x: Any, cfg: PlanConfig, fn: str, t: int | None = None
) -> None:
    t_want = cfg.t if t is None else t  # shard-local channel count under mesh
    if x.ndim < 2 or x.shape[0] != t_want or x.shape[-1] != cfg.n:
        raise ValueError(
            f"{fn}: expected residues (t={t_want}, ..., n={cfg.n}), "
            f"got shape {tuple(x.shape)}"
        )


def _check_poly_segments(z: Any, cfg: PlanConfig, fn: str, name: str) -> None:
    if z.ndim < 2 or z.shape[-2] != cfg.n or z.shape[-1] != cfg.seg_count:
        raise ValueError(
            f"{fn}: expected {name} segments (..., n={cfg.n}, "
            f"S={cfg.seg_count}), got shape {tuple(z.shape)}"
        )


def _no_tracers(cfg: PlanConfig, fn: str, *arrays: Any) -> None:
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        raise ValueError(
            f"{fn}: width={cfg.width!r} plans execute on the host "
            "(exact Python bigints) and cannot be traced — call the api "
            "eagerly, outside jit/vmap"
        )


# --------------------------------------------------------------------------
# execute: polymul (the single entry point) + the stage functions
# --------------------------------------------------------------------------


def polymul(pl: Plan, za: Any, zb: Any) -> jax.Array:
    """za, zb: ``(..., n, S)`` base-2^v segment arrays -> ``(..., n, L)``
    base-2^w limbs of ``a * b mod (x^n + 1, q)`` — the whole Fig-10
    pipeline (decompose -> per-channel no-shuffle NTT cascade ->
    inverse CRT) on whichever datapath the plan resolved.

    jit/vmap/shard_map-native for the int64 and wide widths (the plan is
    a pytree; pass it with ``in_axes=None`` under vmap).  The oracle
    width is host-only and raises under tracing.
    """
    cfg = _require_plan(pl, "polymul")
    if cfg.width == "int64":
        # The profiler stage scopes nest under this root:
        # parentt.polymul/parentt.{decompose,cascade,compose,fused_e2e}
        # (obs stage profiling, DESIGN.md §12).
        with jax.named_scope("parentt.polymul"):
            return ops_mod.fused_polymul_e2e(
                za, zb, _bound_params(pl), backend=cfg.backend,
                use_sau=cfg.use_sau, schedule=cfg.schedule,
                channel_grid=cfg.channel_grid,
            )
    _check_poly_segments(za, cfg, "polymul", "za")
    _check_poly_segments(zb, cfg, "polymul", "zb")
    if za.shape != zb.shape:
        raise ValueError(
            f"polymul: operand shapes differ: {tuple(za.shape)} vs "
            f"{tuple(zb.shape)}"
        )
    if cfg.width == "wide":
        ra = _wide_decompose(pl, za)
        rb = _wide_decompose(pl, zb)
        specs = _wide_exec_specs(pl)
        rp = wide_mod.negacyclic_mul_channels(
            ra, rb, pl.consts["wide_fwd"], pl.consts["wide_inv"], specs
        )
        return _wide_compose(pl, rp)
    return _oracle_polymul(pl, za, zb)


def ntt(pl: Plan, a: Any) -> jax.Array:
    """a: ``(t, ..., n)`` residues -> forward NTT per RNS channel
    (natural-order in, bit-reversed out — the no-shuffle convention)."""
    cfg = _require_plan(pl, "ntt")
    if cfg.width == "int64":
        return ops_mod.ntt_forward(
            a, _bound_params(pl), backend=cfg.backend, schedule=cfg.schedule
        )
    if cfg.width == "wide":
        specs = _wide_exec_specs(pl)
        _check_residues(a, cfg, "ntt", t=len(specs))
        return wide_mod.ntt_channels(a, pl.consts["wide_fwd"], specs)
    raise ValueError(
        "ntt: the oracle width has no device transform; v > 46 plans "
        "serve polymul/decompose/compose on the host only"
    )


def intt(pl: Plan, a: Any) -> jax.Array:
    """a: ``(t, ..., n)`` bit-reversed spectra -> natural-order residues."""
    cfg = _require_plan(pl, "intt")
    if cfg.width == "int64":
        return ops_mod.ntt_inverse(
            a, _bound_params(pl), backend=cfg.backend, schedule=cfg.schedule
        )
    if cfg.width == "wide":
        specs = _wide_exec_specs(pl)
        _check_residues(a, cfg, "intt", t=len(specs))
        return wide_mod.intt_channels(a, pl.consts["wide_inv"], specs)
    raise ValueError(
        "intt: the oracle width has no device transform; v > 46 plans "
        "serve polymul/decompose/compose on the host only"
    )


def negacyclic_mul(pl: Plan, a: Any, b: Any) -> jax.Array:
    """``(t, ..., n) x (t, ..., n)`` -> per-channel negacyclic products
    (the residue-domain cascade — what the BFV layer runs per product)."""
    cfg = _require_plan(pl, "negacyclic_mul")
    if cfg.width == "int64":
        return ops_mod.negacyclic_mul(
            a, b, _bound_params(pl), backend=cfg.backend, schedule=cfg.schedule
        )
    if cfg.width == "wide":
        specs = _wide_exec_specs(pl)
        _check_residues(a, cfg, "negacyclic_mul", t=len(specs))
        _check_residues(b, cfg, "negacyclic_mul", t=len(specs))
        if a.shape != b.shape:
            raise ValueError(
                f"negacyclic_mul: operand shapes differ: {tuple(a.shape)} "
                f"vs {tuple(b.shape)}"
            )
        return wide_mod.negacyclic_mul_channels(
            a, b, pl.consts["wide_fwd"], pl.consts["wide_inv"], specs
        )
    raise ValueError(
        "negacyclic_mul: the oracle width has no device transform; "
        "v > 46 plans serve polymul/decompose/compose on the host only"
    )


def decompose(pl: Plan, z: Any) -> jax.Array:
    """z: ``(..., S)`` base-2^v segments -> residues ``(t, ...)``."""
    cfg = _require_plan(pl, "decompose")
    if cfg.width == "int64":
        return ops_mod.rns_decompose(
            z, _bound_params(pl), backend=cfg.backend, use_sau=cfg.use_sau
        )
    if z.ndim < 1 or z.shape[-1] != cfg.seg_count:
        raise ValueError(
            f"decompose: expected base-2^{cfg.v} segments "
            f"(..., S={cfg.seg_count}), got shape {tuple(z.shape)}"
        )
    if cfg.width == "wide":
        return wide_mod.decompose_channels(
            z, _wide_exec_specs(pl), pl.consts["wide_beta_pows"]
        )
    _no_tracers(cfg, "decompose", z)
    rp = pl.params.plan
    zn = np.asarray(z)
    flat = zn.reshape(-1, zn.shape[-1])
    out = np.empty((cfg.t, flat.shape[0]), dtype=np.int64)
    for r in range(flat.shape[0]):
        x = bigint.limbs_to_int(flat[r], cfg.v)
        for i in range(cfg.t):
            out[i, r] = x % int(rp.qs[i])
    return jnp.asarray(out.reshape((cfg.t,) + zn.shape[:-1]))


def compose(pl: Plan, residues: Any) -> jax.Array:
    """residues: ``(t, ...)`` -> ``(..., L)`` base-2^w limbs of the
    CRT-composed value (canonical, < q)."""
    cfg = _require_plan(pl, "compose")
    if cfg.width == "int64":
        return ops_mod.rns_compose(
            residues, _bound_params(pl), backend=cfg.backend
        )
    t_want = len(_wide_exec_specs(pl)) if cfg.width == "wide" else cfg.t
    if residues.ndim < 1 or residues.shape[0] != t_want:
        raise ValueError(
            f"compose: expected residues (t={t_want}, ...), got shape "
            f"{tuple(residues.shape)}"
        )
    if cfg.width == "wide":
        return _wide_compose(pl, residues)
    _no_tracers(cfg, "compose", residues)
    rp = pl.params.plan
    rn = np.asarray(residues)
    flat = rn.reshape(cfg.t, -1)
    out = np.empty((flat.shape[1], cfg.L), dtype=np.int64)
    for r in range(flat.shape[1]):
        acc = 0
        for i in range(cfg.t):
            qi = int(rp.qs[i])
            y = (int(flat[i, r]) * int(rp.qi_tilde[i])) % qi
            acc = (acc + y * (rp.q // qi)) % rp.q
        out[r] = bigint.int_to_limbs(acc, cfg.w, cfg.L)
    return jnp.asarray(out.reshape(rn.shape[1:] + (cfg.L,)))


# --------------------------------------------------------------------------
# wide-width internals
# --------------------------------------------------------------------------


def _wide_decompose(pl: Plan, z: Any) -> jax.Array:
    return wide_mod.decompose_channels(
        z, _wide_exec_specs(pl), pl.consts["wide_beta_pows"]
    )


def _wide_compose(pl: Plan, residues: Any) -> jax.Array:
    cfg = pl.config
    limbs14 = wide_mod.compose_channels(
        residues,
        _wide_exec_specs(pl),
        pl.consts["wide_qi_tilde"],
        pl.consts["wide_qi_star_limbs"],
        pl.consts["wide_q_limbs"],
    )
    out = wide_mod.repack_limbs(limbs14, wide_mod.POST_W, cfg.w)
    assert out.shape[-1] == cfg.L, (out.shape, cfg.L)
    return out


# --------------------------------------------------------------------------
# oracle-width internals (host, exact, eager-only)
# --------------------------------------------------------------------------


def _oracle_polymul(pl: Plan, za: Any, zb: Any) -> jax.Array:
    cfg = pl.config
    _no_tracers(cfg, "polymul", za, zb)
    za_n, zb_n = np.asarray(za), np.asarray(zb)
    lead = za_n.shape[:-2]
    a3 = za_n.reshape((-1,) + za_n.shape[-2:])
    b3 = zb_n.reshape((-1,) + zb_n.shape[-2:])
    out = np.empty((a3.shape[0], cfg.n, cfg.L), dtype=np.int64)
    for r in range(a3.shape[0]):
        a_ints = [bigint.limbs_to_int(a3[r, j], cfg.v) for j in range(cfg.n)]
        b_ints = [bigint.limbs_to_int(b3[r, j], cfg.v) for j in range(cfg.n)]
        p_ints = polymul_mod.oracle_multiply(a_ints, b_ints, pl.params)
        out[r] = bigint.ints_to_limbs(p_ints, cfg.w, cfg.L)
    return jnp.asarray(out.reshape(lead + (cfg.n, cfg.L)))


# --------------------------------------------------------------------------
# host <-> device format helpers + int convenience
# --------------------------------------------------------------------------


def to_segments(pl: Plan, xs: Any) -> jax.Array:
    """Python ints (length n) -> ``(n, S)`` base-2^v segment array."""
    cfg = _require_plan(pl, "to_segments")
    return jnp.asarray(
        bigint.ints_to_limbs(xs, cfg.v, cfg.seg_count)
    )


def from_limbs(pl: Plan, limbs: Any) -> list[int]:
    """``(..., L)`` base-2^w output limbs -> flat list of Python ints."""
    cfg = _require_plan(pl, "from_limbs")
    return bigint.limbs_to_ints(np.asarray(limbs), cfg.w)


# One module-level jitted executor shared by every plan: the Plan pytree
# is an ordinary argument, so same-config calls hit one compiled entry.
_polymul_jit = jax.jit(polymul)

# Donating twin for serving hot loops: the operand buffers are handed to
# XLA for reuse (the engine builds fresh padded slot buffers per
# dispatch, so nothing ever reads them back).
_polymul_jit_donating = jax.jit(polymul, donate_argnums=(1, 2))


def plan_key(pl: Plan) -> PlanConfig:
    """The hashable bucket/cache key of a plan: its frozen
    :class:`PlanConfig`.  Two plans with equal keys are interchangeable
    executables (same treedef, same shared device tables), so serving
    layers key jit caches and batch buckets on this
    (:class:`repro.serve.crypto_engine.PolymulEngine`)."""
    return _require_plan(pl, "plan_key")


def execute(pl: Plan, za: Any, zb: Any, *, donate: bool = False) -> jax.Array:
    """Jitted :func:`polymul` through the shared module-level executor —
    the serving layer's execute hook.  One compiled entry per distinct
    :func:`plan_key`; ``donate=True`` additionally donates the operand
    buffers to XLA (callers must not reuse ``za``/``zb`` afterwards —
    the batching engine's padded slot buffers are built fresh per
    dispatch, which is exactly this contract; backends without donation
    support, e.g. CPU, warn and copy).  Oracle-width plans fall back to
    the eager host path."""
    cfg = _require_plan(pl, "execute")
    if cfg.width == "oracle":
        return polymul(pl, za, zb)
    if donate:
        return _polymul_jit_donating(pl, za, zb)
    return _polymul_jit(pl, za, zb)


def polymul_ints(pl: Plan, a: Any, b: Any) -> list[int]:
    """Host convenience: Python-int coefficient lists in, Python-int
    product coefficients out, through the plan's full device pipeline
    (or the host oracle for the oracle width)."""
    cfg = _require_plan(pl, "polymul_ints")
    za, zb = to_segments(pl, a), to_segments(pl, b)
    if cfg.width == "oracle":
        limbs = polymul(pl, za, zb)
    else:
        limbs = _polymul_jit(pl, za, zb)
    return from_limbs(pl, limbs)
