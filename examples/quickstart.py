"""Quickstart: PaReNTT long polynomial modular multiplication.

1. Correctness at n=256 against the bigint schoolbook oracle.
2. The paper's operating point: n=4096, 180-bit q, t=6 RNS channels of
   v=30-bit special primes — batched through the jit pipeline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import params as params_mod
from repro.core import polymul as pm


def main():
    # --- 1. correctness (small n so the O(n^2) oracle is fast) -----------
    # One switch selects the datapath for the whole pipeline:
    #   "jnp"              pure-jnp reference (always available)
    #   "pallas"           per-stage Pallas kernels (product round-trips HBM)
    #   "pallas_fused"     the paper's fused NTT -> ⊙ -> iNTT cascade, one
    #                      kernel, NTT-domain product never leaves VMEM
    #   "pallas_fused_e2e" the whole decompose -> cascade -> compose
    #                      pipeline in ONE kernel: residues never touch
    #                      HBM, only segments in / product limbs out
    # and an orthogonal switch selects the NTT stage schedule:
    #   "radix2"     flat stage loop (late stages pair at lane stride < 128)
    #   "four_step"  lane-aligned (n1, 128) tile schedule with a VMEM
    #                transpose — no stage pairs along the lane axis
    #   "auto"       four_step when n >= 256 (the default)
    p = params_mod.make_params(n=256, t=3, v=30)
    rng = random.Random(0)
    a = [rng.randrange(p.q) for _ in range(p.n)]
    b = [rng.randrange(p.q) for _ in range(p.n)]
    want = pm.schoolbook_negacyclic(a, b, p.q)
    for backend in params_mod.BACKENDS:
        for schedule in ("radix2", "four_step"):
            mult = pm.ParenttMultiplier(
                p.with_schedule(schedule), backend=backend
            )
            got = mult.multiply_ints(a, b)
            assert got == want, (
                f"pipeline mismatch on backend={backend}/{schedule}!"
            )
        print(f"[ok] n=256, q={p.q.bit_length()}-bit, backend={backend}: "
              "PaReNTT == schoolbook (radix2 + four_step)")

    # --- 2. the paper's configuration ------------------------------------
    p = params_mod.make_params(n=4096, t=6, v=30)
    print(f"n=4096, t=6 special primes of 30 bits, q = {p.q.bit_length()} bits")
    for s in p.primes:
        terms = " ".join(f"{'+' if sg > 0 else '-'}2^{e}" for e, sg in s.beta_terms)
        print(f"   q_i = 2^30 - ({terms} - 1) = {hex(s.q)}")
    mult = pm.ParenttMultiplier(p)
    rng_np = np.random.default_rng(0)
    batch = 4
    za = jnp.asarray(rng_np.integers(0, 1 << 30, size=(batch, 4096, p.plan.seg_count)))
    zb = jnp.asarray(rng_np.integers(0, 1 << 30, size=(batch, 4096, p.plan.seg_count)))
    out = jax.block_until_ready(mult(za, zb))  # compile + run
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(mult(za, zb))
    dt = (time.perf_counter() - t0) / 3 / batch
    print(
        f"[ok] batched 180-bit x 4096-coeff modular multiplication: "
        f"{dt*1e3:.1f} ms/poly on CPU (paper's FPGA: 17.7us at 240 MHz)"
    )
    print("     output limbs shape:", tuple(out.shape))


if __name__ == "__main__":
    main()
