"""Core PaReNTT correctness: primes, NTT, RNS, polymul, schedule, Barrett."""
import functools
import random

import numpy as np
import pytest

import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to per-test skips, not errors
    from _hypothesis_fallback import given, settings, st

import repro
from repro.core import bigint, ntt as ntt_mod, params as params_mod
from repro.core import polymul as pm, primes as primes_mod, rns as rns_mod
from repro.core import schedule as sched


# --------------------------------------------------------------------------
# primes
# --------------------------------------------------------------------------


class TestPrimes:
    def test_miller_rabin(self):
        assert primes_mod.is_prime(2**31 - 1)
        assert not primes_mod.is_prime(2**32 - 1)
        assert primes_mod.is_prime(0x3FDE0001)

    def test_factorize_roundtrip(self):
        for x in [2**30 - 1, 7 * 11 * 13 * 17, 2**45 - 2**29 + 2**13 + 1]:
            fac = primes_mod.factorize(x)
            y = 1
            for p, e in fac.items():
                assert primes_mod.is_prime(p)
                y *= p**e
            assert y == x

    @pytest.mark.parametrize(
        "t,v,mu,pot,expected",
        [
            (4, 45, 105, 4, 12),
            (4, 45, 120, 4, 33),
            (4, 45, 105, 5, 126),
            (4, 45, 120, 5, 480),
            (6, 30, 75, 4, 8),
            (6, 30, 90, 4, 26),
            (6, 30, 75, 5, 23),
            (6, 30, 90, 5, 169),
        ],
    )
    def test_table_iii_counts(self, t, v, mu, pot, expected):
        """Exact reproduction of every row of paper Table III."""
        found = primes_mod.find_special_primes(v=v, n=4096, mu=mu, pot=pot, n_beta=2)
        assert len(found) == expected

    def test_prime_properties(self):
        for s in primes_mod.default_prime_set(4096, 6, 30):
            assert primes_mod.is_prime(s.q)
            assert (s.q - 1) % (2 * 4096) == 0
            assert s.q == (1 << s.v) - s.beta
            assert s.pot_terms == 4

    def test_root_of_unity(self):
        q = 0x3FDE0001
        psi = primes_mod.root_of_unity(q, 2 * 4096)
        assert pow(psi, 4096, q) == q - 1  # psi^n = -1 (negacyclic)
        assert pow(psi, 8192, q) == 1


# --------------------------------------------------------------------------
# NTT
# --------------------------------------------------------------------------

SMALL_Q = 0x3FDE0001  # 30-bit special prime, 2*4096 | q-1 (so all n <= 4096 ok)


def _tables(n, q=SMALL_Q):
    return ntt_mod.make_tables(q, n)


class TestNtt:
    @pytest.mark.parametrize(
        "n",
        [8, 16, 64, 256, pytest.param(1024, marks=pytest.mark.slow)],
    )
    def test_roundtrip(self, n):
        tb = _tables(n)
        rng = np.random.default_rng(n)
        a = jnp.asarray(rng.integers(0, tb.q, size=(3, n)))
        out = ntt_mod.intt(ntt_mod.ntt(a, tb), tb)
        assert np.array_equal(np.asarray(out), np.asarray(a))

    @pytest.mark.parametrize("n", [8, 64, 512])
    def test_convolution_theorem(self, n):
        tb = _tables(n)
        rng = np.random.default_rng(n + 1)
        a = rng.integers(0, tb.q, size=n)
        b = rng.integers(0, tb.q, size=n)
        got = ntt_mod.negacyclic_mul(jnp.asarray(a), jnp.asarray(b), tb)
        want = pm.schoolbook_negacyclic(a.tolist(), b.tolist(), tb.q)
        assert np.asarray(got).tolist() == want

    def test_no_permutation_between_stages(self):
        """The cascade lowers with zero gather/scatter/permute ops — the
        JAX-level expression of the no-shuffle contribution."""
        import jax

        tb = _tables(256)
        fn = jax.jit(lambda a, b: ntt_mod.negacyclic_mul(a, b, tb))
        a = jnp.zeros((256,), jnp.int64)
        txt = fn.lower(a, a).as_text()
        for op in ("gather", "scatter", "sort"):
            assert op not in txt, f"unexpected {op} in cascade HLO"

    @given(
        st.integers(0, SMALL_Q - 1),
        st.integers(0, SMALL_Q - 1),
        st.integers(0, SMALL_Q - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_linearity_property(self, c1, c2, seed):
        n = 32
        tb = _tables(n)
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(0, tb.q, size=n))
        b = jnp.asarray(rng.integers(0, tb.q, size=n))
        lhs = ntt_mod.ntt((c1 * a + c2 * b) % tb.q, tb)
        rhs = (c1 * ntt_mod.ntt(a, tb) + c2 * ntt_mod.ntt(b, tb)) % tb.q
        assert np.array_equal(np.asarray(lhs), np.asarray(rhs))

    def test_negacyclic_wraparound_sign(self):
        # x^(n-1) * x = x^n = -1 mod (x^n + 1)
        n = 16
        tb = _tables(n)
        a = np.zeros(n, dtype=np.int64)
        b = np.zeros(n, dtype=np.int64)
        a[n - 1] = 1
        b[1] = 1
        got = np.asarray(ntt_mod.negacyclic_mul(jnp.asarray(a), jnp.asarray(b), tb))
        want = np.zeros(n, dtype=np.int64)
        want[0] = tb.q - 1
        assert np.array_equal(got, want)

    def test_channels(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        ct = p.tables
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(0, 2**29, size=(3, 2, 64)))
        b = jnp.asarray(rng.integers(0, 2**29, size=(3, 2, 64)))
        got = np.asarray(ntt_mod.negacyclic_mul_channels(a, b, ct))
        for c in range(3):
            for r in range(2):
                want = pm.schoolbook_negacyclic(
                    np.asarray(a)[c, r].tolist(),
                    np.asarray(b)[c, r].tolist(),
                    int(ct.qs[c]),
                )
                assert got[c, r].tolist() == want


# --------------------------------------------------------------------------
# Barrett
# --------------------------------------------------------------------------


class TestBarrett:
    @pytest.mark.parametrize("c", [35, 45, 54])
    def test_barrett_reduce(self, c):
        q = SMALL_Q
        eps, s1, s2 = rns_mod.barrett_constants(q, c, 30)
        rng = np.random.default_rng(c)
        xs = np.concatenate(
            [
                rng.integers(0, 1 << c, size=4096),
                np.array([0, 1, q - 1, q, q + 1, (1 << c) - 1, (1 << c) - q]),
            ]
        )
        got = np.asarray(rns_mod.barrett_reduce(jnp.asarray(xs), q, eps, s1, s2))
        assert np.array_equal(got, xs % q)


# --------------------------------------------------------------------------
# bigint
# --------------------------------------------------------------------------


class TestBigint:
    @given(st.integers(0, 2**180 - 1))
    @settings(max_examples=50, deadline=None)
    def test_limb_roundtrip(self, x):
        limbs = bigint.int_to_limbs(x, 28, 7)
        assert bigint.limbs_to_int(limbs, 28) == x

    @given(st.integers(0, 2**170), st.integers(0, 2**170))
    @settings(max_examples=50, deadline=None)
    def test_compare_sub(self, a, b):
        a, b = max(a, b), min(a, b)
        la = jnp.asarray(bigint.int_to_limbs(a, 28, 7))
        lb = jnp.asarray(bigint.int_to_limbs(b, 28, 7))
        assert bool(bigint.compare_ge(la, lb))
        diff = bigint.sub_limbs(la, lb, 28)
        assert bigint.limbs_to_int(np.asarray(diff), 28) == a - b

    def test_carry_normalize(self):
        x = jnp.asarray(np.array([[2**60, 2**55, 3, 0, 0, 0, 0]], dtype=np.int64))
        out = bigint.carry_normalize(x, 28)
        assert bigint.limbs_to_int(np.asarray(out)[0], 28) == 2**60 + (2**55 << 28) + (3 << 56)


# --------------------------------------------------------------------------
# RNS
# --------------------------------------------------------------------------


class TestRns:
    @pytest.fixture(scope="class")
    def p(self):
        return params_mod.make_params(n=64, t=3, v=30)

    def test_crt_roundtrip(self, p):
        rng = random.Random(0)
        xs = [rng.randrange(p.q) for _ in range(64)]
        z = jnp.asarray(pm.ints_to_segments(xs, p.plan))
        res = rns_mod.decompose(z, p.plan)
        out = rns_mod.compose(res, p.plan)
        assert pm.limbs_out_to_ints(np.asarray(out), p.plan) == xs

    def test_sau_equals_generic(self, p):
        rng = np.random.default_rng(2)
        z = jnp.asarray(rng.integers(0, 1 << 30, size=(5, p.plan.seg_count)))
        a = np.asarray(rns_mod.decompose(z, p.plan))
        b = np.asarray(rns_mod.decompose_sau(z, p.plan))
        assert np.array_equal(a, b)

    def test_conventional_equals_optimized(self, p):
        rng = random.Random(3)
        xs = [rng.randrange(p.q) for _ in range(32)]
        res = jnp.asarray(
            np.array([[x % int(q) for x in xs] for q in p.plan.qs])
        )
        a = rns_mod.compose(res, p.plan)
        b = rns_mod.compose_conventional(res, p.plan)
        ia = pm.limbs_out_to_ints(np.asarray(a), p.plan)
        ib = pm.limbs_out_to_ints(np.asarray(b), p.plan)
        assert ia == ib == xs

    @given(st.integers(0, 2**89))
    @settings(max_examples=40, deadline=None)
    def test_decompose_property(self, x):
        p = params_mod.make_params(n=64, t=3, v=30)
        x %= p.q
        z = jnp.asarray(bigint.int_to_limbs(x, p.plan.v, p.plan.seg_count))
        res = np.asarray(rns_mod.decompose(z, p.plan))
        for i, qi in enumerate(p.plan.qs):
            assert int(res[i]) == x % int(qi)


# --------------------------------------------------------------------------
# End-to-end multiplier
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cached_plan():
    return repro.plan(n=64, t=3, v=30)


class TestPolymul:
    @pytest.mark.parametrize("t,v,n", [(3, 30, 64), (6, 30, 128)])
    def test_jit_pipeline_matches_schoolbook(self, t, v, n):
        p = params_mod.make_params(n=n, t=t, v=v)
        rng = random.Random(42)
        a = [rng.randrange(p.q) for _ in range(n)]
        b = [rng.randrange(p.q) for _ in range(n)]
        pl = repro.plan(n=n, t=t, v=v)
        assert repro.polymul_ints(pl, a, b) == pm.schoolbook_negacyclic(a, b, p.q)

    def test_sau_and_generic_paths_agree(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        rng = random.Random(7)
        a = [rng.randrange(p.q) for _ in range(64)]
        b = [rng.randrange(p.q) for _ in range(64)]
        pl1 = repro.plan(n=64, t=3, v=30, use_sau=True)
        pl2 = repro.plan(n=64, t=3, v=30, use_sau=False)
        assert repro.polymul_ints(pl1, a, b) == repro.polymul_ints(pl2, a, b)

    def test_oracle_v45(self):
        """The paper's t=4, v=45, 180-bit configuration (oracle path)."""
        p = params_mod.make_params(n=64, t=4, v=45)
        assert p.q.bit_length() == 180
        rng = random.Random(8)
        a = [rng.randrange(p.q) for _ in range(64)]
        b = [rng.randrange(p.q) for _ in range(64)]
        assert pm.oracle_multiply(a, b, p) == pm.schoolbook_negacyclic(a, b, p.q)

    def test_batched(self):
        p = params_mod.make_params(n=64, t=3, v=30)
        pl = repro.plan(n=64, t=3, v=30)
        rng = np.random.default_rng(11)
        ints = lambda: [
            [int(x) for x in rng.integers(0, 2**60, size=64)] for _ in range(2)
        ]
        A, B = ints(), ints()
        za = jnp.asarray(np.stack([pm.ints_to_segments(r, p.plan) for r in A]))
        zb = jnp.asarray(np.stack([pm.ints_to_segments(r, p.plan) for r in B]))
        out = np.asarray(repro.execute(pl, za, zb))
        for r in range(2):
            got = pm.limbs_out_to_ints(out[r], p.plan)
            assert got == pm.schoolbook_negacyclic(A[r], B[r], p.q)

    @given(st.integers(0, 2**64), st.integers(2, 2**20))
    @settings(max_examples=10, deadline=None)
    def test_ring_homomorphism_property(self, seed, scale):
        """(c*a) * b == c * (a*b) in R_q — multiplier respects module structure."""
        p = params_mod.make_params(n=64, t=3, v=30)
        rng = random.Random(seed)
        a = [rng.randrange(p.q) for _ in range(64)]
        b = [rng.randrange(p.q) for _ in range(64)]
        pl = _cached_plan()
        ca = [(scale * x) % p.q for x in a]
        lhs = repro.polymul_ints(pl, ca, b)
        ab = repro.polymul_ints(pl, a, b)
        rhs = [(scale * x) % p.q for x in ab]
        assert lhs == rhs


# --------------------------------------------------------------------------
# Schedule (contribution 1 at the clock level)
# --------------------------------------------------------------------------


class TestSchedule:
    @pytest.mark.parametrize("n", [16, 64, 256, 1024, 4096])
    def test_bit_reversed_folding_needs_zero_buffer(self, n):
        sim = sched.simulate_cascade(n, bit_reversed_intt=True)
        assert sim.max_buffer_pairs == 0
        assert sim.added_latency == 0

    @pytest.mark.parametrize("n", [16, 64, 256, 1024, 4096])
    def test_same_folding_needs_buffer(self, n):
        sim = sched.simulate_cascade(n, bit_reversed_intt=False)
        assert sim.max_buffer_pairs >= n // 8
        assert sim.added_latency > 0

    def test_timing_formulas(self):
        # Fig 17 / §V-B numbers for n = 4096
        assert sched.bpp_cycles(4096) == 2048
        assert sched.latency_cycles(4096) == 4094
        assert sched.latency_cycles(4096, with_shuffle=True) == 4094 + 1024
        # paper: shuffling increases latency by ~20.0%
        inc = sched.latency_cycles(4096, with_shuffle=True) / sched.latency_cycles(4096)
        assert abs(inc - 1.25 * 0.8 - 0.2) < 0.06 or abs(inc - 1.2) < 0.06

    def test_folding_tables_match_paper_16pt(self):
        # Eq (1): NTT folding sets for n=16
        assert sched.ntt_folding_order(16, 0).tolist() == [0, 1, 2, 3, 4, 5, 6, 7]
        assert sched.ntt_folding_order(16, 1).tolist() == [4, 5, 6, 7, 0, 1, 2, 3]
        assert sched.ntt_folding_order(16, 2).tolist() == [2, 3, 4, 5, 6, 7, 0, 1]
        assert sched.ntt_folding_order(16, 3).tolist() == [1, 2, 3, 4, 5, 6, 7, 0]
        # Eq (2): iNTT folding sets
        assert sched.intt_folding_order(16, 0).tolist() == [4, 2, 6, 1, 5, 3, 7, 0]
        assert sched.intt_folding_order(16, 1).tolist() == [0, 4, 2, 6, 1, 5, 3, 7]
