"""Batched serving demo: prefill + decode through the Engine (the same
serve_step the decode-shape dry-runs lower at production scale).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch yi-6b
"""
import argparse

import numpy as np

import jax

from repro.configs import registry
from repro.models import model as M
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = registry.get(args.arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=4, max_len=128)
    prompts = [
        np.array([5, 7, 11], np.int32),
        np.array([2, 4, 6, 8], np.int32),
        np.array([100, 200], np.int32),
    ]
    outs = eng.generate(prompts, max_new=args.max_new)
    for i, o in enumerate(outs):
        print(f"request {i}: prompt={prompts[i].tolist()} -> {o}")
    print(f"[ok] {len(outs)} requests decoded {args.max_new} tokens each "
          f"({cfg.name} reduced)")


if __name__ == "__main__":
    main()
