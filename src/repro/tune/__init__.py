"""Profile-driven autotuner (DESIGN.md §11).

The paper's central trade — clock cycles inversely proportional to the
level of parallelism — shows up here as the plan knobs ``backend``,
``schedule`` (kind + splits), ``row_blk`` and ``channel_grid``.  This
package chooses them from measurement instead of hand-picked defaults:

* :mod:`repro.tune.sweep` — enumerate the servable candidate configs per
  workload key ``(n, t, v, batch)`` (pruning unservable combos via the
  plan-error taxonomy) and measure each with warm-up-excluded compiled
  wall-clock (AOT ``jax.jit(...).lower(...).compile()`` — a real XLA:CPU
  compile today, Mosaic/TPU transparently when present), falling back to
  eager interpret timing when a candidate cannot compile;
* :mod:`repro.tune.table` — the persistent versioned JSON tuning table,
  keyed by device kind + workload key, consulted by
  ``repro.plan(..., tuning=...)`` at plan time (resolution order:
  explicit knob > tuning table > static default);
* :mod:`repro.tune.costcheck` — cross-check of the HLO cost model
  (:mod:`repro.launch.hlo_analyzer`) against the stopwatch: rank
  correlation of predicted vs measured ordering per workload, flagging
  candidates where the two disagree badly.

CLI front door: ``python -m repro.launch.autotune`` (sweep /
show-table / check / prune-stale).

Only the table surface is imported eagerly — the sweep harness pulls in
the full execution stack, so import :mod:`repro.tune.sweep` explicitly.
"""
from repro.tune.table import (
    DEFAULT_TABLE_PATH,
    TABLE_SCHEMA,
    TABLE_VERSION,
    TUNABLE_KNOBS,
    TuningTable,
    TuningTableError,
    device_kind,
    parse_workload_key,
    workload_key,
)

__all__ = [
    "DEFAULT_TABLE_PATH",
    "TABLE_SCHEMA",
    "TABLE_VERSION",
    "TUNABLE_KNOBS",
    "TuningTable",
    "TuningTableError",
    "device_kind",
    "parse_workload_key",
    "workload_key",
]
