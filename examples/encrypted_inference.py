"""Encrypted inference: a linear classifier evaluated on BFV-encrypted
activations — every homomorphic product runs on the PaReNTT multiplier.

The server sees only ciphertexts; the client encrypts features and
decrypts logits.  ct x plaintext-weight products need no relinearization.

Weights are fixed-point quantized; features are packed one-per-slot into
the polynomial coefficients and each class weight vector is packed
reversed so coefficient (n-1) of the product polynomial holds the inner
product (the standard coefficient-packing trick for negacyclic rings).

Run:  PYTHONPATH=src python examples/encrypted_inference.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bfv


def pack_weights(w_row: np.ndarray, n: int) -> np.ndarray:
    """Reverse-pack so (a * w)[n-1] = sum_i a_i w_i (negacyclic ring)."""
    out = np.zeros(n, dtype=np.int64)
    d = len(w_row)
    out[: d][::-1] = w_row  # w at positions d-1-i
    return out


def main():
    rng = np.random.default_rng(0)
    d_in, n_cls = 64, 10
    # synthetic "digit" task: class templates + noise
    templates = rng.normal(size=(n_cls, d_in))
    X = np.stack([templates[i % n_cls] + 0.3 * rng.normal(size=d_in) for i in range(20)])
    labels = np.arange(20) % n_cls

    # train a tiny linear probe in the clear (plain numpy ridge)
    W = templates  # nearest-template classifier is enough for the demo

    # fixed-point quantization
    fx, fw = 6, 6
    Xq = np.round(X * (1 << fx)).astype(np.int64)
    Wq = np.round(W * (1 << fw)).astype(np.int64)

    ctx = bfv.make_context(n=256, t=3, v=30, pt_mod=1 << 26)
    keys = bfv.keygen(jax.random.PRNGKey(0), ctx)

    correct = 0
    for i, (x, y) in enumerate(zip(Xq, labels)):
        poly = np.zeros(ctx.params.n, dtype=np.int64)
        poly[:d_in] = x % ctx.pt_mod
        ct = bfv.encrypt(jax.random.PRNGKey(100 + i), jnp.asarray(poly), keys, ctx)
        logits = []
        for c in range(n_cls):
            wpoly = pack_weights(Wq[c], ctx.params.n)
            prod = bfv.mul_plain(ct, jnp.asarray(wpoly), ctx)  # PaReNTT x2
            dec = bfv.decrypt(prod, keys, ctx)
            v = int(dec[d_in - 1])
            if v > ctx.pt_mod // 2:
                v -= ctx.pt_mod
            logits.append(v / (1 << (fx + fw)))
        pred = int(np.argmax(logits))
        plain = int(np.argmax(X[i] @ W.T))
        assert pred == plain, (i, pred, plain, logits)
        correct += pred == y
    print(f"[ok] encrypted == plaintext predictions on all 20 samples")
    print(f"     accuracy {correct}/20 (synthetic task)")
    print(
        f"     each class logit = 1 homomorphic ct x pt product "
        f"= 2 PaReNTT negacyclic multiplications (t={ctx.params.t} RNS channels)"
    )


if __name__ == "__main__":
    main()
