"""The profile-driven autotuner (DESIGN.md §11): the persistent tuning
table (schema, atomic writes, batch-fallback lookup, staleness), the
plan-time resolution order (explicit knob > tuning table > static
default), the compiled-mode sweep harness (dedupe + taxonomy pruning +
never-slower winner), and the HLO cost-model cross-check."""
import json
import os

import numpy as np
import pytest

import repro
from repro.errors import UnknownKnobError
from repro.tune import (
    TABLE_SCHEMA,
    TABLE_VERSION,
    TUNABLE_KNOBS,
    TuningTable,
    TuningTableError,
    parse_workload_key,
    workload_key,
)
from repro.tune import costcheck


def _table(tmp_path, winner, *, n=64, t=3, v=30, batch=2, kind="cpu", **extra):
    tab = TuningTable()
    tab.put(n=n, t=t, v=v, batch=batch, winner=winner, kind=kind, **extra)
    path = tmp_path / "TUNING.json"
    tab.save(path)
    return str(path), tab


class TestTable:
    def test_round_trip(self, tmp_path):
        winner = {"backend": "jnp", "schedule": "radix2",
                  "row_blk": None, "channel_grid": None}
        path, tab = _table(tmp_path, winner, winner_us=10.0, default_us=12.0)
        got = TuningTable.load(path)
        assert got.entries == tab.entries
        assert got.to_dict()["schema"] == TABLE_SCHEMA
        assert got.to_dict()["version"] == TABLE_VERSION

    def test_workload_key_round_trip(self):
        assert workload_key(256, 6, 30, 2) == "n256_t6_v30_b2"
        assert parse_workload_key("n256_t6_v30_b2") == {
            "n": 256, "t": 6, "v": 30, "batch": 2,
        }
        with pytest.raises(TuningTableError, match="bad workload key"):
            parse_workload_key("n256_t6")

    def test_rejects_bad_schema_and_version(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema": "nope", "version": TABLE_VERSION}))
        with pytest.raises(TuningTableError, match="schema"):
            TuningTable.load(p)
        p.write_text(json.dumps({"schema": TABLE_SCHEMA, "version": 99}))
        with pytest.raises(TuningTableError, match="version"):
            TuningTable.load(p)
        p.write_text("{not json")
        with pytest.raises(TuningTableError, match="malformed"):
            TuningTable.load(p)

    def test_rejects_unresolved_and_non_tunable_winners(self):
        tab = TuningTable()
        with pytest.raises(TuningTableError, match="non-tunable"):
            tab.put(n=64, t=3, v=30, batch=2, winner={"use_sau": False})
        with pytest.raises(TuningTableError, match="resolved backend"):
            tab.put(n=64, t=3, v=30, batch=2, winner={"backend": "auto"})
        with pytest.raises(TuningTableError, match="resolved schedule"):
            tab.put(n=64, t=3, v=30, batch=2, winner={"schedule": "auto"})
        with pytest.raises(TuningTableError, match="row_blk"):
            tab.put(n=64, t=3, v=30, batch=2, winner={"row_blk": True})
        with pytest.raises(TuningTableError, match="channel_grid"):
            tab.put(n=64, t=3, v=30, batch=2, winner={"channel_grid": 1})

    def test_atomic_save_leaves_no_temp_files(self, tmp_path):
        winner = {"backend": "jnp", "schedule": "radix2",
                  "row_blk": None, "channel_grid": None}
        path, tab = _table(tmp_path, winner)
        tab.save(path)  # overwrite in place
        assert sorted(os.listdir(tmp_path)) == ["TUNING.json"]
        assert TuningTable.load(path).entries == tab.entries

    def test_lookup_batch_fallback(self):
        tab = TuningTable()
        tab.put(n=64, t=3, v=30, batch=8, kind="cpu",
                winner={"backend": "pallas"})
        tab.put(n=64, t=3, v=30, batch=2, kind="cpu",
                winner={"backend": "jnp"})
        # exact batch hits its entry; batch=None (the plan-time call,
        # plans are batch-agnostic) resolves the smallest batch
        assert tab.lookup(n=64, t=3, v=30, batch=8, kind="cpu")["backend"] == "pallas"
        assert tab.lookup(n=64, t=3, v=30, kind="cpu")["backend"] == "jnp"
        assert tab.lookup(n=64, t=3, v=30, kind="tpu") is None
        assert tab.lookup(n=128, t=3, v=30, kind="cpu") is None

    def test_prune_stale(self):
        tab = TuningTable()
        tab.put(n=64, t=3, v=30, batch=2, kind="cpu",
                winner={"backend": "jnp"}, measured_at=1000.0)
        tab.put(n=256, t=6, v=30, batch=2, kind="cpu",
                winner={"backend": "jnp"}, measured_at=5000.0)
        removed = tab.prune_stale(max_age_s=3000.0, now=6000.0)
        assert removed == [("cpu", "n64_t3_v30_b2")]
        assert list(tab.entries["cpu"]) == ["n256_t6_v30_b2"]
        # no timestamp counts as stale; emptied kinds are dropped
        tab2 = TuningTable(entries={"cpu": {"n64_t3_v30_b2": {
            "winner": {"backend": "jnp"}}}})
        assert tab2.prune_stale(max_age_s=1.0, now=10.0) == [
            ("cpu", "n64_t3_v30_b2")
        ]
        assert tab2.entries == {}


class TestPlanResolution:
    """plan(tuning=...): explicit knob > tuning table > static default."""

    WINNER = {"backend": "pallas_fused", "schedule": "radix2",
              "row_blk": 2, "channel_grid": None}

    def _path(self, tmp_path):
        import jax

        path, _ = _table(tmp_path, self.WINNER, n=64, t=3, v=30, batch=2,
                         kind=str(jax.default_backend()))
        return path

    def test_table_fills_default_knobs(self, tmp_path):
        path = self._path(tmp_path)
        cfg = repro.plan_key(repro.plan(n=64, t=3, v=30, tuning=path))
        assert cfg.backend == "pallas_fused"
        assert cfg.schedule.canonical == "radix2"
        assert cfg.row_blk == 2

    def test_explicit_knob_beats_table(self, tmp_path):
        path = self._path(tmp_path)
        cfg = repro.plan_key(repro.plan(n=64, t=3, v=30, backend="jnp",
                                        tuning=path))
        assert cfg.backend == "jnp"
        # untouched knobs still come from the table
        assert cfg.schedule.canonical == "radix2"
        cfg2 = repro.plan_key(repro.plan(n=64, t=3, v=30, schedule="four_step",
                                         row_blk=4, tuning=path))
        assert cfg2.schedule.canonical == "four_step"
        assert cfg2.row_blk == 4

    def test_off_and_default_match(self, tmp_path):
        assert repro.plan_key(repro.plan(n=64, t=3, v=30)) == repro.plan_key(
            repro.plan(n=64, t=3, v=30, tuning="off")
        )
        assert repro.plan_key(repro.plan(n=64, t=3, v=30, tuning=None)) == (
            repro.plan_key(repro.plan(n=64, t=3, v=30))
        )

    def test_plan_key_drift_restricted_to_tuned_knobs(self, tmp_path):
        import dataclasses

        path = self._path(tmp_path)
        tcfg = repro.plan_key(repro.plan(n=64, t=3, v=30, tuning=path))
        ucfg = repro.plan_key(repro.plan(n=64, t=3, v=30))
        drift = {
            f.name for f in dataclasses.fields(tcfg)
            if getattr(tcfg, f.name) != getattr(ucfg, f.name)
        }
        assert drift <= set(TUNABLE_KNOBS)

    def test_table_instance_and_missing_path(self, tmp_path):
        tab = TuningTable()
        tab.put(n=64, t=3, v=30, batch=2,
                winner={"backend": "pallas", "schedule": "radix2"})
        cfg = repro.plan_key(repro.plan(n=64, t=3, v=30, tuning=tab))
        assert cfg.backend == "pallas"
        with pytest.raises(TuningTableError, match="no tuning table"):
            repro.plan(n=64, t=3, v=30, tuning=str(tmp_path / "absent.json"))
        with pytest.raises(UnknownKnobError):
            repro.plan(n=64, t=3, v=30, tuning=42)

    def test_tuning_auto_never_raises(self):
        # degrades to static defaults when the seed is absent; resolves
        # the committed seed when present — either way a valid plan
        pl = repro.plan(n=64, t=3, v=30, tuning="auto")
        assert repro.plan_key(pl).n == 64

    def test_other_device_kind_is_ignored(self, tmp_path):
        path, _ = _table(tmp_path, self.WINNER, kind="tpu")
        assert repro.plan_key(repro.plan(n=64, t=3, v=30, tuning=path)) == (
            repro.plan_key(repro.plan(n=64, t=3, v=30))
        )

    def test_tuned_plans_are_retrace_free(self, tmp_path):
        import jax
        import jax.numpy as jnp

        path = self._path(tmp_path)
        traces = 0

        def fn(pl, za, zb):
            nonlocal traces
            traces += 1
            return repro.polymul(pl, za, zb)

        jfn = jax.jit(fn)
        rng = np.random.default_rng(0)
        shape = (2, 64, repro.plan(n=64, t=3, v=30).config.seg_count)
        za = jnp.asarray(rng.integers(0, 1 << 30, size=shape))
        zb = jnp.asarray(rng.integers(0, 1 << 30, size=shape))
        a = jfn(repro.plan(n=64, t=3, v=30, tuning=path), za, zb)
        b = jfn(repro.plan(n=64, t=3, v=30, tuning=path), za, zb)
        assert traces == 1
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_verifier_passes_on_tuned_config(self, tmp_path):
        from repro.analysis.verify import verify_plan

        path = self._path(tmp_path)
        report = verify_plan(repro.plan(n=64, t=3, v=30, tuning=path))
        assert report.ok, [f for f in report.findings]


class TestSweep:
    def test_micro_sweep_prunes_dedupes_and_never_loses(self):
        from repro.tune import sweep as sweep_mod

        wl = sweep_mod.Workload(n=64, t=3, v=30, batch=2)
        cands = (
            sweep_mod.DEFAULT_CANDIDATE,
            sweep_mod.Candidate(backend="jnp", schedule="radix2"),
            # four_step:h is unservable at n=64 — exercises the
            # plan-error-taxonomy pruning path
            sweep_mod.Candidate(backend="jnp", schedule="four_step:h"),
        )
        rep = sweep_mod.sweep_workload(wl, cands, iters=1, warmup=1)
        by_status = {}
        for c in rep["candidates"]:
            by_status.setdefault(c["status"], []).append(c)
        assert len(by_status.get("pruned", [])) == 1
        pruned = by_status["pruned"][0]
        assert pruned["error"]  # taxonomy type recorded
        assert rep["entry"]["winner_us"] <= rep["entry"]["default_us"]
        assert rep["entry"]["rank_correlation"] is None or (
            -1.0 <= rep["entry"]["rank_correlation"] <= 1.0
        )
        # winner knobs are resolved + table-valid
        tab = TuningTable()
        tab.put(**rep["entry"])

    def test_measured_winner_resolves_through_plan(self, tmp_path):
        from repro.tune import sweep as sweep_mod

        wl = sweep_mod.Workload(n=64, t=3, v=30, batch=2)
        tab, report = sweep_mod.sweep([wl], quick=True, iters=1, warmup=0)
        path = tmp_path / "T.json"
        tab.save(path)
        pl = repro.plan(n=64, t=3, v=30, tuning=str(path))
        winner = report["workloads"][0]["entry"]["winner"]
        cfg = repro.plan_key(pl)
        if winner["backend"] is not None:
            assert cfg.backend == winner["backend"]
        if winner["schedule"] is not None:
            assert cfg.schedule.canonical == winner["schedule"]


class TestCostCheck:
    def test_spearman(self):
        assert costcheck.spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
        assert costcheck.spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
        assert costcheck.spearman([1.0, 1.0, 1.0], [1, 2, 3]) is None
        assert costcheck.spearman([1.0], [2.0]) is None
        with pytest.raises(ValueError, match="length"):
            costcheck.spearman([1.0], [1.0, 2.0])

    def test_ranks_average_ties(self):
        assert costcheck._ranks([10.0, 10.0, 30.0]) == [1.5, 1.5, 3.0]

    def test_cross_check_flags_bad_disagreement(self):
        cands = [
            {"name": "a", "measured_us": 1.0, "model_us": 400.0},
            {"name": "b", "measured_us": 2.0, "model_us": 300.0},
            {"name": "c", "measured_us": 3.0, "model_us": 200.0},
            {"name": "d", "measured_us": 4.0, "model_us": 100.0},
            {"name": "e", "measured_us": 5.0, "model_us": None},  # eager
        ]
        out = costcheck.cross_check(cands)
        assert out["modeled"] == 4 and out["unmodeled"] == 1
        assert out["rank_correlation"] == pytest.approx(-1.0)
        flagged = {f["name"] for f in out["flagged"]}
        assert "a" in flagged and "d" in flagged

    def test_predicted_cost_units(self):
        from test_hlo_analyzer import SYNTHETIC_CUSTOM_CALL

        got = costcheck.predicted_cost(SYNTHETIC_CUSTOM_CALL, kind="cpu")
        assert got["custom_call_count"] == 2
        assert got["custom_call_bytes"] > 0
        assert got["model_us"] > 0.0
