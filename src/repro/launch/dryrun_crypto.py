import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Production-mesh dry-run of the PAPER'S OWN workload: a batched stream of
# 180-bit x 4096-coefficient modular polynomial multiplications (the cloud
# HE-evaluation serving shape), plus the BFV ct x pt inference step.
#
#   RNS channels (t=6) -> `model` axis (the paper's t parallel datapaths
#   ARE model parallelism: zero cross-channel communication until the
#   inverse CRT), polynomial batch -> `data`/`pod` axes.
#
#     PYTHONPATH=src python -m repro.launch.dryrun_crypto --mesh both

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro
from repro.kernels import ops as ops_mod
from repro.launch import analysis, hlo_analyzer
from repro.launch.mesh import make_production_mesh

ARTIFACTS = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts")
)


def polymul_step(plan, za, zb):
    """segments (B, n, S) x2 -> product limbs (B, n, L).  The full paper
    pipeline: decompose -> per-channel no-shuffle NTT cascade -> Eq 10,
    through the ONE plan/execute entry point.  The plan defaults to the
    pure-jnp datapath: interpret-mode Pallas loops (any of the pallas*
    backends off-TPU, including pallas_fused_e2e) would bloat the
    lowered HLO on the 512-device mesh; on a real TPU pass --backend
    pallas_fused_e2e to lower the single fused kernel instead."""
    return repro.polymul(plan, za, zb)


def run(mesh_kind: str, batch: int, out_dir: str, backend: str = "jnp",
        schedule: str = "auto", row_blk: int | None = None):
    plan = repro.plan(
        n=4096, t=6, v=30, backend=backend, schedule=schedule,
        row_blk=row_blk, use_sau=False,
    )
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = 512 if mesh_kind == "multi" else 256
    seg = jax.ShapeDtypeStruct((batch, 4096, plan.config.seg_count), jnp.int64)
    ba = ("pod", "data") if mesh_kind == "multi" else ("data",)
    in_sh = NamedSharding(mesh, P(ba, None, None))
    t0 = time.time()
    rec = {"arch": "parentt_he", "shape": f"polymul_b{batch}", "mesh": mesh_kind,
           "n_devices": n_dev, "tag": "crypto", "backend": backend}
    try:
        with mesh:
            # residue-domain tensors (t, B, n): channels over `model`
            def step(za, zb):
                return polymul_step(plan, za, zb)

            jitted = jax.jit(step, in_shardings=(in_sh, in_sh))
            lowered = jitted.lower(seg, seg)
            compiled = lowered.compile()
        rec["memory"] = analysis.memory_stats(compiled)
        hlo = hlo_analyzer.analyze(compiled.as_text())
        rec["hlo"] = {"flops": hlo["flops"], "hbm_bytes": hlo["hbm_bytes"]}
        rec["collectives"] = hlo["collectives"]
        # int butterflies don't ride the MXU: report memory/collective terms
        rec["roofline"] = {
            "memory_s": hlo["hbm_bytes"] / analysis.HBM_BW,
            "collective_s": hlo["collectives"]["total"] / analysis.ICI_BW,
        }
        rec["status"] = "ok"
        print(
            f"[ok] parentt_he x b{batch} x {mesh_kind}: "
            f"hbm/dev={hlo['hbm_bytes']/1e9:.2f}GB "
            f"coll/dev={hlo['collectives']['total']/1e9:.3f}GB "
            f"memory={rec['roofline']['memory_s']*1e6:.0f}us "
            f"({time.time()-t0:.0f}s)"
        )
    except Exception as e:
        import traceback

        rec["status"] = "error"
        rec["error"] = str(e)
        rec["traceback"] = traceback.format_exc()[-3000:]
        print(f"[FAIL] parentt_he {mesh_kind}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"dryrun_{mesh_kind}_parentt_he_b{batch}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def run_dntt(mesh_kind: str, log_n: int, out_dir: str):
    """ONE long polynomial (n = 2^log_n) sharded across the `model` axis —
    the four-step NWC product with a single all-to-all per transform."""
    from repro.core import dntt

    q = 998244353  # 119 * 2^23 + 1: 2n-th roots exist up to n = 2^22
    n = 1 << log_n
    n1 = 1 << (log_n // 2)
    t = dntt.make_fourstep_tables(q, n, n1)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = 512 if mesh_kind == "multi" else 256
    spec_in = NamedSharding(mesh, P("model"))
    a_spec = jax.ShapeDtypeStruct((n,), jnp.int64)
    rec = {"arch": "parentt_dntt", "shape": f"long_2^{log_n}", "mesh": mesh_kind,
           "n_devices": n_dev, "tag": "crypto"}
    t0 = time.time()
    try:
        with mesh:
            cons = dntt.make_shard_constrain(mesh)

            def step(a, b):
                return dntt.negacyclic_mul_fourstep(a, b, t, cons)

            compiled = (
                jax.jit(step, in_shardings=(spec_in, spec_in))
                .lower(a_spec, a_spec)
                .compile()
            )
        hlo = hlo_analyzer.analyze(compiled.as_text())
        rec["hlo"] = {"flops": hlo["flops"], "hbm_bytes": hlo["hbm_bytes"]}
        rec["collectives"] = hlo["collectives"]
        rec["status"] = "ok"
        a2a = hlo["collectives"]["all-to-all"] + hlo["collectives"]["collective-permute"]
        print(
            f"[ok] parentt_dntt n=2^{log_n} x {mesh_kind}: "
            f"a2a/dev={a2a/1e6:.1f}MB coll_total/dev="
            f"{hlo['collectives']['total']/1e6:.1f}MB "
            f"hbm/dev={hlo['hbm_bytes']/1e6:.0f}MB ({time.time()-t0:.0f}s)"
        )
    except Exception as e:
        import traceback

        rec["status"] = "error"
        rec["error"] = str(e)
        rec["traceback"] = traceback.format_exc()[-3000:]
        print(f"[FAIL] parentt_dntt {mesh_kind}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(
        os.path.join(out_dir, f"dryrun_{mesh_kind}_parentt_dntt_2e{log_n}.json"), "w"
    ) as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--log-n", type=int, default=20, help="dntt polynomial size")
    ap.add_argument(
        "--backend", default="jnp", choices=list(ops_mod.BACKENDS),
        help="polymul datapath; keep jnp off-TPU (interpret-mode Pallas "
             "bloats the lowered HLO)",
    )
    ap.add_argument(
        "--schedule", default="auto", choices=list(ops_mod.SCHEDULES),
        help="NTT stage schedule (auto = four_step for n >= 256)",
    )
    ap.add_argument(
        "--row-blk", type=int, default=None,
        help="kernel tile rows per grid step (None = per-kernel default)",
    )
    ap.add_argument("--out", default=ARTIFACTS)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    fails = 0
    for mk in meshes:
        fails += run(
            mk, args.batch, args.out, backend=args.backend,
            schedule=args.schedule, row_blk=args.row_blk,
        )["status"] != "ok"
        fails += run_dntt(mk, args.log_n, args.out)["status"] != "ok"
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
