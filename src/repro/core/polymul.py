"""Ground-truth oracles for the PaReNTT multiplier (paper Fig 10), plus
the deprecated :class:`ParenttMultiplier` class front door.

Oracles:
  * ``schoolbook_negacyclic`` — O(n^2) Python-bigint negacyclic product.
  * ``oracle_multiply``       — the RNS+NTT pipeline in Python bigints
    (any v, including the t=4 / v=45 config whose products exceed
    int64).  This is also the execution path of ``width="oracle"``
    plans in :mod:`repro.api`.

The end-to-end device pipeline moved behind the plan/execute API
(:func:`repro.api.plan` / :func:`repro.api.polymul`), which dispatches
on modulus width internally; :class:`ParenttMultiplier` remains as a
thin delegating shim so existing snippets keep running.
"""
from __future__ import annotations

import functools
import warnings

import jax
import numpy as np

from repro.core import bigint, rns as rns_mod
from repro.core.params import ParenttParams
from repro.kernels import ops as ops_mod

# --------------------------------------------------------------------------
# Oracles (host, exact)
# --------------------------------------------------------------------------


def schoolbook_negacyclic(a: list[int], b: list[int], q: int) -> list[int]:
    """p = a*b mod (x^n + 1, q), Python bigints."""
    n = len(a)
    p = [0] * n
    for i in range(n):
        ai = a[i] % q
        if not ai:
            continue
        for j in range(n):
            k = i + j
            if k >= n:
                p[k - n] = (p[k - n] - ai * b[j]) % q
            else:
                p[k] = (p[k] + ai * b[j]) % q
    return p


def oracle_multiply(a: list[int], b: list[int], params: ParenttParams) -> list[int]:
    """RNS+NTT pipeline in Python bigints (reference for any v)."""
    plan = params.plan
    out = [0] * params.n
    for i in range(params.t):
        qi = int(plan.qs[i])
        pi = schoolbook_negacyclic([x % qi for x in a], [x % qi for x in b], qi)
        star = plan.q // qi
        tilde = int(plan.qi_tilde[i])
        for j in range(params.n):
            out[j] = (out[j] + ((pi[j] * tilde) % qi) * star) % plan.q
    return out


# --------------------------------------------------------------------------
# Host <-> device formats
# --------------------------------------------------------------------------


def ints_to_segments(xs: list[int], plan: rns_mod.RnsPlan) -> np.ndarray:
    return bigint.ints_to_limbs(xs, plan.v, plan.seg_count)


def limbs_out_to_ints(limbs, plan: rns_mod.RnsPlan) -> list[int]:
    return bigint.limbs_to_ints(limbs, plan.w)


# --------------------------------------------------------------------------
# jit pipeline
# --------------------------------------------------------------------------


class ParenttMultiplier:
    """DEPRECATED — use ``repro.api.plan(...)`` + ``repro.api.polymul``:
    the plan/execute API is the single front door and absorbs the
    backend/schedule/width dispatch this class used to expose.  This
    shim delegates every method so existing snippets keep running.

    ``backend`` selects the datapath for all three steps (see
    :mod:`repro.kernels.ops`); ``None`` defers to ``params.backend``.
    """

    def __init__(
        self,
        params: ParenttParams,
        use_sau: bool = True,
        backend: str | None = None,
    ):
        if params.tables is None:
            raise ValueError(
                f"ParenttMultiplier requires int64-safe NTT tables, but params "
                f"(n={params.n}, t={params.t}, v={params.v}) have none: v > 31 "
                f"means residue products overflow int64.  Use "
                f"polymul.oracle_multiply (exact host bigints, any v) or "
                f"repro.core.wide.WideParenttMultiplier (digit-split v=45 "
                f"datapath) instead — or simply repro.api.plan(...), which "
                f"dispatches on width automatically."
            )
        from repro import api  # deferred: api imports this module

        warnings.warn(
            "ParenttMultiplier is deprecated; use repro.api.plan(...) + "
            "repro.api.polymul(...) (one entry point for every modulus width)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.params = params
        self.use_sau = use_sau
        self.backend = ops_mod.resolve_backend(params, backend)
        self._plan = api.plan_from_params(
            params, backend=self.backend, use_sau=use_sau
        )

    # -- step 1: pre-processing ------------------------------------------
    def preprocess(self, z: jax.Array) -> jax.Array:
        """z: (..., n, S) segments -> residues (t, ..., n)."""
        from repro import api

        return api.decompose(self._plan, z)

    # -- step 2: evaluation in the residue domain ------------------------
    def residue_mul(self, ra: jax.Array, rb: jax.Array) -> jax.Array:
        """(t, ..., n) x (t, ..., n) -> (t, ..., n): parallel no-shuffle
        NTT cascades, one per RNS channel."""
        from repro import api

        return api.negacyclic_mul(self._plan, ra, rb)

    # -- step 3: post-processing ------------------------------------------
    def postprocess(self, residues: jax.Array) -> jax.Array:
        """(t, ..., n) -> (..., n, L) limbs of p mod q."""
        from repro import api

        return api.compose(self._plan, residues)

    # -- full pipeline ----------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def __call__(self, za: jax.Array, zb: jax.Array) -> jax.Array:
        """za, zb: (..., n, S) segment arrays -> (..., n, L) limb array,
        via :func:`repro.api.polymul` (one pallas_call end to end on
        ``backend="pallas_fused_e2e"``)."""
        from repro import api

        return api.polymul(self._plan, za, zb)

    # -- host convenience ---------------------------------------------------
    def multiply_ints(self, a: list[int], b: list[int]) -> list[int]:
        from repro import api

        return api.polymul_ints(self._plan, a, b)
