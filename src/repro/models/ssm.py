"""Mamba2 SSD (state-space duality) block — chunked parallel form for
train/prefill, recurrent form for decode (arXiv:2405.21060).

Layout (ngroups = 1):
  in_proj -> [z (d_in), xBC (d_in + 2*N), dt (H)]
  causal depthwise conv over xBC, SiLU
  SSD: X (B,S,H,P), B/C (B,S,N), dt (B,S,H), A (H,) < 0
  y = SSD(X, dt, A, B, C) + D * X ; out = out_proj(rmsnorm(y * silu(z)))

The chunked algorithm (intra-chunk quadratic + inter-chunk state scan) is
validated against the naive per-step recurrence in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import CDTYPE, _cast, dense_init, rmsnorm, rmsnorm_init
from repro.sharding import ctx


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    return d_in, H, cfg.ssm_headdim, cfg.ssm_state


def mamba2_init(key, cfg: ModelConfig):
    d_in, H, P, N = dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * d_in + 2 * N + H)),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in),
        "out_proj": dense_init(ks[2], (d_in, cfg.d_model)),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_in, H, P, N = dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in : 2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N :]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along S.  xBC: (B,S,Cd); w: (K,Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for k in range(K):
        out = out + pad[:, k : k + xBC.shape[1], :].astype(jnp.float32) * w[k][None, None, :]
    return out + b[None, None, :]


def _ssd_chunked(X, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD.  X: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (Y: (B,S,H,P), h_final: (B,H,P,N))."""
    Bsz, S, H, P = X.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    # reshape into chunks
    Xc = X.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    dA = dtc * A[None, None, None, :]  # (B,nc,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk
    seg_end = cs[:, :, -1:, :]  # (B,nc,1,H)
    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    ii = np.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)  # (B,nc,Q,Q,H)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    M = scores[..., None] * L  # (B,nc,Q,Q,H)
    Y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", M, dtc, Xc)
    # chunk states: S_c = sum_j exp(seg_end - cs_j) * dt_j * B_j (x) X_j
    decay_state = jnp.exp(seg_end - cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn", decay_state, dtc, Bc, Xc)
    # inter-chunk scan: h_{c} = exp(sum dA_c) h_{c-1} + S_c
    seg_all = jnp.exp(seg_end[:, :, 0, :])  # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), dtype=X.dtype)

    def step(h, inp):
        decay, s_c = inp  # (B,H), (B,H,P,N)
        h_new = (h * decay[:, :, None, None] + s_c).astype(h.dtype)
        return h_new, h

    (h_final, h_prevs) = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(seg_all, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state BEFORE chunk
    # inter-chunk contribution: y_i += C_i . (exp(cs_i) * h_prev)
    decay_in = jnp.exp(cs)  # (B,nc,Q,H)
    Y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, h_prevs, decay_in)
    Y = (Y_intra + Y_inter).reshape(Bsz, S, H, P)
    return Y, h_final


def _ssd_recurrent_step(h, x, dt, A, Bv, Cv):
    """One decode step.  h: (B,H,P,N); x: (B,H,P); dt: (B,H); Bv/Cv: (B,N)."""
    dA = jnp.exp(dt * A[None, :])  # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv, x)
    h = h * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cv, h)
    return h, y


def mamba2_apply(params, x, cfg: ModelConfig, *, ssm_state=None, conv_state=None):
    """x: (B,S,D).  If states are provided (decode), S is the new-token
    count (typically 1) and updated states are returned.

    Returns (out, (ssm_state, conv_state))."""
    Bsz, S, D = x.shape
    d_in, H, P, N = dims(cfg)
    proj = _cast(x) @ _cast(params["in_proj"])  # (B,S,2*d_in+2N+H)
    z, xBC, dt = _split_proj(proj, cfg)
    K = cfg.conv_kernel
    if conv_state is None:
        conv_in = xBC
        new_conv_state = xBC[:, -(K - 1) :, :] if S >= K - 1 else jnp.pad(
            xBC, ((0, 0), (K - 1 - S, 0), (0, 0))
        )
        conv = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    else:
        full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
        conv = _causal_conv(full, params["conv_w"], params["conv_b"])[:, K - 1 :, :]
        new_conv_state = full[:, -(K - 1) :, :]
    conv = jax.nn.silu(conv)
    Xf = conv[..., :d_in].astype(jnp.float32).reshape(Bsz, S, H, P)
    Bm = conv[..., d_in : d_in + N].astype(jnp.float32)
    Cm = conv[..., d_in + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])  # (H,)

    if ssm_state is None and S > 1:
        Q = min(cfg.ssm_chunk, S)
        pad = (-S) % Q
        if pad:
            Xp = jnp.pad(Xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        else:
            Xp, dtp, Bp, Cp = Xf, dt, Bm, Cm
        Y, h_final = _ssd_chunked(Xp, dtp, A, Bp, Cp, Q)
        Y = Y[:, :S]
    else:
        h = (
            ssm_state
            if ssm_state is not None
            else jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
        )
        ys = []
        for s in range(S):  # S == 1 in decode
            h, y = _ssd_recurrent_step(h, Xf[:, s], dt[:, s], A, Bm[:, s], Cm[:, s])
            ys.append(y)
        Y = jnp.stack(ys, axis=1)
        h_final = h
    Y = Y + Xf * params["D"][None, None, :, None]
    Y = Y.reshape(Bsz, S, d_in).astype(CDTYPE)
    gated = Y * jax.nn.silu(_cast(z))
    out = rmsnorm(params["norm"], gated, cfg.norm_eps) @ _cast(params["out_proj"])
    return ctx.constrain(out, "btd"), (h_final, new_conv_state)
