"""Multi-limb big-integer helpers for the CRT pre/post-processing datapath.

Big integers (e.g. 180-bit polynomial coefficients) are last-axis arrays of
limbs, least-significant first.  Two bases are used:

* base ``2^v`` "segments" — the paper's Alg 1 line 1 splitting
  (``a_j = z_0 + z_1 B + ...``, B = 2^v), input format of pre-processing;
* base ``2^w`` "limbs" (w <= 29) — the accumulation format of
  post-processing, chosen so that (31-bit residue) x (w-bit limb) products
  plus a t-way sum stay inside int64.

Host<->device conversion helpers use Python bigints (exact).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def int_to_limbs(x: int, width: int, count: int) -> np.ndarray:
    assert x >= 0
    mask = (1 << width) - 1
    out = np.zeros(count, dtype=np.int64)
    for i in range(count):
        out[i] = x & mask
        x >>= width
    assert x == 0, "limb count too small"
    return out


def ints_to_limbs(xs, width: int, count: int) -> np.ndarray:
    return np.stack([int_to_limbs(int(x), width, count) for x in xs])


def limbs_to_int(limbs, width: int) -> int:
    x = 0
    for i, l in enumerate(np.asarray(limbs).tolist()):
        x += int(l) << (width * i)
    return x


def limbs_to_ints(arr, width: int) -> list[int]:
    arr = np.asarray(arr)
    return [limbs_to_int(row, width) for row in arr.reshape(-1, arr.shape[-1])]


# --------------------------------------------------------------------------
# jnp limb ops (last axis = limbs, LSB first)
# --------------------------------------------------------------------------


def carry_normalize(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """Propagate carries so every limb < 2^width.  Limbs may hold values up
    to ~2^62 on input.  One sequential pass (running carry) suffices."""
    mask = (1 << width) - 1
    L = x.shape[-1]
    outs = []
    carry = jnp.zeros_like(x[..., 0])
    for i in range(L):
        s = x[..., i] + carry
        outs.append(s & mask)
        carry = s >> width
    # assert-by-construction: caller sizes L so the final carry is zero.
    return jnp.stack(outs, axis=-1)


def compare_ge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a >= b lexicographically from the most-significant limb. Normalized
    inputs. Returns bool array over leading dims."""
    L = a.shape[-1]
    ge = jnp.ones(a.shape[:-1], dtype=bool)
    decided = jnp.zeros(a.shape[:-1], dtype=bool)
    for i in range(L - 1, -1, -1):
        ai, bi = a[..., i], b[..., i]
        gt = ai > bi
        lt = ai < bi
        ge = jnp.where(~decided & gt, True, ge)
        ge = jnp.where(~decided & lt, False, ge)
        decided = decided | gt | lt
    return ge


def sub_limbs(a: jnp.ndarray, b: jnp.ndarray, width: int) -> jnp.ndarray:
    """a - b (requires a >= b), normalized limbs, with borrow propagation."""
    L = a.shape[-1]
    outs = []
    borrow = jnp.zeros_like(a[..., 0])
    base = 1 << width
    for i in range(L):
        d = a[..., i] - b[..., i] - borrow
        neg = d < 0
        outs.append(jnp.where(neg, d + base, d))
        borrow = neg.astype(a.dtype)
    return jnp.stack(outs, axis=-1)


def cond_sub(a: jnp.ndarray, m: jnp.ndarray, width: int) -> jnp.ndarray:
    """If a >= m subtract m, else keep a.  Normalized limbs."""
    ge = compare_ge(a, m)
    return jnp.where(ge[..., None], sub_limbs(a, m, width), a)


def mod_by_subtraction(
    a: jnp.ndarray, m: jnp.ndarray, width: int, times: int
) -> jnp.ndarray:
    """a mod m when a < (times+1) * m, via `times` conditional subtractions —
    the paper's post-processing tail (sum of t terms each < q => a < t*q)."""
    for _ in range(times):
        a = cond_sub(a, m, width)
    return a
