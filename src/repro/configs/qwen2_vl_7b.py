"""qwen2-vl-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
M-RoPE, dynamic resolution; vision frontend stubbed to patch embeddings.
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2 = 64
    frontend="vision",
)
