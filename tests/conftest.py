"""Suite-wide fixtures/hooks.

Multi-device host platform: the serving tests exercise REAL >1-device
meshes (shard_map of the RNS channel axis over `model`, batch over
`data`), so the suite forces 4 virtual CPU devices before jax
initializes.  Existing tests build their meshes from
``jax.devices()[:1]`` and are device-count-agnostic.  The flag only
helps if jax has not been imported yet — conftest runs before test
modules, so that holds under pytest; tests needing >1 device must
still skip when the count is short (e.g. under an externally-set
XLA_FLAGS), via the ``host_mesh_4`` fixture below.
"""
import os
import sys

if "jax" not in sys.modules:  # pragma: no branch
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=4"
        ).strip()

import numpy as np
import pytest


@pytest.fixture
def host_mesh_4():
    """A (data=2, model=2) mesh over 4 real devices; skips when the
    platform came up with fewer (jax imported before our flag)."""
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < 4:
        pytest.skip(f"needs 4 devices, have {len(devs)}")
    return Mesh(np.array(devs[:4]).reshape(2, 2), ("data", "model"))
