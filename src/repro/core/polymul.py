"""Ground-truth oracles for the PaReNTT multiplier (paper Fig 10).

Oracles:
  * ``schoolbook_negacyclic`` — O(n^2) Python-bigint negacyclic product.
  * ``ntt_negacyclic_host``   — O(n log n) Python-bigint negacyclic
    product via a host NTT (any channel prime with 2n | q-1), the big-n
    reference the hierarchical-schedule bit-exactness tests run against.
  * ``oracle_multiply``       — the RNS+NTT pipeline in Python bigints
    (any v, including the t=4 / v=45 config whose products exceed
    int64).  This is also the execution path of ``width="oracle"``
    plans in :mod:`repro.api`.

The end-to-end device pipeline lives behind the plan/execute API
(:func:`repro.api.plan` / :func:`repro.api.polymul`), which dispatches
on modulus width internally.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import bigint, primes as primes_mod, rns as rns_mod
from repro.core.params import ParenttParams

# --------------------------------------------------------------------------
# Oracles (host, exact)
# --------------------------------------------------------------------------


def schoolbook_negacyclic(a: list[int], b: list[int], q: int) -> list[int]:
    """p = a*b mod (x^n + 1, q), Python bigints."""
    n = len(a)
    p = [0] * n
    for i in range(n):
        ai = a[i] % q
        if not ai:
            continue
        for j in range(n):
            k = i + j
            if k >= n:
                p[k - n] = (p[k - n] - ai * b[j]) % q
            else:
                p[k] = (p[k] + ai * b[j]) % q
    return p


def _host_fft(v: list[int], q: int, root: int) -> list[int]:
    """In-place iterative Cooley-Tukey NTT over Python ints; ``root`` is
    a primitive len(v)-th root of unity mod q."""
    n = len(v)
    j = 0
    for i in range(1, n):
        bit = n >> 1
        while j & bit:
            j ^= bit
            bit >>= 1
        j |= bit
        if i < j:
            v[i], v[j] = v[j], v[i]
    length = 2
    while length <= n:
        wlen = pow(root, n // length, q)
        half = length >> 1
        for start in range(0, n, length):
            wcur = 1
            for k in range(start, start + half):
                u = v[k]
                t = v[k + half] * wcur % q
                v[k] = (u + t) % q
                v[k + half] = (u - t) % q
                wcur = wcur * wlen % q
        length <<= 1
    return v


@functools.lru_cache(maxsize=None)
def _host_twist(q: int, n: int) -> tuple[tuple[int, ...], tuple[int, ...], int]:
    """(psi^i, psi^-i, n^-1) mod q for the negacyclic twist, cached."""
    psi = primes_mod.root_of_unity(q, 2 * n)
    psi_inv = pow(psi, q - 2, q)
    tw, itw = [1] * n, [1] * n
    for i in range(1, n):
        tw[i] = tw[i - 1] * psi % q
        itw[i] = itw[i - 1] * psi_inv % q
    return tuple(tw), tuple(itw), pow(n, q - 2, q)


def ntt_negacyclic_host(a: list[int], b: list[int], q: int) -> list[int]:
    """p = a*b mod (x^n + 1, q) via the twisted cyclic NTT, O(n log n)
    Python bigints — the scalable twin of :func:`schoolbook_negacyclic`
    (requires 2n | q-1, which every special channel prime satisfies).
    Cross-checked against the schoolbook oracle in the test suite."""
    n = len(a)
    tw, itw, n_inv = _host_twist(q, n)
    w = tw[1] * tw[1] % q  # psi^2: primitive n-th root
    w_inv = itw[1] * itw[1] % q
    fa = _host_fft([x % q * t % q for x, t in zip(a, tw)], q, w)
    fb = _host_fft([x % q * t % q for x, t in zip(b, tw)], q, w)
    fp = _host_fft([x * y % q for x, y in zip(fa, fb)], q, w_inv)
    return [x * n_inv % q * t % q for x, t in zip(fp, itw)]


# Below this transform length oracle_multiply keeps the schoolbook path,
# preserving a reference with no shared structure with any NTT.
_FAST_ORACLE_MIN_N = 512


def oracle_multiply(a: list[int], b: list[int], params: ParenttParams) -> list[int]:
    """RNS+NTT pipeline in Python bigints (reference for any v).  Per
    channel, small n uses the schoolbook negacyclic product and big n
    the host-NTT product (O(n^2) bigints are infeasible at n >= 4096 —
    the big-n presets' bit-exactness gates run through this path)."""
    plan = params.plan
    out = [0] * params.n
    for i in range(params.t):
        qi = int(plan.qs[i])
        ai = [x % qi for x in a]
        bi = [x % qi for x in b]
        if params.n >= _FAST_ORACLE_MIN_N:
            pi = ntt_negacyclic_host(ai, bi, qi)
        else:
            pi = schoolbook_negacyclic(ai, bi, qi)
        star = plan.q // qi
        tilde = int(plan.qi_tilde[i])
        for j in range(params.n):
            out[j] = (out[j] + ((pi[j] * tilde) % qi) * star) % plan.q
    return out


# --------------------------------------------------------------------------
# Host <-> device formats
# --------------------------------------------------------------------------


def ints_to_segments(xs: list[int], plan: rns_mod.RnsPlan) -> np.ndarray:
    return bigint.ints_to_limbs(xs, plan.v, plan.seg_count)


def limbs_out_to_ints(limbs, plan: rns_mod.RnsPlan) -> list[int]:
    return bigint.limbs_to_ints(limbs, plan.w)


