"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
swept over shapes and configurations."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.kernels import ops
from repro.kernels import ntt as ntt_kernels


@pytest.fixture(scope="module", params=[(3, 30, 64), (3, 30, 256), (6, 30, 128)])
def p(request):
    t, v, n = request.param
    return params_mod.make_params(n=n, t=t, v=v)


def _rand_res(p, rows, seed):
    rng = np.random.default_rng(seed)
    chans = [
        rng.integers(0, int(q), size=(rows, p.n)) for q in p.plan.qs
    ]
    return jnp.asarray(np.stack(chans))


class TestNttKernels:
    @pytest.mark.parametrize("rows", [1, 3, 8, 17])
    def test_forward_matches_ref(self, p, rows):
        a = _rand_res(p, rows, rows)
        got = ops.ntt_forward(a, p, use_pallas=True)
        want = ops.ntt_forward(a, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("rows", [1, 8])
    def test_inverse_matches_ref(self, p, rows):
        a = _rand_res(p, rows, 10 + rows)
        got = ops.ntt_inverse(a, p, use_pallas=True)
        want = ops.ntt_inverse(a, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.slow  # interpret-mode Pallas sweep over presets x rows
    @pytest.mark.parametrize("rows", [1, 5, 8])
    def test_fused_matches_ref_and_schoolbook(self, p, rows):
        a = _rand_res(p, rows, 20 + rows)
        b = _rand_res(p, rows, 30 + rows)
        got = ops.negacyclic_mul(a, b, p, use_pallas=True)
        want = ops.negacyclic_mul(a, b, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        # spot-check channel 0 row 0 against schoolbook
        q0 = int(p.plan.qs[0])
        sb = pm.schoolbook_negacyclic(
            np.asarray(a)[0, 0].tolist(), np.asarray(b)[0, 0].tolist(), q0
        )
        assert np.asarray(got)[0, 0].tolist() == sb

    def test_roundtrip_via_kernels(self, p):
        a = _rand_res(p, 4, 99)
        fa = ops.ntt_forward(a, p, use_pallas=True)
        back = ops.ntt_inverse(fa, p, use_pallas=True)
        assert np.array_equal(np.asarray(back), np.asarray(a))

    @pytest.mark.parametrize("row_blk", [2, 4, 8])
    def test_row_block_sweep(self, row_blk):
        p = params_mod.make_params(n=64, t=3, v=30)
        a = _rand_res(p, 8, row_blk)
        ct = p.tables
        got = ntt_kernels.ntt_channels_pallas(
            a, jnp.asarray(ct.qs), jnp.asarray(ct.fwd), row_blk=row_blk
        )
        want = ops.ntt_forward(a, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_dtype_int32_small_modulus(self):
        """int32 lane variant: works when q < 2^15 (products < 2^31)."""
        from repro.core import ntt as ntt_core

        q, n = 12289, 64  # 2n | q-1
        tb = ntt_core.make_tables(q, n)
        rng = np.random.default_rng(0)
        a = rng.integers(0, q, size=(1, 2, n)).astype(np.int32)
        got = ntt_kernels.ntt_channels_pallas(
            jnp.asarray(a),
            jnp.asarray([q], dtype=jnp.int32),
            jnp.asarray(tb.fwd[None, :].astype(np.int32)),
        )
        want = ntt_core.ntt_raw(jnp.asarray(a[0]).astype(jnp.int64), jnp.asarray(tb.fwd), q)
        assert np.array_equal(np.asarray(got)[0], np.asarray(want).astype(np.int32))


class TestCrtKernels:
    def test_decompose_matches_ref(self, p):
        rng = np.random.default_rng(1)
        z = jnp.asarray(rng.integers(0, 1 << p.plan.v, size=(300, p.plan.seg_count)))
        got = ops.rns_decompose(z, p, use_pallas=True)
        want = ops.rns_decompose(z, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_compose_matches_ref(self, p):
        rng = np.random.default_rng(2)
        res = jnp.asarray(
            np.stack([rng.integers(0, int(q), size=513) for q in p.plan.qs])
        )
        got = ops.rns_compose(res, p, use_pallas=True)
        want = ops.rns_compose(res, p, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_end_to_end_kernel_pipeline(self):
        """segments -> decompose -> fused mul -> compose, all Pallas,
        vs the bigint schoolbook."""
        import random

        p = params_mod.make_params(n=64, t=3, v=30)
        rng = random.Random(5)
        a = [rng.randrange(p.q) for _ in range(p.n)]
        b = [rng.randrange(p.q) for _ in range(p.n)]
        za = jnp.asarray(pm.ints_to_segments(a, p.plan))
        zb = jnp.asarray(pm.ints_to_segments(b, p.plan))
        ra = ops.rns_decompose(za, p)[:, None, :]  # (t, 1, n)
        rb = ops.rns_decompose(zb, p)[:, None, :]
        rp = ops.negacyclic_mul(ra, rb, p)[:, 0, :]
        limbs = ops.rns_compose(rp, p)
        got = pm.limbs_out_to_ints(np.asarray(limbs), p.plan)
        assert got == pm.schoolbook_negacyclic(a, b, p.q)
