"""CLI front door for the profile-driven autotuner (DESIGN.md §11).

Subcommands::

    # sweep two workloads on this box, write/merge a table + full report
    python -m repro.launch.autotune sweep \
        --workloads n64_t3_v30_b2,n256_t6_v30_b2 --quick \
        --out TUNING_ci.json --report TUNE_report.json

    # print a table
    python -m repro.launch.autotune show-table --table TUNING_ci.json

    # verify plan(tuning=<table>) resolves every entry's winner, and that
    # the tuned plan_key differs from the untuned one only in tuned knobs
    python -m repro.launch.autotune check --table TUNING_ci.json

    # drop entries older than N days (atomic rewrite)
    python -m repro.launch.autotune prune-stale --table T.json --max-age-days 90

``sweep`` merges into an existing ``--out`` table by default (other
workloads and device kinds survive); ``--fresh`` starts empty.  Exit
status is nonzero on any check failure, so the ``tune-smoke`` CI job is
blocking.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, List, Optional


def _parse_workloads(spec: str) -> list[Any]:
    from repro.tune.sweep import Workload

    keys = [k.strip() for k in spec.split(",") if k.strip()]
    if not keys:
        raise SystemExit("no workloads given (want e.g. n64_t3_v30_b2,...)")
    return [Workload.from_key(k) for k in keys]


def _cmd_sweep(args: argparse.Namespace) -> int:
    from repro.tune import table as table_mod
    from repro.tune import sweep as sweep_mod

    workloads = _parse_workloads(args.workloads)
    base = None
    if not args.fresh:
        try:
            base = table_mod.TuningTable.load(args.out)
            print(f"merging into existing table {args.out}")
        except table_mod.TuningTableError:
            base = None
    tab, report = sweep_mod.sweep(
        workloads, quick=args.quick, iters=args.iters, warmup=args.warmup,
        table=base, log=print,
    )
    tab.save(args.out)
    print(f"wrote {args.out}")
    if args.report:
        # HLO dumps are per-candidate transient state, never in the report
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.report}")
    return 0


def _cmd_show_table(args: argparse.Namespace) -> int:
    from repro.tune import table as table_mod

    path = args.table or str(table_mod.DEFAULT_TABLE_PATH)
    tab = table_mod.TuningTable.load(path)
    print(f"# {path}")
    for kind, entries in sorted(tab.entries.items()):
        print(f"[{kind}]")
        for key, e in sorted(entries.items()):
            w = e.get("winner", {})
            knobs = ", ".join(f"{k}={w.get(k)!r}" for k in table_mod.TUNABLE_KNOBS)
            print(
                f"  {key}: {knobs}  "
                f"({e.get('winner_us', float('nan')):.1f} us/poly vs default "
                f"{e.get('default_us', float('nan')):.1f}; "
                f"mode={e.get('mode')}, rank-corr={e.get('rank_correlation')})"
            )
    return 0


def _cmd_prune_stale(args: argparse.Namespace) -> int:
    from repro.tune import table as table_mod

    tab = table_mod.TuningTable.load(args.table)
    removed = tab.prune_stale(max_age_s=args.max_age_days * 86400.0)
    tab.save(args.table)
    for kind, key in removed:
        print(f"pruned [{kind}] {key}")
    print(f"{len(removed)} entries pruned; wrote {args.table}")
    return 0


def _check_entry(kind: str, key: str, entry: dict[str, Any], table_path: str) -> list[str]:
    import repro
    from repro.tune import table as table_mod

    problems: list[str] = []
    wl = entry.get("workload") or table_mod.parse_workload_key(key)
    n, t, v = wl["n"], wl["t"], wl["v"]
    winner = entry.get("winner", {})

    tuned = repro.plan(n=n, t=t, v=v, tuning=table_path)
    untuned = repro.plan(n=n, t=t, v=v)
    tcfg, ucfg = repro.plan_key(tuned), repro.plan_key(untuned)

    # 1. the tuned plan carries the table's winner, first-class
    want_backend = winner.get("backend") or ucfg.backend
    if tcfg.backend != want_backend:
        problems.append(
            f"{key}: tuned backend {tcfg.backend!r} != winner {want_backend!r}"
        )
    want_sched = winner.get("schedule")
    if want_sched and tcfg.schedule.canonical != want_sched:
        problems.append(
            f"{key}: tuned schedule {tcfg.schedule.canonical!r} != winner "
            f"{want_sched!r}"
        )
    if tcfg.row_blk != winner.get("row_blk"):
        problems.append(
            f"{key}: tuned row_blk {tcfg.row_blk!r} != winner "
            f"{winner.get('row_blk')!r}"
        )
    if tcfg.channel_grid != winner.get("channel_grid"):
        problems.append(
            f"{key}: tuned channel_grid {tcfg.channel_grid!r} != winner "
            f"{winner.get('channel_grid')!r}"
        )

    # 2. plan_key differs from the untuned plan ONLY in tuned knobs
    # (+ the resolved schedule spec those knobs imply)
    allowed = set(table_mod.TUNABLE_KNOBS)
    for field in dataclasses.fields(tcfg):
        tv, uv = getattr(tcfg, field.name), getattr(ucfg, field.name)
        if tv != uv and field.name not in allowed:
            problems.append(
                f"{key}: plan_key drift outside tuned knobs: "
                f"{field.name}: tuned={tv!r} untuned={uv!r}"
            )

    # 3. explicit knobs still beat the table
    pinned = repro.plan(n=n, t=t, v=v, backend=ucfg.backend, tuning=table_path)
    if repro.plan_key(pinned).backend != ucfg.backend:
        problems.append(f"{key}: explicit backend knob lost to the table")

    # 4. the sweep recorded a never-slower winner
    w_us, d_us = entry.get("winner_us"), entry.get("default_us")
    if w_us is not None and d_us is not None and w_us > d_us:
        problems.append(
            f"{key}: recorded winner ({w_us:.1f} us) slower than default "
            f"({d_us:.1f} us)"
        )
    return problems


def _cmd_check(args: argparse.Namespace) -> int:
    from repro.tune import table as table_mod

    tab = table_mod.TuningTable.load(args.table)
    kind = table_mod.device_kind()
    entries = tab.entries.get(kind, {})
    if not entries:
        print(f"FAIL: table has no entries for device kind {kind!r}")
        return 1
    # plan() is batch-agnostic and resolves the smallest-batch entry per
    # (n, t, v); only those entries are checkable against plan(tuning=...)
    smallest: dict[tuple[int, int, int], tuple[int, str]] = {}
    for key, entry in entries.items():
        wl = entry.get("workload") or table_mod.parse_workload_key(key)
        nk = (wl["n"], wl["t"], wl["v"])
        if nk not in smallest or wl["batch"] < smallest[nk][0]:
            smallest[nk] = (wl["batch"], key)
    checkable = {key for _, key in smallest.values()}
    problems: list[str] = []
    for key, entry in sorted(entries.items()):
        if key not in checkable:
            print(f"skipped [{kind}] {key} (larger-batch twin)")
            continue
        problems.extend(_check_entry(kind, key, entry, args.table))
        print(f"checked [{kind}] {key}")
    if args.report:
        rep = json.load(open(args.report))
        for w in rep.get("workloads", []):
            if "rank_correlation" not in (w.get("entry") or {}):
                problems.append(f"report {w.get('key')}: missing rank_correlation")
    for p in problems:
        print(f"FAIL: {p}")
    print(f"{len(entries)} entries checked, {len(problems)} problems")
    return 1 if problems else 0


def _cmd_seed_default(args: argparse.Namespace) -> int:
    """Regenerate the committed dev-box seed table (maintainer helper)."""
    from repro.tune import table as table_mod

    args.out = str(table_mod.DEFAULT_TABLE_PATH)
    args.fresh = False
    return _cmd_sweep(args)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.autotune",
        description="Profile-driven autotuner: sweep / inspect / check "
        "the persistent tuning table consulted by repro.plan(tuning=...)",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("sweep", help="measure candidates, write a table")
    sp.add_argument(
        "--workloads", required=True,
        help="comma-separated workload keys, e.g. n64_t3_v30_b2,n256_t6_v30_b2",
    )
    sp.add_argument("--quick", action="store_true", help="CI micro-grid")
    sp.add_argument("--iters", type=int, default=3)
    sp.add_argument("--warmup", type=int, default=1)
    sp.add_argument("--out", default="TUNING.json", help="table path (merged)")
    sp.add_argument("--fresh", action="store_true", help="ignore existing table")
    sp.add_argument("--report", default=None, help="full sweep report path")
    sp.set_defaults(fn=_cmd_sweep)

    st = sub.add_parser("show-table", help="pretty-print a table")
    st.add_argument("--table", default=None, help="defaults to the committed seed")
    st.set_defaults(fn=_cmd_show_table)

    pc = sub.add_parser(
        "check",
        help="assert plan(tuning=<table>) resolves every winner first-class",
    )
    pc.add_argument("--table", required=True)
    pc.add_argument("--report", default=None, help="sweep report to cross-check")
    pc.set_defaults(fn=_cmd_check)

    ps = sub.add_parser("prune-stale", help="drop entries past --max-age-days")
    ps.add_argument("--table", required=True)
    ps.add_argument("--max-age-days", type=float, default=180.0)
    ps.set_defaults(fn=_cmd_prune_stale)

    sd = sub.add_parser(
        "seed-default", help="re-sweep the committed TUNING_default.json"
    )
    sd.add_argument(
        "--workloads", default="n64_t3_v30_b2,n256_t6_v30_b2",
        help="comma-separated workload keys",
    )
    sd.add_argument("--quick", action="store_true", default=True)
    sd.add_argument("--iters", type=int, default=3)
    sd.add_argument("--warmup", type=int, default=1)
    sd.add_argument("--report", default=None)
    sd.set_defaults(fn=_cmd_seed_default)

    args = ap.parse_args(argv)
    return int(args.fn(args))


if __name__ == "__main__":
    sys.exit(main())
