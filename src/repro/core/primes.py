"""Special NTT-compatible, CRT-friendly prime search (paper §IV-B, Eq 3/6).

Each RNS modulus has the Solinas-like form

    q_i = 2^v - beta_i,   beta_i = 2^{v1} ± 2^{v2} ± ... ± 2^{v_nq} - 1

(so q_i itself has ``n_q + 2`` signed power-of-two (PoT) terms), subject to

  (C1)  q_i prime,
  (C2)  2n | (q_i - 1)              (NTT-compatible),
  (C3)  ceil((mu - 1) / n_beta) > v1 > v2 > ...   (Eq 6, CRT-friendly:
        bounds the shift-add-unit (SAU) intermediate word-length so that a
        single Barrett unit with input word-length ``mu`` suffices),

where ``n_beta = t - 1`` (Approach 1) or ``t' - 1`` (Approach 2, Alg 2
factorization t = d * t').  The paper's contribution 2 *expands* the
feasible set by allowing mu in {2v+15, 2v+30} instead of the classic 2v
(Table III).  The search is exhaustive and runs offline in Python bigints
(prime selection is a compile-time activity on every platform, FPGA or
TPU alike).

This module is host-side only (no JAX).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
import random
from typing import Iterator

# --------------------------------------------------------------------------
# Primality (deterministic Miller-Rabin for < 3.3e24, covers all our vt-bit
# candidates individually; the composed modulus q is composite by design).
# --------------------------------------------------------------------------

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41)


def is_prime(x: int) -> bool:
    if x < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41):
        if x % p == 0:
            return x == p
    d, s = x - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in _MR_BASES:
        w = pow(a, d, x)
        if w in (1, x - 1):
            continue
        for _ in range(s - 1):
            w = (w * w) % x
            if w == x - 1:
                break
        else:
            return False
    return True


def _pollard_rho(x: int) -> int:
    if x % 2 == 0:
        return 2
    rng = random.Random(0xC0FFEE ^ x)
    while True:
        c = rng.randrange(1, x)
        f = lambda y: (y * y + c) % x
        a = b = rng.randrange(2, x)
        d = 1
        while d == 1:
            a = f(a)
            b = f(f(b))
            d = math.gcd(abs(a - b), x)
        if d != x:
            return d


def factorize(x: int) -> dict[int, int]:
    """Full factorization (trial division + Pollard rho)."""
    out: dict[int, int] = {}
    for p in (2, 3, 5, 7, 11, 13):
        while x % p == 0:
            out[p] = out.get(p, 0) + 1
            x //= p
    stack = [x] if x > 1 else []
    while stack:
        y = stack.pop()
        if y == 1:
            continue
        if is_prime(y):
            out[y] = out.get(y, 0) + 1
            continue
        d = _pollard_rho(y)
        stack += [d, y // d]
    return out


def primitive_root(q: int, factors: dict[int, int] | None = None) -> int:
    """Smallest generator of Z_q^* (q prime)."""
    fac = factors or factorize(q - 1)
    for g in itertools.count(2):
        if all(pow(g, (q - 1) // p, q) != 1 for p in fac):
            return g
    raise RuntimeError("unreachable")


def root_of_unity(q: int, order: int) -> int:
    """A primitive ``order``-th root of unity mod prime q (order | q-1)."""
    if (q - 1) % order != 0:
        raise ValueError(f"{order} does not divide {q}-1")
    g = primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    # primitivity check: w^(order/p) != 1 for prime p | order (order = 2^k here)
    assert pow(w, order, q) == 1
    for p in factorize(order):
        assert pow(w, order // p, q) != 1
    return w


# --------------------------------------------------------------------------
# Special prime search (Eq 3 + Eq 6)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecialPrime:
    """q = 2^v - beta, beta = sum(sign * 2^exp for exp, sign in beta_terms) - 1.

    ``beta_terms`` excludes the trailing ``-1``; exps strictly decreasing.
    """

    q: int
    v: int
    beta_terms: tuple[tuple[int, int], ...]  # ((exp, sign), ...), sign in {+1,-1}

    @property
    def beta(self) -> int:
        return sum(s * (1 << e) for e, s in self.beta_terms) - 1

    @property
    def pot_terms(self) -> int:
        """Number of signed power-of-two terms in q itself (paper's '# PoT')."""
        return len(self.beta_terms) + 2

    def __post_init__(self):
        assert self.q == (1 << self.v) - self.beta


def _beta_candidates(
    v: int, n_pot_inner: int, v1_bound: int, min_exp: int
) -> Iterator[tuple[tuple[int, int], ...]]:
    """Yield beta term tuples: exps from [min_exp, v1_bound), leading sign +."""
    exps_range = range(min_exp, v1_bound)
    for exps in itertools.combinations(exps_range, n_pot_inner):
        exps = tuple(sorted(exps, reverse=True))
        for signs in itertools.product((1, -1), repeat=n_pot_inner - 1):
            yield tuple(
                (e, 1 if k == 0 else signs[k - 1]) for k, e in enumerate(exps)
            )


def find_special_primes(
    *,
    v: int,
    n: int,
    mu: int,
    pot: int,
    n_beta: int = 2,
    constraint: str = "wordlen",
    limit: int | None = None,
) -> list[SpecialPrime]:
    """Exhaustive search per paper §IV-B.

    Args:
      v: word-length of each q_i.
      n: polynomial degree (power of two); requires 2n | q_i - 1.
      mu: Barrett input word-length (paper uses 2v+15 or 2v+30).
      pot: number of signed PoT terms in q_i (4 or 5 in Table III).
      n_beta: SAU chain depth bound.  The paper's Table III numbers are
        reproduced exactly with n_beta = 2 (the Alg-2 factorized datapath,
        t' = 3) for BOTH the v=45 and v=30 rows.
      constraint: 'wordlen' applies the paper's own §IV-C word-length
        derivation, mu >= v + n_beta*(v1+1) + 1  <=>  v1 <= (mu-v-1)/n_beta - 1.
        This reproduces all eight Table III counts exactly
        (12/33/126/480 for v=45; 8/26/23/169 for v=30).  'eq6' applies the
        constraint as *printed* in Eq 6 (v1 < ceil((mu-1)/n_beta)), which is
        inconsistent with Table III — kept for the erratum benchmark.
      limit: optionally stop after this many primes.
    """
    n_inner = pot - 2  # beta has (pot - 2) PoT terms plus the trailing -1
    if n_inner < 1:
        raise ValueError("need pot >= 3")
    if constraint == "wordlen":
        v1_bound = (mu - v - 1) // n_beta  # exclusive: v1 <= bound - 1
    elif constraint == "eq6":
        v1_bound = -(-(mu - 1) // n_beta)  # ceil((mu-1)/n_beta); v1 < bound
    else:
        raise ValueError(constraint)
    v1_bound = min(v1_bound, v)  # beta must stay below 2^v
    two_n = 2 * n
    # NTT compatibility: q-1 = 2^v - beta - ... ; q ≡ 1 (mod 2n) forces the
    # low log2(2n) bits of beta to equal those of 2^v, i.e. beta ≡ 0 mod 2n
    # given v > log2(2n).  beta = 2^{v1} ± ... - 1 is odd - 1 + ...: we just
    # filter on the congruence directly (cheap) rather than pre-pruning.
    out: list[SpecialPrime] = []
    seen: set[int] = set()
    for terms in _beta_candidates(v, n_inner, v1_bound, min_exp=1):
        beta = sum(s * (1 << e) for e, s in terms) - 1
        if beta <= 0:
            continue
        q = (1 << v) - beta
        if q in seen:
            continue
        if q.bit_length() != v:
            continue
        if (q - 1) % two_n != 0:
            continue
        if not is_prime(q):
            continue
        seen.add(q)
        out.append(SpecialPrime(q=q, v=v, beta_terms=terms))
        if limit and len(out) >= limit:
            break
    out.sort(key=lambda s: s.q)
    return out


@functools.lru_cache(maxsize=None)
def default_prime_set(n: int, t: int, v: int) -> tuple[SpecialPrime, ...]:
    """The prime sets used throughout the framework.

    Matches the paper's hardware configs: (t=4, v=45) and (t=6, v=30) with
    mu = 2v + 15 and 4 PoT terms, SAU depth n_beta = 2 (Alg 2, t' = 3) —
    the setting that reproduces Table III exactly.
    """
    mu = 2 * v + 15
    primes = find_special_primes(v=v, n=n, mu=mu, pot=4, n_beta=2)
    if len(primes) < t:
        primes = find_special_primes(v=v, n=n, mu=mu, pot=5, n_beta=2)
    if len(primes) < t:
        primes = find_special_primes(v=v, n=n, mu=2 * v + 30, pot=5, n_beta=2)
    if len(primes) < t:
        raise RuntimeError(
            f"search found only {len(primes)} special primes for n={n} v={v}"
        )
    return tuple(primes[:t])
