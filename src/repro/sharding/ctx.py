"""Activation-sharding policy context.

Model code is mesh-agnostic; the launcher installs a policy that pins
activation shardings at layer boundaries (without it, GSPMD can resolve
the FSDP weight-sharding/batch-sharding conflict on the `data` axis by
all-gathering *activations* to the global batch — observed in the first
mamba2 dry-run, 16x memory blow-up; see EXPERIMENTS §Dry-run notes).

Kinds:
  "btd"   — (B, S, D) residual-stream activations: batch over (pod, data)
  "btv"   — logits
  "cache" — decode caches (handled by explicit in_shardings instead)
"""
from __future__ import annotations

import contextlib

_POLICY = None


@contextlib.contextmanager
def activation_policy(policy):
    global _POLICY
    old = _POLICY
    _POLICY = policy
    try:
        yield
    finally:
        _POLICY = old


def constrain(x, kind: str):
    return _POLICY(x, kind) if _POLICY is not None else x


def moe_scatter(slot, xk, n_rows: int):
    """Dispatch scatter: per-batch-row  zeros(n_rows, D).at[slot_b].add(xk_b).

    Under a mesh policy this runs inside shard_map over the batch axes —
    a *batched* scatter is unpartitionable for GSPMD (it all-gathers the
    (B, S*K, D) operand and all-reduces its gradients: 20+ TB/step
    observed on dbrx train, §Perf cell A iter 5); inside shard_map the
    scatter is shard-local with zero collectives and a local-gather
    gradient."""
    import jax
    import jax.numpy as jnp

    D = xk.shape[-1]

    def scatter_rows(slot_s, xk_s):
        def one(slot_b, xk_b):
            return jnp.zeros((n_rows, D), dtype=xk.dtype).at[slot_b].add(xk_b)

        return jax.vmap(one)(slot_s, xk_s)

    pol = _POLICY
    mesh = getattr(pol, "mesh", None) if pol is not None else None
    if mesh is None:
        return scatter_rows(slot, xk)
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    ba = pol.batch_axes
    size = pol.batch_size
    if slot.shape[0] % size != 0:
        return scatter_rows(slot, xk)
    return shard_map(
        scatter_rows,
        mesh=mesh,
        in_specs=(P(ba, None), P(ba, None, None)),
        out_specs=P(ba, None, None),
    )(slot, xk)


def moe_gather(eout, slot):
    """Combine gather: per-batch-row eout_b[slot_b] — shard_map'd for the
    same reason as moe_scatter (the batched gather's BACKWARD is a batched
    scatter, which GSPMD replicates)."""
    import jax
    import jax.numpy as jnp

    def gather_rows(eout_s, slot_s):
        return jnp.take_along_axis(eout_s, slot_s[..., None], axis=1)

    pol = _POLICY
    mesh = getattr(pol, "mesh", None) if pol is not None else None
    if mesh is None:
        return gather_rows(eout, slot)
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    ba = pol.batch_axes
    if slot.shape[0] % pol.batch_size != 0:
        return gather_rows(eout, slot)
    return shard_map(
        gather_rows,
        mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None)),
        out_specs=P(ba, None, None),
    )(eout, slot)


def make_crypto_policy(mesh, plan):
    """Activation policy for the crypto serving engine: pins the
    polymul *stage boundaries* to the ``partition.polymul_specs``
    layout — ``"segments"``/``"limbs"`` batch-sharded over ``data``,
    ``"residues"`` channel-sharded over ``model`` — so GSPMD cannot
    resolve the batched dispatch by all-gathering residue tensors (the
    crypto twin of the LM policy below; the heavy cascade itself runs
    under an explicit ``shard_map`` in
    :mod:`repro.serve.crypto_engine`).

    ``plan`` is anything with ``.t`` (an ``api.Plan`` or its params).
    Constraints apply only when the named dim divides the mesh axes;
    everything else passes through untouched.
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.sharding import partition

    specs = partition.polymul_specs(mesh, plan)
    ba = partition.batch_axes(mesh)
    size = 1
    for a in ba:
        size *= mesh.shape[a]

    def policy(x, kind):
        spec = specs.get(kind)
        if spec is None or x.ndim != 3:
            return x
        batch_dim = 1 if kind == "residues" else 0
        if x.shape[batch_dim] % size != 0:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    policy.mesh = mesh
    policy.batch_axes = ba
    policy.batch_size = size
    return policy


def make_mesh_policy(mesh, *, strategy: str = "baseline"):
    """Activation policies (the §Perf levers):

    baseline — batch over (pod, data); everything else to GSPMD.
    seqpar   — additionally shard the SEQUENCE dim of (B, S, D) activations
               over `model` (context parallelism): splits the O(S^2)
               attention score tensors 16-way, turning softmax cross-shard
               reductions into (B, H, Sq)-sized collectives instead of
               S^2 resharding.  Prefill/long-context lever.
    dp_only  — small models: batch over ALL mesh axes (pure DP; the 16-way
               TP of a <1B model is pure collective overhead).  Used with
               replicated param specs (see dryrun --strategy dp_only).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import partition

    ba = partition.batch_axes(mesh)
    if strategy == "dp_only":
        ba = tuple(mesh.axis_names)
    size = 1
    for a in ba:
        size *= mesh.shape[a]
    model_n = mesh.shape["model"]

    def policy(x, kind):
        if kind in ("btd", "btv") and x.ndim >= 2 and x.shape[0] % size == 0:
            dims = [ba] + [None] * (x.ndim - 1)
            if (
                strategy == "seqpar"
                and x.ndim >= 3
                and x.shape[1] > 1
                and x.shape[1] % model_n == 0
            ):
                dims[1] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*dims))
            )
        if kind == "moe_buf" and x.ndim == 3 and x.shape[0] % size == 0:
            # (B, E*C+1, D) row-local scatter result: strictly batch-sharded
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, None, None))
            )
        if kind == "moe_w" and x.ndim == 3 and x.shape[0] % model_n == 0:
            # experts stay model-sharded; FSDP dims gathered for compute
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P("model", None, None))
            )
        if (
            kind == "moe_tokens"
            and x.ndim == 4
            and x.shape[0] % size == 0
            and x.shape[1] % model_n == 0
        ):
            # (B, E, C, D) dispatch buffer: batch rows data-parallel,
            # experts model-local (the canonical MoE all-to-all boundary)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(ba, "model", None, None))
            )
        return x

    policy.mesh = mesh
    policy.batch_axes = ba
    policy.batch_size = size
    return policy
