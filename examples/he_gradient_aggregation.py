"""HE-secured gradient aggregation (the paper's federated-learning
motivation [1]): three workers train a shared tiny LM; per-step gradients
are BFV-encrypted, summed as ciphertexts by an untrusted reducer, and
decrypted only by the trusted coordinator.  Compares against plaintext
aggregation.

Run:  PYTHONPATH=src python examples/he_gradient_aggregation.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.train import aggregation as agg_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


def main():
    cfg = registry.get("mamba2-130m").reduced()
    run = RunConfig(model=cfg, remat=False)
    loss_fn = ts_mod.make_loss_fn(run)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    params, opt_state = ts_mod.init_state(run, jax.random.PRNGKey(0))
    adamw = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=50)

    agg = agg_mod.HeAggregator(n=1024, t=3, v=30, pt_mod=1 << 24, frac_bits=12)
    he_keys = agg.keygen(jax.random.PRNGKey(42))

    workers = [
        data_mod.SyntheticLM(cfg, data_mod.DataConfig(batch=2, seq_len=32, seed=s))
        for s in range(3)
    ]
    losses = []
    for step in range(8):
        worker_grads, worker_losses = [], []
        for w in workers:
            batch = jax.tree.map(jnp.asarray, w.batch_at(step))
            loss, g = grad_fn(params, batch)
            worker_grads.append(g)
            worker_losses.append(float(loss))
        # --- the untrusted reducer only ever sees ciphertexts -----------
        g_he = agg_mod.he_aggregate_gradients(
            agg, worker_grads, jax.random.PRNGKey(step), he_keys
        )
        g_plain = jax.tree.map(lambda *xs: sum(xs) / len(xs), *worker_grads)
        errs = [
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(g_he), jax.tree.leaves(g_plain))
        ]
        params, opt_state, m = opt_mod.update(adamw, g_he, opt_state, params)
        losses.append(np.mean(worker_losses))
        print(
            f"step {step}: mean worker loss={losses[-1]:.4f} "
            f"max |HE-plain| grad err={max(errs):.2e}"
        )
    assert losses[-1] < losses[0], "training on HE-aggregated grads diverged"
    print(f"[ok] loss {losses[0]:.3f} -> {losses[-1]:.3f} with encrypted aggregation")


if __name__ == "__main__":
    main()
