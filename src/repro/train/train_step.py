"""Training step: loss, grads, optimizer update — pure & pjit-able.

The returned function has signature
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
and is what dryrun.py lowers against the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models import model as M
from repro.train import optimizer as opt_mod


def cross_entropy(logits, labels, vocab: int):
    """Mean token NLL in f32; ignores label == -1.

    Vocab-parallel formulation: logits may be PADDED (padded_vocab classes,
    sharded over the model axis).  Padding classes are masked to -inf and
    the label logit is picked with a one-hot contraction, so the only
    cross-shard communication is (B, S)-sized partial-reduce traffic —
    never a full-logit all-gather."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab:
        class_ok = jax.lax.iota(jnp.int32, vpad) < vocab
        logits = jnp.where(class_ok, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    valid = labels >= 0
    lab = jnp.clip(labels, 0, vocab - 1).astype(jnp.int32)
    onehot = jax.nn.one_hot(lab, vpad, dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", onehot, logits)
    nll = jnp.where(valid, lse - picked, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom


def make_loss_fn(run: RunConfig):
    cfg = run.model

    def loss_fn(params, batch):
        logits = M.forward(
            params, cfg, batch, remat=run.remat, remat_group=run.remat_group
        )
        loss = cross_entropy(logits, batch["labels"], cfg.vocab)
        return loss

    return loss_fn


def make_train_step(run: RunConfig, adamw: opt_mod.AdamWConfig | None = None):
    adamw = adamw or opt_mod.AdamWConfig(
        lr=run.learning_rate,
        weight_decay=run.weight_decay,
        grad_clip=run.grad_clip,
    )
    loss_fn = make_loss_fn(run)
    accum = max(run.grad_accum_steps, 1)

    def grads_of(params, batch):
        if accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # microbatch accumulation: (B, ...) -> (A, B/A, ...), scan-summed.
        micro = jax.tree.map(
            lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]), batch
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zero = (
            jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )
        (loss_sum, g_sum), _ = jax.lax.scan(body, zero, micro)
        scale = 1.0 / accum
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, g_sum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, om = opt_mod.update(adamw, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_serve_step(run: RunConfig):
    cfg = run.model

    def serve_step(params, cache, batch):
        return M.decode_step(params, cfg, cache, batch)

    return serve_step


def init_state(run: RunConfig, key):
    params = M.init_params(key, run.model)
    return params, opt_mod.init(params)
