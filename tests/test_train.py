"""Trainer runtime: optimizer math, fault-tolerant checkpointing (crash ->
resume == uninterrupted), data determinism, aggregation (int8 + HE), engine."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.models import model as M
from repro.serve.engine import Engine
from repro.train import aggregation as agg_mod
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer


def _tiny_run(tmpdir, arch="mamba2-130m", every=2) -> RunConfig:
    cfg = registry.get(arch).reduced()
    return RunConfig(
        model=cfg,
        checkpoint_every=every,
        checkpoint_dir=str(tmpdir),
        remat=False,
    )


class TestOptimizer:
    def test_adamw_matches_numpy_reference(self):
        cfg = opt_mod.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                                  weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.asarray(np.ones((3, 2), np.float32))}
        grads = {"w": jnp.asarray(np.full((3, 2), 0.5, np.float32))}
        state = opt_mod.init(params)
        new_params, state, _ = opt_mod.update(cfg, grads, state, params)
        # numpy reference (step 1, bias correction makes mhat=g, vhat=g^2)
        lr = float(opt_mod.schedule(cfg, jnp.asarray(1.0)))
        want = 1.0 - lr * (0.5 / (np.sqrt(0.25) + cfg.eps))
        np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-5)

    def test_grad_clip(self):
        g = {"a": jnp.asarray(np.full(4, 10.0, np.float32))}
        clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(opt_mod.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)

    def test_loss_decreases_over_steps(self):
        run = _tiny_run("/tmp/unused")
        step = jax.jit(ts_mod.make_train_step(
            run, opt_mod.AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=50)))
        params, opt_state = ts_mod.init_state(run, jax.random.PRNGKey(0))
        data = data_mod.SyntheticLM(run.model, data_mod.DataConfig(batch=4, seq_len=32))
        losses = []
        for s in range(30):
            batch = jax.tree.map(jnp.asarray, data.batch_at(s % 4))
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


class TestCheckpoint:
    def test_crash_resume_is_bit_identical(self, tmp_path):
        """Train 6 steps straight vs. train 4 + 'crash' + resume to 6 —
        final params identical (fault-tolerance contract)."""
        run = _tiny_run(tmp_path / "a", every=2)
        dc = data_mod.DataConfig(batch=2, seq_len=16)
        t1 = Trainer(run, dc, total_steps=6)
        p_straight, _, _ = t1.train(jax.random.PRNGKey(7), steps=6, log_every=100)

        run2 = _tiny_run(tmp_path / "b", every=2)
        t2 = Trainer(run2, dc, total_steps=6)
        t2.train(jax.random.PRNGKey(7), steps=4, log_every=100)  # "crash" after 4
        t3 = Trainer(run2, dc, total_steps=6)
        p_resumed, _, _ = t3.train(jax.random.PRNGKey(7), steps=6, log_every=100)

        for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_ignores_partial_writes(self, tmp_path):
        d = tmp_path / "ck"
        os.makedirs(d / "step_000000005_tmp")  # simulated torn write
        ckpt.save(str(d), 3, {"x": jnp.ones(2)})
        assert ckpt.latest_step(str(d)) == 3

    def test_gc_keeps_last_k(self, tmp_path):
        d = str(tmp_path / "ck")
        for s in range(5):
            ckpt.save(d, s, {"x": jnp.ones(1)}, keep=2)
        assert ckpt.list_steps(d) == [3, 4]

    def test_restore_roundtrip(self, tmp_path):
        d = str(tmp_path / "ck")
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.float32)}}
        ckpt.save(d, 1, tree)
        back = ckpt.restore(d, 1, jax.tree.map(jnp.zeros_like, tree))
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestData:
    def test_determinism_and_resume(self):
        cfg = registry.get("yi-6b").reduced()
        dc = data_mod.DataConfig(batch=2, seq_len=8, seed=3)
        d1 = data_mod.SyntheticLM(cfg, dc)
        d2 = data_mod.SyntheticLM(cfg, dc)
        b1, b2 = d1.batch_at(5), d2.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = registry.get("yi-6b").reduced()
        d = data_mod.SyntheticLM(cfg, data_mod.DataConfig(batch=1, seq_len=8))
        b = d.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape


class TestAggregation:
    def test_int8_roundtrip_unbiased(self):
        x = jnp.asarray(np.linspace(-1, 1, 1024, dtype=np.float32))
        outs = []
        for i in range(64):
            q, s = agg_mod.quantize_int8(x, jax.random.PRNGKey(i))
            outs.append(np.asarray(agg_mod.dequantize_int8(q, s)))
        est = np.mean(outs, axis=0)
        np.testing.assert_allclose(est, np.asarray(x), atol=2e-3)

    def test_compressed_psum_single_device(self):
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)).astype(np.float32))}
        out = agg_mod.compressed_psum(g, jax.random.PRNGKey(0), mesh)
        np.testing.assert_allclose(
            np.asarray(out["w"]), np.asarray(g["w"]), atol=2e-2
        )

    def test_he_aggregation_matches_plain_mean(self):
        agg = agg_mod.HeAggregator(n=256, t=3, v=30, pt_mod=1 << 24, frac_bits=10)
        keys = agg.keygen(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        workers = [
            {"w": jnp.asarray(rng.normal(size=(20,)).astype(np.float32) * 0.1),
             "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32) * 0.1)}
            for _ in range(3)
        ]
        got = agg_mod.he_aggregate_gradients(agg, workers, jax.random.PRNGKey(2), keys)
        want = jax.tree.map(lambda *xs: sum(xs) / len(xs), *workers)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


class TestEngine:
    def test_generate_smoke(self):
        cfg = registry.get("yi-6b").reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, batch_slots=2, max_len=64)
        outs = eng.generate(
            [np.array([1, 2, 3], np.int32), np.array([4, 5], np.int32)], max_new=4
        )
        assert len(outs) == 2 and all(len(o) == 4 for o in outs)
        assert all(0 <= t < cfg.vocab for o in outs for t in o)

    def test_generate_ssm(self):
        cfg = registry.get("mamba2-130m").reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, batch_slots=2, max_len=64)
        outs = eng.generate([np.array([1, 2, 3], np.int32)], max_new=3)
        assert len(outs) == 1 and len(outs[0]) == 3
