"""Observability layer tests (DESIGN.md §12): metrics registry
semantics, the histogram-quantile accuracy contract vs exact
``numpy.percentile`` (property-tested on latency- and queue-wait-shaped
series), span lifecycle + conservation through the serving engine, the
frozen snapshot schema, Prometheus/JSONL exporter round-trips, and the
per-stage profiling drift record.

Uses hypothesis when installed; otherwise the fallback shim turns each
``@given`` property into an individual skip (the seeded versions of the
same bounds still run — the contract is never untested).
"""
import json
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro import obs
from repro.obs.metrics import MetricsRegistry
from repro.serve.crypto_engine import (
    SNAPSHOT_KEYS,
    SNAPSHOT_SCHEMA_VERSION,
    PolymulEngine,
)


# ---------------------------------------------------------------------------
# metrics registry semantics
# ---------------------------------------------------------------------------


def test_counter_inc_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help", ("engine",))
    c.labels(engine="a").inc()
    c.labels(engine="a").inc(2)
    c.labels(engine="b").inc(5)
    assert c.labels(engine="a").value == 3
    assert c.labels(engine="b").value == 5


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help")
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("repro_depth", "help")
    g.set(4.0)
    g.inc()
    g.dec(2.0)
    assert g.value == 3.0


def test_reregistration_idempotent_and_conflicts_raise():
    reg = MetricsRegistry()
    c1 = reg.counter("repro_shared_total", "help", ("engine",))
    c2 = reg.counter("repro_shared_total", "help", ("engine",))
    assert c1 is c2  # two engines share one family
    with pytest.raises(ValueError):
        reg.counter("repro_shared_total", "help", ("other",))
    with pytest.raises(ValueError):
        reg.gauge("repro_shared_total", "help")


def test_invalid_metric_names_rejected():
    reg = MetricsRegistry()
    for bad in ("", "1abc", "a-b", "a.b"):
        with pytest.raises(ValueError):
            reg.counter(bad, "help")


def test_histogram_empty_and_bad_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("repro_lat_seconds", "help")
    assert h.quantile(0.5) is None
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_counter_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("repro_race_total", "help")

    def bump():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=bump) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000


def test_default_registry_reset_zeroes_values():
    c = obs.registry().counter("repro_tmp_total", "help")
    c.inc(7)
    obs.reset_default_registry()
    # families persist (dashboards keep their series); values zero
    assert obs.registry().get("repro_tmp_total") is c
    assert c.value == 0


# ---------------------------------------------------------------------------
# histogram-quantile accuracy contract vs numpy.percentile
# ---------------------------------------------------------------------------


def _assert_quantile_bound(values, q):
    """The documented contract: exact/G - lo <= estimate <= exact*G + lo
    where G = HIST_GROWTH and lo = the first bucket bound."""
    reg = MetricsRegistry()
    h = reg.histogram("repro_q_seconds", "help")
    for v in values:
        h.observe(v)
    est = h.quantile(q)
    exact = float(np.percentile(np.asarray(values), q * 100))
    lo = h.buckets[0]
    g = obs.HIST_GROWTH
    assert exact / g - lo <= est <= exact * g + lo, (
        f"quantile({q}) = {est} outside [{exact / g - lo}, "
        f"{exact * g + lo}] (exact {exact}, n={len(values)})"
    )


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_quantile_bound_latency_shaped(q):
    # lognormal ~ serving latency: long right tail
    rng = np.random.default_rng(11)
    values = rng.lognormal(mean=-6.0, sigma=1.5, size=500).tolist()
    _assert_quantile_bound(values, q)


@pytest.mark.parametrize("q", [0.5, 0.99])
def test_quantile_bound_queue_wait_shaped(q):
    # exponential with a point mass near zero ~ queue waits: most
    # requests dispatch immediately, stragglers wait out a batch window
    rng = np.random.default_rng(12)
    values = np.concatenate([
        rng.exponential(scale=2e-3, size=300),
        np.full(200, 1e-7),  # below first bucket bound: absolute floor
    ]).tolist()
    _assert_quantile_bound(values, q)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.floats(min_value=1e-8, max_value=60.0,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=200,
    ),
    st.sampled_from([0.0, 0.5, 0.9, 0.99, 1.0]),
)
def test_quantile_bound_property(values, q):
    _assert_quantile_bound(values, q)


def test_quantile_exact_on_degenerate_series():
    reg = MetricsRegistry()
    h = reg.histogram("repro_one_seconds", "help")
    h.observe(1e-3)
    est = h.quantile(0.5)
    assert est is not None
    assert 1e-3 / obs.HIST_GROWTH <= est <= 1e-3 * obs.HIST_GROWTH


# ---------------------------------------------------------------------------
# tracing: span lifecycle, conservation, JSONL round-trip
# ---------------------------------------------------------------------------


def test_span_finish_twice_raises_and_late_events_noop():
    log = obs.SpanLog(None)
    sp = log.start_span("request", bucket="b")
    sp.event("admit")
    sp.finish("resolved")
    sp.event("late")  # must not reopen or mutate
    assert sp.events[-1]["name"] == "admit"
    with pytest.raises(RuntimeError):
        sp.finish("failed")


def test_trace_ids_unique_across_threads():
    log = obs.SpanLog(None)
    ids = []
    lock = threading.Lock()

    def mint():
        local = [log.start_span("request").trace_id for _ in range(200)]
        with lock:
            ids.extend(local)

    threads = [threading.Thread(target=mint) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(set(ids)) == len(ids) == 800


def test_conservation_flags_violations():
    log = obs.SpanLog(None)
    ok = log.start_span("request")
    ok.finish("resolved")
    bad = log.start_span("request")
    bad.status = "pending"  # forged non-terminal record
    bad.t_end = bad.t_start
    log._emit(bad.to_record())
    rej = log.start_span("request")
    rej.finish("rejected")  # never admitted: no terminal obligation
    cons = obs.conservation(log.records)
    assert cons["spans"] == 3
    assert cons["admitted"] == 2
    assert cons["by_status"]["rejected"] == 1
    assert any("non-terminal" in v for v in cons["violations"])


def test_span_log_jsonl_round_trip(tmp_path):
    path = tmp_path / "spans.jsonl"
    with obs.SpanLog(path) as log:
        sp = log.start_span("request", bucket="b", seq=1)
        sp.event("dispatch", backend="jnp")
        sp.finish("resolved", latency_s=0.25)
        log.event("breaker_open", level=1)
    records = obs.read_jsonl(path)
    assert records == log.records
    span = next(r for r in records if r["kind"] == "span")
    assert span["status"] == "resolved"
    assert span["attrs"]["latency_s"] == 0.25
    assert span["events"][0]["name"] == "dispatch"
    assert span["t_unix"] > 1e9  # wall anchor, not perf_counter scale


def test_read_jsonl_rejects_malformed(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "span"}\nnot json\n')
    with pytest.raises(ValueError, match="not valid JSON"):
        obs.read_jsonl(path)
    path.write_text('{"kind": "mystery"}\n')
    with pytest.raises(ValueError, match="not a span/event"):
        obs.read_jsonl(path)


# ---------------------------------------------------------------------------
# engine integration: spans + counters + frozen snapshot schema
# ---------------------------------------------------------------------------


def _drive_engine(span_log=None, registry=None, requests=6):
    eng = PolymulEngine(batch_slots=4, span_log=span_log, registry=registry)
    pl = eng.plan(n=64, t=3, v=30)
    rng = np.random.default_rng(5)
    shape = (pl.n, pl.config.seg_count)
    futs = [
        eng.submit(
            pl,
            rng.integers(0, 1 << pl.v, size=shape),
            rng.integers(0, 1 << pl.v, size=shape),
        )
        for _ in range(requests)
    ]
    eng.run_until_idle()
    return eng, futs


def test_engine_spans_conserve_and_match_counters():
    log = obs.SpanLog(None)
    eng, futs = _drive_engine(span_log=log)
    assert all(f.exception() is None for f in futs)
    cons = obs.conservation(log.records)
    assert cons["violations"] == []
    snap = eng.snapshot()
    assert cons["admitted"] == snap["submitted"] == len(futs)
    assert cons["by_status"].get("resolved", 0) == snap["served"]
    # every future carries its span's trace id
    ids = {f.trace_id for f in futs}
    assert len(ids) == len(futs)
    span_ids = {r["trace_id"] for r in log.spans()}
    assert ids == span_ids


def test_engine_doa_request_sheds_with_span():
    log = obs.SpanLog(None)
    eng = PolymulEngine(batch_slots=4, span_log=log)
    pl = eng.plan(n=64, t=3, v=30)
    shape = (pl.n, pl.config.seg_count)
    z = np.zeros(shape, np.int64)
    fut = eng.submit(pl, z, z, deadline=0.0)  # dead on arrival
    eng.run_until_idle()
    assert fut.exception() is not None
    spans = log.spans("shed")
    assert len(spans) == 1
    assert spans[0]["attrs"]["reason"] == "doa"
    assert obs.conservation(log.records)["violations"] == []


def test_engine_backpressure_rejection_span():
    log = obs.SpanLog(None)
    eng = PolymulEngine(batch_slots=4, max_pending=1, span_log=log)
    pl = eng.plan(n=64, t=3, v=30)
    shape = (pl.n, pl.config.seg_count)
    z = np.zeros(shape, np.int64)
    eng.submit(pl, z, z)
    assert eng.try_submit(pl, z, z) is None  # queue full
    eng.run_until_idle()
    cons = obs.conservation(log.records)
    assert cons["by_status"].get("rejected", 0) == 1
    assert cons["violations"] == []
    assert eng.stats["rejected"] == 1


def test_engine_counters_in_private_registry():
    reg = MetricsRegistry()
    eng, _ = _drive_engine(registry=reg)
    fam = reg.get("repro_engine_served_total")
    assert fam is not None
    assert fam.labels(engine=eng.name).value == 6
    # latency histogram populated — quantile available for export
    lat = reg.get("repro_engine_latency_seconds")
    assert lat.labels(engine=eng.name).count == 6


def test_snapshot_schema_frozen():
    """Regression pin: the snapshot wire contract.  Adding a key means
    bumping SNAPSHOT_SCHEMA_VERSION and updating SNAPSHOT_KEYS — this
    test failing on an unintended change is the point."""
    eng, _ = _drive_engine()
    snap = eng.snapshot()
    assert snap["schema_version"] == SNAPSHOT_SCHEMA_VERSION == 1
    assert set(snap.keys()) == set(SNAPSHOT_KEYS)
    # stable value types (JSON-serializable wire record)
    json.dumps(snap)


def test_reset_stats_zeroes_metrics():
    reg = MetricsRegistry()
    eng, _ = _drive_engine(registry=reg)
    eng.reset_stats()
    assert eng.stats["served"] == 0
    assert reg.get("repro_engine_latency_seconds").labels(
        engine=eng.name
    ).count == 0


# ---------------------------------------------------------------------------
# exporters: Prometheus text format + JSON
# ---------------------------------------------------------------------------


def test_prometheus_round_trip_through_strict_parser():
    reg = MetricsRegistry()
    _drive_engine(registry=reg)
    reg.gauge("repro_demo_ratio", "a gauge", ("stage",)).labels(
        stage="compose"
    ).set(0.25)
    text = obs.to_prometheus(reg)
    families = obs.parse_prometheus(text)
    assert "repro_engine_served_total" in families
    assert "repro_engine_latency_seconds" in families
    hist = families["repro_engine_latency_seconds"]
    assert hist["type"] == "histogram"


def test_prometheus_parser_rejects_malformed():
    for bad in (
        "# TYPE repro_x_total counter\nrepro_x_total notanumber\n",
        "# TYPE repro_x_total counter\nrepro_x_total{le=1.0} 1\n",  # unquoted
        "# TYPE repro_h histogram\nrepro_h 1\n",  # bare sample for histogram
        # histogram without the mandatory +Inf bucket
        "# TYPE repro_h histogram\n"
        'repro_h_bucket{le="1.0"} 1\nrepro_h_sum 1\nrepro_h_count 1\n',
        # bucket counts not cumulative
        "# TYPE repro_h histogram\n"
        'repro_h_bucket{le="1.0"} 5\nrepro_h_bucket{le="+Inf"} 3\n'
        "repro_h_sum 1\nrepro_h_count 5\n",
        # buckets without _sum/_count
        "# TYPE repro_h histogram\n"
        'repro_h_bucket{le="+Inf"} 3\n',
    ):
        with pytest.raises(ValueError):
            obs.parse_prometheus(bad)


def test_json_export_carries_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("repro_j_seconds", "help")
    for v in (0.001, 0.002, 0.004):
        h.observe(v)
    doc = obs.to_json(reg)
    assert doc["schema"] == "repro.obs/v1"
    fam = next(f for f in doc["families"] if f["name"] == "repro_j_seconds")
    series = fam["series"][0]
    assert series["count"] == 3
    assert series["p50"] is not None and series["p99"] is not None


# ---------------------------------------------------------------------------
# per-stage profiling: byte attribution + measured-vs-model drift
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
def test_predicted_stage_bytes_sum_to_model(backend):
    import repro
    from repro.kernels import ops as ops_mod

    from repro import api

    pl = repro.plan(n=64, t=3, v=30, backend=backend)
    rows = 4
    per_stage = obs.predicted_stage_bytes(pl, rows)
    assert set(per_stage) == set(obs.STAGES)
    cfg = api.plan_key(pl)
    total = ops_mod.hbm_traffic_model(
        pl.params, rows, backend=cfg.backend, schedule=cfg.schedule
    )["hbm_bytes"]
    assert sum(per_stage.values()) == total


def test_stage_timings_record_and_drift_gauges():
    import repro

    obs.reset_default_registry()
    pl = repro.plan(n=64, t=3, v=30)
    rec = obs.stage_timings(pl, batch=2, iters=2, warmup=1)
    assert set(rec["stages"]) == set(obs.STAGES)
    shares = [s["share_measured"] for s in rec["stages"].values()]
    assert abs(sum(shares) - 1.0) < 1e-6
    for s in rec["stages"].values():
        assert s["seconds"] > 0
        assert 0.0 <= s["share_predicted"] <= 1.0
        assert np.isfinite(s["drift"])
    assert rec["max_drift"] == pytest.approx(
        max(abs(s["drift"]) for s in rec["stages"].values())
    )
    # drift is a queryable metric, not just a report field
    g = obs.registry().get("repro_stage_share_drift")
    assert g is not None
    labeled = {lv for lv, _ in g.children()}
    assert labeled == {
        (stage, rec["backend"]) for stage in obs.STAGES
    }
    text = obs.to_prometheus(obs.registry())
    obs.parse_prometheus(text)  # exposition stays valid with profiling families
    obs.reset_default_registry()
