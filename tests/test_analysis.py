"""HLO analyzer + dry-run artifact integrity tests (fast, 1-device)."""
import glob
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_analyzer as H

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


class TestHloAnalyzer:
    def test_scan_trip_count_flops(self):
        def scanned(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None

            out, _ = jax.lax.scan(body, x, w)
            return out

        x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
        txt = jax.jit(scanned).lower(x, w).compile().as_text()
        got = H.analyze(txt)["flops"]
        assert got == 8 * 2 * 128 * 256 * 256  # loop-aware, exact

    def test_nested_scan(self):
        def nested(x, w):
            def outer(c, wo):
                def inner(c2, wi):
                    return c2 @ wi, None

                c2, _ = jax.lax.scan(inner, c, wo)
                return c2, None

            out, _ = jax.lax.scan(outer, x, w)
            return out

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
        txt = jax.jit(nested).lower(x, w).compile().as_text()
        got = H.analyze(txt)["flops"]
        assert got == 3 * 4 * 2 * 64 * 64 * 64

    def test_collective_bytes_psum(self):
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.compat import shard_map

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))

        def f(x):
            return shard_map(
                lambda y: jax.lax.psum(y, "data"), mesh=mesh,
                in_specs=P(None), out_specs=P(None),
            )(x)

        x = jax.ShapeDtypeStruct((1024,), jnp.float32)
        txt = jax.jit(f).lower(x).compile().as_text()
        coll = H.analyze(txt)["collectives"]
        assert coll["all-reduce"] == 4096  # 1024 f32 result bytes
        assert coll["total"] == 4096

    def test_dus_counts_update_not_buffer(self):
        def f(buf, upd):
            def body(b, u):
                b = jax.lax.dynamic_update_slice(b, u, (jnp.int32(0), jnp.int32(0)))
                return b, None

            out, _ = jax.lax.scan(body, buf, upd)
            return out

        buf = jax.ShapeDtypeStruct((4096, 128), jnp.float32)
        upd = jax.ShapeDtypeStruct((16, 8, 128), jnp.float32)
        txt = jax.jit(f).lower(buf, upd).compile().as_text()
        got = H.analyze(txt)["hbm_bytes"]
        # 16 iterations x ~2 x (8*128*4 bytes) update traffic, NOT 16 x 2 MB
        assert got < 16 * 4096 * 128 * 4 / 4, got


class TestDryrunArtifacts:
    def test_all_baseline_cells_ok(self):
        files = [
            f
            for f in glob.glob(os.path.join(ART, "dryrun_*.json"))
            if json.load(open(f)).get("tag", "") == ""
        ]
        if not files:
            pytest.skip("no dry-run artifacts present")
        assert len(files) >= 64, f"expected 64 baseline cells, found {len(files)}"
        bad = []
        for f in files:
            d = json.load(open(f))
            if d.get("status") != "ok":
                bad.append((d["arch"], d["shape"], d["mesh"], d.get("error")))
        assert not bad, bad

    def test_roofline_terms_present_and_positive(self):
        files = glob.glob(os.path.join(ART, "dryrun_single_*train_4k.json"))
        files = [f for f in files if json.load(open(f)).get("tag", "") == ""]
        if not files:
            pytest.skip("no artifacts")
        for f in files:
            d = json.load(open(f))
            r = d["roofline"]
            assert r["compute_s"] > 0, d["arch"]
            assert r["memory_s"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
            assert 0 < d["model_flops_ratio"] < 10

    def test_multi_pod_cells_exist(self):
        files = [
            f
            for f in glob.glob(os.path.join(ART, "dryrun_multi_*.json"))
            if json.load(open(f)).get("tag", "") == ""
        ]
        if not files:
            pytest.skip("no artifacts")
        assert len(files) >= 32
        for f in files:
            d = json.load(open(f))
            assert d["n_devices"] == 512
            assert d["status"] == "ok"
