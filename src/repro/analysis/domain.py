"""Interval + q-linear abstract domain for the kernel verifier.

Every traced value is abstracted by an :class:`AbsVal`:

* exact python-int absolute bounds ``[lo, hi]`` (``None`` = unbounded in
  that direction — arbitrary precision, so 2**63 boundaries are exact);
* an optional elementwise **q-linear** upper bound ``x <= qa*q + qb``
  where ``q`` is the element's *own* RNS channel modulus.  Plain
  intervals cannot express "below 2q in every channel" once moduli
  differ across the channel axis; the q-linear term is exactly the
  "units of q" currency of the hand-kept window bookkeeping
  (:func:`repro.core.modmath.lazy_stage_bounds`), so the envelope
  comparison is a direct ``<=`` on these coefficients;
* a matching elementwise q-linear **lower** bound ``x >= la*q + lb``.
  Needed for the conditional-add in ``sub_mod``: ``d = x - y`` with
  canonical x, y satisfies ``d >= -(q_elem - 1)`` *per element*, so
  ``d + q_elem >= 1`` — a fact the absolute interval loses the moment
  the channel moduli differ (``lo(d) + q_min`` can be negative);
* a ``tag`` marking verified host constants (twiddle/Shoup/modulus/...)
  that the pattern matchers in :mod:`repro.analysis.interp` require;
* a ``prov`` provenance tuple ``(prim, *operand AbsVals)`` recorded for
  comparison/arithmetic primitives so the Shoup/Barrett patterns and
  the conditional-subtract refinement can be matched *across* jaxpr
  scopes (jnp ``where`` lands inside ``pjit("_where")`` sub-jaxprs, so
  def-use matching by eqn within one scope would not see the compare).

Soundness rule for the q-linear term: it survives only channel-
preserving elementwise ops (add/sub by a bounded term, singleton
shifts, refinement).  Multiplying two q-linear values, reducing over an
axis, or mixing channels drops it to the absolute interval.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from fractions import Fraction
from typing import Iterator, Optional, Tuple

_UIDS: Iterator[int] = itertools.count(1)

Tag = Tuple[object, ...]
Prov = Tuple[object, ...]


@dataclasses.dataclass(eq=False)
class QCtx:
    """Channel-modulus context: the range the per-element ``q`` can take."""

    q_min: int
    q_max: int


@dataclasses.dataclass(eq=False)
class AbsVal:
    lo: Optional[int]
    hi: Optional[int]
    qa: Optional[Fraction] = None  # x <= qa*q_elem + qb  (requires qa is not None)
    qb: Optional[Fraction] = None
    tag: Optional[Tag] = None
    prov: Optional[Prov] = None
    # Affine form: value == c * base elementwise, c in [aff[1], aff[2]].
    # Set by the interpreter for shift/mul-by-singleton/add/sub chains so
    # SAU accumulations like ``-x + sum(s_j * (x << e_j))`` keep their
    # exact (nonnegative) coefficient instead of a sign-lost interval.
    aff: Optional[Tuple["AbsVal", int, int]] = None
    la: Optional[Fraction] = None  # x >= la*q_elem + lb (requires la is not None)
    lb: Optional[Fraction] = None
    uid: int = dataclasses.field(default_factory=lambda: next(_UIDS))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        q = f" <= {self.qa}q{self.qb:+}" if self.qa is not None else ""
        t = f" tag={self.tag}" if self.tag else ""
        return f"AbsVal[{self.lo}, {self.hi}]{q}{t}"

    @property
    def bounded(self) -> bool:
        return self.lo is not None and self.hi is not None

    def is_singleton(self) -> bool:
        return self.lo is not None and self.lo == self.hi

    def with_qlin(self, qa: Fraction, qb: Fraction, qctx: QCtx) -> "AbsVal":
        """Attach/replace the q-linear upper bound, tightening hi with it."""
        qhi = _floor_frac(qa * qctx.q_max + qb) if qa >= 0 else _floor_frac(qa * qctx.q_min + qb)
        hi = qhi if self.hi is None else min(self.hi, qhi)
        return AbsVal(self.lo, hi, qa, qb, self.tag, self.prov, self.aff, self.la, self.lb)

    def with_qlo(self, la: Fraction, lb: Fraction, qctx: QCtx) -> "AbsVal":
        """Attach/replace the q-linear lower bound, tightening lo with it."""
        qlo = _ceil_frac(la * qctx.q_min + lb) if la >= 0 else _ceil_frac(la * qctx.q_max + lb)
        lo = qlo if self.lo is None else max(self.lo, qlo)
        return AbsVal(lo, self.hi, self.qa, self.qb, self.tag, self.prov, self.aff, la, lb)

    def view(self, *, fresh: bool = False) -> "AbsVal":
        """A layout view: same bounds/tag/prov.  Element-aligned views
        (broadcast/reshape/squeeze) keep the identity so relational
        pattern matching (``_same``) sees through them; element-selecting
        views (slice/rev/transpose) pass ``fresh=True``."""
        out = AbsVal(
            self.lo, self.hi, self.qa, self.qb, self.tag, self.prov,
            self.aff, self.la, self.lb,
        )
        if not fresh:
            out.uid = self.uid
        return out


def const(v: int) -> AbsVal:
    return AbsVal(int(v), int(v), prov=("lit", int(v)))


def top() -> AbsVal:
    return AbsVal(None, None)


def boolean() -> AbsVal:
    return AbsVal(0, 1)


def from_ints(lo: int, hi: int) -> AbsVal:
    return AbsVal(int(lo), int(hi))


def _floor_frac(x: Fraction) -> int:
    return math.floor(x)


def _ceil_frac(x: Fraction) -> int:
    return math.ceil(x)


def _add_b(a: Optional[int], b: Optional[int]) -> Optional[int]:
    return None if a is None or b is None else a + b


def _neg_b(a: Optional[int]) -> Optional[int]:
    return None if a is None else -a


def units_of_q(av: AbsVal, qctx: QCtx) -> Optional[int]:
    """The bookkeeping currency: smallest integer ``k`` provable to
    satisfy ``x < k*q_elem`` (ceil of the bound in units of q)."""
    if av.qa is not None and av.qb is not None:
        # x <= qa*q + qb.  If qb <= 0 this is < qa*q (for qa integral it
        # means k = qa); in general k = ceil(qa + qb/q_min) over q range.
        if av.qb <= 0:
            return max(1, _ceil_frac(av.qa))
        return max(1, _ceil_frac(av.qa + Fraction(av.qb) / qctx.q_min))
    if av.hi is not None:
        # x <= hi  =>  x < hi + 1 <= k * q_min with k = ceil((hi+1)/q_min)
        return max(1, -((av.hi + 1) // -qctx.q_min))
    return None


def join(a: AbsVal, b: AbsVal, qctx: Optional[QCtx] = None) -> AbsVal:
    lo = None if a.lo is None or b.lo is None else min(a.lo, b.lo)
    hi = None if a.hi is None or b.hi is None else max(a.hi, b.hi)
    qa = qb = None
    if a.qa is not None and b.qa is not None and a.qb is not None and b.qb is not None:
        qa, qb = max(a.qa, b.qa), max(a.qb, b.qb)
    elif qctx is not None:
        # One-sided q-linear upper survives the join when it dominates the
        # other side's constant bound on every channel (pad with zeros,
        # concatenate with a small literal).  Never widen qb toward the
        # other side's hi: that would loosen the units-of-q accounting.
        for x, y in ((a, b), (b, a)):
            if x.qa is not None and x.qb is not None and y.qa is None and y.hi is not None:
                worst = x.qa * qctx.q_min + x.qb if x.qa >= 0 else x.qa * qctx.q_max + x.qb
                if Fraction(y.hi) <= worst:
                    qa, qb = x.qa, x.qb
                break
    tag = a.tag if a.tag == b.tag else None
    out = AbsVal(lo, hi, qa, qb, tag)
    if a.la is not None and b.la is not None and a.lb is not None and b.lb is not None:
        out.la, out.lb = min(a.la, b.la), min(a.lb, b.lb)
    elif qctx is not None:
        for x, y in ((a, b), (b, a)):
            if x.la is not None and x.lb is not None and y.la is None and y.lo is not None:
                worst = x.la * qctx.q_max + x.lb if x.la >= 0 else x.la * qctx.q_min + x.lb
                if Fraction(y.lo) >= worst:
                    out.la, out.lb = x.la, x.lb
                break
    return out


def _eff_up(x: AbsVal) -> Optional[Tuple[Fraction, Fraction]]:
    """Elementwise q-linear upper form, falling back to the global hi
    (``x <= 0*q + hi`` holds per element too)."""
    if x.qa is not None and x.qb is not None:
        return (x.qa, x.qb)
    if x.hi is not None:
        return (Fraction(0), Fraction(x.hi))
    return None


def _eff_lo(x: AbsVal) -> Optional[Tuple[Fraction, Fraction]]:
    if x.la is not None and x.lb is not None:
        return (x.la, x.lb)
    if x.lo is not None:
        return (Fraction(0), Fraction(x.lo))
    return None


def add(a: AbsVal, b: AbsVal, qctx: QCtx) -> AbsVal:
    out = AbsVal(_add_b(a.lo, b.lo), _add_b(a.hi, b.hi))
    # Materialize a q-linear *upper* form only when one operand carries a
    # genuine one — synthesizing (0, hi) forms here would flood the
    # units-of-q envelope stream with transient wide products.
    if a.qa is not None and b.qa is not None and a.qb is not None and b.qb is not None:
        out = out.with_qlin(a.qa + b.qa, a.qb + b.qb, qctx)
    elif a.qa is not None and a.qb is not None and b.hi is not None:
        out = out.with_qlin(a.qa, a.qb + b.hi, qctx)
    elif b.qa is not None and b.qb is not None and a.hi is not None:
        out = out.with_qlin(b.qa, b.qb + a.hi, qctx)
    # Lower forms never feed the envelope stream: combine freely.
    ea, eb = _eff_lo(a), _eff_lo(b)
    if ea is not None and eb is not None:
        out = out.with_qlo(ea[0] + eb[0], ea[1] + eb[1], qctx)
    out.prov = ("add", a, b)
    return out


def sub(a: AbsVal, b: AbsVal, qctx: QCtx) -> AbsVal:
    out = AbsVal(_add_b(a.lo, _neg_b(b.hi)), _add_b(a.hi, _neg_b(b.lo)))
    # Upper bound of a - b wants b's *lower* bound; prefer its q-linear
    # form (same channel) over the channel-mixing absolute lo.
    if a.qa is not None and a.qb is not None:
        eb_lo = _eff_lo(b)
        if eb_lo is not None:
            out = out.with_qlin(a.qa - eb_lo[0], a.qb - eb_lo[1], qctx)
    ea_lo, eb_up = _eff_lo(a), _eff_up(b)
    if ea_lo is not None and eb_up is not None:
        out = out.with_qlo(ea_lo[0] - eb_up[0], ea_lo[1] - eb_up[1], qctx)
    out.prov = ("sub", a, b)
    return out


def neg(a: AbsVal) -> AbsVal:
    out = AbsVal(_neg_b(a.hi), _neg_b(a.lo), prov=("neg", a))
    if a.la is not None and a.lb is not None:
        out.qa, out.qb = -a.la, -a.lb
    if a.qa is not None and a.qb is not None:
        out.la, out.lb = -a.qa, -a.qb
    return out


def _mul_b(a: Optional[int], b: Optional[int]) -> Optional[int]:
    if a is None or b is None:
        return None  # treated as unbounded by the caller
    return a * b


def mul(a: AbsVal, b: AbsVal, qctx: QCtx) -> AbsVal:
    if a.bounded and b.bounded:
        assert a.lo is not None and a.hi is not None
        assert b.lo is not None and b.hi is not None
        prods = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
        out = AbsVal(min(prods), max(prods))
    else:
        # Unbounded on some side: only the all-nonnegative case keeps a
        # useful lower bound.
        lo = 0 if (a.lo is not None and a.lo >= 0 and b.lo is not None and b.lo >= 0) else None
        out = AbsVal(lo, None)
    # q-linear survives scaling by a *small* exact nonnegative constant
    # (x2 for 2q, small radix factors).  Data-sized factors would
    # manufacture astronomically loose q-linear forms on multiplier wires
    # which pollute the units-of-q envelope stream; those products are
    # bounded by the interval alone and re-derived by the Shoup/Barrett
    # pattern matchers where it matters.
    for x, y in ((a, b), (b, a)):
        if (
            x.qa is not None
            and x.qb is not None
            and x.lo is not None
            and x.lo >= 0
            and y.qa is None
            and y.lo is not None
            and 0 <= y.lo <= 16
            and y.is_singleton()
        ):
            out = out.with_qlin(x.qa * y.lo, x.qb * y.lo, qctx)
            break
    # q-linear *lower* survives scaling by an exact nonnegative constant
    # (2q = mul(q, 2) must keep q-elementwise lower 2*q_elem, or the
    # lazy-restore add (u - t) + 2q picks up cross-channel slack).
    for x, y in ((a, b), (b, a)):
        if (
            x.la is not None
            and x.lb is not None
            and y.la is None
            and y.lo is not None
            and y.lo >= 0
            and y.is_singleton()
        ):
            out = out.with_qlo(x.la * y.lo, x.lb * y.lo, qctx)
            break
    out.prov = ("mul", a, b)
    return out


def shift_left(a: AbsVal, s: AbsVal, qctx: QCtx) -> AbsVal:
    if s.lo is None or s.hi is None or s.lo < 0:
        return top()
    lo = None
    hi = None
    if a.lo is not None:
        lo = a.lo << (s.lo if a.lo >= 0 else s.hi)
    if a.hi is not None:
        hi = a.hi << (s.hi if a.hi >= 0 else s.lo)
    out = AbsVal(lo, hi)
    if (a.qa is not None and a.qb is not None and s.is_singleton()
            and a.lo is not None and a.lo >= 0):
        out = out.with_qlin(a.qa * (1 << s.lo), a.qb * (1 << s.lo), qctx)
    if a.la is not None and a.lb is not None and s.is_singleton():
        out = out.with_qlo(a.la * (1 << s.lo), a.lb * (1 << s.lo), qctx)
    out.prov = ("shift_left", a, s)
    return out


def shift_right(a: AbsVal, s: AbsVal, qctx: QCtx) -> AbsVal:
    if s.lo is None or s.hi is None or s.lo < 0:
        return top()
    lo = None
    hi = None
    if a.lo is not None:
        lo = a.lo >> (s.hi if a.lo >= 0 else s.lo)
    if a.hi is not None:
        hi = a.hi >> (s.lo if a.hi >= 0 else s.hi)
    out = AbsVal(lo, hi)
    if (
        a.qa is not None
        and a.qb is not None
        and s.is_singleton()
        and a.lo is not None
        and a.lo >= 0
        and s.lo is not None
    ):
        out = out.with_qlin(a.qa / (1 << s.lo), a.qb / (1 << s.lo), qctx)
    out.prov = ("shift_right", a, s)
    return out


def bit_and(a: AbsVal, b: AbsVal) -> AbsVal:
    if a.lo is not None and a.lo >= 0 and b.lo is not None and b.lo >= 0:
        his = [h for h in (a.hi, b.hi) if h is not None]
        return AbsVal(0, min(his) if his else None, prov=("and", a, b))
    return top()


def _pow2_ceil(x: int) -> int:
    return 1 << max(x, 1).bit_length()


def bit_or(a: AbsVal, b: AbsVal) -> AbsVal:
    if (
        a.lo is not None
        and a.lo >= 0
        and b.lo is not None
        and b.lo >= 0
        and a.hi is not None
        and b.hi is not None
    ):
        return AbsVal(0, _pow2_ceil(max(a.hi, b.hi)) - 1, prov=("or", a, b))
    return top()


def rem(a: AbsVal, b: AbsVal, qctx: QCtx) -> AbsVal:
    """jnp ``%`` with a positive divisor (sign follows the divisor)."""
    if b.lo is None or b.lo <= 0 or b.hi is None:
        return top()
    out = AbsVal(0 if (a.lo is not None and a.lo >= 0) else -(b.hi - 1), b.hi - 1)
    if b.qa is not None and b.qb is not None and out.lo is not None and out.lo >= 0:
        out = out.with_qlin(b.qa, b.qb - 1, qctx)
    out.prov = ("rem", a, b)
    return out


def reduce_sum(a: AbsVal, count: int) -> AbsVal:
    """Sum of ``count`` elements each in ``a`` (q-linear dropped: the
    reduced axis may mix channels)."""
    lo = None if a.lo is None else a.lo * count
    hi = None if a.hi is None else a.hi * count
    return AbsVal(lo, hi, prov=("reduce_sum", a))


def compare(kind: str, a: AbsVal, b: AbsVal) -> AbsVal:
    """Comparison → boolean abstract value, folded when decidable."""
    out = boolean()
    t: Optional[bool] = None
    if a.bounded and b.bounded:
        assert a.lo is not None and a.hi is not None
        assert b.lo is not None and b.hi is not None
        if kind == "ge":
            t = True if a.lo >= b.hi else (False if a.hi < b.lo else None)
        elif kind == "gt":
            t = True if a.lo > b.hi else (False if a.hi <= b.lo else None)
        elif kind == "le":
            t = True if a.hi <= b.lo else (False if a.lo > b.hi else None)
        elif kind == "lt":
            t = True if a.hi < b.lo else (False if a.lo >= b.hi else None)
        elif kind == "eq":
            if a.is_singleton() and b.is_singleton() and a.lo == b.lo:
                t = True
            elif a.hi < b.lo or a.lo > b.hi:
                t = False
        elif kind == "ne":
            if a.is_singleton() and b.is_singleton() and a.lo == b.lo:
                t = False
            elif a.hi < b.lo or a.lo > b.hi:
                t = True
    if t is True:
        out = const(1)
    elif t is False:
        out = const(0)
    out.prov = (kind, a, b)
    return out


def _dominates_le(qa1: Fraction, qb1: Fraction, qa2: Fraction, qb2: Fraction, qctx: QCtx) -> bool:
    """qa1*q + qb1 <= qa2*q + qb2 for every q in [q_min, q_max]."""
    return (
        qa1 * qctx.q_min + qb1 <= qa2 * qctx.q_min + qb2
        and qa1 * qctx.q_max + qb1 <= qa2 * qctx.q_max + qb2
    )


def clamp_max(a: AbsVal, hi: int, qctx: QCtx) -> AbsVal:
    """``a`` with the *elementwise-proven* upper bound ``hi`` applied
    (callers only pass bounds that hold per element, so the constant
    form may also replace a weaker q-linear upper bound)."""
    out = AbsVal(a.lo, hi if a.hi is None else min(a.hi, hi), a.qa, a.qb, a.tag)
    out.prov, out.la, out.lb = a.prov, a.la, a.lb
    ch = Fraction(hi)
    if (
        out.qa is not None
        and out.qb is not None
        and _dominates_le(Fraction(0), ch, out.qa, out.qb, qctx)
    ):
        out.qa, out.qb = Fraction(0), ch
    return out


def clamp_min(a: AbsVal, lo: int, qctx: Optional[QCtx] = None) -> AbsVal:
    """``a`` with the elementwise-proven lower bound ``lo`` applied."""
    out = AbsVal(lo if a.lo is None else max(a.lo, lo), a.hi, a.qa, a.qb, a.tag)
    out.prov, out.la, out.lb = a.prov, a.la, a.lb
    cl = Fraction(lo)
    if (
        qctx is not None
        and out.la is not None
        and out.lb is not None
        and _dominates_le(out.la, out.lb, Fraction(0), cl, qctx)
    ):
        out.la, out.lb = Fraction(0), cl
    return out
