"""Model / run configuration schema.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; reduced variants (``.reduced()``) power the
CPU smoke tests.  ``RunConfig`` carries the execution-level knobs
(sharding, remat, HE-aggregation, compression) consumed by the launcher.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- attention variants ---
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (sums to head_dim/2)
    sliding_window: int = 0  # >0: local attention window
    local_global_alternate: bool = False  # gemma2: odd layers local
    attn_softcap: float = 0.0  # gemma2: 50.0
    logit_softcap: float = 0.0  # gemma2: 30.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_shared_expert: bool = False  # llama4: shared expert alongside routed
    moe_every: int = 1  # llama4: every 2nd layer is MoE (interleaved dense)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # --- hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    # --- enc-dec (seamless): n_layers encoder + n_layers decoder ---
    # --- modality frontend stub: model consumes embeddings directly ---
    frontend: str = ""  # "" | "vision" | "audio"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to a multiple of 256 so the vocab dim
        shards evenly on the 16-way mesh axes (logits are sliced back to
        ``vocab`` in unembed)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Same family/topology, laptop-scale: used by smoke tests."""
        scale = dict(
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            sliding_window=16 if self.sliding_window else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
        )
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution-level knobs."""

    model: ModelConfig
    remat: bool = True
    remat_group: int = 1  # checkpoint every k-th layer boundary (sqrt-depth memory)
    grad_accum_steps: int = 1  # microbatch accumulation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # distributed-optimization tricks
    grad_compression: str = ""  # "" | "int8"  (cross-pod hop)
    he_aggregation: bool = False  # BFV-encrypted cross-pod gradient sum
    # fault tolerance
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
