"""Pallas TPU kernels for the batched NWC NTT / iNTT and the fused
no-shuffle polynomial-multiplication cascade (paper contribution 1 mapped
to the TPU memory hierarchy).

TPU mapping
-----------
* One grid step processes a (ROWS, n) tile of polynomials for one RNS
  channel, resident in VMEM; twiddles (n,) for that channel are also VMEM
  blocks.  Per-channel moduli and Barrett constants arrive as (1, 1)
  SMEM-style scalar blocks.
* The fused kernel runs NTT(a), NTT(b), the pointwise product and the
  iNTT inside ONE pallas_call: the NTT-domain product never exists in HBM.
  This is the TPU analogue of the paper's buffer-free NTT->iNTT cascade —
  on the FPGA the eliminated resource is the DSD shuffle buffer; here it
  is an HBM round-trip of 2 x ROWS x n x 8 bytes per channel.
* The fused *e2e* kernel goes one step further (the paper's full
  feed-forward datapath, Fig 10): CRT pre-processing, the cascade and
  CRT post-processing in ONE pallas_call, reusing the in-kernel stages
  of :mod:`repro.kernels.crt` — residue polynomials never exist in HBM
  either; only segments enter and product limbs leave.  Where the RNS
  plan allows (`t >= 2` with in-kernel decompose constants), the grid
  gains a channel-tiled axis: each grid step runs ONE channel's
  specialized circuit with per-channel constants delivered as scalar
  blocks (the data-driven decompose of :func:`repro.kernels.crt
  .decompose_stage_dyn`), accumulating the Eq-10 contributions in the
  revisited output block — per-step VMEM drops by t, so ``row_blk`` can
  grow past the fixed DEFAULT_E2E_ROWS=1 static unroll.
* Stage schedule (DESIGN.md §6): ``schedule="radix2"`` is the flat loop
  whose late forward (early inverse) stages pair at lane strides < 128;
  ``schedule="four_step"`` is the lane-aligned (n1, n2) tile schedule —
  column stages pair along the sublane axis, then the tile is transposed
  in VMEM and the row stages (twist-merged per-row twiddle tables) pair
  along the sublane axis too, so NO butterfly stage ever pairs along the
  lane axis at stride < 128.  The fused cascades keep the tiles
  transposed across the pointwise product: two transposes per cascade
  instead of four.
* Butterfly modular arithmetic is imported from
  :mod:`repro.core.modmath` — the same helpers the pure-jnp reference
  oracle uses, so kernel and oracle cannot drift.  When ``shifts`` is
  given (static), the per-channel Barrett constant ``eps`` replaces the
  generic ``%``; when ``lazy=(window, beta)`` is given the butterflies
  switch to Harvey lazy reduction (Shoup twiddle products, values in
  [0, window*q)) with ONE canonicalizing reduce at transform/cascade
  exit — O(1) conditional subtractions per transform instead of 5 per
  stage.

VMEM budget per grid step (n = 4096, ROWS = 8, int64):
  a, b tiles 2 x 256 KiB + twiddles 2 x 32 KiB + scratch ≈ 0.8 MiB << 128 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import modmath
from repro.core.modmath import add_mod, div2_mod, mul_mod, sub_mod
from repro.kernels.crt import (
    compose_finalize,
    decompose_stage,
    decompose_stage_dyn,
    plan_dec_arrays,
    require_dec,
)

DEFAULT_ROWS = 8
DEFAULT_E2E_ROWS = 1  # polynomials per grid step, unrolled-channel kernel
DEFAULT_E2E_ROWS_CHGRID = 4  # channel-tiled grid: per-step VMEM is ~1/t


# --------------------------------------------------------------------------
# butterfly closures (strict Barrett vs Harvey lazy) and stage loops
# --------------------------------------------------------------------------


def _butterflies(q, half=None, eps=None, shifts=None, lazy=None):
    """(ct, gs) butterfly pair.  Strict: canonical [0, q) values, 5
    conditional subtractions per stage.  Lazy (window, beta): values stay
    in [0, window*q), 1-2 conditional subtractions per stage."""
    if lazy is not None:
        window, beta = lazy

        def ct(u, v, w, ws):
            return modmath.lazy_ct_butterfly(
                u, v, w, ws, q, beta=beta, window=window
            )

        def gs(u, v, w, ws):
            return modmath.lazy_gs_butterfly(
                u, v, w, ws, q, half, beta=beta, window=window
            )

    else:

        def ct(u, v, w, ws):
            p = mul_mod(v, w, q, eps, shifts)
            return add_mod(u, p, q), sub_mod(u, p, q)

        def gs(u, v, w, ws):
            s = add_mod(u, v, q)
            d = mul_mod(sub_mod(u, v, q), w, q, eps, shifts)
            return div2_mod(s, half), div2_mod(d, half)

    return ct, gs


def _canon(x, q, lazy):
    """The single exit reduce of a lazy transform; identity when strict."""
    return x if lazy is None else modmath.canonicalize(x, q, lazy[0])


def _slc(tab, lo, hi, bcast):
    """Static twiddle-table slice, reshaped for broadcast; None-safe for
    the shoup table of a strict transform."""
    if tab is None:
        return None
    return jax.lax.slice_in_dim(tab, lo, hi)[bcast]


_B2 = (None, slice(None), None)  # (1, m, 1)          radix-2 tiles
_B3C = (None, slice(None), None, None)  # (1, m, 1, 1)   four-step columns
_B3R = (None, slice(None), None, slice(None))  # (1, m, 1, n1) rows


def _radix2_fwd(a, fwd, fwd_sh, ct):
    """CT/DIT stages on the last axis of a (rows, n) tile (flat
    schedule: stage pair stride n/2 .. 1)."""
    rows, n = a.shape
    m, t = 1, n
    while m < n:
        t //= 2
        w = _slc(fwd, m, 2 * m, _B2)
        ws = _slc(fwd_sh, m, 2 * m, _B2)
        x = a.reshape(rows, m, 2, t)
        hi, lo = ct(x[:, :, 0, :], x[:, :, 1, :], w, ws)
        a = jnp.stack([hi, lo], axis=2).reshape(rows, n)
        m *= 2
    return a


def _radix2_inv(a, inv, inv_sh, gs):
    """Mirror-order GS stages with the per-stage halving (Fig 9 PE)."""
    rows, n = a.shape
    h, t = n // 2, 1
    while h >= 1:
        w = _slc(inv, h, 2 * h, _B2)
        ws = _slc(inv_sh, h, 2 * h, _B2)
        x = a.reshape(rows, h, 2, t)
        s, d = gs(x[:, :, 0, :], x[:, :, 1, :], w, ws)
        a = jnp.stack([s, d], axis=2).reshape(rows, n)
        h //= 2
        t *= 2
    return a


def _fs_cols_fwd(x, fwd, fwd_sh, ct):
    """Column stages on the (rows, n1, n2) tile: pairing along the n1
    (sublane) axis, lane axis n2 intact; twiddles = fwd[:n1] prefix."""
    rows, n1, n2 = x.shape
    m, tc = 1, n1
    while m < n1:
        tc //= 2
        w = _slc(fwd, m, 2 * m, _B3C)
        ws = _slc(fwd_sh, m, 2 * m, _B3C)
        y = x.reshape(rows, m, 2, tc, n2)
        hi, lo = ct(y[:, :, 0], y[:, :, 1], w, ws)
        x = jnp.stack([hi, lo], axis=2).reshape(rows, n1, n2)
        m *= 2
    return x


def _fs_rows_fwd(xt, row_fwd, row_sh, ct):
    """Row stages on the TRANSPOSED (rows, n2, n1) tile: pairing along
    the n2 (sublane) axis with the (n2, n1) twist-merged row tables."""
    rows, n2, n1 = xt.shape
    m, tr = 1, n2
    while m < n2:
        tr //= 2
        w = _slc(row_fwd, m, 2 * m, _B3R)
        ws = _slc(row_sh, m, 2 * m, _B3R)
        y = xt.reshape(rows, m, 2, tr, n1)
        hi, lo = ct(y[:, :, 0], y[:, :, 1], w, ws)
        xt = jnp.stack([hi, lo], axis=2).reshape(rows, n2, n1)
        m *= 2
    return xt


def _fs_rows_inv(xt, row_inv, row_sh, gs):
    rows, n2, n1 = xt.shape
    h, tr = n2 // 2, 1
    while h >= 1:
        w = _slc(row_inv, h, 2 * h, _B3R)
        ws = _slc(row_sh, h, 2 * h, _B3R)
        y = xt.reshape(rows, h, 2, tr, n1)
        s, d = gs(y[:, :, 0], y[:, :, 1], w, ws)
        xt = jnp.stack([s, d], axis=2).reshape(rows, n2, n1)
        h //= 2
        tr *= 2
    return xt


def _fs_cols_inv(x, inv, inv_sh, gs):
    rows, n1, n2 = x.shape
    h, tc = n1 // 2, 1
    while h >= 1:
        w = _slc(inv, h, 2 * h, _B3C)
        ws = _slc(inv_sh, h, 2 * h, _B3C)
        y = x.reshape(rows, h, 2, tc, n2)
        s, d = gs(y[:, :, 0], y[:, :, 1], w, ws)
        x = jnp.stack([s, d], axis=2).reshape(rows, n1, n2)
        h //= 2
        tc *= 2
    return x


# -- hierarchical column transforms (DESIGN.md §10): at n >= 8192 the
# level-0 column length n1 = n/128 no longer fits a vreg-height tile, so
# the column transform itself recurses through the canonical
# four_step_chain — a length-c sub-transform along the sublane-side axis
# of a (rows, c, B) view, with deeper levels reached by RESHAPE only
# (the one physical transpose stays at level 0).


def _slc_sub(tab, lo, hi):
    """Static sub-row twiddle slice: a (sr, sc) per-level table sliced
    along sr and laid out (1, sc, m, 1, 1) to broadcast over the
    (rows, sc, m, tr, B) pairing view; None-safe."""
    if tab is None:
        return None
    w = jnp.swapaxes(jax.lax.slice_in_dim(tab, lo, hi), 0, 1)
    return w[None, :, :, None, None]


def _fs_sub_rows_fwd(x, rtab, rsh, ct):
    """Sub-row CT stages on a (rows, sc, sr, B) view: pairing along the
    sr axis with the per-sub-column twist-merged tables (rtab: (sr, sc))."""
    rows, sc, sr, B = x.shape
    m, tr = 1, sr
    while m < sr:
        tr //= 2
        w = _slc_sub(rtab, m, 2 * m)
        ws = _slc_sub(rsh, m, 2 * m)
        y = x.reshape(rows, sc, m, 2, tr, B)
        hi, lo = ct(y[:, :, :, 0], y[:, :, :, 1], w, ws)
        x = jnp.stack([hi, lo], axis=3).reshape(rows, sc, sr, B)
        m *= 2
    return x


def _fs_sub_rows_inv(x, rtab, rsh, gs):
    rows, sc, sr, B = x.shape
    h, tr = sr // 2, 1
    while h >= 1:
        w = _slc_sub(rtab, h, 2 * h)
        ws = _slc_sub(rsh, h, 2 * h)
        y = x.reshape(rows, sc, h, 2, tr, B)
        s, d = gs(y[:, :, :, 0], y[:, :, :, 1], w, ws)
        x = jnp.stack([s, d], axis=3).reshape(rows, sc, sr, B)
        h //= 2
        tr *= 2
    return x


def _fs_cols_fwd_hier(x, fwd, fwd_sh, sub_tabs, sub_shs, ct):
    """Length-c forward transform along axis 1 of a (rows, c, B) tile;
    ``sub_tabs`` holds the remaining per-level (sr, sc) sub-row tables
    (empty -> plain column stages on the fwd[:c] prefix).  The sub-column
    recursion folds sr into the batch axis — a reshape, not a transpose."""
    if not sub_tabs:
        return _fs_cols_fwd(x, fwd, fwd_sh, ct)
    rows, c, B = x.shape
    rtab = sub_tabs[0]
    sr, sc = rtab.shape[-2:]
    x = x.reshape(rows, sc, sr * B)
    x = _fs_cols_fwd_hier(x, fwd, fwd_sh, sub_tabs[1:], sub_shs[1:], ct)
    x = _fs_sub_rows_fwd(x.reshape(rows, sc, sr, B), rtab, sub_shs[0], ct)
    return x.reshape(rows, c, B)


def _fs_cols_inv_hier(x, inv, inv_sh, sub_tabs, sub_shs, gs):
    """Inverse mirror: sub-row GS stages first, then the sub-column
    recursion."""
    if not sub_tabs:
        return _fs_cols_inv(x, inv, inv_sh, gs)
    rows, c, B = x.shape
    rtab = sub_tabs[0]
    sr, sc = rtab.shape[-2:]
    x = _fs_sub_rows_inv(x.reshape(rows, sc, sr, B), rtab, sub_shs[0], gs)
    x = x.reshape(rows, sc, sr * B)
    x = _fs_cols_inv_hier(x, inv, inv_sh, sub_tabs[1:], sub_shs[1:], gs)
    return x.reshape(rows, c, B)


def _as_level_tuple(x):
    """Normalize a row-table argument: None / per-level tuple kept,
    single array -> 1-tuple (the historical depth-1 calling convention)."""
    if x is None or isinstance(x, tuple):
        return x
    return (x,)


def _level_shoups(row_sh, depth):
    """Per-level shoup companions; (None,) * depth for strict
    butterflies so the hier recursion can zip them with the tables."""
    if row_sh is None:
        return (None,) * depth
    return row_sh


def _fwd_stages(a, tabs, ct, *, schedule, to_transposed=False):
    """One forward transform of a (rows, n) tile.

    tabs = (fwd, fwd_shoup, row_fwd, row_fwd_shoup); the shoup entries
    are None for strict butterflies, the row entries for radix2.  Row
    entries are per-level tuples for the hierarchical schedule (a single
    array means depth 1).  With ``to_transposed`` the four-step result
    is returned as the (rows, n2, n1) transposed tile so a fused cascade
    can run the pointwise product and start the inverse without
    transposing back."""
    fwd, fwd_sh, row_fwd, row_sh = tabs
    if schedule != "four_step":
        return _radix2_fwd(a, fwd, fwd_sh, ct)
    row_fwd = _as_level_tuple(row_fwd)
    row_sh = _level_shoups(_as_level_tuple(row_sh), len(row_fwd))
    rows, n = a.shape
    n2, n1 = row_fwd[0].shape[-2:]
    x = _fs_cols_fwd_hier(
        a.reshape(rows, n1, n2), fwd, fwd_sh, row_fwd[1:], row_sh[1:], ct
    )
    xt = _fs_rows_fwd(jnp.swapaxes(x, -1, -2), row_fwd[0], row_sh[0], ct)
    if to_transposed:
        return xt
    return jnp.swapaxes(xt, -1, -2).reshape(rows, n)


def _inv_stages(a, tabs, gs, *, schedule, from_transposed=False):
    """One inverse transform; accepts the transposed tile when the
    caller (fused cascade) kept it transposed through the product."""
    inv, inv_sh, row_inv, row_sh = tabs
    if schedule != "four_step":
        return _radix2_inv(a, inv, inv_sh, gs)
    row_inv = _as_level_tuple(row_inv)
    row_sh = _level_shoups(_as_level_tuple(row_sh), len(row_inv))
    n2, n1 = row_inv[0].shape[-2:]
    rows = a.shape[0]
    if from_transposed:
        xt = a
    else:
        xt = jnp.swapaxes(a.reshape(rows, n1, n2), -1, -2)
    xt = _fs_rows_inv(xt, row_inv[0], row_sh[0], gs)
    x = _fs_cols_inv_hier(
        jnp.swapaxes(xt, -1, -2), inv, inv_sh, row_inv[1:], row_sh[1:], gs
    )
    return x.reshape(rows, n1 * n2)


def _cascade(a, b, ftabs, itabs, q, half, eps, shifts, lazy, schedule):
    """NTT(a) ⊙ NTT(b) -> iNTT entirely in VMEM.  Four-step tiles stay
    transposed across the pointwise product (2 transposes per cascade,
    not 4); lazy values are canonicalized once before the product (Shoup
    needs one canonical operand-pair) and once at exit."""
    ct, gs = _butterflies(q, half=half, eps=eps, shifts=shifts, lazy=lazy)
    tr = schedule == "four_step"
    fa = _canon(_fwd_stages(a, ftabs, ct, schedule=schedule, to_transposed=tr), q, lazy)
    fb = _canon(_fwd_stages(b, ftabs, ct, schedule=schedule, to_transposed=tr), q, lazy)
    prod = mul_mod(fa, fb, q, eps, shifts)
    out = _inv_stages(prod, itabs, gs, schedule=schedule, from_transposed=tr)
    return _canon(out, q, lazy)


# --------------------------------------------------------------------------
# kernels (shifts/schedule/lazy are static closure args; eps_ref is a
# dummy zero block when shifts is None and butterflies fall back to %)
# --------------------------------------------------------------------------


def _take(it, cond):
    return next(it) if cond else None


def _ref_or_none(ref):
    return None if ref is None else ref[...]


def _take_levels(it, cond, depth, load=True):
    """Consume one ref per hierarchy level (ORDER CONTRACT below): a
    per-level tuple when cond, else None.  ``load=False`` keeps the refs
    unread for kernels that slice per channel."""
    if not cond:
        return None
    refs = tuple(next(it) for _ in range(depth))
    return tuple(r[...] for r in refs) if load else refs


def _make_ntt_kernel(shifts, schedule, lazy, depth=1):
    four = schedule == "four_step"

    def kernel(*refs):
        it = iter(refs)
        q_ref, eps_ref, fwd_ref = next(it), next(it), next(it)
        fwd_sh = _ref_or_none(_take(it, lazy is not None))
        row_fwd = _take_levels(it, four, depth)
        row_sh = _take_levels(it, four and lazy is not None, depth)
        a_ref, o_ref = next(it), next(it)
        q = q_ref[0]
        eps = eps_ref[0] if shifts is not None else None
        ct, _ = _butterflies(q, eps=eps, shifts=shifts, lazy=lazy)
        out = _fwd_stages(
            a_ref[...], (fwd_ref[...], fwd_sh, row_fwd, row_sh), ct,
            schedule=schedule,
        )
        o_ref[...] = _canon(out, q, lazy)

    return kernel


def _make_intt_kernel(shifts, schedule, lazy, depth=1):
    four = schedule == "four_step"

    def kernel(*refs):
        it = iter(refs)
        q_ref, eps_ref, half_ref, inv_ref = next(it), next(it), next(it), next(it)
        inv_sh = _ref_or_none(_take(it, lazy is not None))
        row_inv = _take_levels(it, four, depth)
        row_sh = _take_levels(it, four and lazy is not None, depth)
        a_ref, o_ref = next(it), next(it)
        q = q_ref[0]
        eps = eps_ref[0] if shifts is not None else None
        half = half_ref[0]
        _, gs = _butterflies(q, half=half, eps=eps, shifts=shifts, lazy=lazy)
        out = _inv_stages(
            a_ref[...], (inv_ref[...], inv_sh, row_inv, row_sh), gs,
            schedule=schedule,
        )
        o_ref[...] = _canon(out, q, lazy)

    return kernel


def _make_fused_kernel(shifts, schedule, lazy, depth=1):
    four = schedule == "four_step"

    def kernel(*refs):
        it = iter(refs)
        q_ref, eps_ref, half_ref = next(it), next(it), next(it)
        fwd_ref, inv_ref = next(it), next(it)
        fwd_sh = _ref_or_none(_take(it, lazy is not None))
        inv_sh = _ref_or_none(_take(it, lazy is not None))
        row_fwd = _take_levels(it, four, depth)
        row_inv = _take_levels(it, four, depth)
        row_fsh = _take_levels(it, four and lazy is not None, depth)
        row_ish = _take_levels(it, four and lazy is not None, depth)
        a_ref, b_ref, o_ref = next(it), next(it), next(it)
        q = q_ref[0]
        eps = eps_ref[0] if shifts is not None else None
        half = half_ref[0]
        o_ref[...] = _cascade(
            a_ref[...], b_ref[...],
            (fwd_ref[...], fwd_sh, row_fwd, row_fsh),
            (inv_ref[...], inv_sh, row_inv, row_ish),
            q, half, eps, shifts, lazy, schedule,
        )

    return kernel


def _chan_tabs(ref, i):
    """Channel i's slice of a stacked (t, ...) table ref; None-safe and
    per-level for the hierarchical row-table tuples."""
    if ref is None:
        return None
    if isinstance(ref, tuple):
        return tuple(r[i] for r in ref)
    return ref[i]


def _make_fused_e2e_kernel(plan, scalars, shifts, schedule, lazy, depth=1):
    """The paper's full feed-forward datapath in ONE kernel: CRT
    pre-processing, the per-channel NTT -> ⊙ -> iNTT cascade and CRT
    post-processing, with every residue polynomial VMEM-resident.

    The channel loop is a static unroll: each iteration is one of the
    paper's t parallel specialized circuits, its moduli/Barrett/SAU
    constants baked in from the plan (``plan.dec`` + ``scalars``), its
    twiddles read from the (t, n) VMEM table blocks.  Only the segment
    tiles enter and the limb tile leaves through HBM."""
    four = schedule == "four_step"

    def kernel(*refs):
        it = iter(refs)
        fwd_ref, inv_ref = next(it), next(it)
        fwd_sh = _take(it, lazy is not None)
        inv_sh = _take(it, lazy is not None)
        row_fwd = _take_levels(it, four, depth, load=False)
        row_inv = _take_levels(it, four, depth, load=False)
        row_fsh = _take_levels(it, four and lazy is not None, depth, load=False)
        row_ish = _take_levels(it, four and lazy is not None, depth, load=False)
        star_ref, qlimb_ref, za_ref, zb_ref, o_ref = (
            next(it), next(it), next(it), next(it), next(it)
        )
        za = za_ref[...]  # (blk, n, S)
        zb = zb_ref[...]
        acc = jnp.zeros(za.shape[:-1] + (plan.L,), dtype=za.dtype)
        for i, (qi, half, eps) in enumerate(scalars):
            ch = plan.dec[i]
            # Step 1: residual computation (Alg 2, SAU circuit)
            ra = decompose_stage(za, ch, seg_count=plan.seg_count,
                                 t_prime=plan.t_prime)  # (blk, n)
            rb = decompose_stage(zb, ch, seg_count=plan.seg_count,
                                 t_prime=plan.t_prime)
            # Step 2: no-shuffle NTT cascade, product never leaves VMEM
            pi = _cascade(
                ra, rb,
                (fwd_ref[i], _chan_tabs(fwd_sh, i),
                 _chan_tabs(row_fwd, i), _chan_tabs(row_fsh, i)),
                (inv_ref[i], _chan_tabs(inv_sh, i),
                 _chan_tabs(row_inv, i), _chan_tabs(row_ish, i)),
                qi, half, eps, shifts, lazy, schedule,
            )
            # Step 3: this channel's Eq-10 contribution y_i * q_i^
            y = mul_mod(pi, int(plan.qi_tilde[i]), qi, eps, shifts)
            acc = acc + y[..., None] * star_ref[i][None, None, :]
        o_ref[...] = compose_finalize(acc, qlimb_ref[0], w=plan.w, t=plan.t)

    return kernel


def _make_fused_e2e_chgrid_kernel(plan, shifts, schedule, lazy, t, depth=1):
    """Channel-tiled variant: grid (row_blocks, t), ONE channel per grid
    step.  The per-channel SAU/Barrett/twiddle constants arrive as
    channel-indexed blocks (the data-driven decompose), the Eq-10
    contributions accumulate in the revisited output block, and the
    carry/subtract finalize runs on the last channel step.  Per-step
    VMEM is ~1/t of the unrolled kernel, so row_blk can grow."""
    four = schedule == "four_step"

    def kernel(*refs):
        it = iter(refs)
        (q_ref, eps_ref, half_ref, tilde_ref, sau_eps_ref, sau_s2_ref,
         acc_eps_ref, beta_e_ref, beta_s_ref, bc_ref) = (
            next(it) for _ in range(10)
        )
        fwd_ref, inv_ref = next(it), next(it)
        fwd_sh = _ref_or_none(_take(it, lazy is not None))
        inv_sh = _ref_or_none(_take(it, lazy is not None))
        row_fwd = _take_levels(it, four, depth)
        row_inv = _take_levels(it, four, depth)
        row_fsh = _take_levels(it, four and lazy is not None, depth)
        row_ish = _take_levels(it, four and lazy is not None, depth)
        star_ref, qlimb_ref, za_ref, zb_ref, o_ref = (
            next(it), next(it), next(it), next(it), next(it)
        )
        c = pl.program_id(1)
        qi = q_ref[0]
        eps = eps_ref[0] if shifts is not None else None
        half = half_ref[0]
        dec = functools.partial(
            decompose_stage_dyn,
            qi=qi, sau_eps=sau_eps_ref[0], sau_s2=sau_s2_ref[0],
            acc_eps=acc_eps_ref[0], beta_e=beta_e_ref[...],
            beta_s=beta_s_ref[...], block_consts=bc_ref[...],
            v=plan.v, seg_count=plan.seg_count, t_prime=plan.t_prime,
        )
        ra = dec(za_ref[...])  # (blk, n)
        rb = dec(zb_ref[...])
        pi = _cascade(
            ra, rb,
            (fwd_ref[...], fwd_sh, row_fwd, row_fsh),
            (inv_ref[...], inv_sh, row_inv, row_ish),
            qi, half, eps, shifts, lazy, schedule,
        )
        y = mul_mod(pi, tilde_ref[0], qi, eps, shifts)
        contrib = y[..., None] * star_ref[...][None, None, :]

        @pl.when(c == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += contrib

        @pl.when(c == t - 1)
        def _finalize():
            o_ref[...] = compose_finalize(
                o_ref[...], qlimb_ref[0], w=plan.w, t=plan.t
            )

    return kernel


# --------------------------------------------------------------------------
# pallas_call wrappers (grid = (channels, row_blocks))
# --------------------------------------------------------------------------


def _grid_specs(t: int, rows: int, n: int, row_blk: int):
    """Common BlockSpecs (leading channel axis squeezed with None):
    per-channel scalars, (n,) tables, (row_blk, n) data tiles."""
    scalar = pl.BlockSpec((None, 1), lambda c, r: (c, 0))
    table = pl.BlockSpec((None, n), lambda c, r: (c, 0))
    data = pl.BlockSpec((None, row_blk, n), lambda c, r: (c, r, 0))
    return scalar, table, data


def _pad_rows(x, row_blk):
    rows = x.shape[1]
    pad = (-rows) % row_blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, rows


def _eps_block(eps, qs, t):
    """(t, 1) Barrett-eps block; zeros (same dtype as qs) when unused."""
    if eps is None:
        return jnp.zeros_like(qs).reshape(t, 1)
    return eps.reshape(t, 1)


def _stage_tables(inputs, specs, lazy, four, make_table_spec, make_fs_spec,
                  shoups, rows, row_shoups):
    """Append the optional shoup/four-step table inputs + specs.

    ORDER CONTRACT (the single owner, used by every wrapper; the kernel
    factories unpack with ``_take``/``_take_levels`` in the same order):
    [shoup tables...] when lazy, then [four-step row tables...] when
    four, then [their shoup tables...] when both.  ``shoups``/``rows``/
    ``row_shoups`` are per-direction tuples (1 entry for the
    single-direction kernels, fwd+inv for the fused ones); each
    direction's row entry may itself be a per-level tuple for the
    hierarchical schedule, flattened direction-major, level-minor.
    ``make_table_spec``/``make_fs_spec`` build the grid-appropriate
    BlockSpec from the array."""
    if lazy is not None:
        for x in shoups:
            inputs.append(x)
            specs.append(make_table_spec(x))
    if four:
        for x in rows:
            for lv in (x if isinstance(x, tuple) else (x,)):
                inputs.append(lv)
                specs.append(make_fs_spec(lv))
        if lazy is not None:
            for x in row_shoups:
                for lv in (x if isinstance(x, tuple) else (x,)):
                    inputs.append(lv)
                    specs.append(make_fs_spec(lv))


# BlockSpec builders for the three grid layouts the tables ride in:
# per-channel blocks on a (channels, row_blocks) grid, full blocks on a
# (row_blocks,) grid, per-channel blocks on a (row_blocks, channels) grid.


def _chan_table_spec(x):
    return pl.BlockSpec((None, x.shape[-1]), lambda c, r: (c, 0))


def _chan_fs_spec(x):
    return pl.BlockSpec((None,) + x.shape[-2:], lambda c, r: (c, 0, 0))


def _full_table_spec(x):
    return pl.BlockSpec(x.shape, lambda r: (0,) * x.ndim)


def _chgrid_table_spec(x):
    return pl.BlockSpec((None, x.shape[-1]), lambda r, c: (c, 0))


def _chgrid_fs_spec(x):
    return pl.BlockSpec((None,) + x.shape[-2:], lambda r, c: (c, 0, 0))


@functools.partial(
    jax.jit,
    static_argnames=("shifts", "schedule", "lazy", "row_blk", "interpret"),
)
def ntt_channels_pallas(
    a, qs, fwd, eps=None, fwd_shoup=None, row_fwd=None, row_fwd_shoup=None,
    *, shifts=None, schedule: str = "radix2", lazy=None,
    row_blk: int = DEFAULT_ROWS, interpret: bool = True,
):
    """a: (t, rows, n) -> forward NTT per channel.  qs: (t,), fwd: (t, n);
    row_fwd: (t, n2, n1) twist-merged row tables (four_step only) or a
    per-level tuple of them for the hierarchical schedule; the *_shoup
    tables ride along (same structure) when lazy=(window, beta).
    ``schedule`` is a concrete string or a resolved ScheduleSpec."""
    kind = getattr(schedule, "kind", schedule)
    row_fwd = _as_level_tuple(row_fwd)
    row_fwd_shoup = _as_level_tuple(row_fwd_shoup)
    depth = len(row_fwd) if isinstance(row_fwd, tuple) else 1
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    inputs = [qs.reshape(t, 1), _eps_block(eps, qs, t), fwd]
    specs = [scalar, scalar, table]
    _stage_tables(
        inputs, specs, lazy, kind == "four_step",
        _chan_table_spec, _chan_fs_spec,
        (fwd_shoup,), (row_fwd,), (row_fwd_shoup,),
    )
    inputs.append(a)
    specs.append(data)
    out = pl.pallas_call(
        _make_ntt_kernel(shifts, kind, lazy, depth),
        grid=(t, a.shape[1] // row_blk),
        in_specs=specs,
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(*inputs)
    return out[:, :rows]


@functools.partial(
    jax.jit,
    static_argnames=("shifts", "schedule", "lazy", "row_blk", "interpret"),
)
def intt_channels_pallas(
    a, qs, half, inv, eps=None, inv_shoup=None, row_inv=None, row_inv_shoup=None,
    *, shifts=None, schedule: str = "radix2", lazy=None,
    row_blk: int = DEFAULT_ROWS, interpret: bool = True,
):
    kind = getattr(schedule, "kind", schedule)
    row_inv = _as_level_tuple(row_inv)
    row_inv_shoup = _as_level_tuple(row_inv_shoup)
    depth = len(row_inv) if isinstance(row_inv, tuple) else 1
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    inputs = [qs.reshape(t, 1), _eps_block(eps, qs, t), half.reshape(t, 1), inv]
    specs = [scalar, scalar, scalar, table]
    _stage_tables(
        inputs, specs, lazy, kind == "four_step",
        _chan_table_spec, _chan_fs_spec,
        (inv_shoup,), (row_inv,), (row_inv_shoup,),
    )
    inputs.append(a)
    specs.append(data)
    out = pl.pallas_call(
        _make_intt_kernel(shifts, kind, lazy, depth),
        grid=(t, a.shape[1] // row_blk),
        in_specs=specs,
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(*inputs)
    return out[:, :rows]


@functools.partial(
    jax.jit,
    static_argnames=("shifts", "schedule", "lazy", "row_blk", "interpret"),
)
def fused_polymul_pallas(
    a, b, qs, half, fwd, inv, eps=None, fwd_shoup=None, inv_shoup=None,
    row_fwd=None, row_inv=None, row_fwd_shoup=None, row_inv_shoup=None,
    *, shifts=None, schedule: str = "radix2", lazy=None,
    row_blk: int = DEFAULT_ROWS, interpret: bool = True,
):
    """(t, rows, n) x (t, rows, n) -> negacyclic products, fused cascade."""
    kind = getattr(schedule, "kind", schedule)
    row_fwd, row_inv = _as_level_tuple(row_fwd), _as_level_tuple(row_inv)
    row_fwd_shoup = _as_level_tuple(row_fwd_shoup)
    row_inv_shoup = _as_level_tuple(row_inv_shoup)
    depth = len(row_fwd) if isinstance(row_fwd, tuple) else 1
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    b, _ = _pad_rows(b, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    inputs = [
        qs.reshape(t, 1), _eps_block(eps, qs, t), half.reshape(t, 1), fwd, inv,
    ]
    specs = [scalar, scalar, scalar, table, table]
    _stage_tables(
        inputs, specs, lazy, kind == "four_step",
        _chan_table_spec, _chan_fs_spec,
        (fwd_shoup, inv_shoup), (row_fwd, row_inv),
        (row_fwd_shoup, row_inv_shoup),
    )
    inputs += [a, b]
    specs += [data, data]
    out = pl.pallas_call(
        _make_fused_kernel(shifts, kind, lazy, depth),
        grid=(t, a.shape[1] // row_blk),
        in_specs=specs,
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(*inputs)
    return out[:, :rows]


@functools.partial(
    jax.jit,
    static_argnames=(
        "plan", "schedule", "lazy", "channel_grid", "row_blk", "interpret",
    ),
)
def fused_e2e_polymul_pallas(
    za, zb, fwd, inv, star, q_limbs, fwd_shoup=None, inv_shoup=None,
    row_fwd=None, row_inv=None, row_fwd_shoup=None, row_inv_shoup=None,
    *, plan, schedule: str = "radix2", lazy=None,
    channel_grid: bool | None = None, row_blk: int | None = None,
    interpret: bool = True,
):
    """za, zb: (rows, n, S) base-2^v segment tiles -> (rows, n, L) limbs
    of the negacyclic products mod q: decompose -> NTT -> ⊙ -> iNTT ->
    compose inside ONE pallas_call.

    fwd/inv: (t, n) twiddle tables, star: (t, L) q_i^ limbs, q_limbs:
    (L,) — all device-resident uploads off the tables/plan.  Grid:

    * ``channel_grid=False`` — (row_blocks,): the channel loop unrolls
      inside the kernel (every channel's circuit in one grid step, the
      Eq-10 recombination done in registers).
    * ``channel_grid=True`` (default whenever t >= 2) — (row_blocks, t):
      one channel per grid step with per-channel constants as
      channel-indexed blocks; Eq-10 contributions accumulate in the
      revisited output block (index map constant in the channel axis, so
      the block stays VMEM-resident across the t inner steps — no extra
      HBM traffic) and the finalize runs on the last channel step.

    VMEM per grid step at the paper's point (n=4096, t=6, S=6, L=7,
    int64): unrolled row_blk=1 ~= 1 MiB; channel grid row_blk=4 ~= 1.5
    MiB — both << 16 MiB.
    """
    require_dec(plan)
    kind = getattr(schedule, "kind", schedule)
    row_fwd, row_inv = _as_level_tuple(row_fwd), _as_level_tuple(row_inv)
    row_fwd_shoup = _as_level_tuple(row_fwd_shoup)
    row_inv_shoup = _as_level_tuple(row_inv_shoup)
    depth = len(row_fwd) if isinstance(row_fwd, tuple) else 1
    rows, n, S = za.shape
    t, L = plan.t, plan.L
    scalars, shifts = modmath.channel_mul_constants(plan.qs)
    if channel_grid is None:
        channel_grid = t >= 2
    if row_blk is None:
        row_blk = getattr(schedule, "row_blk", 0) or (
            DEFAULT_E2E_ROWS_CHGRID if channel_grid else DEFAULT_E2E_ROWS
        )
    pad = (-rows) % row_blk
    if pad:
        zpad = ((0, pad), (0, 0), (0, 0))
        za = jnp.pad(za, zpad)
        zb = jnp.pad(zb, zpad)
    row_blocks = za.shape[0] // row_blk
    four = kind == "four_step"
    if not channel_grid:
        table = pl.BlockSpec((t, n), lambda r: (0, 0))
        data = pl.BlockSpec((row_blk, n, S), lambda r: (r, 0, 0))
        inputs = [fwd, inv]
        specs = [table, table]
        _stage_tables(
            inputs, specs, lazy, four, _full_table_spec, _full_table_spec,
            (fwd_shoup, inv_shoup), (row_fwd, row_inv),
            (row_fwd_shoup, row_inv_shoup),
        )
        inputs += [star, q_limbs.reshape(1, L), za, zb]
        specs += [
            pl.BlockSpec((t, L), lambda r: (0, 0)),
            pl.BlockSpec((1, L), lambda r: (0, 0)),
            data,
            data,
        ]
        out = pl.pallas_call(
            _make_fused_e2e_kernel(plan, scalars, shifts, kind, lazy, depth),
            grid=(row_blocks,),
            in_specs=specs,
            out_specs=pl.BlockSpec((row_blk, n, L), lambda r: (r, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((za.shape[0], n, L), za.dtype),
            interpret=interpret,
        )(*inputs)
        return out[:rows]
    # channel-tiled grid: (row_blocks, t), channel axis innermost so the
    # revisited output block accumulates in VMEM
    dec_arrs = plan_dec_arrays(plan)
    qs_d = jnp.asarray(plan.qs)
    scal = pl.BlockSpec((None, 1), lambda r, c: (c, 0))
    table = pl.BlockSpec((None, n), lambda r, c: (c, 0))
    data = pl.BlockSpec((row_blk, n, S), lambda r, c: (r, 0, 0))

    def vec_spec(x):
        return pl.BlockSpec((None, x.shape[-1]), lambda r, c: (c, 0))

    # per-channel (qi, half, eps) come from the SAME `scalars` tuple the
    # unrolled kernel bakes into its closure, so the two e2e variants
    # cannot disagree on the Barrett envelope
    eps_arr = (
        None
        if scalars[0][2] is None
        else jnp.asarray([s[2] for s in scalars])
    )
    inputs = [
        qs_d.reshape(t, 1),
        _eps_block(eps_arr, qs_d, t),
        jnp.asarray([s[1] for s in scalars]).reshape(t, 1),
        jnp.asarray(plan.qi_tilde).reshape(t, 1),
        jnp.asarray(dec_arrs["sau_eps"]).reshape(t, 1),
        jnp.asarray(dec_arrs["sau_s2"]).reshape(t, 1),
        jnp.asarray(dec_arrs["acc_eps"]).reshape(t, 1),
        jnp.asarray(dec_arrs["beta_e"]),
        jnp.asarray(dec_arrs["beta_s"]),
        jnp.asarray(dec_arrs["block_consts"]),
    ]
    specs = [scal] * 7 + [vec_spec(x) for x in inputs[7:]]
    inputs += [fwd, inv]
    specs += [table, table]
    _stage_tables(
        inputs, specs, lazy, four, _chgrid_table_spec, _chgrid_fs_spec,
        (fwd_shoup, inv_shoup), (row_fwd, row_inv),
        (row_fwd_shoup, row_inv_shoup),
    )
    inputs += [star, q_limbs.reshape(1, L), za, zb]
    specs += [
        pl.BlockSpec((None, L), lambda r, c: (c, 0)),
        pl.BlockSpec((1, L), lambda r, c: (0, 0)),
        data,
        data,
    ]
    out = pl.pallas_call(
        _make_fused_e2e_chgrid_kernel(plan, shifts, kind, lazy, t, depth),
        grid=(row_blocks, t),
        in_specs=specs,
        out_specs=pl.BlockSpec((row_blk, n, L), lambda r, c: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((za.shape[0], n, L), za.dtype),
        interpret=interpret,
    )(*inputs)
    return out[:rows]
