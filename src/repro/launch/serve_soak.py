"""Fault-injected soak of the serving engine — the ``serve-soak`` CI gate.

    PYTHONPATH=src python -m repro.launch.serve_soak --ci-smoke

Drives >= 500 mixed-config requests through an async
:class:`repro.serve.crypto_engine.PolymulEngine` while a seeded
:class:`repro.serve.faults.FaultInjector` raises, delays, and corrupts
on a schedule, then checks the engine's robustness contract as hard
gates rather than vibes:

* **Exactly-once resolution.**  Every submitted future ends DONE or
  FAILED (typed ``EngineError``), none PENDING, and the counters
  conserve: ``served + shed + failed == submitted`` with empty queue
  and zero in-flight.  (Double resolution is impossible by
  construction — a second transition raises inside the engine and
  would surface as a dispatcher-loop error here.)
* **Breaker round-trip.**  A pinned burst of raises on the
  ``pallas_fused_e2e`` bucket forces its circuit breaker open
  (degrading to ``pallas``); after the injector is quiesced and the
  cool-down elapses, a recovery phase observes the probe restore the
  original backend (``breaker_opened/recovered/probes >= 1``).
* **Corruption is detected, not survived.**  ``corrupt`` faults flip
  the low limb bit after execution — the engine sees a success.  The
  injector's log is joined against each future's ``dispatch_index``
  stamp: every corrupted-dispatch result must FAIL the
  :func:`repro.serve.faults.spot_check`, and sampled clean results
  must pass it (plus a small host-bigint-oracle subsample, independent
  of every device datapath).
* **Post-fault bit-exactness.**  Clean results are compared against
  ``api.polymul`` on the request's original plan — degraded dispatches
  included, since the fallback chain re-plans with the same n/t/v.
* **Span conservation** (with ``--span-log``): every admitted request
  leaves exactly ONE terminal span (resolved/shed/failed) in the JSONL
  log, and the span statuses agree with the engine counters — the
  ``obs-smoke`` CI gate re-audits the written log through
  ``launch/obs_report.py --check``.

A ``"serve_soak"`` record (shed rate, retries, breaker counts, p99,
seed) merges into the BENCH_ci.json artifact next to the ``"serve"``
record, along with an ``"obs"`` record (the metrics-registry dump);
``--prom-out`` additionally writes the registry in Prometheus text
format for the exporter-validity gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro import api, obs
from repro.errors import EngineError
from repro.serve.crypto_engine import PolymulEngine, PolymulFuture
from repro.serve.faults import FaultInjector, FaultRule, spot_check

# The two operating points of the soak: the paper's small preset on the
# full fused-e2e Pallas path (the degradation chain's top) and the wide
# digit-split datapath (jnp-only — no chain, exercises mixed buckets).
CONFIGS = (
    {"n": 64, "t": 3, "v": 30, "backend": "pallas_fused_e2e"},
    {"n": 64, "t": 4, "v": 45},
)


def default_rules(breaker_threshold: int) -> list[FaultRule]:
    """The soak schedule: a pinned raise burst that trips the e2e
    bucket's breaker, background transient raises/delays, and silent
    corruptions (one pinned so detection always has work to do)."""
    return [
        # A raise beats a corrupt on the same call, so the pinned
        # corruption window sits past the raise burst and spans several
        # calls — the gate needs >= 1 corruption deterministically.
        FaultRule("raise", backend="pallas_fused_e2e",
                  max_count=breaker_threshold),
        FaultRule("raise", rate=0.02, after=breaker_threshold + 10,
                  max_count=6),
        FaultRule("delay", rate=0.05, delay_s=0.005, max_count=20),
        FaultRule("corrupt", rate=1.0, after=breaker_threshold + 4,
                  until=breaker_threshold + 8, max_count=2),
        FaultRule("corrupt", rate=0.01, after=breaker_threshold + 8,
                  max_count=6),
    ]


def run_soak(*, requests: int = 520, seed: int = 0, batch_slots: int = 8,
             max_pending: int = 64, breaker_threshold: int = 2,
             breaker_cooldown_s: float = 0.25,
             oracle_samples: int = 3, clean_samples: int = 32,
             rules: list[FaultRule] | None = None,
             span_log_path: str | None = None) -> dict:
    """Run the fault-injected soak; returns the gate record (its
    ``failures`` list is empty on success).  With ``span_log_path``,
    every request is traced and the full lifecycle log is written as
    JSONL there, with span conservation audited as an extra gate."""
    rng = np.random.default_rng(seed)
    span_log = (
        obs.SpanLog(span_log_path) if span_log_path is not None else None
    )
    eng = PolymulEngine(
        batch_slots=batch_slots, max_pending=max_pending,
        max_retries=6, breaker_threshold=breaker_threshold,
        breaker_cooldown_s=breaker_cooldown_s, backoff_base_s=0.002,
        span_log=span_log,
    )
    plans = [eng.plan(**c) for c in CONFIGS]

    # The injector installs before ANY dispatch, so its call counter and
    # the engine's dispatch_index stamps advance in lock-step — the
    # corruption join below depends on that 1:1 alignment.  Compilation
    # therefore happens inside the faulted run; the soak deadlines are
    # sized to absorb it.
    inj = FaultInjector(
        rules if rules is not None else default_rules(breaker_threshold),
        seed=seed,
    )
    inj.install(eng)

    t0 = time.perf_counter()
    entries = []  # (plan, za, zb, future, doa)
    with eng:
        for i in range(requests):
            pl = plans[i % len(plans)]
            shape = (pl.n, pl.config.seg_count)
            za = rng.integers(0, 1 << pl.v, size=shape)
            zb = rng.integers(0, 1 << pl.v, size=shape)
            doa = i % 97 == 13  # sprinkle guaranteed-shed requests
            fut = eng.submit(
                pl, za, zb,
                deadline=0.0 if doa else 60.0,
                priority=int(rng.integers(0, 3)),
                timeout=60.0,
            )
            entries.append((pl, za, zb, fut, doa))
        eng.run_until_idle()

        # Recovery phase: silence every raise rule, let the cool-down
        # elapse, and give each bucket traffic so probes fire.
        inj.quiesce("raise")
        time.sleep(breaker_cooldown_s + 0.05)
        for pl in plans:
            shape = (pl.n, pl.config.seg_count)
            za = rng.integers(0, 1 << pl.v, size=shape)
            zb = rng.integers(0, 1 << pl.v, size=shape)
            entries.append((pl, za, zb, eng.submit(pl, za, zb), False))
        eng.run_until_idle()
    wall = time.perf_counter() - t0
    snap = eng.snapshot()

    failures: list[str] = []

    # -- exactly-once / conservation ----------------------------------
    pending = [e for e in entries if not e[3].done()]
    if pending:
        failures.append(f"{len(pending)} futures still PENDING after drain")
    for pl, _, _, fut, _ in entries:
        if fut.done() and fut.state == PolymulFuture.FAILED:
            exc = fut.exception()
            if not isinstance(exc, EngineError):
                failures.append(
                    f"future failed with untyped {type(exc).__name__}: {exc}"
                )
                break
    conserved = (
        snap["served"] + snap["shed"] + snap["failed"] == snap["submitted"]
        and snap["queue_depth"] == 0
        and snap["inflight"] == 0
    )
    if not conserved:
        failures.append(
            f"request conservation violated: served {snap['served']} + "
            f"shed {snap['shed']} + failed {snap['failed']} != submitted "
            f"{snap['submitted']} (queue {snap['queue_depth']}, inflight "
            f"{snap['inflight']})"
        )
    doa_ok = all(
        fut.done() and isinstance(fut.exception(), EngineError)
        for _, _, _, fut, doa in entries if doa
    )
    if not doa_ok:
        failures.append("a dead-on-arrival request was not shed typed")

    # -- breaker round-trip -------------------------------------------
    for key in ("breaker_opened", "breaker_recovered", "probes"):
        if snap[key] < 1:
            failures.append(f"expected {key} >= 1, got {snap[key]}")
    still_degraded = snap["degraded_buckets"]
    if still_degraded:
        failures.append(
            f"{still_degraded} bucket(s) still degraded after recovery "
            f"phase: {snap['bucket_backends']}"
        )

    # -- corruption detection -----------------------------------------
    corrupt_idx = inj.indices("corrupt")
    done = [e for e in entries if e[3].state == PolymulFuture.DONE]
    corrupted = [e for e in done if e[3].dispatch_index in corrupt_idx]
    clean = [e for e in done if e[3].dispatch_index not in corrupt_idx]
    if not corrupt_idx:
        failures.append("no corruption was injected — schedule too light")
    if not corrupted:
        failures.append(
            f"corruptions fired at dispatches {sorted(corrupt_idx)} but "
            f"no served future maps to them — dispatch_index join broken"
        )
    for pl, za, zb, fut, _ in corrupted:
        if spot_check(pl, za, zb, fut.result()):
            failures.append(
                f"corrupted dispatch {fut.dispatch_index} passed the "
                f"spot check — detection arm is blind"
            )
            break
    # -- post-fault bit-exactness of clean results --------------------
    sample = [clean[i] for i in
              rng.choice(len(clean), size=min(clean_samples, len(clean)),
                         replace=False)] if clean else []
    for pl, za, zb, fut, _ in sample:
        if not spot_check(pl, za, zb, fut.result()):
            failures.append(
                f"clean result (dispatch {fut.dispatch_index}, backend "
                f"chain of {api.plan_key(pl).backend}) is NOT bit-exact "
                f"vs api.polymul"
            )
            break
    for pl, za, zb, fut, _ in sample[:oracle_samples]:
        if not spot_check(pl, za, zb, fut.result(), use_oracle=True):
            failures.append(
                f"clean result (dispatch {fut.dispatch_index}) fails the "
                f"host bigint oracle"
            )
            break

    # -- span conservation (tracing enabled) --------------------------
    span_summary = None
    if span_log is not None:
        cons = obs.conservation(span_log.records)
        span_summary = {
            "spans": cons["spans"],
            "admitted": cons["admitted"],
            "by_status": cons["by_status"],
            "violations": cons["violations"],
        }
        failures.extend(cons["violations"])
        # span statuses must agree with the engine's own counters —
        # a span leak would let the log and the registry drift apart
        for status, key in (("resolved", "served"), ("shed", "shed"),
                            ("failed", "failed")):
            got = cons["by_status"].get(status, 0)
            if got != snap[key]:
                failures.append(
                    f"span log has {got} {status!r} spans but the engine "
                    f"counted {key}={snap[key]}"
                )
        if cons["admitted"] != snap["submitted"]:
            failures.append(
                f"span log has {cons['admitted']} admitted spans but "
                f"submitted={snap['submitted']}"
            )
        span_log.flush()

    record = {
        "requests": len(entries),
        "seed": seed,
        "configs": len(CONFIGS),
        "wall_s": round(wall, 3),
        "goodput_rps": round(snap["served"] / wall, 1),
        "served": snap["served"],
        "shed": snap["shed"],
        "failed": snap["failed"],
        "shed_rate": round(snap["shed"] / max(snap["submitted"], 1), 4),
        "retried": snap["retried"],
        "dispatch_failures": snap["dispatch_failures"],
        "rejected": snap["rejected"],
        "breaker_opened": snap["breaker_opened"],
        "breaker_recovered": snap["breaker_recovered"],
        "probes": snap["probes"],
        "faults": {
            "raised": len(inj.indices("raise")),
            "delayed": len(inj.indices("delay")),
            "corrupted": len(corrupt_idx),
            "corrupted_futures": len(corrupted),
        },
        "latency_p50_ms": snap["latency_p50_ms"],
        "latency_p99_ms": snap["latency_p99_ms"],
        "span_conservation": span_summary,
        "failures": failures,
    }
    return record


def merge_record(out_path: str, record: dict) -> None:
    """Merge the ``serve_soak`` record into the bench-smoke artifact
    (same discipline as benchmarks/serve_throughput.py's ``serve``),
    plus an ``obs`` record: the metrics-registry dump, so the artifact
    carries the same numbers an exporter would scrape."""
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc["serve_soak"] = record
    doc["obs"] = obs.to_json()
    doc["failures"] = doc.get("failures", []) + record["failures"]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci-smoke", action="store_true",
                    help="CI gate: 520 requests, merge BENCH record, "
                         "exit non-zero on any contract violation")
    ap.add_argument("--requests", type=int, default=520)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds traffic, priorities, AND the fault "
                         "schedule; stamped into the output record")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="JSON artifact to merge the 'serve_soak' record "
                         "into (--ci-smoke only)")
    ap.add_argument("--span-log", default=None, metavar="FILE",
                    help="trace every request into this JSONL span log "
                         "and audit span conservation as a gate")
    ap.add_argument("--prom-out", default=None, metavar="FILE",
                    help="write the metrics registry in Prometheus text "
                         "format after the run")
    args = ap.parse_args(argv)

    record = run_soak(requests=args.requests, seed=args.seed,
                      batch_slots=args.slots, span_log_path=args.span_log)
    print(json.dumps(record, indent=1))
    if args.prom_out is not None:
        with open(args.prom_out, "w") as f:
            f.write(obs.to_prometheus())
    if args.ci_smoke:
        merge_record(args.out, record)
    for msg in record["failures"]:
        print(f"[FAIL] {msg}", file=sys.stderr)
    return 1 if record["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
