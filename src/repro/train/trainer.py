"""Trainer loop: periodic + async checkpointing, crash-resume, step-time
percentile logging (straggler visibility), optional HE-secured gradient
aggregation demo hook.

1000+-node posture (see DESIGN.md §5): the loop is deterministic given
(seed, step); checkpoints are shard-layout independent; a restart builds
its mesh from the live device set (elasticity) and replays the data
stream from the restored step.  ``preemption_flush`` writes a final
checkpoint on SIGTERM.
"""
from __future__ import annotations

import signal
import time

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


class Trainer:
    def __init__(self, run: RunConfig, dc: data_mod.DataConfig, *, total_steps=1000):
        self.run = run
        self.data = data_mod.SyntheticLM(run.model, dc)
        self.adamw = opt_mod.AdamWConfig(
            lr=run.learning_rate,
            weight_decay=run.weight_decay,
            grad_clip=run.grad_clip,
            total_steps=total_steps,
        )
        self.step_fn = jax.jit(ts_mod.make_train_step(run, self.adamw))
        self.step_times: list[float] = []
        self._pending_ckpt = None
        self._stop = False

    # ---------------------------------------------------------------- state
    def init_or_restore(self, key):
        params, opt_state = ts_mod.init_state(self.run, key)
        start = 0
        last = ckpt.latest_step(self.run.checkpoint_dir)
        if last is not None:
            params, opt_state = ckpt.restore(
                self.run.checkpoint_dir, last, (params, opt_state)
            )
            start = last
        return params, opt_state, start

    # ---------------------------------------------------------------- loop
    def train(self, key, steps: int, *, log_every: int = 10):
        params, opt_state, start = self.init_or_restore(key)
        self._install_preemption_handler(lambda: (params, opt_state))
        metrics_hist = []
        for step in range(start, steps):
            if self._stop:
                break
            t0 = time.perf_counter()
            batch = jax.tree.map(jax.numpy.asarray, self.data.batch_at(step))
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            metrics = jax.tree.map(float, metrics)
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            metrics_hist.append(metrics)
            if (step + 1) % self.run.checkpoint_every == 0:
                self._checkpoint_async(step + 1, params, opt_state)
            if (step + 1) % log_every == 0:
                p50, p99 = self._percentiles()
                print(
                    f"step {step+1}: loss={metrics['loss']:.4f} "
                    f"gnorm={metrics['grad_norm']:.3f} lr={metrics['lr']:.2e} "
                    f"step_p50={p50*1e3:.0f}ms p99={p99*1e3:.0f}ms"
                )
        self._flush_ckpt()
        return params, opt_state, metrics_hist

    # ------------------------------------------------------------- plumbing
    def _checkpoint_async(self, step, params, opt_state):
        self._flush_ckpt()
        self._pending_ckpt = ckpt.save(
            self.run.checkpoint_dir,
            step,
            (params, opt_state),
            keep=self.run.keep_checkpoints,
            blocking=False,
        )

    def _flush_ckpt(self):
        if self._pending_ckpt is not None:
            self._pending_ckpt.result()
            self._pending_ckpt = None

    def _percentiles(self):
        arr = np.array(self.step_times[-200:])
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))

    def _install_preemption_handler(self, state_fn):
        def handler(signum, frame):
            self._stop = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)
