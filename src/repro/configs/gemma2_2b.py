"""gemma2-2b — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local(4096)/global alternating attention, attn softcap 50, final logit
softcap 30, tied embeddings, head_dim 256.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256000,
    head_dim=256,
    sliding_window=4096,
    local_global_alternate=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)
