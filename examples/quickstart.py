"""Quickstart: PaReNTT long polynomial modular multiplication through the
plan/execute API.

1. Correctness against the bigint schoolbook oracle, across every
   backend x schedule combination — one entry point, ``repro.polymul``.
2. Width dispatch: the SAME call serves the paper's t=6/v=30 (int64
   Pallas), t=4/v=45 (digit-split wide) and a v>46 (host bigint oracle)
   configuration.
3. The paper's operating point: n=4096, 180-bit q, t=6 RNS channels of
   v=30-bit special primes — batched through ``jax.jit(repro.polymul)``.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` (the CI fast lane) runs 1 and 2 at small n only.
"""
import argparse
import random
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro
from repro.core import polymul as pm


def check_backends(n: int, t: int, v: int) -> None:
    """Every backend/schedule pair, one code path, vs the schoolbook."""
    rng = random.Random(0)
    pl0 = repro.plan(n=n, t=t, v=v)
    a = [rng.randrange(pl0.q) for _ in range(n)]
    b = [rng.randrange(pl0.q) for _ in range(n)]
    want = pm.schoolbook_negacyclic(a, b, pl0.q)
    for backend in repro.BACKENDS:
        for schedule in ("radix2", "four_step"):
            pl = repro.plan(n=n, t=t, v=v, backend=backend, schedule=schedule)
            got = repro.polymul_ints(pl, a, b)
            assert got == want, (
                f"pipeline mismatch on backend={backend}/{schedule}!"
            )
        print(f"[ok] n={n}, q={pl0.q.bit_length()}-bit, backend={backend}: "
              "PaReNTT == schoolbook (radix2 + four_step)")


def check_width_dispatch(n: int) -> None:
    """One entry point, three datapaths — repro.plan resolves the width.
    Checked against the schoolbook oracle, which is independent of every
    datapath (including the v>46 width, which executes oracle_multiply
    itself)."""
    rng = random.Random(1)
    for t, v in ((3, 30), (4, 45), (2, 50)):
        pl = repro.plan(n=n, t=t, v=v)
        a = [rng.randrange(pl.q) for _ in range(n)]
        b = [rng.randrange(pl.q) for _ in range(n)]
        got = repro.polymul_ints(pl, a, b)
        assert got == pm.schoolbook_negacyclic(a, b, pl.q)
        print(
            f"[ok] width={pl.config.width:<6} (t={t}, v={v}, "
            f"q={pl.q.bit_length()}-bit): polymul == schoolbook oracle"
        )


def paper_operating_point() -> None:
    pl = repro.plan(n=4096, t=6, v=30)
    print(f"n=4096, t=6 special primes of 30 bits, q = {pl.q.bit_length()} bits")
    for s in pl.params.primes:
        terms = " ".join(f"{'+' if sg > 0 else '-'}2^{e}" for e, sg in s.beta_terms)
        print(f"   q_i = 2^30 - ({terms} - 1) = {hex(s.q)}")
    rng_np = np.random.default_rng(0)
    batch = 4
    S = pl.config.seg_count
    za = jnp.asarray(rng_np.integers(0, 1 << 30, size=(batch, 4096, S)))
    zb = jnp.asarray(rng_np.integers(0, 1 << 30, size=(batch, 4096, S)))
    mul = jax.jit(repro.polymul)
    out = jax.block_until_ready(mul(pl, za, zb))  # compile + run
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(mul(pl, za, zb))
    dt = (time.perf_counter() - t0) / 3 / batch
    print(
        f"[ok] batched 180-bit x 4096-coeff modular multiplication: "
        f"{dt*1e3:.1f} ms/poly on CPU (paper's FPGA: 17.7us at 240 MHz)"
    )
    print("     output limbs shape:", tuple(out.shape))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small-n correctness only (the CI fast lane)")
    args = ap.parse_args()
    # One switch (backend=) selects the datapath for the whole pipeline:
    #   "jnp"              pure-jnp reference (always available)
    #   "pallas"           per-stage Pallas kernels (product round-trips HBM)
    #   "pallas_fused"     the paper's fused NTT -> ⊙ -> iNTT cascade
    #   "pallas_fused_e2e" decompose -> cascade -> compose in ONE kernel
    #   "auto"             pallas_fused_e2e on TPU, jnp elsewhere
    # and schedule= selects the NTT stage schedule ("auto" -> four_step
    # for n >= 256, the lane-aligned (n1, 128) tile schedule).
    check_backends(n=64 if args.smoke else 256, t=3, v=30)
    check_width_dispatch(n=32 if args.smoke else 64)
    if not args.smoke:
        paper_operating_point()


if __name__ == "__main__":
    main()
