"""llama4-maverick-400b-a17b — 48L d_model=5120 40H (GQA kv=8) vocab=202048,
MoE 128 experts top-1 with shared expert, interleaved dense/MoE layers
(moe_every=2, dense layers use 2x d_ff).  Early-fusion multimodal backbone;
text path modeled.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    n_experts=128,
    top_k=1,
    moe_shared_expert=True,
    moe_every=2,
    rope_theta=500_000.0,
)
