"""Per-stage device profiling: a compiled-mode stage-timing harness
that reports MEASURED stage shares beside the static model predictions
(:func:`repro.kernels.ops.hbm_traffic_model`,
:func:`repro.kernels.ops.transform_cost_model`), with model-vs-measured
drift exposed as registry gauges.

The pipeline has three stage boundaries the api layer can dispatch
independently (``decompose -> cascade -> compose``, where *cascade* is
the no-shuffle NTT -> ⊙ -> iNTT datapath).  Each stage is jitted with
the plan as a pytree argument and timed with ``block_until_ready``
medians, alongside the full fused :func:`repro.api.execute` — so the
report also shows what fusion buys (``stage_sum_s`` vs ``e2e_s``).

Predicted shares come from an explicit per-stage attribution of the
same byte counts :func:`hbm_traffic_model` totals.  Boundary tensors
are attributed to BOTH touching stages (the decompose output is a
decompose write and a cascade read), which is exactly how the model's
total is built, so :func:`predicted_stage_bytes` cross-checks that its
stages sum to the model's ``hbm_bytes`` and raises if a model change
breaks the attribution.

HBM bytes predict time shares only to the extent the pipeline is
memory-bound — true for the Pallas datapaths on TPU, loose for the
interpret/jnp paths on CPU.  That looseness is the point: the drift
gauges (``repro_stage_share_drift``) make "the model says X, the device
says Y" a queryable number instead of a hunch, which is the measurement
substrate the ROADMAP's overlap/TPU-validation items need.

Registry series written per run (labels ``{stage, backend}``)::

    repro_stage_seconds                 measured median stage latency
    repro_stage_share_measured          stage / sum-of-stages
    repro_stage_share_predicted         byte-attribution share
    repro_stage_share_drift             |measured - predicted|
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro import api
from repro.kernels import ops as ops_mod
from repro.obs.metrics import MetricsRegistry, registry as default_registry

__all__ = [
    "STAGES",
    "predicted_stage_bytes",
    "stage_timings",
]

STAGES = ("decompose", "cascade", "compose")


def predicted_stage_bytes(pl: api.Plan, rows: int) -> dict[str, int]:
    """Per-stage HBM byte attribution for ``rows`` polynomials on the
    plan's backend, consistent with ``hbm_traffic_model`` by
    construction: boundary tensors count for both touching stages, and
    the stage sum is asserted equal to the model's ``hbm_bytes``."""
    cfg = api.plan_key(pl)
    if cfg.width != "int64":
        raise ValueError(
            f"predicted_stage_bytes: HBM model covers the int64 kernel "
            f"datapaths only, plan width is {cfg.width!r}"
        )
    params = pl.params
    model = ops_mod.hbm_traffic_model(
        params, rows, backend=cfg.backend, schedule=cfg.schedule
    )
    t = params.t
    B = 8
    seg = rows * params.n * params.plan.seg_count * B
    res = t * rows * params.n * B
    limb = rows * params.n * params.plan.L * B
    if cfg.backend == "jnp":
        # unfused stage kernels: NTT x2 (2res/2res), ⊙ (2res/res),
        # iNTT (res/res) -> 9 residue-tensor crossings in the cascade
        stages = {
            "decompose": 2 * seg + 2 * res,
            "cascade": 9 * res,
            "compose": res + limb,
        }
    elif cfg.backend == "pallas":
        stages = {
            "decompose": 2 * t * seg + 2 * res,
            "cascade": 9 * res,
            "compose": res + limb,
        }
    else:
        # pallas_fused / pallas_fused_e2e: the cascade is one kernel
        # (2res in / res out).  For e2e even the decompose/compose
        # boundaries vanish at dispatch time; the attribution below is
        # the fused-stage view the stage timer can actually measure,
        # so predictions and measurements describe the same dispatch
        # (hence pallas_fused's model total, asserted against it).
        stages = {
            "decompose": 2 * t * seg + 2 * res,
            "cascade": 3 * res,
            "compose": res + limb,
        }
        if cfg.backend == "pallas_fused_e2e":
            model = ops_mod.hbm_traffic_model(
                params, rows, backend="pallas_fused", schedule=cfg.schedule
            )
    if sum(stages.values()) != model["hbm_bytes"]:
        raise AssertionError(
            f"stage byte attribution ({sum(stages.values())}) != "
            f"hbm_traffic_model total ({model['hbm_bytes']}) for "
            f"backend {cfg.backend!r} — attribution out of sync"
        )
    return stages


def _time_compiled(fn: Callable[[], Any], iters: int, warmup: int) -> float:
    """Median wall seconds of ``fn`` (must block on device completion)."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def stage_timings(
    pl: api.Plan,
    *,
    batch: int = 4,
    iters: int = 10,
    warmup: int = 2,
    seed: int = 0,
    registry: MetricsRegistry | None = None,
) -> dict[str, Any]:
    """Measure compiled per-stage latency for a plan and report it
    beside the static model predictions.

    Returns one JSON-ready record (merged into ``BENCH_ci.json`` by the
    obs harness) and writes the four ``repro_stage_*`` gauge families to
    ``registry`` (default: the process registry)."""
    reg = registry if registry is not None else default_registry()
    cfg = api.plan_key(pl)
    rng = np.random.default_rng(seed)
    shape = (batch, pl.params.n, cfg.seg_count)
    za = jax.numpy.asarray(
        rng.integers(0, 1 << cfg.v, size=shape, dtype=np.int64)
    )
    zb = jax.numpy.asarray(
        rng.integers(0, 1 << cfg.v, size=shape, dtype=np.int64)
    )

    dec = jax.jit(api.decompose)
    cas = jax.jit(api.negacyclic_mul)
    com = jax.jit(api.compose)
    ra = dec(pl, za).block_until_ready()
    rb = dec(pl, zb).block_until_ready()
    rp = cas(pl, ra, rb).block_until_ready()

    measured = {
        "decompose": _time_compiled(
            lambda: dec(pl, za).block_until_ready(), iters, warmup
        ),
        "cascade": _time_compiled(
            lambda: cas(pl, ra, rb).block_until_ready(), iters, warmup
        ),
        "compose": _time_compiled(
            lambda: com(pl, rp).block_until_ready(), iters, warmup
        ),
    }
    e2e = _time_compiled(
        lambda: api.execute(pl, za, zb).block_until_ready(), iters, warmup
    )

    stage_sum = sum(measured.values())
    bytes_by_stage = predicted_stage_bytes(pl, batch)
    byte_sum = sum(bytes_by_stage.values())

    g_sec = reg.gauge(
        "repro_stage_seconds",
        "measured median per-stage latency (compiled, batch input)",
        ("stage", "backend"),
    )
    g_meas = reg.gauge(
        "repro_stage_share_measured",
        "measured stage share of sum-of-stages time",
        ("stage", "backend"),
    )
    g_pred = reg.gauge(
        "repro_stage_share_predicted",
        "hbm_traffic_model byte-attribution stage share",
        ("stage", "backend"),
    )
    g_drift = reg.gauge(
        "repro_stage_share_drift",
        "abs(measured - predicted) stage share: model-vs-device drift",
        ("stage", "backend"),
    )

    stages_out: dict[str, Any] = {}
    for stage in STAGES:
        m_share = measured[stage] / stage_sum if stage_sum else 0.0
        p_share = bytes_by_stage[stage] / byte_sum if byte_sum else 0.0
        drift = abs(m_share - p_share)
        lbl = dict(stage=stage, backend=cfg.backend)
        g_sec.labels(**lbl).set(measured[stage])
        g_meas.labels(**lbl).set(m_share)
        g_pred.labels(**lbl).set(p_share)
        g_drift.labels(**lbl).set(drift)
        stages_out[stage] = {
            "seconds": measured[stage],
            "share_measured": m_share,
            "share_predicted": p_share,
            "drift": drift,
            "hbm_bytes_predicted": bytes_by_stage[stage],
        }

    tc = ops_mod.transform_cost_model(pl.params, schedule=cfg.schedule)
    return {
        "n": pl.params.n,
        "t": pl.params.t,
        "v": cfg.v,
        "backend": cfg.backend,
        "schedule": str(cfg.schedule),
        "batch": batch,
        "iters": iters,
        "seed": seed,
        "stages": stages_out,
        "stage_sum_s": stage_sum,
        "e2e_s": e2e,
        "fusion_speedup": (stage_sum / e2e) if e2e > 0 else None,
        "max_drift": max(s["drift"] for s in stages_out.values()),
        "transform_cost_model": {
            k: tc[k]
            for k in ("sublane_stages", "reduction_ops", "vmem_transposes")
            if k in tc
        },
    }
