"""Paper Fig 17 + Eq 11-13: latency of the 2-parallel NTT-based multiplier
with vs without the shuffling circuit, plus the clock-level cascade
simulation (buffer occupancy) and the JAX-level analogue: wall-clock of
the fused no-permute cascade vs an explicitly shuffled one.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntt as ntt_mod
from repro.core import schedule as sched


def _timeit(fn, *args, iters=5):
    warm = fn(*args)
    warm[0].block_until_ready() if isinstance(warm, tuple) else jax.block_until_ready(warm)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    out = []
    for n in (1024, 4096):
        lat = sched.latency_cycles(n)
        lat_sh = sched.latency_cycles(n, with_shuffle=True)
        out.append(
            (
                f"fig17_latency_model_n{n}",
                0.0,
                f"no_shuffle={lat}cyc shuffle={lat_sh}cyc "
                f"increase={100*(lat_sh-lat)/lat:.1f}% bpp={sched.bpp_cycles(n)}",
            )
        )
        sim0 = sched.simulate_cascade(n, bit_reversed_intt=True)
        sim1 = sched.simulate_cascade(n, bit_reversed_intt=False)
        out.append(
            (
                f"fig17_cascade_sim_n{n}",
                0.0,
                f"bitrev_folding_buffer={sim0.max_buffer_pairs} "
                f"same_folding_buffer={sim1.max_buffer_pairs} (paper DSD=n/4={n//4})",
            )
        )
    # JAX-level: fused (no permute) vs explicit-bit-reverse cascade
    n, q = 4096, 0x3FDE0001
    tb = ntt_mod.make_tables(q, n)
    brv = ntt_mod.bit_reverse_indices(n)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, q, size=(8, n)))
    b = jnp.asarray(rng.integers(0, q, size=(8, n)))

    @jax.jit
    def fused(a, b):
        return ntt_mod.negacyclic_mul(a, b, tb)

    @jax.jit
    def shuffled(a, b):
        fa = ntt_mod.ntt(a, tb)[:, brv]  # materialized reorder, then
        fb = ntt_mod.ntt(b, tb)[:, brv]  # un-reorder before iNTT
        prod = ntt_mod.mul_mod(fa, fb, q)
        return ntt_mod.intt(prod[:, np.argsort(brv)], tb)

    us_f = _timeit(fused, a, b)
    us_s = _timeit(shuffled, a, b)
    assert np.array_equal(np.asarray(fused(a, b)), np.asarray(shuffled(a, b)))
    out.append(
        (
            "fig17_jax_cascade_no_permute",
            us_f,
            f"vs_shuffled={us_s:.0f}us speedup={us_s/us_f:.2f}x (batch=8, n=4096)",
        )
    )
    return out
