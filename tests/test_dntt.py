"""Four-step distributed NWC NTT: correctness vs schoolbook, factorization
invariance, roundtrip, and consistency with the single-step transform."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dntt, ntt as ntt_mod
from repro.core import polymul as pm

Q = 0x3FDE0001  # 30-bit special prime, 2*4096 | q-1


class TestFourStep:
    @pytest.mark.parametrize(
        "n,n1",
        [
            (64, 8),
            (256, 16),
            pytest.param(1024, 32, marks=pytest.mark.slow),
            pytest.param(4096, 64, marks=pytest.mark.slow),
        ],
    )
    def test_negacyclic_mul_matches_schoolbook(self, n, n1):
        t = dntt.make_fourstep_tables(Q, n, n1)
        rng = np.random.default_rng(n)
        a = rng.integers(0, Q, size=n)
        b = rng.integers(0, Q, size=n)
        got = dntt.negacyclic_mul_fourstep(jnp.asarray(a), jnp.asarray(b), t)
        want = pm.schoolbook_negacyclic(a.tolist(), b.tolist(), Q)
        assert np.asarray(got).tolist() == want

    @pytest.mark.parametrize(
        "n1",
        [pytest.param(4, marks=pytest.mark.slow), 16, 64, 256],
    )
    def test_factorization_invariance(self, n1):
        n = 1024
        rng = np.random.default_rng(n1)
        a = rng.integers(0, Q, size=n)
        b = rng.integers(0, Q, size=n)
        t = dntt.make_fourstep_tables(Q, n, n1)
        got = np.asarray(
            dntt.negacyclic_mul_fourstep(jnp.asarray(a), jnp.asarray(b), t)
        )
        tb = ntt_mod.make_tables(Q, n)
        want = np.asarray(
            ntt_mod.negacyclic_mul(jnp.asarray(a), jnp.asarray(b), tb)
        )
        assert np.array_equal(got, want)

    def test_roundtrip(self):
        n, n1 = 512, 16
        t = dntt.make_fourstep_tables(Q, n, n1)
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(0, Q, size=(3, n)))
        back = dntt.fourstep_intt(dntt.fourstep_ntt(a, t), t)
        assert np.array_equal(np.asarray(back), np.asarray(a))

    def test_spectrum_is_permutation_of_single_step(self):
        """Same multiset of spectral values as the 1-step NWC transform."""
        n, n1 = 256, 16
        t = dntt.make_fourstep_tables(Q, n, n1)
        tb = ntt_mod.make_tables(Q, n)
        rng = np.random.default_rng(1)
        a = rng.integers(0, Q, size=n)
        f4 = np.sort(np.asarray(dntt.fourstep_ntt(jnp.asarray(a), t)))
        f1 = np.sort(np.asarray(ntt_mod.ntt(jnp.asarray(a), tb)))
        assert np.array_equal(f4, f1)

    def test_batched(self):
        n, n1 = 128, 8
        t = dntt.make_fourstep_tables(Q, n, n1)
        rng = np.random.default_rng(2)
        a = rng.integers(0, Q, size=(2, 3, n))
        b = rng.integers(0, Q, size=(2, 3, n))
        got = np.asarray(
            dntt.negacyclic_mul_fourstep(jnp.asarray(a), jnp.asarray(b), t)
        )
        for i in range(2):
            for j in range(3):
                want = pm.schoolbook_negacyclic(
                    a[i, j].tolist(), b[i, j].tolist(), Q
                )
                assert got[i, j].tolist() == want

    def test_sharded_constrain_single_device(self):
        """The shard-constrained path is numerically identical (1-dev mesh)."""
        import jax
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        n, n1 = 256, 16
        t = dntt.make_fourstep_tables(Q, n, n1)
        rng = np.random.default_rng(3)
        a = rng.integers(0, Q, size=n)
        b = rng.integers(0, Q, size=n)
        with mesh:
            cons = dntt.make_shard_constrain(mesh)
            got = dntt.negacyclic_mul_fourstep(
                jnp.asarray(a), jnp.asarray(b), t, cons
            )
        want = pm.schoolbook_negacyclic(a.tolist(), b.tolist(), Q)
        assert np.asarray(got).tolist() == want
