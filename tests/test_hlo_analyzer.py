"""launch/hlo_analyzer.py against REAL lowered artifacts: the optimized
HLO text of AOT-compiled programs (``jax.jit(...).lower(...).compile()``
— a genuine XLA:CPU compile; interpret-mode Pallas inlines kernel bodies
into plain HLO so the whole datapath is visible), plus synthetic HLO for
the shapes CPU lowering never emits (``custom-call``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.launch import hlo_analyzer


@pytest.fixture(scope="module")
def polymul_hlo():
    """Optimized HLO of the jitted public polymul on the small preset."""
    pl = repro.plan(n=64, t=3, v=30, backend="jnp")
    rng = np.random.default_rng(0)
    shape = (2, 64, pl.config.seg_count)
    za = jnp.asarray(rng.integers(0, 1 << 30, size=shape))
    zb = jnp.asarray(rng.integers(0, 1 << 30, size=shape))
    compiled = jax.jit(repro.polymul).lower(pl, za, zb).compile()
    return compiled.as_text(), (za, zb), compiled(pl, za, zb)


class TestRealArtifact:
    def test_parses_entry(self, polymul_hlo):
        text, _, _ = polymul_hlo
        comps = hlo_analyzer.parse_computations(text)
        assert "__entry__" in comps
        assert len(comps["__entry__"].instrs) > 0

    def test_hbm_bytes_lower_bound(self, polymul_hlo):
        """The byte walk must at least account for the program's own
        operands and result crossing HBM once each."""
        text, (za, zb), out = polymul_hlo
        floor = za.nbytes + zb.nbytes + np.asarray(out).nbytes
        got = hlo_analyzer.analyze(text)["hbm_bytes"]
        assert got >= floor

    def test_flops_zero_for_integer_program(self, polymul_hlo):
        """The flops counter counts dot ops only; the int64 NTT datapath
        has none, so the cost model leans on hbm_bytes (regression guard
        for tune/costcheck assumptions)."""
        text, _, _ = polymul_hlo
        assert hlo_analyzer.analyze(text)["flops"] == 0.0

    def test_collectives_key_set(self, polymul_hlo):
        text, _, _ = polymul_hlo
        coll = hlo_analyzer.analyze(text)["collectives"]
        assert set(coll) == {
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute", "count", "total",
        }
        assert coll["count"] == 0  # single-device program

    def test_custom_calls_empty_on_cpu(self, polymul_hlo):
        """CPU interpret mode inlines Pallas bodies — no opaque call
        boundary survives to the optimized HLO."""
        text, _, _ = polymul_hlo
        cc = hlo_analyzer.analyze(text)["custom_calls"]
        assert cc["count"] == 0
        assert cc["targets"] == {}


class TestLoopTripCount:
    def test_fori_loop_trip_count(self):
        """A lowered fori_loop keeps its while op; the analyzer recovers
        the static trip count from the condition compare."""

        def f(x):
            return jax.lax.fori_loop(0, 5, lambda i, acc: jnp.dot(acc, x), x)

        x = jnp.ones((8, 8), jnp.float32)
        text = jax.jit(f).lower(x).compile().as_text()
        comps = hlo_analyzer.parse_computations(text)
        whiles = [i for i in comps["__entry__"].instrs if i.op == "while"]
        assert len(whiles) == 1
        cond = hlo_analyzer._called(whiles[0].line, "condition")
        assert hlo_analyzer.trip_count(comps, whiles[0].line, cond or "") == 5

    def test_loop_body_flops_scaled(self):
        def f(x):
            return jax.lax.fori_loop(0, 5, lambda i, acc: jnp.dot(acc, x), x)

        x = jnp.ones((8, 8), jnp.float32)
        text = jax.jit(f).lower(x).compile().as_text()
        # one 8x8x8 dot per iteration, five iterations
        assert hlo_analyzer.analyze(text)["flops"] == 5 * (2 * 8 * 8 * 8)


SYNTHETIC_CUSTOM_CALL = """\
HloModule m

ENTRY %main (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %cc1 = f32[128,128]{1,0} custom-call(%p0), custom_call_target="tpu_custom_call"
  ROOT %cc2 = f32[128,128]{1,0} custom-call(%cc1, %p0), custom_call_target="tpu_custom_call"
}
"""


class TestCustomCalls:
    """Pallas kernels only survive as ``custom-call`` on real
    accelerators (Mosaic/Triton), so the attribution is pinned with
    synthetic HLO in the accelerator shape."""

    def test_target_attribution(self):
        cc = hlo_analyzer.analyze(SYNTHETIC_CUSTOM_CALL)["custom_calls"]
        assert cc["count"] == 2
        assert set(cc["targets"]) == {"tpu_custom_call"}
        rec = cc["targets"]["tpu_custom_call"]
        tile = 128 * 128 * 4
        assert rec["count"] == 2
        assert rec["operand_bytes"] == 3 * tile  # 1 operand + 2 operands
        assert rec["result_bytes"] == 2 * tile
        assert cc["operand_bytes"] == rec["operand_bytes"]
        assert cc["result_bytes"] == rec["result_bytes"]
