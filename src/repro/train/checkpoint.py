"""Fault-tolerant checkpointing: atomic per-host shard files, manifest,
latest-step discovery, async writes, retention GC.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json               {"step": 123, "hosts": N, "complete": true}
        shard_h000.npz              flat {index -> array} for this host
Writes go to ``step_..._tmp`` then os.replace -> crash-safe; readers only
trust directories whose manifest says complete.  Arrays are saved with
their *global* shape on host 0 in this single-host container; the
multi-host variant saves each host's addressable shards (index-annotated)
and reassembles on load — layout is shard-count independent, so restarts
may use a different mesh (elasticity).
"""
from __future__ import annotations

import json
import os
import shutil
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, *, keep: int = 3, blocking: bool = True):
    """Atomic checkpoint write.  Returns a future if blocking=False."""
    leaves, _ = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}

    def _write():
        final = os.path.join(path, f"step_{step:09d}")
        tmp = final + "_tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_h000.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "hosts": 1, "complete": True}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(path, keep)
        return final

    if blocking:
        return _write()
    return _EXEC.submit(_write)


_EXEC = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def _gc(path: str, keep: int):
    steps = sorted(list_steps(path))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:09d}"), ignore_errors=True)


def list_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if not d.startswith("step_") or d.endswith("_tmp"):
            continue
        man = os.path.join(path, d, "manifest.json")
        try:
            with open(man) as f:
                meta = json.load(f)
            if meta.get("complete"):
                out.append(int(meta["step"]))
        except (OSError, ValueError, KeyError):
            continue  # incomplete/corrupt checkpoint: ignore (crash-safe)
    return sorted(out)


def latest_step(path: str) -> int | None:
    steps = list_steps(path)
    return steps[-1] if steps else None


def restore(path: str, step: int, tree_like):
    """Restore into the structure (and shardings) of ``tree_like``."""
    leaves, treedef = _flatten(tree_like)
    fn = os.path.join(path, f"step_{step:09d}", "shard_h000.npz")
    with np.load(fn) as data:
        new_leaves = []
        for i, ref in enumerate(leaves):
            arr = data[f"a{i}"]
            assert arr.shape == ref.shape, (i, arr.shape, ref.shape)
            new_leaves.append(
                jax.device_put(arr.astype(ref.dtype), getattr(ref, "sharding", None))
            )
    return jax.tree.unflatten(treedef, new_leaves)
