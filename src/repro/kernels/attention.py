"""Pallas flash-attention (forward) kernel: online-softmax blocked
attention — Q/K/V/O are the only HBM traffic; the O(S^2) score/probs
tensors live exclusively in VMEM tiles.

Supports: GQA (q-head groups per kv head), causal masking, sliding
window, attention-logit softcap (gemma2), arbitrary Sq != Skv (decode /
chunked prefill).

Grid: (batch, q_heads, Sq / BLK_Q).  Each step loads a (BLK_Q, D) query
tile into VMEM and streams (BLK_K, D) key/value tiles with a fori_loop of
dynamic slices, carrying the running max / normalizer / accumulator.

Validated in interpret mode against a pure-jnp reference attention for a
sweep of shapes (tests/test_kernels_attention.py).  On-TPU HBM traffic
per layer = (Sq*H*D + 2*Skv*Hk*D) * ceil(Sq/BLK_Q reuse) + Sq*H*D output —
this analytic figure is what §Perf uses (interpret-mode HLO inlines the
kernel, so the dry-run analyzer cannot see VMEM residency).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window, softcap,
            blk_k, q_offset_base, skv_true):
    """q_ref: (BLK_Q, D); k_ref/v_ref: (Skv, D); o_ref: (BLK_Q, D)."""
    blk_q, d = q_ref.shape
    skv = k_ref.shape[0]
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = q_offset_base + qi * blk_q + jax.lax.iota(jnp.int32, blk_q)

    n_kv = skv // blk_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], j * blk_k, blk_k).astype(
            jnp.float32
        )
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], j * blk_k, blk_k).astype(
            jnp.float32
        )
        s = q @ k.T  # (BLK_Q, BLK_K)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = j * blk_k + jax.lax.iota(jnp.int32, blk_k)
        mask = k_pos[None, :] < skv_true  # exclude Skv padding
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (k_pos[None, :] > q_pos[:, None] - window) | (window == 0)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc0 = jnp.zeros((blk_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "q_offset", "blk_q", "blk_k", "interpret",
    ),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window=None,  # None/0 = global; int/traced = sliding window
    softcap: float = 0.0,
    q_offset=0,  # absolute position of q[0] (decode: cache fill level)
    blk_q: int = DEFAULT_BLK_Q,
    blk_k: int = DEFAULT_BLK_K,
    interpret: bool = True,
):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hk, D) -> (B, Sq, H, D).

    H must be a multiple of Hk (GQA).  Sq/Skv are padded to the block
    sizes internally.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    g = H // Hk
    scale = float(1.0 / np.sqrt(D))  # python float: weak-typed (x64 safe)
    pad_q = (-Sq) % blk_q
    pad_k = (-Skv) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    # transpose to (B, H, S, D) for clean per-head blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    # window must be STATIC (None or int): a traced scalar would be a
    # captured constant inside the kernel.  Per-layer traced windows
    # (gemma2 alternation under scan) use the reference path; grouping the
    # scan by parity lifts them to static (see DESIGN §6).
    window_static = int(window) if window else None

    kern = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window_static,
        softcap=softcap,
        blk_k=blk_k,
        q_offset_base=q_offset,
        skv_true=Skv,
    )
    out = pl.pallas_call(
        kern,
        grid=(B, H, Sq_p // blk_q),
        in_specs=[
            pl.BlockSpec((None, None, blk_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, Skv_p, D), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((None, None, Skv_p, D), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, blk_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :Sq]
    return out


def hbm_bytes_per_call(B, Sq, Skv, H, Hk, D, *, blk_q=1024, itemsize=2):
    """Analytic on-TPU HBM traffic of a production variant of this kernel
    (for §Perf accounting): Q and O touched once; K/V streamed once per
    q-block with the whole GQA group processed together (a (blk_q, G, D)
    query tile is ~2 MB — VMEM-comfortable), so no H/Hk re-read factor.

    Compare against the materialized path: the (B, H, Sq, Skv) f32 score
    tensor alone is written once and read twice (softmax, PV)."""
    q_bytes = B * Sq * H * D * itemsize
    o_bytes = q_bytes
    kv_reuse = -(-Sq // blk_q)  # K/V re-read once per q-block
    kv_bytes = 2 * B * Skv * Hk * D * itemsize * kv_reuse
    return q_bytes + o_bytes + kv_bytes
