"""Wide-modulus (v=45) arithmetic: digit-split mul_mod vs Python bigints,
wide NTT vs schoolbook, and the paper's full t=4/v=45 multiplier."""
import random

import numpy as np
import pytest

import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to per-test skips, not errors
    from _hypothesis_fallback import given, settings, st

import repro
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.core import primes as primes_mod
from repro.core import wide
from repro.core import ntt as ntt_mod


@pytest.fixture(scope="module")
def spec45():
    p = primes_mod.default_prime_set(64, 4, 45)[0]
    return wide.from_special(p)


class TestWideMulMod:
    @given(st.integers(0, 2**45), st.integers(0, 2**45))
    @settings(max_examples=200, deadline=None)
    def test_matches_bigint(self, a, b):
        p = primes_mod.default_prime_set(64, 4, 45)[0]
        spec = wide.from_special(p)
        a %= spec.q
        b %= spec.q
        got = int(wide.mul_mod(jnp.int64(a), jnp.int64(b), spec))
        assert got == (a * b) % spec.q

    def test_adversarial_values(self, spec45):
        q = spec45.q
        vals = [0, 1, 2, q - 1, q - 2, q // 2, (1 << 45) % q, (1 << 23) - 1,
                (1 << 23), (1 << 44) + 12345]
        for a in vals:
            for b in vals:
                got = int(wide.mul_mod(jnp.int64(a % q), jnp.int64(b % q), spec45))
                assert got == ((a % q) * (b % q)) % q, (a, b)

    def test_vectorized(self, spec45):
        rng = np.random.default_rng(0)
        a = rng.integers(0, spec45.q, size=256)
        b = rng.integers(0, spec45.q, size=256)
        got = np.asarray(wide.mul_mod(jnp.asarray(a), jnp.asarray(b), spec45))
        want = (a.astype(object) * b.astype(object)) % spec45.q
        assert got.astype(object).tolist() == want.tolist()

    def test_all_four_primes(self):
        for p in primes_mod.default_prime_set(64, 4, 45):
            spec = wide.from_special(p)
            rng = np.random.default_rng(p.q & 0xFFFF)
            a = rng.integers(0, spec.q, size=64)
            b = rng.integers(0, spec.q, size=64)
            got = np.asarray(wide.mul_mod(jnp.asarray(a), jnp.asarray(b), spec))
            want = (a.astype(object) * b.astype(object)) % spec.q
            assert got.astype(object).tolist() == want.tolist()


class TestWideNtt:
    @pytest.mark.parametrize("n", [16, 64, 256])
    def test_negacyclic_matches_schoolbook(self, n):
        p = primes_mod.default_prime_set(n, 4, 45)[0]
        spec = wide.from_special(p)
        tb = ntt_mod.make_tables(spec.q, n)
        rng = np.random.default_rng(n)
        a = rng.integers(0, spec.q, size=n)
        b = rng.integers(0, spec.q, size=n)
        got = wide.negacyclic_mul(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(tb.fwd), jnp.asarray(tb.inv), spec
        )
        want = pm.schoolbook_negacyclic(a.tolist(), b.tolist(), spec.q)
        assert np.asarray(got).tolist() == want

    def test_roundtrip(self):
        n = 128
        p = primes_mod.default_prime_set(n, 4, 45)[0]
        spec = wide.from_special(p)
        tb = ntt_mod.make_tables(spec.q, n)
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.integers(0, spec.q, size=(3, n)))
        back = wide.intt_raw(
            wide.ntt_raw(a, jnp.asarray(tb.fwd), spec), jnp.asarray(tb.inv), spec
        )
        assert np.array_equal(np.asarray(back), np.asarray(a))


class TestWideMultiplier:
    @pytest.mark.slow  # wide digit-split pipeline at n=64, heavy host oracle
    def test_t4_v45_full_pipeline(self):
        """The paper's t=4, v=45, 180-bit configuration — in-JAX jit path."""
        pl = repro.plan(n=64, t=4, v=45)
        p = pl.params
        assert p.q.bit_length() == 180
        rng = random.Random(4)
        a = [rng.randrange(p.q) for _ in range(64)]
        b = [rng.randrange(p.q) for _ in range(64)]
        got = repro.polymul_ints(pl, a, b)
        want = pm.schoolbook_negacyclic(a, b, p.q)
        assert got == want

    @pytest.mark.slow
    def test_matches_oracle(self):
        pl = repro.plan(n=32, t=4, v=45)
        p = pl.params
        rng = random.Random(5)
        a = [rng.randrange(p.q) for _ in range(32)]
        b = [rng.randrange(p.q) for _ in range(32)]
        assert repro.polymul_ints(pl, a, b) == pm.oracle_multiply(a, b, p)
