"""Backend-dispatch layer: ONE switch selects the datapath for the whole
stack (``repro.plan``/``repro.polymul``, the BFV layer, benchmarks,
examples).

Backends
--------
* ``"jnp"``          — pure-jnp reference datapath (vmapped channel NTTs,
  SAU/Barrett RNS pre/post).  Always available; the oracle the kernels
  are validated against.
* ``"pallas"``       — per-stage Pallas kernels: NTT(a), NTT(b), the
  pointwise product and the iNTT are separate ``pallas_call``s, so the
  NTT-domain product round-trips HBM between stages (the Fig 11(a)-style
  baseline for the fusion win).
* ``"pallas_fused"`` — the paper's contribution-1 datapath: the whole
  NTT -> ⊙ -> iNTT cascade runs inside one kernel and the NTT-domain
  product never leaves VMEM; decompose/compose are still separate
  kernels.
* ``"pallas_fused_e2e"`` — the paper's complete feed-forward datapath
  (Fig 10): decompose -> cascade -> compose in ONE kernel via
  :func:`fused_polymul_e2e`; residue polynomials never exist in HBM.
  Stage-level entry points (``ntt_forward``, ``rns_decompose``, ...)
  have no single-kernel equivalent, so under this backend they degrade
  to the closest kernel datapath (the cascade to ``pallas_fused``,
  everything else to ``pallas``) — only the e2e product gets the full
  fusion.

The backend is threaded through :class:`repro.core.params.ParenttParams`
(``make_params(..., backend=...)``) and may be overridden per call with
the ``backend=`` keyword.  The legacy ``use_pallas=`` bool is kept as a
deprecated alias (True -> the Pallas path, False -> ``"jnp"``).

The public front door, :mod:`repro.api`, resolves backend/schedule ONCE
at plan time into a frozen ``PlanConfig`` and calls these dispatchers
with concrete values — per-call resolution here exists for the legacy
entry points and degrades to validation when the value is already
concrete.

Pallas kernels run in interpret mode off-TPU and compiled mode on TPU.
The ``"jnp"`` backend is also what the dry-run lowering uses on the
512-device mesh, where interpret-mode python loops would bloat the HLO.

Shape contracts (match :mod:`repro.core.rns` / :mod:`repro.core.ntt`;
violations raise immediately so a backend mismatch fails loudly):

* residues are ``(t, ..., n)`` — RNS channel leading, coefficients last;
* segment arrays are ``(..., S)`` with ``S = plan.seg_count``;
* limb arrays are ``(..., L)``.

The Pallas kernels internally operate on flattened ``(t, rows, n)`` /
``(rows, S)`` tiles; this layer folds/unfolds the batch dims, so callers
may pass any leading shape (``repro.decompose`` passes ``(..., n, S)``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import modmath
from repro.core import ntt as ntt_mod
from repro.core import rns as rns_mod
from repro.core import schedule as schedule_mod
from repro.core.params import (
    BACKENDS,
    SCHEDULES,
    ParenttParams,
    validate_backend,
)
from repro.analysis import walk as walk_mod
from repro.kernels import crt as crt_kernels
from repro.kernels import ntt as ntt_kernels

__all__ = [
    "BACKENDS",
    "SCHEDULES",
    "auto_backend",
    "unbind",
    "resolve_backend",
    "resolve_schedule",
    "ntt_forward",
    "ntt_inverse",
    "negacyclic_mul",
    "rns_decompose",
    "rns_compose",
    "fused_polymul_e2e",
    "hbm_traffic_model",
    "count_pallas_launches",
    "transform_cost_model",
    "count_reduction_selects",
]


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def unbind(obj: Any) -> Any:
    """The stable host object behind a leaf-bound view (see
    ``repro.api._LeafBound``), or ``obj`` itself.

    The api layer hands this dispatch layer params/tables/plan *views*
    whose device arrays are a Plan's pytree leaves (possibly tracers, so
    sharding the leaves is load-bearing).  Kernel wrappers that take a
    plan as a jit-STATIC argument need the underlying identity-hashable
    host object instead — a fresh view (or one holding tracers) must
    never become a jit cache key."""
    return getattr(obj, "_base", obj)


def _stage_backend(backend: str, cascade: bool = False) -> str:
    """Per-stage datapath for a resolved backend: ``pallas_fused_e2e``
    has no standalone-stage kernels, so stage entry points degrade to the
    closest kernel path (see module docstring)."""
    if backend == "pallas_fused_e2e":
        return "pallas_fused" if cascade else "pallas"
    return backend


def auto_backend() -> str:
    """The concrete datapath ``backend="auto"`` resolves to (at plan
    time, see :mod:`repro.api`): the fused single-kernel Pallas path on
    TPU, the pure-jnp reference elsewhere — off-TPU the Pallas kernels
    run in interpret mode, which is an emulation, not a fast path."""
    return "pallas_fused_e2e" if _is_tpu() else "jnp"


def resolve_backend(
    params: ParenttParams | None = None,
    backend: str | None = None,
    use_pallas: bool | None = None,
) -> str:
    """Pick the datapath: explicit ``backend`` > legacy ``use_pallas`` >
    ``params.backend`` > ``"jnp"``."""
    if backend is None and use_pallas is not None:
        backend = "pallas_fused" if use_pallas else "jnp"
    if backend is None:
        backend = getattr(params, "backend", None) or "jnp"
    return validate_backend(backend)


def resolve_schedule(
    params: ParenttParams, schedule=None
) -> schedule_mod.ScheduleSpec:
    """Pick the concrete NTT stage schedule: explicit ``schedule`` >
    ``params.schedule`` > ``"auto"`` (four_step when n >= 256).  Returns
    the resolved :class:`~repro.core.schedule.ScheduleSpec` (an already
    resolved spec — e.g. off a ``PlanConfig`` — passes through).  Unlike
    :func:`resolve_backend`, params is required — auto resolution needs
    the transform length."""
    if schedule is None:
        schedule = getattr(params, "schedule", None) or "auto"
    return schedule_mod.concrete_spec(params.n, schedule)


def _lazy_of(ct: ntt_mod.ChannelTables) -> tuple[int, int] | None:
    """(window, beta) for the Harvey lazy butterflies, or None when the
    table set has no Shoup constants (outside the 63-bit envelope)."""
    if ct.lazy_window is None or ct.mul_shifts is None:
        return None
    return (ct.lazy_window, ct.shoup_beta)


def _sched_tables(
    ct: ntt_mod.ChannelTables, schedule, lazy: tuple[int, int] | None, direction: str
) -> tuple[Any, Any, Any, Any]:
    """(table, shoup, row_tables, row_shoups) device arrays for one
    transform direction under (schedule, lazy) — the positional tail the
    kernel wrappers expect after their required args.  The row entries
    are per-level tuples (level 0 = the (t, n2, n1) tables, deeper
    levels the hierarchical sub-row tables, truncated to the schedule's
    depth); ``schedule`` is a concrete string or a resolved spec."""
    kind = getattr(schedule, "kind", schedule)
    four = kind == "four_step"
    if four and ct.fs_row_fwd is None:
        raise ValueError(
            f"four_step schedule unavailable for n={ct.n}: no row tables"
        )
    depth = getattr(schedule, "depth", 0) or (1 + len(ct.fs_sub_fwd))
    if direction == "fwd":
        tab, sh = ct.fwd_d, ct.fwd_shoup_d
        row = (ct.fs_row_fwd_d,) + tuple(ct.fs_sub_fwd_d[: depth - 1])
        rsh = (
            None
            if ct.fs_row_fwd_shoup_d is None
            else (ct.fs_row_fwd_shoup_d,)
            + tuple(ct.fs_sub_fwd_shoup_d[: depth - 1])
        )
    else:
        tab, sh = ct.inv_d, ct.inv_shoup_d
        row = (ct.fs_row_inv_d,) + tuple(ct.fs_sub_inv_d[: depth - 1])
        rsh = (
            None
            if ct.fs_row_inv_shoup_d is None
            else (ct.fs_row_inv_shoup_d,)
            + tuple(ct.fs_sub_inv_shoup_d[: depth - 1])
        )
    return (
        tab,
        sh if lazy is not None else None,
        row if four else None,
        rsh if (four and lazy is not None) else None,
    )


def _kernel_kw(
    params: ParenttParams, schedule: str, lazy: tuple[int, int] | None
) -> dict[str, Any]:
    assert params.tables is not None  # callers guard via _require_tables
    kw = dict(
        shifts=params.tables.mul_shifts,
        schedule=schedule,
        lazy=lazy,
        interpret=not _is_tpu(),
    )
    if params.row_blk is not None:
        kw["row_blk"] = params.row_blk
    return kw


# --------------------------------------------------------------------------
# shape contracts
# --------------------------------------------------------------------------


def _check_residues(x: Any, params: ParenttParams, fn: str) -> None:
    if x.ndim < 2 or x.shape[0] != params.t or x.shape[-1] != params.n:
        raise ValueError(
            f"{fn}: expected residues (t={params.t}, ..., n={params.n}), "
            f"got shape {tuple(x.shape)}"
        )


def _check_segments(z: Any, params: ParenttParams, fn: str) -> None:
    S = params.plan.seg_count
    if z.ndim < 1 or z.shape[-1] != S:
        raise ValueError(
            f"{fn}: expected base-2^{params.v} segments (..., S={S}), "
            f"got shape {tuple(z.shape)}"
        )


def _require_tables(params: ParenttParams, fn: str) -> ntt_mod.ChannelTables:
    if params.tables is None:
        raise ValueError(
            f"{fn}: params (n={params.n}, t={params.t}, v={params.v}) have no "
            "int64-safe NTT tables (v > 31); use polymul.oracle_multiply or "
            "repro.plan(..., v=45) (the wide width resolves at plan time)"
        )
    return params.tables


def _fold_rows(x: Any) -> tuple[Any, tuple[int, ...]]:
    """(t, ..., n) -> ((t, rows, n), unfold)"""
    t, n = x.shape[0], x.shape[-1]
    lead = x.shape[1:-1]
    return x.reshape(t, -1, n), lead


# --------------------------------------------------------------------------
# NTT / cascade dispatch
# --------------------------------------------------------------------------


def ntt_forward(a: Any, params: ParenttParams, *, backend: str | None = None,
                use_pallas: bool | None = None, schedule: str | None = None) -> Any:
    """a: (t, ..., n) -> forward NTT per RNS channel."""
    backend = _stage_backend(resolve_backend(params, backend, use_pallas))
    schedule = resolve_schedule(params, schedule)
    ct = _require_tables(params, "ntt_forward")
    _check_residues(a, params, "ntt_forward")
    with jax.named_scope("parentt.ntt_fwd"):
        if backend == "jnp":
            return ntt_mod.ntt_channels(a, ct, schedule)
        a3, lead = _fold_rows(a)
        lazy = _lazy_of(ct)
        fwd, sh, row, rsh = _sched_tables(ct, schedule, lazy, "fwd")
        out = ntt_kernels.ntt_channels_pallas(
            a3, ct.qs_d, fwd, ct.mul_eps_d, sh, row, rsh,
            **_kernel_kw(params, schedule, lazy),
        )
        return out.reshape(a.shape[:1] + lead + a.shape[-1:])


def ntt_inverse(a: Any, params: ParenttParams, *, backend: str | None = None,
                use_pallas: bool | None = None, schedule: str | None = None) -> Any:
    """a: (t, ..., n) bit-reversed spectra -> natural-order coefficients."""
    backend = _stage_backend(resolve_backend(params, backend, use_pallas))
    schedule = resolve_schedule(params, schedule)
    ct = _require_tables(params, "ntt_inverse")
    _check_residues(a, params, "ntt_inverse")
    with jax.named_scope("parentt.ntt_inv"):
        if backend == "jnp":
            return ntt_mod.intt_channels(a, ct, schedule)
        a3, lead = _fold_rows(a)
        lazy = _lazy_of(ct)
        inv, sh, row, rsh = _sched_tables(ct, schedule, lazy, "inv")
        out = ntt_kernels.intt_channels_pallas(
            a3, ct.qs_d, ct.half_d, inv, ct.mul_eps_d, sh, row, rsh,
            **_kernel_kw(params, schedule, lazy),
        )
        return out.reshape(a.shape[:1] + lead + a.shape[-1:])


def negacyclic_mul(a: Any, b: Any, params: ParenttParams, *,
                   backend: str | None = None,
                   use_pallas: bool | None = None,
                   schedule: str | None = None) -> Any:
    """(t, ..., n) x (t, ..., n) -> negacyclic products per RNS channel
    (the no-shuffle NTT -> ⊙ -> iNTT cascade)."""
    backend = _stage_backend(
        resolve_backend(params, backend, use_pallas), cascade=True
    )
    schedule = resolve_schedule(params, schedule)
    ct = _require_tables(params, "negacyclic_mul")
    _check_residues(a, params, "negacyclic_mul")
    _check_residues(b, params, "negacyclic_mul")
    if a.shape != b.shape:
        raise ValueError(
            f"negacyclic_mul: operand shapes differ: {tuple(a.shape)} vs "
            f"{tuple(b.shape)}"
        )
    with jax.named_scope("parentt.cascade"):
        if backend == "jnp":
            return ntt_mod.negacyclic_mul_channels(a, b, ct, schedule)
        a3, lead = _fold_rows(a)
        b3, _ = _fold_rows(b)
        lazy = _lazy_of(ct)
        kw = _kernel_kw(params, schedule, lazy)
        fwd, fsh, frow, frsh = _sched_tables(ct, schedule, lazy, "fwd")
        inv, ish, irow, irsh = _sched_tables(ct, schedule, lazy, "inv")
        if backend == "pallas_fused":
            out = ntt_kernels.fused_polymul_pallas(
                a3, b3, ct.qs_d, ct.half_d, fwd, inv, ct.mul_eps_d,
                fsh, ish, frow, irow, frsh, irsh, **kw,
            )
        else:  # "pallas": per-stage kernels, product round-trips HBM
            with jax.named_scope("parentt.ntt_fwd"):
                fa = ntt_kernels.ntt_channels_pallas(
                    a3, ct.qs_d, fwd, ct.mul_eps_d, fsh, frow, frsh, **kw
                )
                fb = ntt_kernels.ntt_channels_pallas(
                    b3, ct.qs_d, fwd, ct.mul_eps_d, fsh, frow, frsh, **kw
                )
            with jax.named_scope("parentt.pointwise"):
                q_b = ct.qs_d[:, None, None]
                eps_b = (
                    None if ct.mul_eps is None
                    else ct.mul_eps_d[:, None, None]
                )
                prod = modmath.mul_mod(fa, fb, q_b, eps_b, ct.mul_shifts)
            with jax.named_scope("parentt.ntt_inv"):
                out = ntt_kernels.intt_channels_pallas(
                    prod, ct.qs_d, ct.half_d, inv, ct.mul_eps_d,
                    ish, irow, irsh, **kw
                )
        return out.reshape(a.shape[:1] + lead + a.shape[-1:])


# --------------------------------------------------------------------------
# RNS pre/post dispatch
# --------------------------------------------------------------------------


def rns_decompose(z: Any, params: ParenttParams, *, backend: str | None = None,
                  use_pallas: bool | None = None, use_sau: bool = True) -> Any:
    """z: (..., S) base-2^v segments -> residues (t, ...)."""
    backend = _stage_backend(resolve_backend(params, backend, use_pallas))
    _check_segments(z, params, "rns_decompose")
    with jax.named_scope("parentt.decompose"):
        if backend == "jnp":
            fn = rns_mod.decompose_sau if use_sau else rns_mod.decompose
            return fn(z, params.plan)
        lead = z.shape[:-1]
        z2 = z.reshape(-1, z.shape[-1])
        out = crt_kernels.decompose_pallas(
            z2, plan=unbind(params.plan), interpret=not _is_tpu()
        )  # (t, rows)
        return out.reshape((params.t,) + lead)


def rns_compose(residues: Any, params: ParenttParams, *,
                backend: str | None = None,
                use_pallas: bool | None = None) -> Any:
    """residues: (t, ...) -> (..., L) base-2^w limbs of the composed value."""
    backend = _stage_backend(resolve_backend(params, backend, use_pallas))
    if residues.ndim < 1 or residues.shape[0] != params.t:
        raise ValueError(
            f"rns_compose: expected residues (t={params.t}, ...), got shape "
            f"{tuple(residues.shape)}"
        )
    with jax.named_scope("parentt.compose"):
        if backend == "jnp":
            return rns_mod.compose(residues, params.plan)
        lead = residues.shape[1:]
        r2 = residues.reshape(params.t, -1)
        rp = params.plan  # possibly a leaf-bound view: its *_d arrays
        # are plan leaves, passed as TRACED kernel operands below
        out = crt_kernels.compose_pallas(
            r2, plan=unbind(rp), qs=rp.qs_d, qi_tilde=rp.qi_tilde_d,
            star=rp.qi_star_limbs_d, q_limbs=rp.q_limbs_d,
            interpret=not _is_tpu(),
        )  # (rows, L)
        return out.reshape(lead + (params.plan.L,))


# --------------------------------------------------------------------------
# end-to-end dispatch (the whole Fig 10 pipeline behind one entry point)
# --------------------------------------------------------------------------


def fused_polymul_e2e(za: Any, zb: Any, params: ParenttParams, *,
                      backend: str | None = None,
                      use_pallas: bool | None = None, use_sau: bool = True,
                      schedule: str | None = None,
                      channel_grid: bool | None = None) -> Any:
    """za, zb: (..., n, S) segment arrays -> (..., n, L) product limbs:
    decompose -> per-channel NTT cascade -> compose.

    On ``backend="pallas_fused_e2e"`` all three steps run inside ONE
    ``pallas_call`` and the residue polynomials stay VMEM-resident (the
    paper's feed-forward datapath — two fewer HBM round-trips than
    ``pallas_fused``, see :func:`hbm_traffic_model`).  On every other
    backend this composes the three stage dispatchers, so callers can
    hold one entry point and switch datapaths with one string.
    ``use_sau`` selects Alg 2 vs generic decompose on the jnp path (the
    kernel paths always run the SAU circuits).  ``channel_grid`` pins
    the fused-e2e kernel's RNS-channel grid axis (None = the kernel's
    own default, ``t >= 2``); other backends have no such grid and
    ignore it (the api layer rejects the combination at plan time).
    """
    backend = resolve_backend(params, backend, use_pallas)
    schedule = resolve_schedule(params, schedule)
    for name, z in (("za", za), ("zb", zb)):
        if z.ndim < 2 or z.shape[-2] != params.n:
            raise ValueError(
                f"fused_polymul_e2e: expected {name} segments "
                f"(..., n={params.n}, S={params.plan.seg_count}), got shape "
                f"{tuple(z.shape)}"
            )
        _check_segments(z, params, "fused_polymul_e2e")
    if za.shape != zb.shape:
        raise ValueError(
            f"fused_polymul_e2e: operand shapes differ: {tuple(za.shape)} "
            f"vs {tuple(zb.shape)}"
        )
    if backend != "pallas_fused_e2e":
        ra = rns_decompose(za, params, backend=backend, use_sau=use_sau)
        rb = rns_decompose(zb, params, backend=backend, use_sau=use_sau)
        rp = negacyclic_mul(ra, rb, params, backend=backend, schedule=schedule)
        return rns_compose(rp, params, backend=backend)
    ct = _require_tables(params, "fused_polymul_e2e")
    plan = params.plan
    lead = za.shape[:-2]
    z3a = za.reshape((-1,) + za.shape[-2:])
    z3b = zb.reshape((-1,) + zb.shape[-2:])
    lazy = _lazy_of(ct)
    fwd, fsh, frow, frsh = _sched_tables(ct, schedule, lazy, "fwd")
    inv, ish, irow, irsh = _sched_tables(ct, schedule, lazy, "inv")
    with jax.named_scope("parentt.fused_e2e"):
        out = ntt_kernels.fused_e2e_polymul_pallas(
            z3a, z3b, fwd, inv, plan.qi_star_limbs_d, plan.q_limbs_d,
            fsh, ish, frow, irow, frsh, irsh,
            plan=unbind(plan), schedule=schedule, lazy=lazy,
            channel_grid=channel_grid,
            row_blk=params.row_blk, interpret=not _is_tpu(),
        )
    return out.reshape(lead + (params.n, plan.L))


def hbm_traffic_model(params: ParenttParams, rows: int,
                      backend: str | None = None,
                      schedule=None) -> dict[str, Any]:
    """Modeled HBM bytes crossing kernel/stage boundaries for ONE
    end-to-end multiply of ``rows`` polynomials (both operands in, limbs
    out), per backend.

    Counts every data tensor entering or leaving a ``pallas_call`` AS
    DISPATCHED ABOVE (device-resident tables excluded) — including the
    t-fold segment re-read of the per-channel specialized decompose
    circuits, which each scan all S segments.  For ``"jnp"`` there are
    no kernel launches; its row is the logical stage-boundary dataflow
    (XLA may fuse some of it), reported as the unfused reference bound.
    The ``kernel_launches`` numbers are structural claims about the
    dispatch and are cross-checked against the traced computation by
    :func:`count_pallas_launches` in the ``bench-smoke`` CI gate — a
    refactor that de-fuses a path cannot silently keep its old row.
    """
    backend = resolve_backend(params, backend)
    plan = params.plan
    t = params.t
    B = 8  # int64 lanes everywhere in the kernel datapaths
    seg = rows * params.n * plan.seg_count * B  # one operand's segments
    res = t * rows * params.n * B  # one full residue tensor
    limb = rows * params.n * plan.L * B  # composed product limbs
    if backend == "jnp":
        # logical stage boundaries: decompose out 2res, NTT/pointwise/
        # iNTT intermediates 8res, compose in res; no pallas launches
        launches, seg_in, total = 0, 2 * seg, 2 * seg + 12 * res + limb
    elif backend == "pallas":
        # decompose: t calls per operand, each reading all S segments
        # (2t seg in / 2res out); NTT x2 (res/res each); pointwise
        # between kernels (2res in / res out); iNTT (res/res); compose
        # (res in / limb out)
        launches = 2 * t + 4
        seg_in = 2 * t * seg
        total = seg_in + 12 * res + limb
    elif backend == "pallas_fused":
        # decompose 2t calls, fused cascade (2res in / res out), compose
        launches = 2 * t + 2
        seg_in = 2 * t * seg
        total = seg_in + 6 * res + limb
    else:  # pallas_fused_e2e: segments in, limbs out, nothing between
        launches, seg_in, total = 1, 2 * seg, 2 * seg + limb
    # schedule/tiling view of the same traffic (hierarchy-aware): how the
    # e2e bytes stream through VMEM — row_blk rows per grid step, each
    # step's working set bounded by the tile model the planner resolved
    # row_blk against.  Depth does NOT change HBM bytes (deeper levels
    # are VMEM reshapes); it changes the per-step tile, reported here.
    spec = resolve_schedule(params, schedule)
    row_blk = spec.row_blk or params.row_blk or ntt_kernels.DEFAULT_E2E_ROWS_CHGRID
    tile = schedule_mod.tile_bytes_model(
        spec.kind, params.n, spec.splits, row_blk, plan.seg_count, plan.L,
        lazy=params.tables is not None and params.tables.lazy_window is not None,
    )
    return {
        "backend": backend,
        "rows": rows,
        "hbm_bytes": total,
        "kernel_launches": launches,
        "segment_bytes_in": seg_in,
        "limb_bytes_out": limb,
        "intermediate_bytes": total - seg_in - limb,
        "schedule": str(spec),
        "schedule_depth": max(spec.depth, 1),
        "row_blk": row_blk,
        "grid_row_steps": -(-rows // row_blk),
        "vmem_tile_bytes": tile,
    }


def count_pallas_launches(params: ParenttParams, backend: str | None = None,
                          rows: int = 1) -> int:
    """Count ``pallas_call`` equations in the TRACED e2e multiply.

    This is the structural ground truth for
    ``hbm_traffic_model(...)['kernel_launches']``: the bench-smoke CI
    gate and the backend tests assert the two agree, so the traffic
    model cannot drift from what the dispatch actually launches (e.g. a
    future change splitting the fused e2e kernel back into stages).
    """
    S = params.plan.seg_count
    z = jnp.zeros((rows, params.n, S), jnp.int64)
    jaxpr = jax.make_jaxpr(
        lambda a, b: fused_polymul_e2e(a, b, params, backend=backend)
    )(z, z)
    return walk_mod.count_prim(jaxpr, "pallas_call")


# --------------------------------------------------------------------------
# stage-schedule cost model (reduction ops + lane alignment), with the
# same traced-jaxpr cross-check discipline as the HBM model above
# --------------------------------------------------------------------------


def transform_cost_model(params: ParenttParams, *, schedule=None,
                         direction: str = "fwd") -> dict[str, Any]:
    """Structural cost of ONE NTT transform under a schedule:

    * ``sublane_stages`` — stages whose butterfly pairs sit within the
      lane (minor) axis at distance < 128, i.e. stages that need lane
      shuffles/strided access on real TPU vregs.  Computed from
      :func:`repro.core.ntt.stage_lane_strides` (the schedule's
      structural definition); 0 for four_step at every n AND every
      hierarchy depth (deeper levels pair along reshaped sublane
      factors — the depth-agnosticity claim of DESIGN.md §10).
    * ``reduction_ops`` — conditional-subtract (jnp.where -> select_n)
      ops the transform traces to: 5 per stage strict, 1-2 per stage +
      an O(1) exit canonicalize under Harvey lazy reduction.  The
      bench-smoke gate cross-checks this number against the actual
      traced kernel via :func:`count_reduction_selects`, so the model
      cannot drift from the implementation.  The total stage count is
      log2(n) at any depth — the hierarchy regroups stages, it does not
      add butterflies.
    * ``vmem_transposes`` — physical tile transposes per transform: 1
      for four_step at ANY depth (only level 0 transposes; deeper
      levels are reshapes), 0 for radix2.
    """
    if direction not in ("fwd", "inv"):
        raise ValueError(f"direction must be 'fwd' or 'inv', got {direction!r}")
    spec = resolve_schedule(params, schedule)
    n = params.n
    stages = n.bit_length() - 1
    strides = ntt_mod.stage_lane_strides(n, spec)
    sublane = sum(1 for s in strides if 0 < s < 128)
    ct = params.tables
    lazy = None if ct is None else _lazy_of(ct)
    if lazy is not None:
        window = lazy[0]
        red = (
            modmath.lazy_selects_per_stage(window, inverse=direction == "inv")
            * stages
            + modmath.canonicalize_selects(window)
        )
    else:
        window = None
        red = modmath.STRICT_SELECTS_PER_STAGE * stages
    return {
        "schedule": spec.kind,
        "spec": spec,
        "depth": max(spec.depth, 1) if spec.kind == "four_step" else 0,
        "splits": spec.splits,
        "vmem_transposes": 1 if spec.kind == "four_step" else 0,
        "direction": direction,
        "stages": stages,
        "lane_strides": strides,
        "sublane_stages": sublane,
        "lazy_window": window,
        "reduction_ops": red,
        "strict_reduction_ops": modmath.STRICT_SELECTS_PER_STAGE * stages,
    }


def count_reduction_selects(params: ParenttParams, *,
                            schedule: str | None = None,
                            direction: str = "fwd", rows: int = 2) -> int:
    """Count conditional-subtract selects in the TRACED transform kernel.

    Traces ``ntt_forward``/``ntt_inverse`` on the ``pallas`` backend and
    counts ``select_n`` equations inside the ``pallas_call`` bodies —
    the structural ground truth for
    ``transform_cost_model(...)['reduction_ops']``, asserted equal by
    the bench-smoke CI gate and the schedule tests."""
    a = jnp.zeros((params.t, rows, params.n), jnp.int64)
    fn = ntt_forward if direction == "fwd" else ntt_inverse
    jaxpr = jax.make_jaxpr(
        lambda x: fn(x, params, backend="pallas", schedule=schedule)
    )(a)
    return walk_mod.count_prim(jaxpr, "select_n", inside_pallas_only=True)
