"""Abstract interpreter over traced crypto jaxprs.

Walks a ``ClosedJaxpr`` (recursing through ``pjit``, ``cond`` and
``pallas_call`` bodies) propagating :class:`repro.analysis.domain.AbsVal`
per value, and emits :class:`Finding` records for

* any integer intermediate whose interval cannot be proven to fit its
  lane dtype (``overflow``), or cannot be bounded at all (``unproven``);
* Shoup / Barrett preconditions that fail (``shoup-precondition``,
  ``barrett-precondition``).

Plain interval arithmetic cannot prove ``v*w - ((v*w')>>beta)*q`` lands
in ``[0, 2q)`` — the two products are correlated.  The interpreter
therefore recognizes the Shoup and Barrett reduction *patterns* through
value provenance, checks their preconditions against concretely
verified table tags (see :mod:`repro.analysis.passes`), and applies the
semantic bound.  Conditional subtracts (``jnp.where(x >= m, x - m, x)``)
are handled by branch refinement on ``select_n``, which is what walks
the Barrett output ``[0,4q)`` down to canonical through the repo's
select chains.  Pallas kernel bodies are executed with mutable ref
cells over an enumerated grid (``program_id`` seeded concretely), which
makes the channel-grid accumulator kernels exact.

Unhandled primitives or unproven preconditions degrade to TOP and a
finding — verification *fails closed*, it never silently passes.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import domain as D
from repro.analysis.domain import AbsVal, QCtx

Path = Tuple[Any, ...]

_CMP_KINDS = ("ge", "gt", "le", "lt", "eq", "ne")

# Layout/view primitives: bounds pass through unchanged.  Element-
# aligned views (same value in every lane position relative to the
# broadcastable shape) keep the source identity so relational pattern
# matching sees through them; element-*selecting* views (slice/rev/
# transpose pick or reorder elements) must not alias their source.
_ALIGNED_VIEW_PRIMS = frozenset(
    {"broadcast_in_dim", "reshape", "squeeze", "copy", "device_put", "stop_gradient"}
)
_REINDEX_VIEW_PRIMS = frozenset({"slice", "rev", "transpose", "dynamic_slice", "gather"})
_VIEW_PRIMS = _ALIGNED_VIEW_PRIMS | _REINDEX_VIEW_PRIMS

_CALL_PRIMS = frozenset({"pjit", "closed_call", "core_call", "custom_jvp_call"})

_CARR_CAP = 65536  # max elements for materialized concrete constant arrays


def _carr_view(prim: str, eqn: Any, arr: np.ndarray) -> Optional[Tuple[Any, ...]]:
    """Re-materialize a concrete constant array through an element-aligned
    view so weighted-reduction bounds stay exact; None when not feasible."""
    try:
        out_shape = tuple(eqn.outvars[0].aval.shape)
        if int(np.prod(out_shape, dtype=np.int64)) > _CARR_CAP:
            return None
        if prim == "broadcast_in_dim":
            dims = tuple(eqn.params.get("broadcast_dimensions", ()) or ())
            shaped = [1] * len(out_shape)
            for i, d in enumerate(dims):
                shaped[d] = arr.shape[i]
            expanded = np.broadcast_to(arr.reshape(tuple(shaped)), out_shape)
            return ("carr", np.ascontiguousarray(expanded))
        if prim in ("reshape", "squeeze"):
            return ("carr", arr.reshape(out_shape))
        return ("carr", arr)
    except Exception:
        return None


# Primitives whose int64 results belong to the mod-q *value stream* that the
# hand-kept ChannelTables envelope bookkeeping tracks in units of q.  The
# multiplier wires inside a Shoup/Barrett reduction (mul, shifts) run to
# ~2^63 by design and are audited in *bits* by the overflow check, not in
# units — counting them here would drown the inter-stage peak.
_STREAM_PRIMS = frozenset({"add", "sub", "select_n", "get", "concatenate", "pad"})


@dataclasses.dataclass
class Finding:
    severity: str  # "error" | "warning" | "info"
    code: str
    where: str
    message: str

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(eq=False)
class AnalysisContext:
    """Per-trace state shared by the interpreter and the passes."""

    qctx: QCtx
    beta: Optional[int]  # plan's Shoup beta (None => strict, no Shoup expected)
    q_set: frozenset[int]  # verified channel moduli (python ints)
    families: Dict[Tuple[Any, ...], Dict[str, Any]]  # Barrett/SAU family facts
    seed_const: Callable[[Any], AbsVal]  # abstraction for closure constants
    grid_cap: int = 64
    max_findings_per_code: int = 8
    registry: Any = None  # ConstRegistry (set by passes.build_context)
    findings: List[Finding] = dataclasses.field(default_factory=list)
    stream: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    prim_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bounds_out: Optional[Dict[Path, Tuple[Optional[int], Optional[int]]]] = None
    _suppressed: Dict[str, int] = dataclasses.field(default_factory=dict)
    _seg_peak: int = 1

    def finding(self, severity: str, code: str, where: str, message: str) -> None:
        n = self._suppressed.get(code, 0)
        self._suppressed[code] = n + 1
        if n < self.max_findings_per_code:
            self.findings.append(Finding(severity, code, where, message))
        elif n == self.max_findings_per_code:
            self.findings.append(
                Finding(severity, code, where, f"(further '{code}' findings suppressed)")
            )

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def note_units(self, u: int) -> None:
        if u > self._seg_peak:
            self._seg_peak = u

    def shoup_event(self, units_in: int, gs: bool) -> None:
        self.stream.append(
            {"units_in": units_in, "gs": gs, "peak_before": self._seg_peak}
        )
        self._seg_peak = 2  # the Shoup output itself: < 2q

    @property
    def tail_peak(self) -> int:
        return self._seg_peak


class Cell:
    """Mutable abstract state of one pallas ref."""

    __slots__ = ("val",)

    def __init__(self, val: Optional[AbsVal]) -> None:
        self.val = val


class RefVal:
    """Environment placeholder for a Ref-typed jaxpr var."""

    __slots__ = ("cell",)

    def __init__(self, cell: Cell) -> None:
        self.cell = cell


def _same(a: AbsVal, b: AbsVal) -> bool:
    if a is b or a.uid == b.uid:
        return True
    pa, pb = a.prov, b.prov
    return (
        pa is not None
        and pb is not None
        and pa[0] == "lit"
        and pb[0] == "lit"
        and pa[1] == pb[1]
    )


def _aff(av: AbsVal) -> Tuple[AbsVal, int, int]:
    """Affine view c*base with c in [c_lo, c_hi]; identity by default."""
    if av.aff is not None:
        return av.aff
    return (av, 1, 1)


def _apply_aff(out: AbsVal, base: AbsVal, c_lo: int, c_hi: int) -> AbsVal:
    """Intersect ``out`` with the interval of c*base and record the form."""
    if base.lo is not None and base.hi is not None:
        prods = [c_lo * base.lo, c_lo * base.hi, c_hi * base.lo, c_hi * base.hi]
        lo, hi = min(prods), max(prods)
        out.lo = lo if out.lo is None else max(out.lo, lo)
        out.hi = hi if out.hi is None else min(out.hi, hi)
    out.aff = (base, c_lo, c_hi)
    return out


def _aval_dtype(aval: Any) -> Optional[np.dtype]:
    dt = getattr(aval, "dtype", None)
    if dt is None:
        inner = getattr(aval, "inner_aval", None)
        dt = getattr(inner, "dtype", None)
    return np.dtype(dt) if dt is not None else None


def _aval_shape(aval: Any) -> Tuple[int, ...]:
    shp = getattr(aval, "shape", None)
    if shp is None:
        inner = getattr(aval, "inner_aval", None)
        shp = getattr(inner, "shape", ())
    return tuple(int(s) for s in (shp or ()))


def _is_ref(var: Any) -> bool:
    aval = getattr(var, "aval", None)
    return aval is not None and (
        hasattr(aval, "inner_aval") or type(aval).__name__ in ("AbstractRef", "MemRef")
    )


class _Interp:
    def __init__(self, ctx: AnalysisContext) -> None:
        self.ctx = ctx
        self._pid: Dict[int, AbsVal] = {}
        self._lit_cache: Dict[int, AbsVal] = {}
        # First-seen broadcast_dimensions per (src uid, output shape): a
        # second broadcast of the same source to the same shape along
        # *different* dims is not element-aligned with the first, so it
        # must not alias it.
        self._bcast: Dict[Tuple[int, Tuple[int, ...]], Tuple[int, ...]] = {}

    # ---------------------------------------------------------- plumbing

    def _lit(self, val: Any) -> AbsVal:
        try:
            arr = np.asarray(val)
            if arr.dtype == np.bool_:
                v = int(arr.reshape(-1)[0]) if arr.size == 1 else None
                return D.const(v) if v is not None else D.boolean()
            if np.issubdtype(arr.dtype, np.integer):
                if arr.size == 1:
                    v = int(arr.reshape(-1)[0])
                    av = D.const(v)
                    if v in self.ctx.q_set:
                        # A scalar literal equal to a registered modulus is
                        # per-channel code operating on its own channel's
                        # lanes (decompose slices each residue channel and
                        # bakes that channel's q_i as a literal), so for the
                        # elements it meets, q_elem == v exactly.  Seed it
                        # with the same q-linear forms the registered q
                        # arrays carry so select-chain refinement can recover
                        # canonical (1, -1) bounds.  The Shoup checker makes
                        # the identical assumption when it accepts literal q.
                        av = av.with_qlin(
                            Fraction(1), Fraction(0), self.ctx.qctx
                        ).with_qlo(Fraction(1), Fraction(0), self.ctx.qctx)
                        av.tag = ("q",)
                    elif 2 * v - 1 in self.ctx.q_set:
                        # (q+1)//2 baked as a literal: the div-by-2 constant
                        # of that channel (the wide digit-split path bakes
                        # both q and half as scalars instead of table leaves).
                        av = av.with_qlin(
                            Fraction(1, 2), Fraction(1, 2), self.ctx.qctx
                        ).with_qlo(Fraction(1, 2), Fraction(1, 2), self.ctx.qctx)
                        av.tag = ("half",)
                    return av
                av = D.from_ints(int(arr.min()), int(arr.max()))
                if arr.size <= _CARR_CAP:
                    av.prov = ("carr", np.asarray(arr))
                return av
        except (TypeError, ValueError):
            pass
        return D.top()

    def _read(self, env: Dict[Any, Any], atom: Any) -> Any:
        if hasattr(atom, "val"):  # jax.core.Literal (Vars carry no .val)
            key = id(atom)
            if key not in self._lit_cache:
                self._lit_cache[key] = self._lit(atom.val)
            return self._lit_cache[key]
        got = env.get(atom)
        if got is None:
            got = D.top()
            env[atom] = got
        return got

    def _check(self, var: Any, av: Any, prim: str, where: Path, out_idx: int) -> None:
        if isinstance(av, RefVal):
            return
        dt = _aval_dtype(getattr(var, "aval", None))
        if dt is None or not np.issubdtype(dt, np.integer):
            return
        if self.ctx.bounds_out is not None:
            self.ctx.bounds_out[where + (out_idx,)] = (av.lo, av.hi)
        info = np.iinfo(dt)
        loc = "/".join(str(w) for w in where) + f" [{prim}]"
        if av.lo is None or av.hi is None:
            self.ctx.finding(
                "error", "unproven", loc, f"{dt} intermediate has unbounded interval"
            )
        elif av.lo < info.min or av.hi > info.max:
            self.ctx.finding(
                "error",
                "overflow",
                loc,
                f"{dt} intermediate in [{av.lo}, {av.hi}] exceeds "
                f"[{info.min}, {info.max}]",
            )
        if (
            av.qa is not None
            and np.dtype(dt) == np.int64
            and av.lo is not None
            and av.lo >= 0
            and prim in _STREAM_PRIMS
        ):
            u = D.units_of_q(av, self.ctx.qctx)
            if u is not None:
                self.ctx.note_units(u)

    # ---------------------------------------------------------- main loop

    def run(
        self,
        jaxpr: Any,
        consts: Sequence[Any],
        args: Sequence[Any],
        where: Path,
    ) -> List[Any]:
        env: Dict[Any, Any] = {}
        for var, av in zip(jaxpr.constvars, consts):
            env[var] = av
        for var, av in zip(jaxpr.invars, args):
            env[var] = av
        for idx, eqn in enumerate(jaxpr.eqns):
            prim = eqn.primitive.name
            self.ctx.prim_counts[prim] = self.ctx.prim_counts.get(prim, 0) + 1
            ins = [self._read(env, x) for x in eqn.invars]
            outs = self._apply(prim, eqn, ins, where + (idx,))
            for oi, (var, av) in enumerate(zip(eqn.outvars, outs)):
                env[var] = av
                self._check(var, av, prim, where + (idx,), oi)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _apply(self, prim: str, eqn: Any, ins: List[Any], where: Path) -> List[Any]:
        ctx = self.ctx
        qctx = ctx.qctx
        if prim in _VIEW_PRIMS:
            src = ins[0]
            if not isinstance(src, AbsVal):
                return [src]
            if prim in _REINDEX_VIEW_PRIMS:
                out = src.view(fresh=True)
                if out.prov is not None and out.prov[0] == "carr":
                    # The concrete array no longer matches the reindexed
                    # layout; drop it rather than mis-align weighted sums.
                    out.prov = None
                return [out]
            fresh = False
            if prim == "broadcast_in_dim":
                params = getattr(eqn, "params", None) or {}
                shape = tuple(params.get("shape", ()) or ())
                dims = tuple(params.get("broadcast_dimensions", ()) or ())
                prior = self._bcast.setdefault((src.uid, shape), dims)
                fresh = prior != dims
            out = src.view(fresh=fresh)
            if out.prov is not None and out.prov[0] == "carr":
                out.prov = _carr_view(prim, eqn, out.prov[1])
            return [out]
        if prim == "convert_element_type":
            dt = _aval_dtype(eqn.outvars[0].aval)
            src = ins[0]
            if dt is not None and dt == np.bool_:
                out = D.boolean()
                out.prov = src.prov
                return [out]
            return [src.view()]
        if prim == "add":
            out = D.add(ins[0], ins[1], qctx)
            d2 = self._try_div2(ins[0], ins[1], out)
            if d2 is not None:
                return [d2]
            ba, ca_lo, ca_hi = _aff(ins[0])
            bb, cb_lo, cb_hi = _aff(ins[1])
            if ba.uid == bb.uid:
                out = _apply_aff(out, ba, ca_lo + cb_lo, ca_hi + cb_hi)
            return [out]
        if prim == "sub":
            pat = self._try_shoup(ins[0], ins[1], where)
            if pat is None:
                pat = self._try_barrett(ins[0], ins[1], where)
            if pat is None:
                pat = self._try_sau_sub(ins[0], ins[1])
            if pat is not None:
                pat.prov = ("sub", ins[0], ins[1])
                return [pat]
            out = D.sub(ins[0], ins[1], qctx)
            ba, ca_lo, ca_hi = _aff(ins[0])
            bb, cb_lo, cb_hi = _aff(ins[1])
            if ba.uid == bb.uid:
                out = _apply_aff(out, ba, ca_lo - cb_hi, ca_hi - cb_lo)
            return [out]
        if prim == "mul":
            out = D.mul(ins[0], ins[1], qctx)
            for x, y in ((ins[0], ins[1]), (ins[1], ins[0])):
                if y.is_singleton() and y.lo is not None:
                    bx, cx_lo, cx_hi = _aff(x)
                    cs = sorted((cx_lo * y.lo, cx_hi * y.lo))
                    out = _apply_aff(out, bx, cs[0], cs[1])
                    break
            return [out]
        if prim == "neg":
            out = D.neg(ins[0])
            b, c_lo, c_hi = _aff(ins[0])
            return [_apply_aff(out, b, -c_hi, -c_lo)]
        if prim == "shift_left":
            out = D.shift_left(ins[0], ins[1], qctx)
            if ins[1].is_singleton() and ins[1].lo is not None:
                b, c_lo, c_hi = _aff(ins[0])
                sh = 1 << ins[1].lo
                out = _apply_aff(out, b, c_lo * sh, c_hi * sh)
            return [out]
        if prim in ("shift_right_arithmetic", "shift_right_logical"):
            if prim == "shift_right_logical" and (ins[0].lo is None or ins[0].lo < 0):
                return [D.top()]
            return [D.shift_right(ins[0], ins[1], qctx)]
        if prim == "and":
            return [D.bit_and(ins[0], ins[1])]
        if prim in ("or", "xor"):
            return [D.bit_or(ins[0], ins[1])]
        if prim == "not":
            out = D.boolean()
            out.prov = ("not", ins[0])
            return [out]
        if prim == "rem":
            return [D.rem(ins[0], ins[1], qctx)]
        if prim in _CMP_KINDS:
            return [D.compare(prim, ins[0], ins[1])]
        if prim == "select_n":
            return [self._select_n(ins)]
        if prim == "min":
            lo = None if ins[0].lo is None or ins[1].lo is None else min(ins[0].lo, ins[1].lo)
            his = [h for h in (ins[0].hi, ins[1].hi) if h is not None]
            return [AbsVal(lo, min(his) if his else None)]
        if prim == "max":
            los = [l for l in (ins[0].lo, ins[1].lo) if l is not None]
            hi = None if ins[0].hi is None or ins[1].hi is None else max(ins[0].hi, ins[1].hi)
            return [AbsVal(max(los) if los else None, hi)]
        if prim == "reduce_sum":
            return [self._reduce_sum(eqn, ins)]
        if prim in ("reduce_max", "reduce_min", "reduce_and", "reduce_or"):
            return [ins[0].view()]
        if prim == "pad":
            return [D.join(ins[0], ins[1], self.ctx.qctx)]
        if prim == "concatenate":
            out = ins[0]
            for other in ins[1:]:
                out = D.join(out, other, self.ctx.qctx)
            return [out]
        if prim == "iota":
            shape = _aval_shape(eqn.outvars[0].aval)
            dim = int(eqn.params.get("dimension", 0))
            size = shape[dim] if shape else 1
            return [D.from_ints(0, max(0, size - 1))]
        if prim in _CALL_PRIMS:
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is None:
                return self._unknown(prim, eqn, where)
            inner_jaxpr = getattr(inner, "jaxpr", inner)
            inner_consts = [ctx.seed_const(c) for c in getattr(inner, "consts", [])]
            name = eqn.params.get("name", prim)
            return self.run(inner_jaxpr, inner_consts, ins, where + (name,))
        if prim == "pallas_call":
            return self._pallas_call(eqn, ins, where)
        if prim == "cond":
            return self._cond(eqn, ins, where)
        if prim == "program_id":
            axis = int(eqn.params.get("axis", 0))
            return [self._pid.get(axis, D.top())]
        if prim == "get":
            ref = ins[0]
            if isinstance(ref, RefVal):
                if ref.cell.val is None:
                    ctx.finding(
                        "error",
                        "uninitialized-ref",
                        "/".join(str(w) for w in where),
                        "read of ref before any write",
                    )
                    return [D.top()]
                return [ref.cell.val.view()]
            return self._unknown(prim, eqn, where)
        if prim == "swap":
            ref = ins[0]
            if isinstance(ref, RefVal):
                new = next((x for x in ins[1:] if isinstance(x, AbsVal)), D.top())
                old = ref.cell.val
                full = len(ins) == 2
                if full or old is None:
                    ref.cell.val = new
                else:
                    ref.cell.val = D.join(old, new, self.ctx.qctx)
                return [old if old is not None else new.view()]
            return self._unknown(prim, eqn, where)
        return self._unknown(prim, eqn, where)

    def _unknown(self, prim: str, eqn: Any, where: Path) -> List[Any]:
        self.ctx.finding(
            "error",
            "unproven-prim",
            "/".join(str(w) for w in where),
            f"no abstract transfer for primitive '{prim}'",
        )
        return [D.top() for _ in eqn.outvars]

    # ---------------------------------------------------------- select_n

    def _select_n(self, ins: List[AbsVal]) -> AbsVal:
        pred, *cases = ins
        feas = list(range(len(cases)))
        if pred.lo is not None and pred.hi is not None:
            feas = [i for i in feas if pred.lo <= i <= pred.hi]
            if not feas:  # infeasible pred abstraction; stay sound
                feas = list(range(len(cases)))
        refined = [self._refine(pred, i, cases[i]) for i in feas]
        out = refined[0]
        for other in refined[1:]:
            out = D.join(out, other, self.ctx.qctx)
        out.prov = ("select_n", pred, *cases)
        return out

    def _refine(self, pred: AbsVal, idx: int, case: AbsVal) -> AbsVal:
        prov = pred.prov
        if prov is None or prov[0] not in ("ge", "gt", "le", "lt") or idx > 1:
            return case
        kind, u, w = prov[0], prov[1], prov[2]
        truth = idx == 1
        # Normalize to u >= w + d ("ge") or u <= w - d ("le").
        rel: Tuple[str, int]
        if kind == "ge":
            rel = ("ge", 0) if truth else ("le", 1)
        elif kind == "gt":
            rel = ("ge", 1) if truth else ("le", 0)
        elif kind == "le":
            rel = ("le", 0) if truth else ("ge", 1)
        else:  # lt
            rel = ("le", 1) if truth else ("ge", 0)
        qctx = self.ctx.qctx
        if _same(case, u):
            if rel[0] == "ge" and w.lo is not None:
                return D.clamp_min(case, w.lo + rel[1], qctx)
            if rel[0] == "le":
                out = case
                if w.hi is not None:
                    out = D.clamp_max(out, w.hi - rel[1], qctx)
                if w.qa is not None and w.qb is not None:
                    out = out.with_qlin(w.qa, w.qb - rel[1], qctx)
                    out.prov = case.prov
                return out
            return case
        cp = case.prov
        if (
            cp is not None
            and cp[0] == "sub"
            and _same(cp[1], u)
            and _same(cp[2], w)
        ):
            if rel[0] == "ge":  # case = u - w >= d
                return D.clamp_min(case, rel[1], qctx)
            return D.clamp_max(case, -rel[1], qctx)  # u - w <= -d
        if cp is not None and cp[0] == "add":
            # case = u + m under a relation on u (e.g. sub_mod's
            # ``where(d < 0, d + q, d)``: d <= -1  =>  d + q <= q - 1).
            for uu, m in ((cp[1], cp[2]), (cp[2], cp[1])):
                if not _same(uu, u):
                    continue
                out = case
                if rel[0] == "le" and w.hi is not None:
                    if m.hi is not None:
                        out = D.clamp_max(out, w.hi - rel[1] + m.hi, qctx)
                    if m.qa is not None and m.qb is not None:
                        out = out.with_qlin(m.qa, m.qb + w.hi - rel[1], qctx)
                elif rel[0] == "ge" and w.lo is not None and m.lo is not None:
                    out = D.clamp_min(out, w.lo + rel[1] + m.lo, qctx)
                return out
        return case

    # ---------------------------------------------------------- patterns

    def _try_div2(self, a: AbsVal, b: AbsVal, out: AbsVal) -> Optional[AbsVal]:
        """``div2_mod``: ``(x >> 1) + (x & 1) * half`` — exact halving mod
        q.  Summing the two halves independently loses the parity
        correlation (even uses only the shift, odd adds ``(q+1)/2`` to
        ``(x-1)/2``), which inflates the bound to ``x/2 + q/2 + 1/2`` and
        compounds across inverse-NTT stages.  The odd branch dominates:
        ``out <= (x + q)/2 <= ((qa+1)/2)*q + qb/2``."""
        qctx = self.ctx.qctx
        for sh, prod in ((a, b), (b, a)):
            ps, pp = sh.prov, prod.prov
            if not (ps and ps[0] == "shift_right" and pp and pp[0] == "mul"):
                continue
            x, s = ps[1], ps[2]
            if not (s.is_singleton() and s.lo == 1):
                continue
            for par, h in ((pp[1], pp[2]), (pp[2], pp[1])):
                if not (h.tag and h.tag[0] == "half"):
                    continue
                pq = par.prov
                if not (pq and pq[0] == "and"):
                    continue
                for x2, one in ((pq[1], pq[2]), (pq[2], pq[1])):
                    if not (one.is_singleton() and one.lo == 1 and _same(x2, x)):
                        continue
                    if x.lo is None or x.lo < 0 or x.qa is None or x.qb is None:
                        continue
                    res = out.with_qlin((x.qa + 1) / 2, x.qb / 2, qctx)
                    res = res.with_qlo(Fraction(0), Fraction(0), qctx)
                    res.prov = ("add", a, b)
                    return res
        return None

    def _try_shoup(self, a: AbsVal, b: AbsVal, where: Path) -> Optional[AbsVal]:
        pa, pb = a.prov, b.prov
        if not (pa and pa[0] == "mul" and pb and pb[0] == "mul"):
            return None
        for v, w in ((pa[1], pa[2]), (pa[2], pa[1])):
            for k, qv in ((pb[1], pb[2]), (pb[2], pb[1])):
                pk = k.prov
                if not (pk and pk[0] == "shift_right"):
                    continue
                p, beta = pk[1], pk[2]
                if not beta.is_singleton() or beta.lo is None:
                    continue
                pp = p.prov
                if not (pp and pp[0] == "mul"):
                    continue
                for v2, wsh in ((pp[1], pp[2]), (pp[2], pp[1])):
                    if not _same(v2, v):
                        continue
                    out = self._shoup_checked(v, w, wsh, qv, beta.lo, where)
                    if out is not None:
                        return out
        return None

    def _shoup_checked(
        self, v: AbsVal, w: AbsVal, wsh: AbsVal, qv: AbsVal, beta: int, where: Path
    ) -> Optional[AbsVal]:
        ctx = self.ctx
        if not (
            w.tag
            and w.tag[0] == "twiddle"
            and wsh.tag
            and wsh.tag[0] == "shoup"
            and w.tag[1:] == wsh.tag[1:]
        ):
            return None
        q_hi: Optional[int] = None
        if qv.tag and qv.tag[0] == "q":
            q_hi = ctx.qctx.q_max
        elif qv.prov and qv.prov[0] == "lit" and int(qv.prov[1]) in ctx.q_set:
            q_hi = int(qv.prov[1])
        if q_hi is None or ctx.beta is None or beta != ctx.beta:
            return None
        loc = "/".join(str(x) for x in where)
        if v.lo is None or v.lo < 0 or v.hi is None or v.hi > (1 << beta):
            ctx.finding(
                "error",
                "shoup-precondition",
                loc,
                f"Shoup multiplicand in [{v.lo}, {v.hi}] not within [0, 2^{beta}]",
            )
            return None
        out = AbsVal(0, 2 * q_hi - 1).with_qlin(Fraction(2), Fraction(-1), ctx.qctx)
        units = D.units_of_q(v, ctx.qctx) or 0
        gs = bool(v.prov and v.prov[0] == "select_n")
        ctx.shoup_event(units, gs)
        return out

    def _try_barrett(self, a: AbsVal, b: AbsVal, where: Path) -> Optional[AbsVal]:
        pb = b.prov
        if not (pb and pb[0] == "mul"):
            return None
        for khat, qv in ((pb[1], pb[2]), (pb[2], pb[1])):
            pk = khat.prov
            if not (pk and pk[0] == "shift_right"):
                continue
            m, s2 = pk[1], pk[2]
            pm = m.prov
            if not (pm and pm[0] == "mul"):
                continue
            for x2, eps in ((pm[1], pm[2]), (pm[2], pm[1])):
                px = x2.prov
                if not (px and px[0] == "shift_right"):
                    continue
                x3, s1 = px[1], px[2]
                if not _same(x3, a):
                    continue
                out = self._barrett_checked(a, eps, qv, s1, s2, where)
                if out is not None:
                    return out
        return None

    def _barrett_checked(
        self,
        x: AbsVal,
        eps: AbsVal,
        qv: AbsVal,
        s1: AbsVal,
        s2: AbsVal,
        where: Path,
    ) -> Optional[AbsVal]:
        ctx = self.ctx
        if not s1.is_singleton() or s1.lo is None:
            return None
        s1v = s1.lo
        q_hi: Optional[int] = None
        if qv.tag and qv.tag[0] == "q":
            q_hi = ctx.qctx.q_max
        elif qv.prov and qv.prov[0] == "lit":
            q_hi = int(qv.prov[1])
        if q_hi is None:
            return None
        c_min: Optional[int] = None
        if eps.tag and eps.tag[0] == "brt":
            fam = ctx.families.get(eps.tag)
            if fam is None or fam["s1"] != s1v:
                return None
            if s2.is_singleton() and s2.lo is not None:
                if not (fam["s2_lo"] <= s2.lo <= fam["s2_hi"]):
                    return None
                c_min = s1v + s2.lo
            elif s2.tag == ("brt_s2",) + eps.tag[1:]:
                c_min = s1v + fam["s2_lo"]
            else:
                return None
        elif (
            eps.prov
            and eps.prov[0] == "lit"
            and qv.prov
            and qv.prov[0] == "lit"
            and s2.is_singleton()
            and s2.lo is not None
        ):
            c = s1v + s2.lo
            if int(eps.prov[1]) != (1 << c) // int(qv.prov[1]):
                return None
            c_min = c
        else:
            return None
        loc = "/".join(str(w) for w in where)
        if x.lo is None or x.lo < 0 or x.hi is None or x.hi >= (1 << c_min):
            ctx.finding(
                "error",
                "barrett-precondition",
                loc,
                f"Barrett input in [{x.lo}, {x.hi}] not within [0, 2^{c_min})",
            )
            return None
        return AbsVal(0, 4 * q_hi - 1).with_qlin(Fraction(4), Fraction(-1), ctx.qctx)

    def _try_sau_sub(self, a: AbsVal, b: AbsVal) -> Optional[AbsVal]:
        """``sau_sum - x`` where sau_sum = c*x with family-verified c."""
        pa = a.prov
        if not (pa and pa[0] == "sau" and _same(pa[1], b)):
            return None
        fam = self.ctx.families.get(pa[2])
        if fam is None or b.lo is None or b.lo < 0 or b.hi is None:
            return None
        return AbsVal((fam["c_lo"] - 1) * b.lo, (fam["c_hi"] - 1) * b.hi)

    def _reduce_sum(self, eqn: Any, ins: List[AbsVal]) -> AbsVal:
        a = ins[0]
        shape = _aval_shape(eqn.invars[0].aval)
        axes = eqn.params.get("axes", ())
        count = 1
        for ax in axes:
            count *= shape[ax] if ax < len(shape) else 1
        pa = a.prov
        if pa and pa[0] == "mul":
            # Weighted digit recompose: sum_k x_k * w_k with w a concrete
            # constant array (powers of the limb base).  Per-output bound is
            # x.hi times the exact per-output weight sum, not count * max.
            for s, t in ((pa[1], pa[2]), (pa[2], pa[1])):
                pt = t.prov
                if (
                    pt
                    and pt[0] == "carr"
                    and isinstance(s, AbsVal)
                    and s.lo is not None
                    and s.lo >= 0
                    and s.hi is not None
                ):
                    try:
                        arr = np.broadcast_to(pt[1], tuple(shape))
                    except ValueError:
                        continue
                    if int(arr.min()) >= 0:
                        wsum = arr.astype(object).sum(axis=tuple(axes))
                        wmax = int(np.max(wsum)) if getattr(wsum, "ndim", 0) else int(wsum)
                        wmin = int(np.min(wsum)) if getattr(wsum, "ndim", 0) else int(wsum)
                        return AbsVal(s.lo * wmin, s.hi * wmax)
            for s, t in ((pa[1], pa[2]), (pa[2], pa[1])):
                pt = t.prov
                if (
                    s.tag
                    and s.tag[0] == "sau_s"
                    and pt
                    and pt[0] == "shift_left"
                    and isinstance(pt[1], AbsVal)
                ):
                    xbase, e = pt[1], pt[2]
                    if e.tag == ("sau_e",) + s.tag[1:]:
                        key = ("sau",) + s.tag[1:]
                        fam = self.ctx.families.get(key)
                        if (
                            fam is not None
                            and xbase.lo is not None
                            and xbase.lo >= 0
                            and xbase.hi is not None
                        ):
                            return AbsVal(
                                fam["c_lo"] * xbase.lo,
                                fam["c_hi"] * xbase.hi,
                                prov=("sau", xbase, key),
                            )
        return D.reduce_sum(a, max(count, 1))

    # ---------------------------------------------------------- control

    def _cond(self, eqn: Any, ins: List[Any], where: Path) -> List[Any]:
        index, *ops = ins
        branches = eqn.params["branches"]
        if isinstance(index, AbsVal) and index.is_singleton() and index.lo is not None:
            k = min(max(index.lo, 0), len(branches) - 1)
            br = branches[k]
            consts = [self.ctx.seed_const(c) for c in getattr(br, "consts", [])]
            return self.run(getattr(br, "jaxpr", br), consts, ops, where + (f"br{k}",))
        # Unknown predicate: run every branch on copies, join states/outputs.
        cells = [op.cell for op in ops if isinstance(op, RefVal)]
        saved = [c.val for c in cells]
        all_outs: List[List[Any]] = []
        all_states: List[List[Optional[AbsVal]]] = []
        for k, br in enumerate(branches):
            for c, v in zip(cells, saved):
                c.val = v
            consts = [self.ctx.seed_const(c) for c in getattr(br, "consts", [])]
            outs = self.run(getattr(br, "jaxpr", br), consts, ops, where + (f"br{k}",))
            all_outs.append(outs)
            all_states.append([c.val for c in cells])
        for i, c in enumerate(cells):
            vals = [st[i] for st in all_states if st[i] is not None]
            if len(vals) < len(all_states):
                c.val = None if not vals else vals[0]
            else:
                acc = vals[0]
                for v in vals[1:]:
                    acc = D.join(acc, v, self.ctx.qctx)
                c.val = acc
        joined: List[Any] = []
        for outs in zip(*all_outs):
            acc = outs[0]
            for other in outs[1:]:
                if isinstance(acc, AbsVal) and isinstance(other, AbsVal):
                    acc = D.join(acc, other, self.ctx.qctx)
            joined.append(acc)
        return joined or [D.top() for _ in eqn.outvars]

    def _pallas_call(self, eqn: Any, ins: List[Any], where: Path) -> List[Any]:
        ctx = self.ctx
        params = eqn.params
        body = params.get("jaxpr")
        if body is None:
            return self._unknown("pallas_call", eqn, where)
        body_jaxpr = getattr(body, "jaxpr", body)
        gm = params.get("grid_mapping")
        grid = tuple(int(g) for g in (getattr(gm, "grid", None) or ()))
        n_out = getattr(gm, "num_outputs", None)
        if n_out is None:
            n_out = len(eqn.outvars)
        n_in = getattr(gm, "num_inputs", None)
        if n_in is None:
            n_in = len(body_jaxpr.invars) - n_out
        in_seeds = [x for x in ins if isinstance(x, AbsVal)][:n_in]
        if len(in_seeds) < n_in:
            in_seeds += [D.top()] * (n_in - len(in_seeds))
        in_cells = [Cell(None) for _ in range(n_in)]
        out_cells = [Cell(None) for _ in range(n_out)]
        body_args: List[Any] = [RefVal(c) for c in in_cells] + [
            RefVal(c) for c in out_cells
        ]
        extra = len(body_jaxpr.invars) - len(body_args)
        if extra > 0:
            body_args += [D.top()] * extra
        body_consts = [ctx.seed_const(c) for c in getattr(body, "consts", [])]
        total = 1
        for g in grid:
            total *= g
        steps: List[Optional[Tuple[int, ...]]]
        if grid and total <= ctx.grid_cap:
            steps = [tuple(ix) for ix in np.ndindex(*grid)]
        else:
            steps = [None]
            if grid:
                ctx.finding(
                    "warning",
                    "grid-not-enumerated",
                    "/".join(str(w) for w in where),
                    f"grid {grid} exceeds enumeration cap {ctx.grid_cap}; "
                    "ref state joined across steps",
                )
        saved_pid = self._pid
        for step in steps:
            if step is None:
                self._pid = {
                    ax: D.from_ints(0, max(0, g - 1)) for ax, g in enumerate(grid)
                }
            else:
                self._pid = {ax: D.const(v) for ax, v in enumerate(step)}
            for cell, seed in zip(in_cells, in_seeds):
                cell.val = seed.view()
            self.run(body_jaxpr, body_consts, body_args, where + ("kernel",))
        self._pid = saved_pid
        outs: List[Any] = []
        for i, cell in enumerate(out_cells):
            if cell.val is None:
                ctx.finding(
                    "error",
                    "unproven",
                    "/".join(str(w) for w in where),
                    f"pallas output {i} never written",
                )
                outs.append(D.top())
            else:
                outs.append(cell.val.view())
        return outs[: len(eqn.outvars)]


def analyze_closed_jaxpr(
    closed: Any, args: Sequence[AbsVal], ctx: AnalysisContext, where: str = "trace"
) -> List[Any]:
    """Run the abstract interpreter over a ClosedJaxpr; findings and the
    Shoup-event stream accumulate on ``ctx``; returns output AbsVals."""
    interp = _Interp(ctx)
    consts = [ctx.seed_const(c) for c in closed.consts]
    return interp.run(closed.jaxpr, consts, list(args), (where,))
