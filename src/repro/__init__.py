"""repro: PaReNTT — parallel RNS + NTT long polynomial modular multiplication
(Tan, Chiu, Wang, Lao, Parhi, 2023) as a production JAX framework.

The crypto core requires 64-bit integer arithmetic; enable x64 once at
package import.  All floating-point model code states dtypes explicitly,
so the x64 default does not leak into LM layers.
"""
from jax import config as _config

_config.update("jax_enable_x64", True)

__version__ = "0.1.0"
