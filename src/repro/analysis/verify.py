"""Preset registry and the ``verify_plan`` front door.

``verify_plan(plan)`` traces every datapath the plan serves (forward
and inverse transform, full polymul pipeline), runs the concrete table
integrity pass, the abstract-interpretation overflow/envelope pass, the
lane/VMEM lint and the staticness lint, and folds everything into one
:class:`VerifyReport`.  ``PRESETS`` pins the (n, t, v, backend,
schedule) matrix the ``verify-kernels`` CI job sweeps;
:func:`mutation_selfcheck` deliberately corrupts a Shoup constant and
widens a lazy window in-memory and asserts the verifier flags both, so
a regression that blinds the analyzer fails CI too.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import passes
from repro.analysis.domain import AbsVal, QCtx
from repro.analysis.interp import AnalysisContext, Finding, analyze_closed_jaxpr


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    n: int
    t: int
    v: int
    backend: str
    schedule: str

    def build_plan(self) -> Any:
        from repro import api

        return api.plan(
            n=self.n, t=self.t, v=self.v, backend=self.backend,
            schedule=self.schedule,
        )


# The registered kernel-path matrix: every backend x schedule the int64
# datapaths serve, both lazy windows (v=30 -> W=2, v<=29 -> W=4), the
# strict fallback (v=31: mixed-width-free but beyond the lazy/Barrett
# envelope) and the wide digit-split width.  Kept at small n so a CI
# sweep stays cheap — the bounds are n-independent per stage, n only
# multiplies how many identical stage instances get checked.
PRESETS: Tuple[Preset, ...] = (
    Preset("n64_t3_v30_jnp_radix2", 64, 3, 30, "jnp", "radix2"),
    Preset("n64_t3_v29_jnp_radix2", 64, 3, 29, "jnp", "radix2"),
    Preset("n256_t2_v30_jnp_four_step", 256, 2, 30, "jnp", "four_step"),
    Preset("n64_t3_v31_jnp_strict", 64, 3, 31, "jnp", "radix2"),
    Preset("n64_t3_v30_pallas_radix2", 64, 3, 30, "pallas", "radix2"),
    Preset("n64_t3_v29_pallas_radix2", 64, 3, 29, "pallas", "radix2"),
    Preset("n256_t2_v30_pallas_four_step", 256, 2, 30, "pallas", "four_step"),
    Preset("n64_t3_v30_fused_radix2", 64, 3, 30, "pallas_fused", "radix2"),
    Preset("n256_t2_v30_fused_four_step", 256, 2, 30, "pallas_fused", "four_step"),
    Preset("n64_t2_v30_e2e_radix2", 64, 2, 30, "pallas_fused_e2e", "radix2"),
    Preset("n256_t2_v30_e2e_four_step", 256, 2, 30, "pallas_fused_e2e", "four_step"),
    Preset("n64_t2_v40_wide", 64, 2, 40, "auto", "radix2"),
    # Big-n hierarchical four-step (DESIGN §10): the n=4096 single-level
    # tile and the n=8192 depth-2 chain, traced through the channel-tiled
    # fused-e2e kernel (interpret-mode off TPU; the static sweep is the
    # gate — no overflow, envelope == bookkeeping, sublane_stages == 0).
    Preset("n4096_t2_v30_e2e_four_step", 4096, 2, 30, "pallas_fused_e2e", "four_step"),
    Preset("n8192_t2_v30_e2e_hier", 8192, 2, 30, "pallas_fused_e2e", "four_step:h"),
)


def registered_presets() -> Tuple[Preset, ...]:
    return PRESETS


@dataclasses.dataclass
class VerifyReport:
    plan_desc: Dict[str, Any]
    findings: List[Finding]
    envelopes: Dict[str, Dict[str, Any]]
    vmem: List[Dict[str, Any]]
    staticness: List[Dict[str, Any]]
    stats: Dict[str, Any]

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def codes(self) -> List[str]:
        return sorted({f.code for f in self.findings})

    def as_dict(self) -> Dict[str, Any]:
        return {
            "plan": self.plan_desc,
            "ok": self.ok,
            "findings": [f.as_dict() for f in self.findings],
            "envelopes": self.envelopes,
            "vmem": self.vmem,
            "staticness": self.staticness,
            "stats": self.stats,
        }

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.as_dict(), **kw)


def _canonical_seed(qctx: QCtx) -> AbsVal:
    from fractions import Fraction

    av = AbsVal(0, qctx.q_max - 1).with_qlin(Fraction(1), Fraction(-1), qctx)
    return av.with_qlo(Fraction(0), Fraction(0), qctx)


def _fresh_ctx(base: AnalysisContext, grid_cap: int) -> AnalysisContext:
    ctx = AnalysisContext(
        qctx=base.qctx,
        beta=base.beta,
        q_set=base.q_set,
        families=base.families,
        seed_const=base.seed_const,
        grid_cap=grid_cap,
        registry=base.registry,
    )
    return ctx


def _trace_specs(pl: Any) -> Dict[str, Tuple[Callable[..., Any], Tuple[Any, ...], str]]:
    """``name -> (callable, example_args, output_contract)`` for every
    datapath the plan serves.  Contracts: 'canonical' (residues < q) or
    'limbs' (< 2^w)."""
    import jax.numpy as jnp

    import repro

    cfg = pl.config
    n, t = cfg.n, cfg.t
    residues = jnp.zeros((t, n), dtype=jnp.int64)
    segments = jnp.zeros((n, cfg.seg_count), dtype=jnp.int64)
    specs: Dict[str, Tuple[Callable[..., Any], Tuple[Any, ...], str]] = {}
    if cfg.width in ("int64", "wide"):
        specs["ntt"] = (lambda a: repro.ntt(pl, a), (residues,), "none")
        specs["intt"] = (lambda a: repro.intt(pl, a), (residues,), "canonical")
        specs["polymul"] = (
            lambda za, zb: repro.polymul(pl, za, zb),
            (segments, segments),
            "limbs",
        )
    return specs


def _seed_for(name: str, arg_idx: int, pl: Any, qctx: QCtx) -> AbsVal:
    from fractions import Fraction

    if name in ("ntt", "intt"):
        return _canonical_seed(qctx)
    # base-2^v digit segments: nonnegative, < 2^v
    seg = AbsVal(0, (1 << pl.config.v) - 1)
    return seg.with_qlo(Fraction(0), Fraction(0), qctx)


def verify_plan(pl: Any, *, grid_cap: int = 64) -> VerifyReport:
    """Statically verify every kernel path of one plan.

    Proves per traced jaxpr that (a) no int64/int32 intermediate can
    overflow, (b) the derived lazy-reduction envelope matches or
    tightens the hand-kept ``ChannelTables`` bookkeeping, (c) transform
    outputs are canonical, i.e. the single exit ``canonicalize``
    suffices; plus the lane/VMEM lint and the staticness (leaf-
    threading) lint over the same traversal.  Returns a
    :class:`VerifyReport`; ``report.ok`` is False when any check could
    not be proven — unknown primitives and unproven preconditions fail
    closed."""
    import jax

    base = passes.build_context(pl, grid_cap=grid_cap)
    findings: List[Finding] = list(base.findings)
    envelopes: Dict[str, Dict[str, Any]] = {}
    vmem: List[Dict[str, Any]] = []
    staticness: List[Dict[str, Any]] = []
    stats: Dict[str, Any] = {"traces": {}, "selects_crosscheck": {}}
    cfg = pl.config
    ct = pl.params.tables
    log2n = int(math.log2(cfg.n))
    for name, (fn, args, contract) in sorted(_trace_specs(pl).items()):
        ctx = _fresh_ctx(base, grid_cap)
        try:
            closed = jax.make_jaxpr(fn)(*args)
        except Exception as e:
            ctx.finding("error", "trace-failed", name, f"{type(e).__name__}: {e}")
            findings.extend(ctx.findings)
            continue
        seeds = [_seed_for(name, i, pl, base.qctx) for i in range(len(args))]
        outs = analyze_closed_jaxpr(closed, seeds, ctx, where=name)
        _check_outputs(ctx, outs, contract, pl, name)
        if cfg.width == "int64" and cfg.backend.startswith("pallas"):
            # The lazy-window envelope is a property of the Shoup-based
            # pallas kernels; the jnp reference path reduces to
            # canonical after every butterfly and has no window to keep.
            n_transforms = {"ntt": 1, "intt": 1, "polymul": 3}[name]
            envelopes[name] = passes.check_envelope(
                ctx, ct, name, min_events=n_transforms * log2n
            )
        vmem.extend(passes.lane_vmem_lint(closed, pl, ctx, name))
        staticness.extend(passes.staticness_lint(closed, ctx, name))
        stats["traces"][name] = {
            "eqns": sum(ctx.prim_counts.values()),
            "prims": dict(sorted(ctx.prim_counts.items())),
            "shoup_events": len(ctx.stream),
        }
        findings.extend(ctx.findings)
    _selects_crosscheck(pl, findings, stats)
    sched = cfg.schedule
    desc = {
        "n": cfg.n, "t": cfg.t, "v": cfg.v, "width": cfg.width,
        "backend": cfg.backend, "schedule": str(sched),
        "schedule_depth": getattr(sched, "depth", 0),
        "lazy_window": None if ct is None else ct.lazy_window,
        "shoup_beta": None if ct is None else ct.shoup_beta,
    }
    return VerifyReport(desc, findings, envelopes, vmem, staticness, stats)


def _check_outputs(
    ctx: AnalysisContext, outs: Sequence[Any], contract: str, pl: Any, name: str
) -> None:
    from repro.analysis import domain as D

    if contract == "none":
        return
    for i, out in enumerate(outs):
        if not isinstance(out, AbsVal):
            continue
        if out.lo is None or out.hi is None:
            ctx.finding(
                "error", "unproven", name, f"output {i} has unbounded interval"
            )
            continue
        if contract == "canonical":
            units = D.units_of_q(out, ctx.qctx)
            if out.lo < 0 or units is None or units > 1:
                ctx.finding(
                    "error",
                    "canonicalize-insufficient",
                    name,
                    f"output {i} not provably canonical: [{out.lo}, {out.hi}]"
                    f" spans {units} units of q — one exit canonicalize does"
                    " not suffice",
                )
        elif contract == "limbs":
            w = pl.config.w
            if out.lo < 0 or out.hi >= (1 << w):
                ctx.finding(
                    "error",
                    "canonicalize-insufficient",
                    name,
                    f"output {i} not within base-2^{w} limb range: "
                    f"[{out.lo}, {out.hi}]",
                )


def _selects_crosscheck(
    pl: Any, findings: List[Finding], stats: Dict[str, Any]
) -> None:
    """Structural (c)-check: the traced reduction-select count equals the
    cost model's — one canonicalize per transform, no hidden extras."""
    cfg = pl.config
    if cfg.width != "int64" or cfg.backend not in ("pallas", "pallas_fused"):
        return
    from repro.kernels import ops as ops_mod

    for direction in ("fwd", "inv"):
        try:
            got = ops_mod.count_reduction_selects(
                pl.params, schedule=cfg.schedule, direction=direction
            )
            want = ops_mod.transform_cost_model(
                pl.params, schedule=cfg.schedule, direction=direction
            )["reduction_ops"]
        except Exception as e:  # pragma: no cover - defensive
            findings.append(
                Finding("error", "selects-crosscheck", direction, str(e))
            )
            continue
        stats["selects_crosscheck"][direction] = {"traced": got, "model": want}
        if got != want:
            findings.append(
                Finding(
                    "error",
                    "selects-crosscheck",
                    direction,
                    f"traced reduction selects {got} != cost model {want}",
                )
            )


# --------------------------------------------------------------------------
# mutation self-check
# --------------------------------------------------------------------------


def _mutated_shoup_plan(pl: Any) -> Any:
    """Loosen one Shoup constant by +1 (off-by-one precompute bug)."""
    from repro import api

    ct = pl.params.tables
    sh = np.array(ct.fwd_shoup)
    sh[0, 1] += 1
    ct2 = dataclasses.replace(ct, fwd_shoup=sh)
    params2 = dataclasses.replace(pl.params, tables=ct2)
    return api.plan_from_params(params2)


def _mutated_window_plan(pl: Any) -> Any:
    """Widen the lazy window 2 -> 4 in-memory, bypassing the constructor
    validation (exactly the hand-bookkeeping drift the verifier guards:
    at v=30 a window-4 Shoup product no longer fits 63 bits)."""
    from repro import api

    ct = pl.params.tables
    ct2 = copy.copy(ct)
    object.__setattr__(ct2, "lazy_window", 4)
    params2 = dataclasses.replace(pl.params, tables=ct2)
    return api.plan_from_params(params2)


def mutation_selfcheck(preset: Optional[Preset] = None) -> Dict[str, Any]:
    """Prove the analyzer is not vacuous: verify a healthy plan, then
    assert both in-memory mutations are flagged as errors."""
    if preset is None:
        preset = next(p for p in PRESETS if p.v == 30 and p.backend == "pallas")
    pl = preset.build_plan()
    baseline = verify_plan(pl)
    shoup_report = verify_plan(_mutated_shoup_plan(pl))
    window_report = verify_plan(_mutated_window_plan(pl))
    result = {
        "preset": preset.name,
        "baseline_ok": baseline.ok,
        "shoup_mutation_flagged": not shoup_report.ok,
        "shoup_mutation_codes": [f.code for f in shoup_report.errors()],
        "window_mutation_flagged": not window_report.ok,
        "window_mutation_codes": [f.code for f in window_report.errors()],
    }
    result["passed"] = bool(
        baseline.ok
        and result["shoup_mutation_flagged"]
        and result["window_mutation_flagged"]
    )
    return result
