"""Pallas TPU kernels for the batched NWC NTT / iNTT and the fused
no-shuffle polynomial-multiplication cascade (paper contribution 1 mapped
to the TPU memory hierarchy).

TPU mapping
-----------
* One grid step processes a (ROWS, n) tile of polynomials for one RNS
  channel, resident in VMEM; twiddles (n,) for that channel are also VMEM
  blocks.  Per-channel moduli and Barrett constants arrive as (1, 1)
  SMEM-style scalar blocks.
* The fused kernel runs NTT(a), NTT(b), the pointwise product and the
  iNTT inside ONE pallas_call: the NTT-domain product never exists in HBM.
  This is the TPU analogue of the paper's buffer-free NTT->iNTT cascade —
  on the FPGA the eliminated resource is the DSD shuffle buffer; here it
  is an HBM round-trip of 2 x ROWS x n x 8 bytes per channel.
* The fused *e2e* kernel goes one step further (the paper's full
  feed-forward datapath, Fig 10): CRT pre-processing, the cascade and
  CRT post-processing in ONE pallas_call, reusing the in-kernel stages
  of :mod:`repro.kernels.crt` — residue polynomials never exist in HBM
  either; only segments enter and product limbs leave.
* Butterfly pairing is expressed as reshapes (m, 2, t) of the trailing
  axis.  Stages with pair stride >= 128 keep the lane dimension intact;
  for stride < 128 a real-TPU deployment flips to the transposed-tile
  schedule (see DESIGN.md §6) — numerically identical, validated here in
  interpret mode.
* Butterfly modular arithmetic is imported from
  :mod:`repro.core.modmath` — the same helpers the pure-jnp reference
  oracle uses, so kernel and oracle cannot drift.  When ``shifts`` is
  given (static), the per-channel Barrett constant ``eps`` replaces the
  generic ``%`` in the butterfly multiply (paper's Barrett PE).

VMEM budget per grid step (n = 4096, ROWS = 8, int64):
  a, b tiles 2 x 256 KiB + twiddles 2 x 32 KiB + scratch ≈ 0.8 MiB << 128 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import modmath
from repro.core.modmath import add_mod, div2_mod, mul_mod, sub_mod
from repro.kernels.crt import compose_finalize, decompose_stage, require_dec

DEFAULT_ROWS = 8
DEFAULT_E2E_ROWS = 1  # polynomials per grid step of the fused e2e kernel


def _fwd_stages(a, fwd, q, eps=None, shifts=None):
    """CT/DIT stages on the last axis of a (rows, n) tile."""
    rows, n = a.shape
    m, t = 1, n
    while m < n:
        t //= 2
        w = jax.lax.slice_in_dim(fwd, m, 2 * m)  # static bounds
        x = a.reshape(rows, m, 2, t)
        u = x[:, :, 0, :]
        v = mul_mod(x[:, :, 1, :], w[None, :, None], q, eps, shifts)
        a = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=2).reshape(rows, n)
        m *= 2
    return a


def _inv_stages(a, inv, q, half, eps=None, shifts=None):
    """Mirror-order GS stages with the per-stage halving (Fig 9 PE)."""
    rows, n = a.shape
    h, t = n // 2, 1
    while h >= 1:
        w = jax.lax.slice_in_dim(inv, h, 2 * h)
        x = a.reshape(rows, h, 2, t)
        u, v = x[:, :, 0, :], x[:, :, 1, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[None, :, None], q, eps, shifts)
        a = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=2).reshape(rows, n)
        h //= 2
        t *= 2
    return a


# --------------------------------------------------------------------------
# kernels (shifts is a static closure arg; eps_ref is a dummy zero block
# when shifts is None and the butterflies fall back to generic %)
# --------------------------------------------------------------------------


def _ntt_kernel(q_ref, eps_ref, fwd_ref, a_ref, o_ref, *, shifts):
    q = q_ref[0]
    eps = eps_ref[0] if shifts is not None else None
    o_ref[...] = _fwd_stages(a_ref[...], fwd_ref[...], q, eps, shifts)


def _intt_kernel(q_ref, eps_ref, half_ref, inv_ref, a_ref, o_ref, *, shifts):
    q = q_ref[0]
    eps = eps_ref[0] if shifts is not None else None
    half = half_ref[0]
    o_ref[...] = _inv_stages(a_ref[...], inv_ref[...], q, half, eps, shifts)


def _fused_kernel(
    q_ref, eps_ref, half_ref, fwd_ref, inv_ref, a_ref, b_ref, o_ref, *, shifts
):
    q = q_ref[0]
    eps = eps_ref[0] if shifts is not None else None
    half = half_ref[0]
    fa = _fwd_stages(a_ref[...], fwd_ref[...], q, eps, shifts)
    fb = _fwd_stages(b_ref[...], fwd_ref[...], q, eps, shifts)
    prod = mul_mod(fa, fb, q, eps, shifts)  # never leaves VMEM
    o_ref[...] = _inv_stages(prod, inv_ref[...], q, half, eps, shifts)


def _fused_e2e_kernel(
    fwd_ref, inv_ref, star_ref, qlimb_ref, za_ref, zb_ref, o_ref,
    *, plan, scalars, shifts
):
    """The paper's full feed-forward datapath in ONE kernel: CRT
    pre-processing, the per-channel NTT -> ⊙ -> iNTT cascade and CRT
    post-processing, with every residue polynomial VMEM-resident.

    The channel loop is a static unroll: each iteration is one of the
    paper's t parallel specialized circuits, its moduli/Barrett/SAU
    constants baked in from the plan (``plan.dec`` + ``scalars``), its
    twiddles read from the (t, n) VMEM table blocks.  Only the segment
    tiles enter and the limb tile leaves through HBM.
    """
    za = za_ref[...]  # (blk, n, S)
    zb = zb_ref[...]
    acc = jnp.zeros(za.shape[:-1] + (plan.L,), dtype=za.dtype)
    for i, (qi, half, eps) in enumerate(scalars):
        ch = plan.dec[i]
        # Step 1: residual computation (Alg 2, SAU circuit)
        ra = decompose_stage(za, ch, seg_count=plan.seg_count,
                             t_prime=plan.t_prime)  # (blk, n)
        rb = decompose_stage(zb, ch, seg_count=plan.seg_count,
                             t_prime=plan.t_prime)
        # Step 2: no-shuffle NTT cascade, product never leaves VMEM
        fa = _fwd_stages(ra, fwd_ref[i], qi, eps, shifts)
        fb = _fwd_stages(rb, fwd_ref[i], qi, eps, shifts)
        prod = mul_mod(fa, fb, qi, eps, shifts)
        pi = _inv_stages(prod, inv_ref[i], qi, half, eps, shifts)
        # Step 3: this channel's Eq-10 contribution y_i * q_i^
        y = mul_mod(pi, int(plan.qi_tilde[i]), qi, eps, shifts)
        acc = acc + y[..., None] * star_ref[i][None, None, :]
    o_ref[...] = compose_finalize(acc, qlimb_ref[0], w=plan.w, t=plan.t)


# --------------------------------------------------------------------------
# pallas_call wrappers (grid = (channels, row_blocks))
# --------------------------------------------------------------------------


def _grid_specs(t: int, rows: int, n: int, row_blk: int):
    """Common BlockSpecs (leading channel axis squeezed with None):
    per-channel scalars, (n,) tables, (row_blk, n) data tiles."""
    scalar = pl.BlockSpec((None, 1), lambda c, r: (c, 0))
    table = pl.BlockSpec((None, n), lambda c, r: (c, 0))
    data = pl.BlockSpec((None, row_blk, n), lambda c, r: (c, r, 0))
    return scalar, table, data


def _pad_rows(x, row_blk):
    rows = x.shape[1]
    pad = (-rows) % row_blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, rows


def _eps_block(eps, qs, t):
    """(t, 1) Barrett-eps block; zeros (same dtype as qs) when unused."""
    if eps is None:
        return jnp.zeros_like(qs).reshape(t, 1)
    return eps.reshape(t, 1)


@functools.partial(jax.jit, static_argnames=("shifts", "row_blk", "interpret"))
def ntt_channels_pallas(
    a, qs, fwd, eps=None, *, shifts=None, row_blk: int = DEFAULT_ROWS, interpret: bool = True
):
    """a: (t, rows, n) -> forward NTT per channel.  qs: (t,), fwd: (t, n)."""
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        functools.partial(_ntt_kernel, shifts=shifts),
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, scalar, table, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(qs.reshape(t, 1), _eps_block(eps, qs, t), fwd, a)
    return out[:, :rows]


@functools.partial(jax.jit, static_argnames=("shifts", "row_blk", "interpret"))
def intt_channels_pallas(
    a, qs, half, inv, eps=None, *, shifts=None, row_blk: int = DEFAULT_ROWS, interpret: bool = True
):
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        functools.partial(_intt_kernel, shifts=shifts),
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, scalar, scalar, table, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(qs.reshape(t, 1), _eps_block(eps, qs, t), half.reshape(t, 1), inv, a)
    return out[:, :rows]


@functools.partial(jax.jit, static_argnames=("shifts", "row_blk", "interpret"))
def fused_polymul_pallas(
    a, b, qs, half, fwd, inv, eps=None, *, shifts=None,
    row_blk: int = DEFAULT_ROWS, interpret: bool = True,
):
    """(t, rows, n) x (t, rows, n) -> negacyclic products, fused cascade."""
    t, _, n = a.shape
    a, rows = _pad_rows(a, row_blk)
    b, _ = _pad_rows(b, row_blk)
    scalar, table, data = _grid_specs(t, a.shape[1], n, row_blk)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, shifts=shifts),
        grid=(t, a.shape[1] // row_blk),
        in_specs=[scalar, scalar, scalar, table, table, data, data],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(
        qs.reshape(t, 1),
        _eps_block(eps, qs, t),
        half.reshape(t, 1),
        fwd,
        inv,
        a,
        b,
    )
    return out[:, :rows]


@functools.partial(jax.jit, static_argnames=("plan", "row_blk", "interpret"))
def fused_e2e_polymul_pallas(
    za, zb, fwd, inv, star, q_limbs, *, plan,
    row_blk: int = DEFAULT_E2E_ROWS, interpret: bool = True,
):
    """za, zb: (rows, n, S) base-2^v segment tiles -> (rows, n, L) limbs
    of the negacyclic products mod q: decompose -> NTT -> ⊙ -> iNTT ->
    compose inside ONE pallas_call.

    fwd/inv: (t, n) twiddle tables, star: (t, L) q_i^ limbs, q_limbs:
    (L,) — all device-resident uploads off the tables/plan.  Grid is
    (row_blocks,): unlike the per-stage kernels there is no channel grid
    axis, because the Eq-10 recombination needs all t channels of a
    coefficient in one grid step; the channel loop unrolls inside.

    VMEM per grid step at the paper's point (n=4096, t=6, S=6, L=7,
    row_blk=1, int64): segments 2 x 192 KiB + twiddles 2 x 192 KiB +
    per-channel scratch ~3 x 32 KiB + limb acc 224 KiB ~= 1 MiB << 16 MiB.
    """
    require_dec(plan)
    rows, n, S = za.shape
    t, L = plan.t, plan.L
    scalars, shifts = modmath.channel_mul_constants(plan.qs)
    pad = (-rows) % row_blk
    if pad:
        zpad = ((0, pad), (0, 0), (0, 0))
        za = jnp.pad(za, zpad)
        zb = jnp.pad(zb, zpad)
    table = pl.BlockSpec((t, n), lambda r: (0, 0))
    data = pl.BlockSpec((row_blk, n, S), lambda r: (r, 0, 0))
    out = pl.pallas_call(
        functools.partial(
            _fused_e2e_kernel, plan=plan, scalars=scalars, shifts=shifts
        ),
        grid=(za.shape[0] // row_blk,),
        in_specs=[
            table,
            table,
            pl.BlockSpec((t, L), lambda r: (0, 0)),
            pl.BlockSpec((1, L), lambda r: (0, 0)),
            data,
            data,
        ],
        out_specs=pl.BlockSpec((row_blk, n, L), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((za.shape[0], n, L), za.dtype),
        interpret=interpret,
    )(fwd, inv, star, q_limbs.reshape(1, L), za, zb)
    return out[:rows]
