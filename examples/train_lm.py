"""End-to-end training driver: train an LM with the full runtime —
deterministic data stream, AdamW, remat, fault-tolerant checkpointing
(kill/resume safe), step-time percentiles.

The default invocation trains the REAL mamba2-130m configuration (~130M
params — the assignment's ~100M end-to-end driver) for a small number of
steps sized for a single CPU core; pass --steps 300 --seq 1024 on real
hardware.  Any --arch from the registry works; --reduced trains the
smoke-scale variant (fast demo).

Run:  PYTHONPATH=src python examples/train_lm.py --reduced --steps 30
      PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 300
"""
import argparse

import jax

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.train import data as data_mod
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(
        model=cfg,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        remat=True,
        learning_rate=3e-3,
    )
    dc = data_mod.DataConfig(batch=args.batch, seq_len=args.seq)
    trainer = Trainer(run, dc, total_steps=args.steps)
    n_dev = len(jax.devices())
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"for {args.steps} steps on {n_dev} device(s); "
          f"resume-safe checkpoints -> {args.ckpt_dir}")
    params, _, hist = trainer.train(jax.random.PRNGKey(0), steps=args.steps)
    from repro.models.model import param_count

    print(f"[done] {param_count(params)/1e6:.1f}M params, "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
