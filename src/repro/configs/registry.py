"""Architecture registry: ``--arch <id>`` resolution for launcher,
dry-run, benchmarks and tests.  long_500k eligibility / decode support are
derived from the config (see DESIGN.md §Arch-applicability)."""
from __future__ import annotations

from repro.configs import (
    dbrx_132b,
    deepseek_7b,
    gemma2_2b,
    llama4_maverick_400b_a17b,
    mamba2_130m,
    mistral_large_123b,
    qwen2_vl_7b,
    seamless_m4t_medium,
    yi_6b,
    zamba2_2p7b,
)
from repro.configs.base import SHAPES, ModelConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen2_vl_7b.CONFIG,
        yi_6b.CONFIG,
        deepseek_7b.CONFIG,
        mistral_large_123b.CONFIG,
        gemma2_2b.CONFIG,
        llama4_maverick_400b_a17b.CONFIG,
        dbrx_132b.CONFIG,
        mamba2_130m.CONFIG,
        seamless_m4t_medium.CONFIG,
        zamba2_2p7b.CONFIG,
    ]
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  Skips (with reasons) follow the
    assignment rules: long_500k only for sub-quadratic archs."""
    out = []
    for name, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            skip = ""
            if sname == "long_500k" and not cfg.subquadratic:
                skip = "full-attention arch: 500k dense attention excluded per assignment"
            if include_skipped or not skip:
                out.append((cfg, shape, skip))
    return out
