"""Flash-attention Pallas kernel vs the reference einsum attention:
shape/flag sweep in interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.attention import flash_attention, hbm_bytes_per_call


def ref_attention(q, k, v, *, causal=True, window=None, softcap=0.0, q_offset=0):
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    g = H // Hk
    qf = q.astype(jnp.float32) / np.sqrt(D)
    qg = qf.reshape(B, Sq, Hk, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + np.arange(Sq)
    k_pos = np.arange(Skv)
    mask = np.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D)


def _mk(B, Sq, Skv, H, Hk, D, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hk, D)).astype(np.float32), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hk, D)).astype(np.float32), dtype=dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,Sq,Skv,H,Hk,D",
        [
            (1, 128, 128, 4, 4, 32),  # MHA square
            (2, 256, 256, 4, 2, 32),  # GQA
            (1, 128, 384, 8, 2, 64),  # Sq < Skv (chunked prefill)
            (1, 96, 160, 4, 4, 32),  # non-multiple of block (padding)
        ],
    )
    def test_matches_ref_causal(self, B, Sq, Skv, H, Hk, D):
        q, k, v = _mk(B, Sq, Skv, H, Hk, D)
        got = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        q, k, v = _mk(1, 128, 128, 4, 4, 32, seed=1)
        got = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_sliding_window(self):
        q, k, v = _mk(1, 256, 256, 4, 4, 32, seed=2)
        got = flash_attention(q, k, v, causal=True, window=64, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_softcap(self):
        q, k, v = _mk(1, 128, 128, 4, 2, 32, seed=3)
        got = flash_attention(q, k, v, softcap=50.0, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v, softcap=50.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_decode_q_offset(self):
        """Single-query decode against a deep cache."""
        q, k, v = _mk(2, 1, 256, 4, 4, 32, seed=4)
        got = flash_attention(q, k, v, causal=True, q_offset=200, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v, causal=True, q_offset=200)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("blk", [32, 64, 128])
    def test_block_size_invariance(self, blk):
        q, k, v = _mk(1, 256, 256, 2, 2, 32, seed=5)
        got = flash_attention(q, k, v, blk_q=blk, blk_k=blk)
        want = ref_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_bf16_io(self):
        q, k, v = _mk(1, 128, 128, 4, 4, 32, seed=6, dtype=jnp.bfloat16)
        got = flash_attention(q, k, v, blk_q=64, blk_k=64)
        want = ref_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want), rtol=2e-2, atol=2e-2
        )

    def test_traffic_model_vs_naive(self):
        """Analytic HBM traffic: kernel beats materialized S^2 by >>10x at 32k."""
        B, S, H, Hk, D = 2, 32768, 28, 4, 128
        naive = 3 * 4 * B * H * S * S  # f32 scores: 1 write + 2 reads
        flash = hbm_bytes_per_call(B, S, S, H, Hk, D)
        assert naive / flash > 100, naive / flash
