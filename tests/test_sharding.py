"""Partition-rule unit tests: param specs, divisibility enforcement,
batch/cache specs, activation policy behavior on a 1-device named mesh."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.models import model as M
from repro.sharding import ctx, partition


def _mesh2():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class TestParamSpecs:
    def test_dense_rules(self):
        cfg = registry.get("yi-6b").reduced()
        shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        specs = partition.param_specs(shapes)
        flat = {
            "/".join(str(getattr(p, "key", p)) for p in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(specs)
        }
        assert flat["embed"] == P("model", "data")
        # stacked layers get a leading None
        wq = [v for k, v in flat.items() if k.endswith("wq")][0]
        assert wq == P(None, "data", "model")
        wo = [v for k, v in flat.items() if k.endswith("wo")][0]
        assert wo == P(None, "model", "data")
        scale = [v for k, v in flat.items() if k.endswith("scale")][0]
        assert all(a is None for a in scale)  # replicated (None-padded P())

    def test_moe_rules(self):
        cfg = registry.get("dbrx-132b").reduced()
        shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        specs = partition.param_specs(shapes)
        flat = {
            "/".join(str(getattr(p, "key", p)) for p in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(specs)
        }
        weg = [v for k, v in flat.items() if k.endswith("we_gate")][0]
        assert weg == P(None, "model", "data", None)

    def test_divisibility_enforcement(self):
        mesh = _mesh2()
        # (mock a 16-way axis by hand: use enforce on shapes not divisible)
        shapes = {"wq": jax.ShapeDtypeStruct((30, 64), jnp.float32)}
        specs = {"wq": P("data", "model")}

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        out = partition.enforce_divisibility(specs, shapes, FakeMesh())
        assert out["wq"] == P(None, "model")  # 30 % 16 != 0 -> dropped

    def test_batch_spec_fallback(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        assert partition.batch_shard_spec(FakeMesh(), (256, 128)) == P(("data",), None)
        assert partition.batch_shard_spec(FakeMesh(), (1, 128)) == P(None, None)


class TestCacheSpecs:
    def test_kv_and_ssm(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cache = {
            "k": jax.ShapeDtypeStruct((4, 128, 1024, 32, 128), jnp.bfloat16),
            "ssm": jax.ShapeDtypeStruct((4, 128, 32, 64, 128), jnp.float32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = partition.cache_specs(cache, FakeMesh())
        # flash-decoding layout: KV sharded on the sequence dim
        assert specs["k"] == P(None, ("data",), "model", None, None)
        assert specs["ssm"] == P(None, ("data",), "model", None, None)
        assert specs["pos"] == P()

    def test_kv_seq_fallback_chain(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        # seq 1000 not divisible -> fall back to kv heads (32 divides 16)
        cache = {"k": jax.ShapeDtypeStruct((4, 128, 1000, 32, 128), jnp.bfloat16)}
        specs = partition.cache_specs(cache, FakeMesh())
        assert specs["k"] == P(None, ("data",), None, "model", None)


class TestActivationPolicy:
    def test_noop_without_policy(self):
        x = jnp.ones((4, 8, 16))
        assert ctx.constrain(x, "btd") is x

    def test_policy_applies_on_mesh(self):
        mesh = _mesh2()
        with ctx.activation_policy(ctx.make_mesh_policy(mesh)):
            x = jnp.ones((4, 8, 16))
            y = ctx.constrain(x, "btd")  # divisible by 1-device axes
            assert y.shape == x.shape

    def test_moe_scatter_matches_plain(self):
        mesh = _mesh2()
        rng = np.random.default_rng(0)
        slot = jnp.asarray(rng.integers(0, 9, size=(2, 12)))
        xk = jnp.asarray(rng.normal(size=(2, 12, 4)).astype(np.float32))

        def plain(slot, xk):
            def one(s, x):
                return jnp.zeros((10, 4), xk.dtype).at[s].add(x)

            return jax.vmap(one)(slot, xk)

        want = plain(slot, xk)
        with ctx.activation_policy(ctx.make_mesh_policy(mesh)):
            got = ctx.moe_scatter(slot, xk, 10)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_moe_gather_matches_plain(self):
        mesh = _mesh2()
        rng = np.random.default_rng(1)
        eout = jnp.asarray(rng.normal(size=(2, 10, 4)).astype(np.float32))
        slot = jnp.asarray(rng.integers(0, 10, size=(2, 12)))
        want = jnp.take_along_axis(eout, slot[..., None], axis=1)
        with ctx.activation_policy(ctx.make_mesh_policy(mesh)):
            got = ctx.moe_gather(eout, slot)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestWideMeshSharding:
    """PR 5 follow-up: the digit-split wide datapath under the
    (data=2, model=2) mesh.  Shard-local kernels rebuild their channel
    specs from the sharded ``wide_qs``/``wide_betas`` leaves (a
    channel-offset view, ``api._wide_exec_specs``), so each model shard
    indexes ITS channels — not channels [0, t/2)."""

    def test_polymul_sharded_wide_bit_exact(self, host_mesh_4):
        import repro
        from repro.serve.crypto_engine import polymul_sharded

        rng = np.random.default_rng(12)
        pl = repro.plan(n=32, t=4, v=45)  # wide width; 4 channels % 2 == 0
        shape = (2, pl.n, pl.config.seg_count)
        za = jnp.asarray(rng.integers(0, 1 << pl.v, size=shape))
        zb = jnp.asarray(rng.integers(0, 1 << pl.v, size=shape))
        want = np.asarray(repro.polymul(pl, za, zb))
        got = polymul_sharded(pl, za, zb, mesh=host_mesh_4)
        assert np.array_equal(np.asarray(got), want)

    @pytest.mark.slow  # 4-device wide shard_map recompile (~80 s)
    def test_negacyclic_mul_sharded_wide_bit_exact(self, host_mesh_4):
        import repro
        from repro.serve.crypto_engine import negacyclic_mul_sharded

        rng = np.random.default_rng(13)
        pl = repro.plan(n=32, t=4, v=45)
        res = jnp.asarray(
            np.stack(
                [
                    rng.integers(0, int(q), size=(2, pl.n))
                    for q in pl.params.plan.qs
                ]
            )
        )
        want = np.asarray(repro.negacyclic_mul(pl, res, res))
        got = negacyclic_mul_sharded(pl, res, res, mesh=host_mesh_4)
        assert np.array_equal(np.asarray(got), want)

    @pytest.mark.slow  # two 4-device wide compiles (~2 min)
    def test_wide_sharded_reads_leaves_not_constants(self, host_mesh_4):
        """The channel-offset view must come from the LEAVES: perturbing
        the sharded ``wide_qs`` leaf changes the sharded result (if the
        shard-local kernels re-derived specs from the static params,
        this would be a no-op)."""
        import repro
        from repro import api
        from repro.serve.crypto_engine import negacyclic_mul_sharded

        rng = np.random.default_rng(14)
        pl = repro.plan(n=32, t=4, v=45)
        res = jnp.asarray(
            np.stack(
                [
                    rng.integers(1, int(q), size=(2, pl.n))
                    for q in pl.params.plan.qs
                ]
            )
        )
        want = np.asarray(negacyclic_mul_sharded(pl, res, res, mesh=host_mesh_4))
        broken_consts = dict(pl.consts)
        broken_consts["wide_qs"] = broken_consts["wide_qs"] + 2
        broken = api.Plan(
            config=pl.config, params=pl.params, consts=broken_consts
        )
        got = np.asarray(
            negacyclic_mul_sharded(broken, res, res, mesh=host_mesh_4)
        )
        assert not np.array_equal(got, want)
