"""Paper Tables VI/VII: the end-to-end PaReNTT modular polynomial
multiplier at the paper's operating point (n=4096, 180-bit q, t=6/v=30).

Reported: BPP / latency cycle model at 240 MHz (the paper's clock), the
measured CPU wall-clock of the full jit pipeline through the PUBLIC
backend-dispatch layer for BOTH the ``jnp`` and ``pallas_fused``
datapaths, a bit-exactness check of the fused path against the Python
bigint oracle, and the 49.2x latency comparison against Roy [7]
re-derived from the cycle model.

Note on absolute numbers: off-TPU the Pallas kernels run in *interpret*
mode, so their wall-clock here measures the emulation, not the silicon;
the comparison that matters off-TPU is the HBM round-trip model (bytes
crossing kernel boundaries per backend, ``ops.hbm_traffic_model``) plus
bit-exactness of every kernel path.

``python -m benchmarks.polymul_e2e --ci-smoke --out BENCH_ci.json``
runs the small-preset interpret-mode smoke used by the ``bench-smoke``
CI job: it records wall-clock + modeled HBM bytes for all four
backends across BOTH stage schedules (radix2 / four_step), checks every
path bit-exact against the bigint oracle, verifies the
reduction-op/lane-alignment cost model against the traced kernels,
executes one n=4096 four-step fused-e2e point bit-exact against the
host-NTT bigint oracle (recording its frozen ScheduleSpec tile), and
exits non-zero if any fusion/lane/lazy invariant regressed.  With
``--baseline BENCH_seed.json`` it additionally diffs op counts and
modeled HBM bytes against the committed baseline, so the perf
trajectory is tracked in-repo instead of only as a build artifact.
``--compiled`` adds an AOT compiled wall-clock column
(``compiled_us_per_poly``, via ``repro.tune.sweep.measure_plan``)
beside the interpret numbers in the same record.
"""
import argparse
import json
import random
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.core import schedule as sched
from repro.kernels import ops as ops_mod

FREQ = 240e6  # paper's post-pipelining clock
CONCRETE_SCHEDULES = ("radix2", "four_step")


def _plan(p, backend: str = "auto", schedule: str = "auto"):
    """api plan for a params preset (one code path for every width)."""
    return repro.plan(
        n=p.n, t=p.t, v=p.v, backend=backend, schedule=schedule,
        row_blk=p.row_blk,
    )


# ONE jitted executor for every timing row: same-config plans hit the
# same compiled entry (the retrace-free property tests/test_api.py gates)
_MUL = jax.jit(repro.polymul)


def _time_plan(pl, za, zb, iters: int = 3) -> float:
    """us per polynomial through jax.jit(repro.polymul) on one plan."""
    batch = za.shape[0]
    jax.block_until_ready(_MUL(pl, za, zb))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(_MUL(pl, za, zb))
    return (time.perf_counter() - t0) / iters / batch * 1e6


def _cost_model_record(p) -> dict:
    """Per-schedule reduction-op/lane-alignment model for one preset,
    cross-checked against the traced kernels (fwd direction; the inverse
    is asserted by tests/test_schedules.py)."""
    out = {}
    for schedule in CONCRETE_SCHEDULES:
        fwd = ops_mod.transform_cost_model(p, schedule=schedule)
        inv = ops_mod.transform_cost_model(p, schedule=schedule, direction="inv")
        out[schedule] = {
            "sublane_stages": fwd["sublane_stages"],
            "lazy_window": fwd["lazy_window"],
            "reduction_ops_fwd": fwd["reduction_ops"],
            "reduction_ops_inv": inv["reduction_ops"],
            "strict_reduction_ops": fwd["strict_reduction_ops"],
            "traced_selects_fwd": ops_mod.count_reduction_selects(
                p, schedule=schedule
            ),
        }
    return out


def run(row_blk: int | None = None):
    out = []
    n = 4096
    bpp = sched.bpp_cycles(n)
    lat = sched.latency_cycles(n, t_pipe=152)  # paper reports 4246-4254
    out.append(
        (
            "tableVII_cycle_model",
            lat / FREQ * 1e6,
            f"bpp={bpp}cyc({bpp/FREQ*1e6:.1f}us) latency={lat}cyc "
            f"({lat/FREQ*1e6:.1f}us) paper=17.4-17.7us",
        )
    )
    roy_cycles = 196_003  # paper's normalized Roy [7] latency (§V-D)
    out.append(
        (
            "tableVII_vs_roy_hpca19",
            roy_cycles / 225e6 * 1e6,
            f"roy=871.1us ours={lat/FREQ*1e6:.1f}us "
            f"reduction={roy_cycles/225e6/(lat/FREQ):.1f}x (paper: 49.2x)",
        )
    )
    # bit-exactness gate: the fused Pallas path vs the Python bigint
    # oracle (and the schoolbook), at a size where the O(n^2) oracle is
    # fast.  Runs through the same public dispatch layer as the timing.
    pchk = params_mod.make_params(n=256, t=6, v=30, row_blk=row_blk)
    rchk = random.Random(0)
    ca = [rchk.randrange(pchk.q) for _ in range(pchk.n)]
    cb = [rchk.randrange(pchk.q) for _ in range(pchk.n)]
    fused_ints = repro.polymul_ints(_plan(pchk, backend="pallas_fused"), ca, cb)
    oracle_ints = pm.oracle_multiply(ca, cb, pchk)
    if fused_ints != oracle_ints or fused_ints != pm.schoolbook_negacyclic(ca, cb, pchk.q):
        raise AssertionError("pallas_fused != bigint oracle at n=256/t=6/v=30")
    out.append(
        (
            "fused_vs_bigint_oracle_n256",
            0.0,
            "pallas_fused bit-exact vs oracle_multiply + schoolbook (n=256, t=6, v=30)",
        )
    )
    e2e_ints = repro.polymul_ints(
        _plan(pchk, backend="pallas_fused_e2e"), ca, cb
    )
    if e2e_ints != oracle_ints:
        raise AssertionError("pallas_fused_e2e != bigint oracle at n=256/t=6/v=30")
    out.append(
        (
            "fused_e2e_vs_bigint_oracle_n256",
            0.0,
            "pallas_fused_e2e bit-exact vs oracle_multiply (n=256, t=6, v=30)",
        )
    )
    # HBM round-trip delta across all four backends (n=256, t=6, batch=4):
    # wall-clock through the public dispatch layer + the bytes-moved model
    # (what the paper's feed-forward datapath eliminates; exact by
    # construction of the dispatch layer, see ops.hbm_traffic_model).
    rng_s = np.random.default_rng(1)
    bs = 4
    zs = [
        jnp.asarray(
            rng_s.integers(0, 1 << 30, size=(bs, pchk.n, pchk.plan.seg_count))
        )
        for _ in range(2)
    ]
    base = ops_mod.hbm_traffic_model(pchk, rows=bs, backend="pallas")
    for bk in ops_mod.BACKENDS:
        us_bk = _time_plan(_plan(pchk, backend=bk), zs[0], zs[1])
        m = ops_mod.hbm_traffic_model(pchk, rows=bs, backend=bk)
        out.append(
            (
                f"hbm_roundtrips_n256_{bk}",
                us_bk,
                f"hbm_bytes={m['hbm_bytes']} ({m['kernel_launches']} kernel "
                f"launches, {m['intermediate_bytes']} intermediate) "
                f"vs 3-kernel path {base['hbm_bytes']}: "
                f"{base['hbm_bytes'] / m['hbm_bytes']:.2f}x less traffic",
            )
        )
    # per-schedule op-count + wall-clock columns: the lane-aligned
    # four-step schedule vs the flat radix-2 loop, both with the Harvey
    # lazy butterflies the cost model accounts for
    cmod = _cost_model_record(pchk)
    for schedule in CONCRETE_SCHEDULES:
        us_s = _time_plan(
            _plan(pchk, backend="pallas_fused", schedule=schedule),
            zs[0], zs[1],
        )
        c = cmod[schedule]
        out.append(
            (
                f"schedule_n256_{schedule}_pallas_fused",
                us_s,
                f"sublane_stages={c['sublane_stages']} "
                f"reduction_ops/transform={c['reduction_ops_fwd']} "
                f"(strict {c['strict_reduction_ops']}, lazy window "
                f"{c['lazy_window']}); traced={c['traced_selects_fwd']}",
            )
        )
    # measured: full pipeline (t=6, v=30, n=4096), both datapaths through
    # the public backend-dispatch layer
    p = params_mod.make_params(n=4096, t=6, v=30, row_blk=row_blk)
    rng = np.random.default_rng(0)
    batch = 4
    za = jnp.asarray(
        rng.integers(0, 1 << 30, size=(batch, n, p.plan.seg_count))
    )
    zb = jnp.asarray(rng.integers(0, 1 << 30, size=(batch, n, p.plan.seg_count)))
    us = _time_plan(_plan(p, backend="jnp"), za, zb)
    out.append(
        (
            "tableVI_measured_polymul_t6_v30_jnp",
            us,
            f"per 4096-coeff 180-bit modular polymul (backend=jnp, CPU, batch={batch})",
        )
    )
    us_fused = _time_plan(_plan(p, backend="pallas_fused"), za, zb)
    mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
    out.append(
        (
            "tableVI_measured_polymul_t6_v30_pallas_fused",
            us_fused,
            f"per 4096-coeff 180-bit modular polymul (backend=pallas_fused, "
            f"{mode} mode, batch={batch})",
        )
    )
    # throughput in NTT-channel butterflies/s for context
    butterflies = 6 * 3 * (n // 2) * 12  # t * (2 NTT + iNTT) * n/2 * log n
    out.append(
        (
            "tableVI_butterfly_rate",
            0.0,
            f"{butterflies / (us/1e6) / 1e6:.1f}M butterflies/s on 1 CPU core",
        )
    )
    # Table VI's t=4 vs t=6 comparison, both measured in-JAX and both
    # through the SAME repro.polymul entry (t=4/v=45 resolves to the
    # digit-split wide datapath at plan time)
    pl4 = repro.plan(n=4096, t=4, v=45)
    za4 = jnp.asarray(
        rng.integers(0, 1 << 45, size=(batch, n, pl4.config.seg_count))
    )
    zb4 = jnp.asarray(
        rng.integers(0, 1 << 45, size=(batch, n, pl4.config.seg_count))
    )
    us4 = _time_plan(pl4, za4, zb4)
    out.append(
        (
            "tableVI_measured_polymul_t4_v45",
            us4,
            f"wide digit-split datapath; t6/t4 time ratio={us/us4:.2f} "
            f"(paper: t=6 wins on ABP/power)",
        )
    )
    # beyond-paper (§Perf P4): fused cascade HBM-traffic model.  Unfused:
    # NTT(a) out, NTT(b) out, product in x2/out, iNTT in = 6 HBM crossings
    # of (rows, n) int64 per channel beyond inputs/outputs; fused kernel
    # keeps everything VMEM-resident: only a/b in + p out cross HBM.
    row_bytes = 8 * n
    unfused = 8 * row_bytes  # 2 in + 2 ntt-out + prod(w+r via 2 reads) + intt in/out
    fused = 3 * row_bytes  # a in, b in, p out
    out.append(
        (
            "perfP4_fused_cascade_traffic",
            0.0,
            f"unfused={unfused/1024:.0f}KiB/row-channel fused={fused/1024:.0f}KiB "
            f"reduction={unfused/fused:.1f}x (plus the paper's zero-permutation property)",
        )
    )
    return out


# --------------------------------------------------------------------------
# CI smoke (the `bench-smoke` job): small preset, interpret mode
# --------------------------------------------------------------------------


def diff_against_baseline(rec: dict, baseline: dict) -> list[str]:
    """Regression diff of the structural columns (op counts + modeled
    HBM bytes; wall-clock is machine-dependent and NOT gated).  A metric
    may improve or hold; growing past the committed baseline fails."""
    fails = []
    for bk, r in rec["backends"].items():
        base = baseline.get("backends", {}).get(bk)
        if not base:
            continue
        for key in ("hbm_bytes", "kernel_launches"):
            if r[key] > base[key]:
                fails.append(
                    f"baseline regression [{bk}].{key}: {r[key]} > "
                    f"committed {base[key]}"
                )
    for scope in ("cost_model", "cost_model_n256"):
        for schedule, c in rec.get(scope, {}).items():
            base = baseline.get(scope, {}).get(schedule)
            if not base:
                continue
            for key in (
                "sublane_stages", "reduction_ops_fwd", "reduction_ops_inv",
            ):
                if c[key] > base[key]:
                    fails.append(
                        f"baseline regression [{scope}.{schedule}].{key}: "
                        f"{c[key]} > committed {base[key]}"
                    )
    for name, c in rec.get("big_n", {}).items():
        base = baseline.get("big_n", {}).get(name)
        if not base:
            continue
        for key in ("hbm_bytes", "kernel_launches", "tile_bytes", "depth"):
            if c[key] > base[key]:
                fails.append(
                    f"baseline regression [big_n.{name}].{key}: "
                    f"{c[key]} > committed {base[key]}"
                )
    return fails


def run_ci_smoke(out_path: str, n: int = 64, t: int = 3, v: int = 30,
                 batch: int = 2, baseline_path: str | None = None,
                 compiled: bool = False) -> dict:
    """Benchmark the small preset across all four backends and BOTH
    stage schedules, write the result JSON, and enforce:

    * fusion — the fused-e2e path moves fewer HBM bytes than the
      three-kernel path, traces to exactly 1 pallas_call, and every
      (backend, schedule) pair is bit-exact vs the bigint oracle;
    * the launch counts and reduction-op counts the models claim match
      the traced computations;
    * lane alignment — the four-step schedule has 0 sub-128-lane stages,
      here and at the n=256 operating preset (structural, no execution);
    * lazy reduction — modeled reduction ops per transform are >= 2x
      below the strict butterfly count whenever the lazy window is on;
    * optionally, no op-count/HBM-byte regression vs a committed
      baseline JSON (``BENCH_seed.json``).

    With ``compiled=True`` every (backend, schedule) row additionally
    records ``compiled_us_per_poly`` + ``compile_s`` from the AOT chain
    (``jax.jit(...).lower(...).compile()`` — a real XLA:CPU compile
    today; see ``repro.tune.sweep.measure_plan``) beside the interpret
    ``us_per_poly``.  Wall-clock columns stay un-gated either way.
    """
    p = params_mod.make_params(n=n, t=t, v=v)
    rng = random.Random(7)
    a = [rng.randrange(p.q) for _ in range(p.n)]
    b = [rng.randrange(p.q) for _ in range(p.n)]
    oracle = pm.oracle_multiply(a, b, p)
    rng_np = np.random.default_rng(7)
    za = jnp.asarray(
        rng_np.integers(0, 1 << v, size=(batch, n, p.plan.seg_count))
    )
    zb = jnp.asarray(
        rng_np.integers(0, 1 << v, size=(batch, n, p.plan.seg_count))
    )
    rec = {
        "preset": {"n": n, "t": t, "v": v, "batch": batch},
        "mode": "compiled" if jax.default_backend() == "tpu" else "interpret",
        "compiled_mode": compiled,
        "backends": {},
    }
    if compiled:
        from repro.tune import sweep as sweep_mod
    for bk in ops_mod.BACKENDS:
        model = ops_mod.hbm_traffic_model(p, rows=batch, backend=bk)
        r = {
            "hbm_bytes": model["hbm_bytes"],
            "kernel_launches": model["kernel_launches"],
            # structural ground truth: pallas_call eqns in the traced
            # computation; must equal the model's claim or the gate fails
            "traced_pallas_calls": ops_mod.count_pallas_launches(
                p, backend=bk, rows=batch
            ),
            "intermediate_bytes": model["intermediate_bytes"],
            "schedules": {},
        }
        for schedule in CONCRETE_SCHEDULES:
            pl = _plan(p, backend=bk, schedule=schedule)
            us = _time_plan(pl, za, zb, iters=1)
            exact = repro.polymul_ints(pl, a, b) == oracle
            rs = {
                "us_per_poly": us,
                "bit_exact_vs_oracle": exact,
            }
            if compiled:
                m = sweep_mod.measure_plan(pl, za, zb, iters=3, warmup=1)
                rs["compiled_us_per_poly"] = (
                    m["us_per_poly"] if m["mode"] == "compiled" else None
                )
                rs["compile_s"] = m["compile_s"]
            r["schedules"][schedule] = rs
        rec["backends"][bk] = r
    rec["cost_model"] = _cost_model_record(p)
    # the lane-alignment claim is about the operating point (n >= 256
    # where the tile reaches the full 128-lane width): record it
    # structurally — models + traced kernels, no interpret-mode execution
    rec["cost_model_n256"] = _cost_model_record(
        params_mod.make_params(n=256, t=6, v=30)
    )
    # big-n point (PR 7): the n=4096 four-step operating size through the
    # fused-e2e Pallas path, bit-exact vs the host-NTT bigint oracle, with
    # the frozen ScheduleSpec's VMEM tile recorded so tiling regressions
    # show up in the baseline diff (interpret mode: one execution, t=2
    # keeps the smoke under a few seconds)
    p4k = params_mod.make_params(n=4096, t=2, v=30)
    pl4k = _plan(p4k, backend="pallas_fused_e2e", schedule="four_step")
    spec4k = pl4k.config.schedule
    rng4k = random.Random(11)
    a4 = [rng4k.randrange(p4k.q) for _ in range(p4k.n)]
    b4 = [rng4k.randrange(p4k.q) for _ in range(p4k.n)]
    t0 = time.perf_counter()
    got4k = repro.polymul_ints(pl4k, a4, b4)
    us4k = (time.perf_counter() - t0) * 1e6
    m4k = ops_mod.hbm_traffic_model(p4k, rows=1, backend="pallas_fused_e2e")
    rec["big_n"] = {
        "n4096_fused_e2e_four_step": {
            "schedule": str(spec4k),
            "depth": spec4k.depth,
            "row_blk": spec4k.row_blk,
            "tile_bytes": spec4k.tile_bytes,
            "vmem_budget": spec4k.vmem_budget,
            "hbm_bytes": m4k["hbm_bytes"],
            "kernel_launches": m4k["kernel_launches"],
            "us_per_poly": us4k,
            "bit_exact_vs_oracle": got4k == pm.oracle_multiply(a4, b4, p4k),
        }
    }
    fused = rec["backends"]["pallas_fused_e2e"]
    three = rec["backends"]["pallas"]
    rec["fused_e2e_hbm_reduction_vs_pallas"] = (
        three["hbm_bytes"] / fused["hbm_bytes"]
    )
    failures = []
    if fused["hbm_bytes"] >= three["hbm_bytes"]:
        failures.append(
            f"fused-e2e moves {fused['hbm_bytes']} HBM bytes but the "
            f"three-kernel path moves {three['hbm_bytes']}: fusion regressed"
        )
    for bk, r in rec["backends"].items():
        if r["traced_pallas_calls"] != r["kernel_launches"]:
            failures.append(
                f"backend {bk}: traffic model claims "
                f"{r['kernel_launches']} kernel launches but the traced "
                f"computation contains {r['traced_pallas_calls']} "
                f"pallas_calls — the model no longer matches the dispatch"
            )
        for schedule, rs in r["schedules"].items():
            if not rs["bit_exact_vs_oracle"]:
                failures.append(
                    f"backend {bk} / schedule {schedule} is not bit-exact "
                    "vs the bigint oracle"
                )
    if fused["traced_pallas_calls"] != 1:
        failures.append(
            f"fused-e2e path traces to {fused['traced_pallas_calls']} "
            "pallas_calls, expected exactly 1: the e2e fusion was undone"
        )
    for scope in ("cost_model", "cost_model_n256"):
        cm = rec[scope]
        if cm["four_step"]["sublane_stages"] != 0:
            failures.append(
                f"{scope}: four_step schedule has "
                f"{cm['four_step']['sublane_stages']} sub-128-lane stages, "
                "expected 0 — the lane-aligned schedule regressed"
            )
        for schedule, c in cm.items():
            if c["traced_selects_fwd"] != c["reduction_ops_fwd"]:
                failures.append(
                    f"{scope}.{schedule}: model claims "
                    f"{c['reduction_ops_fwd']} reduction ops but the traced "
                    f"kernel contains {c['traced_selects_fwd']} selects"
                )
            if (
                c["lazy_window"] is not None
                and 2 * c["reduction_ops_fwd"] > c["strict_reduction_ops"]
            ):
                failures.append(
                    f"{scope}.{schedule}: lazy reduction saves < 2x "
                    f"({c['reduction_ops_fwd']} vs strict "
                    f"{c['strict_reduction_ops']})"
                )
    for name, c in rec["big_n"].items():
        if not c["bit_exact_vs_oracle"]:
            failures.append(
                f"big_n {name} is not bit-exact vs the bigint oracle"
            )
        if c["tile_bytes"] > c["vmem_budget"]:
            failures.append(
                f"big_n {name}: frozen schedule tile ({c['tile_bytes']} B) "
                f"exceeds the VMEM budget ({c['vmem_budget']} B)"
            )
    if baseline_path:
        with open(baseline_path) as f:
            failures += diff_against_baseline(rec, json.load(f))
    rec["failures"] = failures
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci-smoke", action="store_true",
                    help="small-preset smoke for the bench-smoke CI job")
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="JSON output path for --ci-smoke")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (BENCH_seed.json) to "
                         "diff op counts / HBM bytes against")
    ap.add_argument("--compiled", action="store_true",
                    help="with --ci-smoke: also record AOT compiled "
                         "wall-clock (compiled_us_per_poly) per "
                         "backend/schedule beside the interpret numbers")
    ap.add_argument("--row-blk", type=int, default=None,
                    help="kernel tile rows per grid step "
                         "(None = per-kernel default)")
    args = ap.parse_args(argv)
    if args.ci_smoke:
        rec = run_ci_smoke(
            args.out, baseline_path=args.baseline, compiled=args.compiled
        )
        for msg in rec["failures"]:
            print(f"[FAIL] {msg}", file=sys.stderr)
        return 1 if rec["failures"] else 0
    print("name,us_per_call,derived")
    for name, us, derived in run(row_blk=args.row_blk):
        print(f'{name},{us:.1f},"{derived}"')
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
