"""Partitioning rules: param/optimizer/activation PartitionSpecs per arch.

Mesh axes:
  * ``pod``   — inter-pod data parallel (multi-pod mesh only)
  * ``data``  — intra-pod data parallel; doubles as the FSDP axis
                (params/optimizer state shard their d_model-ish dim here)
  * ``model`` — tensor parallel (attention heads / FFN hidden / vocab /
                MoE experts / RNS channels for the crypto workload)

Rules key off parameter-leaf names.  2-D+ weights shard (fsdp_dim -> data,
tp_dim -> model); GSPMD pads non-divisible dims (e.g. 28 heads on 16-way
model axis shards the flattened head*dim columns).  Stacked-layer leading
axes get a None prepended automatically.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# leaf-name -> spec for the *trailing* (unstacked) dims
_RULES: dict[str, P] = {
    # embeddings / heads
    "embed": P("model", "data"),
    "lm_head": P("data", "model"),
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    # dense mlp
    "w_gate": P("data", "model"),
    "w_up": P("data", "model"),
    "w_down": P("model", "data"),
    # moe (leading experts dim -> model = expert parallelism)
    "router": P("data", None),
    "we_gate": P("model", "data", None),
    "we_up": P("model", "data", None),
    "we_down": P("model", None, "data"),
    # mamba
    "in_proj": P("data", None),  # mixed z/xBC/dt columns: not 16-divisible
    "out_proj": P("model", "data"),
    "conv_w": P(None, "model"),
    "conv_b": P("model"),
    # scalars / vectors replicate
    "scale": P(),
    "A_log": P(),
    "D": P(),
    "dt_bias": P(),
}

def _leaf_spec(path, leaf) -> P:
    name = None
    for part in reversed(path):
        if isinstance(part, jax.tree_util.DictKey):
            name = part.key
            break
    shape = leaf.shape
    ndim = len(shape)
    # stacked layer dims: any leading dims beyond the rule's spec length
    if name in _RULES:
        base = _RULES[name]
        pad = ndim - len(base)
        if pad < 0:  # e.g. 1-D bias matched by 2-D rule
            return P()
        return P(*([None] * pad + list(base)))
    return P()  # replicate unknown leaves (norms, biases)


def enforce_divisibility(spec_tree, shape_tree, mesh: Mesh):
    """Drop sharding on any dim the mesh axes don't divide evenly (jit
    input shardings require divisibility)."""

    def fix(spec, leaf):
        dims = []
        for i, axes in enumerate(tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            dims.append(_fit(mesh, leaf.shape[i], axes))
        return P(*dims)

    return jax.tree.map(fix, spec_tree, shape_tree)


def param_specs(params):
    """Pytree of PartitionSpecs matching ``params``."""
    return jax.tree_util.tree_map_with_path(_leaf_spec, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params)
    )


def batch_spec(mesh: Mesh, *, ndim: int = 2) -> P:
    """Token batches: batch dim over (pod, data); rest replicated."""
    ba = batch_axes(mesh)
    return P(ba, *([None] * (ndim - 1)))


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """Axes if the dim divides evenly, else replicate (jit input shardings
    require divisibility)."""
    return axes if dim % _axes_size(mesh, axes) == 0 else None


def batch_shard_spec(mesh: Mesh, shape) -> P:
    ba = batch_axes(mesh)
    first = _fit(mesh, shape[0], ba)
    return P(first, *([None] * (len(shape) - 1)))


# --------------------------------------------------------------------------
# crypto workload rules (the PaReNTT serving layer, DESIGN §8)
# --------------------------------------------------------------------------

# Plan leaves that carry NO RNS-channel axis (everything else in an
# int64/wide plan's leaf dict is (t, ...)-leading and shards its channel
# dim over `model`).  Keyed by leaf NAME, not shape, so a coincidental
# t == L can never shard the composed-modulus limb vector.
_CRYPTO_REPLICATED_LEAVES = frozenset({"rns_q_limbs", "wide_q_limbs"})


def polymul_specs(mesh: Mesh, plan) -> dict[str, P]:
    """PartitionSpecs for the crypto serving tensors over a
    (data, model) mesh — the stage-boundary layout of one batched
    polymul (DESIGN §8):

    * ``segments`` / ``limbs`` — ``(B, n, S)`` / ``(B, n, L)`` operand
      and product tiles: batch over the data axes, coefficients and
      limbs local (the n axis feeds the NTT butterflies, which must see
      whole polynomials);
    * ``residues`` — ``(t, B, n)`` residue polynomials: the RNS channel
      axis over ``model`` (the paper's t parallel datapaths mapped to t
      parallel shards) and batch over data.

    ``plan`` is anything with ``.t`` (an ``api.Plan``, ``RnsPlan`` or
    ``ParenttParams``).  Non-divisible dims fall back to replication,
    same policy as the LM rules above.
    """
    ba = batch_axes(mesh)
    ch = "model" if "model" in mesh.axis_names else None
    ch = _fit(mesh, plan.t, ch)
    return {
        "segments": P(ba, None, None),
        "residues": P(ch, ba, None),
        "limbs": P(ba, None, None),
    }


def plan_leaf_specs(mesh: Mesh, pl) -> dict[str, P]:
    """Per-leaf PartitionSpecs for an ``api.Plan``'s ``consts`` dict:
    every ``(t, ...)``-leading table shards its RNS-channel dim over
    ``model`` (twiddle/Shoup/row tables, per-channel CRT constants) so
    each shard holds exactly its channels' tables; channel-free leaves
    (the composed-modulus limbs) replicate.

    This is what makes the plan-leaf threading (DESIGN §7) load-bearing
    for serving: ``shard_map`` slices these leaves per shard, and the
    ops layer rebinds its kernels to the shard-local tables.
    """
    t = pl.t
    out = {}
    for name, leaf in pl.consts.items():
        if (
            name not in _CRYPTO_REPLICATED_LEAVES
            and leaf.ndim >= 1
            and leaf.shape[0] == t
        ):
            ch = _fit(mesh, t, "model" if "model" in mesh.axis_names else None)
            out[name] = P(ch, *([None] * (leaf.ndim - 1)))
        else:
            out[name] = P(*([None] * leaf.ndim))
    return out


def plan_leaf_shardings(mesh: Mesh, pl):
    """NamedShardings matching :func:`plan_leaf_specs` — pass to
    ``jax.device_put(pl.consts, ...)`` to make the tables resident
    per-shard before serving."""
    return {
        name: NamedSharding(mesh, spec)
        for name, spec in plan_leaf_specs(mesh, pl).items()
    }


def cache_specs(cache, mesh: Mesh):
    """Decode-state sharding.  Batch over (pod, data); a head-ish dim over
    model (falling back to head_dim / replication when kv-heads don't
    divide the 16-way axis):
      k/v/ck/cv : (L, B, T, Hk, Dh) -> P(None, ba, None, 'model'|fallback)
      ssm       : (L, B, H, P, N)   -> P(None, ba, 'model'|fallback, ...)
      conv      : (L, B, K-1, Cd)   -> P(None, ba, None, 'model')
      pos       : scalar            -> P()
    """
    ba = batch_axes(mesh)

    def spec(path, leaf):
        name = None
        for part in reversed(path):
            if isinstance(part, jax.tree_util.DictKey):
                name = part.key
                break
        shp = leaf.shape
        nd = len(shp)
        if nd < 2:
            return P(*([None] * nd))
        b_ax = _fit(mesh, shp[1], ba)
        if name in ("k", "v", "ck", "cv") and nd == 5:
            # Flash-decoding layout: shard the SEQUENCE dim.  Attention
            # logits/probs stay seq-sharded and the softmax/PV reductions
            # are tiny (B,H)-sized all-reduces; the token write is a
            # predicated local DUS.  Sharding kv-heads or head_dim instead
            # makes the contraction gather the whole cache (17 GB/step
            # observed on yi-6b decode_32k; §Perf cell D).
            if shp[2] % mesh.shape["model"] == 0:
                return P(None, b_ax, "model", None, None)
            if shp[3] % mesh.shape["model"] == 0:
                return P(None, b_ax, None, "model", None)
            if shp[4] % mesh.shape["model"] == 0:
                return P(None, b_ax, None, None, "model")
            return P(None, b_ax, None, None, None)
        if name == "ssm" and nd == 5:
            if shp[2] % mesh.shape["model"] == 0:
                return P(None, b_ax, "model", None, None)
            if shp[3] % mesh.shape["model"] == 0:
                return P(None, b_ax, None, "model", None)
            return P(None, b_ax, None, None, None)
        if name == "conv" and nd == 4:
            return P(None, b_ax, None, _fit(mesh, shp[3], "model"))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache)
