"""Dead-module report: which ``repro.*`` modules are unreachable from the
public surface and the test suite.

A stdlib-``ast`` import-graph walk (no imports are executed): roots are
``repro/__init__.py``, every ``tests/test_*.py``, ``benchmarks/``,
``examples/``, and the ``repro.launch`` CLIs (each is an entry point via
``python -m``).  Edges are ``import x`` / ``from x import y`` statements,
including relative imports and the lazy ``_LAZY``-table indirection used
by ``repro.analysis`` (string module paths in the module body are picked
up conservatively).  Modules never reached are reported.

``--check`` makes the report BLOCKING: any dead module not named in the
explicit :data:`ALLOWED_DEAD` allowlist fails the run (the CI
``dead-modules`` gate).  Allowlisting is a reviewed code change — add
the module name with a justification comment, not a wildcard.

Usage::

    python -m repro.launch.dead_modules --out DEAD_modules.json
    python -m repro.launch.dead_modules --check   # CI gate
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set


# Modules allowed to be unreachable under --check.  Every entry needs a
# justification comment; an empty tuple means the whole tree must stay
# reachable from the public surface, the tests, or a CLI entry point.
ALLOWED_DEAD: tuple = ()


def _module_name(path: Path, src_root: Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _iter_string_modules(tree: ast.AST, known: Set[str]) -> Iterable[str]:
    """String literals that name known modules (lazy-import tables)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            if node.value in known:
                yield node.value


def _edges_of(path: Path, mod: str, known: Set[str]) -> Set[str]:
    try:
        tree = ast.parse(path.read_text())
    except SyntaxError:
        return set()
    out: Set[str] = set()

    def note(name: Optional[str]) -> None:
        if not name:
            return
        # register the module itself and every package prefix (importing
        # repro.analysis.verify also executes repro and repro.analysis)
        parts = name.split(".")
        for k in range(1, len(parts) + 1):
            cand = ".".join(parts[:k])
            if cand in known:
                out.add(cand)

    pkg_parts = mod.split(".") if mod else []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                note(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:  # relative import
                base = pkg_parts[: len(pkg_parts) - node.level + 1]
                stem = ".".join(base + ([node.module] if node.module else []))
            else:
                stem = node.module or ""
            note(stem)
            for alias in node.names:
                note(f"{stem}.{alias.name}" if stem else alias.name)
    for name in _iter_string_modules(tree, known):
        out.add(name)
    return out


def build_report(repo_root: Path) -> Dict[str, object]:
    src_root = repo_root / "src"
    files = {p for p in (src_root / "repro").rglob("*.py")}
    mods: Dict[str, Path] = {_module_name(p, src_root): p for p in files}
    known = set(mods)

    graph: Dict[str, Set[str]] = {m: _edges_of(p, m, known) for m, p in mods.items()}

    roots: Set[str] = {"repro"}
    # every launch CLI is a python -m entry point
    roots |= {m for m in known if m.startswith("repro.launch.")}
    external_roots: List[Path] = []
    for pattern in ("tests/*.py", "benchmarks/*.py", "examples/*.py"):
        external_roots.extend(repo_root.glob(pattern))
    external_edges: Set[str] = set()
    for p in external_roots:
        external_edges |= _edges_of(p, "", known)
    roots |= external_edges

    seen: Set[str] = set()
    stack = [r for r in roots if r in known]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        # reaching a package reaches its __init__ edges; reaching any
        # module reaches its package __init__ too
        parent = m.rsplit(".", 1)[0] if "." in m else None
        if parent in known and parent not in seen:
            stack.append(parent)
        stack.extend(graph.get(m, ()) - seen)

    dead = sorted(known - seen)
    return {
        "roots": sorted(r for r in roots if r in known),
        "module_count": len(known),
        "reachable_count": len(seen),
        "dead_modules": dead,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.dead_modules")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument(
        "--root", default=None, help="repo root (default: auto from this file)"
    )
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero on dead modules outside the ALLOWED_DEAD "
             "allowlist (the CI dead-modules gate)",
    )
    args = ap.parse_args(argv)
    repo_root = Path(args.root) if args.root else Path(__file__).resolve().parents[3]
    report = build_report(repo_root)
    print(
        f"[dead-modules] {report['reachable_count']}/{report['module_count']} "
        f"modules reachable; {len(report['dead_modules'])} dead"
    )
    for m in report["dead_modules"]:
        flag = " (allowlisted)" if m in ALLOWED_DEAD else ""
        print(f"    {m}{flag}")
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=1))
        print(f"[dead-modules] report -> {args.out}")
    if args.check:
        unexpected = [m for m in report["dead_modules"] if m not in ALLOWED_DEAD]
        stale = [m for m in ALLOWED_DEAD if m not in report["dead_modules"]]
        for m in unexpected:
            print(
                f"[dead-modules] FAIL: {m} is unreachable and not "
                f"allowlisted — wire it in or add it to ALLOWED_DEAD "
                f"with a justification",
                file=sys.stderr,
            )
        for m in stale:
            print(
                f"[dead-modules] FAIL: allowlist entry {m} is reachable "
                f"(or gone) — remove the stale entry",
                file=sys.stderr,
            )
        return 1 if (unexpected or stale) else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
