"""Compiled-mode sweep harness: enumerate servable candidate configs per
workload, measure each, pick the winner, cross-check the cost model.

A *workload* is ``(n, t, v, batch)``; a *candidate* is an assignment of
the four tunable plan knobs (``backend``, ``schedule``, ``row_blk``,
``channel_grid``).  For each candidate the harness:

1. builds the plan — :class:`repro.errors.PlanError` subclasses
   (UnknownKnobError / UnservableConfigError) PRUNE the candidate with
   the taxonomy's knob/alternatives recorded, they never abort a sweep;
2. dedupes by :func:`repro.api.plan_key` — ``backend="auto"`` and its
   resolution measure once;
3. measures warm-up-excluded compiled wall-clock through the AOT chain
   ``jax.jit(polymul).lower(...).compile()`` — a real XLA:CPU compile
   today (interpret-mode Pallas inlines kernel bodies into the traced
   program, so XLA compiles the full datapath), Mosaic/TPU or Triton/GPU
   transparently when that is the default backend — and keeps the
   optimized HLO for the cost model.  A candidate that fails to compile
   falls back to eager interpret timing (``mode="eager"``, no HLO).

The winner is the fastest measured config, with a stability bias: the
static default keeps the crown unless a challenger beats it by more
than :data:`WINNER_MARGIN` (so the tuned choice is never slower than
the default on the box that swept, and plan caches don't churn over
noise).  Winner knobs are recorded RESOLVED (concrete backend string,
canonical schedule string), so ``plan(tuning=<table>)`` reproduces the
measured :class:`repro.api.PlanConfig` exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.errors import PlanError
from repro.obs import metrics as obs_metrics
from repro.tune import costcheck, table as table_mod

# A challenger must beat the static default by this factor to dethrone it.
WINNER_MARGIN = 0.02

_INT64_BACKENDS = ("jnp", "pallas", "pallas_fused", "pallas_fused_e2e")
_SCHEDULES = ("radix2", "four_step", "four_step:h")


@dataclasses.dataclass(frozen=True)
class Workload:
    n: int
    t: int
    v: int
    batch: int

    @property
    def key(self) -> str:
        return table_mod.workload_key(self.n, self.t, self.v, self.batch)

    @classmethod
    def from_key(cls, key: str) -> "Workload":
        return cls(**table_mod.parse_workload_key(key))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One assignment of the tunable knobs (None = static default)."""

    backend: str = "auto"
    schedule: str = "auto"
    row_blk: int | None = None
    channel_grid: bool | None = None

    @property
    def name(self) -> str:
        rb = "-" if self.row_blk is None else str(self.row_blk)
        cg = "-" if self.channel_grid is None else ("1" if self.channel_grid else "0")
        return f"{self.backend}/{self.schedule}/rb{rb}/cg{cg}"

    def knobs(self) -> dict[str, Any]:
        return {
            "backend": self.backend,
            "schedule": self.schedule,
            "row_blk": self.row_blk,
            "channel_grid": self.channel_grid,
        }


DEFAULT_CANDIDATE = Candidate()


def default_candidates(v: int, *, quick: bool = False) -> tuple[Candidate, ...]:
    """The candidate grid for a modulus width.

    The static default is always first (the winner baseline).  The int64
    width sweeps backend x schedule, with ``row_blk``/``channel_grid``
    varied only where they reach a kernel (the fused-e2e path); the wide
    and oracle widths have one datapath, so only the schedule vocabulary
    exercises the pruner.  ``quick`` is the CI grid: two backends, a
    trimmed row-block set, and the hierarchical schedule kept in to
    demonstrate taxonomy pruning at small n.
    """
    width = api.width_for(v)
    out: list[Candidate] = [DEFAULT_CANDIDATE]
    if width != "int64":
        # one datapath; radix2 is the only servable schedule, the rest
        # exist to exercise (and document) the pruning path
        out.extend(Candidate(backend="jnp" if width == "wide" else "oracle",
                             schedule=s)
                   for s in ("radix2", "four_step"))
        return tuple(out)
    backends = ("jnp", "pallas_fused_e2e") if quick else _INT64_BACKENDS
    row_blks: tuple[int | None, ...] = (None, 2) if quick else (None, 1, 2, 8)
    channel_grids: tuple[bool | None, ...] = (None,) if quick else (None, False, True)
    for be in backends:
        for sched in _SCHEDULES:
            if be == "pallas_fused_e2e":
                for rb in row_blks:
                    for cg in channel_grids:
                        out.append(Candidate(be, sched, rb, cg))
            else:
                out.append(Candidate(be, sched))
    return tuple(out)


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------


def make_operands(
    wl: Workload, seg_count: int, *, seed: int = 0
) -> tuple[jax.Array, jax.Array]:
    rng = np.random.default_rng(seed)
    shape = (wl.batch, wl.n, seg_count)
    za = jnp.asarray(rng.integers(0, 1 << wl.v, size=shape, dtype=np.int64))
    zb = jnp.asarray(rng.integers(0, 1 << wl.v, size=shape, dtype=np.int64))
    return za, zb


def measure_plan(
    pl: api.Plan,
    za: Any,
    zb: Any,
    *,
    iters: int = 3,
    warmup: int = 1,
    fn: Callable[..., Any] = api.polymul,
) -> dict[str, Any]:
    """Warm-up-excluded wall-clock for one plan, preferring the AOT
    compiled executable.

    Returns ``us_per_poly`` (median over ``iters`` timed calls, divided
    by the batch), ``compile_s``, ``mode`` ("compiled" | "eager") and
    the optimized ``hlo`` text (compiled mode only).  Oracle-width plans
    and compile failures time the eager path.
    """
    batch = int(np.shape(za)[0]) if np.ndim(za) >= 3 else 1
    compiled = None
    hlo = None
    compile_s = None
    if api.plan_key(pl).width != "oracle":
        try:
            t0 = time.perf_counter()
            compiled = jax.jit(fn).lower(pl, za, zb).compile()
            compile_s = time.perf_counter() - t0
            hlo = compiled.as_text()
        except Exception:  # interpret-mode fallback below  # noqa: BLE001
            compiled = None
    run: Callable[[], Any] = (
        (lambda: compiled(pl, za, zb)) if compiled is not None
        else (lambda: fn(pl, za, zb))
    )
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(run())
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        samples.append(time.perf_counter() - t0)
    return {
        "us_per_poly": float(np.median(samples)) * 1e6 / batch,
        "compile_s": compile_s,
        "mode": "compiled" if compiled is not None else "eager",
        "hlo": hlo,
    }


def _config_summary(cfg: api.PlanConfig) -> dict[str, Any]:
    return {
        "backend": cfg.backend,
        "schedule": cfg.schedule.canonical,
        "schedule_detail": str(cfg.schedule),
        "row_blk": cfg.row_blk,
        "channel_grid": cfg.channel_grid,
    }


# --------------------------------------------------------------------------
# per-workload sweep
# --------------------------------------------------------------------------


def sweep_workload(
    wl: Workload,
    candidates: tuple[Candidate, ...] | None = None,
    *,
    quick: bool = False,
    iters: int = 3,
    warmup: int = 1,
    kind: str | None = None,
    log: Callable[[str], None] | None = None,
) -> dict[str, Any]:
    """Sweep one workload; returns the report entry (see module docs).

    The report's ``entry`` field is the tuning-table payload
    (``TuningTable.put(**entry)`` ready)."""
    kind = kind or table_mod.device_kind()
    if candidates is None:
        candidates = default_candidates(wl.v, quick=quick)
    say = log or (lambda _msg: None)

    plans: list[tuple[Candidate, api.Plan]] = []
    records: list[dict[str, Any]] = []
    seen: dict[api.PlanConfig, str] = {}
    for cand in candidates:
        rec: dict[str, Any] = {"name": cand.name, "knobs": cand.knobs()}
        try:
            pl = api.plan(
                n=wl.n, t=wl.t, v=wl.v, backend=cand.backend,
                schedule=cand.schedule, row_blk=cand.row_blk,
                channel_grid=cand.channel_grid,
            )
        except PlanError as e:
            rec.update(
                status="pruned",
                error=type(e).__name__,
                knob=e.knob,
                reason=str(e),
                alternatives=list(getattr(e, "alternatives", ()) or ()),
            )
            records.append(rec)
            continue
        cfg = api.plan_key(pl)
        rec["config"] = _config_summary(cfg)
        first = seen.get(cfg)
        if first is not None:
            rec.update(status="duplicate", same_as=first)
            records.append(rec)
            continue
        seen[cfg] = cand.name
        rec["status"] = "measured"
        plans.append((cand, pl))
        records.append(rec)

    if not plans:
        raise PlanError(
            f"sweep {wl.key}: every candidate was pruned — nothing servable",
            knob="workload", value=wl.key, alternatives=(),
        )

    # measure (default candidate is plans[0] by construction)
    seg_count = api.plan_key(plans[0][1]).seg_count
    za, zb = make_operands(wl, seg_count)
    by_name = {r["name"]: r for r in records}
    for cand, pl in plans:
        say(f"  measuring {cand.name} ...")
        m = measure_plan(pl, za, zb, iters=iters, warmup=warmup)
        rec = by_name[cand.name]
        rec.update(
            us_per_poly=m["us_per_poly"],
            compile_s=m["compile_s"],
            mode=m["mode"],
        )
        if m["hlo"] is not None:
            rec.update(costcheck.predicted_cost(m["hlo"], kind))

    # candidate outcomes land in the process registry beside the serve
    # metrics, so a tuning run is scrapeable like any soak
    cand_counter = obs_metrics.registry().counter(
        "repro_tune_candidates_total",
        "sweep candidate outcomes",
        ("status",),
    )
    for r in records:
        cand_counter.labels(status=r["status"]).inc()

    measured = [r for r in records if r["status"] == "measured"]
    check = costcheck.cross_check(
        [
            {
                "name": r["name"],
                "measured_us": r.get("us_per_poly"),
                "model_us": r.get("model_us"),
            }
            for r in measured
        ]
    )

    default_rec = measured[0]  # DEFAULT_CANDIDATE is always first
    winner_rec = min(measured, key=lambda r: r["us_per_poly"])
    if winner_rec["us_per_poly"] >= default_rec["us_per_poly"] * (1 - WINNER_MARGIN):
        winner_rec = default_rec  # stability bias: default keeps the crown
    winner_cfg = dict(winner_rec["config"])
    winner_cfg.pop("schedule_detail", None)

    entry = {
        "n": wl.n, "t": wl.t, "v": wl.v, "batch": wl.batch,
        "winner": winner_cfg,
        "winner_us": winner_rec["us_per_poly"],
        "default_us": default_rec["us_per_poly"],
        "mode": winner_rec["mode"],
        "candidates_measured": len(measured),
        "candidates_pruned": sum(1 for r in records if r["status"] == "pruned"),
        "rank_correlation": check["rank_correlation"],
    }
    return {
        "key": wl.key,
        "workload": dataclasses.asdict(wl),
        "device_kind": kind,
        "entry": entry,
        "winner": winner_rec["name"],
        "candidates": records,
        "costcheck": check,
    }


def sweep(
    workloads: list[Workload],
    *,
    quick: bool = False,
    iters: int = 3,
    warmup: int = 1,
    table: table_mod.TuningTable | None = None,
    log: Callable[[str], None] | None = None,
) -> tuple[table_mod.TuningTable, dict[str, Any]]:
    """Sweep several workloads into one table + one report dict.

    Pass an existing ``table`` to merge (entries for swept workloads are
    overwritten, everything else is kept — including other device
    kinds)."""
    kind = table_mod.device_kind()
    tab = table if table is not None else table_mod.TuningTable()
    say = log or (lambda _msg: None)
    report: dict[str, Any] = {
        "schema": "repro.tune.sweep-report/v1",
        "device_kind": kind,
        "quick": quick,
        "iters": iters,
        "warmup": warmup,
        "workloads": [],
    }
    for wl in workloads:
        say(f"sweep {wl.key} [{kind}] ...")
        res = sweep_workload(
            wl, quick=quick, iters=iters, warmup=warmup, kind=kind, log=log
        )
        tab.put(kind=kind, **res["entry"])
        report["workloads"].append(res)
        say(
            f"  -> winner {res['winner']} "
            f"({res['entry']['winner_us']:.1f} us/poly vs default "
            f"{res['entry']['default_us']:.1f}), rank-corr "
            f"{res['entry']['rank_correlation']}"
        )
    return tab, report
