"""PR 7 gates: the resolved ``ScheduleSpec`` API and the hierarchical
four-step schedules behind it.

* plan() freezes a concrete spec — no ``"auto"`` survives, the tile
  model always fits the VMEM budget, equal specs share one jit trace;
* the error taxonomy: vocabulary mistakes raise ``UnknownKnobError``,
  valid-but-unservable combos raise ``UnservableConfigError``, both
  carrying knob/value/alternatives;
* big-n acceptance: n=4096 (depth 1) and n=8192 (depth 2 hierarchical)
  bit-exact vs the bigint oracle through ``repro.polymul`` on the
  fused-e2e Pallas path;
* the fast host-NTT oracle itself cross-checked vs the schoolbook.

Property tests use hypothesis when installed; otherwise the fallback
shim turns each into an individual skip (tests/_hypothesis_fallback.py).
"""
import random

import numpy as np
import pytest

import jax

import repro
from repro.core import ntt as ntt_mod
from repro.core import params as params_mod
from repro.core import polymul as pm
from repro.core import primes as primes_mod
from repro.core import schedule as sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from _hypothesis_fallback import given, settings, st


# --------------------------------------------------------------------------
# Spec resolution: structure + round trip
# --------------------------------------------------------------------------


class TestSpecResolution:
    @pytest.mark.parametrize("n,depth", [
        (64, 1), (256, 1), (1024, 1), (4096, 1),
        (8192, 2), (16384, 2), (32768, 2), (65536, 3),
    ])
    def test_chain_shape(self, n, depth):
        """The four-step chain is a function of n alone: level 0 tiles
        n itself, every deeper level re-splits the previous column, and
        the final column transform fits the direct kernel bound."""
        spec = sched.concrete_spec(n, "four_step")
        assert spec.depth == depth
        c0, r0 = spec.splits[0]
        assert c0 * r0 == n
        for (c_prev, _), (c, r) in zip(spec.splits, spec.splits[1:]):
            assert c * r == c_prev
        assert spec.splits[-1][0] <= ntt_mod.MAX_FS_COL

    def test_no_auto_survives_plan(self):
        for n in (64, 256, 8192):
            pl = repro.plan(n=n, t=2, v=30)
            spec = pl.config.schedule
            assert isinstance(spec, repro.ScheduleSpec)
            assert spec.kind in ("radix2", "four_step")
            assert spec.canonical in sched.SCHEDULE_STRINGS
            assert spec.canonical != "auto"

    def test_plan_round_trips_through_frozen_spec(self):
        """A frozen spec fed back as the schedule knob reproduces the
        identical config (and therefore the identical plan_key)."""
        a = repro.plan(n=256, t=3, v=30, backend="pallas_fused_e2e")
        b = repro.plan(
            n=256, t=3, v=30, backend="pallas_fused_e2e",
            schedule=a.config.schedule,
        )
        assert a.config == b.config
        assert repro.plan_key(a) == repro.plan_key(b)

    def test_canonical_string_round_trips(self):
        for n, schedule in [(64, "radix2"), (256, "four_step"),
                            (8192, "four_step:h")]:
            spec = sched.concrete_spec(n, schedule)
            again = sched.concrete_spec(n, spec.canonical)
            assert again.kind == spec.kind
            assert again.splits == spec.splits

    def test_tiling_hint_accepted_when_canonical(self):
        pl = repro.plan(
            n=256, t=3, v=30, backend="pallas_fused_e2e",
            schedule="four_step", tiling=((2, 128),),
        )
        assert pl.config.schedule.splits == ((2, 128),)

    @settings(max_examples=60, deadline=None)
    @given(
        logn=st.integers(min_value=6, max_value=16),
        seg_count=st.integers(min_value=1, max_value=16),
        limb_count=st.integers(min_value=1, max_value=12),
        lazy=st.booleans(),
        schedule=st.sampled_from(("auto", "radix2", "four_step")),
    )
    def test_resolved_spec_fits_budget_property(
        self, logn, seg_count, limb_count, lazy, schedule
    ):
        """Property: whatever plan-shaped knobs come in, the resolved
        spec either fits the VMEM budget (tile_bytes consistent with a
        recomputation of the tile model) or resolution raises the
        structured unservable error — never a silent over-budget spec."""
        n = 1 << logn
        try:
            spec = sched.resolve_spec(
                n, schedule, seg_count=seg_count, limb_count=limb_count,
                lazy=lazy,
            )
        except repro.UnservableConfigError as e:
            assert e.knob is not None
            return
        assert spec.kind in ("radix2", "four_step")
        assert spec.row_blk >= 1
        assert spec.tile_bytes <= spec.vmem_budget
        assert spec.tile_bytes == sched.tile_bytes_model(
            spec.kind, n, spec.splits, spec.row_blk, seg_count,
            limb_count, lazy,
        )

    def test_default_row_blk_halves_until_fit(self):
        """Deterministic pin of the property above: at n=65536 with a
        wide operand the default row block must shrink below
        DEFAULT_E2E_ROW_BLK to fit, and the result still fits."""
        spec = sched.resolve_spec(
            65536, "four_step", seg_count=4, limb_count=8, lazy=True
        )
        assert spec.row_blk < sched.DEFAULT_E2E_ROW_BLK
        assert spec.tile_bytes <= spec.vmem_budget


# --------------------------------------------------------------------------
# Error taxonomy
# --------------------------------------------------------------------------


class TestErrorTaxonomy:
    def test_unknown_schedule_string(self):
        with pytest.raises(repro.UnknownKnobError) as ei:
            repro.plan(n=64, t=3, v=30, schedule="radix4")
        assert ei.value.knob == "schedule"
        assert ei.value.value == "radix4"
        assert "four_step" in ei.value.alternatives

    def test_hier_unservable_below_8192(self):
        with pytest.raises(repro.UnservableConfigError) as ei:
            repro.plan(n=4096, t=2, v=30, schedule="four_step:h")
        assert ei.value.knob == "schedule"
        assert "four_step" in ei.value.alternatives

    def test_errors_are_valueerrors(self):
        """Back-compat: every taxonomy member still satisfies the
        pre-PR-7 ``pytest.raises(ValueError)`` call sites."""
        assert issubclass(repro.PlanError, ValueError)
        assert issubclass(repro.UnknownKnobError, repro.PlanError)
        assert issubclass(repro.UnservableConfigError, repro.PlanError)

    def test_mismatched_tiling_hint_unservable(self):
        with pytest.raises(repro.UnservableConfigError) as ei:
            repro.plan(
                n=256, t=3, v=30, backend="pallas_fused_e2e",
                schedule="four_step", tiling=((4, 64),),
            )
        assert ei.value.knob == "tiling"
        assert ei.value.alternatives == (((2, 128),),)

    def test_non_power_of_two_row_blk(self):
        with pytest.raises(repro.UnknownKnobError) as ei:
            repro.plan(n=64, t=3, v=30, row_blk=3)
        assert ei.value.knob == "row_blk"
        assert ei.value.value == 3

    def test_oversized_row_blk_unservable_big_n(self):
        """Valid row_blk vocabulary, unservable combination: at n=65536
        a wide explicit row block blows the VMEM tile budget, and the
        error names smaller row blocks that do fit."""
        with pytest.raises(repro.UnservableConfigError) as ei:
            sched.resolve_spec(
                65536, "four_step", row_blk=8, seg_count=4,
                limb_count=8, lazy=True,
            )
        err = ei.value
        assert err.knob == "row_blk"
        assert err.value == 8
        assert err.alternatives  # at least one servable fallback
        for rb in err.alternatives:
            assert sched.tile_bytes_model(
                "four_step", 65536, sched.concrete_spec(65536, "four_step").splits,
                rb, 4, 8, True,
            ) <= sched.VMEM_BUDGET_BYTES

    def test_no_servable_row_blk_names_n(self):
        with pytest.raises(repro.UnservableConfigError) as ei:
            sched.resolve_spec(
                65536, "four_step", seg_count=512, limb_count=512,
                lazy=True,
            )
        assert ei.value.knob == "n"
        assert ei.value.value == 65536


# --------------------------------------------------------------------------
# Retrace probe: spec identity == jit identity
# --------------------------------------------------------------------------


class TestSpecRetrace:
    def test_string_and_spec_routes_share_one_trace(self):
        """plan(schedule="four_step") and plan(schedule=<frozen spec>)
        produce equal configs, hence one compilation."""
        traces = []

        def f(pl, za, zb):
            traces.append(1)
            return repro.polymul(pl, za, zb)

        fj = jax.jit(f)
        a = repro.plan(n=256, t=2, v=30, schedule="four_step")
        b = repro.plan(n=256, t=2, v=30, schedule=a.config.schedule)
        c = repro.plan(n=256, t=2, v=30)  # auto -> same four_step spec
        rng = np.random.default_rng(3)
        import jax.numpy as jnp
        za = jnp.asarray(
            rng.integers(0, 1 << 30, size=(256, a.config.seg_count))
        )
        zb = jnp.asarray(
            rng.integers(0, 1 << 30, size=(256, a.config.seg_count))
        )
        fj(a, za, zb)
        fj(b, za, zb)
        fj(c, za, zb)
        assert len(traces) == 1
        fj(repro.plan(n=256, t=2, v=30, schedule="radix2"), za, zb)
        assert len(traces) == 2


# --------------------------------------------------------------------------
# Oracles + big-n acceptance
# --------------------------------------------------------------------------


class TestHostNttOracle:
    @pytest.mark.parametrize("n", [8, 64, 256])
    def test_matches_schoolbook(self, n):
        q = primes_mod.default_prime_set(n, 1, 30)[0].q
        rng = random.Random(n)
        a = [rng.randrange(q) for _ in range(n)]
        b = [rng.randrange(q) for _ in range(n)]
        assert pm.ntt_negacyclic_host(a, b, q) == pm.schoolbook_negacyclic(
            a, b, q
        )


class TestBigNAcceptance:
    """The PR's acceptance gate: hierarchical sizes bit-exact through
    the PUBLIC plan/polymul API on the fused-e2e Pallas backend
    (interpret mode off-TPU), against the bigint oracle."""

    @pytest.mark.parametrize("n,schedule,depth", [
        (4096, "four_step", 1),
        (8192, "four_step:h", 2),
    ])
    def test_fused_e2e_bit_exact_vs_oracle(self, n, schedule, depth):
        pl = repro.plan(
            n=n, t=2, v=30, backend="pallas_fused_e2e", schedule=schedule
        )
        assert pl.config.schedule.depth == depth
        p = pl.params
        rng = random.Random(n)
        a = [rng.randrange(p.q) for _ in range(n)]
        b = [rng.randrange(p.q) for _ in range(n)]
        assert repro.polymul_ints(pl, a, b) == pm.oracle_multiply(a, b, p)
