"""Serving engines: the LM slot-batching decode engine and the crypto
polymul batching engine (shape-bucketed continuous batching over the
plan/execute API, DESIGN §8)."""
from repro.serve.crypto_engine import (
    PolymulEngine,
    PolymulFuture,
    negacyclic_mul_sharded,
    polymul_sharded,
)
from repro.serve.engine import Engine

__all__ = [
    "Engine",
    "PolymulEngine",
    "PolymulFuture",
    "negacyclic_mul_sharded",
    "polymul_sharded",
]
