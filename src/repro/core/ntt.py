"""Low-complexity negative-wrapped-convolution NTT / iNTT (paper §II-D, Fig 1,
supplementary Eq 14-25) with the *no-shuffle cascade* (contribution 1).

Design notes
------------
* Forward transform: decimation-in-time (CT) butterflies with the weights
  psi_{2n}^{(2k+1)} merged into the twiddles (Eq 16-19).  Natural-order
  input -> **bit-reversed** output.
* Inverse transform retraces the forward flow graph in reverse stage order
  (first inverse stage undoes the forward's last), with the inverse
  twiddles psi^{-brv(h+i)} and the factor n^{-1} folded in: every stage
  halves both butterfly outputs with the shift-and-conditional-add trick
  of Eq 24/25 (the paper's Fig 9 PE).  **Bit-reversed** input ->
  natural-order output.
* Because the pointwise product is order-agnostic, the cascade
  ``intt(ntt(a) * ntt(b))`` needs **zero permutations** — this is the
  data-flow-level content of the paper's different-folding-sets trick
  (the hardware folding/latency model itself lives in
  :mod:`repro.core.schedule`).

All arithmetic is int64; residues must satisfy q < 2**31 so products fit
(the v<=30 fast path; the paper's preferred config).  The v=45 config is
served by the numpy-object oracle in :mod:`repro.core.polymul`.

Shapes: transforms operate on the last axis; any leading batch dims.  The
`*_channels` variants vmap over a leading RNS-channel axis with per-channel
moduli/tables.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primes as primes_mod


def bit_reverse_indices(n: int) -> np.ndarray:
    """Permutation p with p[i] = bit-reverse of i over log2(n) bits."""
    m = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    out = np.zeros_like(idx)
    for b in range(m):
        out |= ((idx >> b) & 1) << (m - 1 - b)
    return out


class NttTables(NamedTuple):
    """Per-modulus twiddle tables for the merged-weight NWC transforms."""

    q: int
    n: int
    psi: int  # primitive 2n-th root of unity mod q
    fwd: np.ndarray  # (n,)  fwd[i] = psi^{brv(i)}    (CT/DIT stage tables)
    inv: np.ndarray  # (n,)  inv[i] = psi^{-brv(i)}   (mirror-order inverse)
    half: int  # (q + 1) / 2, for the div-by-2 PE (Eq 24)


@functools.lru_cache(maxsize=None)
def make_tables(q: int, n: int) -> NttTables:
    """Precompute twiddles (host-side Python bigints, cached)."""
    psi = primes_mod.root_of_unity(q, 2 * n)
    brv = bit_reverse_indices(n)
    fwd = np.array([pow(psi, int(b), q) for b in brv], dtype=np.int64)
    psi_inv = pow(psi, q - 2, q)
    inv = np.array([pow(psi_inv, int(b), q) for b in brv], dtype=np.int64)
    return NttTables(q=q, n=n, psi=psi, fwd=fwd, inv=inv, half=(q + 1) // 2)


# --------------------------------------------------------------------------
# Modular helper ops (int64, q < 2**31).  q / half may be python ints or
# (broadcastable) arrays so the same code serves single- and multi-channel.
# --------------------------------------------------------------------------


def add_mod(x, y, q):
    s = x + y
    return jnp.where(s >= q, s - q, s)


def sub_mod(x, y, q):
    d = x - y
    return jnp.where(d < 0, d + q, d)


def mul_mod(x, y, q):
    return (x * y) % q


def div2_mod(x, q_half):
    """x * 2^{-1} mod q via Eq 24: (x >> 1) + (x & 1) * (q+1)/2.
    Result < q whenever x < q (no reduction needed)."""
    return (x >> 1) + (x & 1) * q_half


# --------------------------------------------------------------------------
# Transforms (single modulus; q/half scalars or 0-d arrays)
# --------------------------------------------------------------------------


def ntt_raw(a: jax.Array, fwd: jax.Array, q) -> jax.Array:
    """Forward NWC NTT, natural-in, bit-reversed-out. Last-axis transform."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    m, t = 1, n
    while m < n:
        t //= 2
        w = fwd[m : 2 * m]  # static slice
        x = a.reshape(lead + (m, 2, t))
        u = x[..., 0, :]
        v = mul_mod(x[..., 1, :], w[:, None], q)
        a = jnp.stack([add_mod(u, v, q), sub_mod(u, v, q)], axis=-2)
        a = a.reshape(lead + (n,))
        m *= 2
    return a


def intt_raw(a: jax.Array, inv: jax.Array, q, half) -> jax.Array:
    """Inverse NWC NTT, bit-reversed-in, natural-out; n^{-1} folded into the
    per-stage halving (paper Fig 9 / Eq 20-25)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    h, t = n // 2, 1
    while h >= 1:
        w = inv[h : 2 * h]
        x = a.reshape(lead + (h, 2, t))
        u, v = x[..., 0, :], x[..., 1, :]
        s = add_mod(u, v, q)
        d = mul_mod(sub_mod(u, v, q), w[:, None], q)
        a = jnp.stack([div2_mod(s, half), div2_mod(d, half)], axis=-2)
        a = a.reshape(lead + (n,))
        h //= 2
        t *= 2
    return a


def ntt(a: jax.Array, tables: NttTables) -> jax.Array:
    return ntt_raw(a, jnp.asarray(tables.fwd), tables.q)


def intt(a: jax.Array, tables: NttTables) -> jax.Array:
    return intt_raw(a, jnp.asarray(tables.inv), tables.q, tables.half)


def negacyclic_mul(a: jax.Array, b: jax.Array, tables: NttTables) -> jax.Array:
    """The no-shuffle cascade: NTT(a) ⊙ NTT(b) -> iNTT, zero permutations."""
    fa = ntt(a, tables)
    fb = ntt(b, tables)
    return intt(mul_mod(fa, fb, tables.q), tables)


# --------------------------------------------------------------------------
# Multi-channel (RNS) variants: leading axis = RNS channel, one modulus each.
# This is the paper's "t parallel residue datapaths"; under pjit the channel
# axis shards over the `model` mesh axis.
# --------------------------------------------------------------------------


class ChannelTables(NamedTuple):
    qs: np.ndarray  # (t,)
    fwd: np.ndarray  # (t, n)
    inv: np.ndarray  # (t, n)
    half: np.ndarray  # (t,)

    @property
    def n(self) -> int:
        return self.fwd.shape[-1]

    @property
    def t(self) -> int:
        return self.fwd.shape[0]


def make_channel_tables(qs, n: int) -> ChannelTables:
    tabs = [make_tables(int(q), n) for q in qs]
    return ChannelTables(
        qs=np.array([t.q for t in tabs], dtype=np.int64),
        fwd=np.stack([t.fwd for t in tabs]),
        inv=np.stack([t.inv for t in tabs]),
        half=np.array([t.half for t in tabs], dtype=np.int64),
    )


def ntt_channels(a: jax.Array, ct: ChannelTables) -> jax.Array:
    """a: (t, ..., n) -> (t, ..., n), channel c transformed mod qs[c]."""
    return jax.vmap(ntt_raw, in_axes=(0, 0, 0))(
        a, jnp.asarray(ct.fwd), jnp.asarray(ct.qs)
    )


def intt_channels(a: jax.Array, ct: ChannelTables) -> jax.Array:
    return jax.vmap(intt_raw, in_axes=(0, 0, 0, 0))(
        a, jnp.asarray(ct.inv), jnp.asarray(ct.qs), jnp.asarray(ct.half)
    )


def negacyclic_mul_channels(a, b, ct: ChannelTables) -> jax.Array:
    """(t, ..., n) x (t, ..., n) — the full RNS-parallel no-shuffle cascade."""
    qs = jnp.asarray(ct.qs)
    q_b = qs.reshape((ct.t,) + (1,) * (a.ndim - 1))
    fa = ntt_channels(a, ct)
    fb = ntt_channels(b, ct)
    return intt_channels(mul_mod(fa, fb, q_b), ct)
