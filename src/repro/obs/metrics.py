"""Process-wide metrics registry: counters, gauges, histograms — with
labels, lock-protected, zero-dependency.

The serving engine, the fault injector, the autotuner sweep, and the
profiling harness all count things; before this module each kept its own
ad-hoc dict (``PolymulEngine.stats``, ``FaultInjector.log`` tallies,
sweep report fields).  The registry unifies them behind one vocabulary
so every exporter (:mod:`repro.obs.export`), the engine's versioned
``snapshot()``, and the ``obs-smoke`` CI gate read the same numbers.

Naming convention (DESIGN.md §12): ``repro_<subsystem>_<noun>[_<unit>]``
with counters suffixed ``_total`` and histograms carrying a base unit
(``_seconds``).  Labels are a fixed tuple declared at metric creation;
every observation goes through a :meth:`Metric.labels` child keyed by
the label values.

Histogram resolution bound
--------------------------
Histograms use exponential bucket bounds with growth factor
:data:`HIST_GROWTH` (default sqrt(2)).  :meth:`Histogram.quantile`
interpolates linearly inside the bucket holding the requested rank, so
the estimate and the exact sample quantile always land in the same
bucket or adjacent ranks of it; the documented accuracy contract is::

    exact / GROWTH - lowest_bound <= quantile(q) <= exact * GROWTH + lowest_bound

i.e. relative error bounded by the bucket growth factor, plus an
absolute floor of the first bucket bound for values below resolution.
``tests/test_obs.py`` property-tests this bound against exact
``numpy.percentile`` on latency- and queue-wait-shaped series.

Thread safety: one registry lock serializes metric creation and child
lookup; each child carries its own lock for observations, so hot-path
``inc()``/``observe()`` calls from the engine's dispatcher thread and
submitting threads never contend on the registry lock.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Iterator

__all__ = [
    "HIST_GROWTH",
    "Counter",
    "Gauge",
    "Histogram",
    "Metric",
    "MetricsRegistry",
    "default_buckets",
    "registry",
    "reset_default_registry",
]

# Exponential bucket growth factor: the histogram-quantile relative
# error bound (see module docstring).
HIST_GROWTH = math.sqrt(2.0)


def default_buckets(
    lo: float = 1e-6, hi: float = 64.0, growth: float = HIST_GROWTH
) -> tuple[float, ...]:
    """Exponential bucket upper bounds from ``lo`` to >= ``hi``: the
    default latency/queue-wait scale (1 microsecond .. ~1 minute in
    seconds).  The implicit final bucket is +inf."""
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * growth)
    return tuple(out)


class _Child:
    """One labeled time series of a metric.  Base for value holders."""

    __slots__ = ("_lock",)

    def __init__(self) -> None:
        self._lock = threading.Lock()


class _CounterChild(_Child):
    __slots__ = ("value",)

    def __init__(self) -> None:
        super().__init__()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        with self._lock:
            self.value += amount

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class _GaugeChild(_Child):
    __slots__ = ("value",)

    def __init__(self) -> None:
        super().__init__()
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value -= amount

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class _HistogramChild(_Child):
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple[float, ...]) -> None:
        super().__init__()
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last = +inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self.sum += value
            self.count += 1

    def reset(self) -> None:
        with self._lock:
            self.counts = [0] * len(self.counts)
            self.sum = 0.0
            self.count = 0

    def quantile(self, q: float) -> float | None:
        """Bucket-interpolated sample quantile (None when empty).  See
        the module docstring for the accuracy contract vs the exact
        ``numpy.percentile`` of the observed series."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            total = self.count
            counts = list(self.counts)
        if total == 0:
            return None
        rank = q * (total - 1)  # numpy 'linear' convention
        seen = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if seen + c > rank:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i] if i < len(self.bounds) else lo * HIST_GROWTH
                frac = (rank - seen + 1.0) / c  # position inside bucket
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        # rank == total-1 landed exactly on the last populated bucket
        last = max(i for i, c in enumerate(counts) if c)
        return self.bounds[min(last, len(self.bounds) - 1)]


class Metric:
    """One named metric family: fixed label names, one child per label
    value tuple.  Unlabeled metrics have a single anonymous child."""

    kind = "untyped"

    def __init__(
        self, name: str, help: str, labelnames: tuple[str, ...], **kw: Any
    ) -> None:
        _validate_name(name)
        for ln in labelnames:
            _validate_name(ln)
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._kw = kw
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], Any] = {}

    def _new_child(self) -> Any:
        raise NotImplementedError

    def labels(self, **labels: Any) -> Any:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}"
            )
        key = tuple(str(labels[ln]) for ln in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._new_child()
            return child

    @property
    def _anon(self) -> Any:
        if self.labelnames:
            raise ValueError(
                f"{self.name} declares labels {self.labelnames}; "
                f"use .labels(...)"
            )
        with self._lock:
            child = self._children.get(())
            if child is None:
                child = self._children[()] = self._new_child()
            return child

    def children(self) -> Iterator[tuple[tuple[str, ...], Any]]:
        with self._lock:
            items = list(self._children.items())
        yield from items

    def reset(self) -> None:
        for _, child in self.children():
            child.reset()


class Counter(Metric):
    kind = "counter"

    def _new_child(self) -> _CounterChild:
        return _CounterChild()

    def inc(self, amount: float = 1.0) -> None:
        self._anon.inc(amount)

    @property
    def value(self) -> float:
        return float(self._anon.value)


class Gauge(Metric):
    kind = "gauge"

    def _new_child(self) -> _GaugeChild:
        return _GaugeChild()

    def set(self, value: float) -> None:
        self._anon.set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._anon.inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._anon.dec(amount)

    @property
    def value(self) -> float:
        return float(self._anon.value)


class Histogram(Metric):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: tuple[str, ...],
        buckets: tuple[float, ...] | None = None,
    ) -> None:
        bounds = tuple(buckets) if buckets is not None else default_buckets()
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"{name}: bucket bounds must strictly increase")
        super().__init__(name, help, labelnames, buckets=bounds)
        self.buckets = bounds

    def _new_child(self) -> _HistogramChild:
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        self._anon.observe(value)

    def quantile(self, q: float) -> float | None:
        return self._anon.quantile(q)


def _validate_name(name: str) -> None:
    if not name or not (name[0].isalpha() or name[0] == "_"):
        raise ValueError(f"invalid metric/label name {name!r}")
    if not all(c.isalnum() or c == "_" for c in name):
        raise ValueError(f"invalid metric/label name {name!r}")


class MetricsRegistry:
    """A set of named metrics.  One process-wide default instance lives
    behind :func:`registry`; tests and harnesses may build private ones.

    Re-registering a name returns the existing metric when the kind,
    labels, and bucket bounds match (so two engines share one family),
    and raises on any mismatch — silent redefinition is how dashboards
    break."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, Metric] = {}

    def _get_or_make(self, cls: type, name: str, help: str,
                     labelnames: tuple[str, ...], **kw: Any) -> Any:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if (
                    type(existing) is not cls
                    or existing.labelnames != tuple(labelnames)
                    or existing._kw != (existing._kw | kw)
                ):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(existing).__name__}{existing.labelnames} — "
                        f"conflicting re-registration"
                    )
                return existing
            m = cls(name, help, tuple(labelnames), **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] | None = None) -> Histogram:
        return self._get_or_make(
            Histogram, name, help, labelnames,
            **({} if buckets is None else {"buckets": tuple(buckets)}),
        )

    def get(self, name: str) -> Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def metrics(self) -> list[Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def reset(self) -> None:
        """Zero every child of every metric (benchmark warm-up hygiene);
        families and label children stay registered."""
        for m in self.metrics():
            m.reset()


_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry — what the engine, the fault
    injector, the sweep harness, and the exporters use unless handed a
    private one."""
    return _DEFAULT


def reset_default_registry() -> None:
    """Zero the default registry's values (tests, benchmark warm-up)."""
    _DEFAULT.reset()
