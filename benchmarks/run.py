"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run prime      # substring filter
"""
import sys
import time


def main() -> None:
    from benchmarks import (
        latency_model,
        lm_step,
        polymul_e2e,
        postprocess,
        preprocess,
        prime_search,
        roofline,
    )

    suites = [
        ("prime_search(Table III)", prime_search.run),
        ("latency_model(Fig 17)", latency_model.run),
        ("preprocess(Table IV)", preprocess.run),
        ("postprocess(Table V)", postprocess.run),
        ("polymul_e2e(Tables VI/VII)", polymul_e2e.run),
        ("lm_step(framework)", lm_step.run),
        ("roofline(dry-run artifacts)", roofline.run),
    ]
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for title, fn in suites:
        if flt and flt not in title:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the driver robust: report and continue
            print(f"{title},0.0,SUITE ERROR {type(e).__name__}: {e}")
            continue
        for name, us, derived in rows:
            print(f'{name},{us:.1f},"{derived}"')
        print(f"# {title} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
