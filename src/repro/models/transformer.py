"""Decoder-only transformer family: dense (yi, deepseek, mistral-large),
GQA + M-RoPE backbone (qwen2-vl), local/global + softcap (gemma2), and
MoE variants (llama4-maverick with interleaved dense/MoE, dbrx all-MoE).

Layers are stacked and executed with lax.scan (compile time stays flat in
depth — 88-layer mistral-large lowers as one loop).  Mixed llama4 stacks
scan over (dense, moe) super-blocks.  KV caches are stacked per layer and
threaded through the scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import ctx


def _moe_every(cfg: ModelConfig) -> int:
    """llama4-style interleaving: every k-th layer is MoE (k=2 for llama4);
    1 means every layer (dbrx); 0 means dense model."""
    if cfg.n_experts == 0:
        return 0
    return cfg.moe_every


def block_init(key, cfg: ModelConfig, *, moe: bool, dense_ff: int | None = None):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {
        "ln1": L.rmsnorm_init(d),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(d),
    }
    if moe:
        p["ffn"] = L.moe_init(ks[1], cfg)
    else:
        p["ffn"] = L.mlp_init(ks[1], d, dense_ff or cfg.d_ff)
    return p


def block_apply(p, x, cfg: ModelConfig, positions, *, window=None, cache=None, moe: bool):
    a, new_cache = L.attention_apply(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg, positions,
        layer_window=window, kv_cache=cache,
    )
    x = x + a
    h_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    h = L.moe_apply(p["ffn"], h_in, cfg) if moe else L.mlp_apply(p["ffn"], h_in)
    return ctx.constrain(x + h, "btd"), new_cache


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------


def _windows(cfg: ModelConfig, n: int) -> np.ndarray:
    """Per-layer sliding-window sizes (0 = global attention)."""
    if cfg.sliding_window and cfg.local_global_alternate:
        return np.array(
            [cfg.sliding_window if i % 2 == 0 else 0 for i in range(n)], np.int32
        )
    if cfg.sliding_window:
        return np.full((n,), cfg.sliding_window, np.int32)
    return np.zeros((n,), np.int32)


def init_params(key, cfg: ModelConfig):
    me = _moe_every(cfg)
    d = cfg.d_model
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    if me == 2:
        n_blocks = cfg.n_layers // 2
        keys = jax.random.split(k_layers, n_blocks)
        layers = jax.vmap(
            lambda k: {
                "dense": block_init(
                    jax.random.fold_in(k, 0), cfg, moe=False, dense_ff=2 * cfg.d_ff
                ),
                "moe": block_init(jax.random.fold_in(k, 1), cfg, moe=True),
            }
        )(keys)
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(lambda k: block_init(k, cfg, moe=me == 1))(keys)
    p = {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, d), scale=0.02),
        "layers": layers,
        "final_norm": L.rmsnorm_init(d),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (d, cfg.padded_vocab))
    return p


def embed_inputs(params, cfg: ModelConfig, batch):
    """tokens (B,S) -> embeddings; or pass-through precomputed frontend
    embeddings (vlm/audio stubs)."""
    if "embeddings" in batch:
        x = batch["embeddings"].astype(L.CDTYPE)
    else:
        x = params["embed"][batch["tokens"]].astype(L.CDTYPE)
    if cfg.attn_softcap:  # gemma2 scales embeddings
        x = x * jnp.asarray(np.sqrt(cfg.d_model), L.CDTYPE)
    return ctx.constrain(x, "btd")


def unembed(params, cfg: ModelConfig, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(L.CDTYPE)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    # NOTE: returns PADDED-vocab logits (padded_vocab columns).  The loss
    # masks the padding classes; decode paths slice to cfg.vocab.  Keeping
    # the padded width preserves vocab-sharding over the model axis
    # (slicing to 50280 of 50432 would force an all-gather of the logits —
    # observed 13 GB/step in the first dry-run).
    return logits


def _grouped(stack, windows, group: int):
    """Reshape a stacked-layer pytree (L, ...) into (L/group, group, ...)."""
    lead = windows.shape[0]
    assert lead % group == 0, (lead, group)
    f = lambda a: a.reshape((lead // group, group) + a.shape[1:])
    return jax.tree.map(f, stack), windows.reshape(lead // group, group)


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            remat_group: int = 1, last_only: bool = False):
    """Training/prefill forward -> logits (B,S,V).

    remat_group > 1 checkpoints only every `group`-th layer boundary
    (sqrt-depth activation memory at sqrt-depth recompute — the standard
    large-model memory lever, see EXPERIMENTS §Perf)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    positions = batch.get(
        "positions",
        jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
    )
    me = _moe_every(cfg)
    if me == 2:
        windows = jnp.asarray(_windows(cfg, cfg.n_layers // 2))

        def one(x, lp, w):
            x, _ = block_apply(lp["dense"], x, cfg, positions, window=w, moe=False)
            x, _ = block_apply(lp["moe"], x, cfg, positions, window=w, moe=True)
            return x

    else:
        windows = jnp.asarray(_windows(cfg, cfg.n_layers))

        def one(x, lp, w):
            x, _ = block_apply(lp, x, cfg, positions, window=w, moe=me == 1)
            return x

    stack = params["layers"]
    if remat_group > 1 and windows.shape[0] % remat_group == 0:
        stack, windows = _grouped(stack, windows, remat_group)

        def body(x, inp):
            lps, ws = inp
            for i in range(remat_group):
                x = one(x, jax.tree.map(lambda a: a[i], lps), ws[i])
            return x, None

    else:

        def body(x, inp):
            lp, w = inp
            return one(x, lp, w), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (stack, windows))
    if last_only:
        x = x[:, -1:]
    return unembed(params, cfg, x)


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hk, dh = cfg.n_kv_heads, cfg.head_dim_
    me = _moe_every(cfg)
    n_slots = cfg.n_layers if me != 2 else cfg.n_layers  # 2 per super-block
    shape = (n_slots, batch, max_len, hk, dh)
    return {
        "k": jnp.zeros(shape, L.CDTYPE),
        "v": jnp.zeros(shape, L.CDTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, batch):
    """One token step.  batch: {"tokens": (B,1)} (or embeddings), cache as
    from init_cache (possibly prefilled).  Returns (logits (B,1,V), cache)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    pos = cache["pos"]
    positions = pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    me = _moe_every(cfg)

    if me == 2:
        windows = jnp.asarray(_windows(cfg, cfg.n_layers // 2))
        kk = cache["k"].reshape((cfg.n_layers // 2, 2) + cache["k"].shape[1:])
        vv = cache["v"].reshape((cfg.n_layers // 2, 2) + cache["v"].shape[1:])

        def body(x, inp):
            lp, w, ck, cv = inp
            x, nc1 = block_apply(
                lp["dense"], x, cfg, positions, window=w, moe=False,
                cache={"k": ck[0], "v": cv[0], "pos": pos},
            )
            x, nc2 = block_apply(
                lp["moe"], x, cfg, positions, window=w, moe=True,
                cache={"k": ck[1], "v": cv[1], "pos": pos},
            )
            return x, (
                jnp.stack([nc1["k"], nc2["k"]]),
                jnp.stack([nc1["v"], nc2["v"]]),
            )

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], windows, kk, vv))
        new_cache = {
            "k": nk.reshape(cache["k"].shape),
            "v": nv.reshape(cache["v"].shape),
            "pos": pos + S,
        }
    else:
        windows = jnp.asarray(_windows(cfg, cfg.n_layers))

        def body(x, inp):
            lp, w, ck, cv = inp
            x, nc = block_apply(
                lp, x, cfg, positions, window=w, moe=me == 1,
                cache={"k": ck, "v": cv, "pos": pos},
            )
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], windows, cache["k"], cache["v"])
        )
        new_cache = {"k": nk, "v": nv, "pos": pos + S}
    return unembed(params, cfg, x), new_cache
