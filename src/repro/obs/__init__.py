"""Unified observability layer: metrics registry, request-scoped
tracing, per-stage device profiling, and exporters (DESIGN.md §12).

Quickstart::

    from repro import obs

    reg = obs.registry()                       # process-wide registry
    log = obs.SpanLog("spans.jsonl")           # JSONL span sink
    eng = PolymulEngine(pl, span_log=log)      # traces every request
    ...
    log.flush()
    print(obs.to_prometheus(reg))              # scrape-ready text
    obs.conservation(obs.read_jsonl("spans.jsonl"))  # lifecycle audit

``python -m repro.launch.obs_report spans.jsonl`` renders the
latency/throughput/stage-breakdown report and runs the conservation
gate from the CLI.
"""
from repro.obs.export import parse_prometheus, to_json, to_prometheus
from repro.obs.metrics import (
    HIST_GROWTH,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_buckets,
    registry,
    reset_default_registry,
)
from repro.obs.profiling import STAGES, predicted_stage_bytes, stage_timings
from repro.obs.tracing import (
    TERMINAL_STATUSES,
    Span,
    SpanLog,
    conservation,
    read_jsonl,
)

__all__ = [
    "HIST_GROWTH",
    "STAGES",
    "TERMINAL_STATUSES",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "SpanLog",
    "conservation",
    "default_buckets",
    "parse_prometheus",
    "predicted_stage_bytes",
    "read_jsonl",
    "registry",
    "reset_default_registry",
    "stage_timings",
    "to_json",
    "to_prometheus",
]
